"""Shared harness for the FL benchmarks (Tables/Figures of the paper).

Runs an algorithm on the synthetic non-iid task and returns accuracy,
per-round wall time and communication cost. Scaled to CPU budgets:
same protocol as the paper (20 clients, label-skew, R local steps),
smaller nets and round counts.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import BaselineConfig, BaselineFL
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.models import smallnets as sn


def make_task(num_clients=10, noise=1.2, concept_shift=True, hidden=64,
              classes_per_client=2, seed=0):
    data = ds.make_federated_classification(
        jax.random.key(seed), num_clients=num_clients, noise=noise,
        classes_per_client=classes_per_client, concept_shift=concept_shift,
        train_per_client=192, test_per_client=96,
    )
    init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=hidden)
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    eval_fn = lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
    return data, init_fn, loss_fn, eval_fn


def run_algo(algo, data, init_fn, loss_fn, eval_fn, *, rounds=15,
             local_steps=5, batch=32, lr=0.05, participate=None, seed=0,
             lam=5e-4, mu=1e-5, gamma=1e4, m_ratio=0.1, chunk=4096):
    k = data.num_clients
    participate = participate or k
    template = jax.eval_shape(init_fn, jax.random.key(1))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(template))
    nt = len(jax.tree.leaves(template))

    if algo == "pfed1bs":
        eng = PFed1BS(PFed1BSConfig(
            num_clients=k, participate=participate, local_steps=local_steps,
            lr=lr, lam=lam, mu=mu, gamma=gamma, m_ratio=m_ratio, chunk=chunk,
            sketch_seed=seed), loss_fn, template)
        m_dim = eng.spec.m
    else:
        eng = BaselineFL(BaselineConfig(
            algo=algo, num_clients=k, participate=participate,
            local_steps=local_steps, lr=lr, m_ratio=m_ratio, chunk=chunk,
            seed=seed), loss_fn, template)
        m_dim = eng.spec.m

    state = eng.init(init_fn, jax.random.key(seed + 1))
    losses = []
    t0 = time.time()
    for r in range(rounds):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(seed + 2), r))
        batches = ds.sample_round_batches(kb, data, local_steps, batch)
        state, m = eng.round(state, batches, data.weights, kr)
        losses.append(float(m["task_loss"]))
    wall = time.time() - t0

    # Evaluation semantics (documented in experiments/bench/EXP_MATRIX.md):
    # personalized engines are scored with each client's OWN model on its
    # own shard (`acc`); a mean-of-clients consensus model scored the same
    # way is recorded as `acc_global` so the table is comparable with the
    # single-global-model baselines (where acc == acc_global by
    # construction). Under concept_shift the global number is expected to
    # collapse — that asymmetry is the paper's point, not a bug.
    if hasattr(state, "clients"):
        personalized = True
        accs = jax.vmap(eval_fn)(state.clients, data.test_x, data.test_y)
        consensus = jax.tree.map(lambda x: x.mean(0), state.clients)
        gaccs = jax.vmap(lambda x, y: eval_fn(consensus, x, y))(
            data.test_x, data.test_y)
    else:
        personalized = False
        accs = jax.vmap(lambda x, y: eval_fn(state.params, x, y))(
            data.test_x, data.test_y)
        gaccs = accs
    bits = comms.round_bits(algo, n=n, m=m_dim, s=participate, num_tensors=nt)
    return {
        "algo": algo,
        "personalized": personalized,
        "acc": float(accs.mean()),
        "acc_global": float(gaccs.mean()),
        "acc_std": float(accs.std()),
        "loss_curve": losses,
        "mb_per_round": bits["total_mb"],
        "reduction_vs_fedavg": comms.reduction_vs_fedavg(
            algo, n=n, m=m_dim, s=participate, num_tensors=nt),
        "us_per_round": wall / rounds * 1e6,
    }
