"""Fused vs staged SRHT benchmark -> BENCH_sketch.json.

Times the two levers of the fused-sketch PR (DESIGN.md §3.3, §4):

  1. sketch micro-bench: fused dispatch (`sketch_forward_2d` /
     `sketch_adjoint`) vs the seed's staged four-stage pipeline
     (`sketch_forward_2d_staged` / `sketch_adjoint_staged`), plus the
     packed-uplink epilogue, at paper-scale n on the host's default impl.
  2. round bench: one full `PFed1BS.round` on the synthetic non-iid FL task
     with the restructured hot path (`fused_round=True`: gather -> vmapped
     update on the S sampled clients -> scatter, one sketch per client per
     round) vs the seed path (`fused_round=False`: all-K update + mask,
     re-sketching potential).

Emits BENCH_sketch.json at the repo root (and a copy under
experiments/bench/) with per-case microseconds, the round speedup, and a
fused-vs-staged parity check.

Run:  PYTHONPATH=src python -m benchmarks.sketch_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig


def _time(fn, *args, reps=30, warmup=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_sketch_micro(fast=False):
    n = 2 ** 14 if fast else 2 ** 16
    spec = sk.make_sketch_spec(n, 0.1, chunk=4096)
    x = jax.random.normal(jax.random.key(0), (n,))
    v = jax.random.normal(jax.random.key(1), (spec.m,))

    fwd_fused = jax.jit(lambda w: sk.sketch_forward_2d(spec, w))
    fwd_staged = jax.jit(lambda w: sk.sketch_forward_2d_staged(spec, w))
    adj_fused = jax.jit(lambda u: sk.sketch_adjoint(spec, u))
    adj_staged = jax.jit(lambda u: sk.sketch_adjoint_staged(spec, u))
    # packed epilogue needs m_chunk % 32 == 0 -> bench it on a 1/8 ratio spec
    spec_p = sk.make_sketch_spec(n, 0.125, chunk=4096)
    fwd_packed = jax.jit(lambda w: sk.sketch_forward_packed(spec_p, w))

    parity = float(jnp.max(jnp.abs(fwd_fused(x) - fwd_staged(x))))
    rel = parity / float(jnp.max(jnp.abs(fwd_staged(x))))
    impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    out = {
        "n": n,
        "m": spec.m,
        "chunk": spec.chunk,
        "impl": impl,
        # on a ref host the dispatch falls back to the staged pipeline, so
        # fused-vs-staged micro timings compare identical code (parity 0.0
        # confirms it) — only the round numbers are meaningful there
        "micro_comparison_valid": impl == "pallas",
        "fwd_fused_us": _time(fwd_fused, x),
        "fwd_staged_us": _time(fwd_staged, x),
        "adj_fused_us": _time(adj_fused, v),
        "adj_staged_us": _time(adj_staged, v),
        "fwd_packed_us": _time(fwd_packed, x),
        "fwd_parity_max_abs": parity,
        "fwd_parity_max_rel": rel,
    }
    out["fwd_speedup"] = out["fwd_staged_us"] / out["fwd_fused_us"]
    out["adj_speedup"] = out["adj_staged_us"] / out["adj_fused_us"]
    return out


def bench_round(fast=False):
    from benchmarks.fl_bench import make_task

    num_clients, participate = 10, 5
    local_steps, batch = 5, 32
    data, init_fn, loss_fn, _ = make_task(num_clients=num_clients)
    from repro.data import synthetic as ds

    template = jax.eval_shape(init_fn, jax.random.key(1))
    rounds = 4 if fast else 12

    # pre-generate all round batches so the bench times the round itself,
    # not the synthetic data loader
    batch_sets, round_keys = [], []
    for r in range(rounds + 1):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(4), r))
        batch_sets.append(
            jax.block_until_ready(ds.sample_round_batches(kb, data, local_steps, batch))
        )
        round_keys.append(kr)

    def make(fused: bool):
        cfg = PFed1BSConfig(
            num_clients=num_clients, participate=participate,
            local_steps=local_steps, chunk=4096, fused_round=fused,
        )
        eng = PFed1BS(cfg, loss_fn, template)
        state = eng.init(init_fn, jax.random.key(2))
        # warmup: compile + one executed round
        state, m = eng.round(state, batch_sets[0], data.weights, round_keys[0])
        jax.block_until_ready(m["task_loss"])
        return eng, state

    # interleave the staged and fused rounds and median-reduce per-round
    # times: host contention on a shared CPU box swings absolute wall-clock
    # by 2-3x over seconds, so back-to-back phases would compare different
    # machine states; alternating rounds sees the same noise on both sides
    eng_s, st_s = make(fused=False)
    eng_f, st_f = make(fused=True)
    t_staged, t_fused = [], []
    for r in range(1, rounds + 1):
        t0 = time.perf_counter()
        st_s, m_s = eng_s.round(st_s, batch_sets[r], data.weights, round_keys[r])
        jax.block_until_ready(m_s["task_loss"])
        t_staged.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        st_f, m_f = eng_f.round(st_f, batch_sets[r], data.weights, round_keys[r])
        jax.block_until_ready(m_f["task_loss"])
        t_fused.append(time.perf_counter() - t0)
    staged_us = float(np.median(t_staged)) * 1e6
    fused_us = float(np.median(t_fused)) * 1e6
    staged_loss, fused_loss = float(m_s["task_loss"]), float(m_f["task_loss"])
    return {
        "num_clients": num_clients,
        "participate": participate,
        "local_steps": local_steps,
        "rounds_timed": rounds,
        "round_staged_us": staged_us,
        "round_fused_us": fused_us,
        "round_speedup": staged_us / fused_us,
        "task_loss_staged": staged_loss,
        "task_loss_fused": fused_loss,
    }


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """Single writer for the BENCH_sketch artifacts (also used by
    benchmarks/run.py). --fast smoke runs land in BENCH_sketch.fast.json by
    default and never touch the canonical copies."""
    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_sketch.fast.json" if fast else "BENCH_sketch.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_sketch.json", "w") as f:
            json.dump(results, f, indent=2)
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = {
        "fast": args.fast,
        "sketch": bench_sketch_micro(fast=args.fast),
        "round": bench_round(fast=args.fast),
    }
    s, r = results["sketch"], results["round"]
    print(f"sketch fwd: staged {s['fwd_staged_us']:.0f}us  fused "
          f"{s['fwd_fused_us']:.0f}us  ({s['fwd_speedup']:.2f}x, "
          f"parity {s['fwd_parity_max_rel']:.2e})")
    print(f"sketch adj: staged {s['adj_staged_us']:.0f}us  fused "
          f"{s['adj_fused_us']:.0f}us  ({s['adj_speedup']:.2f}x)")
    print(f"round:      staged {r['round_staged_us']:.0f}us  fused "
          f"{r['round_fused_us']:.0f}us  ({r['round_speedup']:.2f}x)")

    out_path = write_artifacts(results, args.out)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
