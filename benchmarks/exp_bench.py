"""Scenario-matrix bench: algorithm x heterogeneity accuracy-vs-bits sweep.

Runs the exp/ harness (src/repro/exp/) over the named heterogeneity matrix
and emits the paper-style Table-1/2 artifact:

  BENCH_exp.json        canonical: all 7 algorithms x all 7 scenarios,
                        12 rounds, periodic eval curves (also mirrored to
                        experiments/bench/ with the rendered markdown at
                        experiments/bench/EXP_MATRIX.md)
  BENCH_exp.fast.json   --fast smoke tier: 7 algorithms x 3 scenarios
                        (iid / dir0.1 / straggler — one cell per
                        heterogeneity axis), 3 rounds; never touches the
                        canonical artifacts

Both artifacts pass exp/report.validate_matrix — including the invariant
that every cell's billed bits (pFed1BS's in particular) re-derive EXACTLY
from fl/comms.accumulate_round_bits over the recorded per-round realized
participation. `python -m benchmarks.report --validate` re-checks this
from the file, which is what the CI bench-smoke job gates on.

Run: PYTHONPATH=src python -m benchmarks.run exp [--fast]
     (or this module directly: python -m benchmarks.exp_bench [--fast])
"""
from __future__ import annotations

import argparse
import json
import os

FAST_SCENARIOS = ("iid", "dir0.1", "straggler")


def bench_matrix(fast: bool = False, progress=None,
                 trace: bool = False) -> dict:
    """trace=True additionally records a wall-clock obs.Tracer through the
    whole sweep (per-cell spans, per-round engine spans, cumulative bit
    counters) and dumps TRACE_exp[.fast].json — validated on the spot by
    obs.validate_trace, which re-derives the counter totals from the
    cells' billing specs via fl/comms."""
    from repro import obs
    from repro.exp import report, runner, scenarios

    matrix = scenarios.paper_matrix()
    if fast:
        cfg = runner.ExpConfig(
            num_clients=8, rounds=3, local_steps=2, batch=16, hidden=32,
            train_per_client=64, test_per_client=32, chunk=2048,
        )
        use = {k: matrix[k] for k in FAST_SCENARIOS}
    else:
        cfg = runner.ExpConfig(
            num_clients=10, rounds=12, local_steps=4, batch=24, hidden=48,
            train_per_client=128, test_per_client=64, chunk=2048,
            eval_every=3, noise_scale=3.0,  # hard enough that the matrix
            #                                 separates the algorithms
        )
        use = matrix
    tracer = obs.Tracer(clock="wall") if trace else None
    results = runner.sweep(
        runner.ALGOS, use, cfg, progress=progress, tracer=tracer
    )
    results["fast"] = fast
    report.validate_matrix(results)
    if tracer is not None:
        trace_path = "TRACE_exp.fast.json" if fast else "TRACE_exp.json"
        obs.dump_trace(
            trace_path, tracer,
            billing=[c["billing"] for c in results["cells"]],
            meta={"bench": "exp", "fast": fast},
        )
        obs.validate_trace(json.load(open(trace_path)))
        results["trace_path"] = trace_path
    return results


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """BENCH_exp.json writer; --fast runs land in BENCH_exp.fast.json and
    never touch the canonical artifacts (same policy as the other benches).
    The canonical run also renders experiments/bench/EXP_MATRIX.md."""
    from repro.exp import report

    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_exp.fast.json" if fast else "BENCH_exp.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_exp.json", "w") as f:
            json.dump(results, f, indent=2)
        with open("experiments/bench/EXP_MATRIX.md", "w") as f:
            f.write(report.matrix_markdown(results))
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="also dump + validate TRACE_exp[.fast].json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = bench_matrix(
        fast=args.fast, trace=args.trace,
        progress=lambda c: print(
            f"{c['algo']:9s} x {c['scenario']:11s} acc={c['acc']:.4f} "
            f"bits={c['total_bits']:>12,} s/round={c['s_per_round']}",
            flush=True,
        ),
    )
    path = write_artifacts(results, args.out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
