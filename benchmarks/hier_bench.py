"""Hierarchical federation bench: tree-of-aggregators root-ingress scaling
plus the counter-merge parity cell — emits BENCH_hier.json (DESIGN.md §11).

Two result blocks, in the order the numbers should be read:

  counter_merge_parity  the CALIBRATION cell, measured on a real (small)
                        federation: the tree executor (launch/fedexec.
                        hier_round, partial popcount counters merged
                        fan_out-at-a-time up the tiers) must be BIT-exact
                        with the flat popcount server — same consensus
                        words, same client params, same loss curve, per
                        round, for every tested topology (balanced,
                        ragged, single-leaf). Plus a pure vote sweep:
                        core/consensus.tree_vote_popcount vs the flat
                        kernels/ops.vote_popcount on random packed words.
                        If this cell drifts, the count-merge stopped being
                        sum-decomposable and every scaling row below is
                        fiction.
  scaling               the headline curve: clients S on a log scale,
                        10^3 -> 10^6, at fixed fan-out. Root ingress of
                        the flat server is S*m bits (linear); the tree
                        root ingests fan_out counters of
                        ceil(log2(w+1))*m bits each — O(m log S), flat on
                        this axis. Rows are billed analytically via
                        fl/comms.hier_round_bits over the exact
                        HierTopology the executor would build; rows with
                        clients > the real-run limit are marked
                        "simulated": true — no client weights are
                        materialized at 10^6 clients (that is the point
                        of the curve), only the wire accounting, which
                        benchmarks/report.py --validate re-derives from
                        fl/comms per row.

Run: PYTHONPATH=src python -m benchmarks.run hier [--fast]
     (or this module directly: python -m benchmarks.hier_bench [--fast])
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

# real engine pairs are run up to this many clients; scaling rows above it
# are analytic billing only (the note in the artifact says exactly this)
REAL_RUN_LIMIT = 64

SIMULATED_NOTE = (
    "scaling rows with simulated=true are analytic wire accounting "
    f"(fl/comms.hier_round_bits over HierTopology.build): above {REAL_RUN_LIMIT} "
    "clients no client weights are materialized — the counter-merge itself "
    "is pinned bit-exact by the counter_merge_parity cell and "
    "tests/test_hier.py, and the per-row bits are re-derived from fl/comms "
    "by benchmarks/report.py --validate."
)


def _engine_parity(fast: bool, progress=None) -> dict:
    """Real small runs: hier_round vs the flat popcount sharded_round,
    identical inputs, bit-exact state or bust."""
    import jax
    import jax.numpy as jnp

    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.data import synthetic as ds
    from repro.launch.fedexec import HierTopology
    from repro.models import smallnets as sn

    s = 8
    rounds = 2
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=s, train_per_client=32,
        test_per_client=16, noise=0.8,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=16)
    template = jax.eval_shape(init_fn, jax.random.key(1))

    base = dict(num_clients=s, participate=s, local_steps=2, m_ratio=0.05,
                chunk=2048, sharded_round=True, vote="popcount")
    topos = {
        "fan2-balanced": HierTopology.build(s, fan_out=2),
        "fan4-balanced": HierTopology.build(s, fan_out=4),
    }
    if not fast:
        topos["ragged"] = HierTopology(leaf_sizes=(1, 3, 4), fan_out=2)
        topos["single-leaf"] = HierTopology(leaf_sizes=(s,), fan_out=4)

    def run(cfg):
        eng = PFed1BS(cfg, loss_fn, template)
        state = eng.init(init_fn, jax.random.key(2))
        losses = []
        for r in range(rounds):
            kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), r))
            batches = ds.sample_round_batches(kb, data, cfg.local_steps, 16)
            state, m = eng.round(state, batches, data.weights, kr)
            losses.append(float(m["task_loss"]))
        return state, losses, m

    cfg_flat = PFed1BSConfig(**base)
    st_flat, losses_flat, _ = run(cfg_flat)
    cells, bit_exact = [], True
    for name, topo in topos.items():
        st_t, losses_t, m_t = run(dataclasses.replace(cfg_flat, topology=topo))
        same = bool(np.array_equal(np.asarray(st_t.v), np.asarray(st_flat.v)))
        for a, b in zip(jax.tree.leaves(st_t.clients),
                        jax.tree.leaves(st_flat.clients)):
            same = same and bool(np.array_equal(np.asarray(a), np.asarray(b)))
        same = same and losses_t == losses_flat
        bit_exact = bit_exact and same
        cell = {
            "topology": name,
            "leaf_sizes": list(topo.leaf_sizes),
            "fan_out": topo.fan_out,
            "tiers": int(m_t["tiers"]),
            "root_ingress_bits": int(m_t["root_ingress_bits"]),
            "bit_exact": same,
        }
        cells.append(cell)
        if progress is not None:
            progress(f"parity:{name}", cell)

    # pure vote sweep: tree counters vs the flat popcount kernel on random
    # packed words (no training in the loop — the vote alone, wider shapes)
    from repro.core import consensus
    from repro.kernels import ops as kops

    rng = np.random.default_rng(7)
    vote_cases = []
    for k, leaves, fan in [(9, (3, 3, 3), 2), (16, (4, 4, 4, 4), 4),
                           (11, (1, 3, 3, 4), 2)]:
        words = jnp.asarray(
            rng.integers(0, 2 ** 32, size=(k, 40), dtype=np.uint32)
        )
        tree = np.asarray(consensus.tree_vote_popcount(words, leaves))
        flat = np.asarray(kops.vote_popcount(words))
        same = bool(np.array_equal(tree, flat))
        bit_exact = bit_exact and same
        vote_cases.append({"clients": k, "leaf_sizes": list(leaves),
                           "fan_out": fan, "bit_exact": same})

    return {
        "bit_exact": bit_exact,
        "clients": s,
        "rounds": rounds,
        "engine_cells": cells,
        "vote_cases": vote_cases,
    }


def _traced_async_run(fast: bool) -> dict:
    """A small REAL HierAsyncSimulator run recorded on a virtual-clock
    obs.Tracer: dispatch/arrive instants, per-tier forward instants,
    per-version root spans and cumulative bit counters, all on the
    simulator's own virtual clock — dumped as TRACE_hier[.fast].json with
    a "hier" billing spec re-derived by obs.validate_trace. Virtual time
    means seed-identical runs export byte-identical files."""
    import jax

    from repro import obs
    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.core import rounds as rounds_mod
    from repro.data import synthetic as ds
    from repro.launch.fedexec import HierTopology
    from repro.models import smallnets as sn
    from repro.sim.clock import ComputeNetworkLatency
    from repro.sim.hier import HierAsyncSimulator, HierSimConfig, TierSpec

    k = s = 8
    versions = 2 if fast else 3
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=k, train_per_client=32,
        test_per_client=16, noise=0.8,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda kk: sn.init_mlp(kk, input_dim=784, hidden=16)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    topo = HierTopology.build(s, fan_out=2)
    eng = PFed1BS(
        PFed1BSConfig(num_clients=k, participate=s, local_steps=2,
                      m_ratio=0.05, chunk=2048, sharded_round=True,
                      vote="popcount", topology=topo),
        loss_fn, template,
    )
    pf = lambda v: rounds_mod.draw_participants(
        jax.random.fold_in(jax.random.key(7), v), k, s, None
    )
    bf = lambda v: ds.sample_round_batches(
        jax.random.fold_in(jax.random.key(9), v), data, 2, 16
    )
    tracer = obs.Tracer(clock="virtual")
    sim = HierAsyncSimulator(
        eng,
        HierSimConfig(topology=topo, max_versions=versions,
                      client_latency=ComputeNetworkLatency(),
                      tiers=(TierSpec(latency=ComputeNetworkLatency()),)),
        data.weights, pf, bf, tracer=tracer,
    )
    _, report = sim.run(eng.init(init_fn, jax.random.key(2)))

    trace_path = "TRACE_hier.fast.json" if fast else "TRACE_hier.json"
    billing = {
        "kind": "hier", "m": eng.m,
        "uplink_events": [
            [tier, width]
            for _, tier, width, _ in report.meter.uplink_events
        ],
        "versions": report.versions,
        "levels": len(topo.level_widths()),
    }
    obs.dump_trace(trace_path, tracer, billing=[billing],
                   meta={"bench": "hier", "fast": fast})
    obs.validate_trace(json.load(open(trace_path)))
    return {
        "trace_path": trace_path,
        "versions": report.versions,
        "events": len(tracer.events),
        "uplink_bits": report.meter.uplink_bits,
        "downlink_bits": report.meter.downlink_bits,
    }


def bench_hier(fast: bool = False, progress=None, trace: bool = False) -> dict:
    from repro.fl import comms
    from repro.launch.fedexec import HierTopology

    m = 4096
    fan_out = 32
    client_counts = (
        [1_000, 10_000, 1_000_000] if fast
        else [1_000, 3_162, 10_000, 31_623, 100_000, 316_228, 1_000_000]
    )

    parity = _engine_parity(fast, progress=progress)

    scaling = []
    for s in client_counts:
        topo = HierTopology.build(s, fan_out=fan_out)
        hb = topo.round_bits(m)
        row = {
            "clients": s,
            "fan_out": fan_out,
            "tiers": hb["tiers"],
            "root_ingress_bits": hb["root_ingress_bits"],
            "flat_ingress_bits": s * m,
            "uplink_bits": hb["uplink_bits"],
            "downlink_bits": hb["downlink_bits"],
            "tier_uplink_bits": hb["tier_uplink_bits"],
            "simulated": s > REAL_RUN_LIMIT,
        }
        scaling.append(row)
        if progress is not None:
            progress(f"scale:{s}", row)

    traced = _traced_async_run(fast) if trace else None

    first, last = scaling[0], scaling[-1]
    return {
        "fast": fast,
        "m": m,
        "fan_out": fan_out,
        "counter_merge_parity": parity,
        **({"trace": traced} if traced is not None else {}),
        "scaling": scaling,
        "root_ingress_growth": (
            last["root_ingress_bits"] / first["root_ingress_bits"]
        ),
        "flat_ingress_growth": (
            last["flat_ingress_bits"] / first["flat_ingress_bits"]
        ),
        "simulated_note": SIMULATED_NOTE,
    }


def hier_markdown(results: dict) -> str:
    lines = [
        "# Hierarchical federation: root ingress vs client count",
        "",
        f"m = {results['m']} sketch bits, fan-out {results['fan_out']}; "
        f"counter-merge parity bit_exact = "
        f"{results['counter_merge_parity']['bit_exact']}.",
        "",
        "| clients | tiers | root ingress (bits) | flat server (bits) | "
        "ratio | simulated |",
        "|---|---|---|---|---|---|",
    ]
    for r in results["scaling"]:
        lines.append(
            f"| {r['clients']:,} | {r['tiers']} | {r['root_ingress_bits']:,} "
            f"| {r['flat_ingress_bits']:,} "
            f"| {r['flat_ingress_bits'] / r['root_ingress_bits']:.0f}x "
            f"| {r['simulated']} |"
        )
    lines += ["", results["simulated_note"], ""]
    return "\n".join(lines)


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """BENCH_hier.json writer; --fast runs land in BENCH_hier.fast.json and
    never touch the canonical artifacts. The canonical run also renders
    experiments/bench/HIER.md."""
    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_hier.fast.json" if fast else "BENCH_hier.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_hier.json", "w") as f:
            json.dump(results, f, indent=2)
        with open("experiments/bench/HIER.md", "w") as f:
            f.write(hier_markdown(results))
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="also dump + validate TRACE_hier[.fast].json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    results = bench_hier(
        fast=args.fast, trace=args.trace,
        progress=lambda tag, c: print(f"{tag:16s} {json.dumps(c)[:110]}",
                                      flush=True),
    )
    print(f"note: {SIMULATED_NOTE}")
    print(
        f"root ingress growth 10^3 -> 10^6 clients: "
        f"{results['root_ingress_growth']:.2f}x (flat: "
        f"{results['flat_ingress_growth']:.0f}x)"
    )
    path = write_artifacts(results, args.out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
