"""Async-vs-sync federation bench: time-to-target accuracy under straggler
tails — emits BENCH_async.json (--fast: BENCH_async.fast.json).

The claim this artifact carries: under a heavy-tailed latency scenario
(exp/scenarios.async_matrix()["straggler-tail"]), the buffered async tier
(repro/sim) reaches the same accuracy in LESS virtual time than the
synchronous fused round, at equal billed uplink bits — because a
synchronous round waits for the slowest active client (the tail pays
~tail_mult x base almost every round at realistic cohort sizes) while the
buffered server flushes on the fastest B arrivals and discounts
stragglers by staleness instead of waiting for them.

Both runs share the task, the participation draws' key discipline, the
latency model and the bit meter:

  sync    T rounds; round r costs max over the round's ACTIVE clients of
          latency.duration(seed, c, r) virtual seconds (the server waits
          for the slowest upload it accepts); billed via fl/comms with
          s_r = sum(active).
  async   buffer B < S, staleness exponent p; max_versions = T*S/B so the
          two runs bill the SAME uplink bits (same number of client
          uploads; async pays more m-bit broadcasts — that difference is
          in the artifact, and is tiny: m bits per extra flush).

The artifact also carries the sync-parity cell (the keystone invariant
re-checked end-to-end: zero latency + B=S + p=0 drain bit-exact vs the
sync engine, EF on and off) and a cost-model-at-scale block that prices
the protocol at a REAL architecture size from repro/configs (the paper's
table uses n = 1e6; granite-8b is ~8e9 — the async tier is aimed at the
latter). `benchmarks/report.py --validate` gates the schema AND re-derives
every bit count through fl/comms (sim/metrics.validate_async_artifact).

Run: PYTHONPATH=src python -m benchmarks.run async [--fast]
     (or directly: python -m benchmarks.async_bench [--fast])
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import numpy as np


def _build(fast: bool):
    """Task + engine + the shared draw/batch closures."""
    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.data import synthetic as ds
    from repro.exp import scenarios
    from repro.models import smallnets as sn

    if fast:
        knobs = dict(num_clients=8, rounds=6, local_steps=2, batch=16,
                     hidden=32, train_per_client=64, test_per_client=32)
    else:
        knobs = dict(num_clients=10, rounds=12, local_steps=4, batch=24,
                     hidden=48, train_per_client=128, test_per_client=64)

    sc = scenarios.async_matrix()["straggler-tail"]
    sc = dataclasses.replace(sc, noise=sc.noise * 2.0)  # separable but hard
    data = sc.build(
        jax.random.key(0), knobs["num_clients"],
        train_per_client=knobs["train_per_client"],
        test_per_client=knobs["test_per_client"],
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda k: sn.init_mlp(
        k, input_dim=784, hidden=knobs["hidden"], classes=10
    )
    eval_fn = lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    capacity = sc.capacity(knobs["num_clients"])
    eng = PFed1BS(
        PFed1BSConfig(
            num_clients=knobs["num_clients"], participate=capacity,
            local_steps=knobs["local_steps"], m_ratio=0.1, chunk=2048,
        ),
        loss_fn, template,
    )

    participants_fn = lambda v: sc.draw_participants(
        jax.random.key(17), v, knobs["num_clients"]
    )
    batch_fn = lambda v: ds.sample_round_batches(
        jax.random.fold_in(jax.random.key(23), v), data,
        knobs["local_steps"], knobs["batch"],
    )

    def evaluate(state):
        accs = jax.vmap(eval_fn)(state.clients, data.test_x, data.test_y)
        return float(accs.mean())

    return sc, data, eng, init_fn, participants_fn, batch_fn, evaluate, knobs


def run_sync(sc, data, eng, init_fn, participants_fn, batch_fn, evaluate,
             rounds: int, seed: int = 0) -> dict:
    """Synchronous fused rounds with a virtual wall clock: each round
    costs the slowest active client's latency."""
    from repro.fl import comms

    state = eng.init(init_fn, jax.random.key(2))
    t = 0.0
    s_per_round, curve, round_times = [], [], []
    for r in range(rounds):
        idx, active = participants_fn(r)
        idx_np, act_np = np.asarray(idx), np.asarray(active)
        durations = [
            sc.latency.duration(seed, int(c), r)
            for c, a in zip(idx_np, act_np) if a > 0
        ]
        t += max(durations) if durations else 0.0
        round_times.append(max(durations) if durations else 0.0)
        state, _ = eng.round(
            state, batch_fn(r), data.weights, jax.random.key(0),
            (idx, active),
        )
        s_per_round.append(int(round(float(np.sum(act_np)))))
        curve.append((t, evaluate(state)))
    bits = comms.accumulate_round_bits(
        "pfed1bs", n=eng.n, m=eng.m, s_per_round=s_per_round
    )
    # cumulative billed bits after each round (uploads + that round's
    # m-bit broadcast) on the same virtual clock as acc_curve
    cum = np.cumsum(s_per_round) * eng.m + np.arange(1, rounds + 1) * eng.m
    return {
        "rounds": rounds,
        "s_per_round": s_per_round,
        "round_times": round_times,
        "cum_bits_curve": [[t_, int(b)] for (t_, _), b in zip(curve, cum)],
        "acc_curve": [[t_, a] for t_, a in curve],
        "final_acc": curve[-1][1],
        "final_t": curve[-1][0],
        "uplink_bits": bits["uplink_bits"],
        "downlink_bits": bits["downlink_bits"],
        "total_bits": bits["total_bits"],
    }


def run_async(data, eng, init_fn, participants_fn, batch_fn, evaluate,
              latency, buffer_size: int, max_versions: int,
              staleness_exponent: float = 0.5, seed: int = 0,
              tracer=None) -> dict:
    from repro.sim import metrics as simmetrics
    from repro.sim.server import AsyncConfig, AsyncSimulator

    cfg = AsyncConfig(
        buffer_size=buffer_size, staleness_exponent=staleness_exponent,
        max_versions=max_versions, seed=seed, latency=latency,
    )
    sim = AsyncSimulator(eng, cfg, data.weights, participants_fn, batch_fn,
                         tracer=tracer)
    curve = []
    st, rep = sim.run(
        eng.init(init_fn, jax.random.key(2)),
        on_flush=lambda t, v, s: curve.append((t, evaluate(s))),
    )
    d = rep.to_dict()
    cum = [
        rep.meter.cumulative_bits_at(f.t) for f in rep.flushes
    ]
    return {
        "buffer_size": buffer_size,
        "staleness_exponent": staleness_exponent,
        "versions": rep.versions,
        "arrivals_per_flush": d["arrivals_per_flush"],
        "residual_arrivals": d["residual_arrivals"],
        "lag_histogram": d["lag_histogram"],
        "lag_summary": simmetrics.summarize_lags(
            [tau for f in rep.flushes for tau in f.taus]
        ),
        "flush_t": d["flush_t"],
        "cum_bits_curve": [[f.t, int(b)] for f, b in zip(rep.flushes, cum)],
        "acc_curve": [[t_, a] for t_, a in curve],
        "final_acc": curve[-1][1],
        "final_t": rep.final_t,
        "uplink_bits": d["uplink_bits"],
        "downlink_bits": d["downlink_bits"],
        "total_bits": d["total_bits"],
    }


def check_sync_parity(fast: bool) -> dict:
    """The keystone invariant, re-proven on the bench task: zero latency,
    B = S, p = 0 drain vs the sync engine, EF on and off."""
    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.data import synthetic as ds
    from repro.models import smallnets as sn
    from repro.sim.clock import ConstantLatency
    from repro.sim.server import AsyncConfig, AsyncSimulator
    import repro.core.rounds as rounds

    k = s = 4
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=k, train_per_client=32,
        test_per_client=16,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda kk: sn.init_mlp(kk, input_dim=784, hidden=16)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    rounds_ = 2 if fast else 3
    checked = []
    for ef in (False, True):
        eng = PFed1BS(
            PFed1BSConfig(num_clients=k, participate=s, local_steps=2,
                          m_ratio=0.05, chunk=2048, error_feedback=ef),
            loss_fn, template,
        )
        pf = lambda v: rounds.draw_participants(
            jax.random.fold_in(jax.random.key(7), v), k, s, None
        )
        bf = lambda v: ds.sample_round_batches(
            jax.random.fold_in(jax.random.key(9), v), data, 2, 16
        )
        st_sync = eng.init(init_fn, jax.random.key(2))
        for r in range(rounds_):
            st_sync, _ = eng.round(
                st_sync, bf(r), data.weights, jax.random.key(0), pf(r)
            )
        sim = AsyncSimulator(
            eng,
            AsyncConfig(buffer_size=s, staleness_exponent=0.0,
                        max_versions=rounds_, latency=ConstantLatency(0.0)),
            data.weights, pf, bf,
        )
        st_async, _ = sim.run(eng.init(init_fn, jax.random.key(2)))
        same = bool(np.array_equal(np.asarray(st_sync.v), np.asarray(st_async.v)))
        for a, b in zip(jax.tree.leaves(st_sync.clients),
                        jax.tree.leaves(st_async.clients)):
            same = same and bool(np.array_equal(np.asarray(a), np.asarray(b)))
        if ef:
            same = same and bool(
                np.array_equal(np.asarray(st_sync.ef), np.asarray(st_async.ef))
            )
        checked.append({"error_feedback": ef, "bit_exact": same})
    return {
        "bit_exact": all(c["bit_exact"] for c in checked),
        "rounds": rounds_,
        "checked": checked,
    }


def cost_model_at_scale(m_ratio: float = 0.1) -> dict:
    """Price one round at a REAL architecture size (repro/configs): the
    README cost-model table uses n = 1e6; production federated fine-tuning
    of granite-8b is ~8e9 parameters. Pure accounting — only shapes are
    built (jax.eval_shape), no weights are allocated."""
    from repro import configs
    from repro.core import flatten
    from repro.fl import comms
    from repro.launch.steps import param_template

    arch = configs.get("granite-8b")
    n = flatten.tree_size(param_template(arch))
    m = int(n * m_ratio)
    s = 20
    ours = comms.round_bits("pfed1bs", n=n, m=m, s=s)
    fedavg = comms.round_bits("fedavg", n=n, m=m, s=s)
    return {
        "arch": arch.name,
        "n": n,
        "m": m,
        "s": s,
        "pfed1bs_mb_round": ours["total_mb"],
        "fedavg_mb_round": fedavg["total_mb"],
        "reduction_vs_fedavg": comms.reduction_vs_fedavg(
            "pfed1bs", n=n, m=m, s=s
        ),
    }


def bench_async_vs_sync(fast: bool = False, trace: bool = False) -> dict:
    """trace=True records the async run's event loop on a virtual-clock
    obs.Tracer (dispatch/arrive/flush/broadcast instants + cumulative bit
    counters on the simulator's own clock) and dumps
    TRACE_async[.fast].json, validated by obs.validate_trace against the
    run's "async" billing spec. Seed-identical runs export byte-identical
    trace files — virtual time carries no wall jitter."""
    from repro import obs
    from repro.sim import metrics as simmetrics

    sc, data, eng, init_fn, participants_fn, batch_fn, evaluate, knobs = (
        _build(fast)
    )
    rounds = knobs["rounds"]
    s_cap = sc.capacity(knobs["num_clients"])
    buffer_size = max(2, s_cap // 2)
    # same number of client uploads as the sync run -> equal billed uplink
    max_versions = rounds * s_cap // buffer_size

    tracer = obs.Tracer(clock="virtual") if trace else None
    sync = run_sync(sc, data, eng, init_fn, participants_fn, batch_fn,
                    evaluate, rounds)
    asyn = run_async(data, eng, init_fn, participants_fn, batch_fn, evaluate,
                     sc.latency, buffer_size, max_versions, tracer=tracer)
    if tracer is not None:
        trace_path = "TRACE_async.fast.json" if fast else "TRACE_async.json"
        obs.dump_trace(
            trace_path, tracer,
            billing=[{
                "kind": "async", "m": eng.m,
                "arrivals_per_flush": asyn["arrivals_per_flush"],
                "residual_arrivals": asyn["residual_arrivals"],
            }],
            meta={"bench": "async", "fast": fast},
        )
        obs.validate_trace(json.load(open(trace_path)))

    target = 0.95 * min(sync["final_acc"], asyn["final_acc"])
    sync["time_to_target_s"] = simmetrics.time_to_target(
        sync["acc_curve"], target
    )
    asyn["time_to_target_s"] = simmetrics.time_to_target(
        asyn["acc_curve"], target
    )
    speedup = (
        sync["time_to_target_s"] / asyn["time_to_target_s"]
        if sync["time_to_target_s"] and asyn["time_to_target_s"]
        else None
    )

    def bits_at(run, t):
        spent = [b for tt, b in run["cum_bits_curve"] if tt <= t]
        return spent[-1] if spent else 0

    sync["bits_at_target"] = (
        bits_at(sync, sync["time_to_target_s"])
        if sync["time_to_target_s"] is not None else None
    )
    asyn["bits_at_target"] = (
        bits_at(asyn, asyn["time_to_target_s"])
        if asyn["time_to_target_s"] is not None else None
    )
    out = {
        "fast": fast,
        "scenario": sc.name,
        "m": eng.m,
        "n": eng.n,
        "config": {**knobs, "capacity": s_cap, "buffer_size": buffer_size,
                   "max_versions": max_versions},
        "target_acc": target,
        "sync": sync,
        "async": asyn,
        "speedup_time_to_target": speedup,
        "sync_parity": check_sync_parity(fast),
        "cost_model_at_scale": cost_model_at_scale(),
    }
    if tracer is not None:
        out["trace_path"] = trace_path
    simmetrics.validate_async_artifact(out)
    return out


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """BENCH_async.json writer; --fast runs land in BENCH_async.fast.json
    and never touch the canonical artifact (same policy as the other
    benches). The canonical run is also mirrored to experiments/bench/."""
    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_async.fast.json" if fast else "BENCH_async.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_async.json", "w") as f:
            json.dump(results, f, indent=2)
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="also dump + validate TRACE_async[.fast].json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    results = bench_async_vs_sync(fast=args.fast, trace=args.trace)
    path = write_artifacts(results, args.out)
    s, a = results["sync"], results["async"]
    print(f"target acc {results['target_acc']:.4f}")
    print(f"sync : tta {s['time_to_target_s']:.2f}s  final {s['final_acc']:.4f}"
          f"  bits {s['total_bits']:,}")
    print(f"async: tta {a['time_to_target_s']:.2f}s  final {a['final_acc']:.4f}"
          f"  bits {a['total_bits']:,}  lags {a['lag_histogram']}")
    print(f"speedup (time-to-target) {results['speedup_time_to_target']:.2f}x")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
