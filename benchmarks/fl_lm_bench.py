"""fed_lm bench: pFed1BS over real models/lm.py architectures — emits
BENCH_fl_lm.json (DESIGN.md §13).

Four result blocks, in the order the numbers should be read:

  parity    the CALIBRATION cell, on a tiny real config: the streamed
            per-leaf encode (core/stream.stream_sketch, fed one leaf at a
            time from a checkpoint/ckpt.py npz via
            models/io.checkpoint_leaf_reader — the model is never
            resident) must be BIT-exact with the engine's materialized
            leaf-layout sketch flat_view(tree_sketch_forward(...)). If
            this drifts, every memory row below describes a different
            operator than the one the round votes on.
  memory    per lm_matrix cell (reduced arch): the MemMeter peak of the
            streamed encode vs the 4n bytes a materialized flat vector
            would hold. The measured peak must EQUAL the closed-form
            core/stream.stream_peak_bound — O(max-layer + m) — which
            exp/report.validate_fl_lm re-derives per row.
  rounds    real PFed1BS.round wall time over each cell's reduced arch on
            a (fed, model) = (1, 1) mesh (full params AND the LoRA-style
            attention subset), with the Table-2 bit bill through
            fl/comms.subset_round_bits at the trainable count.
  at_scale  the same geometry over the FULL (unreduced) configs — purely
            analytic via jax.eval_shape (no allocation): n, m, streaming
            peak bound, flat-vector bytes, subset bits. This is the
            headline: federating an 8B model one-bit-sketched at
            m_ratio=0.05 holds O(max-layer + m) host bytes per client,
            not 4n.

Run: PYTHONPATH=src python -m benchmarks.run fl_lm [--fast]
     (or this module directly: python -m benchmarks.fl_lm_bench [--fast])
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import tempfile
import time

import numpy as np


def _template(arch):
    import jax

    from repro.models import lm

    return jax.eval_shape(
        functools.partial(lm.init_params, arch), jax.random.PRNGKey(0)
    )


def _cell_tspec(cell, reduced: bool):
    """(arch, template, tspec) for a cell — the SAME derivation
    exp/report.validate_fl_lm re-runs against every artifact row."""
    from repro.core import subset
    from repro.core import treesketch as ts

    arch = cell.arch_config(reduced=reduced)
    template = _template(arch)
    paths = (
        subset.match_paths(template, cell.trainable) if cell.trainable else None
    )
    tspec = ts.make_tree_sketch_spec(
        template, cell.m_ratio, chunk=cell.chunk, paths=paths
    )
    return arch, template, tspec


def _parity_cell(progress=None) -> dict:
    """Streamed-vs-materialized bit-exactness on a tiny real config, with
    the streamed side reading one leaf at a time from an npz checkpoint."""
    import jax

    from repro.checkpoint import ckpt
    from repro.core import stream
    from repro.core import treesketch as ts
    from repro.exp import scenarios
    from repro.launch import fedexec
    from repro.models import io as mio
    from repro.models import lm

    cell = scenarios.lm_matrix()["granite-attn"]
    eng, mesh, template = fedexec.make_fed_lm_engine(
        cell.arch_config(reduced=True), cell.fl_config()
    )
    params = lm.init_params(cell.arch_config(reduced=True), jax.random.PRNGKey(3))

    materialized = np.asarray(
        jax.jit(
            lambda t: ts.flat_view(eng.tspec, ts.tree_sketch_forward(eng.tspec, t))
        )(params)
    )
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "client0.npz")
        ckpt.save_checkpoint(path, params)
        stored_paths, get_leaf = mio.checkpoint_leaf_reader(path)
        meter = stream.MemMeter()
        streamed = stream.stream_sketch(eng.tspec, get_leaf, meter=meter)
    bit_exact = bool(np.array_equal(streamed, materialized))
    out = {
        "cell": cell.name,
        "arch": cell.arch,
        "reduced": True,
        "n": eng.n,
        "n_trainable": eng.n_trainable,
        "m": eng.m,
        "bit_exact": bit_exact,
        "checkpoint_leaves": len(stored_paths),
        "stream_peak_bytes": meter.peak,
    }
    if progress is not None:
        progress("parity", out)
    return out


def _memory_rows(cells, progress=None) -> list:
    """Measured MemMeter peak of the streamed encode per reduced cell."""
    import jax

    from repro.core import stream, subset
    from repro.models import lm

    rows = []
    for cell in cells:
        arch, template, tspec = _cell_tspec(cell, reduced=True)
        params = lm.init_params(arch, jax.random.PRNGKey(3))
        leaves = dict(subset.leaf_paths(params))
        meter = stream.MemMeter()
        stream.stream_sketch(tspec, lambda p: leaves[p], meter=meter)
        n_total = sum(
            int(np.prod(l.shape)) if l.shape else 1 for l in leaves.values()
        )
        row = {
            "cell": cell.name,
            "arch": cell.arch,
            "reduced": True,
            "n": n_total,
            "n_trainable": tspec.n,
            "m": tspec.m,
            "peak_bytes": meter.peak,
            "peak_bound_bytes": stream.stream_peak_bound(tspec),
            "flat_bytes": 4 * n_total,
        }
        rows.append(row)
        if progress is not None:
            progress(f"memory:{cell.name}", row)
    return rows


def _round_rows(cells, fast: bool, progress=None) -> list:
    """Real fed_lm rounds on a (1, 1) mesh: wall time + Table-2 billing."""
    import jax
    import jax.numpy as jnp

    from repro.fl import comms
    from repro.launch import fedexec
    from repro.models import io as mio
    from repro.models import lm

    reps = 2 if fast else 4
    rows = []
    for cell in cells:
        arch = cell.arch_config(reduced=True)
        eng, mesh, template = fedexec.make_fed_lm_engine(arch, cell.fl_config())
        sh = fedexec.fed_lm_shardings(arch, template, mesh)
        state = fedexec.place_fed_lm_state(
            eng.init(lambda k: lm.init_params(arch, k), jax.random.PRNGKey(0)),
            sh,
        )
        k, r, b = cell.num_clients, cell.local_steps, cell.batch
        mk = lambda key: mio.make_batch(arch, key, b, cell.seq)
        batches = jax.vmap(
            lambda key: jax.vmap(mk)(jax.random.split(key, r))
        )(jax.random.split(jax.random.PRNGKey(1), k))
        batches = fedexec.place_fed_lm_batches(batches, sh)
        weights = jnp.ones((k,)) / k

        state, metrics = eng.round(state, batches, weights, jax.random.PRNGKey(2))
        jax.block_until_ready(state)                        # compile + warm
        t0 = time.perf_counter()
        for i in range(reps):
            state, metrics = eng.round(
                state, batches, weights, jax.random.PRNGKey(3 + i)
            )
        jax.block_until_ready(state)
        us = (time.perf_counter() - t0) / reps * 1e6

        row = {
            "cell": cell.name,
            "arch": cell.arch,
            "reduced": True,
            "n": eng.n,
            "n_trainable": eng.n_trainable,
            "m": eng.m,
            "participate": cell.participate,
            "local_steps": cell.local_steps,
            "us_per_round": us,
            "task_loss": float(metrics["task_loss"]),
            "uplink_bits": int(metrics["uplink_bits"]),
            "downlink_bits": int(metrics["downlink_bits"]),
            "bits": comms.subset_round_bits(
                "pfed1bs", n_total=eng.n, n_trainable=eng.n_trainable,
                m=eng.m, s=cell.participate,
            ),
        }
        rows.append(row)
        if progress is not None:
            progress(f"round:{cell.name}", row)
    return rows


def _at_scale_rows(cells, progress=None) -> list:
    """Full-config geometry, analytic (eval_shape — nothing allocated)."""
    from repro.core import flatten, stream
    from repro.fl import comms

    rows = []
    for cell in cells:
        arch, template, tspec = _cell_tspec(cell, reduced=False)
        n_total = flatten.tree_size(template)
        row = {
            "cell": cell.name,
            "arch": cell.arch,
            "reduced": False,
            "n": n_total,
            "n_trainable": tspec.n,
            "m": tspec.m,
            "peak_bound_bytes": stream.stream_peak_bound(tspec),
            "flat_bytes": 4 * n_total,
            "bits": comms.subset_round_bits(
                "pfed1bs", n_total=n_total, n_trainable=tspec.n, m=tspec.m,
                s=cell.participate,
            ),
        }
        rows.append(row)
        if progress is not None:
            progress(f"at_scale:{cell.name}", row)
    return rows


def bench_fl_lm(fast: bool = False, progress=None) -> dict:
    from repro.exp import scenarios

    matrix = scenarios.lm_matrix()
    cells = list(matrix.values())
    round_cells = (
        [matrix["granite-full"], matrix["granite-attn"]] if fast else cells
    )
    return {
        "bench": "fl_lm",
        "fast": fast,
        "parity": _parity_cell(progress=progress),
        "memory": _memory_rows(cells, progress=progress),
        "rounds": _round_rows(round_cells, fast, progress=progress),
        "at_scale": _at_scale_rows(cells, progress=progress),
    }


def fl_lm_markdown(results: dict) -> str:
    lines = [
        "# Federating a real LM (BENCH_fl_lm)",
        "",
        "| cell | n | trainable | m | stream peak (bytes) | flat vector (bytes) | uplink bits/round |",
        "|---|---|---|---|---|---|---|",
    ]
    for row in results["at_scale"]:
        lines.append(
            f"| {row['cell']} | {row['n']:,} | {row['n_trainable']:,} | "
            f"{row['m']:,} | {row['peak_bound_bytes']:,} | "
            f"{row['flat_bytes']:,} | {row['bits']['uplink_bits']:,} |"
        )
    lines += [
        "",
        "Streamed per-leaf sketching holds O(max-layer + m) host bytes per "
        "client — never the 4n flat vector — and is bit-exact with the "
        "materialized leaf-layout sketch (parity cell).",
    ]
    return "\n".join(lines) + "\n"


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """BENCH_fl_lm.json writer; --fast runs land in BENCH_fl_lm.fast.json
    and never touch the canonical artifacts. The canonical run also
    renders experiments/bench/FL_LM.md."""
    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_fl_lm.fast.json" if fast else "BENCH_fl_lm.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_fl_lm.json", "w") as f:
            json.dump(results, f, indent=2)
        with open("experiments/bench/FL_LM.md", "w") as f:
            f.write(fl_lm_markdown(results))
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    results = bench_fl_lm(
        fast=args.fast,
        progress=lambda tag, row: print(f"{tag}: {row}", flush=True),
    )
    path = write_artifacts(results)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
