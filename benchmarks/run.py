"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; full JSON results land in
experiments/bench/ (``--fast`` smoke runs write ``<name>.fast.json``
there, mirroring the repo-root BENCH_*.fast.json convention — canonical
filenames only ever hold full-settings results). Scaled to the CPU
container (smaller nets / rounds, same protocols); the full-scale numbers
live in the dry-run roofline.

  table2          paper Table 2: accuracy + comm cost across 7 algorithms
  fig3_fig4       convergence curves (acc/loss vs rounds), ours vs one-bit
  fht             FHT vs dense projection scaling (the O(n log n) claim)
  ablation_S      paper §A.1: participating clients
  ablation_R      paper §A.2: local steps
  ablation_fht    paper §A.3: FHT vs dense Gaussian accuracy
  sensitivity     paper §A.4: lambda/mu/gamma grids
  kernels         Pallas kernel ops: sketch fwd/adjoint, pack/vote
  sketch          fused vs staged SRHT + round hot path (BENCH_sketch.json)
  round_sharded   shard_map executor scaling: clients x fed-mesh grid
                  (BENCH_round_sharded.json; runs in a subprocess because
                  the simulated mesh needs XLA_FLAGS set before jax import)
  serve           personalized serving tier: sketch-store vs fp32-store
                  accuracy, batched vs sequential reconstruct, Zipf request
                  streams over K personalized LMs (BENCH_serve.json;
                  --fast emits BENCH_serve.fast.json)
  exp             scenario-matrix sweep: 7 algorithms x heterogeneity
                  scenarios (Dirichlet alpha, label skew, imbalance,
                  stragglers, availability cycling) -> accuracy vs bits
                  (BENCH_exp.json; --fast emits BENCH_exp.fast.json)
  async           async federation tier: sync vs buffered-async
                  time-to-target accuracy under a straggler-tail latency
                  scenario, sync-parity cell, cost model at a real
                  configs/ architecture size (BENCH_async.json; --fast
                  emits BENCH_async.fast.json)
  robust          robustness curves: accuracy vs Byzantine adversary
                  fraction x defense (none/trim/reputation) and vs
                  randomized-response epsilon, garbage-neutralization
                  parity, recovery gate (BENCH_robust.json; --fast emits
                  BENCH_robust.fast.json)
  hier            hierarchical tree-of-aggregators: counter-merge parity
                  (tree vote bit-exact vs the flat popcount server) and
                  the root-ingress-vs-client-count scaling curve 10^3 ->
                  10^6 clients, billed via fl/comms.hier_round_bits
                  (BENCH_hier.json; --fast emits BENCH_hier.fast.json)
  fl_lm           pFed1BS over real models/lm.py configs: streamed
                  per-leaf sketch parity (bit-exact vs materialized),
                  O(max-layer + m) streaming peak vs model size, real
                  round times on the (fed, model) mesh, analytic at-scale
                  geometry + subset billing (BENCH_fl_lm.json; --fast
                  emits BENCH_fl_lm.fast.json)
  roofline        reads experiments/dryrun/*.json -> per-(arch,shape) terms

Run all:  PYTHONPATH=src python -m benchmarks.run         (or: run all)
One:      PYTHONPATH=src python -m benchmarks.run exp [--fast]
          (--only exp is the same; positional wins if both given)
CI:       `run.py all --fast` is the bench-smoke consistency mode — one
          process runs every target, a failure deletes that target's
          stale artifacts and exits nonzero after the rest finish. `all`
          runs also write BENCH_index[.fast].json: one entry per target
          with its artifact path, a headline-metric dict, and the
          embedded SLO verdict (serving carries one; see DESIGN.md §14).

A sub-benchmark that raises is reported and the process exits nonzero
after the remaining ones run — the CI bench-smoke job gates on this.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def emit(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _save(name, obj, fast=False):
    """Write a paper-table artifact. Fast-mode (smoke) runs land in
    ``<name>.fast.json`` — mirroring the BENCH_*.fast.json convention —
    so a reduced-scale run can never masquerade as the canonical result."""
    os.makedirs("experiments/bench", exist_ok=True)
    suffix = ".fast" if fast else ""
    with open(f"experiments/bench/{name}{suffix}.json", "w") as f:
        json.dump(obj, f, indent=2)


# ---------------------------------------------------------------------------

def bench_table2(fast=False):
    """Paper Table 2: Top-1 acc + per-round MB for all algorithms, non-iid."""
    from benchmarks.fl_bench import make_task, run_algo

    rounds = 8 if fast else 20
    data, init_fn, loss_fn, eval_fn = make_task()
    algos = ["fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat", "pfed1bs"]
    out = {}
    for algo in algos:
        r = run_algo(algo, data, init_fn, loss_fn, eval_fn, rounds=rounds)
        out[algo] = r
        emit(f"table2/{algo}", r["us_per_round"],
             f"acc={r['acc']:.4f} acc_global={r['acc_global']:.4f} "
             f"mb_round={r['mb_per_round']:.4f} "
             f"red={r['reduction_vs_fedavg'] * 100:.2f}%")
    _save("table2", out, fast)
    return out


def bench_fig3_fig4(fast=False):
    """Figures 3-4: convergence of accuracy/loss over rounds."""
    from benchmarks.fl_bench import make_task, run_algo

    rounds = 10 if fast else 25
    data, init_fn, loss_fn, eval_fn = make_task()
    out = {}
    for algo in ["pfed1bs", "obda", "zsignfed", "fedavg"]:
        r = run_algo(algo, data, init_fn, loss_fn, eval_fn, rounds=rounds)
        out[algo] = {"loss_curve": r["loss_curve"], "final_acc": r["acc"],
                     "final_acc_global": r["acc_global"],
                     "personalized": r["personalized"]}
        emit(f"fig34/{algo}", r["us_per_round"],
             f"loss0={r['loss_curve'][0]:.3f} lossT={r['loss_curve'][-1]:.4f}")
    _save("fig34_convergence", out, fast)
    return out


def _median_us(f, arg, reps):
    """Median per-call wall time in us (warmup excluded) — medians are
    robust to the container's scheduling noise, which single-shot means
    are not (a 5-rep mean once produced a non-monotonic scaling curve)."""
    f(arg).block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        f(arg).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def bench_fht(fast=False):
    """FHT O(n log n) vs dense O(mn): wall time of the forward sketch.

    chunk=2048 keeps every size on the SAME code path — the chunked
    block-diagonal fused SRHT the FL engines use (smallest n here is 2^12
    = 2 chunks). Mixing in the global-permutation mode (n <= chunk) would
    compare two different kernels in one scaling curve; each row records
    its spec mode so that can't regress silently."""
    from repro.core import sketch as sk

    sizes = [2 ** 12, 2 ** 14, 2 ** 16] + ([] if fast else [2 ** 18, 2 ** 20])
    reps = 10 if fast else 30
    out = {}
    for n in sizes:
        x = jax.random.normal(jax.random.key(0), (n,))
        spec = sk.make_sketch_spec(n, 0.1, chunk=2048)
        assert spec.mode == "chunked", f"n={n} fell off the chunked path"
        f = jax.jit(lambda w: sk.sketch_forward(spec, w))
        row = {"n": n, "m": spec.m, "mode": spec.mode,
               "fht_us": _median_us(f, x, reps)}
        if n <= 2 ** 16:
            phi = sk.dense_gaussian_sketch(n, spec.m, seed=0)
            g = jax.jit(lambda w: phi @ w)
            row["dense_us"] = _median_us(g, x, max(5, reps // 2))
        out[str(n)] = row
        emit(f"fht/n={n}", row["fht_us"],
             f"dense_us={row.get('dense_us', float('nan')):.1f} m={spec.m}")
    _save("fht_scaling", out, fast)
    return out


# Ablation/sensitivity task: harder than the Table-2 cell (5 classes per
# client, noise 3.0) so pfed1bs sits BELOW the accuracy ceiling — at the
# default task every grid point saturates at 1.0 and the sweep carries no
# signal. Every per-setting record is the same {acc, loss_final} object
# across all ablation files (downstream plotting relies on one schema).
ABLATION_TASK = dict(num_clients=10, hidden=48, classes_per_client=5,
                     noise=3.0)


def _setting(r):
    return {"acc": r["acc"], "loss_final": r["loss_curve"][-1]}


def bench_ablation_S(fast=False):
    """Paper §A.1: accuracy vs number of participating clients S."""
    from benchmarks.fl_bench import make_task, run_algo

    rounds = 8 if fast else 20
    data, init_fn, loss_fn, eval_fn = make_task(**ABLATION_TASK)
    out = {}
    for s in ([5, 10] if fast else [2, 5, 8, 10]):
        r = run_algo("pfed1bs", data, init_fn, loss_fn, eval_fn,
                     rounds=rounds, participate=s)
        out[str(s)] = _setting(r)
        emit(f"ablation_S/S={s}", r["us_per_round"], f"acc={r['acc']:.4f}")
    _save("ablation_S", out, fast)
    return out


def bench_ablation_R(fast=False):
    """Paper §A.2: accuracy/convergence vs local steps R."""
    from benchmarks.fl_bench import make_task, run_algo

    rounds = 8 if fast else 16
    data, init_fn, loss_fn, eval_fn = make_task(**ABLATION_TASK)
    out = {}
    for r_steps in ([2, 8] if fast else [1, 3, 5, 10]):
        r = run_algo("pfed1bs", data, init_fn, loss_fn, eval_fn,
                     rounds=rounds, local_steps=r_steps)
        out[str(r_steps)] = _setting(r)
        emit(f"ablation_R/R={r_steps}", r["us_per_round"],
             f"acc={r['acc']:.4f} loss={r['loss_curve'][-1]:.4f}")
    _save("ablation_R", out, fast)
    return out


def bench_ablation_fht(fast=False):
    """Paper §A.3: FHT-structured vs dense-Gaussian projection quality."""
    from benchmarks.fl_bench import make_task, run_algo
    from benchmarks.dense_proj import run_dense_pfed1bs

    rounds = 8 if fast else 16
    data, init_fn, loss_fn, eval_fn = make_task(**{**ABLATION_TASK,
                                                   "num_clients": 6})
    r_fht = run_algo("pfed1bs", data, init_fn, loss_fn, eval_fn, rounds=rounds)
    r_dense = run_dense_pfed1bs(data, init_fn, loss_fn, eval_fn, rounds=rounds)
    out = {"fht": _setting(r_fht), "dense": _setting(r_dense)}
    emit("ablation_fht/fht", r_fht["us_per_round"], f"acc={r_fht['acc']:.4f}")
    emit("ablation_fht/dense", r_dense["us_per_round"], f"acc={r_dense['acc']:.4f}")
    _save("ablation_fht", out, fast)
    return out


def bench_sensitivity(fast=False):
    """Paper §A.4 (Table 1 appendix): lambda / mu / gamma sensitivity."""
    from benchmarks.fl_bench import make_task, run_algo

    rounds = 6 if fast else 12
    data, init_fn, loss_fn, eval_fn = make_task(**{**ABLATION_TASK,
                                                   "num_clients": 6})
    grids = {
        "lam": [5e-6, 5e-4, 5e-2] if not fast else [5e-4],
        "mu": [1e-6, 1e-4, 1e-2] if not fast else [1e-5],
        "gamma": [1e2, 1e4, 1e6] if not fast else [1e4],
    }
    out = {}
    for pname, values in grids.items():
        for val in values:
            kw = {pname: val} if pname != "gamma" else {"gamma": val}
            r = run_algo("pfed1bs", data, init_fn, loss_fn, eval_fn,
                         rounds=rounds, **kw)
            out[f"{pname}={val}"] = _setting(r)
            emit(f"sensitivity/{pname}={val}", r["us_per_round"],
                 f"acc={r['acc']:.4f}")
    _save("sensitivity", out, fast)
    return out


def bench_kernels(fast=False):
    """Micro-bench of the core ops: sketch fwd/adjoint, pack, vote."""
    from repro.core import sketch as sk
    from repro.kernels import ops as kops

    n = 2 ** 16
    spec = sk.make_sketch_spec(n, 0.1, chunk=16384)
    x = jax.random.normal(jax.random.key(0), (n,))
    v = jax.random.normal(jax.random.key(1), (spec.m,))
    z = jnp.sign(jax.random.normal(jax.random.key(2), (20, 6400)))
    p = jnp.full((20,), 0.05)
    packed = kops.pack_signs(z)
    cases = {
        "sketch_fwd": (jax.jit(lambda a: sk.sketch_forward(spec, a)), x),
        "sketch_adj": (jax.jit(lambda a: sk.sketch_adjoint(spec, a)), v),
        "pack": (jax.jit(kops.pack_signs), z),
        "vote_packed": (jax.jit(lambda w: kops.vote_packed(w, p)), packed),
    }
    out = {}
    for name, (f, arg) in cases.items():
        f(arg).block_until_ready()
        t0 = time.time()
        reps = 20
        for _ in range(reps):
            f(arg).block_until_ready()
        us = (time.time() - t0) / reps * 1e6
        out[name] = us
        emit(f"kernels/{name}", us, f"n={n}")

    # eager pass under an active KernelProbe (obs/probe.py): the jitted
    # cases above bypass the probe (tracer args pass through untimed), so
    # this drives the instrumented kernels/ops entry points eagerly for
    # the per-kernel steady/compile/bytes-moved table that
    # benchmarks/report.py --kernels renders
    from repro import obs
    from repro.kernels import ops as kops2

    probe = obs.KernelProbe()
    with obs.probing(probe):
        for _ in range(3):
            sk.sketch_forward(spec, x)
            sk.sketch_adjoint(spec, v)
            kops2.pack_signs(z)
            kops2.vote_packed(packed, p)
            kops2.vote_popcount(packed)
    out["probe_table"] = probe.table()
    for row in out["probe_table"]:
        emit(f"kernels/probe/{row['kernel']}", row["us_per_call"] or 0.0,
             f"calls={row['calls']} compile_s={row['compile_s']:.3f} "
             f"gb_s={row['est_gb_per_s'] or 0.0:.2f}")
    _save("kernels", out, fast)
    return out


def bench_roofline(fast=False):
    """Aggregate the dry-run artifacts into the §Roofline table."""
    rows = {}
    for path in sorted(glob.glob("experiments/dryrun/*__pod16x16.json")):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            key = f"{rec.get('arch')}__{rec.get('shape')}"
            rows[key] = {"status": rec.get("status"),
                         "reason": rec.get("reason", rec.get("error", ""))[:100]}
            continue
        r = rec["roofline"]
        key = f"{rec['arch']}__{rec['shape']}"
        rows[key] = {
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
        }
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(f"roofline/{key}", step_s * 1e6,
             f"dom={r['dominant']} useful={rec['useful_flops_ratio']:.3f}")
    if not rows:
        # no artifact written: an empty {} would mask that the roofline
        # step never ran while still satisfying file-presence checks
        print("# no dry-run artifacts found — run repro.launch.dryrun --all "
              "first (roofline_summary NOT written)")
        return rows
    _save("roofline_summary", rows, fast)
    return rows


def bench_sketch(fast=False):
    """Fused vs staged SRHT + round hot path — emits BENCH_sketch.json."""
    from benchmarks import sketch_bench

    out = {
        "fast": fast,
        "sketch": sketch_bench.bench_sketch_micro(fast=fast),
        "round": sketch_bench.bench_round(fast=fast),
    }
    emit("sketch/fwd_fused", out["sketch"]["fwd_fused_us"],
         f"staged_us={out['sketch']['fwd_staged_us']:.1f} "
         f"speedup={out['sketch']['fwd_speedup']:.2f}x")
    emit("sketch/round_fused", out["round"]["round_fused_us"],
         f"staged_us={out['round']['round_staged_us']:.1f} "
         f"speedup={out['round']['round_speedup']:.2f}x")
    sketch_bench.write_artifacts(out)
    return out


def bench_round_sharded(fast=False):
    """Sharded-executor round scaling — emits BENCH_round_sharded.json.

    Delegates to benchmarks/round_sharded_bench.py in a fresh subprocess:
    the multi-device federation is simulated with
    --xla_force_host_platform_device_count, which must be in XLA_FLAGS
    before jax is imported (and this process imported jax long ago)."""
    import subprocess
    import sys

    cmd = [sys.executable, "-m", "benchmarks.round_sharded_bench"]
    if fast:
        cmd.append("--fast")
    res = subprocess.run(cmd, capture_output=True, text=True)
    if res.returncode != 0:
        print(res.stdout, flush=True)
        print(res.stderr, flush=True)
        raise RuntimeError("round_sharded_bench failed")
    for line in res.stdout.splitlines():   # scaling summary lines only;
        if line.startswith("#"):           # grid rows are emit()ed below
            print(line, flush=True)
    path = ("BENCH_round_sharded.fast.json" if fast
            else "BENCH_round_sharded.json")
    out = json.load(open(path))
    for rec in out["grid"]:
        emit(f"round_sharded/mesh={rec['mesh']}/S={rec['clients']}",
             rec["round_us"],
             f"devices={out['device_count']}")
    return out


def bench_serve(fast=False, trace=False):
    """Serving-tier numbers — emits BENCH_serve.json (fast:
    BENCH_serve.fast.json; see benchmarks/serve_bench.py). --trace also
    dumps the serving flight ring as TRACE_serve[.fast].json; a breached
    per-cell SLO snapshots FLIGHT_serve[.fast].json."""
    from benchmarks import serve_bench

    results = {"fast": fast}
    results["quality"] = serve_bench.bench_quality(fast=fast)
    q = results["quality"]
    emit("serve/quality", 0.0,
         f"acc_fp32={q['acc_fp32_store']:.4f} "
         f"acc_sketch={q['acc_sketch_store']:.4f} "
         f"gap_pts={q['acc_gap_points']:.2f} "
         f"compression={q['compression_vs_fp32']:.1f}x")
    results["reconstruct"] = bench_rec = serve_bench.bench_reconstruct(fast=fast)
    for b, r in bench_rec["batches"].items():
        emit(f"serve/reconstruct_B{b}", r["batched_us"],
             f"sequential_us={r['sequential_us']:.0f} "
             f"speedup={r['speedup']:.2f}x")
    results["stream"] = serve_bench.bench_stream(fast=fast, trace=trace)
    for k, r in results["stream"]["grid"].items():
        emit(f"serve/stream_K{k}", r["materialize_p50_ms"] * 1e3,
             f"tok_s={r['tokens_per_sec']:.0f} "
             f"p99_ms={r['materialize_p99_ms']:.0f} hit={r['hit_rate']:.2f} "
             f"telemetry_B={r['telemetry_bytes']} "
             f"compression={r['compression_vs_fp32']:.1f}x "
             f"slo={'ok' if r['slo']['ok'] else 'BREACH'}")
    s = results["stream"]["slo"]
    emit("serve/slo", 0.0,
         f"spec={s['spec']} {'OK' if s['ok'] else 'BREACH:' + ';'.join(s['breaches'])}")
    serve_bench.write_artifacts(results)
    return results


def bench_exp(fast=False, trace=False):
    """Scenario-matrix sweep — emits BENCH_exp.json (fast:
    BENCH_exp.fast.json; see benchmarks/exp_bench.py). --trace also dumps
    the Perfetto timeline TRACE_exp[.fast].json."""
    from benchmarks import exp_bench

    results = exp_bench.bench_matrix(
        fast=fast, trace=trace,
        progress=lambda c: emit(
            f"exp/{c['scenario']}/{c['algo']}", c["us_per_round"],
            f"acc={c['acc']:.4f} total_bits={c['total_bits']} "
            f"s={'/'.join(str(s) for s in c['s_per_round'][:4])}"
        ),
    )
    exp_bench.write_artifacts(results)
    return results


def bench_robust(fast=False):
    """Robustness curves — accuracy vs adversary fraction x defense and vs
    RR epsilon; emits BENCH_robust.json (fast: BENCH_robust.fast.json; see
    benchmarks/robust_bench.py)."""
    from benchmarks import robust_bench

    results = robust_bench.bench_robust(
        fast=fast,
        progress=lambda tag, c: emit(
            f"robust/{tag}", c["us_per_round"],
            f"acc={c['acc']:.4f} uplink_bits={c['uplink_bits']}"
        ),
    )
    rec = results["recovery"]
    emit("robust/recovery", 0.0,
         f"defense={rec['defense']} recovered_frac={rec['recovered_frac']:.2f} "
         f"garbage_parity={'OK' if results['garbage_parity']['bit_exact'] else 'FAIL'}")
    robust_bench.write_artifacts(results)
    return results


def bench_hier(fast=False, trace=False):
    """Tree-of-aggregators parity + root-ingress scaling — emits
    BENCH_hier.json (fast: BENCH_hier.fast.json; see
    benchmarks/hier_bench.py). --trace also runs a small real
    HierAsyncSimulator and dumps TRACE_hier[.fast].json."""
    from benchmarks import hier_bench

    results = hier_bench.bench_hier(fast=fast, trace=trace)
    par = results["counter_merge_parity"]
    emit("hier/parity", 0.0,
         f"bit_exact={'OK' if par['bit_exact'] else 'FAIL'} "
         f"topologies={len(par['engine_cells'])}")
    last = results["scaling"][-1]
    emit("hier/scaling", 0.0,
         f"clients={last['clients']} root_ingress_bits={last['root_ingress_bits']} "
         f"flat_bits={last['flat_ingress_bits']} "
         f"growth={results['root_ingress_growth']:.2f}x")
    hier_bench.write_artifacts(results)
    return results


def bench_async(fast=False, trace=False):
    """Async-vs-sync time-to-target — emits BENCH_async.json (fast:
    BENCH_async.fast.json; see benchmarks/async_bench.py). --trace also
    dumps the virtual-time timeline TRACE_async[.fast].json."""
    from benchmarks import async_bench

    results = async_bench.bench_async_vs_sync(fast=fast, trace=trace)
    s, a = results["sync"], results["async"]
    emit("async/sync", (s["time_to_target_s"] or 0.0) * 1e6,
         f"final_acc={s['final_acc']:.4f} bits={s['total_bits']}")
    emit("async/buffered", (a["time_to_target_s"] or 0.0) * 1e6,
         f"final_acc={a['final_acc']:.4f} bits={a['total_bits']} "
         f"B={a['buffer_size']} p={a['staleness_exponent']}")
    emit("async/speedup", 0.0,
         f"time_to_target={results['speedup_time_to_target']:.2f}x "
         f"parity={'OK' if results['sync_parity']['bit_exact'] else 'FAIL'}")
    async_bench.write_artifacts(results)
    return results


def bench_fl_lm(fast=False):
    """pFed1BS over real models/lm.py configs: streamed-vs-materialized
    sketch parity, O(max-layer + m) streaming peak per model size, real
    (1,1)-mesh round times, analytic at-scale geometry — emits
    BENCH_fl_lm.json (fast: BENCH_fl_lm.fast.json; see
    benchmarks/fl_lm_bench.py)."""
    from benchmarks import fl_lm_bench

    results = fl_lm_bench.bench_fl_lm(
        fast=fast,
        progress=lambda tag, row: emit(
            f"fl_lm/{tag}", row.get("us_per_round", 0.0),
            f"n={row.get('n')} m={row.get('m')} "
            + (f"bit_exact={'OK' if row['bit_exact'] else 'FAIL'}"
               if "bit_exact" in row else
               f"peak={row.get('peak_bytes', row.get('peak_bound_bytes'))} "
               f"flat={row.get('flat_bytes')}"),
        ),
    )
    par = results["parity"]
    emit("fl_lm/parity", 0.0,
         f"bit_exact={'OK' if par['bit_exact'] else 'FAIL'} "
         f"m={par['m']} leaves={par['checkpoint_leaves']}")
    last = results["at_scale"][-1]
    emit("fl_lm/at_scale", 0.0,
         f"cell={last['cell']} n={last['n']} "
         f"peak_bound={last['peak_bound_bytes']} flat={last['flat_bytes']}")
    fl_lm_bench.write_artifacts(results)
    return results


# benches that can also record an obs timeline (--trace)
TRACEABLE = ("exp", "async", "hier", "serve")

# artifact stems each bench owns (repo-root BENCH_*/TRACE_* plus the
# experiments/bench paper tables); on a FAILED run the matching
# {stem}[.fast].json files are deleted so a stale artifact from an earlier
# green run can never satisfy `report.py --validate` for a now-broken bench
ARTIFACTS = {
    "sketch": ("BENCH_sketch",),
    "round_sharded": ("BENCH_round_sharded",),
    "serve": ("BENCH_serve", "TRACE_serve", "FLIGHT_serve"),
    "exp": ("BENCH_exp", "TRACE_exp"),
    "async": ("BENCH_async", "TRACE_async"),
    "robust": ("BENCH_robust",),
    "hier": ("BENCH_hier", "TRACE_hier"),
    "fl_lm": ("BENCH_fl_lm",),
    "table2": ("experiments/bench/table2",),
    "fig3_fig4": ("experiments/bench/fig34_convergence",),
    "fht": ("experiments/bench/fht_scaling",),
    "ablation_S": ("experiments/bench/ablation_S",),
    "ablation_R": ("experiments/bench/ablation_R",),
    "ablation_fht": ("experiments/bench/ablation_fht",),
    "sensitivity": ("experiments/bench/sensitivity",),
    "kernels": ("experiments/bench/kernels",),
    "roofline": ("experiments/bench/roofline_summary",),
}


def _remove_stale_artifacts(name: str, fast: bool) -> None:
    suffix = ".fast.json" if fast else ".json"
    for stem in ARTIFACTS.get(name, ()):
        path = f"{stem}{suffix}"
        if os.path.exists(path):
            os.remove(path)
            print(f"# removed stale {path} (bench {name} failed)", flush=True)


# headline metric per target for the consolidated BENCH_index (one small
# dict of load-bearing numbers per artifact; missing keys -> empty headline)
_HEADLINES = {
    "table2": lambda o: {"pfed1bs_acc": o["pfed1bs"]["acc"]},
    "sketch": lambda o: {"round_speedup": o["round"]["round_speedup"]},
    "round_sharded": lambda o: {"device_count": o["device_count"]},
    "serve": lambda o: {
        "compression_vs_fp32": o["quality"]["compression_vs_fp32"],
        "acc_gap_points": o["quality"]["acc_gap_points"],
    },
    "exp": lambda o: {"cells": len(o["cells"])},
    "async": lambda o: {
        "speedup_time_to_target": o["speedup_time_to_target"],
        "sync_parity": o["sync_parity"]["bit_exact"],
    },
    "robust": lambda o: {"recovered_frac": o["recovery"]["recovered_frac"]},
    "hier": lambda o: {
        "root_ingress_growth": o["root_ingress_growth"],
        "parity": o["counter_merge_parity"]["bit_exact"],
    },
    "fl_lm": lambda o: {"parity": o["parity"]["bit_exact"]},
}


def write_index(targets, failures, fast: bool) -> str:
    """Consolidated BENCH_index[.fast].json for `all` runs: per target its
    primary artifact path, a small headline-metric dict, the embedded SLO
    verdict (serving carries one; others null), and an ok flag (bench ran
    AND its SLO, if any, holds). Built from the artifacts ON DISK so the
    index always agrees with what validate/compare gate on."""
    suffix = ".fast" if fast else ""
    index = {"fast": fast, "targets": {}}
    for name in targets:
        stems = ARTIFACTS.get(name, ())
        path = f"{stems[0]}{suffix}.json" if stems else None
        entry = {"ok": name not in failures, "artifact": path,
                 "headline": {}, "slo": None}
        if path and os.path.exists(path):
            obj = json.load(open(path))
            try:
                entry["headline"] = _HEADLINES.get(name, lambda o: {})(obj)
            except (KeyError, IndexError, TypeError):
                pass                      # schema drift is --validate's job
            stream = obj.get("stream")
            slo = (stream.get("slo") if isinstance(stream, dict) else None) \
                or obj.get("slo")
            if isinstance(slo, dict):
                entry["slo"] = slo
                if not slo.get("ok", True):
                    entry["ok"] = False
        else:
            entry["artifact"] = None
        index["targets"][name] = entry
    out_path = f"BENCH_index{suffix}.json"
    with open(out_path, "w") as f:
        json.dump(index, f, indent=2)
    return out_path


BENCHES = {
    "table2": bench_table2,
    "fig3_fig4": bench_fig3_fig4,
    "fht": bench_fht,
    "ablation_S": bench_ablation_S,
    "ablation_R": bench_ablation_R,
    "ablation_fht": bench_ablation_fht,
    "sensitivity": bench_sensitivity,
    "kernels": bench_kernels,
    "sketch": bench_sketch,
    "round_sharded": bench_round_sharded,
    "serve": bench_serve,
    "exp": bench_exp,
    "async": bench_async,
    "robust": bench_robust,
    "hier": bench_hier,
    "fl_lm": bench_fl_lm,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    # "all" is an explicit consistency mode for CI: ONE process runs every
    # target so a failed bench deletes its stale artifacts and fails the
    # job as a whole — later validate/compare steps can never gate on a
    # stale artifact mix left by per-target steps with independent caches.
    ap.add_argument("bench", nargs="?", default=None,
                    choices=list(BENCHES) + ["all"],
                    help="benchmark to run (same as --only); 'all' runs "
                         "every target in one process")
    ap.add_argument("--only", default=None, choices=list(BENCHES) + ["all"])
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="benches that support it also dump a Perfetto "
                         "timeline TRACE_<target>[.fast].json "
                         f"(supported: {', '.join(TRACEABLE)})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    only = args.bench or args.only
    todo = list(BENCHES) if only in (None, "all") else [only]
    failures = []
    for name in todo:
        kw = {"fast": args.fast}
        if args.trace and name in TRACEABLE:
            kw["trace"] = True
        try:
            BENCHES[name](**kw)
        except Exception:
            import traceback

            traceback.print_exc()
            failures.append(name)
            _remove_stale_artifacts(name, args.fast)
    if only in (None, "all"):
        # consolidated cross-target index (headline + SLO verdict each);
        # written even on failure so the ok flags record what broke
        path = write_index(todo, failures, args.fast)
        print(f"# wrote {path}", flush=True)
    if failures:
        print(f"# FAILED: {', '.join(failures)}", flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
