"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the artifacts,
and validate the BENCH_* artifact schemas.

Usage: PYTHONPATH=src python -m benchmarks.report [--multi-pod] [--tag X]
Prints a GitHub-markdown table; EXPERIMENTS.md embeds the output.

Validator mode (the CI bench-smoke gate):

  PYTHONPATH=src python -m benchmarks.report --validate --fast

checks every expected BENCH_*.fast.json (or canonical BENCH_*.json
without --fast) at the repo root for presence and required keys, and runs
the exp artifact through exp/report.validate_matrix (which re-derives the
bit accounting from fl/comms). Exit 1 on any miss — a bench script whose
artifact rots now fails the job instead of rotting silently.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# required (dotted) keys per BENCH artifact stem — the load-bearing numbers
# README/DESIGN cite; a bench refactor that drops one fails --validate.
BENCH_SCHEMAS = {
    "BENCH_sketch": [
        "sketch.fwd_fused_us", "sketch.fwd_staged_us", "sketch.fwd_speedup",
        "round.round_fused_us", "round.round_staged_us", "round.round_speedup",
    ],
    "BENCH_round_sharded": [
        "device_count", "grid", "scaling", "sublinear_mesh_sizes",
    ],
    "BENCH_serve": [
        "quality.acc_fp32_store", "quality.acc_sketch_store",
        "quality.compression_vs_fp32", "reconstruct.batches", "stream.grid",
        "stream.slo.ok",
    ],
    # written by `run.py all` (the CI bench-smoke mode): consolidated
    # per-target headline metrics + SLO verdicts
    "BENCH_index": [
        "targets.sketch.ok", "targets.round_sharded.ok",
        "targets.serve.ok", "targets.serve.slo.ok",
        "targets.exp.ok", "targets.async.ok", "targets.robust.ok",
        "targets.hier.ok", "targets.fl_lm.ok",
    ],
    "BENCH_exp": [
        "cells", "algos", "scenarios", "config",
    ],
    "BENCH_async": [
        "m", "scenario", "config", "target_acc", "speedup_time_to_target",
        "sync.s_per_round", "sync.time_to_target_s", "sync.uplink_bits",
        "async.arrivals_per_flush", "async.time_to_target_s",
        "async.uplink_bits", "async.lag_histogram",
        "sync_parity.bit_exact", "cost_model_at_scale.n",
    ],
    "BENCH_robust": [
        "config", "m", "honest", "garbage_parity.bit_exact",
        "signflip_curve", "rr_curve", "recovery.recovered_frac",
    ],
    "BENCH_hier": [
        "m", "fan_out", "counter_merge_parity.bit_exact",
        "counter_merge_parity.engine_cells", "scaling",
        "root_ingress_growth", "simulated_note",
    ],
    "BENCH_fl_lm": [
        "parity.bit_exact", "parity.stream_peak_bytes", "memory", "rounds",
        "at_scale",
    ],
}

# metrics the perf-regression gate (--compare-baselines) never fails on:
# wall-clock and throughput vary across runners; the gate holds the line
# on the DERIVED numbers (bits, bytes, accuracy, parity flags, geometry),
# which are deterministic for fixed seeds.
DEFAULT_COMPARE_IGNORE = (
    r"_us\b|_ms\b|us_per|ms_per|_s\b|speedup|time_to_target|per_sec"
    r"|gb_per_s|compile_s|wall|arrivals_per_flush|stream_peak_bytes"
    r"|doubling_ratios|time_growth"   # ratios of wall times drift too
)


def _dig(obj, dotted: str) -> bool:
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return False
        obj = obj[part]
    return True


def validate_bench_artifacts(fast: bool, root: str = ".") -> list[str]:
    """Returns a list of problems ([] = all artifacts present and sane)."""
    problems = []
    for stem, required in BENCH_SCHEMAS.items():
        path = os.path.join(root, f"{stem}.fast.json" if fast else f"{stem}.json")
        if not os.path.exists(path):
            problems.append(f"{path}: missing (did its bench run?)")
            continue
        try:
            obj = json.load(open(path))
        except json.JSONDecodeError as e:
            problems.append(f"{path}: unparseable JSON ({e})")
            continue
        for key in required:
            if not _dig(obj, key):
                problems.append(f"{path}: missing required key {key!r}")
        if stem == "BENCH_exp" and not any(p.startswith(path) for p in problems):
            from repro.exp.report import validate_matrix

            try:
                validate_matrix(obj)
            except ValueError as e:
                problems.append(f"{path}: {e}")
        if stem == "BENCH_async" and not any(p.startswith(path) for p in problems):
            # sync-parity cell present + bit-exact, bits re-derivable from
            # fl/comms, async time-to-target beats sync
            from repro.sim.metrics import validate_async_artifact

            try:
                validate_async_artifact(obj)
            except ValueError as e:
                problems.append(f"{path}: {e}")
        if stem == "BENCH_robust" and not any(p.startswith(path) for p in problems):
            # garbage cell bit-exact with honest, equal billed bits across
            # every cell, defense recovers >= half the attack's accuracy gap
            from repro.exp.report import validate_robust

            try:
                validate_robust(obj)
            except ValueError as e:
                problems.append(f"{path}: {e}")
        if stem == "BENCH_hier" and not any(p.startswith(path) for p in problems):
            # counter-merge parity cell present + bit-exact, every scaling
            # row's per-tier bits re-derive from fl/comms.hier_round_bits,
            # tree root ingress O(log S) while the flat server's is linear
            from repro.exp.report import validate_hier

            try:
                validate_hier(obj)
            except ValueError as e:
                problems.append(f"{path}: {e}")
        if stem == "BENCH_fl_lm" and not any(p.startswith(path) for p in problems):
            # streamed-vs-materialized sketch parity bit-exact, measured
            # streaming peak == the O(max-layer + m) closed form re-derived
            # per row, subset bits re-invoiced via fl/comms.subset_round_bits
            from repro.exp.report import validate_fl_lm

            try:
                validate_fl_lm(obj)
            except ValueError as e:
                problems.append(f"{path}: {e}")
    return problems


def numeric_leaves(obj, prefix: str = "") -> dict:
    """Flatten a JSON object to {dotted.path: float} over its numeric
    scalar leaves (bools excluded; list items indexed as path[i])."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(numeric_leaves(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(numeric_leaves(v, f"{prefix}[{i}]"))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)
    return out


def compare_artifacts(old: dict, new: dict, tolerance: float,
                      max_rows: int = 25, ignore: str | None = None) -> list[str]:
    """Per-metric relative deltas between two bench artifacts; returns the
    list of violations (metrics whose |relative delta| exceeds
    `tolerance`). Prints a markdown table of the largest movers plus every
    violation; metrics present in only one file are reported but never
    violations (schema drift is --validate's job). `ignore`: regex of
    metric paths excluded from comparison entirely (the regression gate
    passes DEFAULT_COMPARE_IGNORE so runner-dependent timings never fail
    CI)."""
    import re

    a, b = numeric_leaves(old), numeric_leaves(new)
    shared = sorted(set(a) & set(b))
    if ignore:
        rx = re.compile(ignore)
        skipped = [k for k in shared if rx.search(k)]
        shared = [k for k in shared if not rx.search(k)]
        if skipped:
            print(f"(ignoring {len(skipped)} timing/throughput metrics)")
    deltas = {}
    for key in shared:
        base = abs(a[key])
        deltas[key] = (b[key] - a[key]) / base if base > 0 else (
            0.0 if b[key] == a[key] else float("inf")
        )
    violations = [k for k in shared if abs(deltas[k]) > tolerance]
    show = sorted(shared, key=lambda k: -abs(deltas[k]))
    show = list(dict.fromkeys(violations + show[:max_rows]))
    print(f"| metric | old | new | delta | over {tolerance:.0%}? |")
    print("|---|---|---|---|---|")
    for key in show:
        d = deltas[key]
        print(f"| {key} | {a[key]:.6g} | {b[key]:.6g} | {d:+.2%} "
              f"| {'YES' if key in violations else ''} |")
    only_old, only_new = set(a) - set(b), set(b) - set(a)
    print(f"\n{len(shared)} shared metrics, {len(violations)} over "
          f"tolerance; {len(only_old)} only in old, {len(only_new)} only "
          f"in new.")
    return violations


def kernel_table_markdown(table: list) -> str:
    """Render KernelProbe.table() rows (bench `kernels` saves them as
    `probe_table` in experiments/bench/kernels.json)."""
    lines = [
        "| kernel | steady calls | us/call | est GB/s | compile calls "
        "| compile (s) |",
        "|---|---|---|---|---|---|",
    ]
    for r in table:
        us = f"{r['us_per_call']:.1f}" if r["us_per_call"] is not None else "—"
        gb = (f"{r['est_gb_per_s']:.2f}"
              if r["est_gb_per_s"] is not None else "—")
        lines.append(
            f"| {r['kernel']} | {r['calls']} | {us} | {gb} "
            f"| {r['compile_calls']} | {r['compile_s']:.3f} |"
        )
    return "\n".join(lines)


def fmt_s(x):
    if x == 0:
        return "0"
    return f"{x:.2e}"


def load(mesh_name, tag=""):
    suffix = f"__{tag}" if tag else ""
    recs = {}
    for path in sorted(glob.glob(f"experiments/dryrun/*__{mesh_name}{suffix}.json")):
        rec = json.load(open(path))
        base = os.path.basename(path).split("__")
        recs[(base[0], base[1])] = rec
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--validate", action="store_true",
                    help="check BENCH_* artifact schemas; exit 1 on any miss")
    ap.add_argument("--fast", action="store_true",
                    help="with --validate: check the *.fast.json smoke tier")
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    help="per-metric relative deltas between two bench "
                         "artifacts; exit 1 if any exceeds --tolerance")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative delta allowed by --compare (default 0.25)")
    ap.add_argument("--ignore", default=None, metavar="REGEX",
                    help="metric paths matching REGEX are excluded from "
                         "--compare / --compare-baselines (default for "
                         "--compare-baselines: the built-in timing filter)")
    ap.add_argument("--compare-baselines", default=None, metavar="DIR",
                    help="perf-regression gate: compare every "
                         "BENCH_*.fast.json baseline in DIR against the "
                         "fresh repo-root artifact of the same name, "
                         "ignoring timing metrics; exit 1 on any drift "
                         "past --tolerance or any missing fresh artifact")
    ap.add_argument("--kernels", nargs="?", const="experiments/bench/kernels.json",
                    metavar="PATH", default=None,
                    help="render the per-kernel probe table from the "
                         "kernels bench artifact")
    args = ap.parse_args()
    if args.compare:
        old, new = (json.load(open(p)) for p in args.compare)
        violations = compare_artifacts(old, new, args.tolerance,
                                       ignore=args.ignore)
        if violations:
            sys.exit(1)
        return
    if args.compare_baselines:
        ignore = args.ignore or DEFAULT_COMPARE_IGNORE
        baselines = sorted(
            glob.glob(os.path.join(args.compare_baselines, "BENCH_*.fast.json"))
        )
        if not baselines:
            print(f"no BENCH_*.fast.json baselines in {args.compare_baselines}")
            sys.exit(1)
        failed = []
        for base_path in baselines:
            name = os.path.basename(base_path)
            fresh_path = name
            print(f"\n## {name}")
            if not os.path.exists(fresh_path):
                print(f"FRESH MISSING: {fresh_path} (did its bench run?)")
                failed.append(name)
                continue
            old = json.load(open(base_path))
            new = json.load(open(fresh_path))
            if compare_artifacts(old, new, args.tolerance, ignore=ignore):
                failed.append(name)
        if failed:
            print(f"\nPERF REGRESSION GATE FAILED: {', '.join(failed)}")
            sys.exit(1)
        print(f"\nregression gate: {len(baselines)} baselines within "
              f"{args.tolerance:.0%}")
        return
    if args.kernels:
        obj = json.load(open(args.kernels))
        table = obj.get("probe_table")
        if not table:
            print(f"{args.kernels}: no probe_table "
                  "(re-run `python -m benchmarks.run kernels`)")
            sys.exit(1)
        print(kernel_table_markdown(table))
        return
    if args.validate:
        problems = validate_bench_artifacts(fast=args.fast)
        tier = "fast" if args.fast else "canonical"
        if problems:
            for p in problems:
                print(f"SCHEMA FAIL: {p}")
            sys.exit(1)
        print(f"all {len(BENCH_SCHEMAS)} {tier} BENCH artifacts validate")
        return
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    recs = load(mesh_name, args.tag)
    if not recs:
        print(f"(no artifacts for {mesh_name})")
        return

    print(f"### Mesh {mesh_name} ({'2x16x16 pod,data,model' if args.multi_pod else '16x16 data,model'})\n")
    print("| arch | shape | status | compute (s) | memory (s) | collective (s) "
          "| dominant | coll bytes/dev | useful FLOPs ratio | HBM GiB/dev (args+tmp) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    arches = sorted({a for a, _ in recs})
    for arch in arches:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                continue
            if rec["status"] == "skipped":
                print(f"| {arch} | {shape} | skipped (sub-quadratic N/A) | — | — | — | — | — | — | — |")
                continue
            if rec["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | — | — | — | — | — | — | — |")
                continue
            r = rec["roofline"]
            ma = rec.get("memory_analysis", {})
            hbm = (ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)) / 2 ** 30
            print(
                f"| {arch} | {shape} | ok | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['dominant'].replace('_s','')} "
                f"| {r['collective_bytes_total']/2**20:.1f} MiB | {rec['useful_flops_ratio']:.3f} "
                f"| {hbm:.2f} |"
            )
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    err = len(recs) - ok - sk
    print(f"\n{ok} ok / {sk} skipped (documented) / {err} errors out of {len(recs)} combos.\n")


if __name__ == "__main__":
    main()
