"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the artifacts.

Usage: PYTHONPATH=src python -m benchmarks.report [--multi-pod] [--tag X]
Prints a GitHub-markdown table; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x == 0:
        return "0"
    return f"{x:.2e}"


def load(mesh_name, tag=""):
    suffix = f"__{tag}" if tag else ""
    recs = {}
    for path in sorted(glob.glob(f"experiments/dryrun/*__{mesh_name}{suffix}.json")):
        rec = json.load(open(path))
        base = os.path.basename(path).split("__")
        recs[(base[0], base[1])] = rec
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    recs = load(mesh_name, args.tag)
    if not recs:
        print(f"(no artifacts for {mesh_name})")
        return

    print(f"### Mesh {mesh_name} ({'2x16x16 pod,data,model' if args.multi_pod else '16x16 data,model'})\n")
    print("| arch | shape | status | compute (s) | memory (s) | collective (s) "
          "| dominant | coll bytes/dev | useful FLOPs ratio | HBM GiB/dev (args+tmp) |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    arches = sorted({a for a, _ in recs})
    for arch in arches:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                continue
            if rec["status"] == "skipped":
                print(f"| {arch} | {shape} | skipped (sub-quadratic N/A) | — | — | — | — | — | — | — |")
                continue
            if rec["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | — | — | — | — | — | — | — |")
                continue
            r = rec["roofline"]
            ma = rec.get("memory_analysis", {})
            hbm = (ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)) / 2 ** 30
            print(
                f"| {arch} | {shape} | ok | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | {r['dominant'].replace('_s','')} "
                f"| {r['collective_bytes_total']/2**20:.1f} MiB | {rec['useful_flops_ratio']:.3f} "
                f"| {hbm:.2f} |"
            )
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    err = len(recs) - ok - sk
    print(f"\n{ok} ok / {sk} skipped (documented) / {err} errors out of {len(recs)} combos.\n")


if __name__ == "__main__":
    main()
