"""Round-scaling benchmark for the sharded federation executor.

Grid over (sampled clients S) x (fed mesh size F): one full
`PFed1BS.round` through the shard_map executor (launch/fedexec.py,
DESIGN.md §6) per cell, best-observed (minimum) per-round wall time over
several timed rounds — see bench_cell for why min, not median. Emits
BENCH_round_sharded.json at the repo root (and a copy under
experiments/bench/) with, per mesh size, the time ratio when S doubles —
the acceptance signal is that this ratio stays below 2 (sub-linear
scaling: the executor amortizes fixed round overhead and parallelizes the
client shards) on at least two mesh sizes.

Multi-device federations are SIMULATED on the CPU host: XLA only exposes
multiple host devices if --xla_force_host_platform_device_count is set
before jax is imported, so this script re-spawns itself as a subprocess
with that flag baked into XLA_FLAGS (device count = the largest mesh in
the grid, constant across all cells so every cell runs on the identical
backend).

Run:  PYTHONPATH=src python -m benchmarks.round_sharded_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_CHILD_ENV = "_ROUND_SHARDED_BENCH_CHILD"


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--rounds", type=int, default=0, help="0 => auto")
    return ap.parse_args(argv)


def grid(fast: bool):
    mesh_sizes = [1, 2] if fast else [1, 2, 4]
    clients = [4, 8] if fast else [4, 8, 16, 32]
    return mesh_sizes, clients


def _respawn_with_devices(n: int) -> None:
    """Re-exec this module with the forced host device count (must land in
    XLA_FLAGS before the child imports jax)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}"
    ).strip()
    env[_CHILD_ENV] = "1"
    ret = subprocess.call(
        [sys.executable, "-m", "benchmarks.round_sharded_bench", *sys.argv[1:]],
        env=env,
    )
    sys.exit(ret)


def bench_cell(mesh_size: int, s: int, *, rounds: int):
    """Best-observed per-round us for one (mesh, clients) cell.

    Min, not median: the forced-host-device simulation oversubscribes the
    container's cores, so wall-clock swings multiples between rounds; the
    minimum over `rounds` timed rounds approximates the uncontended round
    time (same reasoning as sketch_bench's interleaved-median, but robust
    to a grid too large to interleave)."""
    import jax

    from benchmarks.fl_bench import make_task
    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.data import synthetic as ds

    local_steps, batch = 2, 16
    data, init_fn, loss_fn, _ = make_task(num_clients=s, hidden=32)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    cfg = PFed1BSConfig(
        num_clients=s, participate=s, local_steps=local_steps, chunk=4096,
        sharded_round=True, fed_shards=mesh_size,
        diagnostics=False,            # the production wire path
    )
    eng = PFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(2))

    batch_sets, keys = [], []
    for r in range(rounds + 2):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(4), r))
        batch_sets.append(jax.block_until_ready(
            ds.sample_round_batches(kb, data, local_steps, batch)))
        keys.append(kr)

    # warmup: compile + two executed rounds (the first post-compile round
    # still pays allocator/thread-pool startup)
    for r in range(2):
        state, m = eng.round(state, batch_sets[r], data.weights, keys[r])
        jax.block_until_ready(m["task_loss"])
    times = []
    for r in range(2, rounds + 2):
        t0 = time.perf_counter()
        state, m = eng.round(state, batch_sets[r], data.weights, keys[r])
        jax.block_until_ready(m["task_loss"])
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6  # us


def run_grid(args):
    import jax

    mesh_sizes, clients = grid(args.fast)
    rounds = args.rounds or (3 if args.fast else 8)
    cells = []
    for f in mesh_sizes:
        for s in clients:
            if s % f:
                continue
            us = bench_cell(f, s, rounds=rounds)
            cells.append({"mesh": f, "clients": s, "round_us": us})
            print(f"round_sharded/mesh={f}/S={s},{us:.1f},", flush=True)

    # scaling: per-doubling ratios (detail) + the endpoint criterion —
    # sub-linear iff total time growth < total client growth over the whole
    # S range (per-doubling ratios alone are too noisy on a contended host)
    scaling = {}
    sublinear = []
    for f in mesh_sizes:
        row = {c["clients"]: c["round_us"] for c in cells if c["mesh"] == f}
        if len(row) < 2:
            continue
        ratios = {}
        for s in sorted(row):
            if 2 * s in row:
                ratios[f"S={s}->S={2 * s}"] = row[2 * s] / row[s]
        lo, hi = min(row), max(row)
        growth = row[hi] / row[lo]
        scaling[f"mesh={f}"] = {
            "doubling_ratios": ratios,
            "time_growth": growth,          # time(S_max) / time(S_min)
            "client_growth": hi / lo,       # S_max / S_min
            "sublinear": growth < hi / lo,
        }
        if growth < hi / lo:
            sublinear.append(f)
    return {
        "fast": args.fast,
        "device_count": len(jax.devices()),
        "rounds_timed": rounds,
        "local_steps": 2,
        "grid": cells,
        "scaling": scaling,
        "sublinear_mesh_sizes": sublinear,
    }


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """--fast smoke runs land in BENCH_round_sharded.fast.json and never
    touch the canonical artifact (mirrors sketch_bench.write_artifacts)."""
    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = (
            "BENCH_round_sharded.fast.json" if fast else "BENCH_round_sharded.json"
        )
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_round_sharded.json", "w") as f:
            json.dump(results, f, indent=2)
    return out_path


def main() -> None:
    args = parse_args()
    mesh_sizes, _ = grid(args.fast)
    if os.environ.get(_CHILD_ENV) != "1":
        _respawn_with_devices(max(mesh_sizes))
    results = run_grid(args)
    for f, rec in results["scaling"].items():
        print(f"# {f}: S x{rec['client_growth']:.0f} -> time "
              f"x{rec['time_growth']:.2f} "
              f"({'sub' if rec['sublinear'] else 'SUPER'}-linear)")
    print(f"# sub-linear on mesh sizes: {results['sublinear_mesh_sizes']}")
    out_path = write_artifacts(results, args.out)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
