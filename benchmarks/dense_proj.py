"""Dense-Gaussian-projection variant of pFed1BS (paper §A.3 ablation)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatten, regularizer
from repro.core import sketch as sk
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms


class DensePFed1BS(PFed1BS):
    """Same algorithm, Phi materialized as a dense Gaussian matrix."""

    def __init__(self, cfg, loss_fn, template, seed=7):
        super().__init__(cfg, loss_fn, template)
        self.phi = sk.dense_gaussian_sketch(self.n, self.spec.m, seed=seed)

    def _sketch_client(self, params):
        return self.phi @ flatten.ravel(params)

    def _client_update(self, params, batches, v):
        cfg = self.cfg

        def objective(p, batch):
            task = self.loss_fn(p, batch)
            w = flatten.ravel(p)
            z = self.phi @ w
            reg = regularizer.smoothed_reg(v, z, cfg.gamma)
            return task + cfg.lam * reg + 0.5 * cfg.mu * jnp.sum(w * w), task

        def step(p, batch):
            (_, task), grads = jax.value_and_grad(objective, has_aux=True)(p, batch)
            return jax.tree.map(lambda a, g: a - cfg.lr * g, p, grads), task

        params, losses = jax.lax.scan(step, params, batches)
        return params, jnp.mean(losses)


def run_dense_pfed1bs(data, init_fn, loss_fn, eval_fn, *, rounds=15,
                      local_steps=5, batch=32, lr=0.05, seed=0):
    template = jax.eval_shape(init_fn, jax.random.key(1))
    cfg = PFed1BSConfig(
        num_clients=data.num_clients, participate=data.num_clients,
        local_steps=local_steps, lr=lr, m_ratio=0.1, chunk=4096,
    )
    eng = DensePFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(seed + 1))
    losses = []
    t0 = time.time()
    for r in range(rounds):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(seed + 2), r))
        batches = ds.sample_round_batches(kb, data, local_steps, batch)
        state, m = eng.round(state, batches, data.weights, kr)
        losses.append(float(m["task_loss"]))
    wall = time.time() - t0
    accs = jax.vmap(eval_fn)(state.clients, data.test_x, data.test_y)
    n = eng.n
    return {
        "algo": "pfed1bs_dense_phi",
        "acc": float(accs.mean()),
        "loss_curve": losses,
        "us_per_round": wall / rounds * 1e6,
        "mb_per_round": comms.round_bits("pfed1bs", n=n, m=eng.spec.m,
                                         s=data.num_clients)["total_mb"],
    }
