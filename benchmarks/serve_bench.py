"""Serving-tier benchmark -> BENCH_serve.json (DESIGN.md §7.4).

Three sections:

  quality     train pFed1BS on the synthetic non-iid FL task, then serve the
              personalized models from (a) an fp32-per-client DenseStore and
              (b) the one-bit SketchStore, and compare personalized test
              accuracy. Acceptance: the sketch-store gap stays within 1
              point while resident state compresses >= 20x (K = 64 clients,
              m = n EDEN regime: ~1 bit/param + amortized fp32 base).
  reconstruct batched fused-adjoint decode (ONE kernel pass for B clients)
              vs B sequential adjoints — the store's decode path win.
  stream      Zipf-distributed request streams over K in {64, 256, 1024}
              personalized LMs through the ServeEngine: tokens/sec, p50/p99
              materialization latency (sketch-derived, DESIGN.md §14), LRU
              hit rate, resident bytes per client vs the fp32 store, and a
              per-cell SLO verdict against the committed spec
              benchmarks/slo_serve.json. The engine's tracer is an
              always-on FlightRecorder ring: a breached cell snapshots
              FLIGHT_serve[.fast].json for postmortem, and --trace dumps
              the ring as TRACE_serve[.fast].json (billing kind "serve" —
              zero federation bits, asserted), validated in-process like
              the exp/async/hier benches.

Run:  PYTHONPATH=src python -m benchmarks.serve_bench [--fast] [--trace]
(--fast shrinks every axis and writes BENCH_serve.fast.json, never the
canonical artifacts.)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import flatten
from repro.obs import slo as obsslo
from repro.serve import router
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.store import DenseStore, SketchStore, make_store_spec

SLO_SPEC_PATH = os.path.join(os.path.dirname(__file__), "slo_serve.json")


# ---------------------------------------------------------------------------
# quality: sketch store vs fp32 store at matched serving config
# ---------------------------------------------------------------------------

def bench_quality(fast=False):
    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.data import synthetic as ds
    from repro.models import smallnets as sn

    k = 12 if fast else 64
    rounds = 3 if fast else 12
    local_steps, batch = 5, 32

    key = jax.random.key(0)
    data = ds.make_federated_classification(
        key, num_clients=k, classes_per_client=2, noise=1.2,
        train_per_client=256, test_per_client=128,
    )
    init_fn = lambda kk: sn.init_mlp(kk, input_dim=784, hidden=200)
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    eval_fn = lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
    template = jax.eval_shape(init_fn, jax.random.key(1))

    cfg = PFed1BSConfig(num_clients=k, participate=k, local_steps=local_steps)
    eng = PFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(2))
    for r in range(rounds):
        kb, kr = jax.random.split(jax.random.fold_in(key, r))
        state, _ = eng.round(
            state, ds.sample_round_batches(kb, data, local_steps, batch),
            data.weights, kr,
        )

    # serving stores: fp32 baseline vs one-bit sketch-delta (base = client mean)
    base = jax.tree.map(lambda a: jnp.mean(a.astype(jnp.float32), 0), state.clients)
    dense = DenseStore(k, base)
    dense.put_batch(np.arange(k), state.clients)
    sspec = make_store_spec(base, k, m_ratio=1.0, chunk=4096)
    store = SketchStore(sspec, base)
    store.put_batch(np.arange(k), state.clients)

    ids = np.arange(k)
    acc_fp32 = jax.vmap(eval_fn)(dense.materialize(ids), data.test_x, data.test_y)
    acc_sket = jax.vmap(eval_fn)(store.materialize(ids), data.test_x, data.test_y)
    acc_base = jax.vmap(lambda x, y: eval_fn(base, x, y))(data.test_x, data.test_y)
    rb = store.resident_bytes()
    return {
        "clients": k,
        "rounds": rounds,
        "model_n": sspec.n,
        "acc_fp32_store": float(acc_fp32.mean()),
        "acc_sketch_store": float(acc_sket.mean()),
        "acc_base_only": float(acc_base.mean()),
        "acc_gap_points": float(acc_fp32.mean() - acc_sket.mean()) * 100,
        "per_client_bytes_fp32": rb["fp32_per_client_bytes"],
        "per_client_bytes_sketch": rb["per_client_bytes"],
        "compression_vs_fp32": rb["compression_vs_fp32"],
    }


# ---------------------------------------------------------------------------
# reconstruct: one batched pass vs B sequential adjoints
# ---------------------------------------------------------------------------

def bench_reconstruct(fast=False):
    """Store-level decode: ONE batched materialize (the §7.2 fold — unpack,
    batched fused adjoint, scale, base-add, unravel in a single jitted
    call) vs B sequential materialize_one calls, i.e. what a store without
    the batched path would do per cache-miss group. Interleaved-median
    timing (the sketch_bench idiom) because absolute CPU wall time swings
    with host contention. On this CPU ref host the win is dispatch/epilogue
    amortization; on TPU the fold also collapses B kernel launches into
    one row-grid pass."""
    from repro.models import smallnets as sn

    hidden = 64 if fast else 200
    kmax = 8 if fast else 32
    base = sn.init_mlp(jax.random.key(0), input_dim=784, hidden=hidden)
    clients = jax.vmap(
        lambda k: sn.init_mlp(k, input_dim=784, hidden=hidden)
    )(jax.random.split(jax.random.key(1), kmax))
    sspec = make_store_spec(base, kmax, m_ratio=1.0, chunk=4096)
    store = SketchStore(sspec, base)
    store.put_batch(np.arange(kmax), clients)

    out = {"n": sspec.n, "m": sspec.m, "chunk": sspec.chunk, "batches": {}}
    for b in (8,) if fast else (8, 32):
        ids = list(range(b))
        batched = lambda: store.materialize(ids)
        sequential = lambda: [store.materialize_one(i) for i in ids]
        jax.block_until_ready(batched())          # compile both shapes
        jax.block_until_ready(sequential())
        t_bat, t_seq = [], []
        for _ in range(10):
            t0 = time.perf_counter()
            jax.block_until_ready(batched())
            t_bat.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(sequential())
            t_seq.append(time.perf_counter() - t0)
        bat_us = float(np.median(t_bat)) * 1e6
        seq_us = float(np.median(t_seq)) * 1e6
        out["batches"][str(b)] = {
            "sequential_us": seq_us,
            "batched_us": bat_us,
            "speedup": seq_us / bat_us,
        }
    return out


# ---------------------------------------------------------------------------
# stream: Zipf traffic over K personalized LMs
# ---------------------------------------------------------------------------

def _perturbed_clients(base, keys, scale=0.05):
    """Stand-ins for FL output at serving scale: base + small random
    residual per client (training K=1024 LMs on this host is not the
    point of the stream bench; quality is measured in bench_quality)."""

    def one(k):
        leaves, treedef = jax.tree_util.tree_flatten(base)
        ks = jax.random.split(k, len(leaves))
        noise = [
            scale * jax.random.normal(kk, l.shape, jnp.float32)
            for kk, l in zip(ks, leaves)
        ]
        return jax.tree_util.tree_unflatten(
            treedef, [l + nz for l, nz in zip(leaves, noise)]
        )

    return jax.vmap(one)(keys)


def bench_stream(fast=False, trace=False):
    from repro import configs
    from repro.models import lm

    arch = configs.get("granite-8b").reduced(remat=False)
    base = lm.init_params(arch, jax.random.key(0))
    n = flatten.tree_size(base)
    grid = (16, 64) if fast else (64, 256, 1024)
    requests = 32 if fast else 96
    ecfg = EngineConfig(prompt_len=8, gen_len=16, max_batch=8, hot_models=16)
    import dataclasses

    spec = obsslo.SLOSpec.load(SLO_SPEC_PATH)
    # always-on flight ring: the engine traces into a bounded buffer so a
    # breached cell can snapshot the last moments for postmortem
    recorder = obs.FlightRecorder(clock="wall", capacity=4096)
    suffix = ".fast" if fast else ""
    flight_path = f"FLIGHT_serve{suffix}.json"

    out = {"arch": arch.name, "model_n": n,
           "engine": dataclasses.asdict(ecfg), "grid": {},
           "slo": {"spec": spec.name, "ok": True, "breaches": []}}

    for k in grid:
        sspec = make_store_spec(base, k, m_ratio=1.0, chunk=4096)
        store = SketchStore(sspec, base)
        enc = 32  # encode in slabs: never hold K full fp32 models at once
        for lo in range(0, k, enc):
            ids = np.arange(lo, min(lo + enc, k))
            keys = jax.random.split(jax.random.fold_in(jax.random.key(1), lo), len(ids))
            store.put_batch(ids, _perturbed_clients(base, keys))
        engine = ServeEngine(arch, store, ecfg, tracer=recorder)
        cids = router.zipf_stream(k, k, requests, alpha=1.1)
        prompts = router.random_prompts(k + 1, requests, ecfg.prompt_len, arch.vocab)
        rep = router.run_stream(engine, cids, prompts, zipf_alpha=1.1, warm=True)
        rb = store.resident_bytes()
        cell = {
            **rep.to_dict(),
            "per_client_bytes_sketch": rb["per_client_bytes"],
            "per_client_bytes_fp32": rb["fp32_per_client_bytes"],
            "compression_vs_fp32": rb["compression_vs_fp32"],
        }
        # per-cell SLO verdict: thresholds on the cell scalars, burn rates
        # on the engine's recent-materialization event ring
        verdict = obsslo.evaluate(spec, cell, events=engine.slo_events(),
                                  now=engine.now)
        cell["slo"] = verdict
        out["grid"][str(k)] = cell
        if not verdict["ok"]:
            out["slo"]["ok"] = False
            out["slo"]["breaches"].extend(
                f"K={k}:{b}" for b in verdict["breaches"])
            if not os.path.exists(flight_path):   # first breach wins
                obs.maybe_snapshot(
                    recorder, flight_path, slo_verdict=verdict,
                    meta={"bench": "serve", "fast": fast, "K": k})
                out["slo"]["flight"] = flight_path

    if trace:
        trace_path = f"TRACE_serve{suffix}.json"
        obj = obs.dump_trace(trace_path, recorder,
                             billing=[{"kind": "serve"}],
                             meta={"bench": "serve", "fast": fast})
        obs.validate_trace(obj)   # in-process: bad trace fails the bench
        out["trace_path"] = trace_path
    return out


# ---------------------------------------------------------------------------

def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """BENCH_serve.json writer; --fast runs land in BENCH_serve.fast.json and
    never touch the canonical artifacts (same policy as sketch_bench)."""
    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_serve.fast.json" if fast else "BENCH_serve.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_serve.json", "w") as f:
            json.dump(results, f, indent=2)
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="dump the serving flight ring as TRACE_serve[.fast].json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    results = {"fast": args.fast}
    results["quality"] = bench_quality(fast=args.fast)
    q = results["quality"]
    print(f"quality: fp32 {q['acc_fp32_store']:.4f}  sketch "
          f"{q['acc_sketch_store']:.4f}  (gap {q['acc_gap_points']:.2f} pts, "
          f"base-only {q['acc_base_only']:.4f})  "
          f"compression {q['compression_vs_fp32']:.1f}x")

    results["reconstruct"] = bench_reconstruct(fast=args.fast)
    for b, r in results["reconstruct"]["batches"].items():
        print(f"reconstruct B={b}: sequential {r['sequential_us']:.0f}us  "
              f"batched {r['batched_us']:.0f}us  ({r['speedup']:.2f}x)")

    results["stream"] = bench_stream(fast=args.fast, trace=args.trace)
    for k, r in results["stream"]["grid"].items():
        print(f"stream K={k}: {r['tokens_per_sec']:.0f} tok/s decode  "
              f"mat p50 {r['materialize_p50_ms']:.1f}ms p99 "
              f"{r['materialize_p99_ms']:.1f}ms  hit {r['hit_rate']:.2f}  "
              f"telemetry {r['telemetry_bytes']}B  "
              f"{r['per_client_bytes_sketch'] / 1e3:.0f} KB/client "
              f"({r['compression_vs_fp32']:.1f}x)  "
              f"slo {'ok' if r['slo']['ok'] else 'BREACH'}")
    s = results["stream"]["slo"]
    print(f"slo[{s['spec']}]: {'OK' if s['ok'] else 'BREACH ' + str(s['breaches'])}")
    if results["stream"].get("trace_path"):
        print(f"wrote {results['stream']['trace_path']}")

    out_path = write_artifacts(results, args.out)
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
