"""Robustness bench: accuracy vs adversary fraction x defense, and vs
epsilon — emits BENCH_robust.json (DESIGN.md §10).

The experimental design, in the order the numbers should be read:

  honest          the baseline cell: no adversary, no privacy, no defense.
  garbage_parity  ScaledGarbage(20%, scale=1e6) vs honest — the
                  CALIBRATION cell: sign quantization provably neutralizes
                  magnitude garbage (sign(c*z) = sign(z), c > 0), so the
                  attacked run must be BIT-exact with the honest one, per
                  seed, accuracy and loss curve both. If this cell drifts,
                  the injection hook leaked past the encoder.
  signflip_curve  accuracy vs SignFlipAttack fraction (0-40% of clients)
                  x defense in {none, trim, reputation}. The attack is
                  given its worst case: client weights are lognormal-
                  imbalanced and the byzantine PLACEMENT is adversarial —
                  the mask seed is searched so the compromised clients
                  hold the largest p_k mass below the 50% breakdown point
                  (a 20%-of-clients bloc holding ~46% of the vote mass).
                  This is what makes 20% sign-flippers actually corrupt a
                  weighted majority vote; head-count-minority attacks with
                  uniform weights are absorbed by the vote's own margin.
  rr_curve        accuracy vs RandomizedResponse epsilon (no adversary):
                  the privacy-utility knee of the one-bit uplink.
  recovery        the headline gate: at 20% sign-flippers, the trimmed
                  vote must recover >= half of the accuracy gap the attack
                  opened, at unchanged billed uplink bits
                  (exp/report.validate_robust re-checks from the file).

Every cell shares ONE scenario (Dirichlet 0.3, lognormal imbalance, full
participation) and is averaged over the same seeds, so differences are
attributable to the attack/defense axes alone.

Run: PYTHONPATH=src python -m benchmarks.run robust [--fast]
     (or this module directly: python -m benchmarks.robust_bench [--fast])
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np


def adversarial_placement(weights, fraction: float, num_clients: int,
                          target: float = 0.42, search: int = 300) -> int:
    """Adversarial byzantine placement: the mask seed whose compromised
    clients hold p_k mass closest to `target` — heavy (far above the
    client fraction, which is what imbalance buys the attacker) but
    safely below the 50% breakdown point, past which NO vote defense is
    sound (a byzantine vote majority owns every weighted consensus bit,
    including the defense's reference) and the comparison measures
    nothing but impossibility."""
    from repro.core import rounds

    w = np.asarray(weights)
    best, best_d = 0, float("inf")
    for seed in range(search):
        mask = np.asarray(rounds.byzantine_mask(seed, num_clients, fraction))
        d = abs(float((mask * w).sum()) - target)
        if d < best_d:
            best, best_d = seed, d
    return best


def bench_robust(fast: bool = False, progress=None) -> dict:
    from repro.exp import report, runner, scenarios

    base = scenarios.Scenario(
        "robust", scenarios.DirichletPartition(0.3),
        scenarios.FullParticipation(), imbalance=1.0,
    )
    if fast:
        cfg = runner.ExpConfig(
            num_clients=10, rounds=8, local_steps=2, batch=16, hidden=32,
            train_per_client=32, test_per_client=32, chunk=2048,
            m_ratio=0.25, lam=0.1, noise_scale=3.0,
            trim_frac=0.2, rep_beta=0.5,
        )
        seeds = (2,)
        fractions = (0.0, 0.2)
        defenses = ("none", "trim")
        epsilons = (2.0,)
    else:
        cfg = runner.ExpConfig(
            num_clients=10, rounds=10, local_steps=2, batch=16, hidden=32,
            train_per_client=32, test_per_client=32, chunk=2048,
            m_ratio=0.25, lam=0.1, noise_scale=3.0,
            trim_frac=0.2, rep_beta=0.5,
        )
        seeds = (0, 1, 2)
        fractions = (0.0, 0.1, 0.2, 0.3, 0.4)
        defenses = ("none", "trim", "reputation")
        epsilons = (0.5, 1.0, 2.0, 4.0)

    # the placement search needs the realized client weights
    import jax

    from repro.core import rounds

    data = base.build(jax.random.key(0), cfg.num_clients)
    placements = {
        f: adversarial_placement(data.weights, f, cfg.num_clients)
        for f in fractions if f > 0
    }

    def run(scenario, defense="none", tag=""):
        """One seed-averaged cell; keeps per-seed curves for parity."""
        per_seed = [
            runner.run_cell(
                "pfed1bs", scenario,
                dataclasses.replace(cfg, defense=defense, seed=s),
            )
            for s in seeds
        ]
        cell = dict(per_seed[0])
        cell["acc"] = float(np.mean([c["acc"] for c in per_seed]))
        cell["acc_per_seed"] = [c["acc"] for c in per_seed]
        cell["loss_curves_per_seed"] = [c["loss_curve"] for c in per_seed]
        cell["uplink_bits"] = sum(c["uplink_bits"] for c in per_seed)
        cell["downlink_bits"] = sum(c["downlink_bits"] for c in per_seed)
        if progress is not None:
            progress(tag or scenario.name, cell)
        return cell

    honest = run(base, tag="honest")

    # -- calibration: scaled garbage is provably a no-op ---------------------
    garbage = run(
        dataclasses.replace(
            base, adversary=scenarios.ScaledGarbage(
                0.2, scale=1e6, seed=placements.get(0.2, 0)
            ),
        ),
        tag="garbage20",
    )
    garbage_parity = {
        "honest_acc": honest["acc"],
        "garbage_acc": garbage["acc"],
        "honest_loss_curve": honest["loss_curves_per_seed"],
        "garbage_loss_curve": garbage["loss_curves_per_seed"],
        "bit_exact": (
            garbage["acc_per_seed"] == honest["acc_per_seed"]
            and garbage["loss_curves_per_seed"] == honest["loss_curves_per_seed"]
        ),
    }

    # -- accuracy vs adversary fraction x defense ----------------------------
    signflip_curve = []
    for frac in fractions:
        adv = (
            scenarios.SignFlipAttack(frac, seed=placements[frac])
            if frac > 0 else None
        )
        scen = dataclasses.replace(base, adversary=adv)
        for defense in defenses:
            signflip_curve.append(
                run(scen, defense, tag=f"signflip{frac:.0%}/{defense}")
            )

    # -- accuracy vs epsilon -------------------------------------------------
    rr_curve = [
        run(
            dataclasses.replace(
                base, privacy=scenarios.RandomizedResponse(eps)
            ),
            tag=f"rr-eps{eps}",
        )
        for eps in epsilons
    ]

    # -- the headline recovery gate ------------------------------------------
    at = lambda f, d: next(
        c for c in signflip_curve
        if c["adversary_fraction"] == f and c["defense"] == d
    )
    undef = at(0.2, "none")
    defended = max(
        (at(0.2, d) for d in defenses if d != "none"),
        key=lambda c: c["acc"],
    )
    gap = honest["acc"] - undef["acc"]
    recovery = {
        "fraction": 0.2,
        "defense": defended["defense"],
        "honest_acc": honest["acc"],
        "undefended_acc": undef["acc"],
        "defended_acc": defended["acc"],
        "recovered_frac": (
            (defended["acc"] - undef["acc"]) / gap if gap > 0 else 1.0
        ),
    }

    results = {
        "fast": fast,
        "config": dataclasses.asdict(cfg),
        "seeds": list(seeds),
        "m": honest["m"],
        "placements": {str(f): s for f, s in placements.items()},
        "byz_mass": {
            str(f): float(
                (np.asarray(rounds.byzantine_mask(s, cfg.num_clients, f))
                 * np.asarray(data.weights)).sum()
            )
            for f, s in placements.items()
        },
        "honest": honest,
        "garbage_parity": garbage_parity,
        "signflip_curve": signflip_curve,
        "rr_curve": rr_curve,
        "recovery": recovery,
    }
    report.validate_robust(results)
    return results


def write_artifacts(results: dict, out_path: str | None = None) -> str:
    """BENCH_robust.json writer; --fast runs land in BENCH_robust.fast.json
    and never touch the canonical artifacts. The canonical run also renders
    experiments/bench/ROBUST.md."""
    from repro.exp import report

    fast = bool(results.get("fast"))
    if out_path is None:
        out_path = "BENCH_robust.fast.json" if fast else "BENCH_robust.json"
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    if not fast:
        os.makedirs("experiments/bench", exist_ok=True)
        with open("experiments/bench/BENCH_robust.json", "w") as f:
            json.dump(results, f, indent=2)
        with open("experiments/bench/ROBUST.md", "w") as f:
            f.write(report.robust_markdown(results))
    return out_path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    results = bench_robust(
        fast=args.fast,
        progress=lambda tag, c: print(
            f"{tag:24s} acc={c['acc']:.4f} bits={c['uplink_bits']:,}",
            flush=True,
        ),
    )
    rec = results["recovery"]
    print(
        f"recovery: defense={rec['defense']} "
        f"recovered_frac={rec['recovered_frac']:.2f}"
    )
    path = write_artifacts(results, args.out)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
