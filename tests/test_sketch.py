"""SRHT sketch operator properties (paper Lemma 2 + adjoint exactness),
including hypothesis property tests over dimensions/seeds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, hst

from repro.core import sketch as sk


def _spec(n, ratio=0.1, chunk=256, seed=0, mode="auto"):
    return sk.make_sketch_spec(n, ratio, chunk=chunk, seed=seed, mode=mode)


@pytest.mark.parametrize("mode,chunk,n", [
    ("chunked", 128, 1000), ("chunked", 256, 4096), ("global", 4096, 700),
])
def test_adjoint_identity(mode, chunk, n):
    spec = _spec(n, chunk=chunk, mode=mode)
    x = jax.random.normal(jax.random.key(1), (n,))
    v = jax.random.normal(jax.random.key(2), (spec.m,))
    lhs = jnp.vdot(sk.sketch_forward(spec, x), v)
    rhs = jnp.vdot(x, sk.sketch_adjoint(spec, v))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_spectral_norm_exact_lemma2():
    """||Phi|| = sqrt(n'/m) EXACTLY (per block) — the paper's Lemma 2."""
    for mode, chunk, n in [("global", 1024, 600), ("chunked", 128, 700)]:
        spec = _spec(n, chunk=chunk, mode=mode)
        phi = np.asarray(sk.materialize(spec))
        sv = np.linalg.svd(phi, compute_uv=False)
        np.testing.assert_allclose(sv[0], spec.scale, rtol=1e-5)


def test_phi_phit_scaled_identity():
    """Q Q^T = I => Phi Phi^T = (n'/m) I per block (any row subset).
    Exact only when n is a chunk multiple (zero-padding truncates the
    last block's row support otherwise)."""
    spec = _spec(512, chunk=256, mode="chunked")
    phi = np.asarray(sk.materialize(spec))
    g = phi @ phi.T
    np.testing.assert_allclose(
        g, (spec.scale ** 2) * np.eye(spec.m), atol=1e-4
    )


def test_sketch_preserves_norm_in_expectation():
    """JL behaviour: E||Phi x||^2 / ||x||^2 ~ n'/m * (m/n') ... after the
    sqrt(n'/m) scaling, E||Phi x||^2 = ||x_pad||^2 for dense-H rows; check
    the concentration is sane (within 3x) across seeds."""
    n = 2048
    x = jax.random.normal(jax.random.key(3), (n,))
    ratios = []
    for seed in range(8):
        spec = _spec(n, ratio=0.25, chunk=512, seed=seed)
        z = sk.sketch_forward(spec, x)
        ratios.append(float(jnp.sum(z * z) / jnp.sum(x * x)))
    assert 0.5 < np.mean(ratios) < 2.0, ratios


def test_forward_2d_matches_flat():
    spec = _spec(1000, chunk=256)
    x = jax.random.normal(jax.random.key(4), (1000,))
    z2 = sk.sketch_forward_2d(spec, x)
    assert z2.shape == (spec.num_chunks, spec.m_chunk)
    np.testing.assert_allclose(z2.reshape(-1), sk.sketch_forward(spec, x))


def test_autodiff_transpose_matches_adjoint():
    spec = _spec(512, chunk=128)
    x = jax.random.normal(jax.random.key(5), (512,))
    v = jax.random.normal(jax.random.key(6), (spec.m,))
    f = lambda w: jnp.vdot(sk.sketch_forward(spec, w), v)
    np.testing.assert_allclose(
        jax.grad(f)(x), sk.sketch_adjoint(spec, v), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    n=hst.integers(min_value=10, max_value=2000),
    seed=hst.integers(min_value=0, max_value=2 ** 30),
    ratio=hst.sampled_from([0.05, 0.1, 0.3]),
)
def test_property_linearity_and_adjoint(n, seed, ratio):
    spec = sk.make_sketch_spec(n, ratio, chunk=256, seed=seed)
    kx, ky, kv = jax.random.split(jax.random.key(seed), 3)
    x = jax.random.normal(kx, (n,))
    y = jax.random.normal(ky, (n,))
    a = 1.7
    # linearity
    np.testing.assert_allclose(
        sk.sketch_forward(spec, a * x + y),
        a * sk.sketch_forward(spec, x) + sk.sketch_forward(spec, y),
        rtol=2e-3, atol=2e-3,
    )
    # adjoint identity
    v = jax.random.normal(kv, (spec.m,))
    np.testing.assert_allclose(
        jnp.vdot(sk.sketch_forward(spec, x), v),
        jnp.vdot(x, sk.sketch_adjoint(spec, v)),
        rtol=2e-3, atol=2e-3,
    )


def test_dense_gaussian_reference():
    phi = sk.dense_gaussian_sketch(100, 50, seed=0)
    x = jax.random.normal(jax.random.key(7), (100,))
    # E||Phi x||^2 = ||x||^2 with entries N(0, 1/m)
    norms = []
    for s in range(10):
        p = sk.dense_gaussian_sketch(100, 50, seed=s)
        norms.append(float(jnp.sum((p @ x) ** 2)))
    assert 0.5 < np.mean(norms) / float(jnp.sum(x * x)) < 1.5
