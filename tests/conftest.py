import jax
import pytest

# Tests run on the host CPU with ONE device (the 512-device forcing is
# strictly confined to the dry-run launcher, per the brief).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
