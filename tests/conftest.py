import os

import jax
import pytest

# Tests run on the host CPU with ONE device (the 512-device forcing is
# strictly confined to the dry-run launcher, per the brief).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


# --- slow-marker audit (CI test-hygiene gate; see pytest.ini) ---------------
# With PYTEST_SLOW_BUDGET=<seconds> set (the CI fast-tier job sets 90), any
# PASSING test whose call phase exceeds the budget but does not carry
# @pytest.mark.slow is turned into a failure: the fast tier stays fast as
# the suite grows, and the fix is always to add the marker (or make the
# test faster). Unset/0 (the default) disables the audit for local runs.
_SLOW_BUDGET = float(os.environ.get("PYTEST_SLOW_BUDGET", "0") or 0.0)


@pytest.hookimpl(wrapper=True)
def pytest_runtest_makereport(item, call):
    rep = yield
    if (
        _SLOW_BUDGET > 0
        and rep.when == "call"
        and rep.passed
        and call.duration > _SLOW_BUDGET
        and "slow" not in item.keywords
    ):
        rep.outcome = "failed"
        rep.longrepr = (
            f"marker-audit: {item.nodeid} took {call.duration:.1f}s "
            f"(> PYTEST_SLOW_BUDGET={_SLOW_BUDGET:g}s) but is not marked "
            f"@pytest.mark.slow — mark it so the fast tier "
            f'(-m "not slow") stays fast, or speed it up'
        )
    return rep
