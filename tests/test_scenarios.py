"""Scenario-matrix harness: partition correctness (Dirichlet limits),
participation determinism + bit-meter agreement, and one slow end-to-end
sweep through the shared round surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as ds
from repro.exp import report, runner, scenarios
from repro.fl import comms


# --- Dirichlet partitioning --------------------------------------------------

def _pool_labels(n=4000, classes=10, seed=0):
    return np.random.RandomState(seed).randint(0, classes, size=n)


def test_dirichlet_partition_sums_to_full_dataset():
    labels = _pool_labels()
    for alpha in (0.05, 0.5, 5.0):
        parts = ds.dirichlet_partition(
            np.random.RandomState(1), labels, num_clients=12, alpha=alpha
        )
        allidx = np.concatenate(parts)
        assert len(allidx) == len(labels)
        # pairwise disjoint AND covering: the sorted union is exactly 0..N-1
        assert np.array_equal(np.sort(allidx), np.arange(len(labels)))


def test_dirichlet_alpha_inf_recovers_iid():
    """alpha -> inf: every client sees every class in ~1/K proportion."""
    labels = _pool_labels()
    k = 10
    parts = ds.dirichlet_partition(
        np.random.RandomState(2), labels, num_clients=k, alpha=1e6
    )
    for p in parts:
        hist = np.bincount(labels[p], minlength=10) / max(len(p), 1)
        # close to the pool's uniform class distribution
        assert np.all(np.abs(hist - 0.1) < 0.05), hist
    sizes = np.asarray([len(p) for p in parts])
    assert sizes.max() - sizes.min() < 0.2 * sizes.mean()


def test_dirichlet_alpha_zero_recovers_label_skew():
    """alpha -> 0: each class concentrates on ~one client, so clients see
    few distinct classes — the label-skew regime."""
    labels = _pool_labels()
    parts = ds.dirichlet_partition(
        np.random.RandomState(3), labels, num_clients=10, alpha=1e-3
    )
    distinct = [len(np.unique(labels[p])) for p in parts if len(p) > 0]
    assert np.mean(distinct) <= 2.5, distinct
    # vs the IID limit which sees all 10
    parts_iid = ds.dirichlet_partition(
        np.random.RandomState(3), labels, num_clients=10, alpha=1e6
    )
    assert np.mean([len(np.unique(labels[p])) for p in parts_iid]) > 9


def test_label_skew_partition_covers_pool():
    labels = _pool_labels()
    parts = ds.label_skew_partition(
        np.random.RandomState(4), labels, num_clients=8, classes_per_client=2
    )
    assert np.array_equal(
        np.sort(np.concatenate(parts)), np.arange(len(labels))
    )
    # each client sees its classes_per_client classes, plus at most the
    # orphan classes dealt to the least-loaded clients (8 clients x 2 draws
    # over 10 classes leaves a couple of orphans)
    distinct = [len(np.unique(labels[p])) for p in parts if len(p)]
    assert max(distinct) <= 4 and np.mean(distinct) <= 3, distinct


def test_imbalance_counts_trims_lognormally():
    labels = _pool_labels()
    parts = ds.iid_partition(np.random.RandomState(5), labels, 10)
    trimmed, counts = ds.imbalance_counts(np.random.RandomState(5), parts, sigma=1.0)
    assert counts.max() == max(len(p) for p in parts)   # largest keeps all
    assert counts.min() < counts.max() // 2             # real spread
    assert all(len(t) == c for t, c in zip(trimmed, counts))
    # sigma=0 is the identity
    same, counts0 = ds.imbalance_counts(np.random.RandomState(5), parts, sigma=0.0)
    assert all(len(a) == len(b) for a, b in zip(same, parts))


def test_materialized_train_test_disjoint():
    """No test row may be a training row: the client's partition is split
    disjointly before resampling, so accuracy measures generalization."""
    key = jax.random.key(0)
    px, py = ds.make_classification_pool(key, 800, num_classes=10)
    parts = ds.dirichlet_partition(
        np.random.RandomState(6), np.asarray(py), num_clients=6, alpha=0.5
    )
    fed = ds.materialize_from_partition(
        jax.random.key(2), px, py, parts, train_per_client=64,
        test_per_client=32, num_classes=10,
    )
    tr = np.asarray(fed.train_x).reshape(6, 64, -1)
    te = np.asarray(fed.test_x).reshape(6, 32, -1)
    for k in range(6):
        # byte-identical rows across the split would be contamination
        tr_set = {r.tobytes() for r in tr[k]}
        assert not any(r.tobytes() in tr_set for r in te[k]), f"client {k}"


def test_materialized_weights_follow_counts():
    key = jax.random.key(0)
    px, py = ds.make_classification_pool(key, 600, num_classes=4)
    parts = [np.arange(0, 300), np.arange(300, 500), np.arange(500, 600)]
    fed = ds.materialize_from_partition(
        jax.random.key(1), px, py, parts, train_per_client=32,
        test_per_client=16, num_classes=4,
    )
    w = np.asarray(fed.weights)
    assert np.allclose(w, [0.5, 1 / 3, 1 / 6], atol=1e-6)
    assert fed.train_x.shape == (3, 32, 28, 28, 1)


# --- participation models ----------------------------------------------------

PARTICIPATIONS = [
    scenarios.FullParticipation(),
    scenarios.UniformSampling(0.5),
    scenarios.StragglerDropout(0.5, 0.4),
    scenarios.AvailabilityCycle(0.5, period=4, duty=0.5),
]


@pytest.mark.parametrize("part", PARTICIPATIONS, ids=lambda p: type(p).__name__)
def test_participation_seed_deterministic(part):
    key = jax.random.key(7)
    k = 12
    for rnd in range(4):
        i1, a1 = part.draw(key, rnd, k)
        i2, a2 = part.draw(key, rnd, k)
        assert np.array_equal(np.asarray(i1), np.asarray(i2))
        assert np.array_equal(np.asarray(a1), np.asarray(a2))
        assert i1.shape == (part.capacity(k),) == a1.shape
        assert len(np.unique(np.asarray(i1))) == len(np.asarray(i1))  # no dup clients
        assert float(jnp.sum(a1)) >= 1.0   # a round always has a voter
    # a different key must be able to move the draw
    moved = any(
        not np.array_equal(
            np.asarray(part.draw(key, r, k)[0]),
            np.asarray(part.draw(jax.random.key(8), r, k)[0]),
        )
        for r in range(4)
    ) or isinstance(part, scenarios.FullParticipation)
    assert moved


def test_availability_cycle_honors_phase():
    part = scenarios.AvailabilityCycle(rate=1.0, period=4, duty=0.5)
    key = jax.random.key(0)
    k = 8
    for rnd in range(8):
        idx, active = part.draw(key, rnd, k)
        phases = np.asarray(idx) % 4
        online = ((rnd + phases) % 4) < 2
        assert np.array_equal(np.asarray(active) > 0, online)


def test_availability_cycle_keep_alive_on_dead_rounds():
    """Degenerate cycles (k < period / tiny duty) must still produce >= 1
    active client every round — a zero-voter round would clobber the
    consensus with the vote's tie value."""
    for part in (
        scenarios.AvailabilityCycle(rate=1.0, period=4, duty=0.5),
        scenarios.AvailabilityCycle(rate=0.5, period=8, duty=0.1),
    ):
        for k in (2, 3, 5):
            for rnd in range(10):
                _, active = part.draw(jax.random.key(1), rnd, k)
                assert float(jnp.sum(active)) >= 1.0, (part, k, rnd)


def test_participation_matches_round_bits_accounting():
    """The runner bills each round with s = sum(active); the engines' own
    uplink_bits metric and fl/comms must agree on every round."""
    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
    from repro.models import smallnets as sn

    k, rounds = 8, 3
    part = scenarios.StragglerDropout(0.5, 0.4)
    cap = part.capacity(k)
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=k, train_per_client=32,
        test_per_client=16,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda kk: sn.init_mlp(kk, input_dim=784, hidden=16)
    eng = PFed1BS(
        PFed1BSConfig(num_clients=k, participate=cap, local_steps=2, chunk=2048),
        loss_fn, jax.eval_shape(init_fn, jax.random.key(1)),
    )
    state = eng.init(init_fn, jax.random.key(2))
    pkey = jax.random.key(9)
    s_per_round = []
    for r in range(rounds):
        idx, active = part.draw(pkey, r, k)
        s_r = int(round(float(jnp.sum(active))))
        batches = ds.sample_round_batches(jax.random.key(10 + r), data, 2, 16)
        state, m = eng.round(
            state, batches, data.weights, jax.random.key(20 + r), (idx, active)
        )
        # engine's own uplink meter == the realized participant count * m
        assert float(m["uplink_bits"]) == s_r * eng.m
        assert float(m["downlink_bits"]) == eng.m
        s_per_round.append(s_r)
    total = comms.accumulate_round_bits(
        "pfed1bs", n=eng.n, m=eng.m, s_per_round=s_per_round
    )
    assert total["uplink_bits"] == sum(s_per_round) * eng.m
    assert total["downlink_bits"] == rounds * eng.m
    per_round = [
        comms.round_bits("pfed1bs", n=eng.n, m=eng.m, s=s) for s in s_per_round
    ]
    assert total["total_bits"] == sum(b["total_bits"] for b in per_round)


# --- scenario build + end-to-end sweep ---------------------------------------

def test_scenario_build_shapes_and_determinism():
    sc = scenarios.paper_matrix()["dir0.3-imb"]
    d1 = sc.build(jax.random.key(3), num_clients=6, train_per_client=32,
                  test_per_client=16)
    d2 = sc.build(jax.random.key(3), num_clients=6, train_per_client=32,
                  test_per_client=16)
    assert d1.train_x.shape == (6, 32, 28, 28, 1)
    assert np.array_equal(np.asarray(d1.train_y), np.asarray(d2.train_y))
    assert np.array_equal(np.asarray(d1.counts), np.asarray(d2.counts))
    # imbalance sigma=1.0 must produce a real count spread
    c = np.asarray(d1.counts)
    assert c.max() > 2 * c.min()


@pytest.mark.slow
def test_end_to_end_sweep_losses_decrease():
    """2 algorithms x 2 scenarios through the shared round surface: the
    training signal must actually descend in every cell, and the artifact
    must pass the report layer's accounting gate."""
    cfg = runner.ExpConfig(
        num_clients=6, rounds=6, local_steps=3, batch=16, hidden=32,
        train_per_client=64, test_per_client=32, chunk=2048,
    )
    mat = scenarios.paper_matrix()
    use = {k: mat[k] for k in ("dir0.1", "straggler")}
    res = runner.sweep(["fedavg", "pfed1bs"], use, cfg)
    assert len(res["cells"]) == 4
    for cell in res["cells"]:
        losses = cell["loss_curve"]
        # decreasing trend: last third clearly below first third, and no
        # catastrophic blow-up anywhere
        assert np.mean(losses[-2:]) < np.mean(losses[:2]) * 0.85, (
            cell["algo"], cell["scenario"], losses,
        )
        assert np.all(np.isfinite(losses))
        assert cell["acc"] > 0.3
    report.validate_matrix(res, min_algos=2, min_scenarios=2)
