"""Test hygiene for CI: the slow-marker audit (tests/conftest.py) must
actually catch an over-budget test that forgot @pytest.mark.slow, and must
leave marked / under-budget tests alone.

Runs pytest-in-pytest on a tiny generated suite with a sub-second budget,
so the meta-test itself stays cheap but exercises the real hook path the
CI fast-tier job runs with PYTEST_SLOW_BUDGET=90.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SUITE = textwrap.dedent(
    """
    import time
    import pytest

    def test_fast_unmarked():
        pass

    def test_slow_unmarked():        # the offender the audit must flag
        time.sleep(0.6)

    @pytest.mark.slow
    def test_slow_marked():          # carries the marker: audit-exempt
        time.sleep(0.6)
    """
)


def _run_pytest(tmp_path, budget):
    suite = tmp_path / "test_generated_audit_suite.py"
    suite.write_text(SUITE)
    # the generated suite must run under the REPO's conftest/pytest.ini so
    # the real audit hook (and the real `slow` marker) are in force
    (tmp_path / "conftest.py").write_text(
        open(os.path.join(REPO, "tests", "conftest.py")).read()
    )
    (tmp_path / "pytest.ini").write_text(
        open(os.path.join(REPO, "pytest.ini")).read()
    )
    env = dict(os.environ, PYTEST_SLOW_BUDGET=str(budget))
    env.pop("PYTEST_ADDOPTS", None)
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-q", str(suite)],
        capture_output=True, text=True, cwd=tmp_path, env=env, timeout=120,
    )


@pytest.mark.slow
def test_audit_flags_unmarked_over_budget_test(tmp_path):
    res = _run_pytest(tmp_path, budget=0.3)
    out = res.stdout + res.stderr
    assert res.returncode != 0, out
    assert "marker-audit" in out, out
    assert "test_slow_unmarked" in out, out
    # the marked slow test and the fast test must NOT be flagged
    assert "2 passed" in out, out
    assert "1 failed" in out, out


@pytest.mark.slow
def test_audit_disabled_without_budget(tmp_path):
    res = _run_pytest(tmp_path, budget=0)
    out = res.stdout + res.stderr
    assert res.returncode == 0, out
    assert "3 passed" in out, out
