"""Mergeable quantile sketch tests (obs/hist.py, DESIGN.md §14).

Two layers, mirroring tests/test_hier.py's counter-merge suite:

  * Pinned parity: at small N the sketch quantile is within the
    configured relative accuracy of the EXACT sample statistic
    np.percentile(values, 100q, method="lower") — the convention the
    sketch's rank rule targets — and min/max/mean/count are exact.
  * Property sweep (hypothesis, when installed): the merge is
    associative, commutative, and invariant to HOW a stream is split
    into shards (merge of per-shard sketches == one sketch of the whole
    stream, bucket-for-bucket via __eq__) — the algebra that lets
    latency histograms ride the aggregation tree next to the vote
    counters. Plus the relative-error bound itself as a property.

The bounded variant (max_buckets) is pinned separately: resident bytes
obey the hard cap regardless of sample count/range, and collapsing only
the LOW buckets leaves upper quantiles accurate.
"""
import math

import numpy as np
import pytest

from repro.obs import hist
from repro.obs.hist import QuantileSketch, merged
from tests._hypothesis_shim import given, settings, hst


def _exact(values, q):
    return float(np.percentile(np.asarray(values, np.float64), 100.0 * q,
                               method="lower"))


def _rel_err(got, want):
    return abs(got - want) / abs(want) if want != 0 else abs(got)


# ---------------------------------------------------------------------------
# pinned small-N parity with np.percentile
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("rel_acc", [0.01, 0.05])
def test_quantiles_match_percentile_within_rel_acc(seed, rel_acc):
    rng = np.random.default_rng(seed)
    values = rng.lognormal(mean=2.0, sigma=1.5, size=200)
    sk = QuantileSketch(rel_acc=rel_acc)
    for v in values:
        sk.add(v)
    for q in (0.0, 0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        assert _rel_err(sk.quantile(q), _exact(values, q)) <= rel_acc, q
    assert sk.count == 200
    assert sk.min == values.min() and sk.max == values.max()
    assert np.isclose(sk.mean, values.mean())


def test_exact_extremes_and_empty():
    sk = QuantileSketch(0.01)
    assert sk.quantile(0.5) == 0.0 and sk.count == 0       # empty -> 0
    sk.add(3.0)
    sk.add(7.0)
    assert sk.quantile(0.0) == 3.0 and sk.quantile(1.0) == 7.0


def test_zero_and_tiny_values_land_in_zero_bucket():
    sk = QuantileSketch(0.01)
    for v in (0.0, hist.ZERO_EPS / 2, 5.0):
        sk.add(v)
    assert sk.zero_count == 2
    assert sk.quantile(0.0) == 0.0
    assert sk.quantile(1.0) == 5.0


def test_rejects_invalid_input():
    sk = QuantileSketch(0.01)
    with pytest.raises(ValueError):
        sk.add(-1.0)
    with pytest.raises(ValueError):
        sk.add(float("nan"))
    with pytest.raises(ValueError):
        sk.add(1.0, count=0)
    with pytest.raises(ValueError):
        QuantileSketch(rel_acc=1.5)
    with pytest.raises(ValueError):
        QuantileSketch(0.01, max_buckets=1)
    with pytest.raises(ValueError):
        sk.add_many([1.0, -2.0])


def test_merge_rejects_mismatched_rel_acc():
    with pytest.raises(ValueError, match="rel_acc"):
        QuantileSketch(0.01).merge(QuantileSketch(0.05))


def test_add_many_equals_add_loop():
    rng = np.random.default_rng(7)
    values = np.concatenate([rng.exponential(10.0, 300), np.zeros(5)])
    a, b = QuantileSketch(0.02), QuantileSketch(0.02)
    a.add_many(values)
    for v in values:
        b.add(v)
    assert a == b and a.count == b.count and np.isclose(a.sum, b.sum)


def test_serialization_roundtrip_exact():
    rng = np.random.default_rng(3)
    sk = QuantileSketch(0.01, max_buckets=64)
    sk.add_many(rng.lognormal(0.0, 2.0, 500))
    back = QuantileSketch.from_dict(sk.to_dict())
    assert back == sk
    assert back.max_buckets == sk.max_buckets
    assert back.quantile(0.99) == sk.quantile(0.99)
    assert back.min == sk.min and back.max == sk.max
    # and through actual JSON text
    import json

    again = QuantileSketch.from_dict(json.loads(json.dumps(sk.to_dict())))
    assert again == sk


# ---------------------------------------------------------------------------
# bounded variant: hard memory cap, upper quantiles survive collapsing
# ---------------------------------------------------------------------------

def test_bounded_sketch_resident_bytes_capped():
    cap = 32
    sk = QuantileSketch(0.01, max_buckets=cap)
    rng = np.random.default_rng(0)
    for n in (10, 1000, 100_000):
        sk.add_many(rng.lognormal(0.0, 3.0, n))   # huge dynamic range
        assert len(sk.buckets) <= cap
        assert sk.resident_bytes() <= (
            hist.FIXED_BYTES + hist.BUCKET_BYTES * (cap + 1)
        )
    assert sk.count == 101_010


def test_bounded_collapse_preserves_upper_quantiles():
    rng = np.random.default_rng(1)
    values = rng.lognormal(mean=0.0, sigma=2.0, size=2000)
    unbounded = QuantileSketch(0.01)
    bounded = QuantileSketch(0.01, max_buckets=32)
    unbounded.add_many(values)
    bounded.add_many(values)
    # collapsing folds the LOWEST keys, so the quantiles living in the top
    # 31 retained buckets — here the p99 (top 1% = 20 samples spread over
    # at most 20 keys) and the max — keep the full accuracy guarantee; the
    # left tail is what degrades, never the p99 the SLOs gate on
    for q in (0.99, 1.0):
        assert _rel_err(bounded.quantile(q), _exact(values, q)) <= 0.01, q
    assert bounded.quantile(0.99) == unbounded.quantile(0.99)
    # and the left tail really did collapse upward (lossy by design)
    assert bounded.quantile(0.1) > unbounded.quantile(0.1)


# ---------------------------------------------------------------------------
# property sweep: merge algebra (hypothesis)
# ---------------------------------------------------------------------------

def _sketch_of(values):
    sk = QuantileSketch(0.01)
    sk.add_many(np.asarray(values, np.float64))
    return sk


_values = hst.lists(
    hst.floats(min_value=0.0, max_value=1e9, allow_nan=False,
               allow_infinity=False),
    min_size=0, max_size=60,
)


@settings(max_examples=40, deadline=None)
@given(_values, _values, _values)
def test_merge_associative_commutative(xs, ys, zs):
    a, b, c = _sketch_of(xs), _sketch_of(ys), _sketch_of(zs)
    ab_c = merged(merged(a, b), c)
    a_bc = merged(a, merged(b, c))
    assert ab_c == a_bc                                    # associative
    assert merged(a, b) == merged(b, a)                    # commutative
    assert ab_c.count == len(xs) + len(ys) + len(zs)


@settings(max_examples=40, deadline=None)
@given(hst.integers(0, 2 ** 31), hst.integers(1, 200),
       hst.lists(hst.integers(0, 200), max_size=6))
def test_split_invariance(seed, n, cuts):
    """Sketching shards then merging == sketching the whole stream,
    bucket-for-bucket — no matter where the stream is cut."""
    rng = np.random.default_rng(seed)
    values = rng.lognormal(0.0, 1.5, n)
    bounds = sorted({min(c, n) for c in cuts} | {0, n})
    shards = [values[lo:hi] for lo, hi in zip(bounds, bounds[1:])]
    whole = _sketch_of(values)
    parts = merged(*[_sketch_of(s) for s in shards]) if shards else whole
    assert parts == whole
    assert parts.quantile(0.99) == whole.quantile(0.99)


@settings(max_examples=40, deadline=None)
@given(hst.lists(hst.floats(min_value=1e-6, max_value=1e6, allow_nan=False,
                            allow_infinity=False),
                 min_size=1, max_size=80),
       hst.floats(min_value=0.0, max_value=1.0))
def test_quantile_relative_error_bound(values, q):
    sk = _sketch_of(values)
    assert _rel_err(sk.quantile(q), _exact(values, q)) <= sk.rel_acc + 1e-12


# ---------------------------------------------------------------------------
# representative geometry (the DDSketch accuracy argument in one test)
# ---------------------------------------------------------------------------

def test_bucket_representative_within_rel_acc_of_any_member():
    sk = QuantileSketch(0.05)
    for x in (0.001, 0.7, 1.0, 33.0, 1e6):
        k = sk._key(x)
        rep = sk._value(k)
        assert abs(rep - x) <= sk.rel_acc * x * (1 + 1e-9), x
        # and the bucket really contains x: gamma^(k-1) < x <= gamma^k
        assert sk._gamma ** (k - 1) < x <= sk._gamma ** k * (1 + 1e-12)


def test_gamma_matches_rel_acc():
    sk = QuantileSketch(0.03)
    assert math.isclose(sk._gamma, 1.03 / 0.97)
