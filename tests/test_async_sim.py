"""Async federation tier (repro/sim, DESIGN.md §9).

Contracts pinned here:
  * KEYSTONE PARITY: with zero latency, buffer size B = S and staleness
    exponent p = 0, one full drain of the event queue is BIT-exact with
    the synchronous fused round — consensus, client params AND EF
    residuals, with EF on and off, flat and leaf layouts (the same parity
    discipline the sharded executors pinned in tests/test_fedexec.py).
  * The virtual clock is deterministic: equal-time events pop in push
    order, latency draws are pure functions of (seed, client, version).
  * Buffered operation under real latency: every flush holds exactly B
    arrivals, stragglers land with positive consensus-version lag, and
    the time-stamped billing re-derives exactly from fl/comms.
  * The ragged final drain and the packed ragged wire vote
    (kernels/ops.vote_packed_ragged).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus, rounds
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.kernels import ops as kops
from repro.models import smallnets as sn
from repro.sim import clock as simclock
from repro.sim import metrics as simmetrics
from repro.sim.client import Roster
from repro.sim.server import AsyncConfig, AsyncSimulator


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_push_order():
    q = simclock.EventQueue()
    q.push(2.0, "arrival", 0)
    q.push(1.0, "arrival", 1)
    q.push(1.0, "arrival", 2)   # same t as client 1: must pop AFTER it
    q.push(0.5, "arrival", 3)
    got = [(q.pop().client) for _ in range(len(q))]
    assert got == [3, 1, 2, 0]


def test_event_queue_zero_latency_preserves_push_order():
    q = simclock.EventQueue()
    for c in (5, 0, 3, 1):
        q.push(0.0, "arrival", c)
    assert [q.pop().client for _ in range(4)] == [5, 0, 3, 1]


@pytest.mark.parametrize("model", [
    simclock.ConstantLatency(0.25),
    simclock.ComputeNetworkLatency(),
    simclock.StragglerTailLatency(),
], ids=lambda m: type(m).__name__)
def test_latency_models_deterministic_and_nonnegative(model):
    for client in (0, 3):
        for version in (0, 7):
            d1 = model.duration(seed=1, client=client, version=version)
            d2 = model.duration(seed=1, client=client, version=version)
            assert d1 == d2
            assert d1 >= 0.0 and np.isfinite(d1)
    # a different seed moves the stochastic models
    if not isinstance(model, simclock.ConstantLatency):
        assert (
            model.duration(seed=1, client=0, version=0)
            != model.duration(seed=2, client=0, version=0)
        )


def test_straggler_tail_heavier_than_base():
    base = simclock.ComputeNetworkLatency()
    tail = simclock.StragglerTailLatency(base=base, tail_prob=1.0,
                                         tail_mult=10.0)
    ds_ = [tail.duration(0, c, 0) - base.duration(0, c, 0) for c in range(8)]
    assert min(ds_) > 0           # tail_prob=1: every job pays the stall


def test_client_speed_is_persistent():
    m = simclock.ComputeNetworkLatency(client_speed_sigma=1.0)
    assert m.client_speed(0, 3) == m.client_speed(0, 3)
    speeds = {m.client_speed(0, c) for c in range(8)}
    assert len(speeds) == 8       # heterogeneous across clients


# ---------------------------------------------------------------------------
# staleness weights
# ---------------------------------------------------------------------------

def test_staleness_weights_p0_is_exact_ones():
    w = consensus.staleness_weights(jnp.asarray([0.0, 3.0, 17.0]), 0.0)
    np.testing.assert_array_equal(np.asarray(w), np.ones(3, np.float32))


def test_staleness_weights_monotone():
    tau = jnp.arange(6, dtype=jnp.float32)
    w = np.asarray(consensus.staleness_weights(tau, 1.0))
    assert np.all(np.diff(w) < 0)
    np.testing.assert_allclose(w, 1.0 / (1.0 + np.arange(6)), rtol=1e-6)
    # stronger exponent discounts harder
    w2 = np.asarray(consensus.staleness_weights(tau, 2.0))
    assert np.all(w2[1:] < w[1:])


def test_staleness_weighted_vote_downweights_stale_rows():
    zs = jnp.asarray([[1.0, 1.0], [-1.0, -1.0], [-1.0, -1.0]])
    p = jnp.ones((3,))
    # fresh +1 row vs two very stale -1 rows: discount flips the outcome
    tau = jnp.asarray([0.0, 10.0, 10.0])
    v = consensus.staleness_weighted_vote(zs, p, tau, 2.0)
    np.testing.assert_array_equal(np.asarray(v), [1.0, 1.0])
    v0 = consensus.staleness_weighted_vote(zs, p, tau, 0.0)
    np.testing.assert_array_equal(np.asarray(v0), [-1.0, -1.0])


# ---------------------------------------------------------------------------
# roster
# ---------------------------------------------------------------------------

def test_roster_version_gating():
    r = Roster(3)
    assert r.idle(0)
    r.dispatch(0, version=4)
    assert not r.idle(0)
    with pytest.raises(AssertionError):
        r.dispatch(0, version=5)          # one job in flight max
    assert r.arrive(0, t=1.5) == 4        # returns the download version
    assert r.idle(0) and r.states[0].jobs_done == 1
    with pytest.raises(AssertionError):
        r.arrive(1, t=0.0)                # never dispatched


# ---------------------------------------------------------------------------
# the keystone parity contract
# ---------------------------------------------------------------------------

K, S, R = 6, 6, 2


@pytest.fixture(scope="module")
def task():
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=K, train_per_client=48,
        test_per_client=24,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=16)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    return data, loss_fn, init_fn, template


def _fns(data):
    participants_fn = lambda v: rounds.draw_participants(
        jax.random.fold_in(jax.random.key(7), v), K, S, None
    )
    batch_fn = lambda v: ds.sample_round_batches(
        jax.random.fold_in(jax.random.key(9), v), data, R, 16
    )
    return participants_fn, batch_fn


def _parity_check(task, error_feedback, layout, rounds_=3):
    data, loss_fn, init_fn, template = task
    cfg = PFed1BSConfig(
        num_clients=K, participate=S, local_steps=R, m_ratio=0.05,
        chunk=2048, error_feedback=error_feedback, layout=layout,
    )
    eng = PFed1BS(cfg, loss_fn, template)
    participants_fn, batch_fn = _fns(data)

    st_sync = eng.init(init_fn, jax.random.key(2))
    for r in range(rounds_):
        st_sync, _ = eng.round(
            st_sync, batch_fn(r), data.weights, jax.random.key(0),
            participants_fn(r),
        )

    sim = AsyncSimulator(
        eng,
        AsyncConfig(buffer_size=S, staleness_exponent=0.0,
                    max_versions=rounds_,
                    latency=simclock.ConstantLatency(0.0)),
        data.weights, participants_fn, batch_fn,
    )
    st_async, rep = sim.run(eng.init(init_fn, jax.random.key(2)))

    assert rep.versions == rounds_
    assert rep.arrivals_per_flush == [S] * rounds_
    assert rep.lag_histogram() == {0: S * rounds_}   # nothing ever stale
    np.testing.assert_array_equal(np.asarray(st_sync.v), np.asarray(st_async.v))
    for a, b in zip(jax.tree.leaves(st_sync.clients),
                    jax.tree.leaves(st_async.clients)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if error_feedback:
        np.testing.assert_array_equal(
            np.asarray(st_sync.ef), np.asarray(st_async.ef)
        )
    return rep


@pytest.mark.parametrize("error_feedback", [False, True])
def test_parity_zero_latency_drain_bit_exact_flat(task, error_feedback):
    rep = _parity_check(task, error_feedback, "flat")
    # the drain was also billed exactly like the sync rounds
    assert rep.meter.uplink_bits == 3 * S * rep.m
    assert rep.meter.downlink_bits == 3 * rep.m


@pytest.mark.slow
@pytest.mark.parametrize("error_feedback", [False, True])
def test_parity_zero_latency_drain_bit_exact_leaf(task, error_feedback):
    _parity_check(task, error_feedback, "leaf")


# ---------------------------------------------------------------------------
# buffered operation under latency
# ---------------------------------------------------------------------------

def _engine(task, **over):
    data, loss_fn, init_fn, template = task
    cfg = PFed1BSConfig(**{
        "num_clients": K, "participate": S, "local_steps": R,
        "m_ratio": 0.05, "chunk": 2048, **over,
    })
    return PFed1BS(cfg, loss_fn, template), data, init_fn


@pytest.mark.parametrize("vote", ["exact", "packed"])
def test_buffered_flushes_and_staleness(task, vote):
    eng, data, init_fn = _engine(task, error_feedback=True)
    participants_fn, batch_fn = _fns(data)
    cfg = AsyncConfig(
        buffer_size=3, staleness_exponent=0.5, max_versions=6,
        latency=simclock.StragglerTailLatency(tail_prob=0.4), vote=vote,
    )
    sim = AsyncSimulator(eng, cfg, data.weights, participants_fn, batch_fn)
    st, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
    assert rep.versions == 6
    assert rep.arrivals_per_flush == [3] * 6     # every flush exactly B
    lags = rep.lag_histogram()
    assert sum(lags.values()) == 18
    assert any(tau > 0 for tau in lags)          # stragglers landed stale
    # consensus values stay in the vote codomain
    vals = set(np.unique(np.asarray(st.v)))
    assert vals <= {-1.0, 0.0, 1.0}
    if vote == "packed":
        assert vals <= {-1.0, 1.0}               # wire vote never emits 0
    # billing re-derives from fl/comms (check_billing ran inside run;
    # assert the totals once more from the artifact view)
    d = rep.to_dict()
    assert d["uplink_bits"] == 18 * eng.m
    assert d["downlink_bits"] == 6 * eng.m


def test_run_is_deterministic(task):
    eng, data, init_fn = _engine(task)
    participants_fn, batch_fn = _fns(data)
    cfg = AsyncConfig(buffer_size=2, staleness_exponent=1.0, max_versions=5,
                      latency=simclock.ComputeNetworkLatency())
    outs = []
    for _ in range(2):
        sim = AsyncSimulator(eng, cfg, data.weights, participants_fn, batch_fn)
        st, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
        outs.append((st, rep))
    (s1, r1), (s2, r2) = outs
    assert [f.t for f in r1.flushes] == [f.t for f in r2.flushes]
    assert r1.arrivals_per_flush == r2.arrivals_per_flush
    np.testing.assert_array_equal(np.asarray(s1.v), np.asarray(s2.v))


def test_ragged_final_drain(task):
    """B larger than the dispatched cohort: the queue empties part-full and
    the drain flush votes the ragged remainder."""
    eng, data, init_fn = _engine(task)
    participants_fn, batch_fn = _fns(data)
    cfg = AsyncConfig(buffer_size=S + 2, max_versions=2,
                      latency=simclock.ConstantLatency(1.0))
    sim = AsyncSimulator(eng, cfg, data.weights, participants_fn, batch_fn)
    st, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
    assert rep.versions == 2
    assert rep.arrivals_per_flush == [S, S]      # ragged: S < B per flush
    assert rep.residual_arrivals == 0


def test_in_flight_clients_are_not_redispatched(task):
    """With a spread of latencies and B < S, slow clients are still in
    flight when new cohorts are drawn; the roster must never double-dispatch
    and their late arrivals must carry tau > 0."""
    eng, data, init_fn = _engine(task)
    participants_fn, batch_fn = _fns(data)
    cfg = AsyncConfig(buffer_size=2, staleness_exponent=1.0, max_versions=8,
                      latency=simclock.StragglerTailLatency(tail_prob=0.5))
    sim = AsyncSimulator(eng, cfg, data.weights, participants_fn, batch_fn)
    st, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
    assert rep.versions == 8
    assert any(tau > 0 for tau in rep.lag_histogram())
    # flush times strictly increase with positive latency
    ts = [f.t for f in rep.flushes]
    assert all(b >= a for a, b in zip(ts, ts[1:])) and ts[-1] > ts[0]


# ---------------------------------------------------------------------------
# ragged packed vote
# ---------------------------------------------------------------------------

def test_vote_packed_ragged_ignores_invalid_rows():
    rng = np.random.default_rng(0)
    words = jnp.asarray(
        rng.integers(0, 2**32, size=(5, 7), dtype=np.uint32)
    )
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=(5,)), jnp.float32)
    ref = kops.vote_packed(words[:3], w[:3])
    # pad to capacity 5 with GARBAGE rows masked out
    valid = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0])
    got = kops.vote_packed_ragged(words, w, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# metrics layer
# ---------------------------------------------------------------------------

def test_meter_time_stamped_billing():
    m = simmetrics.AsyncMeter(m=100)
    m.bill_uplink(0.2)
    m.bill_uplink(1.4)
    m.bill_downlink(1.4)
    assert m.uplink_bits == 200 and m.downlink_bits == 100
    assert m.bits_by_second(1.0) == {0: 100, 1: 200}
    assert m.cumulative_bits_at(1.0) == 100
    assert m.cumulative_bits_at(2.0) == 300


def test_time_to_target():
    curve = [(0.0, 0.1), (1.0, 0.5), (2.0, 0.9)]
    assert simmetrics.time_to_target(curve, 0.5) == 1.0
    assert simmetrics.time_to_target(curve, 0.95) is None


def test_report_billing_check_catches_mismatch():
    rep = simmetrics.SimReport(m=10, meter=simmetrics.AsyncMeter(m=10))
    rep.flushes.append(simmetrics.FlushRecord(
        version=1, t=0.0, arrivals=2, taus=[0, 0], task_loss=0.0
    ))
    with pytest.raises(ValueError, match="billing mismatch"):
        rep.check_billing()      # meter never billed anything
    rep.meter.bill_uplink(0.0)
    rep.meter.bill_uplink(0.0)
    rep.meter.bill_downlink(0.0)
    rep.check_billing()          # now consistent


def test_validate_async_artifact_gates():
    good = {
        "m": 10,
        "sync_parity": {"bit_exact": True},
        "async": {"arrivals_per_flush": [2, 2], "residual_arrivals": 0,
                  "uplink_bits": 40, "downlink_bits": 20,
                  "time_to_target_s": 1.0},
        "sync": {"s_per_round": [2, 2], "uplink_bits": 40,
                 "downlink_bits": 20, "time_to_target_s": 3.0},
    }
    simmetrics.validate_async_artifact(good)
    bad = {**good, "sync_parity": {"bit_exact": False}}
    with pytest.raises(ValueError, match="bit_exact"):
        simmetrics.validate_async_artifact(bad)
    bad = {**good, "async": {**good["async"], "uplink_bits": 41}}
    with pytest.raises(ValueError, match="re-derive"):
        simmetrics.validate_async_artifact(bad)
    bad = {**good, "async": {**good["async"], "time_to_target_s": 5.0}}
    with pytest.raises(ValueError, match="beat"):
        simmetrics.validate_async_artifact(bad)
    # equal-billed-bits premise: sync billing must match async's uploads
    bad = {**good,
           "sync": {**good["sync"], "s_per_round": [3, 3],
                    "uplink_bits": 60}}
    with pytest.raises(ValueError, match="equal billed bits"):
        simmetrics.validate_async_artifact(bad)


# ---------------------------------------------------------------------------
# scenario composition (the fourth axis)
# ---------------------------------------------------------------------------

def test_async_scenarios_compose_with_participation(task):
    from repro.exp import scenarios

    mat = scenarios.async_matrix()
    assert set(mat) >= {"uniform-const", "hetero-lognormal", "straggler-tail"}
    sc = mat["straggler-tail"]
    assert isinstance(sc.latency, simclock.StragglerTailLatency)
    hash(sc)                      # still a frozen, hashable Scenario

    # drive the simulator with the scenario's OWN participation draw
    eng, data, init_fn = _engine(task, participate=sc.capacity(K))
    participants_fn = lambda v: sc.draw_participants(jax.random.key(3), v, K)
    _, batch_fn = _fns(data)
    cfg = AsyncConfig(buffer_size=2, max_versions=4, latency=sc.latency)
    sim = AsyncSimulator(eng, cfg, data.weights, participants_fn, batch_fn)
    st, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
    assert rep.versions == 4
    assert all(a == 2 for a in rep.arrivals_per_flush)
