"""Sign regularizer (Eqs. 2-7) and server consensus (Lemma 1) properties."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, hst

from repro.core import consensus as cons
from repro.core import regularizer as reg
from repro.core import sketch as sk


def test_logcosh_stable_large_inputs():
    y = jnp.array([-1e6, -50.0, 0.0, 50.0, 1e6])
    out = reg.logcosh(y)
    assert np.isfinite(np.asarray(out)).all()
    # log cosh(y) -> |y| - log 2 for large |y|
    np.testing.assert_allclose(out[0], 1e6 - np.log(2), rtol=1e-6)


def test_h_gamma_converges_to_l1():
    z = jax.random.normal(jax.random.key(0), (64,))
    for gamma, tol in [(10.0, 0.5), (1e3, 5e-3), (1e5, 1e-4)]:
        err = abs(float(reg.h_gamma(z, gamma)) - float(jnp.sum(jnp.abs(z))))
        assert err < tol * 64, (gamma, err)


def test_eq3_equivalence_one_sided_l1():
    """For v in {+-1}^m: ||[v . z]_-||_1 = (||z||_1 - <v, z>)/2 (Eq. 3)."""
    key = jax.random.key(1)
    z = jax.random.normal(key, (128,))
    v = jnp.sign(jax.random.normal(jax.random.key(2), (128,)))
    lhs = reg.one_sided_l1(v, z)
    rhs = 0.5 * (jnp.sum(jnp.abs(z)) - jnp.vdot(v, z))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


def test_reg_grad_matches_autodiff():
    spec = sk.make_sketch_spec(300, 0.2, chunk=128)
    x = jax.random.normal(jax.random.key(3), (300,))
    v = jnp.sign(jax.random.normal(jax.random.key(4), (spec.m,)))
    gamma = 500.0
    f = lambda w: reg.smoothed_reg(v, sk.sketch_forward(spec, w), gamma)
    _, man = reg.reg_value_and_grad_w(spec, x, v, gamma)
    np.testing.assert_allclose(jax.grad(f)(x), man, rtol=1e-3, atol=1e-5)


def test_tanh_gradient_approaches_sign_penalty():
    """As gamma -> inf the z-gradient -> sign(z) - v (Remark 3)."""
    z = jax.random.normal(jax.random.key(5), (64,))
    v = jnp.sign(jax.random.normal(jax.random.key(6), (64,)))
    g = reg.reg_grad_z(v, z, 1e6)
    np.testing.assert_allclose(g, jnp.sign(z) - v, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    k=hst.integers(min_value=1, max_value=6),
    m=hst.integers(min_value=1, max_value=8),
    seed=hst.integers(min_value=0, max_value=2 ** 30),
)
def test_lemma1_majority_vote_is_optimal(k, m, seed):
    """Exhaustive check that sign(sum p_k z_k) minimizes the server
    objective over {+-1}^m (Lemma 1)."""
    rng = np.random.RandomState(seed)
    zs = np.sign(rng.randn(k, m)).astype(np.float32)
    zs[zs == 0] = 1.0
    p = rng.rand(k).astype(np.float32) + 0.1
    p /= p.sum()
    v_mv = np.asarray(cons.majority_vote(jnp.asarray(zs), jnp.asarray(p)))
    v_mv = np.where(v_mv == 0, 1.0, v_mv).astype(np.float32)
    obj_mv = float(cons.server_objective(jnp.asarray(v_mv), jnp.asarray(zs), jnp.asarray(p)))
    best = min(
        float(cons.server_objective(jnp.asarray(np.asarray(v, np.float32)), jnp.asarray(zs), jnp.asarray(p)))
        for v in itertools.product((-1.0, 1.0), repeat=m)
    )
    assert obj_mv <= best + 1e-5


def test_client_sampling_variance_lemma6():
    """Empirical check of the without-replacement variance bound."""
    rng = np.random.RandomState(0)
    k, s, m = 12, 5, 32
    zs = np.sign(rng.randn(k, m)).astype(np.float64)
    zbar = zs.mean(0)
    bound = (k - s) / (s * k * (k - 1)) * np.sum((zs - zbar) ** 2)
    trials = []
    for _ in range(4000):
        idx = rng.choice(k, s, replace=False)
        trials.append(np.sum((zs[idx].mean(0) - zbar) ** 2))
    assert np.mean(trials) <= bound * 1.02, (np.mean(trials), bound)


def test_tie_break_conventions():
    """S1: the float and packed vote paths DIVERGE on exact ties, by design
    (consensus.py module docstring). Float paths: tie -> 0 (jnp.sign
    semantics, paper's {-1,0,+1} consensus). Packed paths: tie -> +1 (a
    packed word has no zero bit). Robust votes inherit their base vote's
    convention. An adversary can FORCE a tie — one sign-flipped row exactly
    cancels its honest twin under uniform weights — so the divergence is
    pinned here rather than left as folklore."""
    from repro.kernels import ops as kops

    # two voters, equal weight, opposite signs on every coordinate -> tie
    # (m = 32: the packed paths require whole uint32 words)
    row = jnp.tile(jnp.asarray([1.0, -1.0]), 16)
    zs = jnp.stack([row, -row])
    p = jnp.asarray([0.5, 0.5])

    # float paths: tie -> 0
    np.testing.assert_array_equal(np.asarray(cons.majority_vote(zs, p)), 0.0)
    np.testing.assert_array_equal(
        np.asarray(cons.staleness_weighted_vote(zs, p, jnp.zeros(2), 0.5)),
        0.0,
    )
    v_rep, _ = cons.reputation_vote(zs, p, jnp.ones(2), beta=0.5)
    np.testing.assert_array_equal(np.asarray(v_rep), 0.0)
    # trimmed_vote with trim=0 keeps both voters -> still a tie -> 0
    v_tr, kept = cons.trimmed_vote(zs, p, trim=0)
    np.testing.assert_array_equal(np.asarray(v_tr), 0.0)
    np.testing.assert_array_equal(np.asarray(kept), np.asarray(p))

    # packed paths: the same tie -> +1 on every bit
    words = kops.pack_signs(zs)
    ones = np.asarray(kops.unpack_signs(kops.vote_packed(words, p)))
    np.testing.assert_array_equal(ones, 1.0)
    pop = np.asarray(kops.unpack_signs(kops.vote_popcount(words)))
    np.testing.assert_array_equal(pop, 1.0)
    tr = np.asarray(
        kops.unpack_signs(cons.trimmed_vote_packed(words, p, trim=0))
    )
    np.testing.assert_array_equal(tr, 1.0)
