"""fl/comms.py cost model: every number in the README Table-2 column.

The expected values here are the SAME literals shown in README.md's
"Communication cost model" table (n = 1e6 params, m = 1e5 sketch rows,
S = 20 participating clients, T = 4 tensors). If you change the cost
model, change the README table and these literals together.
"""
import pytest

from repro.fl import comms

N, M, S, T = 1_000_000, 100_000, 20, 4

# algo -> (uplink_bits, downlink_bits) at (N, M, S, T)
EXPECTED = {
    "fedavg":   (S * 32 * N,            S * 32 * N),   # 640e6 / 640e6
    "obda":     (S * N,                 S * N),        # 1-bit both ways
    "obcsaa":   (S * (M + 32),          S * 32 * N),   # m-bit CS + 1 scalar
    "zsignfed": (S * (N + 32),          S * 32 * N),   # n bits + 1 scalar
    "eden":     (S * (N + 32),          S * 32 * N),   # n bits + 1 scalar
    "fedbat":   (S * (N + 32 * T),      S * 32 * N),   # n bits + T scalars
    "pfed1bs":  (S * M,                 M),            # m bits up, ONE m-bit
    #                                                    broadcast down
}


@pytest.mark.parametrize("algo", sorted(EXPECTED))
def test_round_bits_matches_table2(algo):
    up, down = EXPECTED[algo]
    got = comms.round_bits(algo, n=N, m=M, s=S, num_tensors=T)
    assert got["uplink_bits"] == up, algo
    assert got["downlink_bits"] == down, algo
    assert got["total_bits"] == up + down
    assert got["total_mb"] == (up + down) / 8e6


def test_concrete_readme_numbers():
    """The literal MB-per-round numbers printed in README.md."""
    mb = {a: comms.round_bits(a, n=N, m=M, s=S, num_tensors=T)["total_mb"]
          for a in EXPECTED}
    assert mb["fedavg"] == 160.0
    assert mb["obda"] == 5.0
    assert mb["obcsaa"] == 80.25008
    assert mb["zsignfed"] == 82.50008
    assert mb["eden"] == 82.50008
    assert mb["fedbat"] == 82.50032
    assert mb["pfed1bs"] == 0.2625


def test_total_mb_is_decimal_megabytes():
    """total_mb is SI decimal MB (bits / 8e6), NOT binary MiB — the README
    tables and the docstring promise exactly this. FedAvg's 160.0 is only
    a round number in decimal; the MiB value differs by ~4.9%."""
    got = comms.round_bits("fedavg", n=N, m=M, s=S)
    assert got["total_mb"] == got["total_bits"] / 8e6 == 160.0
    mib = got["total_bits"] / (8 * 2**20)
    assert abs(got["total_mb"] - mib) > 7  # the two conventions are far apart
    # and the accumulated meter uses the same convention
    acc = comms.accumulate_round_bits("pfed1bs", n=N, m=M, s_per_round=[S, S])
    assert acc["total_mb"] == acc["total_bits"] / 8e6


def test_num_tensors_only_affects_fedbat():
    """num_tensors is FedBAT's per-tensor scale count (one fp32 alpha per
    tensor); every other algorithm ignores it."""
    for algo in EXPECTED:
        a = comms.round_bits(algo, n=N, m=M, s=S, num_tensors=1)
        b = comms.round_bits(algo, n=N, m=M, s=S, num_tensors=64)
        if algo == "fedbat":
            assert b["uplink_bits"] - a["uplink_bits"] == S * 32 * 63
        else:
            assert a == b, algo


def test_reduction_vs_fedavg_ordering():
    red = {a: comms.reduction_vs_fedavg(a, n=N, m=M, s=S, num_tensors=T)
           for a in EXPECTED}
    assert red["fedavg"] == 0.0
    assert red["pfed1bs"] > 0.998          # >99.8% of FedAvg traffic removed
    assert red["pfed1bs"] > red["obda"] > red["obcsaa"] > red["fedavg"]


def test_unknown_algo_raises():
    with pytest.raises(ValueError):
        comms.round_bits("nope", n=N, m=M, s=S)


# ---------------------------------------------------------------------------
# Serving-tier storage accounting (fl/comms.storage_bits — the README
# cost-model row for the personalized-state store, serve/store.py)
# ---------------------------------------------------------------------------

def test_storage_bits_formulas():
    """fp32: 32nK. pfed1bs: 32n base + K*(m+32) per pass."""
    k = 64
    fp32 = comms.storage_bits("fp32", n=N, m=N, k=k)
    assert fp32["total_bits"] == 32 * N * k
    assert fp32["compression_vs_fp32"] == 1.0

    ours = comms.storage_bits("pfed1bs", n=N, m=N, k=k)   # m = n: EDEN regime
    assert ours["total_bits"] == 32 * N + k * (N + 32)
    assert ours["per_client_bits"] == (32 * N + k * (N + 32)) / k

    two = comms.storage_bits("pfed1bs", n=N, m=N, k=k, passes=2)
    assert two["total_bits"] == 32 * N + k * 2 * (N + 32)


def test_storage_concrete_readme_numbers():
    """The literal compression factors shown in README.md (m = n, 1 bit per
    parameter per client + amortized fp32 base)."""
    for k, expect in ((64, 21.33), (256, 28.44), (1024, 31.03)):
        got = comms.storage_bits("pfed1bs", n=N, m=N, k=k)["compression_vs_fp32"]
        assert abs(got - expect) < 0.01, (k, got)
    # >= 20x resident-state compression from 64 clients up
    assert comms.storage_bits("pfed1bs", n=N, m=N, k=64)["compression_vs_fp32"] > 20


def test_storage_unknown_algo_raises():
    with pytest.raises(ValueError):
        comms.storage_bits("nope", n=N, m=M, k=4)
