"""Serving subsystem tests (serve/store, serve/engine, serve/router,
batched reconstruct, store checkpointing).

Fast tier-1: everything here is unit-scale (tiny models, a few decode
steps) — the multi-round end-to-end quality run lives in
benchmarks/serve_bench.py and the example smoke tests carry the `slow`
marker instead.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import flatten
from repro.core import sketch as sk
from repro.obs import hist
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import smallnets as sn
from repro.serve import router
from repro.serve import store as st
from repro.serve.engine import EngineConfig, ModelLRU, ServeEngine


def _mlp_template(key=0, input_dim=48, hidden=24):
    return sn.init_mlp(jax.random.key(key), input_dim=input_dim, hidden=hidden)


# ---------------------------------------------------------------------------
# Batched fused adjoint (the decode kernel path)
# ---------------------------------------------------------------------------

def test_batched_adjoint_matches_ref_oracle_rowwise():
    """ops.srht_adjoint_batched_2d == per-row kernels/ref.py oracle,
    bit-exact, on both the ref dispatch and the Pallas (interpret) path."""
    b, rows, c, m_chunk = 5, 3, 256, 64
    key = jax.random.key(0)
    kv, kd, ko = jax.random.split(key, 3)
    v = jax.random.normal(kv, (b, rows, m_chunk), jnp.float32)
    d = jax.random.rademacher(kd, (rows, c), dtype=jnp.float32)
    off = jax.random.randint(ko, (rows, 1), 0, c // m_chunk).astype(jnp.int32)
    scale = float(np.sqrt(c / m_chunk))

    oracle = np.stack([
        np.asarray(kref.srht_adj_ref(v[i], d, off, scale=scale))
        for i in range(b)
    ])
    # ref dispatch: bit-exact with the oracle (same butterfly algorithm)
    got_ref = np.asarray(
        kops.srht_adjoint_batched_2d(v, d, off, scale=scale, impl="ref")
    )
    assert got_ref.shape == (b, rows, c)
    np.testing.assert_array_equal(got_ref, oracle)
    # pallas path: bit-exact with the UNbatched pallas adjoint per client
    # (same kernel, bigger row grid), allclose with the oracle at the
    # repo's matmul-FHT-vs-butterfly tolerance (cf. test_srht_fused.py)
    got_pl = np.asarray(
        kops.srht_adjoint_batched_2d(v, d, off, scale=scale, impl="pallas")
    )
    seq_pl = np.stack([
        np.asarray(kops.srht_adjoint_2d(v[i], d, off, scale=scale, impl="pallas"))
        for i in range(b)
    ])
    np.testing.assert_array_equal(got_pl, seq_pl)
    np.testing.assert_allclose(got_pl, oracle, rtol=2e-4, atol=2e-4)


def test_sketch_adjoint_batched_matches_sequential():
    """sketch_adjoint_batched row b is bit-exact with sketch_adjoint(v[b])."""
    n, b = 1000, 7
    spec = sk.make_sketch_spec(n, 0.25, chunk=256, mode="chunked")
    v = jax.random.normal(jax.random.key(1), (b, spec.m), jnp.float32)
    batched = np.asarray(sk.sketch_adjoint_batched(spec, v))
    for i in range(b):
        np.testing.assert_array_equal(
            batched[i], np.asarray(sk.sketch_adjoint(spec, v[i]))
        )


def test_sketch_adjoint_batched_global_mode():
    spec = sk.make_sketch_spec(300, 0.2, chunk=4096, mode="global")
    v = jax.random.normal(jax.random.key(2), (3, spec.m), jnp.float32)
    batched = np.asarray(sk.sketch_adjoint_batched(spec, v))
    for i in range(3):
        np.testing.assert_array_equal(
            batched[i], np.asarray(sk.sketch_adjoint(spec, v[i]))
        )


# ---------------------------------------------------------------------------
# Codec: encode / decode round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["flat", "leaf"])
def test_store_roundtrip_reduces_residual(layout):
    """Decoded residual keeps ~2/pi of the energy at m=n (EDEN regime):
    reconstruction error must be well below the all-zero-residual baseline,
    and a second refinement pass must strictly improve it."""
    base = _mlp_template(0)
    k = 4
    clients = jax.vmap(lambda kk: sn.init_mlp(kk, input_dim=48, hidden=24))(
        jax.random.split(jax.random.key(1), k)
    )
    errs = {}
    for passes in (1, 2):
        sspec = st.make_store_spec(
            base, k, m_ratio=1.0, chunk=512, layout=layout, passes=passes
        )
        store = st.SketchStore(sspec, base)
        store.put_batch(np.arange(k), clients)
        rec = store.materialize(np.arange(k))
        rv = jax.vmap(flatten.ravel)(rec)
        cv = jax.vmap(flatten.ravel)(clients)
        bv = flatten.ravel(base)[None]
        errs[passes] = float(jnp.sum((rv - cv) ** 2) / jnp.sum((cv - bv) ** 2))
    assert errs[1] < 0.55        # theory: 1 - 2/pi ~= 0.36 at m = n
    assert errs[2] < errs[1]     # refinement strictly helps
    assert errs[2] < 0.25        # ~ (1 - 2/pi)^2


@pytest.mark.parametrize("layout", ["flat", "leaf"])
def test_materialize_one_matches_batch_row(layout):
    base = _mlp_template(0)
    clients = jax.vmap(lambda kk: sn.init_mlp(kk, input_dim=48, hidden=24))(
        jax.random.split(jax.random.key(3), 5)
    )
    sspec = st.make_store_spec(base, 5, m_ratio=0.5, chunk=512, layout=layout)
    store = st.SketchStore(sspec, base)
    store.put_batch(np.arange(5), clients)
    batch = store.materialize([4, 1, 2])
    one = store.materialize_one(1)
    for a, b in zip(jax.tree.leaves(one), jax.tree.leaves(batch)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b[1]))


def test_encode_wire_format_matches_oracle():
    """Stored words are exactly pack_ref(sign(Phi r)) and the stored scale
    is sum|z| / n' — the codec's wire format pinned against the oracle."""
    base = _mlp_template(0)
    params = sn.init_mlp(jax.random.key(9), input_dim=48, hidden=24)
    sspec = st.make_store_spec(base, 1, m_ratio=1.0, chunk=512)
    store = st.SketchStore(sspec, base)
    store.put(0, params)

    r = flatten.ravel(params) - flatten.ravel(base)
    z = sk.sketch_forward(sspec.flat_specs[0], r)
    signs = jnp.sign(z) + (z == 0)
    pad = (-sspec.m) % 32
    expect_words = kref.pack_ref(jnp.pad(signs, (0, pad)))
    np.testing.assert_array_equal(
        np.asarray(store.words[0, 0]), np.asarray(expect_words)
    )
    expect_scale = float(jnp.sum(jnp.abs(z)) / sspec.n_pad)
    assert np.isclose(float(store.scales[0, 0]), expect_scale, rtol=1e-6)


def test_store_flat_decode_is_lsq_scale():
    """At m = n the decode is base + alpha * Phi^T sign(Phi r) with the
    least-squares-optimal alpha: check the reconstruction correlates
    positively and no alternative scalar multiple does better."""
    base = _mlp_template(0)
    params = sn.init_mlp(jax.random.key(5), input_dim=48, hidden=24)
    sspec = st.make_store_spec(base, 1, m_ratio=1.0, chunk=512)
    store = st.SketchStore(sspec, base)
    store.put(0, params)
    r = flatten.ravel(params) - flatten.ravel(base)
    rec = store.materialize_flat([0])[0] - flatten.ravel(base)
    err_opt = float(jnp.sum((rec - r) ** 2))
    for factor in (0.5, 0.9, 1.1, 2.0):
        err = float(jnp.sum((factor * rec - r) ** 2))
        assert err_opt <= err + 1e-6, factor


def test_store_rejects_out_of_range_ids():
    """Out-of-range ids must raise: jnp gathers clamp and scatters drop,
    which in a multi-tenant store means serving the wrong user's weights
    or silently losing a write."""
    base = _mlp_template(0)
    store = st.SketchStore(st.make_store_spec(base, 3, chunk=512), base)
    with pytest.raises(ValueError, match=r"\[0, 3\)"):
        store.materialize([3])
    with pytest.raises(ValueError):
        store.put(-1, base)
    dense = st.DenseStore(3, base)
    with pytest.raises(ValueError):
        dense.materialize_one(7)
    with pytest.raises(ValueError):
        dense.put_batch([0, 3], jax.tree.map(lambda a: jnp.stack([a, a]), base))


def test_dense_store_exact():
    base = _mlp_template(0)
    clients = jax.vmap(lambda kk: sn.init_mlp(kk, input_dim=48, hidden=24))(
        jax.random.split(jax.random.key(4), 3)
    )
    store = st.DenseStore(3, base)
    store.put_batch(np.arange(3), clients)
    rec = store.materialize([2, 0])
    for a, b in zip(jax.tree.leaves(rec), jax.tree.leaves(clients)):
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[2]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[0]))
    assert store.resident_bytes()["compression_vs_fp32"] == 1.0


def test_store_compression_accounting_matches_comms():
    """SketchStore.resident_bytes agrees with fl/comms.storage_bits up to
    the uint32 word padding (exact when m % 32 == 0)."""
    from repro.fl import comms

    base = _mlp_template(0)
    k = 8
    sspec = st.make_store_spec(base, k, m_ratio=1.0, chunk=512)
    assert sspec.m % 32 == 0
    store = st.SketchStore(sspec, base)
    rb = store.resident_bytes()
    analytic = comms.storage_bits("pfed1bs", n=sspec.n, m=sspec.m, k=k)
    # base leaves are all fp32 here, so resident bytes == analytic bits/8
    assert rb["client_state_bytes"] * 8 == k * (sspec.m + 32)
    assert rb["total_bytes"] * 8 == analytic["total_bits"]


# ---------------------------------------------------------------------------
# Engine: LRU + multi-tenant batched decode
# ---------------------------------------------------------------------------

def test_model_lru_eviction_and_hits():
    lru = ModelLRU(2)
    assert lru.get(0) is None
    lru.put(0, "a")
    lru.put(1, "b")
    assert lru.get(0) == "a"          # hit; 0 now most-recent
    lru.put(2, "c")                   # evicts 1
    assert lru.get(1) is None
    assert lru.get(0) == "a" and lru.get(2) == "c"
    assert lru.hits == 3 and lru.misses == 2
    assert len(lru) == 2


def _tiny_arch():
    from repro import configs

    return configs.get("granite-8b").reduced(
        n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2, n_kv=1,
        head_dim=16, remat=False,
    )


def test_engine_multitenant_matches_per_client_decode():
    """A vmapped multi-tenant batch must produce exactly the tokens each
    client's model produces when decoded alone."""
    from repro.models import lm

    arch = _tiny_arch()
    k = 3
    clients = jax.vmap(lambda kk: lm.init_params(arch, kk))(
        jax.random.split(jax.random.key(0), k)
    )
    store = st.DenseStore(k, jax.tree.map(lambda a: a[0], clients))
    store.put_batch(np.arange(k), clients)

    cfg = EngineConfig(prompt_len=4, gen_len=5, max_batch=3, hot_models=2)
    engine = ServeEngine(arch, store, cfg)
    prompts = router.random_prompts(7, k, cfg.prompt_len, arch.vocab)
    for i in range(k):
        engine.submit(i, prompts[i])
    results = engine.flush()
    assert len(results) == 1
    got = results[0].tokens                              # (k, gen)

    # oracle: each client alone, plain decode_step loop
    for i in range(k):
        params = jax.tree.map(lambda a: a[i], clients)
        cache = lm.init_cache(arch, 1, cfg.prompt_len + cfg.gen_len)
        logits = None
        for t in range(cfg.prompt_len):
            logits, cache = lm.decode_step(
                arch, params, prompts[i, t].reshape(1, 1), cache, jnp.int32(t)
            )
        cur = int(jnp.argmax(logits[0, 0, : arch.vocab]))
        toks = []
        for t in range(cfg.gen_len):
            toks.append(cur)
            logits, cache = lm.decode_step(
                arch, params, jnp.full((1, 1), cur, jnp.int32), cache,
                jnp.int32(cfg.prompt_len + t),
            )
            cur = int(jnp.argmax(logits[0, 0, : arch.vocab]))
        np.testing.assert_array_equal(got[i], np.asarray(toks, np.int32))


def test_engine_batches_misses_and_caches_hits():
    from repro.models import lm

    arch = _tiny_arch()
    base = lm.init_params(arch, jax.random.key(0))
    k = 6
    sspec = st.make_store_spec(base, k, m_ratio=0.25, chunk=1024)
    store = st.SketchStore(sspec, base)
    cfg = EngineConfig(prompt_len=2, gen_len=2, max_batch=4, hot_models=2)
    engine = ServeEngine(arch, store, cfg)
    prompts = router.random_prompts(8, 6, cfg.prompt_len, arch.vocab)
    for i, c in enumerate([0, 1, 0, 2, 3, 1]):
        engine.submit(c, prompts[i])
    engine.flush()
    s = engine.stats()
    # group1 = [0,1,0,2]: LRU empty -> unique misses {0,1,2} decoded in ONE
    # materialize call; all 4 requests are misses (the duplicate 0 arrived
    # before its model was resident). LRU(2) then holds {1, 2}.
    # group2 = [3,1]: 3 misses (second call), 1 hits.
    assert s["materialize_calls"] == 2
    assert s["requests_miss"] == 5
    assert s["requests_hit"] == 1
    assert s["tokens_generated"] == 6 * cfg.gen_len


def test_engine_telemetry_bytes_bounded_and_sketch_stats():
    """PR 10 acceptance: stream telemetry memory must be independent of
    request count (sketch + bounded burn ring, never a per-request list),
    and the reported p50/p99 must come from the mergeable sketch — within
    its relative accuracy of the exact per-call percentiles."""
    from repro.models import lm
    from repro.serve import engine as eng_mod

    arch = _tiny_arch()
    base = lm.init_params(arch, jax.random.key(0))
    k = 8
    sspec = st.make_store_spec(base, k, m_ratio=0.25, chunk=1024)
    store = st.SketchStore(sspec, base)
    cfg = EngineConfig(prompt_len=2, gen_len=1, max_batch=2, hot_models=1)
    engine = ServeEngine(arch, store, cfg)
    prompts = router.random_prompts(3, 1, cfg.prompt_len, arch.vocab)

    hard_cap = (
        hist.FIXED_BYTES
        + hist.BUCKET_BYTES * (eng_mod.SKETCH_MAX_BUCKETS + 1)
        + hist.BUCKET_BYTES * eng_mod.SLO_RING_EVENTS
    )
    for i in range(24):                          # round-robin cold clients:
        engine.submit(i % k, prompts[0])         # hot_models=1 -> all miss
    engine.flush()
    s = engine.stats()
    assert s["materialize_calls"] >= 12
    assert s["telemetry_bytes"] == engine.telemetry_bytes() <= hard_cap

    # sketch-derived percentiles within rel_acc of the exact sample stats
    # (re-derive the exact stream from the engine's own burn ring, which
    # retains every event here: calls < SLO_RING_EVENTS)
    events = engine.slo_events()
    assert len(events) == s["materialize_calls"]
    exact_ms = np.asarray([ms for _, ms in events])
    for q, key in ((0.50, "materialize_p50_ms"), (0.99, "materialize_p99_ms")):
        want = float(np.percentile(exact_ms, q * 100, method="lower"))
        assert abs(s[key] - want) <= engine.mat_ms.rel_acc * want + 1e-9
    assert s["materialize_max_ms"] == exact_ms.max()

    # now pump 10k more samples through the SAME structures the serving
    # path feeds (sketch + burn ring): the footprint must saturate at the
    # hard cap — resident bytes a function of bounded structure sizes,
    # never of how many requests went through
    rng = np.random.default_rng(0)
    sizes = []
    for chunk in range(4):
        for ms in rng.lognormal(2.0, 1.0, 2500):
            engine.mat_ms.add(ms)
            engine.mat_recent.append((engine.now, ms))
        sizes.append(engine.telemetry_bytes())
        assert sizes[-1] <= hard_cap
    assert sizes[-1] == sizes[-2]                # flat after saturation
    assert len(engine.mat_recent) == eng_mod.SLO_RING_EVENTS
    assert len(engine.mat_ms.buckets) <= eng_mod.SKETCH_MAX_BUCKETS


def test_stream_report_carries_sketch_and_telemetry():
    """router.run_stream must surface the sketch-derived percentiles, the
    serialized sketch itself (mergeable downstream), and the bounded
    telemetry footprint."""
    from repro.models import lm

    arch = _tiny_arch()
    base = lm.init_params(arch, jax.random.key(0))
    store = st.DenseStore(4, base)
    store.put_batch(
        np.arange(4),
        jax.tree.map(lambda a: jnp.stack([a] * 4), base),
    )
    cfg = EngineConfig(prompt_len=2, gen_len=1, max_batch=2, hot_models=2)
    engine = ServeEngine(arch, store, cfg)
    cids = router.zipf_stream(0, 4, 6, alpha=1.1)
    prompts = router.random_prompts(1, 6, cfg.prompt_len, arch.vocab)
    rep = router.run_stream(engine, cids, prompts, zipf_alpha=1.1, warm=False)
    d = rep.to_dict()
    assert d["telemetry_bytes"] == engine.telemetry_bytes() > 0
    assert d["materialize_max_ms"] >= d["materialize_p99_ms"] >= 0.0
    back = hist.QuantileSketch.from_dict(rep.mat_sketch)
    assert back == engine.mat_ms
    assert back.quantile(0.99) == engine.mat_ms.quantile(0.99)


# ---------------------------------------------------------------------------
# Router stream shape
# ---------------------------------------------------------------------------

def test_zipf_stream_is_heavy_tailed():
    ids = router.zipf_stream(0, 100, 4000, alpha=1.2)
    assert ids.shape == (4000,)
    assert ids.min() >= 0 and ids.max() < 100
    _, counts = np.unique(ids, return_counts=True)
    top = np.sort(counts)[::-1]
    assert top[0] > 4000 * 0.1       # hottest client dominates
    probs = router.zipf_probs(100, 1.2)
    assert np.isclose(probs.sum(), 1.0)
    assert probs[0] > probs[1] > probs[-1]


# ---------------------------------------------------------------------------
# Checkpointing the packed store
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["flat", "leaf"])
def test_client_store_checkpoint_roundtrip(tmp_path, layout):
    base = _mlp_template(0)
    k = 3
    clients = jax.vmap(lambda kk: sn.init_mlp(kk, input_dim=48, hidden=24))(
        jax.random.split(jax.random.key(6), k)
    )
    sspec = st.make_store_spec(
        base, k, m_ratio=0.5, chunk=512, layout=layout, passes=2, seed=11
    )
    store = st.SketchStore(sspec, base)
    store.put_batch(np.arange(k), clients)

    path = str(tmp_path / "store.npz")
    ckpt.save_client_store(path, store, extra_meta={"round": 42})
    loaded = ckpt.load_client_store(path, base)

    np.testing.assert_array_equal(np.asarray(loaded.words), np.asarray(store.words))
    np.testing.assert_array_equal(np.asarray(loaded.scales), np.asarray(store.scales))
    for a, b in zip(jax.tree.leaves(loaded.base), jax.tree.leaves(store.base)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert loaded.sspec == store.sspec
    assert ckpt.load_meta(path)["round"] == 42

    # decoded models identical before/after the round trip
    a = store.materialize([0, 2])
    b = loaded.materialize([0, 2])
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_client_store_checkpoint_wrong_template_raises(tmp_path):
    base = _mlp_template(0)
    store = st.SketchStore(st.make_store_spec(base, 2), base)
    path = str(tmp_path / "store.npz")
    ckpt.save_client_store(path, store)
    other = sn.init_mlp(jax.random.key(0), input_dim=80, hidden=24)
    with pytest.raises(ValueError):
        ckpt.load_client_store(path, other)


def test_load_checkpoint_shape_mismatch_is_value_error(tmp_path):
    """The old bare `assert` vanished under python -O; must be ValueError
    and must name the offending leaf."""
    tree = {"w": jnp.ones((3, 4)), "b": jnp.zeros((4,))}
    path = str(tmp_path / "c.npz")
    ckpt.save_checkpoint(path, tree)
    bad = {"w": jnp.ones((3, 5)), "b": jnp.zeros((4,))}
    with pytest.raises(ValueError, match="'w'"):
        ckpt.load_checkpoint(path, bad)


def test_load_checkpoint_missing_leaf_is_value_error(tmp_path):
    tree = {"w": jnp.ones((3, 4))}
    path = str(tmp_path / "c.npz")
    ckpt.save_checkpoint(path, tree)
    with pytest.raises(ValueError, match="missing leaf"):
        ckpt.load_checkpoint(path, {"w": jnp.ones((3, 4)), "extra": jnp.ones(2)})
