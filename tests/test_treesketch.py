"""Sharding-aware tree sketch: block-diagonal SRHT over pytrees."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import treesketch as ts
from repro.core import regularizer as reg


def _tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(k1, (8, 96)),
        "b": {"w": jax.random.normal(k2, (300,)), "s": jax.random.normal(k3, (4, 4))},
    }


def test_tree_forward_adjoint_identity():
    tree = _tree(jax.random.key(0))
    tspec = ts.make_tree_sketch_spec(tree, 0.2, chunk=128)
    z = ts.tree_sketch_forward(tspec, tree)
    v = {k: jax.random.normal(jax.random.fold_in(jax.random.key(1), i), zz.shape)
         for i, (k, zz) in enumerate(z.items())}
    # <Phi x, v> == <x, Phi^T v>
    lhs = sum(float(jnp.vdot(z[k], v[k])) for k in z)
    back = ts.tree_sketch_adjoint(tspec, v, tree)
    rhs = sum(
        float(jnp.vdot(a, b))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back))
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_major_axis_layout_is_equivalent_sketch():
    """Moving the sharded axis outermost permutes elements; the sketch stays
    a valid block-SRHT (same norms), though a different operator."""
    tree = _tree(jax.random.key(2))
    majors = {"a": 1, "b": {"w": -1, "s": 0}}
    t0 = ts.make_tree_sketch_spec(tree, 0.25, chunk=128)
    t1 = ts.make_tree_sketch_spec(tree, 0.25, chunk=128, major_axes=majors)
    z0 = ts.tree_sketch_forward(t0, tree)
    z1 = ts.tree_sketch_forward(t1, tree)
    assert all(z0[k].shape == z1[k].shape for k in z0)
    # Parseval-ish: comparable energy between layouts
    e0 = sum(float(jnp.sum(v ** 2)) for v in z0.values())
    e1 = sum(float(jnp.sum(v ** 2)) for v in z1.values())
    assert 0.2 < e0 / e1 < 5.0


def test_tree_reg_grad_matches_autodiff():
    tree = _tree(jax.random.key(3))
    tspec = ts.make_tree_sketch_spec(tree, 0.2, chunk=128)
    v = {k: jnp.sign(jax.random.normal(jax.random.fold_in(jax.random.key(4), i), (s.num_chunks, s.m_chunk)))
         for i, (k, s, _, _) in enumerate(tspec.entries)}
    gamma, lam, mu = 200.0, 0.3, 0.01

    def obj(t):
        z = ts.tree_sketch_forward(tspec, t)
        val = sum(lam * reg.smoothed_reg(v[k].reshape(-1), z[k].reshape(-1), gamma) for k in z)
        l2 = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(t))
        return val + 0.5 * mu * l2

    g_auto = jax.grad(obj)(tree)
    val, g_man = ts.tree_reg_value_and_grad(tspec, tree, v, gamma, lam, mu)
    np.testing.assert_allclose(float(obj(tree)), float(val), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_auto), jax.tree.leaves(g_man)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_leaf_vs_flat_layout_parity():
    """Leaf-layout (per-leaf block-diagonal, treesketch) vs flat-layout
    (global-ravel SketchSpec) sketches of the same tree. They are different
    operators (different block randomness), but must be interchangeable:
    same analytic guarantees at matched compression.

      * both satisfy the adjoint identity <Phi x, v> == <x, Phi^T v>;
      * both are near-isometries on the same input (Lemma 2's
        ||Phi_i|| = sqrt(c/m_i) per block => comparable sketch energy);
      * the sketch dimensions match to within per-leaf rounding.
    """
    from repro.core import flatten
    from repro.core import sketch as sk

    tree = _tree(jax.random.key(7))
    m_ratio, chunk = 0.25, 128
    tspec = ts.make_tree_sketch_spec(tree, m_ratio, chunk=chunk)
    w = flatten.ravel(tree)
    fspec = sk.make_sketch_spec(int(w.shape[0]), m_ratio, chunk=chunk,
                                mode="chunked")

    # matched compression (total rows differ only by per-leaf rounding)
    assert abs(tspec.m - fspec.m) / fspec.m < 0.1, (tspec.m, fspec.m)

    # adjoint identity, leaf layout
    z_leaf = ts.tree_sketch_forward(tspec, tree)
    v_leaf = {k: jax.random.normal(jax.random.fold_in(jax.random.key(8), i), zz.shape)
              for i, (k, zz) in enumerate(z_leaf.items())}
    lhs = sum(float(jnp.vdot(z_leaf[k], v_leaf[k])) for k in z_leaf)
    back = ts.tree_sketch_adjoint(tspec, v_leaf, tree)
    rhs = sum(float(jnp.vdot(a, b))
              for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)

    # adjoint identity, flat layout
    z_flat = sk.sketch_forward(fspec, w)
    v_flat = jax.random.normal(jax.random.key(9), z_flat.shape)
    np.testing.assert_allclose(
        float(jnp.vdot(z_flat, v_flat)),
        float(jnp.vdot(w, sk.sketch_adjoint(fspec, v_flat))),
        rtol=1e-4,
    )

    # near-isometry on the same vector for both layouts
    e_in = float(jnp.sum(w ** 2))
    e_leaf = sum(float(jnp.sum(zz ** 2)) for zz in z_leaf.values())
    e_flat = float(jnp.sum(z_flat ** 2))
    assert 0.5 < e_leaf / e_in < 2.0, e_leaf / e_in
    assert 0.5 < e_flat / e_in < 2.0, e_flat / e_in
    assert 0.5 < e_leaf / e_flat < 2.0, e_leaf / e_flat


def test_engine_leaf_layout_matches_treesketch_dims():
    """PFed1BS(layout="leaf") sketches through the tree spec: engine m is
    the TreeSketchSpec m and the consensus/EF buffers size accordingly."""
    import dataclasses

    from repro.core.pfed1bs import PFed1BS, PFed1BSConfig

    tree = _tree(jax.random.key(10))
    template = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    cfg = PFed1BSConfig(num_clients=3, participate=3, m_ratio=0.2, chunk=128,
                        layout="leaf", error_feedback=True)
    eng = PFed1BS(cfg, lambda p, b: 0.0, template)
    tspec = ts.make_tree_sketch_spec(template, 0.2, chunk=128)
    assert eng.spec is None and eng.tspec.m == tspec.m == eng.m
    state = eng.init(lambda k: jax.tree.map(
        lambda a: jnp.zeros(a.shape, a.dtype), tree), jax.random.key(0))
    assert state.v.shape == (tspec.m,)
    assert state.ef.shape == (3, tspec.m)


def test_zeros_like_and_flat_view():
    tree = _tree(jax.random.key(5))
    tspec = ts.make_tree_sketch_spec(tree, 0.1, chunk=128)
    v0 = ts.zeros_like_sketch(tspec)
    assert ts.flat_view(tspec, v0).shape == (tspec.m,)
    assert float(ts.flat_view(tspec, v0).sum()) == 0.0
    assert tspec.n == 8 * 96 + 300 + 16
