"""Sharding-aware tree sketch: block-diagonal SRHT over pytrees."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import treesketch as ts
from repro.core import regularizer as reg


def _tree(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "a": jax.random.normal(k1, (8, 96)),
        "b": {"w": jax.random.normal(k2, (300,)), "s": jax.random.normal(k3, (4, 4))},
    }


def test_tree_forward_adjoint_identity():
    tree = _tree(jax.random.key(0))
    tspec = ts.make_tree_sketch_spec(tree, 0.2, chunk=128)
    z = ts.tree_sketch_forward(tspec, tree)
    v = {k: jax.random.normal(jax.random.fold_in(jax.random.key(1), i), zz.shape)
         for i, (k, zz) in enumerate(z.items())}
    # <Phi x, v> == <x, Phi^T v>
    lhs = sum(float(jnp.vdot(z[k], v[k])) for k in z)
    back = ts.tree_sketch_adjoint(tspec, v, tree)
    rhs = sum(
        float(jnp.vdot(a, b))
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back))
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_major_axis_layout_is_equivalent_sketch():
    """Moving the sharded axis outermost permutes elements; the sketch stays
    a valid block-SRHT (same norms), though a different operator."""
    tree = _tree(jax.random.key(2))
    majors = {"a": 1, "b": {"w": -1, "s": 0}}
    t0 = ts.make_tree_sketch_spec(tree, 0.25, chunk=128)
    t1 = ts.make_tree_sketch_spec(tree, 0.25, chunk=128, major_axes=majors)
    z0 = ts.tree_sketch_forward(t0, tree)
    z1 = ts.tree_sketch_forward(t1, tree)
    assert all(z0[k].shape == z1[k].shape for k in z0)
    # Parseval-ish: comparable energy between layouts
    e0 = sum(float(jnp.sum(v ** 2)) for v in z0.values())
    e1 = sum(float(jnp.sum(v ** 2)) for v in z1.values())
    assert 0.2 < e0 / e1 < 5.0


def test_tree_reg_grad_matches_autodiff():
    tree = _tree(jax.random.key(3))
    tspec = ts.make_tree_sketch_spec(tree, 0.2, chunk=128)
    v = {k: jnp.sign(jax.random.normal(jax.random.fold_in(jax.random.key(4), i), (s.num_chunks, s.m_chunk)))
         for i, (k, s, _, _) in enumerate(tspec.entries)}
    gamma, lam, mu = 200.0, 0.3, 0.01

    def obj(t):
        z = ts.tree_sketch_forward(tspec, t)
        val = sum(lam * reg.smoothed_reg(v[k].reshape(-1), z[k].reshape(-1), gamma) for k in z)
        l2 = sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(t))
        return val + 0.5 * mu * l2

    g_auto = jax.grad(obj)(tree)
    val, g_man = ts.tree_reg_value_and_grad(tspec, tree, v, gamma, lam, mu)
    np.testing.assert_allclose(float(obj(tree)), float(val), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_auto), jax.tree.leaves(g_man)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


def test_zeros_like_and_flat_view():
    tree = _tree(jax.random.key(5))
    tspec = ts.make_tree_sketch_spec(tree, 0.1, chunk=128)
    v0 = ts.zeros_like_sketch(tspec)
    assert ts.flat_view(tspec, v0).shape == (tspec.m,)
    assert float(ts.flat_view(tspec, v0).sum()) == 0.0
    assert tspec.n == 8 * 96 + 300 + 16
