"""core/rounds.py round-surface helpers: scatter with unsorted/duplicate-free
index vectors, mixed-dtype stacked pytrees, and the billing invariant that
an inactive (straggler) client is never invoiced.

Property tests run under hypothesis when installed (the CI extras leg);
plain examples always run (tests/_hypothesis_shim.py).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.fl import comms
from tests._hypothesis_shim import given, settings, hst


# ---------------------------------------------------------------------------
# scatter_rows
# ---------------------------------------------------------------------------

def _tree(k=6, d=3):
    """Stacked client tree with MIXED dtypes: float weights + int counters."""
    return {
        "w": jnp.arange(k * d, dtype=jnp.float32).reshape(k, d),
        "steps": jnp.arange(k, dtype=jnp.int32) * 10,
    }


def test_scatter_rows_unsorted_idx():
    """Duplicate-free but UNSORTED idx must land each row on its own
    client, independent of draw order."""
    tree = _tree()
    idx = jnp.asarray([4, 0, 2], jnp.int32)          # unsorted
    rows = {
        "w": jnp.full((3, 3), -1.0, jnp.float32),
        "steps": jnp.asarray([100, 200, 300], jnp.int32),
    }
    active = jnp.ones((3,), jnp.float32)
    out = rounds.scatter_rows(tree, idx, rows, active)
    np.testing.assert_array_equal(np.asarray(out["steps"]),
                                  [200, 10, 300, 30, 100, 50])
    for row, c in enumerate([4, 0, 2]):
        np.testing.assert_array_equal(np.asarray(out["w"][c]),
                                      np.asarray(rows["w"][row]))
    # untouched clients keep their rows bit-for-bit
    for c in (1, 3, 5):
        np.testing.assert_array_equal(np.asarray(out["w"][c]),
                                      np.asarray(tree["w"][c]))


def test_scatter_rows_mixed_dtype_straggler_mask():
    """active=0 rows keep the client's old row on EVERY leaf, including
    integer leaves (the new row must be cast, not the mask arithmetic)."""
    tree = _tree()
    idx = jnp.asarray([5, 1], jnp.int32)
    rows = {
        "w": jnp.full((2, 3), 7.5, jnp.float32),
        # float64-ish input rows: scatter casts to the leaf dtype
        "steps": jnp.asarray([111.0, 222.0], jnp.float32),
    }
    active = jnp.asarray([0.0, 1.0], jnp.float32)    # client 5 dropped out
    out = rounds.scatter_rows(tree, idx, rows, active)
    assert out["steps"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out["steps"]),
                                  [0, 222, 20, 30, 40, 50])
    np.testing.assert_array_equal(np.asarray(out["w"][5]),
                                  np.asarray(tree["w"][5]))
    np.testing.assert_array_equal(np.asarray(out["w"][1]), [7.5, 7.5, 7.5])


@given(hst.integers(min_value=1, max_value=8), hst.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_scatter_rows_permutation_property(s, seed):
    """For ANY duplicate-free permutation prefix idx and ANY active mask:
    active rows land, inactive and unsampled rows are bit-identical to the
    input tree."""
    k = 8
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.permutation(k)[:s], jnp.int32)
    active = jnp.asarray(rng.integers(0, 2, size=s), jnp.float32)
    tree = _tree(k=k)
    rows = {
        "w": jnp.asarray(rng.normal(size=(s, 3)), jnp.float32),
        "steps": jnp.asarray(rng.integers(0, 999, size=s), jnp.int32),
    }
    out = rounds.scatter_rows(tree, idx, rows, active)
    landed = {int(c) for c, a in zip(np.asarray(idx), np.asarray(active)) if a > 0}
    for c in range(k):
        for leaf, new in (("w", rows["w"]), ("steps", rows["steps"])):
            if c in landed:
                row = int(np.flatnonzero(np.asarray(idx) == c)[0])
                np.testing.assert_array_equal(np.asarray(out[leaf][c]),
                                              np.asarray(new[row]))
            else:
                np.testing.assert_array_equal(np.asarray(out[leaf][c]),
                                              np.asarray(tree[leaf][c]))


# ---------------------------------------------------------------------------
# draw_participants + billing: stragglers are never invoiced
# ---------------------------------------------------------------------------

def test_draw_participants_external_pair_passthrough():
    idx = jnp.asarray([3, 1, 4], jnp.int32)
    active = jnp.asarray([1, 0, 1], jnp.int32)       # int mask in, float out
    got_idx, got_active = rounds.draw_participants(
        jax.random.key(0), 6, 3, (idx, active)
    )
    np.testing.assert_array_equal(np.asarray(got_idx), np.asarray(idx))
    assert got_active.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got_active), [1.0, 0.0, 1.0])


def test_draw_participants_default_draw_all_active():
    idx, active = rounds.draw_participants(jax.random.key(3), 10, 4, None)
    assert idx.shape == (4,) == active.shape
    assert len(np.unique(np.asarray(idx))) == 4
    np.testing.assert_array_equal(np.asarray(active), np.ones(4))


@given(
    hst.integers(min_value=1, max_value=12),
    hst.integers(min_value=0, max_value=2**31 - 1),
    hst.integers(min_value=2, max_value=6),
)
@settings(max_examples=25, deadline=None)
def test_inactive_clients_never_billed_property(s, seed, rounds_n):
    """For ANY externally drawn (idx, active) sequence, the run's invoice
    through accumulate_round_bits equals the sum over rounds of
    (active clients) * m uplink — a straggler whose active=0 contributes
    exactly zero bits, no matter which client id it carries."""
    k, m = 16, 512
    rng = np.random.default_rng(seed)
    s_real = []
    for _ in range(rounds_n):
        idx = jnp.asarray(rng.permutation(k)[:s], jnp.int32)
        active = jnp.asarray(rng.integers(0, 2, size=s), jnp.float32)
        got_idx, got_active = rounds.draw_participants(
            jax.random.key(0), k, s, (idx, active)
        )
        # the billing contract: s_r = sum(active), never len(idx)
        s_real.append(int(np.sum(np.asarray(got_active))))
    bill = comms.accumulate_round_bits(
        "pfed1bs", n=10_000, m=m, s_per_round=s_real
    )
    assert bill["uplink_bits"] == sum(s_real) * m
    assert bill["downlink_bits"] == rounds_n * m          # broadcast per round
    # padding every round's draw with extra PURE STRAGGLERS (active=0 rows)
    # leaves sum(active) — and therefore the invoice — unchanged
    s_padded = []
    for s_r in s_real:
        extra = int(rng.integers(1, 4))
        idx = jnp.asarray(rng.permutation(k)[:s_r + extra], jnp.int32)
        active = jnp.concatenate([
            jnp.ones((s_r,), jnp.float32), jnp.zeros((extra,), jnp.float32)
        ])
        _, got_active = rounds.draw_participants(
            jax.random.key(0), k, s_r + extra, (idx, active)
        )
        s_padded.append(int(np.sum(np.asarray(got_active))))
    assert s_padded == s_real
    bill2 = comms.accumulate_round_bits(
        "pfed1bs", n=10_000, m=m, s_per_round=s_padded
    )
    assert bill == bill2
