"""Sharding rules, data pipeline properties, checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import synthetic as ds
from repro.launch.mesh import make_debug_mesh, make_fed_model_mesh
from repro.launch import fedexec, steps as st
from repro.models import io, lm
from repro.sharding import specs as sh


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_param_pspecs_rank_and_divisibility(arch):
    """Every PartitionSpec matches leaf rank, and sharded dims divide by a
    16-way model axis on the full config (the production mesh contract)."""
    cfg = configs.get(arch)
    tmpl = st.param_template(cfg)

    class FakeMesh:
        shape = {"model": 16, "data": 16}

    pspecs = sh.param_pspecs(cfg, tmpl, FakeMesh())
    flat_t = jax.tree_util.tree_flatten_with_path(tmpl)[0]
    flat_s = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    n_sharded = 0
    for (path, leaf), spec in zip(flat_t, flat_s):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax == "model":
                assert dim % 16 == 0, (jax.tree_util.keystr(path), leaf.shape, spec)
                n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing is model-sharded"


@pytest.mark.slow
def test_train_step_runs_on_debug_mesh():
    """The full lowered train step (loss+sketch+vote-ready grads) executes
    on a real (1,1) mesh with concrete values."""
    cfg = configs.get("granite-8b").reduced()
    mesh = make_debug_mesh()
    hyper = st.StepHyper(chunk=2048)
    with mesh:
        step, tmpl, tspec, pspec, vspec = st.make_train_step(cfg, hyper, mesh)
        params = lm.init_params(cfg, jax.random.key(0))
        batch = io.make_batch(cfg, jax.random.key(1), 2, 64)
        from repro.core import treesketch as ts
        v = ts.zeros_like_sketch(tspec)
        params2, loss = jax.jit(step)(params, batch, v)
    assert np.isfinite(float(loss))
    d = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert d > 0


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_fed_lm_specs_valid_on_one_device_mesh(arch):
    """Every named config yields placeable fed_lm specs on the degenerate
    (1, 1) mesh (the CI/laptop tier): NamedShardings construct for every
    leaf, and the major axes param_major_axes picks are real leaf axes —
    the contract make_fed_lm_engine's leaf-layout treesketch relies on."""
    cfg = configs.get(arch).reduced()
    tmpl = st.param_template(cfg)
    mesh = make_fed_model_mesh(1, 1)
    shd = fedexec.fed_lm_shardings(cfg, tmpl, mesh)
    flat_t = jax.tree_util.tree_flatten_with_path(tmpl)[0]
    flat_s = jax.tree.leaves(
        shd["clients"],
        is_leaf=lambda x: hasattr(x, "spec") and not isinstance(x, dict),
    )
    assert len(flat_s) == len(flat_t)
    for (path, leaf), ns in zip(flat_t, flat_s):
        assert tuple(ns.spec)[0] == "fed", (path, ns.spec)
        assert len(ns.spec) <= 1 + leaf.ndim, (path, ns.spec, leaf.shape)
    majors = sh.param_major_axes(cfg, tmpl, mesh)
    for (path, leaf), (p2, ax) in zip(
        flat_t, jax.tree_util.tree_flatten_with_path(majors)[0]
    ):
        assert ax == -1 or 0 <= ax < leaf.ndim, (path, ax, leaf.shape)


def test_sharded_lm_checkpoint_roundtrip():
    """A fed_lm client store (K leading axis, leaves placed through
    fed_lm_shardings) round-trips bit-exactly through checkpoint/ckpt.py,
    and the loaded tree re-places under the same shardings."""
    cfg = configs.get("granite-8b").reduced()
    tmpl = st.param_template(cfg)
    mesh = make_fed_model_mesh(1, 1)
    shd = fedexec.fed_lm_shardings(cfg, tmpl, mesh)
    params = lm.init_params(cfg, jax.random.key(0))
    clients = jax.tree.map(lambda a: jnp.stack([a, a + 1]), params)
    placed = jax.tree.map(jax.device_put, clients, shd["clients"])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "clients.npz")
        save_checkpoint(path, placed, meta={"round": 1})
        back = load_checkpoint(path, placed)
        for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        again = jax.tree.map(jax.device_put, back, shd["clients"])
        for a, b in zip(jax.tree.leaves(placed), jax.tree.leaves(again)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_label_skew_partition():
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=10, classes_per_client=2,
        train_per_client=64, test_per_client=16,
    )
    for k in range(10):
        labels = np.unique(np.asarray(data.train_y[k]))
        assert len(labels) <= 2, f"client {k} sees {labels}"


def test_lm_data_skew():
    data = ds.make_federated_lm(jax.random.key(0), 4, vocab=256, seq=32)
    b = ds.sample_lm_batches(jax.random.key(1), data, local_steps=2, batch=4)
    assert b["tokens"].shape == (4, 2, 4, 32)
    # client streams should concentrate on different vocab slices
    h0 = np.bincount(np.asarray(data.tokens[0]).ravel(), minlength=256)
    h1 = np.bincount(np.asarray(data.tokens[1]).ravel(), minlength=256)
    cos = (h0 @ h1) / (np.linalg.norm(h0) * np.linalg.norm(h1))
    assert cos < 0.9, cos


def test_checkpoint_roundtrip():
    cfg = configs.get("granite-8b").reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, params, meta={"round": 3})
        back = load_checkpoint(path, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_pspecs_divisibility():
    cfg = configs.get("granite-8b")

    class FakeMesh:
        shape = {"model": 16, "data": 16}

    specs = sh.batch_pspecs(cfg, io.batch_specs(cfg, 256, 128), FakeMesh())
    assert jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))[0][0] == "data"
    # batch=1 cannot shard
    specs1 = sh.batch_pspecs(cfg, io.batch_specs(cfg, 1, 128), FakeMesh())
    assert jax.tree.leaves(specs1, is_leaf=lambda x: isinstance(x, P))[0][0] is None
