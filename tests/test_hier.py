"""Hierarchical tree-of-aggregators federation (DESIGN.md §11).

Contracts pinned here:
  * The COUNTEREXAMPLE: majority-of-majorities (sign-then-sign per leaf)
    is NOT the flat vote — an explicit 3-leaf instance flips a coordinate
    — while the partial-popcount counter merge is bit-exact with the flat
    popcount on the same words. This is the theorem the whole tier rests
    on: counts are sum-decomposable, signs are not.
  * Property sweep (hypothesis, when installed): counter merge is
    associative, commutative, and invariant to HOW the client rows are
    sharded into leaves; the tree vote is bit-identical to the flat
    kernels/ops.vote_popcount (ref AND pallas dispatch) for fan-out 2-16,
    depth 1-4, ragged leaves.
  * Kernel parity: popcount_partial / merge_counters / finish_vote_counts
    pallas(interpret) == ref on lane-aligned and ragged word counts;
    K=0 and traced-k edges.
  * Executor parity: launch/fedexec.hier_round == the flat popcount
    sharded_round bit-for-bit (consensus, client params, EF) on a
    1-device mesh, for balanced/ragged/single-leaf topologies, honest and
    (slow tier) under adversary/defense/privacy axes.
  * Async tier: the HierAsyncSimulator's zero-latency full-fan-in drain
    reproduces the synchronous hier_round sequence bit-for-bit, and eager
    partial forwards (buffer_size=1) + nonzero latency change message
    counts and timing but never the per-version consensus.
  * Billing: fl/comms.counter_bits / hier_round_bits invariants, executor
    metrics re-derive from comms, and exp/report.validate_hier accepts
    exactly the artifacts whose numbers re-derive.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.launch.fedexec import HierTopology
from repro.models import smallnets as sn

from tests._hypothesis_shim import given, settings, hst


# ---------------------------------------------------------------------------
# the 3-leaf counterexample: sign-then-sign != flat vote; count-merge == flat
# ---------------------------------------------------------------------------

def test_sign_then_sign_counterexample_count_merge_exact():
    """9 clients in 3 leaves of 3. Bit 0 tallies per leaf: 2-1, 2-1, 0-3.
    Majority-of-majorities sees two +1 leaves and votes +1; the flat vote
    sees 4-of-9 ones and votes -1. The counter tree reproduces the flat
    vote bit-for-bit on the same words."""
    rows = [1, 1, 0,  1, 1, 0,  0, 0, 0]          # bit 0 of each client word
    words = jnp.asarray(np.array(rows, np.uint32)[:, None])   # (9, 1)
    leaves = (3, 3, 3)

    flat = kops.vote_popcount(words, impl="ref")             # the truth
    assert int(np.asarray(flat)[0]) & 1 == 0                  # 4 < 9/2 -> -1

    # sign-then-sign: each leaf votes, then the 3 one-row leaf votes vote
    leaf_votes = jnp.stack([
        kops.vote_popcount(words[i:i + 3], impl="ref") for i in (0, 3, 6)
    ])
    naive = kops.vote_popcount(leaf_votes, impl="ref")
    assert int(np.asarray(naive)[0]) & 1 == 1                 # flipped to +1
    assert not np.array_equal(np.asarray(naive), np.asarray(flat))

    # the counter merge over the SAME leaves is bit-exact with flat
    tree = consensus.tree_vote_popcount(words, leaves, impl="ref")
    np.testing.assert_array_equal(np.asarray(tree), np.asarray(flat))


# ---------------------------------------------------------------------------
# property sweep: merge algebra + shard-split invariance (hypothesis)
# ---------------------------------------------------------------------------

def _rand_words(seed: int, k: int, w: int) -> jnp.ndarray:
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** 32, size=(k, w), dtype=np.uint32
    ))


def _partition(k: int, cuts: list[int]) -> tuple[int, ...]:
    """Turn sorted cut points into leaf sizes covering k rows."""
    edges = [0] + sorted(set(c % (k + 1) for c in cuts)) + [k]
    sizes = [b - a for a, b in zip(edges, edges[1:]) if b > a]
    return tuple(sizes) if sizes else (k,)


@settings(max_examples=30, deadline=None)
@given(hst.integers(0, 2 ** 31), hst.integers(1, 40), hst.integers(1, 12),
       hst.lists(hst.integers(0, 40), max_size=6))
def test_merge_associative_commutative_split_invariant(seed, k, w, cuts):
    words = _rand_words(seed, k, w)
    leaves = _partition(k, cuts)
    # split-invariance: counting per leaf then merging == counting flat
    parts = []
    start = 0
    for s in leaves:
        parts.append(kref.popcount_partial_ref(words[start:start + s]))
        start += s
    merged = kref.merge_counters_ref(jnp.stack(parts))
    flat_counts = kref.popcount_partial_ref(words)
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(flat_counts))
    if len(parts) >= 2:
        a, b, rest = parts[0], parts[1], parts[2:]
        # commutative
        ab = kref.merge_counters_ref(jnp.stack([a, b]))
        ba = kref.merge_counters_ref(jnp.stack([b, a]))
        np.testing.assert_array_equal(np.asarray(ab), np.asarray(ba))
        # associative: ((a+b)+rest) == (a+(b+rest...)) == flat merge
        left = kref.merge_counters_ref(jnp.stack([ab, *rest]))
        np.testing.assert_array_equal(np.asarray(left), np.asarray(merged))


@settings(max_examples=30, deadline=None)
@given(hst.integers(0, 2 ** 31), hst.integers(1, 48), hst.integers(1, 8),
       hst.integers(2, 16), hst.lists(hst.integers(0, 48), max_size=7))
def test_tree_vote_bit_identical_to_flat_popcount(seed, k, w, fan, cuts):
    """Ragged leaves, any fan-out in [2,16] (depth follows: up to
    log_2(48) ~ 6 tiers at fan-out 2), vote == flat popcount, always."""
    words = _rand_words(seed, k, w)
    leaves = _partition(k, cuts)
    tree = consensus.tree_vote_popcount(words, leaves, impl="ref")
    flat = kops.vote_popcount(words, impl="ref")
    np.testing.assert_array_equal(np.asarray(tree), np.asarray(flat))
    # the executor's fan-out-at-a-time merge schedule over the same leaves
    topo_sizes = leaves if sum(leaves) == k else (k,)
    counters = []
    start = 0
    for s in topo_sizes:
        counters.append(kref.popcount_partial_ref(words[start:start + s]))
        start += s
    while len(counters) > 1:
        counters = [
            kref.merge_counters_ref(jnp.stack(counters[i:i + fan]))
            for i in range(0, len(counters), fan)
        ]
    got = kref.finish_vote_counts_ref(counters[0], k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(flat))


@settings(max_examples=10, deadline=None)
@given(hst.integers(0, 2 ** 31), hst.integers(1, 33), hst.integers(1, 5),
       hst.lists(hst.integers(0, 33), max_size=4))
def test_tree_vote_matches_vote_popcount_pallas(seed, k, w, cuts):
    """The tree vote through the PALLAS dispatch (interpret off-TPU) is
    bit-identical to the flat pallas popcount vote."""
    words = _rand_words(seed, k, w)
    leaves = _partition(k, cuts)
    tree = consensus.tree_vote_popcount(words, leaves, impl="pallas")
    flat = kops.vote_popcount(words, impl="pallas")
    np.testing.assert_array_equal(np.asarray(tree), np.asarray(flat))


# ---------------------------------------------------------------------------
# counter kernel parity + edges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 7, 33])
@pytest.mark.parametrize("w", [1, 130])
def test_counter_kernels_pallas_match_ref(k, w):
    words = _rand_words(k * 1000 + w, k, w)
    c_ref = kops.popcount_partial(words, impl="ref")
    c_pl = kops.popcount_partial(words, impl="pallas")
    np.testing.assert_array_equal(np.asarray(c_pl), np.asarray(c_ref))
    stack = jnp.stack([c_ref, c_ref, 2 * c_ref])
    np.testing.assert_array_equal(
        np.asarray(kops.merge_counters(stack, impl="pallas")),
        np.asarray(kops.merge_counters(stack, impl="ref")),
    )
    np.testing.assert_array_equal(
        np.asarray(kops.finish_vote_counts(c_ref, k, impl="pallas")),
        np.asarray(kops.finish_vote_counts(c_ref, k, impl="ref")),
    )
    # finish over the flat counts IS the flat popcount vote
    np.testing.assert_array_equal(
        np.asarray(kops.finish_vote_counts(c_ref, k)),
        np.asarray(kops.vote_popcount(words, impl="ref")),
    )


def test_counter_kernel_edges():
    # K=0: zero counters; finishing k=0 counts gives all-ones (+1 ties)
    empty = kops.popcount_partial(jnp.zeros((0, 3), jnp.uint32))
    assert empty.shape == (3, 32)
    assert int(jnp.sum(jnp.abs(empty))) == 0
    vw = kops.finish_vote_counts(empty, 0)
    assert np.all(np.asarray(vw) == 0xFFFFFFFF)
    # traced k (the trim revote's data-dependent head count) routes to ref
    words = _rand_words(5, 9, 4)
    counts = kops.popcount_partial(words)

    @jax.jit
    def finish_traced(c, k):
        return kops.finish_vote_counts(c, k)

    np.testing.assert_array_equal(
        np.asarray(finish_traced(counts, jnp.int32(9))),
        np.asarray(kops.finish_vote_counts(counts, 9)),
    )


# ---------------------------------------------------------------------------
# HierTopology + billing
# ---------------------------------------------------------------------------

def test_hier_topology_build_shapes():
    topo = HierTopology.build(10, fan_out=4)
    assert sum(topo.leaf_sizes) == 10 and topo.num_clients == 10
    assert max(topo.leaf_sizes) - min(topo.leaf_sizes) <= 1
    levels = topo.level_widths()
    assert [sum(w) for w in levels] == [10] * len(levels)
    assert levels[-1] == [10]
    with pytest.raises(AssertionError):
        HierTopology(leaf_sizes=(), fan_out=2)
    with pytest.raises(AssertionError):
        HierTopology(leaf_sizes=(3,), fan_out=1)


def test_counter_bits_closed_interval():
    """A width-w counter must represent the count w itself: the wire
    format is ceil(log2(w + 1)) bit planes (NOT the ceil(log2(w))
    shorthand — a width-4 counter holds the value 4)."""
    assert [comms.counter_bits(w) for w in (1, 2, 3, 4, 7, 8, 1000)] == \
        [1, 2, 2, 3, 3, 4, 10]


def test_hier_round_bits_invariants():
    m = 64
    hb = comms.hier_round_bits(m=m, leaf_widths=(3, 3, 2), fan_out=2)
    assert hb["client_uplink_bits"] == 8 * m
    # tier 1: three leaf counters (widths 3,3,2 -> 2,2,2 planes)
    # tier 2: two counters (widths 6 -> 3 planes, 2 -> 2 planes)
    assert hb["tier_uplink_bits"] == [6 * m, 5 * m]
    assert hb["tiers"] == 3
    assert hb["root_ingress_bits"] == 5 * m
    assert hb["downlink_bits"] == 3 * m
    assert hb["uplink_bits"] == (8 + 6 + 5) * m
    assert hb["total_bits"] == hb["uplink_bits"] + hb["downlink_bits"]
    # single leaf degenerates to the flat server: root ingests S*m
    flat = comms.hier_round_bits(m=m, leaf_widths=(8,), fan_out=2)
    assert flat["root_ingress_bits"] == 8 * m
    assert flat["tier_uplink_bits"] == [] and flat["tiers"] == 1


def test_validate_hier_accepts_rederivable_rejects_tampered():
    from repro.exp.report import validate_hier

    m = 128
    rows = []
    for s in (100, 10_000):
        topo = HierTopology.build(s, fan_out=8)
        hb = comms.hier_round_bits(m=m, leaf_widths=topo.leaf_sizes,
                                   fan_out=8)
        rows.append({
            "clients": s, "fan_out": 8, "tiers": hb["tiers"],
            "root_ingress_bits": hb["root_ingress_bits"],
            "flat_ingress_bits": s * m, "uplink_bits": hb["uplink_bits"],
            "downlink_bits": hb["downlink_bits"],
            "tier_uplink_bits": hb["tier_uplink_bits"], "simulated": True,
        })
    art = {
        "m": m, "fan_out": 8,
        "counter_merge_parity": {
            "bit_exact": True,
            "engine_cells": [{"topology": "fan2", "bit_exact": True}],
            "vote_cases": [],
        },
        "scaling": rows,
    }
    validate_hier(art)                                   # re-derives clean
    bad = {**art, "scaling": [dict(rows[0]), dict(rows[1])]}
    bad["scaling"][1]["root_ingress_bits"] += 1
    with pytest.raises(ValueError, match="does not re-derive"):
        validate_hier(bad)
    with pytest.raises(ValueError, match="bit_exact"):
        validate_hier({**art, "counter_merge_parity": {
            "bit_exact": True,
            "engine_cells": [{"topology": "x", "bit_exact": False}],
            "vote_cases": [],
        }})


# ---------------------------------------------------------------------------
# executor parity: hier_round vs the flat popcount sharded_round
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fed_setup():
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=6, train_per_client=48,
        test_per_client=24, noise=0.8,
    )

    def loss_fn(params, batch):
        return sn.softmax_xent(sn.apply_mlp(params, batch["x"]), batch["y"])

    def init_fn(k):
        return sn.init_mlp(k, input_dim=784, hidden=16)

    return data, loss_fn, init_fn


BASE = dict(num_clients=6, participate=6, local_steps=2, m_ratio=0.05,
            chunk=2048, sharded_round=True, vote="popcount")


def _run(cfg, data, loss_fn, init_fn, rounds=2):
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng = PFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(2))
    metrics = None
    for r in range(rounds):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), r))
        batches = ds.sample_round_batches(kb, data, cfg.local_steps, 16)
        state, metrics = eng.round(state, batches, data.weights, kr)
    return eng, state, metrics


def _assert_states_equal(st_a, st_b):
    np.testing.assert_array_equal(np.asarray(st_a.v), np.asarray(st_b.v))
    for a, b in zip(jax.tree.leaves(st_a.clients),
                    jax.tree.leaves(st_b.clients)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if st_a.ef is not None:
        np.testing.assert_array_equal(np.asarray(st_a.ef),
                                      np.asarray(st_b.ef))


@pytest.fixture(scope="module")
def flat_popcount_run(fed_setup):
    data, loss_fn, init_fn = fed_setup
    return _run(PFed1BSConfig(**BASE), data, loss_fn, init_fn)


TOPOLOGIES = {
    "fan2-balanced": HierTopology.build(6, fan_out=2),
    "ragged": HierTopology(leaf_sizes=(1, 2, 3), fan_out=2),
    "single-leaf": HierTopology(leaf_sizes=(6,), fan_out=4),
}


@pytest.mark.parametrize("name", list(TOPOLOGIES))
def test_hier_round_bit_exact_vs_flat_popcount(fed_setup, flat_popcount_run,
                                               name):
    data, loss_fn, init_fn = fed_setup
    topo = TOPOLOGIES[name]
    cfg = PFed1BSConfig(**BASE, topology=topo)
    _, st_t, m_t = _run(cfg, data, loss_fn, init_fn)
    _, st_f, m_f = flat_popcount_run
    _assert_states_equal(st_t, st_f)
    # per-tier billing re-derives from fl/comms
    eng = PFed1BS(cfg, loss_fn,
                  jax.eval_shape(init_fn, jax.random.key(1)))
    hb = topo.round_bits(eng.m)
    assert int(m_t["tiers"]) == hb["tiers"]
    assert int(m_t["root_ingress_bits"]) == hb["root_ingress_bits"]
    assert int(m_t["tier_uplink_bits"]) == sum(hb["tier_uplink_bits"])
    assert int(m_t["downlink_bits"]) == hb["downlink_bits"]
    assert int(m_t["uplink_bits"]) == 6 * eng.m + sum(hb["tier_uplink_bits"])


def test_hier_round_ef_bit_exact(fed_setup):
    data, loss_fn, init_fn = fed_setup
    cfg_f = PFed1BSConfig(**BASE, error_feedback=True)
    cfg_t = dataclasses.replace(cfg_f, topology=TOPOLOGIES["fan2-balanced"])
    _, st_f, _ = _run(cfg_f, data, loss_fn, init_fn)
    _, st_t, _ = _run(cfg_t, data, loss_fn, init_fn)
    _assert_states_equal(st_t, st_f)


def test_topology_config_guards(fed_setup):
    data, loss_fn, init_fn = fed_setup
    template = jax.eval_shape(init_fn, jax.random.key(1))
    topo = TOPOLOGIES["fan2-balanced"]
    with pytest.raises(AssertionError, match="popcount"):
        PFed1BS(PFed1BSConfig(**{**BASE, "vote": "exact"}, topology=topo),
                loss_fn, template)
    with pytest.raises(AssertionError, match="sharded_round"):
        PFed1BS(
            PFed1BSConfig(**{**BASE, "sharded_round": False}, topology=topo),
            loss_fn, template,
        )
    with pytest.raises(AssertionError, match="covers"):
        PFed1BS(
            PFed1BSConfig(**BASE,
                          topology=HierTopology.build(5, fan_out=2)),
            loss_fn, template,
        )


@pytest.mark.slow
@pytest.mark.parametrize("axes", [
    ("trim", "signflip", None),
    ("none", None, 2.0),
    ("trim", "colluding", 1.5),
])
def test_hier_round_parity_under_axes(fed_setup, axes):
    """Adversary corruption and RR privacy flips are keyed by (seed,
    round, client) — executor-invariant — and the trimmed defense runs at
    the ROOT on the merged counts, so the tree stays bit-exact with the
    flat popcount server under every axis combination."""
    from repro.exp import scenarios

    defense, adv_name, eps = axes
    adv = {
        "signflip": scenarios.SignFlipAttack(fraction=0.34),
        "colluding": scenarios.ColludingBloc(fraction=0.34),
        None: None,
    }[adv_name]
    privacy = scenarios.RandomizedResponse(epsilon=eps) if eps else None
    data, loss_fn, init_fn = fed_setup
    cfg_f = PFed1BSConfig(**BASE, defense=defense, adversary=adv,
                          privacy=privacy)
    cfg_t = dataclasses.replace(cfg_f, topology=TOPOLOGIES["ragged"])
    _, st_f, _ = _run(cfg_f, data, loss_fn, init_fn)
    _, st_t, _ = _run(cfg_t, data, loss_fn, init_fn)
    _assert_states_equal(st_t, st_f)


@pytest.mark.slow
def test_run_cell_topology_axis(fed_setup):
    """The scenario-matrix topology axis threads into the engine and the
    cell bills the tiers on top of the flat uplink."""
    from repro.exp import runner, scenarios

    sc = scenarios.Scenario(
        "tree", scenarios.DirichletPartition(0.3),
        scenarios.FullParticipation(),
        topology=scenarios.TreeAggregation(fan_out=2),
    )
    cfg = runner.ExpConfig(num_clients=4, rounds=2, local_steps=1, batch=8,
                           hidden=16, train_per_client=16, test_per_client=8,
                           chunk=2048, m_ratio=0.05)
    cell = runner.run_cell("pfed1bs", sc, cfg)
    assert cell["topology"] == "tree-fan2"
    topo = HierTopology.build(4, fan_out=2)
    hb = comms.hier_round_bits(m=cell["m"], leaf_widths=topo.leaf_sizes,
                               fan_out=2)
    flat = comms.accumulate_round_bits(
        "pfed1bs", n=cell["n"], m=cell["m"],
        s_per_round=cell["s_per_round"], num_tensors=cell["num_tensors"],
    )
    assert cell["uplink_bits"] == \
        flat["uplink_bits"] + sum(hb["tier_uplink_bits"]) * cfg.rounds
    assert cell["downlink_bits"] == hb["downlink_bits"] * cfg.rounds
    with pytest.raises(ValueError, match="topology axis"):
        runner.run_cell("fedavg", sc, cfg)
    assert "tree-fan4" in scenarios.hier_matrix()


# ---------------------------------------------------------------------------
# async tier: zero-latency drain == synchronous hier_round, bit-for-bit
# ---------------------------------------------------------------------------

def _sim_inputs(data, s, versions):
    def participants_fn(version):
        return jnp.arange(s, dtype=jnp.int32), jnp.ones((s,), jnp.float32)

    def batch_fn(version):
        kb, _ = jax.random.split(
            jax.random.fold_in(jax.random.key(11), version)
        )
        return ds.sample_round_batches(kb, data, 2, 16)

    return participants_fn, batch_fn


def _sync_hier_sequence(fed_setup, topo, versions):
    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(**BASE, topology=topo)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng = PFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(2))
    participants_fn, batch_fn = _sim_inputs(data, 6, versions)
    seq = []
    for v in range(versions):
        _, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), v))
        state, _ = eng.round(state, batch_fn(v), data.weights, kr,
                             participants=participants_fn(v))
        seq.append(np.asarray(state.v).copy())
    return eng, state, seq


def _drain(fed_setup, topo, versions, tiers=()):
    from repro.sim import HierAsyncSimulator, HierSimConfig

    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(**BASE, topology=topo)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng = PFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(2))
    participants_fn, batch_fn = _sim_inputs(data, 6, versions)
    sim = HierAsyncSimulator(
        eng,
        HierSimConfig(topology=topo, max_versions=versions, seed=0,
                      tiers=tiers),
        data.weights, participants_fn, batch_fn,
    )
    seq = []
    final, report = sim.run(
        state, on_flush=lambda t, ver, st: seq.append(np.asarray(st.v).copy())
    )
    return final, report, seq


def test_hier_sim_zero_latency_drain_bit_exact(fed_setup):
    topo = TOPOLOGIES["fan2-balanced"]
    versions = 2
    _, st_sync, seq_sync = _sync_hier_sequence(fed_setup, topo, versions)
    st_sim, report, seq_sim = _drain(fed_setup, topo, versions)
    for a, b in zip(seq_sim, seq_sync):
        np.testing.assert_array_equal(a, b)
    _assert_states_equal(st_sim, st_sync)
    # billing re-derives: sim meter == versions * the synchronous bill
    eng_m = report.m
    hb = topo.round_bits(eng_m)
    assert report.meter.uplink_bits == versions * (
        6 * eng_m + sum(hb["tier_uplink_bits"])
    )
    assert report.meter.downlink_bits == versions * hb["downlink_bits"]
    report.check_billing()                    # internal re-derivation
    d = report.to_dict()
    assert d["versions"] == versions


@pytest.mark.slow
def test_hier_sim_eager_buffers_change_messages_not_votes(fed_setup):
    """buffer_size=1 at the leaf tier forwards every arrival immediately:
    more counter messages, nonzero virtual time under latency, and the
    SAME consensus per version (integer counts merge to the same total in
    any grouping)."""
    from repro.sim import TierSpec
    from repro.sim.clock import ConstantLatency

    topo = TOPOLOGIES["fan2-balanced"]
    versions = 2
    _, _, seq_sync = _sync_hier_sequence(fed_setup, topo, versions)
    _, rep_lazy, seq_lazy = _drain(fed_setup, topo, versions)
    _, rep_eager, seq_eager = _drain(
        fed_setup, topo, versions,
        tiers=(TierSpec(latency=ConstantLatency(0.25), buffer_size=1),),
    )
    for a, b, c in zip(seq_lazy, seq_eager, seq_sync):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert rep_eager.flushes[-1].counter_messages > \
        rep_lazy.flushes[-1].counter_messages
    assert rep_eager.final_t > rep_lazy.final_t
    # the VOTE is grouping-invariant (asserted above); the BILL is not:
    # every extra partial forward pays its node's full counter width, so
    # the lazy drain bills exactly the synchronous analytic figure and the
    # eager drain strictly more — both re-derive event-by-event
    hb = topo.round_bits(rep_lazy.m)
    assert rep_lazy.meter.uplink_bits == versions * (
        6 * rep_lazy.m + sum(hb["tier_uplink_bits"])
    )
    assert rep_eager.meter.uplink_bits > rep_lazy.meter.uplink_bits
    assert rep_eager.meter.downlink_bits == rep_lazy.meter.downlink_bits
    rep_eager.check_billing()


def test_hier_sim_rejects_defended_votes(fed_setup):
    from repro.sim import HierAsyncSimulator, HierSimConfig

    data, loss_fn, init_fn = fed_setup
    topo = TOPOLOGIES["fan2-balanced"]
    cfg = PFed1BSConfig(**BASE, topology=topo, defense="trim")
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng = PFed1BS(cfg, loss_fn, template)
    participants_fn, batch_fn = _sim_inputs(data, 6, 1)
    with pytest.raises(AssertionError, match="global ranking"):
        HierAsyncSimulator(
            eng, HierSimConfig(topology=topo, max_versions=1),
            data.weights, participants_fn, batch_fn,
        )
