"""Per-architecture smoke tests (REQUIRED per brief: reduced variant of the
same family, one forward/train step on CPU, output shapes + no NaNs) plus
decode-vs-forward consistency checks for every cache mechanism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import io, layers as L, lm
from repro.models.config import ArchConfig

# per-arch smoke sweeps dominate suite wall time; deselect with -m "not slow"
pytestmark = pytest.mark.slow

SEQ, BATCH = 64, 2


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    cfg = configs.get(arch).reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    batch = io.make_batch(cfg, jax.random.key(1), BATCH, SEQ)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    logits, _ = lm.forward(cfg, params, batch)
    expect_s = SEQ - (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (BATCH, expect_s, cfg.vocab_pad)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN logits"
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.isfinite(np.asarray(g)).all(), f"{arch}: NaN grad at {path}"


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_serve_step(arch):
    cfg = configs.get(arch).reduced()
    params = lm.init_params(cfg, jax.random.key(0))
    cache = lm.init_cache(cfg, BATCH, SEQ, enc_len=SEQ)
    tok = io.make_decode_token(cfg, jax.random.key(2), BATCH)
    logits, cache2 = lm.decode_step(cfg, params, tok, cache, jnp.int32(3))
    assert logits.shape == (BATCH, 1, cfg.vocab_pad)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def _decode_replay(cfg, params, tokens, cache):
    """Feed tokens one at a time through decode_step, stacking logits."""
    outs = []
    for t in range(tokens.shape[1]):
        logits, cache = lm.decode_step(
            cfg, params, tokens[:, t : t + 1], cache, jnp.int32(t)
        )
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1), cache


@pytest.mark.parametrize("arch", [
    "granite-8b",              # GQA + RoPE path
    "h2o-danube-3-4b",         # SWA ring-cache path
    "falcon-mamba-7b",         # mamba1 state path
    "zamba2-2.7b",             # hybrid mamba2 + shared-attn path
    "deepseek-v2-236b",        # MLA absorbed-decode path
])
def test_decode_matches_forward(arch):
    """Sequential one-token decode must reproduce the full causal forward —
    validates every cache/state mechanism end to end."""
    cfg = configs.get(arch).reduced()
    if cfg.n_experts:
        # capacity dropping is data-dependent; make it non-binding so the
        # forward and decode paths route identically
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    s = 16
    params = lm.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (BATCH, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    full_logits, _ = lm.forward(cfg, params, batch)
    cache = lm.init_cache(cfg, BATCH, s)
    dec_logits, _ = _decode_replay(cfg, params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_audio_decode_matches_forward():
    cfg = configs.get("seamless-m4t-medium").reduced()
    s = 12
    params = lm.init_params(cfg, jax.random.key(0))
    frames = jax.random.normal(jax.random.key(1), (BATCH, s, cfg.d_model))
    tokens = jax.random.randint(jax.random.key(2), (BATCH, s), 0, cfg.vocab)
    batch = {"frames": frames, "tokens": tokens, "labels": tokens}
    full_logits, _ = lm.forward(cfg, params, batch)
    cache = lm.init_cache(cfg, BATCH, s, enc_len=s)
    cache["cross"] = lm.build_cross_cache(cfg, params, frames)
    dec_logits, _ = _decode_replay(cfg, params, tokens, cache)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
    )


def test_block_swa_equals_masked_full_attention():
    """The sub-quadratic block-SWA path is EXACT vs the masked dense path."""
    cfg = dataclasses.replace(
        configs.get("h2o-danube-3-4b").reduced(), window=16
    )
    p = L.init_attention(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model))
    pos = jnp.arange(64)
    blocked = L.attention(p, cfg, x, pos, window=16)       # 64 > 16: block path
    # force dense path by calling with window but s == window after reshape:
    ar = jnp.arange(64)
    mask = (ar[None, :] <= ar[:, None]) & (ar[:, None] - ar[None, :] < 16)
    q, k, v = L._qkv(p, cfg, x, pos)
    dense = L._sdpa(q, k, v, mask) @ p["wo"]
    np.testing.assert_allclose(
        np.asarray(blocked), np.asarray(dense), rtol=1e-4, atol=1e-4
    )


def test_moe_sorted_matches_dense_dispatch():
    """sort/gather dispatch == GShard one-hot dispatch (same tokens kept when
    capacity is not binding)."""
    cfg = dataclasses.replace(
        configs.get("granite-moe-3b-a800m").reduced(), capacity_factor=4.0
    )
    p = L.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_dense, _ = L.moe(p, dataclasses.replace(cfg, moe_impl="dense"), x)
    y_sorted, _ = L.moe(p, dataclasses.replace(cfg, moe_impl="sorted"), x)
    np.testing.assert_allclose(
        np.asarray(y_dense), np.asarray(y_sorted), rtol=1e-3, atol=1e-4
    )


def test_long_context_support_flags():
    assert configs.get("falcon-mamba-7b").supports_long_context
    assert configs.get("zamba2-2.7b").supports_long_context
    assert configs.get("h2o-danube-3-4b").supports_long_context
    assert not configs.get("deepseek-67b").supports_long_context
    assert not configs.get("starcoder2-7b").supports_long_context


def test_smallnets():
    from repro.models import smallnets as sn

    mp = sn.init_mlp(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 28, 28, 1))
    assert sn.apply_mlp(mp, x).shape == (4, 10)
    vp = sn.init_vgg(jax.random.key(2))
    xi = jax.random.normal(jax.random.key(3), (2, 32, 32, 3))
    logits = sn.apply_vgg(vp, xi)
    assert logits.shape == (2, 10)
    assert np.isfinite(np.asarray(logits)).all()
