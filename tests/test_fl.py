"""FL system behaviour: pFed1BS learns personalized models on non-iid data,
the potential descends, every baseline runs, comms accounting matches the
paper's cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import BaselineConfig, BaselineFL
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.models import smallnets as sn

# multi-round end-to-end FL runs; deselect with -m "not slow" for tier-1 fast
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def fed_setup():
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=6, train_per_client=96,
        test_per_client=48, noise=0.8,
    )

    def loss_fn(params, batch):
        return sn.softmax_xent(sn.apply_mlp(params, batch["x"]), batch["y"])

    def init_fn(k):
        return sn.init_mlp(k, input_dim=784, hidden=32)

    return data, loss_fn, init_fn


def _run_pfed1bs(data, loss_fn, init_fn, rounds=12, participate=6):
    cfg = PFed1BSConfig(
        num_clients=6, participate=participate, local_steps=4, lr=0.05,
        lam=5e-4, mu=1e-5, gamma=1e4, m_ratio=0.1, chunk=2048,
    )
    eng = PFed1BS(cfg, loss_fn, jax.eval_shape(init_fn, jax.random.key(1)))
    state = eng.init(init_fn, jax.random.key(2))
    hist = []
    for r in range(rounds):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(3), r))
        batches = ds.sample_round_batches(kb, data, cfg.local_steps, 24)
        state, m = eng.round(state, batches, data.weights, kr)
        # per-coordinate vote_margins is a vector diagnostic for the
        # health monitor — history keeps the scalar metrics
        assert m["vote_margins"].shape == (eng.m,)
        hist.append({k: float(v) for k, v in m.items() if np.ndim(v) == 0})
    return eng, state, hist


def test_pfed1bs_personalization_learns(fed_setup):
    data, loss_fn, init_fn = fed_setup
    eng, state, hist = _run_pfed1bs(data, loss_fn, init_fn)
    assert hist[-1]["task_loss"] < hist[0]["task_loss"] * 0.5
    accs = jax.vmap(
        lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
    )(state.clients, data.test_x, data.test_y)
    assert float(accs.mean()) > 0.85, np.asarray(accs)


def test_potential_descends(fed_setup):
    """Theorem 1's object: Psi^t decreases to a neighborhood."""
    data, loss_fn, init_fn = fed_setup
    _, _, hist = _run_pfed1bs(data, loss_fn, init_fn)
    psi = [h["potential"] for h in hist]
    assert psi[-1] < psi[0]
    # monotone up to small noise
    assert sum(psi[i + 1] <= psi[i] + 0.05 for i in range(len(psi) - 1)) >= len(psi) - 3


def test_partial_participation_runs(fed_setup):
    data, loss_fn, init_fn = fed_setup
    _, state, hist = _run_pfed1bs(data, loss_fn, init_fn, rounds=6, participate=3)
    assert np.isfinite(hist[-1]["task_loss"])
    assert hist[-1]["uplink_bits"] == 3 * PFed1BS(
        PFed1BSConfig(num_clients=6, participate=3, chunk=2048),
        loss_fn, jax.eval_shape(init_fn, jax.random.key(1)),
    ).spec.m


def test_sign_agreement_increases(fed_setup):
    data, loss_fn, init_fn = fed_setup
    _, _, hist = _run_pfed1bs(data, loss_fn, init_fn)
    assert hist[-1]["sign_agreement"] > hist[0]["sign_agreement"]


@pytest.mark.parametrize("algo", ["fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat"])
def test_baselines_one_round(fed_setup, algo):
    data, loss_fn, init_fn = fed_setup
    cfg = BaselineConfig(algo=algo, num_clients=6, participate=6,
                         local_steps=3, lr=0.05, chunk=2048)
    eng = BaselineFL(cfg, loss_fn, jax.eval_shape(init_fn, jax.random.key(1)))
    state = eng.init(init_fn, jax.random.key(2))
    kb, kr = jax.random.split(jax.random.key(4))
    batches = ds.sample_round_batches(kb, data, 3, 24)
    state2, m = eng.round(state, batches, data.weights, kr)
    assert np.isfinite(float(m["task_loss"]))
    diff = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params))
    )
    assert diff > 0, f"{algo}: global model did not move"


def test_comms_cost_model_matches_paper_claims():
    """pFed1BS cuts >99% of FedAvg traffic at m/n=0.1 (paper Table 2)."""
    n, s = 1_000_000, 20
    m = n // 10
    red = comms.reduction_vs_fedavg("pfed1bs", n=n, m=m, s=s)
    assert red > 0.99, red
    # OBDA is ~1/32 of fedavg (1-bit both ways)
    red_obda = comms.reduction_vs_fedavg("obda", n=n, m=m, s=s)
    assert 0.96 < red_obda < 0.97
    # ordering: pfed1bs < obda < obcsaa < fedavg total bits
    bits = {a: comms.round_bits(a, n=n, m=m, s=s)["total_bits"]
            for a in ["pfed1bs", "obda", "obcsaa", "fedavg"]}
    assert bits["pfed1bs"] < bits["obda"] < bits["obcsaa"] < bits["fedavg"]


def test_fedavg_iid_sanity(fed_setup):
    """FedAvg learns the (easy) synthetic task — baselines are real learners."""
    data, loss_fn, init_fn = fed_setup
    cfg = BaselineConfig(algo="fedavg", num_clients=6, participate=6,
                         local_steps=4, lr=0.05)
    eng = BaselineFL(cfg, loss_fn, jax.eval_shape(init_fn, jax.random.key(1)))
    state = eng.init(init_fn, jax.random.key(2))
    for r in range(10):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(5), r))
        batches = ds.sample_round_batches(kb, data, 4, 24)
        state, m = eng.round(state, batches, data.weights, kr)
    acc = jax.vmap(
        lambda x, y: sn.accuracy(sn.apply_mlp(state.params, x), y)
    )(data.test_x, data.test_y)
    assert float(acc.mean()) > 0.7


@pytest.mark.parametrize("error_feedback", [False, True])
def test_fused_round_matches_staged_round(fed_setup, error_feedback):
    """The restructured gather/scatter round (fused_round=True) must be
    behaviorally identical to the seed all-K round at full participation:
    same consensus v, same client params, same EF residuals, and the
    potential/sign-agreement metrics agree (the fused potential is the
    importance-normalized estimate — exact when everyone participates)."""
    import dataclasses

    data, loss_fn, init_fn = fed_setup
    cfg_f = PFed1BSConfig(num_clients=6, participate=6, local_steps=3,
                          m_ratio=0.05, chunk=2048,
                          error_feedback=error_feedback)
    cfg_s = dataclasses.replace(cfg_f, fused_round=False)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng_f, eng_s = PFed1BS(cfg_f, loss_fn, template), PFed1BS(cfg_s, loss_fn, template)
    st_f, st_s = eng_f.init(init_fn, jax.random.key(2)), eng_s.init(init_fn, jax.random.key(2))
    for r in range(3):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), r))
        batches = ds.sample_round_batches(kb, data, 3, 24)
        st_f, m_f = eng_f.round(st_f, batches, data.weights, kr)
        st_s, m_s = eng_s.round(st_s, batches, data.weights, kr)
    np.testing.assert_array_equal(np.asarray(st_f.v), np.asarray(st_s.v))
    for a, b in zip(jax.tree.leaves(st_f.clients), jax.tree.leaves(st_s.clients)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    if error_feedback:
        np.testing.assert_allclose(
            np.asarray(st_f.ef), np.asarray(st_s.ef), atol=1e-6
        )
    np.testing.assert_allclose(
        float(m_f["potential"]), float(m_s["potential"]), rtol=1e-5
    )
    np.testing.assert_allclose(
        float(m_f["sign_agreement"]), float(m_s["sign_agreement"]), rtol=1e-6
    )


def test_error_feedback_variant_runs_and_is_stable(fed_setup):
    """Beyond-paper EF extension: runs, learns, residuals stay finite.
    (EXPERIMENTS.md records that EF *hurts* consensus agreement — this test
    pins the mechanism, not a win.)"""
    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(num_clients=6, participate=4, local_steps=3, lr=0.05,
                        m_ratio=0.05, chunk=2048, error_feedback=True)
    eng = PFed1BS(cfg, loss_fn, jax.eval_shape(init_fn, jax.random.key(1)))
    state = eng.init(init_fn, jax.random.key(2))
    assert state.ef is not None and state.ef.shape == (6, eng.spec.m)
    for r in range(5):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(7), r))
        batches = ds.sample_round_batches(kb, data, 3, 24)
        state, m = eng.round(state, batches, data.weights, kr)
    assert np.isfinite(float(m["task_loss"]))
    assert np.isfinite(np.asarray(state.ef)).all()
    assert float(jnp.sum(jnp.abs(state.ef))) > 0  # residuals accumulated
