"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus mathematical properties of the FHT."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fht import fht_pallas
from repro.kernels.onebit import pack_pallas, unpack_pallas, vote_pallas


@pytest.mark.parametrize("n", [2, 4, 16, 64, 128, 512, 2048, 16384])
@pytest.mark.parametrize("rows", [1, 3, 8])
def test_fht_pallas_matches_ref(n, rows):
    x = jax.random.normal(jax.random.key(n + rows), (rows, n))
    got = fht_pallas(x, interpret=True)
    want = ref.fht_ref(x)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fht_pallas_dtypes(dtype):
    x = jax.random.normal(jax.random.key(0), (4, 256)).astype(dtype)
    got = fht_pallas(x, interpret=True).astype(jnp.float32)
    want = ref.fht_ref(x.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_fht_ref_equals_dense_hadamard():
    for n in (2, 8, 32, 128):
        x = jax.random.normal(jax.random.key(n), (3, n))
        h = ref.hadamard_matrix(n)
        np.testing.assert_allclose(ref.fht_ref(x), x @ h.T, rtol=1e-5, atol=1e-5)


def test_fht_is_involution_and_orthonormal():
    x = jax.random.normal(jax.random.key(1), (2, 1024))
    y = ref.fht_ref(x)
    np.testing.assert_allclose(ref.fht_ref(y), x, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        jnp.sum(y * y, -1), jnp.sum(x * x, -1), rtol=1e-5
    )  # Parseval


def test_ops_fht_large_recursion():
    """Lengths beyond the single-tile kernel limit use the Kronecker split."""
    x = jax.random.normal(jax.random.key(2), (1, 2 ** 16))
    got = ops.fht(x, impl="pallas")  # interpret on CPU
    want = ref.fht_ref(x)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("rows,words", [(8, 512), (16, 1024)])
def test_pack_unpack_pallas(rows, words):
    z = jnp.sign(jax.random.normal(jax.random.key(3), (rows, words * 32)))
    z = jnp.where(z == 0, 1.0, z)
    packed = pack_pallas(z, interpret=True)
    np.testing.assert_array_equal(packed, ref.pack_ref(z))
    unpacked = unpack_pallas(packed, interpret=True)
    np.testing.assert_allclose(unpacked, z)


def test_vote_pallas_matches_ref():
    k, words = 5, 256
    z = jnp.sign(jax.random.normal(jax.random.key(4), (k, words * 32)))
    z = jnp.where(z == 0, 1.0, z)
    packed = ref.pack_ref(z)
    p = jnp.array([0.3, 0.25, 0.2, 0.15, 0.1])
    got = vote_pallas(packed, p, interpret=True)
    np.testing.assert_array_equal(got, ref.vote_ref(packed, p))


def test_vote_equals_sign_of_weighted_sum():
    k, m = 7, 320
    z = jnp.sign(jax.random.normal(jax.random.key(5), (k, m)))
    z = jnp.where(z == 0, 1.0, z)
    p = jax.nn.softmax(jax.random.normal(jax.random.key(6), (k,)))
    v_packed = ref.vote_ref(ref.pack_ref(z), p)
    v = ref.unpack_ref(v_packed)
    s = jnp.einsum("k,km->m", p, z)
    expect = jnp.where(s >= 0, 1.0, -1.0)
    np.testing.assert_allclose(v, expect)


def test_pack_roundtrip_random_floats():
    x = jax.random.normal(jax.random.key(7), (4, 320))
    w = ops.pack_signs(x)
    back = ops.unpack_signs(w)
    np.testing.assert_allclose(back, jnp.where(x >= 0, 1.0, -1.0))
