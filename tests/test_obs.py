"""Observability layer (src/repro/obs, DESIGN.md §12).

Contracts pinned here:
  * Disabled tracers are invisible: zero events, zero counters, and the
    engine's round output is BIT-identical with tracing on or off — the
    tracer lives entirely outside the jitted program (same jaxpr either
    way).
  * Virtual-clock determinism: a virtual tracer refuses wall-clock
    fallback (explicit t= or ValueError), and two same-seed simulator
    runs export BYTE-identical trace JSON — for both the flat async tier
    and the hierarchical tree tier.
  * One counter catalog: the registry rejects unknown names, mirrors
    every add into the tracer, and the shared billing checkers
    (expected_async_bits / expected_hier_bits / assert_billing) re-derive
    the meters' totals exactly.
  * validate_trace is a real gate: malformed events, non-monotone bit
    counters, missing billing, and billing that doesn't re-derive all
    raise.
  * The kernel probe times eager calls only (first call per signature is
    compile), and stays out of jit traces entirely.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import rounds
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.kernels import ops as kops
from repro.models import smallnets as sn
from repro.obs import probe as obsprobe
from repro.obs import registry as obsreg
from repro.sim.clock import ComputeNetworkLatency
from repro.sim.hier import HierAsyncSimulator, HierSimConfig
from repro.sim.server import AsyncConfig, AsyncSimulator


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_disabled_tracer_records_nothing():
    tr = obs.Tracer(enabled=False)
    with tr.span("round", track="engine", executor="fused"):
        pass
    tr.instant("dispatch", t=1.0)
    tr.complete("flush", t0=0.0, t1=1.0)
    tr.count("uplink_bits", 128, t=1.0)
    assert tr.events == []
    assert tr.counter_totals == {}
    assert obs.NOOP.events == []


def test_virtual_tracer_requires_explicit_t():
    tr = obs.Tracer(clock="virtual")
    with pytest.raises(ValueError, match="explicit t="):
        tr.instant("dispatch")
    with pytest.raises(ValueError, match="explicit t="):
        tr.count("uplink_bits", 1)
    # span() is a no-op on virtual clocks: durations go through complete()
    with tr.span("never-recorded"):
        pass
    tr.instant("dispatch", t=0.5)
    assert [e["name"] for e in tr.events] == ["dispatch"]


def test_counters_cumulative_and_integer():
    tr = obs.Tracer(clock="virtual")
    tr.count("uplink_bits", 100, t=0.0)
    tr.count("uplink_bits", 28, t=1.0)
    assert tr.counter_total("uplink_bits") == 128
    values = [e["args"]["value"] for e in tr.events]
    assert values == [100, 128]            # cumulative samples
    assert all(isinstance(v, int) for v in values)
    assert all(e["tid"] == 0 for e in tr.events)   # counters share tid 0


def test_wall_span_records_duration_and_named_track():
    tr = obs.Tracer(clock="wall")
    with tr.span("round", track="engine", executor="fused"):
        pass
    (ev,) = tr.events
    assert ev["ph"] == "X" and ev["dur"] >= 0 and ev["ts"] >= 0
    assert ev["args"] == {"executor": "fused"}
    assert tr.tracks == {"engine": 1}
    assert ev["tid"] == 1                  # named tracks start after tid 0


def _toy_trace():
    """A tiny valid virtual trace + its matching async billing spec."""
    tr = obs.Tracer(clock="virtual")
    reg = obsreg.MetricsRegistry(tracer=tr)
    tr.instant("dispatch", t=0.0, track="server", version=0)
    reg.add("uplink_bits", 2 * 64, t=0.5)
    tr.complete("flush", t0=0.0, t1=1.0, track="server", version=1)
    reg.add("downlink_bits", 64, t=1.0)
    billing = [{"kind": "async", "m": 64, "arrivals_per_flush": [2]}]
    return obs.to_chrome(tr, billing=billing)


def test_chrome_export_shape_and_validation():
    obj = _toy_trace()
    # Perfetto-loadable: traceEvents + thread_name metadata for every lane
    names = {e["args"]["name"] for e in obj["traceEvents"] if e["ph"] == "M"}
    assert "counters" in names and "server" in names
    info = obs.validate_trace(json.loads(obs.dumps_trace(obj)))
    assert info["expected"] == {"uplink_bits": 128, "downlink_bits": 64}


def test_export_byte_identical_replay():
    a = obs.dumps_trace(_toy_trace())
    b = obs.dumps_trace(_toy_trace())
    assert a == b


# ---------------------------------------------------------------------------
# registry + shared billing checkers
# ---------------------------------------------------------------------------

def test_registry_rejects_unknown_and_series_misuse():
    reg = obsreg.MetricsRegistry()
    with pytest.raises(KeyError):
        reg.add("made_up_counter", 1)
    with pytest.raises(KeyError):
        reg.add("flush_sizes", 1)          # series: observe(), not add()
    with pytest.raises(KeyError):
        reg.observe("uplink_bits", 1.0)    # counter: add(), not observe()


def test_registry_mirrors_into_tracer():
    tr = obs.Tracer(clock="virtual")
    reg = obsreg.MetricsRegistry(tracer=tr)
    reg.add("uplink_bits", 96, t=0.0)
    reg.add("votes_cast", 3, t=0.0)
    reg.observe("flush_sizes", 3, t=0.0)
    assert reg.get("uplink_bits") == 96
    assert reg.series("flush_sizes") == [3]
    assert tr.counter_total("uplink_bits") == 96
    assert tr.counter_total("votes_cast") == 3


def test_expected_async_bits_matches_comms():
    m = 64
    exp = obsreg.expected_async_bits(m, [3, 2], residual_arrivals=1)
    acc = comms.accumulate_round_bits("pfed1bs", n=0, m=m, s_per_round=[3, 2])
    assert exp == {"uplink_bits": acc["uplink_bits"] + m,
                   "downlink_bits": acc["downlink_bits"]}


def test_expected_hier_bits_matches_counter_bits():
    m = 32
    events = [(0, 1), (0, 1), (1, 4), (2, 8)]
    exp = obsreg.expected_hier_bits(m, events, versions=2, levels=3)
    up = 2 * m + comms.counter_bits(4) * m + comms.counter_bits(8) * m
    assert exp == {"uplink_bits": up, "downlink_bits": 2 * 3 * m}


def test_assert_billing_exact_or_raises():
    obsreg.assert_billing("x", {"uplink_bits": 5, "downlink_bits": 0},
                          {"uplink_bits": 5, "downlink_bits": 0})
    with pytest.raises(ValueError, match="diff 1"):
        obsreg.assert_billing("x", {"uplink_bits": 6, "downlink_bits": 0},
                              {"uplink_bits": 5, "downlink_bits": 0})


# ---------------------------------------------------------------------------
# validate_trace rejections
# ---------------------------------------------------------------------------

def test_validate_trace_rejects_missing_billing():
    obj = _toy_trace()
    obj["billing"] = []
    with pytest.raises(ValueError, match="billing"):
        obs.validate_trace(obj)


def test_validate_trace_rejects_billing_mismatch():
    obj = _toy_trace()
    obj["billing"][0]["m"] = 32            # half the actual wire traffic
    with pytest.raises(ValueError, match="does not re-derive"):
        obs.validate_trace(obj)


def test_validate_trace_rejects_nonmonotone_bit_counter():
    obj = _toy_trace()
    (sample,) = [e for e in obj["traceEvents"]
                 if e["ph"] == "C" and e["name"] == "uplink_bits"]
    tampered = {**sample, "ts": sample["ts"] + 1,
                "args": {"value": sample["args"]["value"] - 1}}
    obj["traceEvents"].append(tampered)
    with pytest.raises(ValueError, match="decreases"):
        obs.validate_trace(obj)


def test_validate_trace_rejects_bad_phase():
    obj = _toy_trace()
    obj["traceEvents"].append({"name": "x", "ph": "B", "pid": 1, "tid": 1,
                               "ts": 0})
    with pytest.raises(ValueError, match="unsupported ph"):
        obs.validate_trace(obj)


# ---------------------------------------------------------------------------
# kernel probe
# ---------------------------------------------------------------------------

def test_probe_first_call_is_compile_then_steady():
    z = jnp.sign(jax.random.normal(jax.random.key(0), (4, 64)))
    probe = obs.KernelProbe()
    with obs.probing(probe):
        kops.pack_signs(z)
        kops.pack_signs(z)
        kops.pack_signs(z)
    recs = [r for r in probe.records if r["kernel"] == "pack_signs"]
    assert [r["compile"] for r in recs] == [True, False, False]
    assert all(r["arg_bytes"] > 0 and r["out_bytes"] > 0 for r in recs)
    (row,) = [r for r in probe.table() if r["kernel"] == "pack_signs"]
    assert row["calls"] == 2 and row["compile_calls"] == 1
    assert row["us_per_call"] is not None and row["est_gb_per_s"] is not None


def test_probe_ignores_traced_calls_and_restores_on_exit():
    z = jnp.sign(jax.random.normal(jax.random.key(0), (4, 64)))
    probe = obs.KernelProbe()
    with obs.probing(probe):
        jitted = jax.jit(lambda a: kops.pack_signs(a))
        jitted(z).block_until_ready()      # tracer args: pass through untimed
        jitted(z).block_until_ready()
    assert probe.records == []
    assert obsprobe._ACTIVE is None        # deactivated after the block
    kops.pack_signs(z)                     # and recording stays off
    assert probe.records == []


# ---------------------------------------------------------------------------
# engine integration: tracer outside the jitted program
# ---------------------------------------------------------------------------

def _tiny_engine(tracer=None):
    k = s = 4
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=k, train_per_client=32,
        test_per_client=16,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda kk: sn.init_mlp(kk, input_dim=784, hidden=8)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng = PFed1BS(
        PFed1BSConfig(num_clients=k, participate=s, local_steps=2,
                      m_ratio=0.05, chunk=2048),
        loss_fn, template, tracer=tracer,
    )
    pf = lambda v: rounds.draw_participants(
        jax.random.fold_in(jax.random.key(7), v), k, s, None
    )
    bf = lambda v: ds.sample_round_batches(
        jax.random.fold_in(jax.random.key(9), v), data, 2, 16
    )
    return eng, data, init_fn, pf, bf


def test_engine_round_bit_exact_with_and_without_tracer():
    tr = obs.Tracer(clock="wall")
    states = {}
    for label, tracer in (("off", None), ("on", tr)):
        eng, data, init_fn, pf, bf = _tiny_engine(tracer)
        st = eng.init(init_fn, jax.random.key(2))
        for r in range(2):
            st, _ = eng.round(st, bf(r), data.weights, jax.random.key(0),
                              pf(r))
        states[label] = st
    for a, b in zip(jax.tree.leaves(states["off"]),
                    jax.tree.leaves(states["on"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    round_spans = [e for e in tr.events
                   if e["name"] == "round" and e["ph"] == "X"]
    assert len(round_spans) == 2
    assert round_spans[0]["args"]["executor"] == "fused"


def test_jaxpr_identical_with_and_without_tracer():
    # the SAME engine with its tracer swapped must build a character-
    # identical jaxpr: the tracer is not part of the jitted program, and
    # since `_round_jit` hashes `self` by identity, the swap also never
    # invalidates the jit cache (two separate engines would differ by
    # closure repr addresses, which is why this mutates one engine)
    eng, data, init_fn, pf, bf = _tiny_engine(None)
    st = eng.init(init_fn, jax.random.key(2))
    args = (st, bf(0), data.weights, jax.random.key(0), pf(0))
    assert eng.tracer is obs.NOOP
    jx_off = jax.make_jaxpr(eng._round_jit)(*args)
    eng.tracer = obs.Tracer(clock="wall")
    jx_on = jax.make_jaxpr(eng._round_jit)(*args)
    assert str(jx_off) == str(jx_on)
    assert eng.tracer.events == []         # tracing jaxprs records nothing


# ---------------------------------------------------------------------------
# simulator traces: byte-identical replay + billing parity
# ---------------------------------------------------------------------------

def _async_trace_bytes():
    eng, data, init_fn, pf, bf = _tiny_engine()
    tr = obs.Tracer(clock="virtual")
    sim = AsyncSimulator(
        eng,
        AsyncConfig(buffer_size=2, staleness_exponent=0.5, max_versions=2,
                    latency=ComputeNetworkLatency()),
        data.weights, pf, bf, tracer=tr,
    )
    _, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
    d = rep.to_dict()
    billing = [{"kind": "async", "m": eng.m,
                "arrivals_per_flush": d["arrivals_per_flush"],
                "residual_arrivals": d["residual_arrivals"]}]
    obj = obs.to_chrome(tr, billing=billing)
    return obs.dumps_trace(obj), tr, d


def test_async_sim_trace_byte_identical_and_counters_match_meter():
    blob1, tr, d = _async_trace_bytes()
    blob2, *_ = _async_trace_bytes()
    assert blob1 == blob2
    info = obs.validate_trace(json.loads(blob1))
    assert info["expected"]["uplink_bits"] == d["uplink_bits"]
    assert tr.counter_total("uplink_bits") == d["uplink_bits"]
    assert tr.counter_total("downlink_bits") == d["downlink_bits"]
    names = {e["name"] for e in tr.events}
    assert {"dispatch", "arrive", "flush", "broadcast"} <= names


def _hier_trace_bytes():
    from repro.launch.fedexec import HierTopology

    k = s = 4
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=k, train_per_client=32,
        test_per_client=16,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda kk: sn.init_mlp(kk, input_dim=784, hidden=8)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    topo = HierTopology.build(s, fan_out=2)
    eng = PFed1BS(
        PFed1BSConfig(num_clients=k, participate=s, local_steps=2,
                      m_ratio=0.05, chunk=2048, sharded_round=True,
                      vote="popcount", topology=topo),
        loss_fn, template,
    )
    pf = lambda v: rounds.draw_participants(
        jax.random.fold_in(jax.random.key(7), v), k, s, None
    )
    bf = lambda v: ds.sample_round_batches(
        jax.random.fold_in(jax.random.key(9), v), data, 2, 16
    )
    tr = obs.Tracer(clock="virtual")
    sim = HierAsyncSimulator(
        eng,
        HierSimConfig(topology=topo, max_versions=2,
                      client_latency=ComputeNetworkLatency()),
        data.weights, pf, bf, tracer=tr,
    )
    _, rep = sim.run(eng.init(init_fn, jax.random.key(2)))
    billing = [{
        "kind": "hier", "m": eng.m,
        "uplink_events": [[tier, width] for _, tier, width, _
                          in rep.meter.uplink_events],
        "versions": rep.versions,
        "levels": len(topo.level_widths()),
    }]
    return obs.dumps_trace(obs.to_chrome(tr, billing=billing)), tr, rep


def test_hier_sim_trace_byte_identical_and_counters_match_meter():
    blob1, tr, rep = _hier_trace_bytes()
    blob2, *_ = _hier_trace_bytes()
    assert blob1 == blob2
    obs.validate_trace(json.loads(blob1))
    assert tr.counter_total("uplink_bits") == rep.meter.uplink_bits
    assert tr.counter_total("downlink_bits") == rep.meter.downlink_bits
    assert tr.counter_total("tier_merges") > 0
    names = {e["name"] for e in tr.events}
    assert {"dispatch", "arrive", "forward", "version", "broadcast"} <= names
