"""Fused SRHT subsystem: single-pass Pallas kernels vs staged pipeline,
adjoint exactness vs dense materialization, custom-VJP gradient vs autodiff
on the full client objective, and the packed uplink epilogue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import regularizer as reg
from repro.core import sketch as sk
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.srht import dfht_pallas, srht_adj_pallas, srht_fwd_pallas


def _rand_operands(rows, c, m, seed=0):
    key = jax.random.key(seed)
    kx, kd, ko = jax.random.split(key, 3)
    x = jax.random.normal(kx, (rows, c))
    d = jax.vmap(
        lambda k: jax.random.rademacher(k, (c,), dtype=jnp.float32)
    )(jax.random.split(kd, rows))
    off = jax.random.randint(ko, (rows, 1), 0, c // m)
    return x, d, off


# -- kernel vs staged oracle -------------------------------------------------

@pytest.mark.parametrize("rows,c,m", [
    (5, 256, 26), (8, 1024, 102), (1, 4096, 409), (3, 512, 512), (11, 2048, 64),
])
def test_srht_fwd_kernel_matches_staged_oracle(rows, c, m):
    x, d, off = _rand_operands(rows, c, m, seed=rows)
    scale = float(np.sqrt(c / m))
    got = srht_fwd_pallas(x, d, off, m_chunk=m, scale=scale, interpret=True)
    want = ref.srht_fwd_ref(x, d, off, m_chunk=m, scale=scale)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rows,c,m", [(5, 256, 26), (8, 1024, 102), (3, 512, 512)])
def test_srht_adj_kernel_matches_staged_oracle(rows, c, m):
    _, d, off = _rand_operands(rows, c, m, seed=rows + 100)
    v = jax.random.normal(jax.random.key(rows), (rows, m))
    scale = float(np.sqrt(c / m))
    got = srht_adj_pallas(v, d, off, scale=scale, interpret=True)
    want = ref.srht_adj_ref(v, d, off, scale=scale)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("d_post", [False, True])
def test_dfht_kernel_matches_oracle(d_post):
    x, d, _ = _rand_operands(4, 2048, 128, seed=7)
    got = dfht_pallas(x, d, scale=1.7, d_post=d_post, interpret=True)
    want = ref.dfht_ref(x, d, scale=1.7, d_post=d_post)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_srht_fwd_packed_epilogue_bit_exact():
    x, d, off = _rand_operands(4, 4096, 512, seed=9)
    z = ref.srht_fwd_ref(x, d, off, m_chunk=512, scale=1.0)
    got = srht_fwd_pallas(x, d, off, m_chunk=512, scale=1.0, pack=True,
                          interpret=True)
    np.testing.assert_array_equal(got, ref.pack_ref(z))


# -- fused dispatch vs staged sketch, both modes ------------------------------

@pytest.mark.parametrize("mode,chunk,n", [
    ("chunked", 256, 1000), ("chunked", 128, 700), ("global", 4096, 700),
    ("global", 1024, 1024),
])
def test_fused_forward_matches_staged(mode, chunk, n):
    spec = sk.make_sketch_spec(n, 0.1, chunk=chunk, mode=mode)
    x = jax.random.normal(jax.random.key(1), (n,))
    z_staged = sk.sketch_forward_2d_staged(spec, x, impl="ref")
    z_fused = sk.sketch_forward_2d(spec, x, impl="pallas")
    # float32 tolerance: matmul-FHT vs butterfly-FHT rounding differs
    np.testing.assert_allclose(z_fused, z_staged, rtol=3e-4, atol=3e-4)
    # same math, same kernels => ref dispatch is bit-identical to staged
    z_ref = sk.sketch_forward_2d(spec, x, impl="ref")
    np.testing.assert_array_equal(np.asarray(z_ref), np.asarray(z_staged))


@pytest.mark.parametrize("mode,chunk,n", [
    ("chunked", 256, 1000), ("global", 2048, 1500),
])
def test_fused_adjoint_matches_staged_and_materialization(mode, chunk, n):
    spec = sk.make_sketch_spec(n, 0.1, chunk=chunk, mode=mode)
    v = jax.random.normal(jax.random.key(2), (spec.m,))
    a_staged = sk.sketch_adjoint_staged(spec, v, impl="ref")
    a_fused = sk.sketch_adjoint(spec, v, impl="pallas")
    np.testing.assert_allclose(a_fused, a_staged, rtol=3e-4, atol=3e-4)
    phi = np.asarray(sk.materialize(spec))
    np.testing.assert_allclose(a_fused, phi.T @ np.asarray(v), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("mode,chunk,n", [
    ("chunked", 128, 1000), ("chunked", 256, 4096), ("global", 4096, 700),
])
def test_fused_adjoint_dot_product_identity(mode, chunk, n):
    """<Phi w, v> == <w, Phi^T v> with both sides on the fused kernels."""
    spec = sk.make_sketch_spec(n, 0.1, chunk=chunk, mode=mode)
    x = jax.random.normal(jax.random.key(3), (n,))
    v = jax.random.normal(jax.random.key(4), (spec.m,))
    lhs = jnp.vdot(sk.sketch_forward(spec, x, impl="pallas"), v)
    rhs = jnp.vdot(x, sk.sketch_adjoint(spec, v, impl="pallas"))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3)


def test_sketch_forward_packed_matches_pack_of_forward():
    spec = sk.make_sketch_spec(2048, 0.25, chunk=512, mode="chunked")
    assert spec.m_chunk % 32 == 0
    x = jax.random.normal(jax.random.key(5), (spec.n,))
    z = sk.sketch_forward_2d(spec, x, impl="ref")
    for impl in ("ref", "pallas"):
        got = sk.sketch_forward_packed(spec, x, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.pack_ref(z)))


# -- custom VJP ---------------------------------------------------------------

def test_custom_vjp_matches_autodiff_on_client_objective():
    """grad of the full smoothed client objective (Eq. 6): hand-written
    adjoint VJP vs autodiff through the staged (no-custom-VJP) pipeline."""
    spec = sk.make_sketch_spec(500, 0.2, chunk=128)
    gamma, lam, mu = 500.0, 5e-4, 1e-5
    w0 = jax.random.normal(jax.random.key(6), (spec.n,))
    tgt = jax.random.normal(jax.random.key(7), (spec.n,))
    v = jnp.sign(jax.random.normal(jax.random.key(8), (spec.m,)))

    def objective(fwd):
        def f(w):
            task = 0.5 * jnp.sum((w - tgt) ** 2)
            z = fwd(spec, w)
            return task + lam * reg.smoothed_reg(v, z, gamma) + 0.5 * mu * jnp.sum(w * w)
        return f

    g_vjp = jax.grad(objective(sk.sketch_forward))(w0)
    g_auto = jax.grad(objective(sk.sketch_forward_staged))(w0)
    np.testing.assert_allclose(g_vjp, g_auto, rtol=1e-4, atol=1e-6)


def test_custom_vjp_under_vmap():
    spec = sk.make_sketch_spec(300, 0.2, chunk=128)
    v = jnp.sign(jax.random.normal(jax.random.key(9), (spec.m,)))
    W = jax.random.normal(jax.random.key(10), (4, spec.n))
    f = lambda w: reg.smoothed_reg(v, sk.sketch_forward(spec, w), 100.0)
    got = jax.vmap(jax.grad(f))(W)
    want = jax.vmap(jax.grad(lambda w: reg.smoothed_reg(
        v, sk.sketch_forward_staged(spec, w), 100.0)))(W)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


# -- ops padding paths --------------------------------------------------------

@pytest.mark.parametrize("rows,words", [(3, 7), (5, 600), (1, 130), (9, 513)])
def test_pack_unpack_pallas_arbitrary_shapes(rows, words):
    """The Pallas pack path pads internally — no silent ref fallback for
    rows % 8 != 0 or unaligned word counts."""
    x = jax.random.normal(jax.random.key(rows * 1000 + words), (rows, words * 32))
    np.testing.assert_array_equal(
        kops.pack_signs(x, impl="pallas"), ref.pack_ref(x)
    )
    w = ref.pack_ref(x)
    np.testing.assert_allclose(
        kops.unpack_signs(w, impl="pallas"), ref.unpack_ref(w)
    )


def test_vote_packed_pallas_arbitrary_width():
    z = jnp.sign(jax.random.normal(jax.random.key(11), (5, 300 * 32)))
    z = jnp.where(z == 0, 1.0, z)
    p = jnp.arange(1, 6, dtype=jnp.float32) / 15.0
    packed = ref.pack_ref(z)
    np.testing.assert_array_equal(
        kops.vote_packed(packed, p, impl="pallas"), ref.vote_ref(packed, p)
    )
