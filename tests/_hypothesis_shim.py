"""Optional-import shim for hypothesis.

The property tests use hypothesis when it is installed; on hosts without it
the suite must still *collect* cleanly (the container image does not ship
hypothesis). Importing ``given``/``settings``/``hst`` from here instead of
from hypothesis directly turns each property test into an explicit skip when
the dependency is absent, while every plain test in the module keeps running.

The CI matrix has one leg that installs hypothesis (ci.yml `extras`), so
the property tests run somewhere on every push. That leg also sets
``REQUIRE_HYPOTHESIS=1``: if the install silently drops out of the image,
this module hard-fails at import instead of quietly skipping everything —
the leg reports 0 hypothesis skips by construction.
"""
from __future__ import annotations

import os

import pytest

try:
    from hypothesis import given, settings, strategies as hst  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        raise RuntimeError(
            "REQUIRE_HYPOTHESIS is set but hypothesis is not installed — "
            "this environment promised to RUN the property tests, not skip "
            "them (see .github/workflows/ci.yml, extras leg)"
        )

    def given(*_args, **_kwargs):
        def deco(fn):
            # NB: do not functools.wraps here — copying the wrapped signature
            # makes pytest treat the strategy kwargs as fixtures.
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stub mirroring the strategies used in this test suite."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None

            return strategy

    hst = _Strategies()
