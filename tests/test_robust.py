"""Robustness tier: Byzantine injection, robust votes, RR privacy
(DESIGN.md §10).

Contracts pinned here:
  * Seed-determinism of the adversary axis: the byzantine mask is a pure
    function of (seed, K, fraction) with exactly round(fraction*K) members,
    and injection lands identically in the fused, sharded and async
    executors — the robust round is bit-exact across all three for every
    defense x privacy combination (the §6/§9 parity contracts survive the
    robustness axes).
  * Sign quantization provably neutralizes magnitude garbage:
    sign(c * z) == sign(z) for any c > 0 (hypothesis property + engine-level
    bit-exactness of the full state).
  * The trimmed vote zeroes a planted sign-flipper's weight; the
    reputation EMA decays it geometrically; reputations stay in [0, 1] and
    finite under ANY adversarial sign history (hypothesis property).
  * Randomized response flips deterministically per (seed, round, client)
    at the calibrated rate q = 1/(1+e^eps), and the debias factor is
    1/tanh(eps/2).
  * The packed trimmed vote (XOR-popcount Hamming ranking) matches the
    float trimmed vote when no exact vote tie exists; hamming_packed
    matches the numpy popcount oracle on both impls.
  * One bit is one bit: attack, defense and privacy leave the billed
    uplink/downlink bits unchanged.
  * Baselines refuse the adversary/privacy axes loudly (exp/runner.py) —
    they have no one-bit vote to corrupt or defend.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, hst

from repro.core import consensus as cons
from repro.core import rounds
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.exp import scenarios
from repro.kernels import ops as kops
from repro.models import smallnets as sn

K, S, R = 6, 6, 2


@pytest.fixture(scope="module")
def task():
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=K, train_per_client=48,
        test_per_client=24,
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=16)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    return data, loss_fn, init_fn, template


def _engine(task, **over):
    data, loss_fn, init_fn, template = task
    cfg = PFed1BSConfig(**{
        "num_clients": K, "participate": S, "local_steps": R,
        "m_ratio": 0.05, "chunk": 2048, **over,
    })
    return PFed1BS(cfg, loss_fn, template), data, init_fn


def _run(eng, data, init_fn, rounds_=3):
    state = eng.init(init_fn, jax.random.key(2))
    metrics = None
    for r in range(rounds_):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), r))
        batches = ds.sample_round_batches(kb, data, R, 16)
        state, metrics = eng.round(state, batches, data.weights, kr)
    return state, metrics


def _tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# byzantine membership + injection primitives
# ---------------------------------------------------------------------------

def test_byzantine_mask_deterministic_and_counted():
    for frac, want in ((0.0, 0), (0.2, 1), (0.25, 2), (0.5, 3), (1.0, 6)):
        m1 = np.asarray(rounds.byzantine_mask(7, 6, frac))
        m2 = np.asarray(rounds.byzantine_mask(7, 6, frac))
        np.testing.assert_array_equal(m1, m2)       # pure in the seed
        assert m1.sum() == want, (frac, m1)
        assert set(np.unique(m1)) <= {0.0, 1.0}
    # different seeds place the same count differently (some seed pair must)
    masks = {tuple(np.asarray(rounds.byzantine_mask(s, 6, 0.5))) for s in range(8)}
    assert len(masks) > 1


@settings(max_examples=25, deadline=None)
@given(
    seed=hst.integers(min_value=0, max_value=2 ** 30),
    scale=hst.floats(min_value=1e-6, max_value=1e6, allow_nan=False,
                     allow_infinity=False),
)
def test_scaled_garbage_neutralized_property(seed, scale):
    """S2: sign(scale * z) == sign(z) for ANY scale > 0 — the magnitude
    attack is bit-exactly erased by the one-bit quantizer, whatever the
    scale and whoever the byzantine clients are."""
    rng = np.random.RandomState(seed)
    zs = jnp.asarray(rng.randn(5, 64).astype(np.float32))
    byz = jnp.asarray((rng.rand(5) < 0.5).astype(np.float32))
    corrupted = rounds.corrupt_scaled(zs, byz, float(scale))
    np.testing.assert_array_equal(
        np.asarray(jnp.sign(corrupted)), np.asarray(jnp.sign(zs))
    )


@settings(max_examples=25, deadline=None)
@given(
    seed=hst.integers(min_value=0, max_value=2 ** 30),
    beta=hst.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    rounds_=hst.integers(min_value=1, max_value=6),
)
def test_reputation_bounds_under_adversarial_history(seed, beta, rounds_):
    """S2: reputations stay in [0, 1] and finite under ANY sign history —
    the EMA of [0,1] agreements can never escape the interval, no matter
    how adversarial the votes or how partial the participation."""
    rng = np.random.RandomState(seed)
    rep = jnp.ones((5,))
    for _ in range(rounds_):
        zs = jnp.asarray(np.sign(rng.randn(5, 32)).astype(np.float32))
        p = jnp.asarray((rng.rand(5) * (rng.rand(5) < 0.8)).astype(np.float32))
        _, rep = cons.reputation_vote(zs, p, rep, float(beta))
        r = np.asarray(rep)
        assert np.isfinite(r).all()
        assert (r >= 0.0).all() and (r <= 1.0).all(), r


def test_rr_flip_deterministic_and_calibrated():
    eps = 1.0
    signs = jnp.ones((4, 4096), jnp.float32)
    idx = jnp.arange(4)
    a = np.asarray(rounds.rr_flip(signs, idx, jnp.int32(3), 0, eps))
    b = np.asarray(rounds.rr_flip(signs, idx, jnp.int32(3), 0, eps))
    np.testing.assert_array_equal(a, b)             # pure in (seed, rnd, id)
    c = np.asarray(rounds.rr_flip(signs, idx, jnp.int32(4), 0, eps))
    assert not np.array_equal(a, c)                 # round changes the stream
    q = rounds.rr_flip_probability(eps)
    assert abs(np.mean(a < 0) - q) < 0.02           # empirical rate ~ q
    assert np.isclose(q, 1.0 / (1.0 + np.e))
    assert np.isclose(rounds.rr_debias(eps), 1.0 / np.tanh(0.5))
    # LDP constraint: keep/flip odds are exactly e^eps
    assert np.isclose((1 - q) / q, np.e ** eps)


# ---------------------------------------------------------------------------
# robust votes
# ---------------------------------------------------------------------------

def _planted(flippers, m=96, k=7, seed=0):
    """k voters: honest ones share a base consensus + light noise, the
    `flippers` transmit its exact negation."""
    rng = np.random.RandomState(seed)
    base = np.sign(rng.randn(m)).astype(np.float32)
    zs = np.tile(base, (k, 1))
    noise = rng.rand(k, m) < 0.1
    zs = np.where(noise, -zs, zs)
    for f in flippers:
        zs[f] = -base
    return jnp.asarray(zs), jnp.asarray(base)


def test_trimmed_vote_drops_planted_flipper():
    zs, base = _planted([2])
    p = jnp.full((7,), 1.0 / 7)
    v, kept = cons.trimmed_vote(zs, p, trim=1)
    assert float(kept[2]) == 0.0                    # the flipper is trimmed
    assert float(jnp.sum(kept > 0)) == 6.0
    # the 6 kept voters are honest-but-noisy; their vote tracks the base
    # consensus closely (exactness is not claimed: 10% per-voter noise can
    # outvote a coordinate)
    assert float(jnp.mean((v == base).astype(jnp.float32))) > 0.9


def test_trimmed_vote_never_trims_to_empty():
    zs, _ = _planted([0])
    p = jnp.zeros((7,)).at[3].set(1.0)              # a single voter
    v, kept = cons.trimmed_vote(zs, p, trim=5)      # trim clamps to voters-1
    assert float(jnp.sum(kept > 0)) == 1.0
    assert float(kept[3]) > 0.0


def test_reputation_vote_decays_flipper_geometrically():
    zs, _ = _planted([1])
    p = jnp.full((7,), 1.0 / 7)
    rep = jnp.ones((7,))
    for _ in range(5):
        _, rep = cons.reputation_vote(zs, p, rep, beta=0.5)
    r = np.asarray(rep)
    assert r[1] < 0.2                               # flipper decayed
    assert (np.delete(r, 1) > 0.7).all()            # honest voters retained


def test_packed_trimmed_matches_float_trimmed():
    """No exact vote ties -> the XOR-popcount Hamming ranking and the float
    disagreement ranking pick the same voters and the same consensus."""
    rng = np.random.RandomState(3)
    zs = np.sign(rng.randn(7, 128)).astype(np.float32)   # odd K: no ref tie
    zs[zs == 0] = 1.0
    p = (rng.rand(7) + 0.1).astype(np.float32)
    v_f, _ = cons.trimmed_vote(jnp.asarray(zs), jnp.asarray(p), trim=2)
    words = kops.pack_signs(jnp.asarray(zs))
    v_p = kops.unpack_signs(cons.trimmed_vote_packed(words, jnp.asarray(p), 2))
    np.testing.assert_array_equal(np.asarray(v_f), np.asarray(v_p)[:128])


def test_hamming_packed_matches_popcount_oracle():
    rng = np.random.default_rng(5)
    words = jnp.asarray(rng.integers(0, 2 ** 32, size=(9, 33), dtype=np.uint32))
    ref = jnp.asarray(rng.integers(0, 2 ** 32, size=(33,), dtype=np.uint32))
    want = np.asarray([
        sum(int(a ^ b).bit_count() for a, b in zip(row, np.asarray(ref)))
        for row in np.asarray(words)
    ])
    for impl in ("ref", "pallas"):
        got = np.asarray(kops.hamming_packed(words, ref, impl=impl))
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# engine-level: injection, neutralization, billing
# ---------------------------------------------------------------------------

def test_engine_scaled_garbage_bit_exact_with_honest(task):
    eng_h, data, init_fn = _engine(task)
    eng_g, _, _ = _engine(
        task, adversary=scenarios.ScaledGarbage(0.5, scale=1e6, seed=4)
    )
    st_h, m_h = _run(eng_h, data, init_fn)
    st_g, m_g = _run(eng_g, data, init_fn)
    np.testing.assert_array_equal(np.asarray(st_h.v), np.asarray(st_g.v))
    _tree_eq(st_h.clients, st_g.clients)
    assert float(m_h["task_loss"]) == float(m_g["task_loss"])


def test_engine_sign_flip_perturbs_the_round(task):
    eng_h, data, init_fn = _engine(task)
    eng_a, _, _ = _engine(task, adversary=scenarios.SignFlipAttack(0.5, seed=4))
    st_h, _ = _run(eng_h, data, init_fn)
    st_a, _ = _run(eng_a, data, init_fn)
    assert not np.array_equal(np.asarray(st_h.v), np.asarray(st_a.v))


def test_billing_is_attack_defense_privacy_invariant(task):
    """One bit is one bit: the robustness axes change nothing at the wire."""
    eng_h, data, init_fn = _engine(task)
    _, m_h = _run(eng_h, data, init_fn, rounds_=1)
    eng_r, _, _ = _engine(
        task, adversary=scenarios.SignFlipAttack(0.25, seed=1),
        privacy=scenarios.RandomizedResponse(1.5), defense="trim",
    )
    _, m_r = _run(eng_r, data, init_fn, rounds_=1)
    for k in ("uplink_bits", "downlink_bits"):
        assert int(m_h[k]) == int(m_r[k])


def test_runner_refuses_adversary_on_baselines(task):
    from repro.exp import runner

    data, loss_fn, _, template = task
    scen = scenarios.Scenario(
        "x", scenarios.DirichletPartition(0.3), scenarios.FullParticipation(),
        adversary=scenarios.SignFlipAttack(0.2),
    )
    cfg = runner.ExpConfig(num_clients=K)
    with pytest.raises(ValueError, match="one-bit-vote semantics"):
        runner.build_engine("fedavg", cfg, K, loss_fn, template, scenario=scen)
    with pytest.raises(ValueError, match="defense"):
        runner.build_engine(
            "obda", dataclasses.replace(cfg, defense="trim"), K, loss_fn,
            template,
        )


# ---------------------------------------------------------------------------
# S3: seed-deterministic injection across the three executors
# ---------------------------------------------------------------------------

ROBUST_AXES = dict(
    adversary=scenarios.SignFlipAttack(0.25, seed=3),
    privacy=scenarios.RandomizedResponse(1.5),
    trim_frac=0.2, rep_beta=0.5,
)


@pytest.mark.parametrize("defense", ["none", "trim", "reputation"])
def test_fused_vs_sharded_bit_exact_under_attack(task, defense):
    """The §6 one-device-mesh parity contract survives the robustness axes:
    corruption + RR flips + defended vote land identically in the fused and
    shard_map executors (same mask, same flip stream, same vote program)."""
    eng_f, data, init_fn = _engine(task, defense=defense, **ROBUST_AXES)
    eng_s, _, _ = _engine(
        task, defense=defense, sharded_round=True, **ROBUST_AXES
    )
    st_f, m_f = _run(eng_f, data, init_fn)
    st_s, m_s = _run(eng_s, data, init_fn)
    np.testing.assert_array_equal(np.asarray(st_f.v), np.asarray(st_s.v))
    _tree_eq(st_f.clients, st_s.clients)
    np.testing.assert_array_equal(np.asarray(st_f.rep), np.asarray(st_s.rep))
    assert float(m_f["task_loss"]) == float(m_s["task_loss"])


@pytest.mark.parametrize("defense", ["none", "reputation"])
def test_async_drain_bit_exact_under_attack(task, defense):
    """The §9 keystone parity contract survives the robustness axes: a
    zero-latency full drain (B=S, p=0) reproduces the synchronous robust
    rounds bit-for-bit, reputation state included — the async tier keys
    corruption and RR by the download version, which at zero staleness IS
    the sync round counter."""
    from repro.sim import clock as simclock
    from repro.sim.server import AsyncConfig, AsyncSimulator

    eng, data, init_fn = _engine(task, defense=defense, **ROBUST_AXES)
    participants_fn = lambda v: rounds.draw_participants(
        jax.random.fold_in(jax.random.key(7), v), K, S, None
    )
    batch_fn = lambda v: ds.sample_round_batches(
        jax.random.fold_in(jax.random.key(9), v), data, R, 16
    )

    st_sync = eng.init(init_fn, jax.random.key(2))
    for r in range(3):
        st_sync, _ = eng.round(
            st_sync, batch_fn(r), data.weights, jax.random.key(0),
            participants_fn(r),
        )

    sim = AsyncSimulator(
        eng,
        AsyncConfig(buffer_size=S, staleness_exponent=0.0, max_versions=3,
                    latency=simclock.ConstantLatency(0.0)),
        data.weights, participants_fn, batch_fn,
    )
    st_async, rep = sim.run(eng.init(init_fn, jax.random.key(2)))

    np.testing.assert_array_equal(np.asarray(st_sync.v), np.asarray(st_async.v))
    _tree_eq(st_sync.clients, st_async.clients)
    np.testing.assert_array_equal(
        np.asarray(st_sync.rep), np.asarray(st_async.rep)
    )
    if defense == "reputation":
        assert rep.final_reputation is not None
        np.testing.assert_allclose(
            np.asarray(rep.final_reputation), np.asarray(st_async.rep)
        )


# ---------------------------------------------------------------------------
# the documented residue (§10.2): a COHERENT colluding bloc defeats the
# disagreement-ranked trimmed vote
# ---------------------------------------------------------------------------

@pytest.mark.xfail(
    strict=True,
    reason="§10.2 residue: a coherent colluding bloc votes as one unit, so "
    "it drags the head-count provisional consensus toward its target and "
    "then scores LOWER disagreement than the honest-but-heterogeneous "
    "voters — the ranking trims honest clients even when the trim budget "
    "equals the bloc size. Disagreement ranking cannot separate 'coherent "
    "because colluding' from 'coherent because correct'; fixing this needs "
    "a different statistic (e.g. inter-voter agreement clustering), not a "
    "bigger budget.",
)
def test_trimmed_vote_defeats_coherent_colluding_bloc():
    """What a sound defense would deliver — and this one, by construction,
    cannot: with 4-of-10 coherent colluders and trim budget 4, the
    defended vote should land near the honest-only majority."""
    rng = np.random.default_rng(42)
    m, k, bloc = 256, 10, 4
    h = rng.choice([-1.0, 1.0], size=m).astype(np.float32)
    # honest voters: h with 40% independent coordinate noise (the paper's
    # heterogeneous-client regime — individually far from consensus)
    honest = np.stack(
        [np.where(rng.random(m) < 0.4, -h, h) for _ in range(k - bloc)]
    )
    # the bloc transmits ONE crafted sketch: the exact anti-consensus
    zs = np.concatenate([honest, np.tile(-h, (bloc, 1))]).astype(np.float32)
    p = np.full((k,), 1.0 / k, np.float32)

    v, kept = cons.trimmed_vote(jnp.asarray(zs), jnp.asarray(p), trim=bloc)
    v, kept = np.asarray(v), np.asarray(kept)
    honest_majority = np.sign(honest.sum(axis=0))

    # a sound defense keeps a majority of honest voters ...
    assert int((kept[:k - bloc] > 0).sum()) > int((kept[k - bloc:] > 0).sum())
    # ... and recovers the honest-only consensus (measured: ~0.19 — the
    # trimmed vote returns the BLOC's target almost everywhere)
    assert float(np.mean(v * honest_majority > 0)) > 0.8
