"""fed_lm: federating a real models/lm.py transformer through streamed
per-leaf sketching (DESIGN.md §13).

Contracts pinned here:
  * subset selection (core/subset.py): substring patterns resolve in
    template leaf order, extract/merge round-trips, size accounting.
  * a path-filtered TreeSketchSpec keeps full-template seeds: selecting
    every path rebuilds the identical spec, and each filtered entry uses
    exactly the operator the full spec gave that leaf.
  * the streamed encode (core/stream.py) is bit-exact with the
    materialized leaf-layout sketch, its measured peak EQUALS the
    closed-form O(max-layer + m) bound (never the 4n flat vector), and
    the decode mirror matches tree_sketch_adjoint leaf-for-leaf.
  * models/io.checkpoint_leaf_reader feeds the stream straight off a
    checkpoint/ckpt.py npz — full tree never resident.
  * a cfg.trainable engine updates ONLY the selected leaves (frozen
    leaves bit-identical across a round) and sizes its sketch from the
    trainable count.
  * make_fed_lm_engine's placed round on the default (1, 1) fed-model
    mesh is the same program as the unplaced fused round.
  * fl/comms.subset_round_bits bills every algorithm at n_trainable.
"""
import dataclasses
import functools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.core import stream, subset
from repro.core import treesketch as ts
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.fl import comms
from repro.launch import fedexec
from repro.models import io as mio
from repro.models import lm

TINY = dataclasses.replace(
    configs.get("granite-8b"), n_layers=2, d_model=64, n_heads=4, n_kv=2,
    head_dim=16, d_ff=128, vocab=256, name="granite-tiny",
)


@pytest.fixture(scope="module")
def template():
    return jax.eval_shape(
        functools.partial(lm.init_params, TINY), jax.random.PRNGKey(0)
    )


@pytest.fixture(scope="module")
def params():
    return lm.init_params(TINY, jax.random.PRNGKey(3))


def _lm_batches(arch, k, r, b, seq=32, seed=1):
    mk = lambda key: mio.make_batch(arch, key, b, seq)
    return jax.vmap(lambda key: jax.vmap(mk)(jax.random.split(key, r)))(
        jax.random.split(jax.random.PRNGKey(seed), k)
    )


# ---------------------------------------------------------------------------
# subset selection
# ---------------------------------------------------------------------------

def test_match_paths_substring_in_template_order(template):
    all_paths = [p for p, _ in subset.leaf_paths(template)]
    sel = subset.match_paths(template, ("attn",))
    assert sel and all("attn" in p for p in sel)
    assert list(sel) == [p for p in all_paths if "attn" in p]
    # pattern order does not reorder the selection
    two = subset.match_paths(template, ("head", "attn"))
    assert list(two) == [p for p in all_paths if "attn" in p or "head" in p]


def test_match_paths_unmatched_pattern_raises(template):
    with pytest.raises(ValueError, match="no_such_leaf"):
        subset.match_paths(template, ("attn", "no_such_leaf"))


def test_extract_merge_roundtrip(params):
    paths = subset.match_paths(params, ("attn",))
    sub = subset.extract(params, paths)
    assert set(sub) == set(paths)
    # merge(extract) is the identity
    back = subset.merge(params, sub)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # merging zeroed subset leaves zeroes exactly the selected leaves
    zeroed = subset.merge(params, {p: jnp.zeros_like(l) for p, l in sub.items()})
    for p, leaf in subset.leaf_paths(zeroed):
        if p in sub:
            assert not np.any(np.asarray(leaf))
        else:
            np.testing.assert_array_equal(
                np.asarray(leaf), np.asarray(dict(subset.leaf_paths(params))[p])
            )


def test_subset_size_counts_selected_leaves(template):
    paths = subset.match_paths(template, ("attn",))
    want = sum(
        int(np.prod(l.shape)) for p, l in subset.leaf_paths(template)
        if p in set(paths)
    )
    assert subset.subset_size(template, paths) == want > 0


# ---------------------------------------------------------------------------
# path-filtered spec keeps full-template seeds
# ---------------------------------------------------------------------------

def _entry_key(e):
    path, spec, off, major = e
    return (path, spec.seed, spec.n, spec.m, major)


def test_filtered_spec_selecting_all_is_identity(template):
    full = ts.make_tree_sketch_spec(template, 0.1, chunk=1024)
    every = tuple(p for p, _ in subset.leaf_paths(template))
    refilt = ts.make_tree_sketch_spec(template, 0.1, chunk=1024, paths=every)
    assert [_entry_key(e) for e in full.entries] == \
           [_entry_key(e) for e in refilt.entries]
    assert (full.n, full.m) == (refilt.n, refilt.m)


def test_filtered_spec_reuses_full_template_operator(template):
    full = ts.make_tree_sketch_spec(template, 0.1, chunk=1024)
    paths = subset.match_paths(template, ("attn",))
    filt = ts.make_tree_sketch_spec(template, 0.1, chunk=1024, paths=paths)
    by_path = {e[0]: e for e in full.entries}
    off = 0
    for e in filt.entries:
        assert _entry_key(e) == _entry_key(by_path[e[0]])  # same seed/geometry
        assert e[2] == off                                 # offsets repacked
        off += e[1].m
    assert filt.n == subset.subset_size(template, paths)
    assert filt.m == off < full.m


def test_empty_filter_raises(template):
    with pytest.raises(AssertionError):
        ts.make_tree_sketch_spec(template, 0.1, chunk=1024, paths=())


# ---------------------------------------------------------------------------
# streamed encode/decode
# ---------------------------------------------------------------------------

def test_stream_sketch_bit_exact_and_peak_is_closed_form(params):
    tspec = ts.make_tree_sketch_spec(params, 0.1, chunk=1024)
    materialized = np.asarray(
        jax.jit(lambda t: ts.flat_view(tspec, ts.tree_sketch_forward(tspec, t)))(
            params
        )
    )
    leaves = dict(subset.leaf_paths(params))
    meter = stream.MemMeter()
    streamed = stream.stream_sketch(tspec, leaves.__getitem__, meter=meter)
    np.testing.assert_array_equal(streamed, materialized)
    assert meter.peak == stream.stream_peak_bound(tspec)
    assert meter.peak < 4 * tspec.n       # never the flat vector
    assert meter.live == 0                # everything released


def test_stream_sketch_through_checkpoint_reader(params):
    """Full protocol: params -> npz on disk -> lazy per-leaf reads ->
    streamed sketch. Bit-exact with the in-memory streamed sketch."""
    tspec = ts.make_tree_sketch_spec(params, 0.1, chunk=1024)
    leaves = dict(subset.leaf_paths(params))
    want = stream.stream_sketch(tspec, leaves.__getitem__)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "c.npz")
        save_checkpoint(path, params)
        stored, get_leaf = mio.checkpoint_leaf_reader(path)
        assert set(stored) >= {p for p, *_ in tspec.entries}
        got = stream.stream_sketch(tspec, get_leaf, meter=stream.MemMeter())
    np.testing.assert_array_equal(got, want)


def test_subset_spec_streams_from_full_checkpoint(params):
    """A path-filtered spec only ever asks the reader for its own leaves,
    so a full checkpoint feeds a LoRA-subset stream unchanged."""
    paths = subset.match_paths(params, ("attn",))
    tspec = ts.make_tree_sketch_spec(params, 0.1, chunk=1024, paths=paths)
    asked = []
    leaves = dict(subset.leaf_paths(params))
    got = stream.stream_sketch(
        tspec, lambda p: (asked.append(p), leaves[p])[1]
    )
    assert set(asked) == set(paths)
    sub = subset.extract(params, paths)
    materialized = np.asarray(
        jax.jit(lambda t: ts.flat_view(tspec, ts.tree_sketch_forward(tspec, t)))(sub)
    )
    np.testing.assert_array_equal(got, materialized)


def test_stream_adjoint_matches_tree_sketch_adjoint(params, template):
    tspec = ts.make_tree_sketch_spec(params, 0.1, chunk=1024)
    v = np.random.default_rng(0).standard_normal(tspec.m).astype(np.float32)
    vdict = {
        path: jnp.asarray(v[off: off + spec.m].reshape(spec.num_chunks, spec.m_chunk))
        for path, spec, off, major in tspec.entries
    }
    want = ts.tree_sketch_adjoint(tspec, vdict, template)
    got = {}
    stream.stream_adjoint(tspec, v, template, lambda p, l: got.__setitem__(p, l))
    want_by_path = dict(subset.leaf_paths(want))
    assert set(got) == set(want_by_path)
    for p in got:
        np.testing.assert_array_equal(got[p], np.asarray(want_by_path[p]))


# ---------------------------------------------------------------------------
# subset engine + placed fed_lm round
# ---------------------------------------------------------------------------

def _fl_cfg(**kw):
    base = dict(num_clients=2, participate=2, local_steps=1, lr=0.02,
                m_ratio=0.1, chunk=4096, layout="leaf")
    return PFed1BSConfig(**{**base, **kw})


def test_subset_engine_trains_only_selected_leaves(template):
    eng = PFed1BS(
        _fl_cfg(trainable=("attn",)),
        lambda p, b: lm.loss_fn(TINY, p, b)[0],
        template,
    )
    assert eng.n_trainable == subset.subset_size(template, eng.trainable_paths)
    assert eng.n_trainable < eng.n
    state = eng.init(lambda k: lm.init_params(TINY, k), jax.random.PRNGKey(0))
    before = jax.tree.map(np.asarray, state.clients)
    batches = _lm_batches(TINY, 2, 1, 2)
    state, m = eng.round(state, batches, jnp.ones((2,)) / 2, jax.random.PRNGKey(5))
    assert int(m["uplink_bits"]) == 2 * eng.m
    frozen = moved = 0
    trainable = set(eng.trainable_paths)
    after = dict(subset.leaf_paths(state.clients))
    for path, leaf in subset.leaf_paths(before):
        if path in trainable:
            moved += int(not np.array_equal(np.asarray(after[path]), leaf))
        else:
            np.testing.assert_array_equal(np.asarray(after[path]), leaf)
            frozen += 1
    assert moved > 0 and frozen > 0


def test_fed_lm_placed_round_matches_unplaced(template):
    """On the default (1, 1) fed-model mesh, NamedSharding placement is a
    layout annotation — the placed round must be the identical program."""
    eng, mesh, tmpl = fedexec.make_fed_lm_engine(TINY, _fl_cfg())
    assert dict(mesh.shape) == {"fed": 1, "model": 1}
    init_fn = lambda k: lm.init_params(TINY, k)
    state = eng.init(init_fn, jax.random.PRNGKey(0))
    sh = fedexec.fed_lm_shardings(TINY, tmpl, mesh)
    placed = fedexec.place_fed_lm_state(state, sh)
    batches = _lm_batches(TINY, 2, 1, 2)
    pbatches = fedexec.place_fed_lm_batches(batches, sh)
    w = jnp.ones((2,)) / 2
    st_p, m_p = eng.round(placed, pbatches, w, jax.random.PRNGKey(7))
    st_u, m_u = eng.round(state, batches, w, jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(st_p.v), np.asarray(st_u.v))
    for a, b in zip(jax.tree.leaves(st_p.clients), jax.tree.leaves(st_u.clients)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_p["task_loss"]) == float(m_u["task_loss"])


def test_trainable_requires_leaf_layout(template):
    with pytest.raises(AssertionError):
        PFed1BS(
            _fl_cfg(trainable=("attn",), layout="flat"),
            lambda p, b: lm.loss_fn(TINY, p, b)[0],
            template,
        )


# ---------------------------------------------------------------------------
# subset billing
# ---------------------------------------------------------------------------

def test_subset_round_bits_bills_at_trainable_count():
    n, m, s = 1_000_000, 50_000, 8
    for algo in ("pfed1bs", "fedavg", "obda"):
        sub = comms.subset_round_bits(
            algo, n_total=n, n_trainable=n // 4, m=m, s=s
        )
        at_sub = comms.round_bits(algo, n=n // 4, m=m, s=s)
        assert sub["uplink_bits"] == at_sub["uplink_bits"], algo
        assert sub["downlink_bits"] == at_sub["downlink_bits"], algo
    sub = comms.subset_round_bits("pfed1bs", n_total=n, n_trainable=n // 4,
                                  m=m, s=s)
    assert sub["n_total"] == n and sub["n_trainable"] == n // 4
    assert sub["trainable_fraction"] == 0.25
    # full tree is the round_bits identity (plus the bookkeeping keys)
    full = comms.subset_round_bits("pfed1bs", n_total=n, n_trainable=n,
                                   m=m, s=s)
    assert {k: v for k, v in full.items()
            if k not in ("n_total", "n_trainable", "trainable_fraction")} \
        == comms.round_bits("pfed1bs", n=n, m=m, s=s)


def test_subset_round_bits_rejects_bad_counts():
    with pytest.raises(AssertionError):
        comms.subset_round_bits("pfed1bs", n_total=10, n_trainable=0, m=4, s=2)
    with pytest.raises(AssertionError):
        comms.subset_round_bits("pfed1bs", n_total=10, n_trainable=11, m=4, s=2)
