"""SLO gates, federation health monitor, and flight recorder tests
(obs/slo.py, obs/health.py, obs/flight.py — DESIGN.md §14).

Covers the acceptance chain end to end at unit scale: objective math
(threshold + burn-rate windows), spec JSON round-trips, verdict schema
(validate_slo_verdict), the artifact-level CI gate's nonzero exit on
breach, health state transitions (warming/converging/plateau/diverging),
and the bounded flight ring whose breach snapshot must validate as a
FLIGHT_*.json.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.obs import slo as obsslo
from repro.obs.flight import _Ring
from repro.obs.health import HealthConfig, HealthMonitor


# ---------------------------------------------------------------------------
# threshold objectives
# ---------------------------------------------------------------------------

def test_threshold_objective_pass_and_breach():
    obj = obsslo.Objective("p99", "materialize_p99_ms", "<", 100.0)
    ok = obj.evaluate({"materialize_p99_ms": 42.0})
    assert ok["ok"] and ok["observed"] == 42.0
    bad = obj.evaluate({"materialize_p99_ms": 150.0})
    assert not bad["ok"]


def test_threshold_missing_metric_is_breach():
    """An SLO that silently passes because nobody emitted the metric is
    worse than a false alarm."""
    obj = obsslo.Objective("hit", "hit_rate", ">=", 0.2)
    r = obj.evaluate({})
    assert not r["ok"] and r["observed"] is None


def test_threshold_rejects_unknown_op():
    with pytest.raises(ValueError, match="unknown op"):
        obsslo.Objective("x", "m", "!=", 1.0)


# ---------------------------------------------------------------------------
# burn-rate objectives
# ---------------------------------------------------------------------------

def _burn(windows=(10.0, 100.0), max_burn=2.0, target=0.9, threshold=5.0):
    return obsslo.BurnRateObjective(
        "burn", "lat_ms", threshold=threshold, target=target,
        windows_s=tuple(windows), max_burn=max_burn,
    )


def test_burn_rate_math():
    """bad_fraction / (1 - target): 2 bad of 4 events at target 0.9 is
    0.5 / 0.1 = burn 5."""
    obj = _burn(windows=(100.0,))
    events = [(1.0, 1.0), (2.0, 9.0), (3.0, 1.0), (4.0, 9.0)]
    assert obj.burn_rates(events, now=5.0) == [pytest.approx(5.0)]


def test_burn_rate_window_filters_old_events():
    obj = _burn(windows=(10.0,))
    # the only bad event is 50s old — outside the 10s window
    events = [(0.0, 99.0), (55.0, 1.0), (58.0, 1.0)]
    assert obj.burn_rates(events, now=60.0) == [0.0]


def test_burn_rate_empty_window_burns_zero():
    obj = _burn()
    r = obj.evaluate([], now=0.0)
    assert r["ok"] and r["observed"] == 0.0


def test_burn_rate_breach_needs_every_window():
    """Multi-window alerting: the short window proves the problem is
    current, the long one that it is not a blip — a breach needs both."""
    obj = _burn(windows=(10.0, 1000.0), max_burn=2.0)
    # all-bad burst in the last 10s, but 100 old good events dilute the
    # long window below max_burn -> NOT a breach
    events = [(float(t), 1.0) for t in range(100)] + \
             [(995.0 + i, 9.0) for i in range(5)]
    r = obj.evaluate(events, now=1000.0)
    rates = r["burn_rates"]
    assert rates[0] > 2.0 and rates[1] < 2.0
    assert r["ok"]
    # sustained badness: both windows over -> breach
    bad = [(990.0 + i, 9.0) for i in range(10)]
    assert not obj.evaluate(bad, now=1000.0)["ok"]


def test_burn_rate_validates_config():
    with pytest.raises(ValueError, match="target"):
        _burn(target=1.5)
    with pytest.raises(ValueError, match="window"):
        _burn(windows=())


# ---------------------------------------------------------------------------
# spec round-trip + verdict schema
# ---------------------------------------------------------------------------

def _spec():
    return obsslo.SLOSpec.from_dict({
        "name": "t",
        "objectives": [
            {"kind": "threshold", "name": "p99", "metric": "p99_ms",
             "op": "<", "threshold": 100.0},
            {"kind": "burn_rate", "name": "burn", "metric": "lat_ms",
             "threshold": 5.0, "target": 0.9, "windows_s": [10.0],
             "max_burn": 2.0},
        ],
    })


def test_spec_dict_roundtrip(tmp_path):
    spec = _spec()
    again = obsslo.SLOSpec.from_dict(spec.to_dict())
    assert again == spec
    p = tmp_path / "spec.json"
    p.write_text(json.dumps(spec.to_dict()))
    assert obsslo.SLOSpec.load(p) == spec


def test_spec_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown objective kind"):
        obsslo.SLOSpec.from_dict(
            {"name": "x", "objectives": [{"kind": "latency", "name": "a"}]}
        )


def test_evaluate_verdict_schema_and_breach_listing():
    spec = _spec()
    good = obsslo.evaluate(spec, {"p99_ms": 50.0}, events=[(0.0, 1.0)], now=1.0)
    assert good["ok"] and good["breaches"] == []
    obs.validate_slo_verdict(good)
    bad = obsslo.evaluate(spec, {"p99_ms": 500.0},
                          events=[(0.5, 9.0), (0.9, 9.0)], now=1.0)
    assert not bad["ok"]
    assert set(bad["breaches"]) == {"p99", "burn"}
    obs.validate_slo_verdict(bad)


def test_validate_slo_verdict_rejects_inconsistency():
    v = obsslo.evaluate(_spec(), {"p99_ms": 50.0})
    v["breaches"] = ["phantom"]           # ok=True but breaches non-empty
    with pytest.raises(ValueError, match="disagrees"):
        obs.validate_slo_verdict(v)
    v2 = obsslo.evaluate(_spec(), {"p99_ms": 500.0})
    v2["breaches"] = []                   # failing objective unaccounted
    v2["ok"] = True
    with pytest.raises(ValueError):
        obs.validate_slo_verdict(v2)


# ---------------------------------------------------------------------------
# artifact-level CI gate
# ---------------------------------------------------------------------------

def _artifact(p99=50.0, stored_burn=0.0):
    cell = {"p99_ms": p99, "slo": {"objectives": [
        {"name": "burn", "kind": "burn_rate", "observed": stored_burn},
    ]}}
    return {"stream": {"grid": {"16": dict(cell), "64": dict(cell)}}}


def test_evaluate_artifact_per_cell_and_prefixes():
    spec = _spec()
    good = obsslo.evaluate_artifact(spec, _artifact())
    assert good["ok"] and good["cells"] == {"16": True, "64": True}
    obs.validate_slo_verdict(good)
    bad = obsslo.evaluate_artifact(spec, _artifact(p99=900.0, stored_burn=7.0))
    assert not bad["ok"]
    assert "K=16:p99" in bad["breaches"] and "K=64:burn" in bad["breaches"]
    obs.validate_slo_verdict(bad)


def test_evaluate_artifact_requires_grid():
    with pytest.raises(ValueError, match="stream.grid"):
        obsslo.evaluate_artifact(_spec(), {})


def test_cli_gate_exits_nonzero_on_breach(tmp_path, capsys):
    spec_path = tmp_path / "spec.json"
    spec_path.write_text(json.dumps(_spec().to_dict()))
    good_path = tmp_path / "good.json"
    good_path.write_text(json.dumps(_artifact()))
    bad_path = tmp_path / "bad.json"
    bad_path.write_text(json.dumps(_artifact(p99=900.0)))
    assert obsslo.main([str(spec_path), "--artifact", str(good_path)]) == 0
    assert obsslo.main([str(spec_path), "--artifact", str(bad_path)]) == 1
    err = capsys.readouterr().err
    obs.validate_slo_verdict(json.loads(err))   # stderr carries the verdict


def test_committed_serve_spec_parses_and_is_wired():
    """The committed CI spec must load, and its threshold metrics must be
    fields the serving stream cells actually emit (engine.stats keys)."""
    spec = obsslo.SLOSpec.load("benchmarks/slo_serve.json")
    emitted = {"materialize_p99_ms", "hit_rate", "telemetry_bytes",
               "materialize_p50_ms", "tokens_per_sec"}
    for o in spec.objectives:
        if isinstance(o, obsslo.Objective):
            assert o.metric in emitted, o.metric


# ---------------------------------------------------------------------------
# health monitor state machine
# ---------------------------------------------------------------------------

def test_health_warming_then_converging():
    mon = HealthMonitor(HealthConfig(warmup=3))
    v = np.ones(50)
    mon.update(v=v)
    assert mon.status() == "warming"
    v2 = v.copy()
    v2[:5] = -1                               # 10% churn: healthy, not flat
    mon.update(v=v2)
    mon.update(v=v, ef_norm=1.0)
    assert mon.status() == "converging"
    assert mon.verdict()["ok"]


def test_health_plateau_on_low_churn():
    mon = HealthMonitor(HealthConfig(warmup=2, churn_plateau=0.02))
    v = np.ones(100)
    for _ in range(6):
        mon.update(v=v)                    # zero churn every round
    assert mon.status() == "plateau"
    rep = mon.verdict()
    assert rep["ok"] and rep["churn"]["mean_window"] == 0.0


def test_health_churn_alarm_diverges():
    mon = HealthMonitor(HealthConfig(warmup=2, churn_alarm=0.5))
    rng = np.random.default_rng(0)
    for _ in range(5):
        mon.update(v=rng.choice([-1, 1], size=64))   # ~50% churn
    mon.update(v=-mon._prev_v)                        # 100% churn
    assert mon.status() == "diverging"
    rep = mon.verdict()
    assert not rep["ok"] and "churn_alarm" in rep["alarms"]


def test_health_ef_divergence_alarm():
    mon = HealthMonitor(HealthConfig(warmup=2, ef_growth_alarm=1.5,
                                     churn_alarm=2.0))
    for i in range(8):
        mon.update(ef_norm=1.0 * (2.0 ** i))          # doubling residual
    rep = mon.verdict()
    assert "ef_divergence" in rep["alarms"]
    assert rep["status"] == "diverging" and not rep["ok"]
    assert rep["ef"]["trend"] > 1.5


def test_health_sketches_ride_margins_and_staleness():
    mon = HealthMonitor()
    mon.update(margins=np.array([0.1, -0.5, 0.9]), staleness=[1.0, 3.0])
    mon.update(margins=np.array([0.2, 0.4]), staleness=7.0)
    rep = mon.verdict()
    assert rep["margins"]["count"] == 5
    assert rep["margins"]["max"] == pytest.approx(0.9)  # abs() applied
    assert rep["staleness"]["count"] == 3
    json.dumps(rep)                                     # JSON-clean


def test_health_verdict_is_json_strict_even_with_zero_early_ef():
    mon = HealthMonitor(HealthConfig(warmup=1))
    for ef in (0.0, 0.0, 1.0, 1.0):
        mon.update(ef_norm=ef)
    rep = mon.verdict()
    json.dumps(rep, allow_nan=False)       # no inf/nan anywhere
    assert rep["ef"]["trend"] > 1.5        # maximal measurable growth


# ---------------------------------------------------------------------------
# flight recorder ring + snapshot
# ---------------------------------------------------------------------------

def test_ring_bounds_and_eviction_count():
    ring = _Ring(4)
    for i in range(10):
        ring.append(i)
    assert len(ring) == 4 and ring.total == 10 and ring.dropped == 6
    assert list(ring) == [6, 7, 8, 9]


def test_flight_recorder_memory_is_bounded():
    rec = obs.FlightRecorder(clock="virtual", capacity=8)
    for i in range(100):
        rec.complete(f"s{i}", float(i), float(i) + 0.5, track="t")
    assert len(rec.events) <= 8
    assert rec.dropped == rec.events.total - len(rec.events) > 0


def test_counter_totals_exact_despite_eviction(tmp_path):
    rec = obs.FlightRecorder(clock="virtual", capacity=4)
    for i in range(50):
        rec.count("uplink_bits", 10, t=float(i))
    assert rec.counter_totals["uplink_bits"] == 500
    snap = rec.snapshot(tmp_path / "f.json", reason="manual")
    assert snap["counterTotals"]["uplink_bits"] == 500
    # surviving samples are the most recent -> still monotone
    samples = [e["args"]["value"] for e in rec.events if e.get("ph") == "C"]
    assert samples == sorted(samples) and samples[-1] == 500


def test_maybe_snapshot_none_when_healthy(tmp_path):
    rec = obs.FlightRecorder(clock="virtual")
    path = tmp_path / "FLIGHT_x.json"
    out = obs.maybe_snapshot(rec, path, slo_verdict={"ok": True},
                             health={"ok": True})
    assert out is None and not path.exists()


def test_breach_snapshot_is_schema_valid(tmp_path):
    rec = obs.FlightRecorder(clock="virtual", capacity=16)
    for i in range(30):                       # overflow the ring
        rec.complete("materialize", i * 1.0, i * 1.0 + 0.5, track="serve")
    verdict = obsslo.evaluate(_spec(), {"p99_ms": 900.0})
    assert not verdict["ok"]
    path = tmp_path / "FLIGHT_serve.json"
    written = obs.maybe_snapshot(
        rec, path, slo_verdict=verdict,
        health={"ok": False, "status": "diverging"},
        meta={"bench": "serve"},
    )
    assert written["flight"]["reason"] == "slo_breach+health_alarm"
    loaded = json.loads(path.read_text())
    info = obs.validate_flight(loaded)
    assert info["dropped"] > 0
    assert loaded["flight"]["capacity"] == 16
    assert loaded["slo_verdict"]["breaches"] == ["p99"]
    assert loaded["bench"] == "serve"


def test_validate_flight_rejects_overfull_and_missing_block(tmp_path):
    rec = obs.FlightRecorder(clock="virtual", capacity=4)
    rec.complete("a", 0.0, 1.0, track="t")
    snap = rec.snapshot(tmp_path / "f2.json", reason="manual")
    obs.validate_flight(snap)
    bad = dict(snap)
    bad["flight"] = dict(snap["flight"], capacity=0)
    with pytest.raises(ValueError, match="capacity"):
        obs.validate_flight(bad)
    nof = {k: v for k, v in snap.items() if k != "flight"}
    with pytest.raises(ValueError, match="flight block"):
        obs.validate_flight(nof)
    over = dict(snap)
    over["flight"] = dict(snap["flight"], capacity=1)
    over["traceEvents"] = snap["traceEvents"] + snap["traceEvents"]
    with pytest.raises(ValueError, match="claims"):
        obs.validate_flight(over)


def test_flight_rejects_capacity_zero():
    with pytest.raises(ValueError, match="capacity"):
        obs.FlightRecorder(capacity=0)
