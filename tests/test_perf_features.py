"""§Perf optimizations preserve semantics: sequence-parallel attention,
packed cross-pod vote, grouped MoE dispatch."""
import dataclasses

import jax
import pytest
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as st
from repro.launch.mesh import make_debug_mesh
from repro.models import io, layers as L, lm


def test_seq_attention_constraints_preserve_values():
    """attn_shard='seq' only adds sharding constraints — same numbers."""
    cfg_auto = configs.get("starcoder2-7b").reduced()
    cfg_seq = dataclasses.replace(cfg_auto, attn_shard="seq")
    p = L.init_attention(jax.random.key(0), cfg_auto)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg_auto.d_model))
    pos = jnp.arange(32)
    mesh = make_debug_mesh()
    with mesh:
        ya = jax.jit(lambda: L.attention(p, cfg_auto, x, pos))()
        ys = jax.jit(lambda: L.attention(p, cfg_seq, x, pos))()
    np.testing.assert_allclose(np.asarray(ya), np.asarray(ys), rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_packed_vote_matches_f32_vote():
    """The shard_map packed vote computes the same consensus as the f32
    einsum vote (ties broken to +1 in both paths here: weights irrational)."""
    cfg = configs.get("granite-8b").reduced()
    mesh = make_debug_mesh(shape=(1, 1, 1), axes=("pod", "data", "model"))
    hyper = st.StepHyper(chunk=1024)
    with mesh:
        step_f32, tspec = st.make_round_step(
            cfg, dataclasses.replace(hyper, packed_vote=False), mesh, 1
        )
        step_packed, _ = st.make_round_step(
            cfg, dataclasses.replace(hyper, packed_vote=True), mesh, 1
        )
        params = jax.vmap(lambda k: lm.init_params(cfg, k))(
            jax.random.split(jax.random.key(0), 1)
        )
        batch = jax.tree.map(
            lambda a: a[None],
            io.make_batch(cfg, jax.random.key(1), 2, 32),
        )
        from repro.core import treesketch as ts

        v0 = ts.zeros_like_sketch(tspec)
        w = jnp.array([1.0])
        _, v_f32, loss1 = jax.jit(step_f32)(params, batch, v0, w)
        _, v_packed, loss2 = jax.jit(step_packed)(params, batch, v0, w)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-5)
    for k in v_f32:
        a = np.asarray(v_f32[k])
        b = np.asarray(v_packed[k])
        # f32 vote keeps sign(0)=0; packed breaks ties to +1 — compare where
        # the f32 vote is decisive (ties have measure ~0 with real sketches)
        mask = a != 0
        np.testing.assert_array_equal(a[mask], b[mask])


@pytest.mark.slow
def test_round_step_executes_on_debug_mesh():
    """Concrete multi-client round: params move, consensus becomes +-1."""
    cfg = configs.get("granite-8b").reduced()
    mesh = make_debug_mesh(shape=(1, 1, 1), axes=("pod", "data", "model"))
    hyper = st.StepHyper(chunk=1024, lr=0.05)
    with mesh:
        step, tspec = st.make_round_step(cfg, hyper, mesh, 2)
        params = jax.vmap(lambda k: lm.init_params(cfg, k))(
            jax.random.split(jax.random.key(0), 2)
        )
        batch = jax.tree.map(
            lambda a: jnp.stack([a, a]),
            io.make_batch(cfg, jax.random.key(1), 2, 32),
        )
        from repro.core import treesketch as ts

        v0 = ts.zeros_like_sketch(tspec)
        w = jnp.array([0.5, 0.5])
        newp, v1, loss = jax.jit(step)(params, batch, v0, w)
    assert np.isfinite(float(loss))
    moved = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(newp))
    )
    assert moved > 0
    for k, vv in v1.items():
        assert set(np.unique(np.asarray(vv))) <= {-1.0, 0.0, 1.0}
