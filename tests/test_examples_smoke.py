"""Smoke tests for the runnable examples (slow tier).

The examples are user-facing entry points that no unit test imports, so
they can rot silently. Each test runs the example's real main path in a
subprocess (fresh jax, exactly what a user gets) with env-var-shrunk
problem sizes so the whole file stays in CI-able territory.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(script: str, env_overrides: dict, timeout: int = 480):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.update({k: str(v) for k, v in env_overrides.items()})
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        capture_output=True, text=True, timeout=timeout, cwd=REPO, env=env,
    )
    assert res.returncode == 0, (
        f"{script} failed\n--- stdout ---\n{res.stdout[-3000:]}"
        f"\n--- stderr ---\n{res.stderr[-3000:]}"
    )
    return res.stdout


@pytest.mark.slow
def test_quickstart_runs():
    out = _run_example(
        "quickstart.py", {"QUICKSTART_ROUNDS": 2, "QUICKSTART_CLIENTS": 4}
    )
    # the example's own final summary lines must be reached
    assert "pFed1BS personalized accuracy" in out
    assert "FedAvg global accuracy" in out
    assert "per-round traffic" in out


@pytest.mark.slow
def test_scenario_sweep_runs():
    out = _run_example(
        "scenario_sweep.py",
        {"SWEEP_ALGOS": "fedavg,pfed1bs", "SWEEP_ROUNDS": 2, "SWEEP_CLIENTS": 4},
    )
    assert "### Scenario `dir0.1`" in out
    assert "### Scenario `straggler`" in out
    assert "accounting validated" in out


@pytest.mark.slow
def test_fl_llm_finetune_runs():
    out = _run_example(
        "fl_llm_finetune.py",
        {
            "FLLM_ROUNDS": 3, "FLLM_CLIENTS": 3, "FLLM_PARTICIPATE": 2,
            "FLLM_LAYERS": 2, "FLLM_D_MODEL": 64, "FLLM_HEADS": 4,
            "FLLM_KV_HEADS": 2, "FLLM_HEAD_DIM": 16, "FLLM_D_FF": 128,
            "FLLM_VOCAB": 512, "FLLM_SEQ": 32, "FLLM_BATCH": 2,
            "FLLM_CHUNK": 4096,
        },
    )
    assert "params per client" in out
    assert "sketch m=" in out
    assert "final CE" in out
    assert "checkpoints in experiments/runs/" in out


@pytest.mark.slow
def test_serve_personalized_runs():
    out = _run_example(
        "serve_personalized.py", {"SERVE_CLIENTS": 4, "SERVE_REQUESTS": 6}
    )
    assert "encoded 4 clients" in out
    assert "store round-tripped through checkpoint/ckpt.py" in out
    assert "served 6 requests" in out
    assert "materialized model sanity check passed" in out
