"""Sharded federation executor (launch/fedexec.py, DESIGN.md §6).

Contracts pinned here:
  * 1-device-mesh bit-exactness: the shard_map round at full participation
    reproduces the PR-1 fused round bit-for-bit (consensus, client params,
    EF residuals) with EF on and off.
  * Word-level popcount vote == the unpacked integer-count oracle, for odd
    and even K, on arbitrary word counts (incl. non-lane-aligned), and its
    tie semantics vs the float vote.
  * The wire-only path (diagnostics=False, the packed kernel epilogue)
    produces the identical state without the float diagnostics.
  * Multi-device executor (subprocess, slow): a 2-shard fed mesh runs and
    tracks the fused round closely (bit-exactness is only claimed for the
    1-device mesh — per-shard compilation may round differently).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import smallnets as sn


@pytest.fixture(scope="module")
def fed_setup():
    data = ds.make_federated_classification(
        jax.random.key(0), num_clients=6, train_per_client=96,
        test_per_client=48, noise=0.8,
    )

    def loss_fn(params, batch):
        return sn.softmax_xent(sn.apply_mlp(params, batch["x"]), batch["y"])

    def init_fn(k):
        return sn.init_mlp(k, input_dim=784, hidden=32)

    return data, loss_fn, init_fn


BASE = dict(num_clients=6, participate=6, local_steps=3, m_ratio=0.05,
            chunk=2048)


def _run(cfg, data, loss_fn, init_fn, rounds=3):
    template = jax.eval_shape(init_fn, jax.random.key(1))
    eng = PFed1BS(cfg, loss_fn, template)
    state = eng.init(init_fn, jax.random.key(2))
    metrics = None
    for r in range(rounds):
        kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), r))
        batches = ds.sample_round_batches(kb, data, cfg.local_steps, 24)
        state, metrics = eng.round(state, batches, data.weights, kr)
    return eng, state, metrics


# ---------------------------------------------------------------------------
# 1-device-mesh bit-exactness vs the fused round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["flat", "leaf"])
@pytest.mark.parametrize("error_feedback", [False, True])
def test_sharded_round_bit_exact_vs_fused(fed_setup, error_feedback, layout):
    data, loss_fn, init_fn = fed_setup
    cfg_sh = PFed1BSConfig(**BASE, error_feedback=error_feedback,
                           layout=layout, sharded_round=True)
    cfg_fu = dataclasses.replace(cfg_sh, sharded_round=False)
    _, st_sh, m_sh = _run(cfg_sh, data, loss_fn, init_fn)
    _, st_fu, m_fu = _run(cfg_fu, data, loss_fn, init_fn)
    np.testing.assert_array_equal(np.asarray(st_sh.v), np.asarray(st_fu.v))
    for a, b in zip(jax.tree.leaves(st_sh.clients), jax.tree.leaves(st_fu.clients)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    if error_feedback:
        np.testing.assert_array_equal(np.asarray(st_sh.ef), np.asarray(st_fu.ef))
    np.testing.assert_allclose(
        float(m_sh["potential"]), float(m_fu["potential"]), rtol=1e-6
    )
    np.testing.assert_allclose(
        float(m_sh["sign_agreement"]), float(m_fu["sign_agreement"]), rtol=1e-6
    )


def test_sharded_round_partial_participation(fed_setup):
    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(**{**BASE, "participate": 3}, sharded_round=True)
    eng, state, m = _run(cfg, data, loss_fn, init_fn, rounds=2)
    assert np.isfinite(float(m["task_loss"]))
    assert int(m["uplink_bits"]) == 3 * eng.m
    assert int(m["downlink_bits"]) == eng.m


def test_wire_only_path_matches_diagnostics_path(fed_setup):
    """diagnostics=False routes the uplink through the packed kernel
    epilogue and must produce the identical state; the float diagnostics
    simply disappear from the metrics dict."""
    data, loss_fn, init_fn = fed_setup
    cfg_d = PFed1BSConfig(**BASE, sharded_round=True)
    cfg_w = dataclasses.replace(cfg_d, diagnostics=False)
    _, st_d, m_d = _run(cfg_d, data, loss_fn, init_fn, rounds=2)
    _, st_w, m_w = _run(cfg_w, data, loss_fn, init_fn, rounds=2)
    np.testing.assert_array_equal(np.asarray(st_w.v), np.asarray(st_d.v))
    for a, b in zip(jax.tree.leaves(st_w.clients), jax.tree.leaves(st_d.clients)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert "potential" in m_d and "sign_agreement" in m_d
    assert "potential" not in m_w and "sign_agreement" not in m_w
    assert int(m_w["uplink_bits"]) == int(m_d["uplink_bits"])
    assert int(m_w["packed_words"]) == int(m_d["packed_words"])


def test_leaf_layout_staged_round_runs(fed_setup):
    """layout="leaf" must work in every executor, including the seed staged
    round (its potential re-sketches through the layout-aware path)."""
    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(**{**BASE, "local_steps": 1}, layout="leaf",
                        fused_round=False)
    _, state, m = _run(cfg, data, loss_fn, init_fn, rounds=1)
    assert np.isfinite(float(m["task_loss"]))
    assert np.isfinite(float(m["potential"]))


def test_ef_without_diagnostics_runs(fed_setup):
    """EF on + diagnostics off: residuals update, no float sketches leave
    the shard region beyond the EF rows, metrics carry no diagnostics."""
    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(**BASE, sharded_round=True, error_feedback=True,
                        diagnostics=False)
    _, state, m = _run(cfg, data, loss_fn, init_fn, rounds=2)
    assert np.isfinite(float(m["task_loss"]))
    assert "potential" not in m
    assert np.isfinite(np.asarray(state.ef)).all()
    assert float(jnp.sum(jnp.abs(state.ef))) > 0


def test_popcount_vote_round_runs(fed_setup):
    """vote="popcount" produces a {-1,+1} consensus and a working round."""
    data, loss_fn, init_fn = fed_setup
    cfg = PFed1BSConfig(**BASE, sharded_round=True, vote="popcount")
    _, state, m = _run(cfg, data, loss_fn, init_fn, rounds=2)
    assert np.isfinite(float(m["task_loss"]))
    vals = set(np.unique(np.asarray(state.v)))
    assert vals <= {-1.0, 1.0}, vals  # word-level vote never emits 0
    # the integer vote assumes uniform p_k; the metric confirms they were
    assert float(m["vote_uniform_ok"]) == 1.0


# ---------------------------------------------------------------------------
# word-level popcount vote vs the unpacked oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 3, 6, 7, 20, 33])
@pytest.mark.parametrize("w", [1, 5, 128, 200])
def test_popcount_vote_matches_unpacked_oracle(k, w):
    words = jnp.asarray(
        np.random.default_rng(k * 1000 + w).integers(
            0, 2 ** 32, size=(k, w), dtype=np.uint32
        )
    )
    # oracle: unpack to {0,1}, integer-count per position, threshold
    bits = np.asarray(kops.unpack_signs(words, impl="ref") > 0, np.int64)
    maj = (2 * bits.sum(axis=0) >= k).astype(np.float32) * 2 - 1
    got = np.asarray(kops.unpack_signs(kops.vote_popcount(words, impl="ref"),
                                       impl="ref"))
    np.testing.assert_array_equal(got, maj)
    # pallas (interpret) path agrees with the ref path bit-for-bit
    got_pl = np.asarray(kops.vote_popcount(words, impl="pallas"))
    np.testing.assert_array_equal(
        got_pl, np.asarray(kops.vote_popcount(words, impl="ref"))
    )


@pytest.mark.parametrize("k", [3, 7, 21])
def test_popcount_vote_matches_float_vote_odd_k(k):
    """For odd K and uniform weights no exact tie exists, so the integer
    popcount vote and the float vote_ref agree bit-for-bit."""
    words = jnp.asarray(
        np.random.default_rng(k).integers(0, 2 ** 32, size=(k, 64), dtype=np.uint32)
    )
    np.testing.assert_array_equal(
        np.asarray(kref.vote_popcount_ref(words)),
        np.asarray(kref.vote_ref(words, jnp.full((k,), 1.0 / k))),
    )


def test_popcount_vote_tie_semantics():
    """Even K, exact tie: the integer vote breaks to +1 deterministically
    (the float path's behavior at a tie depends on rounding of the p_k)."""
    w1 = jnp.asarray([[0xFFFFFFFF], [0x00000000]], dtype=jnp.uint32)
    out = np.asarray(kref.vote_popcount_ref(w1))
    assert out[0] == 0xFFFFFFFF  # 1 vs 1 per position -> +1 everywhere


# ---------------------------------------------------------------------------
# multi-device executor (simulated via forced host devices; subprocess
# because XLA_FLAGS must be set before jax import)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["fedavg", "obda", "eden"])
def test_sharded_baseline_round_bit_exact_vs_unsharded(fed_setup, algo):
    """The baselines' shard_map client side (sharded_baseline_round) on a
    1-device mesh reproduces the unsharded encode->aggregate round
    bit-for-bit (same vmap body, one psum over a singleton axis)."""
    from repro.core.baselines import BaselineConfig, BaselineFL

    data, loss_fn, init_fn = fed_setup
    template = jax.eval_shape(init_fn, jax.random.key(1))
    base = dict(algo=algo, num_clients=6, participate=4, local_steps=2,
                chunk=2048)
    eng_u = BaselineFL(BaselineConfig(**base), loss_fn, template)
    eng_s = BaselineFL(
        BaselineConfig(**base, sharded_round=True, fed_shards=1),
        loss_fn, template,
    )
    st = eng_u.init(init_fn, jax.random.key(2))
    kb, kr = jax.random.split(jax.random.key(5))
    batches = ds.sample_round_batches(kb, data, 2, 24)
    st_u, m_u = eng_u.round(st, batches, data.weights, kr)
    st_s, m_s = eng_s.round(st, batches, data.weights, kr)
    for a, b in zip(jax.tree.leaves(st_u.params), jax.tree.leaves(st_s.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_u["task_loss"]) == float(m_s["task_loss"])


@pytest.mark.slow
def test_two_shard_mesh_tracks_fused_round():
    prog = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        assert len(jax.devices()) == 2, jax.devices()
        from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
        from repro.data import synthetic as ds
        from repro.models import smallnets as sn

        data = ds.make_federated_classification(
            jax.random.key(0), num_clients=6, train_per_client=96,
            test_per_client=48, noise=0.8)
        loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
        init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=32)
        template = jax.eval_shape(init_fn, jax.random.key(1))

        cfg2 = PFed1BSConfig(num_clients=6, participate=6, local_steps=3,
            m_ratio=0.05, chunk=2048, sharded_round=True, fed_shards=2)
        cfg1 = dataclasses.replace(cfg2, sharded_round=False)
        e2 = PFed1BS(cfg2, loss_fn, template)
        e1 = PFed1BS(cfg1, loss_fn, template)
        st2, st1 = e2.init(init_fn, jax.random.key(2)), e1.init(init_fn, jax.random.key(2))
        for r in range(2):
            kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(11), r))
            batches = ds.sample_round_batches(kb, data, 3, 24)
            st2, m2 = e2.round(st2, batches, data.weights, kr)
            st1, m1 = e1.round(st1, batches, data.weights, kr)
        agree = float(jnp.mean((st2.v == st1.v).astype(jnp.float32)))
        assert agree > 0.9, agree
        for a, b in zip(jax.tree.leaves(st2.clients), jax.tree.leaves(st1.clients)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
        assert np.isfinite(float(m2["task_loss"]))
        print("OK agree=%.4f" % agree)
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2"
    ).strip()
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    res = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "OK" in res.stdout
