"""Quickstart: pFed1BS on the paper's own setting, in ~60 lines of user code.

20 clients, label-skew non-iid synthetic MNIST-like data, a 2-layer MLP,
one-bit bidirectional communication. Prints per-round loss / potential /
bits-on-the-wire, and final personalized accuracy vs a FedAvg global model.

Run:  PYTHONPATH=src python examples/quickstart.py
Env:  QUICKSTART_ROUNDS / QUICKSTART_CLIENTS — smaller values for smoke
      tests (tests/test_examples_smoke.py runs this file with tiny
      settings); defaults reproduce the paper's setting.
"""
import os

import jax
import jax.numpy as jnp

from repro.core.baselines import BaselineConfig, BaselineFL
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.models import smallnets as sn

ROUNDS = int(os.environ.get("QUICKSTART_ROUNDS", 25))
CLIENTS = int(os.environ.get("QUICKSTART_CLIENTS", 20))
LOCAL_STEPS, BATCH = 5, 32

key = jax.random.key(0)
data = ds.make_federated_classification(
    key, num_clients=CLIENTS, classes_per_client=2, noise=1.2,
    train_per_client=256, test_per_client=128,
)

init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=200)
loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
eval_fn = lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
template = jax.eval_shape(init_fn, jax.random.key(1))

# ---- pFed1BS: one-bit sketches up, one-bit consensus down -----------------
cfg = PFed1BSConfig(
    num_clients=CLIENTS, participate=CLIENTS, local_steps=LOCAL_STEPS,
    lr=0.05, lam=5e-4, mu=1e-5, gamma=1e4, m_ratio=0.1,  # paper's grid values
)
engine = PFed1BS(cfg, loss_fn, template)
state = engine.init(init_fn, jax.random.key(2))
print(f"model n={engine.n}  sketch m={engine.spec.m}  "
      f"(compression {engine.spec.m / engine.n:.3f})")

for r in range(ROUNDS):
    kb, kr = jax.random.split(jax.random.fold_in(key, r))
    batches = ds.sample_round_batches(kb, data, LOCAL_STEPS, BATCH)
    state, m = engine.round(state, batches, data.weights, kr)
    if r % 5 == 0 or r == ROUNDS - 1:
        print(f"round {r:3d}  loss={m['task_loss']:.4f}  "
              f"Psi={m['potential']:.4f}  agree={m['sign_agreement']:.3f}  "
              f"up={int(m['uplink_bits'])}b down={int(m['downlink_bits'])}b")

accs = jax.vmap(eval_fn)(state.clients, data.test_x, data.test_y)
print(f"\npFed1BS personalized accuracy: {float(accs.mean()):.4f} "
      f"± {float(accs.std()):.4f}")

# ---- FedAvg reference (full-precision, global model) ----------------------
bl = BaselineFL(BaselineConfig(algo="fedavg", num_clients=CLIENTS,
                               participate=CLIENTS, local_steps=LOCAL_STEPS,
                               lr=0.05), loss_fn, template)
bstate = bl.init(init_fn, jax.random.key(2))
for r in range(ROUNDS):
    kb, kr = jax.random.split(jax.random.fold_in(key, 10_000 + r))
    bstate, _ = bl.round(bstate, ds.sample_round_batches(kb, data, LOCAL_STEPS, BATCH),
                         data.weights, kr)
gaccs = jax.vmap(lambda x, y: eval_fn(bstate.params, x, y))(data.test_x, data.test_y)
print(f"FedAvg global accuracy:        {float(gaccs.mean()):.4f}")

ours = comms.round_bits("pfed1bs", n=engine.n, m=engine.spec.m, s=CLIENTS)
fa = comms.round_bits("fedavg", n=engine.n, m=engine.spec.m, s=CLIENTS)
print(f"\nper-round traffic: pFed1BS {ours['total_mb']:.4f} MB vs "
      f"FedAvg {fa['total_mb']:.2f} MB "
      f"(-{100 * (1 - ours['total_bits'] / fa['total_bits']):.2f}%)")
