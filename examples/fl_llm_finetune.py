"""End-to-end driver: federated fine-tuning of a real models/lm.py
transformer (granite-8b family; ~100M-parameter member by default) with
pFed1BS — the canonical fed_lm demo (DESIGN.md §13).

What this exercises, in order:
  1. the engine is built through launch/fedexec.make_fed_lm_engine on a
     2-D (fed, model) mesh: client store K-axis over `fed`, Megatron-TP
     leaves over `model`, per-leaf SRHT chunks flattened sharded-axis-
     major so no FHT block straddles a model shard;
  2. --subset restricts training/sketching/billing to a LoRA-style
     leaf-path subset (core/subset.py; e.g. --subset attn);
  3. before round 0, client 0 is round-tripped through checkpoint/ckpt.py
     and its sketch is recomputed by STREAMING one leaf at a time off the
     npz (models/io.checkpoint_leaf_reader -> core/stream.stream_sketch):
     asserted bit-exact with the engine's materialized leaf-layout sketch,
     with measured peak host bytes == the O(max-layer + m) closed form —
     never the 4n flat vector;
  4. a few hundred PFed1BS.round calls: only one-bit sketches go up, the
     one-bit consensus comes down, billed at the trainable count via
     fl/comms.subset_round_bits. Checkpoints land in experiments/runs/.

Run:  PYTHONPATH=src python examples/fl_llm_finetune.py [--rounds 200]
      [--subset attn] [--fed-shards F --model-shards M]  (F*M devices;
      set XLA_FLAGS=--xla_force_host_platform_device_count=F*M on CPU)
"""
import argparse
import dataclasses
import json
import os
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.core import stream
from repro.core import treesketch as ts
from repro.core.pfed1bs import PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.launch import fedexec
from repro.launch.mesh import make_fed_model_mesh
from repro.models import io as mio
from repro.models import lm

# every size knob also reads an FLLM_* env var so the CI smoke test
# (tests/test_examples_smoke.py) can shrink the run without forking the file
_env = lambda name, default: int(os.environ.get(name, default))
ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=_env("FLLM_ROUNDS", 200))
ap.add_argument("--clients", type=int, default=_env("FLLM_CLIENTS", 4))
ap.add_argument("--participate", type=int,
                default=_env("FLLM_PARTICIPATE", 3))
ap.add_argument("--local-steps", type=int, default=_env("FLLM_LOCAL_STEPS", 2))
ap.add_argument("--batch", type=int, default=_env("FLLM_BATCH", 4))
ap.add_argument("--seq", type=int, default=_env("FLLM_SEQ", 128))
ap.add_argument("--d-model", type=int, default=_env("FLLM_D_MODEL", 768))
ap.add_argument("--layers", type=int, default=_env("FLLM_LAYERS", 12))
ap.add_argument("--heads", type=int, default=_env("FLLM_HEADS", 12))
ap.add_argument("--kv-heads", type=int, default=_env("FLLM_KV_HEADS", 4))
ap.add_argument("--head-dim", type=int, default=_env("FLLM_HEAD_DIM", 64))
ap.add_argument("--d-ff", type=int, default=_env("FLLM_D_FF", 2048))
ap.add_argument("--vocab", type=int, default=_env("FLLM_VOCAB", 8192))
ap.add_argument("--chunk", type=int, default=_env("FLLM_CHUNK", 16384))
ap.add_argument("--subset", default=os.environ.get("FLLM_SUBSET", ""),
                help="comma-separated leaf-path patterns; only matching "
                     "leaves train/sketch/bill (e.g. 'attn' = attention "
                     "projections). Empty = federate the full tree.")
ap.add_argument("--fed-shards", type=int, default=_env("FLLM_FED_SHARDS", 1))
ap.add_argument("--model-shards", type=int,
                default=_env("FLLM_MODEL_SHARDS", 1))
args = ap.parse_args()

# ~100M-param member of the granite-8b family (same arch, smaller dims)
cfg = dataclasses.replace(
    configs.get("granite-8b"),
    n_layers=args.layers, d_model=args.d_model, n_heads=args.heads,
    n_kv=args.kv_heads, head_dim=args.head_dim, d_ff=args.d_ff,
    vocab=args.vocab, name="granite-100m",
)
print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

data = ds.make_federated_lm(
    jax.random.key(0), args.clients, vocab=cfg.vocab, seq=args.seq,
    samples_per_client=64, skew=0.85,
)

trainable = tuple(p for p in args.subset.split(",") if p) or None
fl = PFed1BSConfig(
    num_clients=args.clients, participate=args.participate,
    local_steps=args.local_steps, lr=0.01, lam=5e-4, mu=1e-5, gamma=1e4,
    m_ratio=0.1, chunk=args.chunk, layout="leaf", trainable=trainable,
)
mesh = make_fed_model_mesh(args.fed_shards, args.model_shards)
engine, mesh, template = fedexec.make_fed_lm_engine(cfg, fl, mesh=mesh)
n = engine.n
print(f"params per client: {n / 1e6:.1f}M"
      + (f" (trainable subset {trainable}: "
         f"{engine.n_trainable / 1e6:.1f}M)" if trainable else ""))

init_fn = lambda k: lm.init_params(cfg, k)
shardings = fedexec.fed_lm_shardings(cfg, template, mesh)
state = fedexec.place_fed_lm_state(
    engine.init(init_fn, jax.random.key(2)), shardings
)
bits = comms.subset_round_bits(
    "pfed1bs", n_total=n, n_trainable=engine.n_trainable, m=engine.m,
    s=args.participate,
)
fedavg = comms.round_bits("fedavg", n=engine.n_trainable, m=engine.m,
                          s=args.participate)
print(f"sketch m={engine.m} -> {bits['total_mb']:.2f} MB/round "
      f"(FedAvg on the same trainable set would be "
      f"{fedavg['total_mb']:.0f} MB)")

# ---- streamed-sketch calibration (the §13 memory contract) ----------------
# Client 0 goes through checkpoint/ckpt.py; its sketch is then recomputed by
# streaming one leaf at a time off the npz. Bit-exact or bust, and the
# measured peak must equal the O(max-layer + m) closed form — proving the
# engine's wire object is computable without ever materializing the model.
client0 = jax.tree.map(lambda a: np.asarray(a[0]), state.clients)
materialized = np.asarray(
    jax.jit(
        lambda t: ts.flat_view(engine.tspec, ts.tree_sketch_forward(engine.tspec, t))
    )(client0)
)
with tempfile.TemporaryDirectory() as td:
    ck = os.path.join(td, "client0.npz")
    save_checkpoint(ck, client0)
    _, get_leaf = mio.checkpoint_leaf_reader(ck)
    meter = stream.MemMeter()
    streamed = stream.stream_sketch(engine.tspec, get_leaf, meter=meter)
assert np.array_equal(streamed, materialized), (
    "streamed sketch diverged from the materialized leaf-layout sketch"
)
bound = stream.stream_peak_bound(engine.tspec)
assert meter.peak == bound < 4 * n, (meter.peak, bound, 4 * n)
print(f"streamed sketch bit-exact; peak {meter.peak / 1e6:.2f} MB "
      f"(= max-layer + m bound) vs {4 * n / 1e6:.1f} MB flat vector")

hist = []
t0 = time.time()
for r in range(args.rounds):
    kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(3), r))
    batches = ds.sample_lm_batches(kb, data, args.local_steps, args.batch)
    batches = fedexec.place_fed_lm_batches(batches, shardings)
    state, m = engine.round(state, batches, data.weights, kr)
    hist.append(float(m["task_loss"]))
    if r % 10 == 0 or r == args.rounds - 1:
        print(f"round {r:4d}  ce={hist[-1]:.4f}  Psi={float(m['potential']):.3f}  "
              f"agree={float(m['sign_agreement']):.3f}  "
              f"({(time.time() - t0) / (r + 1):.1f}s/round)", flush=True)

os.makedirs("experiments/runs", exist_ok=True)
save_checkpoint("experiments/runs/fl_llm_clients.npz", state.clients,
                meta={"arch": cfg.name, "rounds": args.rounds,
                      "trainable": list(trainable or ())})
with open("experiments/runs/fl_llm_finetune.json", "w") as f:
    json.dump({"ce_history": hist, "n_params": n,
               "n_trainable": engine.n_trainable, "m": engine.m,
               "mesh": {"fed": args.fed_shards, "model": args.model_shards},
               "comm_per_round": bits}, f, indent=2)
print(f"final CE {hist[-1]:.4f} (started {hist[0]:.4f}); "
      f"checkpoints in experiments/runs/")
assert hist[-1] < hist[0], "training did not reduce loss"
