"""End-to-end driver: federated fine-tuning of a ~100M-parameter GQA
transformer (granite-8b family, 12 layers x d_model 768) with pFed1BS for a
few hundred rounds on per-client skewed token streams.

This is the (b) end-to-end deliverable at LM scale: every client holds its
own personalized LLM; per round only one-bit sketches go up and the one-bit
consensus comes down. Checkpoints land in experiments/runs/.

Run:  PYTHONPATH=src python examples/fl_llm_finetune.py [--rounds 200]
"""
import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.models import lm

# every size knob also reads an FLLM_* env var so the CI smoke test
# (tests/test_examples_smoke.py) can shrink the run without forking the file
_env = lambda name, default: int(os.environ.get(name, default))
ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=_env("FLLM_ROUNDS", 200))
ap.add_argument("--clients", type=int, default=_env("FLLM_CLIENTS", 4))
ap.add_argument("--participate", type=int,
                default=_env("FLLM_PARTICIPATE", 3))
ap.add_argument("--local-steps", type=int, default=_env("FLLM_LOCAL_STEPS", 2))
ap.add_argument("--batch", type=int, default=_env("FLLM_BATCH", 4))
ap.add_argument("--seq", type=int, default=_env("FLLM_SEQ", 128))
ap.add_argument("--d-model", type=int, default=_env("FLLM_D_MODEL", 768))
ap.add_argument("--layers", type=int, default=_env("FLLM_LAYERS", 12))
ap.add_argument("--heads", type=int, default=_env("FLLM_HEADS", 12))
ap.add_argument("--kv-heads", type=int, default=_env("FLLM_KV_HEADS", 4))
ap.add_argument("--head-dim", type=int, default=_env("FLLM_HEAD_DIM", 64))
ap.add_argument("--d-ff", type=int, default=_env("FLLM_D_FF", 2048))
ap.add_argument("--vocab", type=int, default=_env("FLLM_VOCAB", 8192))
ap.add_argument("--chunk", type=int, default=_env("FLLM_CHUNK", 16384))
args = ap.parse_args()

# ~100M-param member of the granite-8b family (same arch, smaller dims)
cfg = dataclasses.replace(
    configs.get("granite-8b"),
    n_layers=args.layers, d_model=args.d_model, n_heads=args.heads,
    n_kv=args.kv_heads, head_dim=args.head_dim, d_ff=args.d_ff,
    vocab=args.vocab, name="granite-100m",
)
print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

data = ds.make_federated_lm(
    jax.random.key(0), args.clients, vocab=cfg.vocab, seq=args.seq,
    samples_per_client=64, skew=0.85,
)

init_fn = lambda k: lm.init_params(cfg, k)
loss_fn = lambda p, b: lm.loss_fn(cfg, p, b)[0]
template = jax.eval_shape(init_fn, jax.random.key(1))
n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(template))
print(f"params per client: {n / 1e6:.1f}M")

fl = PFed1BSConfig(
    num_clients=args.clients, participate=args.participate,
    local_steps=args.local_steps, lr=0.01, lam=5e-4, mu=1e-5, gamma=1e4,
    m_ratio=0.1, chunk=args.chunk,
)
engine = PFed1BS(fl, loss_fn, template)
state = engine.init(init_fn, jax.random.key(2))
bits = comms.round_bits("pfed1bs", n=n, m=engine.spec.m, s=args.participate)
print(f"sketch m={engine.spec.m} -> {bits['total_mb']:.2f} MB/round "
      f"(FedAvg would be {comms.round_bits('fedavg', n=n, m=engine.spec.m, s=args.participate)['total_mb']:.0f} MB)")

hist = []
t0 = time.time()
for r in range(args.rounds):
    kb, kr = jax.random.split(jax.random.fold_in(jax.random.key(3), r))
    batches = ds.sample_lm_batches(kb, data, args.local_steps, args.batch)
    state, m = engine.round(state, batches, data.weights, kr)
    hist.append(float(m["task_loss"]))
    if r % 10 == 0 or r == args.rounds - 1:
        print(f"round {r:4d}  ce={hist[-1]:.4f}  Psi={float(m['potential']):.3f}  "
              f"agree={float(m['sign_agreement']):.3f}  "
              f"({(time.time() - t0) / (r + 1):.1f}s/round)", flush=True)

os.makedirs("experiments/runs", exist_ok=True)
save_checkpoint("experiments/runs/fl_llm_clients.npz", state.clients,
                meta={"arch": cfg.name, "rounds": args.rounds})
with open("experiments/runs/fl_llm_finetune.json", "w") as f:
    json.dump({"ce_history": hist, "n_params": n, "m": engine.spec.m,
               "comm_per_round": bits}, f, indent=2)
print(f"final CE {hist[-1]:.4f} (started {hist[0]:.4f}); "
      f"checkpoints in experiments/runs/")
assert hist[-1] < hist[0], "training did not reduce loss"
