"""Scenario-matrix mini-sweep: the accuracy-vs-bits comparison the paper's
Tables 1-2 make, across heterogeneity regimes.

Sweeps a few algorithms over two heterogeneity scenarios (severe Dirichlet
non-IID with client sampling, and straggler dropout) through the shared
round surface (src/repro/exp/), then prints the per-scenario markdown
table. Shrink/grow with env vars:

  SWEEP_ALGOS=fedavg,obda,pfed1bs  SWEEP_ROUNDS=6  SWEEP_CLIENTS=8 \
    PYTHONPATH=src python examples/scenario_sweep.py

The full matrix (7 algorithms x 7 scenarios) is the `exp` benchmark:
PYTHONPATH=src python -m benchmarks.run exp [--fast].
"""
import os

from repro.exp import report, runner, scenarios

ALGOS = os.environ.get("SWEEP_ALGOS", "fedavg,obda,pfed1bs").split(",")
ROUNDS = int(os.environ.get("SWEEP_ROUNDS", 6))
CLIENTS = int(os.environ.get("SWEEP_CLIENTS", 8))

cfg = runner.ExpConfig(
    num_clients=CLIENTS, rounds=ROUNDS, local_steps=2, batch=16, hidden=32,
    train_per_client=64, test_per_client=32, chunk=2048,
)
matrix = scenarios.paper_matrix()
use = {name: matrix[name] for name in ("dir0.1", "straggler")}

print(f"sweeping {ALGOS} x {list(use)} ({ROUNDS} rounds, {CLIENTS} clients)")
results = runner.sweep(
    ALGOS, use, cfg,
    progress=lambda c: print(
        f"  {c['algo']:9s} x {c['scenario']:10s} acc={c['acc']:.4f} "
        f"bits={c['total_bits']:,} participants/round={c['s_per_round']}"
    ),
)
report.validate_matrix(results, min_algos=len(ALGOS), min_scenarios=len(use))

print()
print(report.matrix_markdown(results))
print(f"swept {len(results['cells'])} cells; accounting validated")
