"""Paper §A.3 reproduction: the FHT structured projection matches a dense
Gaussian projection in downstream quality, at O(n log n) instead of O(mn).

Trains pFed1BS twice — once with the SRHT sketch (ours) and once with an
explicit dense Gaussian Phi — and compares accuracy trajectories + timing
of the projection itself.

Run:  PYTHONPATH=src python examples/fht_projection_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.models import smallnets as sn

CLIENTS, ROUNDS = 8, 15

key = jax.random.key(0)
data = ds.make_federated_classification(key, num_clients=CLIENTS, noise=1.0,
                                        train_per_client=192, test_per_client=96)
init_fn = lambda k: sn.init_mlp(k, input_dim=784, hidden=100)
loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
eval_fn = lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
template = jax.eval_shape(init_fn, jax.random.key(1))


def run(engine):
    state = engine.init(init_fn, jax.random.key(2))
    for r in range(ROUNDS):
        kb, kr = jax.random.split(jax.random.fold_in(key, r))
        state, m = engine.round(state, ds.sample_round_batches(kb, data, 5, 32),
                                data.weights, kr)
    accs = jax.vmap(eval_fn)(state.clients, data.test_x, data.test_y)
    return float(accs.mean())


cfg = PFed1BSConfig(num_clients=CLIENTS, participate=CLIENTS, local_steps=5,
                    lr=0.05, m_ratio=0.1, chunk=4096)
fht_engine = PFed1BS(cfg, loss_fn, template)
acc_fht = run(fht_engine)
print(f"FHT structured projection: personalized acc = {acc_fht:.4f}")

# dense Gaussian variant: same engine, Phi replaced by an explicit matrix
n, m = fht_engine.n, fht_engine.spec.m


class DensePFed1BS(PFed1BS):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.phi = sk.dense_gaussian_sketch(self.n, self.spec.m, seed=7)

    def _sketch_client(self, params):
        from repro.core import flatten
        return self.phi @ flatten.ravel(params)

    def _client_update(self, params, batches, v):
        from repro.core import flatten, regularizer
        cfg = self.cfg

        def objective(p, batch):
            task = self.loss_fn(p, batch)
            w = flatten.ravel(p)
            z = self.phi @ w
            reg = regularizer.smoothed_reg(v, z, cfg.gamma)
            return task + cfg.lam * reg + 0.5 * cfg.mu * jnp.sum(w * w), task

        def step(p, batch):
            (_, task), grads = jax.value_and_grad(objective, has_aux=True)(p, batch)
            return jax.tree.map(lambda a, g: a - cfg.lr * g, p, grads), task

        params, losses = jax.lax.scan(step, params, batches)
        return params, jnp.mean(losses)


dense_engine = DensePFed1BS(cfg, loss_fn, template)
acc_dense = run(dense_engine)
print(f"dense Gaussian projection:  personalized acc = {acc_dense:.4f}")
print(f"accuracy gap: {abs(acc_fht - acc_dense):.4f} (paper §A.3: 'nearly identical')")

# projection timing at growing n (the O(n log n) vs O(mn) claim)
print("\nprojection timing (forward sketch):")
for nn in (2 ** 14, 2 ** 16, 2 ** 18):
    x = jax.random.normal(jax.random.key(3), (nn,))
    spec = sk.make_sketch_spec(nn, 0.1, chunk=16384)
    f = jax.jit(lambda w: sk.sketch_forward(spec, w))
    f(x).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        f(x).block_until_ready()
    t_fht = (time.time() - t0) / 5
    mm = spec.m
    if nn <= 2 ** 16:
        phi = sk.dense_gaussian_sketch(nn, mm, seed=0)
        g = jax.jit(lambda w: phi @ w)
        g(x).block_until_ready()
        t0 = time.time()
        for _ in range(5):
            g(x).block_until_ready()
        t_dense = (time.time() - t0) / 5
        print(f"  n={nn:7d}  FHT {t_fht * 1e3:7.2f} ms   dense {t_dense * 1e3:8.2f} ms")
    else:
        print(f"  n={nn:7d}  FHT {t_fht * 1e3:7.2f} ms   dense (OOM at {mm}x{nn})")
