"""Serve personalized models from the compressed sketch-delta store.

After federated training every client owns a personalized model. Instead of
keeping K full fp32 models resident, the serving tier (src/repro/serve/)
keeps ONE fp32 base plus a per-client one-bit sketch of the residual
w_k - w_base (~1 bit/param, DESIGN.md §7), materializes models on demand
through the batched fused SRHT adjoint, and serves multi-tenant batched
generation — every request in a decode batch runs against its own client's
weights and KV cache via one vmapped `decode_step`.

The store round-trips through checkpoint/ckpt.py (packed uint32 words +
scales + base), so this is the full serve path: encode -> save -> load ->
materialize -> batched decode.

Run:  PYTHONPATH=src python examples/serve_personalized.py
Env:  SERVE_CLIENTS / SERVE_REQUESTS — smaller values for smoke tests
      (tests/test_examples_smoke.py runs this file with tiny settings).
"""
import os
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.models import lm
from repro.serve import router
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.store import SketchStore, make_store_spec

CLIENTS = int(os.environ.get("SERVE_CLIENTS", 12))
REQUESTS = int(os.environ.get("SERVE_REQUESTS", 32))
PROMPT, GEN, BATCH = 12, 20, 4

cfg = configs.get("granite-8b").reduced()
keys = jax.random.split(jax.random.key(0), CLIENTS + 1)
base = lm.init_params(cfg, keys[0])
# stand-ins for FL output: base + per-client perturbation
clients = jax.vmap(
    lambda k: jax.tree.map(
        lambda b, g: b + 0.05 * g,
        base,
        lm.init_params(cfg, k),
    )
)(keys[1:])

# ---- encode into the compressed store & round-trip through a checkpoint ---
spec = make_store_spec(base, CLIENTS, m_ratio=1.0, chunk=4096)
store = SketchStore(spec, base)
t0 = time.time()
store.put_batch(np.arange(CLIENTS), clients)
jax.block_until_ready(store.words)
rb = store.resident_bytes()
print(f"encoded {CLIENTS} clients in {time.time() - t0:.1f}s: "
      f"{rb['per_client_bytes'] / 1e3:.0f} KB/client resident "
      f"(fp32 store: {rb['fp32_per_client_bytes'] / 1e3:.0f} KB/client, "
      f"{rb['compression_vs_fp32']:.1f}x smaller)")

with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "client_store.npz")
    ckpt.save_client_store(path, store)
    store = ckpt.load_client_store(path, base)
print("store round-tripped through checkpoint/ckpt.py")

# ---- serve a Zipf-distributed request stream ------------------------------
engine = ServeEngine(
    cfg, store,
    EngineConfig(prompt_len=PROMPT, gen_len=GEN, max_batch=BATCH,
                 hot_models=max(CLIENTS // 3, 2)),
)
cids = router.zipf_stream(0, CLIENTS, REQUESTS)
prompts = router.random_prompts(1, REQUESTS, PROMPT, cfg.vocab)
report = router.run_stream(engine, cids, prompts, zipf_alpha=1.1, warm=True)

assert report.tokens_generated == REQUESTS * GEN
print(f"served {REQUESTS} requests over {CLIENTS} personalized models: "
      f"{report.tokens_per_sec:.0f} tok/s decode "
      f"({report.end_to_end_tokens_per_sec:.0f} tok/s end-to-end)")
print(f"LRU hit rate {report.hit_rate:.2f}; materialization "
      f"p50 {report.materialize_p50_ms:.1f} ms / "
      f"p99 {report.materialize_p99_ms:.1f} ms over "
      f"{report.materialize_calls} batched reconstructs")

# sanity: a materialized model decodes finite tokens
one = store.materialize_one(0)
probe_cache = lm.init_cache(cfg, 1, 4)
logits, _ = lm.decode_step(cfg, one, np.zeros((1, 1), np.int32), probe_cache,
                           np.int32(0))
assert np.isfinite(np.asarray(logits)).all()
print("materialized model sanity check passed")
