"""Serve personalized models with batched one-token decode steps.

After federated training every client owns a personalized model. This
example builds a tiny personalized LM per client, then serves BATCHED
generation requests against per-client KV caches with the same
`decode_step` the dry-run lowers at 32k/500k scale.

Run:  PYTHONPATH=src python examples/serve_personalized.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm

CLIENTS, BATCH, PROMPT, GEN = 3, 4, 12, 20

cfg = configs.get("granite-8b").reduced()
keys = jax.random.split(jax.random.key(0), CLIENTS)
clients = [lm.init_params(cfg, k) for k in keys]  # stand-ins for FL output

decode = jax.jit(
    lambda p, t, c, pos: lm.decode_step(cfg, p, t, c, pos),
    donate_argnums=(2,),
)


def serve(params, prompts):
    """prompts: (B, PROMPT) -> greedy continuation (B, GEN)."""
    cache = lm.init_cache(cfg, prompts.shape[0], PROMPT + GEN)
    logits = None
    for t in range(PROMPT):  # prefill by stepping (tiny model)
        logits, cache = decode(params, prompts[:, t : t + 1], cache, jnp.int32(t))
    toks = []
    cur = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    for t in range(GEN):
        toks.append(cur[:, 0])
        logits, cache = decode(params, cur, cache, jnp.int32(PROMPT + t))
        cur = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    return jnp.stack(toks, axis=1)


t0 = time.time()
for cid, params in enumerate(clients):
    prompts = jax.random.randint(
        jax.random.fold_in(jax.random.key(1), cid), (BATCH, PROMPT), 0, cfg.vocab
    )
    out = serve(params, prompts)
    assert out.shape == (BATCH, GEN)
    assert np.isfinite(np.asarray(out)).all()
    print(f"client {cid}: served batch of {BATCH}, first continuation: "
          f"{np.asarray(out[0])[:8].tolist()}")
print(f"served {CLIENTS * BATCH} requests ({GEN} tokens each) "
      f"in {time.time() - t0:.1f}s")
