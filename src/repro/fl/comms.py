"""Bidirectional communication-cost accounting (paper Table 2 cost model).

Per-round bits between the server and the S participating clients, with
n = model parameters, m = sketch rows, T = `num_tensors`:

  algorithm   uplink (client->server)   downlink (server->client)
  ---------   -----------------------   -------------------------
  FedAvg      S * 32n                   S * 32n      (fp32 both ways)
  OBDA        S * n                     S * n        (1 bit both ways)
  OBCSAA      S * (m + 32)              S * 32n      (1-bit CS sketch +
                                                      one fp32 amplitude)
  zSignFed    S * (n + 32)              S * 32n      (sign vector + one
                                                      fp32 scale)
  EDEN        S * (n + 32)              S * 32n      (1-bit lattice code +
                                                      one fp32 scale)
  FedBAT      S * (n + 32*T)            S * 32n      (binarized tensors,
                                                      one fp32 alpha EACH)
  pFed1BS     S * m                     m            (one m-bit sketch up
                                                      per client; ONE m-bit
                                                      consensus broadcast)

`num_tensors` semantics: FedBAT binarizes each parameter tensor separately
and ships one fp32 scale alpha per tensor, so its uplink carries 32 bits
per tensor per client; callers should pass the leaf count of the model
pytree (benchmarks/fl_bench.py passes len(jax.tree.leaves(template))).
Every other algorithm ignores it — their scales are per-model, already
counted in the +32 terms above.

pFed1BS's downlink is NOT multiplied by S: the consensus v is one
broadcast message (every client receives the same m bits), which is how
the paper counts it and how the sharded executor realizes it
(launch/fedexec.py broadcasts one consensus over the `fed` axis).

These formulas are pinned, with concrete numbers, by
tests/test_comms_table2.py — the same numbers shown in README.md. Change
all three together.
"""
from __future__ import annotations

FP_BITS = 32


def round_bits(algo: str, *, n: int, m: int, s: int, num_tensors: int = 1) -> dict:
    """Table-2 wire cost of one round.

    n: model parameters; m: sketch rows (pFed1BS/OBCSAA only); s: number of
    participating clients S; num_tensors: pytree leaf count (FedBAT only —
    see module docstring). Returns integer bit counts
    {uplink_bits, downlink_bits, total_bits} plus total_mb (float, MB).
    """
    algo = algo.lower()
    if algo == "fedavg":
        up, down = s * FP_BITS * n, s * FP_BITS * n
    elif algo == "obda":
        up, down = s * n, s * n
    elif algo == "obcsaa":
        up, down = s * (m + FP_BITS), s * FP_BITS * n
    elif algo in ("zsignfed", "fedbat", "eden"):
        scalars = num_tensors if algo == "fedbat" else 1
        up, down = s * (n + FP_BITS * scalars), s * FP_BITS * n
    elif algo == "pfed1bs":
        up, down = s * m, m
    else:
        raise ValueError(algo)
    return {"uplink_bits": up, "downlink_bits": down, "total_bits": up + down,
            "total_mb": (up + down) / 8e6}


def reduction_vs_fedavg(algo: str, **kw) -> float:
    """Fraction of FedAvg's per-round traffic removed (1 - this/fedavg)."""
    base = round_bits("fedavg", **kw)["total_bits"]
    this = round_bits(algo, **kw)["total_bits"]
    return 1.0 - this / base
