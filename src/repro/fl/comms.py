"""Bidirectional communication-cost accounting (paper Table 2 cost model).

Per-round bits between the server and all S participating clients:

  FedAvg    up S*32n, down S*32n
  OBDA      up S*n,   down S*n        (1-bit both directions)
  OBCSAA    up S*(m+32), down S*32n   (1-bit CS uplink + amplitude scalar)
  zSignFed  up S*(n+32), down S*32n
  EDEN      up S*(n+32), down S*32n
  FedBAT    up S*(n+32*T), down S*32n (T = #tensors, one alpha each)
  pFed1BS   up S*m,   down m          (one m-bit sketch each way; the
                                       consensus is broadcast once)
"""
from __future__ import annotations

FP_BITS = 32


def round_bits(algo: str, *, n: int, m: int, s: int, num_tensors: int = 1) -> dict:
    algo = algo.lower()
    if algo == "fedavg":
        up, down = s * FP_BITS * n, s * FP_BITS * n
    elif algo == "obda":
        up, down = s * n, s * n
    elif algo == "obcsaa":
        up, down = s * (m + FP_BITS), s * FP_BITS * n
    elif algo in ("zsignfed", "fedbat", "eden"):
        scalars = num_tensors if algo == "fedbat" else 1
        up, down = s * (n + FP_BITS * scalars), s * FP_BITS * n
    elif algo == "pfed1bs":
        up, down = s * m, m
    else:
        raise ValueError(algo)
    return {"uplink_bits": up, "downlink_bits": down, "total_bits": up + down,
            "total_mb": (up + down) / 8e6}


def reduction_vs_fedavg(algo: str, **kw) -> float:
    base = round_bits("fedavg", **kw)["total_bits"]
    this = round_bits(algo, **kw)["total_bits"]
    return 1.0 - this / base
