"""Bidirectional communication-cost accounting (paper Table 2 cost model).

Per-round bits between the server and the S participating clients, with
n = model parameters, m = sketch rows, T = `num_tensors`:

  algorithm   uplink (client->server)   downlink (server->client)
  ---------   -----------------------   -------------------------
  FedAvg      S * 32n                   S * 32n      (fp32 both ways)
  OBDA        S * n                     S * n        (1 bit both ways)
  OBCSAA      S * (m + 32)              S * 32n      (1-bit CS sketch +
                                                      one fp32 amplitude)
  zSignFed    S * (n + 32)              S * 32n      (sign vector + one
                                                      fp32 scale)
  EDEN        S * (n + 32)              S * 32n      (1-bit lattice code +
                                                      one fp32 scale)
  FedBAT      S * (n + 32*T)            S * 32n      (binarized tensors,
                                                      one fp32 alpha EACH)
  pFed1BS     S * m                     m            (one m-bit sketch up
                                                      per client; ONE m-bit
                                                      consensus broadcast)

`num_tensors` semantics: FedBAT binarizes each parameter tensor separately
and ships one fp32 scale alpha per tensor, so its uplink carries 32 bits
per tensor per client; callers should pass the leaf count of the model
pytree (benchmarks/fl_bench.py passes len(jax.tree.leaves(template))).
Every other algorithm ignores it — their scales are per-model, already
counted in the +32 terms above.

pFed1BS's downlink is NOT multiplied by S: the consensus v is one
broadcast message (every client receives the same m bits), which is how
the paper counts it and how the sharded executor realizes it
(launch/fedexec.py broadcasts one consensus over the `fed` axis).

The robustness axes (DESIGN.md §10) change NOTHING here by design: a
Byzantine client's corrupted sketch is still S*m uplink bits, a
RandomizedResponse-flipped bit is still one bit, and the trimmed /
reputation defenses are server-side re-weightings of bits already paid
for. One bit is one bit — BENCH_robust's validator asserts equal billed
bits across every attack x defense x privacy cell.

These formulas are pinned, with concrete numbers, by
tests/test_comms_table2.py — the same numbers shown in README.md. Change
all three together.

`storage_bits` is the SERVING-tier companion: resident bits to hold K
personalized models (fp32-per-client vs the base + m-bit-sketch-per-client
store of serve/store.py). Pinned by the same test file and mirrored in the
README cost-model section.
"""
from __future__ import annotations

FP_BITS = 32


def round_bits(algo: str, *, n: int, m: int, s: int, num_tensors: int = 1) -> dict:
    """Table-2 wire cost of one round.

    n: model parameters; m: sketch rows (pFed1BS/OBCSAA only); s: number of
    participating clients S; num_tensors: pytree leaf count (FedBAT only —
    see module docstring). Returns integer bit counts
    {uplink_bits, downlink_bits, total_bits} plus total_mb (float).

    UNIT CONVENTION: total_mb is DECIMAL megabytes — total_bits / 8e6,
    i.e. 1 MB = 10^6 bytes (SI), NOT 2^20-byte MiB. This is the unit the
    README cost-model tables print and tests/test_comms_table2.py pins
    (160.0 MB for FedAvg at n=1e6, S=20 — the round number is only round
    in decimal). Anything comparing against these figures must divide by
    8e6, not 8 * 2**20.
    """
    algo = algo.lower()
    if algo == "fedavg":
        up, down = s * FP_BITS * n, s * FP_BITS * n
    elif algo == "obda":
        up, down = s * n, s * n
    elif algo == "obcsaa":
        up, down = s * (m + FP_BITS), s * FP_BITS * n
    elif algo in ("zsignfed", "fedbat", "eden"):
        scalars = num_tensors if algo == "fedbat" else 1
        up, down = s * (n + FP_BITS * scalars), s * FP_BITS * n
    elif algo == "pfed1bs":
        up, down = s * m, m
    else:
        raise ValueError(algo)
    return {"uplink_bits": up, "downlink_bits": down, "total_bits": up + down,
            "total_mb": (up + down) / 8e6}


def accumulate_round_bits(algo: str, *, n: int, m: int, s_per_round,
                          num_tensors: int = 1) -> dict:
    """Total wire cost of a multi-round run whose participation varied:
    sum of `round_bits` with that round's realized client count s_r (the
    scenario harness bills sum(active) per round — a straggler that never
    uploaded is not invoiced). pFed1BS's m-bit consensus broadcast is
    counted once per round regardless of s_r, exactly as `round_bits` does.

    s_per_round: iterable of ints. Returns {uplink_bits, downlink_bits,
    total_bits, total_mb, rounds}; total_mb uses the same decimal-MB
    (total_bits / 8e6) convention as `round_bits`.
    """
    up = down = 0
    rounds = 0
    for s in s_per_round:
        b = round_bits(algo, n=n, m=m, s=int(s), num_tensors=num_tensors)
        up += b["uplink_bits"]
        down += b["downlink_bits"]
        rounds += 1
    return {"uplink_bits": up, "downlink_bits": down, "total_bits": up + down,
            "total_mb": (up + down) / 8e6, "rounds": rounds}


def subset_round_bits(algo: str, *, n_total: int, n_trainable: int, m: int,
                      s: int, num_tensors: int = 1) -> dict:
    """Table-2 wire cost when only a trainable subset federates (the
    fed_lm LoRA-style path, DESIGN.md §13): every algorithm ships the
    TRAINABLE parameters only — n_trainable replaces n, and for pFed1BS
    the m is the path-filtered TreeSketchSpec's m (already sized
    ~m_ratio * n_trainable; `PFed1BS.m` under cfg.trainable). Frozen
    leaves never cross the wire for anyone, so the competitor baselines
    are billed at the same subset — the comparison stays apples-to-apples.

    Returns round_bits' dict plus {n_total, n_trainable,
    trainable_fraction}; the total_mb decimal-MB convention is inherited.
    """
    assert 0 < n_trainable <= n_total, (n_trainable, n_total)
    out = round_bits(algo, n=n_trainable, m=m, s=s, num_tensors=num_tensors)
    out["n_total"] = int(n_total)
    out["n_trainable"] = int(n_trainable)
    out["trainable_fraction"] = n_trainable / n_total
    return out


def counter_bits(width: int) -> int:
    """Bits per sketch coordinate of a partial popcount counter covering
    `width` clients: the count lies in [0, width], so the wire format is
    ceil(log2(width + 1)) bit planes of m bits each (width = 1 degenerates
    to the 1-bit sketch itself). ISSUE/DESIGN shorthand says
    ceil(log2(width)); the +1 is the honest closed-interval count — a
    width-4 counter must represent the value 4 and needs 3 bits, not 2.
    DESIGN.md §11 documents the divergence; validators re-derive from HERE.
    """
    width = int(width)
    assert width >= 1, f"counter width must be positive, got {width}"
    return width.bit_length()   # == ceil(log2(width + 1)) for width >= 1


def hier_round_bits(*, m: int, leaf_widths, fan_out: int) -> dict:
    """Per-tier wire cost of one hierarchical pFed1BS round (DESIGN.md §11).

    Clients upload their m-bit sketches to their leaf aggregator (same
    S*m uplink as the flat server — tier 0 bills identically). Each
    aggregation tier then ships one partial counter per node upward:
    a node covering `width` clients sends counter_bits(width) * m bits.
    Tiers are formed by merging `fan_out` consecutive nodes until one
    node (the root) remains; the last pre-root tier's traffic is the
    ROOT INGRESS — with bounded fan-out it is
    fan_out * counter_bits(~S/fan_out) * m = O(m log S), versus the flat
    server's S * m = O(S m) ingress. Downlink is one m-bit consensus
    broadcast per tier level (root -> edges -> ... -> clients).

    m: sketch rows; leaf_widths: client count per leaf aggregator
    (sum = S); fan_out: merge arity of the interior tiers. Returns
    {client_uplink_bits, tier_uplink_bits (list, leaf->root order),
    uplink_bits, root_ingress_bits, downlink_bits, tiers, total_bits,
    total_mb} — decimal MB, same convention as round_bits.
    """
    widths = [int(w) for w in leaf_widths]
    assert widths and all(w >= 1 for w in widths), widths
    assert fan_out >= 2, f"fan-out must be >= 2, got {fan_out}"
    s = sum(widths)
    client_up = s * m
    tier_up = []
    while len(widths) > 1:
        tier_up.append(sum(counter_bits(w) * m for w in widths))
        widths = [sum(widths[i : i + fan_out])
                  for i in range(0, len(widths), fan_out)]
    tiers = len(tier_up) + 1                      # +1: the client->leaf tier
    root_ingress = tier_up[-1] if tier_up else client_up
    up = client_up + sum(tier_up)
    down = tiers * m                              # one broadcast per level
    return {
        "client_uplink_bits": client_up,
        "tier_uplink_bits": tier_up,
        "uplink_bits": up,
        "root_ingress_bits": root_ingress,
        "downlink_bits": down,
        "tiers": tiers,
        "total_bits": up + down,
        "total_mb": (up + down) / 8e6,
    }


def reduction_vs_fedavg(algo: str, **kw) -> float:
    """Fraction of FedAvg's per-round traffic removed (1 - this/fedavg)."""
    base = round_bits("fedavg", **kw)["total_bits"]
    this = round_bits(algo, **kw)["total_bits"]
    return 1.0 - this / base


def storage_bits(algo: str, *, n: int, m: int, k: int, passes: int = 1) -> dict:
    """Personalization-STATE accounting: resident bits to hold K clients'
    personalized models on the serving tier (the storage mirror of the
    Table-2 wire model; realized by serve/store.py and pinned by
    tests/test_comms_table2.py).

      fp32      K full models                      -> 32 n K
      pfed1bs   one fp32 base + per client one
                m-bit sketch of the residual
                w_k - w_base plus one fp32 scale,
                per refinement pass               -> 32 n + K * passes * (m + 32)

    n: model parameters; m: sketch rows per pass; k: number of clients;
    passes: sketch-refinement rounds (serve.store.StoreSpec.passes).
    Returns {total_bits, per_client_bits, compression_vs_fp32}. Note this
    is the analytic count (no uint32 word padding); SketchStore
    .resident_bytes() reports the padded resident arrays.
    """
    algo = algo.lower()
    fp32_total = 32 * n * k
    if algo == "fp32":
        total = fp32_total
    elif algo == "pfed1bs":
        total = 32 * n + k * passes * (m + FP_BITS)
    else:
        raise ValueError(algo)
    return {
        "total_bits": total,
        "per_client_bits": total / k,
        "compression_vs_fp32": fp32_total / total,
    }
