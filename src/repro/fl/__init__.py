# Federated-learning runtime: round scheduling, comms accounting, serving.
from repro.fl import comms
