"""repro: pFed1BS — personalized FL with bidirectional one-bit random sketching.

A multi-pod JAX training/serving framework implementing Cheng et al.,
AAAI 2026, plus the substrate it needs (models, data, optim, checkpoint,
distribution) and the full baseline suite from the paper.
"""
__version__ = "0.1.0"
