# Launchers: mesh factories, shard_map federation executor (fedexec),
# multi-pod dry-run, training driver.
