"""Production mesh factory (2 pods x 256 chips of TPU v5e target).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 forced host devices).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices but only {len(devs)} visible. "
            "The dry-run launcher must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 BEFORE importing jax."
        )
    if len(devs) == ndev:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, devices=devs[:ndev])


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh on whatever devices exist (CPU tests)."""
    import jax

    return jax.make_mesh(shape, axes, devices=jax.devices()[: int(np.prod(shape))])
