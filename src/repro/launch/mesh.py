"""Production mesh factory (2 pods x 256 chips of TPU v5e target).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 forced host devices).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices but only {len(devs)} visible. "
            "The dry-run launcher must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 BEFORE importing jax."
        )
    if len(devs) == ndev:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, devices=devs[:ndev])


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh on whatever devices exist (CPU tests)."""
    import jax

    return jax.make_mesh(shape, axes, devices=jax.devices()[: int(np.prod(shape))])


def make_fed_mesh(shards: int = 1):
    """1-D federation mesh: the `fed` axis the sharded round executor
    (launch/fedexec.py, DESIGN.md §6) lays sampled clients out on.

    Uses the first `shards` visible devices. To simulate a multi-device
    federation on a CPU host, set
    XLA_FLAGS=--xla_force_host_platform_device_count=N before importing jax
    (benchmarks/round_sharded_bench.py does this by re-spawning itself).
    """
    import jax

    devs = jax.devices()
    if len(devs) < shards:
        raise RuntimeError(
            f"fed mesh needs {shards} devices but only {len(devs)} visible. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{shards} BEFORE importing jax to simulate the federation."
        )
    return jax.make_mesh((shards,), ("fed",), devices=devs[:shards])


def make_fed_model_mesh(fed: int = 1, model: int = 1):
    """2-D federation x tensor-parallel mesh for the fed_lm path (DESIGN.md
    §13): client store K-axis over `fed`, each client's LM leaves sharded
    over `model` per sharding/specs.param_pspecs. Composes the §6 wire
    discipline (only m-bit words cross `fed`) with Megatron-style TP
    within a client.
    """
    import jax

    ndev = fed * model
    devs = jax.devices()
    if len(devs) < ndev:
        raise RuntimeError(
            f"fed_lm mesh ({fed}, {model}) needs {ndev} devices but only "
            f"{len(devs)} visible. Set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={ndev} BEFORE "
            "importing jax to simulate the federation."
        )
    return jax.make_mesh((fed, model), ("fed", "model"), devices=devs[:ndev])
