"""Sharded federation executor: shard_map rounds over a `fed` mesh axis.

One pFed1BS round (core/pfed1bs.py, Algorithm 1) laid out the way a real
federation is: the S sampled clients are split over the F shards of a 1-D
`fed` mesh (launch/mesh.py::make_fed_mesh), and EVERYTHING client-side —
the R local SGD steps, the fused SRHT sketch, the EF correction, sign +
bit-pack — runs inside one shard_map region with zero collectives. The
data that leaves that region over the federation axis is exactly the wire
traffic of the paper's Table 2 accounting (fl/comms.py, algo="pfed1bs"):

    uplink    (S, ceil(m/32)) uint32 sign words   = S * m bits
    downlink  one broadcast consensus             = m bits

Everything else stays put: client params and EF residuals are gathered /
scattered against the simulator's replicated state store (bookkeeping of
the simulation, not wire traffic — a deployed client keeps its own params),
and the diagnostics (potential Psi^t, sign agreement) are optional float
crossings that `diagnostics=False` removes entirely. With diagnostics off
and EF off the uplink words come straight from the fused kernel's pack
epilogue (`sketch_forward_packed`): the float sketch never hits HBM.

Server vote (DESIGN.md §6.2): `vote="exact"` unpacks the S*m wire bits
server-side and evaluates Lemma 1's sign(sum_k p_k z_k) in natural client
order — bit-exact with the fused single-host round on a 1-device mesh at
full participation (tests/test_fedexec.py). `vote="popcount"` never
unpacks: the word-level bit-sliced majority kernel (kernels/onebit.py)
counts set bits per position across clients in integer arithmetic (uniform
p_k; ties -> +1, and — unlike any float path — a tie can never be flipped
by rounding).

See DESIGN.md §6 for the mesh diagram and the bit accounting.

`sharded_baseline_round` (bottom of file) lays the six global-model
baselines (core/baselines.py) on the same `fed` mesh: local steps + the
per-client compress->decompress encode run collective-free per shard and
the axis is crossed by one psum of the weighted aggregate — the scenario
matrix (exp/runner.py, DESIGN.md §8) drives every algorithm through this
one executor family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import consensus, rounds
from repro.kernels import ops as kops


def sharded_round(eng, state, batches, weights, key, participants=None):
    """One shard_map federation round. Same contract as PFed1BS.round:
    batches (K, R, B, ...) pytree, weights (K,) p_k, optional externally
    drawn participants (idx, active) -> (state', metrics).

    Requires cfg.participate % cfg.fed_shards == 0 (checked at engine
    construction); each fed shard owns S/F clients for the round.
    """
    cfg = eng.cfg
    mesh = eng.fed_mesh
    m = eng.m
    pad = (-m) % 32
    nw = (m + pad) // 32

    # partial participation: sample S of K without replacement (replicated —
    # every shard derives the same draw from the same key). Dropped-out rows
    # (active=0) keep their params, cast no vote, transmit no bits.
    idx, active = eng._draw_participants(key, participants)
    take = lambda tree: jax.tree.map(lambda a: a[idx], tree)
    clients_s, batches_s = take(state.clients), take(batches)
    w_s = weights[idx] * active
    ef_s = state.ef[idx] if cfg.error_feedback else None

    # floats are needed beyond the shard only for EF (residual update) or
    # diagnostics; otherwise the uplink is packed in the kernel epilogue.
    # Byzantine/RR injection also disables the packed fast path: corruption
    # acts on the float sketch, the flips on the sign vector.
    robust = cfg.adversary is not None or cfg.privacy is not None
    wire_only = not (cfg.diagnostics or cfg.error_feedback or robust)

    def client_shards(params, bats, idx_rows, rnd, v, ef):
        """Body per fed shard: S/F clients, collective-free. Corruption and
        RR flips run per shard on the shard's own cohort rows — both are
        keyed by (seed, round, client id), so the injection is identical to
        the fused round's regardless of the shard layout
        (core/rounds.py, tests/test_robust.py)."""
        upd, task_loss = jax.vmap(
            lambda p, b: eng._client_update(p, b, v)
        )(params, bats)
        out = {"upd": upd, "task_loss": task_loss}
        if wire_only:
            out["packed"] = jax.vmap(eng._sketch_client_packed)(upd)
            return out
        zs = jax.vmap(eng._sketch_client)(upd)              # (S/F, m) float32
        zs = rounds.corrupt_cohort(
            cfg.adversary, zs, idx_rows, rnd, cfg.num_clients
        )
        if cfg.diagnostics:
            out["zs"] = zs                                   # pre-EF (Eq. 28)
        if cfg.error_feedback:
            _, signs, out["ef"] = eng._ef_quantize(zs, ef)
        else:
            signs = jnp.sign(zs) + (zs == 0)                 # {-1,+1}
        signs = rounds.privatize_signs(cfg.privacy, signs, idx_rows, rnd)
        out["packed"] = eng._pack_uplink(signs)
        return out

    fed = P("fed")
    out_specs = {"upd": fed, "task_loss": fed, "packed": fed}
    if cfg.diagnostics:
        out_specs["zs"] = fed
    if cfg.error_feedback:
        out_specs["ef"] = fed
    res = shard_map(
        client_shards,
        mesh=mesh,
        in_specs=(fed, fed, fed, P(), P(), fed),
        out_specs=out_specs,
        check_rep=False,
    )(clients_s, batches_s, idx, state.round, state.v, ef_s)

    # ---- the wire ----------------------------------------------------------
    # res["packed"] is the (S, nw) uint32 uplink; replicating it for the
    # server step below is the all-gather of S*m bits — the ONLY fed-axis
    # traffic besides the m-bit consensus broadcast (plus optional
    # diagnostics, see module docstring).
    packed = res["packed"]

    if cfg.vote == "popcount":
        # word-level integer majority — the uniform-p_k specialization of
        # Lemma 1; `weights` does NOT enter the vote. The vote_uniform_ok
        # metric (below) flags rounds where the sampled weights were not
        # actually uniform and the consensus therefore differs from the
        # weighted Lemma 1 object.
        new_rep = state.rep
        if cfg.defense == "trim":
            # trimmed vote stays on the wire words: XOR-popcount Hamming
            # ranking against a provisional packed consensus
            # (kernels/ops.py::vote_packed_trimmed; ties -> +1 like every
            # packed path). `active` doubles as the uniform weight vector so
            # dropped-out rows neither vote nor get trimmed.
            vw = consensus.trimmed_vote_packed(packed, active, eng.trim_count)
        else:
            vw = consensus.majority_vote_popcount(packed)
        v_new = kops.unpack_signs(vw)[:m]
    else:
        # Lemma 1 exactly: unpack server-side, vote in natural client order
        # with zero weights on non-sampled rows, routed through the
        # configured defense (eng.vote_defended — the same float
        # accumulation as the fused round, see §4 note on vote ordering),
        # hence bit-exact with it on a 1-device mesh.
        pm = kops.unpack_signs(packed)[:, :m]
        v_new, new_rep = eng.vote_defended(pm, idx, w_s, state.rep)

    # ---- simulator state bookkeeping (not wire traffic) --------------------
    clients = rounds.scatter_rows(state.clients, idx, res["upd"], active)
    new_ef = state.ef
    if cfg.error_feedback:
        ef_rows = jnp.where(active[:, None] > 0, res["ef"], state.ef[idx])
        new_ef = state.ef.at[idx].set(ef_rows)

    w_norm = jnp.maximum(jnp.sum(w_s), 1e-9)
    metrics = {
        "task_loss": jnp.sum(res["task_loss"] * w_s) / w_norm,
        "uplink_bits": jnp.sum(active) * m,
        "downlink_bits": jnp.float32(m),
        "packed_words": jnp.float32(nw),
    }
    if cfg.vote == "popcount":
        # 1.0 iff the sampled weights really were uniform, i.e. the integer
        # vote computed the same object as weighted Lemma 1 would have.
        # (An external participation draw with dropped-out rows zeroes some
        # weights, so it also trips this flag: popcount counts every sampled
        # row — use vote="exact" with straggler/availability scenarios.)
        metrics["vote_uniform_ok"] = jnp.all(w_s == w_s[0]).astype(jnp.float32)
    if cfg.diagnostics:
        zs = res["zs"]
        corr = zs + state.ef[idx] if cfg.error_feedback else zs
        metrics["potential"] = eng._potential_from_sketches(
            res["upd"], zs, v_new, res["task_loss"], w_s
        )
        metrics["sign_agreement"] = jnp.mean(
            (corr * v_new[None, :] > 0).astype(jnp.float32)
        )
    # FLState is a NamedTuple; _replace avoids importing core from launch
    # (core.pfed1bs lazily imports this module inside round()).
    state = state._replace(
        clients=clients, v=v_new, round=state.round + 1, ef=new_ef,
        rep=new_rep,
    )
    return state, metrics


def sharded_baseline_round(eng, params, batches_s, pw, keys):
    """Client side of a BaselineFL round over the `fed` mesh (DESIGN.md §8).

    The S sampled clients are split across the F fed shards; each shard runs
    its clients' R local SGD steps and the per-client compress->decompress
    `_encode` (core/baselines.py) with ZERO collectives, reduces its own
    weighted partial sum, and the fed axis is crossed once by a psum of the
    (n,) aggregate + the scalar loss partial — the simulator analogue of S
    uplinks meeting at the server. The global model `params` is replicated
    (every real client holds the downlinked model).

    eng: BaselineFL; params: global-model pytree (replicated);
    batches_s: (S, R, B, ...) pytree; pw: (S,) masked weights (weight 0 =
    dropped out — its encode result is computed but annihilated, like a
    straggler whose upload never lands); keys: (S,) per-client PRNG keys.
    Returns (agg (n,), task_loss_weighted_sum ()) — the same aggregate the
    unsharded round feeds `_finish`.
    """
    fed = P("fed")

    def shard(p, bats, w, ks):
        deltas, losses = jax.vmap(
            lambda b: eng._local_delta(p, b)
        )(bats)
        recs = jax.vmap(eng._encode)(deltas, ks)
        part = jnp.einsum("k,kn->n", w, recs)
        lpart = jnp.sum(losses * w)
        return (
            jax.lax.psum(part, "fed"),
            jax.lax.psum(lpart, "fed"),
        )

    return shard_map(
        shard,
        mesh=eng.fed_mesh,
        in_specs=(P(), fed, fed, fed),
        out_specs=(P(), P()),
        check_rep=False,
    )(params, batches_s, pw, keys)
