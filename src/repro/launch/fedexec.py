"""Sharded federation executor: shard_map rounds over a `fed` mesh axis.

One pFed1BS round (core/pfed1bs.py, Algorithm 1) laid out the way a real
federation is: the S sampled clients are split over the F shards of a 1-D
`fed` mesh (launch/mesh.py::make_fed_mesh), and EVERYTHING client-side —
the R local SGD steps, the fused SRHT sketch, the EF correction, sign +
bit-pack — runs inside one shard_map region with zero collectives. The
data that leaves that region over the federation axis is exactly the wire
traffic of the paper's Table 2 accounting (fl/comms.py, algo="pfed1bs"):

    uplink    (S, ceil(m/32)) uint32 sign words   = S * m bits
    downlink  one broadcast consensus             = m bits

Everything else stays put: client params and EF residuals are gathered /
scattered against the simulator's replicated state store (bookkeeping of
the simulation, not wire traffic — a deployed client keeps its own params),
and the diagnostics (potential Psi^t, sign agreement) are optional float
crossings that `diagnostics=False` removes entirely. With diagnostics off
and EF off the uplink words come straight from the fused kernel's pack
epilogue (`sketch_forward_packed`): the float sketch never hits HBM.

Server vote (DESIGN.md §6.2): `vote="exact"` unpacks the S*m wire bits
server-side and evaluates Lemma 1's sign(sum_k p_k z_k) in natural client
order — bit-exact with the fused single-host round on a 1-device mesh at
full participation (tests/test_fedexec.py). `vote="popcount"` never
unpacks: the word-level bit-sliced majority kernel (kernels/onebit.py)
counts set bits per position across clients in integer arithmetic (uniform
p_k; ties -> +1, and — unlike any float path — a tie can never be flipped
by rounding).

See DESIGN.md §6 for the mesh diagram and the bit accounting.

`sharded_baseline_round` (bottom of file) lays the six global-model
baselines (core/baselines.py) on the same `fed` mesh: local steps + the
per-client compress->decompress encode run collective-free per shard and
the axis is crossed by one psum of the weighted aggregate — the scenario
matrix (exp/runner.py, DESIGN.md §8) drives every algorithm through this
one executor family.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import consensus, rounds
from repro.fl import comms
from repro.kernels import ops as kops
from repro.obs import trace as obstrace


@dataclasses.dataclass(frozen=True)
class HierTopology:
    """Tree-of-aggregators shape over the sampled cohort (DESIGN.md §11).

    The S sampled clients are split CONTIGUOUSLY into leaf aggregators of
    the given sizes; interior tiers merge `fan_out` consecutive nodes at a
    time until one root remains. Frozen/hashable so it can ride inside
    PFed1BSConfig (a static jit argument) like the adversary/privacy axes.

    leaf_sizes: clients per leaf (each >= 1, sum == S);
    fan_out: merge arity of the interior tiers (>= 2).
    """

    leaf_sizes: tuple
    fan_out: int = 4

    def __post_init__(self):
        assert self.fan_out >= 2, f"fan_out must be >= 2, got {self.fan_out}"
        assert self.leaf_sizes and all(int(s) >= 1 for s in self.leaf_sizes), (
            f"leaf sizes must be positive, got {self.leaf_sizes}"
        )

    @classmethod
    def build(cls, s: int, fan_out: int = 4) -> "HierTopology":
        """Balanced topology for S clients: ceil(S/fan_out) leaves of width
        <= fan_out (the last leaf ragged), merged fan_out at a time."""
        assert s >= 1
        n_leaves = -(-s // fan_out)
        base, extra = divmod(s, n_leaves)
        sizes = tuple(base + (1 if i < extra else 0) for i in range(n_leaves))
        return cls(leaf_sizes=sizes, fan_out=fan_out)

    @property
    def num_clients(self) -> int:
        return sum(int(s) for s in self.leaf_sizes)

    @property
    def depth(self) -> int:
        """Counter-merge levels above the leaves (0 when a single leaf IS
        the root)."""
        return len(self.level_widths()) - 1

    def level_widths(self) -> list:
        """Per-level node widths (clients covered), leaves first, ending
        with the single root: [[leaf widths], [edge widths], ..., [S]]."""
        widths = [int(s) for s in self.leaf_sizes]
        levels = [widths]
        while len(widths) > 1:
            widths = [sum(widths[i : i + self.fan_out])
                      for i in range(0, len(widths), self.fan_out)]
            levels.append(widths)
        return levels

    def round_bits(self, m: int) -> dict:
        """Per-tier Table-2 accounting of one round at sketch size m
        (fl/comms.hier_round_bits on this topology)."""
        return comms.hier_round_bits(
            m=m, leaf_widths=self.leaf_sizes, fan_out=self.fan_out
        )


def _client_wire(eng, state, batches, weights, key, participants):
    """The collective-free client side shared by EVERY fed-mesh executor:
    draw the cohort, shard it over the `fed` axis, run local steps + sketch
    + (EF, corruption, RR flips) per shard, and emit the packed uplink.

    Returns (idx, active, w_s, res) where res holds {"upd", "task_loss",
    "packed"} (+"zs" under diagnostics, +"ef" under error feedback), each
    with leading axis S. `res["packed"]` is the (S, ceil(m/32)) uint32 wire
    uplink — the flat executor votes on it directly; the hierarchical
    executor counts it at the leaves (hier_round).
    """
    cfg = eng.cfg

    # partial participation: sample S of K without replacement (replicated —
    # every shard derives the same draw from the same key). Dropped-out rows
    # (active=0) keep their params, cast no vote, transmit no bits.
    idx, active = eng._draw_participants(key, participants)
    take = lambda tree: jax.tree.map(lambda a: a[idx], tree)
    clients_s, batches_s = take(state.clients), take(batches)
    w_s = weights[idx] * active
    ef_s = state.ef[idx] if cfg.error_feedback else None

    # floats are needed beyond the shard only for EF (residual update) or
    # diagnostics; otherwise the uplink is packed in the kernel epilogue.
    # Byzantine/RR injection also disables the packed fast path: corruption
    # acts on the float sketch, the flips on the sign vector.
    robust = cfg.adversary is not None or cfg.privacy is not None
    wire_only = not (cfg.diagnostics or cfg.error_feedback or robust)

    def client_shards(params, bats, idx_rows, rnd, v, ef):
        """Body per fed shard: S/F clients, collective-free. Corruption and
        RR flips run per shard on the shard's own cohort rows — both are
        keyed by (seed, round, client id), so the injection is identical to
        the fused round's regardless of the shard layout
        (core/rounds.py, tests/test_robust.py)."""
        upd, task_loss = jax.vmap(
            lambda p, b: eng._client_update(p, b, v)
        )(params, bats)
        out = {"upd": upd, "task_loss": task_loss}
        if wire_only:
            out["packed"] = jax.vmap(eng._sketch_client_packed)(upd)
            return out
        zs = jax.vmap(eng._sketch_client)(upd)              # (S/F, m) float32
        zs = rounds.corrupt_cohort(
            cfg.adversary, zs, idx_rows, rnd, cfg.num_clients
        )
        if cfg.diagnostics:
            out["zs"] = zs                                   # pre-EF (Eq. 28)
        if cfg.error_feedback:
            _, signs, out["ef"] = eng._ef_quantize(zs, ef)
        else:
            signs = jnp.sign(zs) + (zs == 0)                 # {-1,+1}
        signs = rounds.privatize_signs(cfg.privacy, signs, idx_rows, rnd)
        out["packed"] = eng._pack_uplink(signs)
        return out

    fed = P("fed")
    out_specs = {"upd": fed, "task_loss": fed, "packed": fed}
    if cfg.diagnostics:
        out_specs["zs"] = fed
    if cfg.error_feedback:
        out_specs["ef"] = fed
    with eng.tracer.span("client_wire", track="sharded",
                         shards=cfg.fed_shards, wire_only=wire_only):
        res = shard_map(
            client_shards,
            mesh=eng.fed_mesh,
            in_specs=(fed, fed, fed, P(), P(), fed),
            out_specs=out_specs,
            check_rep=False,
        )(clients_s, batches_s, idx, state.round, state.v, ef_s)
    return idx, active, w_s, res


def sharded_round(eng, state, batches, weights, key, participants=None):
    """One shard_map federation round. Same contract as PFed1BS.round:
    batches (K, R, B, ...) pytree, weights (K,) p_k, optional externally
    drawn participants (idx, active) -> (state', metrics).

    Requires cfg.participate % cfg.fed_shards == 0 (checked at engine
    construction); each fed shard owns S/F clients for the round.
    """
    cfg = eng.cfg
    m = eng.m
    pad = (-m) % 32
    nw = (m + pad) // 32

    idx, active, w_s, res = _client_wire(
        eng, state, batches, weights, key, participants
    )

    # ---- the wire ----------------------------------------------------------
    # res["packed"] is the (S, nw) uint32 uplink; replicating it for the
    # server step below is the all-gather of S*m bits — the ONLY fed-axis
    # traffic besides the m-bit consensus broadcast (plus optional
    # diagnostics, see module docstring).
    packed = res["packed"]

    with eng.tracer.span("vote", track="sharded", kind=cfg.vote,
                         defense=cfg.defense):
        if cfg.vote == "popcount":
            # word-level integer majority — the uniform-p_k specialization of
            # Lemma 1; `weights` does NOT enter the vote. The vote_uniform_ok
            # metric (below) flags rounds where the sampled weights were not
            # actually uniform and the consensus therefore differs from the
            # weighted Lemma 1 object.
            new_rep = state.rep
            if cfg.defense == "trim":
                # trimmed vote stays on the wire words: XOR-popcount Hamming
                # ranking against a provisional packed consensus
                # (kernels/ops.py::vote_packed_trimmed; ties -> +1 like every
                # packed path). `active` doubles as the uniform weight vector
                # so dropped-out rows neither vote nor get trimmed.
                vw = consensus.trimmed_vote_packed(
                    packed, active, eng.trim_count
                )
            else:
                vw = consensus.majority_vote_popcount(packed)
            v_new = kops.unpack_signs(vw)[:m]
        else:
            # Lemma 1 exactly: unpack server-side, vote in natural client
            # order with zero weights on non-sampled rows, routed through the
            # configured defense (eng.vote_defended — the same float
            # accumulation as the fused round, see §4 note on vote ordering),
            # hence bit-exact with it on a 1-device mesh.
            pm = kops.unpack_signs(packed)[:, :m]
            v_new, new_rep = eng.vote_defended(pm, idx, w_s, state.rep)

    # ---- simulator state bookkeeping (not wire traffic) --------------------
    clients = rounds.scatter_rows(state.clients, idx, res["upd"], active)
    new_ef = state.ef
    if cfg.error_feedback:
        ef_rows = jnp.where(active[:, None] > 0, res["ef"], state.ef[idx])
        new_ef = state.ef.at[idx].set(ef_rows)

    w_norm = jnp.maximum(jnp.sum(w_s), 1e-9)
    metrics = {
        "task_loss": jnp.sum(res["task_loss"] * w_s) / w_norm,
        "uplink_bits": jnp.sum(active) * m,
        "downlink_bits": jnp.float32(m),
        "packed_words": jnp.float32(nw),
    }
    if cfg.vote == "popcount":
        # 1.0 iff the sampled weights really were uniform, i.e. the integer
        # vote computed the same object as weighted Lemma 1 would have.
        # (An external participation draw with dropped-out rows zeroes some
        # weights, so it also trips this flag: popcount counts every sampled
        # row — use vote="exact" with straggler/availability scenarios.)
        metrics["vote_uniform_ok"] = jnp.all(w_s == w_s[0]).astype(jnp.float32)
    if cfg.diagnostics:
        zs = res["zs"]
        corr = zs + state.ef[idx] if cfg.error_feedback else zs
        metrics["potential"] = eng._potential_from_sketches(
            res["upd"], zs, v_new, res["task_loss"], w_s
        )
        metrics["sign_agreement"] = jnp.mean(
            (corr * v_new[None, :] > 0).astype(jnp.float32)
        )
    # FLState is a NamedTuple; _replace avoids importing core from launch
    # (core.pfed1bs lazily imports this module inside round()).
    state = state._replace(
        clients=clients, v=v_new, round=state.round + 1, ef=new_ef,
        rep=new_rep,
    )
    return state, metrics


def tree_counts(packed, topo, tracer=None):
    """Aggregate packed uplink words through the topology's counter tree:
    per-leaf partial popcount counters, merged `fan_out` consecutive nodes
    at a time until the root holds the (W, 32) int32 global counts.

    Merge order follows the topology level by level to mirror what a real
    deployment ships — though by integer associativity ANY order yields the
    same counts (core/consensus.tree_vote_popcount's contract).

    The optional tracer records one span per merge tier. This runs inside
    the jitted round, so the spans land on the "jit-trace" track at trace
    time — they show the tree's structure (tier count, node widths), not
    steady-state runtime (DESIGN.md §12).
    """
    tr = obstrace.NOOP if tracer is None else tracer
    counters, start = [], 0
    with tr.span("tree_counts:leaves", track="hier",
                 leaves=len(topo.leaf_sizes)):
        for ls in topo.leaf_sizes:
            counters.append(
                kops.popcount_partial(packed[start : start + int(ls)])
            )
            start += int(ls)
    level = 0
    while len(counters) > 1:
        level += 1
        with tr.span(f"tree_counts:merge_tier{level}", track="hier",
                     nodes_in=len(counters), fan_out=topo.fan_out):
            counters = [
                kops.merge_counters(jnp.stack(counters[i : i + topo.fan_out]))
                for i in range(0, len(counters), topo.fan_out)
            ]
    return counters[0]


def hier_round(eng, state, batches, weights, key, participants=None):
    """One hierarchical federation round (DESIGN.md §11): the client side is
    the SAME collective-free shard_map as `sharded_round` (_client_wire),
    but the uplink words are aggregated through cfg.topology's counter tree
    — leaves emit partial popcount counters, interior tiers sum them, and
    only the root finishes the vote. Bit-exact with the flat popcount
    executor for every topology (tests/test_hier.py), because counting is
    integer addition; the win is the wire shape: root ingress is
    fan_out * ceil(log2(width+1)) * m bits instead of S * m
    (fl/comms.hier_round_bits).

    Defense="trim" runs the SAME two-pass rank-and-drop as
    trimmed_vote_packed, with both votes finished from tree counts and the
    Hamming distances computable leaf-locally against the broadcast
    provisional consensus; the RANKING itself is root-side — it needs the
    global order, which is exactly why the defended votes live at the root
    (ISSUE 7 / PR 6 design). Bit-exact with the flat trimmed packed vote
    since every weight is 0/1: the float vote sum 2*cnt - k is
    integer-exact in fp32. RandomizedResponse debiasing is a uniform
    positive weight scaling — provably a no-op on an unweighted sign vote —
    so the popcount paths (flat and tree) coincide with the debiased vote
    by construction.

    Requires cfg.vote="popcount" and sum(topology.leaf_sizes) ==
    cfg.participate (checked at engine construction).
    """
    cfg = eng.cfg
    topo = cfg.topology
    m = eng.m
    pad = (-m) % 32
    nw = (m + pad) // 32
    s = cfg.participate

    idx, active, w_s, res = _client_wire(
        eng, state, batches, weights, key, participants
    )
    packed = res["packed"]                                   # (S, nw) uint32

    new_rep = state.rep
    if cfg.defense == "trim":
        # Pass 1 — provisional consensus over the ACTIVE voters: inactive
        # rows' words are zeroed (contributing nothing to any count) and the
        # threshold is the active head-count, which reproduces
        # vote_packed_trimmed's unweighted 0/1-weight float vote exactly.
        aw = active > 0
        voters = jnp.sum(aw.astype(jnp.int32))
        vw0 = kops.finish_vote_counts(
            tree_counts(jnp.where(aw[:, None], packed, jnp.uint32(0)), topo,
                        tracer=eng.tracer),
            voters,
        )
        # Leaf-local disagreement vs the broadcast provisional consensus;
        # ranking/trim happen at the root where the global order exists.
        d = kops.hamming_packed(packed, vw0)
        score = jnp.where(active > 0, d, -1)                 # non-voters last
        t = jnp.minimum(jnp.asarray(eng.trim_count, jnp.int32),
                        jnp.maximum(voters - 1, 0))
        order = jnp.argsort(-score)                          # stable ties
        ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
        kept = jnp.where(ranks < t, 0.0, active)
        # Pass 2 — revote over the kept voters through the tree again.
        kw = kept > 0
        vw = kops.finish_vote_counts(
            tree_counts(jnp.where(kw[:, None], packed, jnp.uint32(0)), topo,
                        tracer=eng.tracer),
            jnp.sum(kw.astype(jnp.int32)),
        )
    else:
        # undefended: count ALL S sampled rows, threshold at S — identical
        # to majority_vote_popcount(packed) (the flat executor's object).
        vw = kops.finish_vote_counts(
            tree_counts(packed, topo, tracer=eng.tracer), s
        )
    v_new = kops.unpack_signs(vw)[:m]

    # ---- simulator state bookkeeping (not wire traffic) --------------------
    clients = rounds.scatter_rows(state.clients, idx, res["upd"], active)
    new_ef = state.ef
    if cfg.error_feedback:
        ef_rows = jnp.where(active[:, None] > 0, res["ef"], state.ef[idx])
        new_ef = state.ef.at[idx].set(ef_rows)

    # per-tier billing: client->leaf uplink is the realized sum(active)*m
    # (a dropped-out client transmits nothing); the aggregator tiers always
    # ship their counters — counter bits depend on tier WIDTH, not on how
    # many of the covered clients showed up (a counter of a quiet subtree
    # is a valid all-zero count). Static per topology, so python ints here.
    hb = topo.round_bits(m)
    tier_bits = sum(hb["tier_uplink_bits"])
    w_norm = jnp.maximum(jnp.sum(w_s), 1e-9)
    metrics = {
        "task_loss": jnp.sum(res["task_loss"] * w_s) / w_norm,
        "uplink_bits": jnp.sum(active) * m + tier_bits,
        "downlink_bits": jnp.float32(hb["downlink_bits"]),
        "packed_words": jnp.float32(nw),
        "tier_uplink_bits": jnp.float32(tier_bits),
        "root_ingress_bits": jnp.float32(hb["root_ingress_bits"]),
        "tiers": jnp.float32(hb["tiers"]),
        # same uniformity tripwire as the flat popcount executor
        "vote_uniform_ok": jnp.all(w_s == w_s[0]).astype(jnp.float32),
    }
    if cfg.diagnostics:
        zs = res["zs"]
        corr = zs + state.ef[idx] if cfg.error_feedback else zs
        metrics["potential"] = eng._potential_from_sketches(
            res["upd"], zs, v_new, res["task_loss"], w_s
        )
        metrics["sign_agreement"] = jnp.mean(
            (corr * v_new[None, :] > 0).astype(jnp.float32)
        )
    state = state._replace(
        clients=clients, v=v_new, round=state.round + 1, ef=new_ef,
        rep=new_rep,
    )
    return state, metrics


def sharded_baseline_round(eng, params, batches_s, pw, keys):
    """Client side of a BaselineFL round over the `fed` mesh (DESIGN.md §8).

    The S sampled clients are split across the F fed shards; each shard runs
    its clients' R local SGD steps and the per-client compress->decompress
    `_encode` (core/baselines.py) with ZERO collectives, reduces its own
    weighted partial sum, and the fed axis is crossed once by a psum of the
    (n,) aggregate + the scalar loss partial — the simulator analogue of S
    uplinks meeting at the server. The global model `params` is replicated
    (every real client holds the downlinked model).

    eng: BaselineFL; params: global-model pytree (replicated);
    batches_s: (S, R, B, ...) pytree; pw: (S,) masked weights (weight 0 =
    dropped out — its encode result is computed but annihilated, like a
    straggler whose upload never lands); keys: (S,) per-client PRNG keys.
    Returns (agg (n,), task_loss_weighted_sum ()) — the same aggregate the
    unsharded round feeds `_finish`.
    """
    fed = P("fed")

    def shard(p, bats, w, ks):
        deltas, losses = jax.vmap(
            lambda b: eng._local_delta(p, b)
        )(bats)
        recs = jax.vmap(eng._encode)(deltas, ks)
        part = jnp.einsum("k,kn->n", w, recs)
        lpart = jnp.sum(losses * w)
        return (
            jax.lax.psum(part, "fed"),
            jax.lax.psum(lpart, "fed"),
        )

    return shard_map(
        shard,
        mesh=eng.fed_mesh,
        in_specs=(P(), fed, fed, fed),
        out_specs=(P(), P()),
        check_rep=False,
    )(params, batches_s, pw, keys)


# ---------------------------------------------------------------------------
# fed_lm: pFed1BS over a real models/lm.py architecture (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The LM path composes two parallelism regimes on ONE 2-D ("fed", "model")
# mesh (launch/mesh.make_fed_model_mesh): the client store's K axis lays
# out over `fed` while each client's parameter leaves shard over `model`
# per sharding/specs.param_pspecs (Megatron TP). Unlike the 1-D executors
# above this is NOT a shard_map region — models/lm.py is written
# GSPMD-style, so the round is the ordinary fused `PFed1BS.round` program
# with its inputs placed by NamedSharding and the partitioner propagating
# the layout. The §6 wire discipline survives by construction: per-client
# work is independent along K, so the ONLY fed-axis crossings GSPMD can
# emit are the vote's sum over clients and the scalar metrics — the m-bit
# consensus + diagnostics, exactly the Table-2 traffic. TP collectives
# WITHIN a client (the usual Megatron all-reduces of the forward/backward)
# stay inside the `model` submesh, and because the engine is built with
# sharding/specs.param_major_axes, every leaf's SRHT chunks flatten
# sharded-axis-major — no FHT block straddles a model shard. On a (1, 1)
# debug mesh the placed round is the SAME jitted program as the unplaced
# fused round, hence bit-exact (tests/test_fed_lm.py).


def make_fed_lm_engine(arch, fl_cfg, *, mesh=None, tracer=None):
    """Bind PFed1BS to a real models/lm.py architecture.

    arch: models/config.ArchConfig (a registry entry or its .reduced());
    fl_cfg: PFed1BSConfig — layout must be "leaf" (the flat layout would
    ravel the LM: the O(n) materialization this path exists to avoid);
    cfg.trainable selects the LoRA-style subset by leaf path. mesh:
    a ("fed", "model") mesh (default: make_fed_model_mesh(1, 1)).

    Returns (engine, mesh, template). The engine's tspec is built with the
    mesh's param_major_axes so leaf chunks never straddle model shards.
    """
    import functools

    from repro.core.pfed1bs import PFed1BS
    from repro.models import lm
    from repro.sharding import specs as shspec

    assert fl_cfg.layout == "leaf", "fed_lm requires layout='leaf'"
    if mesh is None:
        from repro.launch.mesh import make_fed_model_mesh

        mesh = make_fed_model_mesh(1, 1)
    assert "fed" in mesh.shape and "model" in mesh.shape, mesh.shape
    template = jax.eval_shape(
        functools.partial(lm.init_params, arch), jax.random.PRNGKey(0)
    )
    major = shspec.param_major_axes(arch, template, mesh)

    def loss(p, b):
        return lm.loss_fn(arch, p, b)[0]

    eng = PFed1BS(fl_cfg, loss, template, tracer=tracer, major_axes=major)
    return eng, mesh, template


def fed_lm_shardings(arch, template, mesh):
    """NamedShardings placing an FLState on the ("fed", "model") mesh:
    stacked clients K-major over `fed` with each leaf's TP axis over
    `model` (sharding/specs.param_pspecs shifted one stacking axis right);
    consensus v and the round counter replicated (every client receives
    the same m-bit broadcast); EF residuals / reputation row-shard over
    `fed` with their owning clients. cfg.num_clients must divide the fed
    axis size for an even client layout (GSPMD handles ragged, but the
    fed_lm benches keep it even)."""
    from jax.sharding import NamedSharding

    from repro.sharding import specs as shspec

    pspecs = shspec.param_pspecs(arch, template, mesh)
    clients = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*(("fed",) + tuple(s)))),
        pspecs, is_leaf=lambda x: isinstance(x, P),
    )
    rep = NamedSharding(mesh, P())
    return {
        "clients": clients,
        "v": rep,
        "round": rep,
        "ef": NamedSharding(mesh, P("fed", None)),
        "rep": NamedSharding(mesh, P("fed")),
        "batches": NamedSharding(mesh, P("fed")),
    }


def place_fed_lm_state(state, shardings):
    """device_put an FLState per `fed_lm_shardings` (None fields pass
    through). After placement, `PFed1BS.round` compiles under GSPMD with
    clients resident along `fed` — the fed_lm round IS the fused round on
    placed operands."""
    put = lambda x, s: None if x is None else jax.device_put(x, s)
    return state._replace(
        clients=jax.device_put(state.clients, shardings["clients"]),
        v=jax.device_put(state.v, shardings["v"]),
        round=jax.device_put(state.round, shardings["round"]),
        ef=put(state.ef, shardings["ef"]),
        rep=put(state.rep, shardings["rep"]),
    )


def place_fed_lm_batches(batches, shardings):
    """Place a (K, R, B, ...) batch pytree client-major over `fed`
    (trailing dims replicated — sequence batches are small next to the
    model; shard them over `model` via sharding/specs.batch_pspecs if
    that ever inverts)."""
    return jax.tree.map(
        lambda a: jax.device_put(a, shardings["batches"]), batches
    )
