import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config lowers and compiles for
every (architecture x input shape x mesh) combination, and extract the
roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 combos, single-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json and
feed EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.models import io, lm
from repro.sharding import specs as sh

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# TPU v5e single-chip constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def skip_reason(cfg, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return (
            "full-attention architecture without a sub-quadratic variant: "
            "long_500k decode skipped per brief (see DESIGN.md §5)"
        )
    return None


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective in a post-SPMD module."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += size * nbytes
    return out


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float, chips: int) -> dict:
    """Seconds per step for each roofline term (flops/bytes are PER-DEVICE —
    post-SPMD modules are per-partition programs)."""
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }


def model_flops(cfg, seq: int, batch: int, kind: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful-compute reference."""
    tmpl = st.param_template(cfg)
    n_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tmpl))
    if cfg.n_experts:
        # subtract non-active expert params
        per_expert = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tmpl)[0]:
            kp = jax.tree_util.keystr(path)
            if "'w1'" in kp or "'w2'" in kp:
                if leaf.ndim == 4:  # (L, E, ., .)
                    per_expert += int(np.prod(leaf.shape)) // leaf.shape[1]
        n_active = n_total - per_expert * (cfg.n_experts - cfg.top_k)
    else:
        n_active = n_total
    tokens = batch * (seq if kind != "decode" else 1)
    mult = 6 if kind == "train" else 2
    return float(mult * n_active * tokens), n_total


def lower_one(arch: str, shape_name: str, *, multi_pod: bool, hyper: st.StepHyper,
              reduced: bool = False, moe_impl: str = "dense", tag: str = "",
              dtype: str = "bfloat16", attn_shard: str = "auto",
              remat: bool = True):
    cfg = configs.get(arch)
    cfg = dataclasses.replace(cfg, param_dtype=dtype, compute_dtype=dtype,
                              moe_impl=moe_impl, attn_shard=attn_shard,
                              remat=remat)
    if reduced:
        cfg = dataclasses.replace(cfg.reduced(), param_dtype=dtype,
                                  compute_dtype=dtype, moe_impl=moe_impl,
                                  attn_shard=attn_shard, remat=remat)
    sdesc = SHAPES[shape_name]
    seq, batch, kind = sdesc["seq"], sdesc["batch"], sdesc["kind"]
    if reduced:
        seq, batch = min(seq, 512), min(batch, 16)
        if cfg.family == "vlm":
            seq = max(seq, cfg.num_patches + 64)

    reason = skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(tuple(mesh.shape.values())))
    record = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": dict(mesh.shape), "chips": chips, "multi_pod": multi_pod,
        "seq": seq, "batch": batch, "dtype": dtype, "tag": tag,
        "hyper": dataclasses.asdict(hyper),
    }
    t0 = time.time()

    with mesh:
        if kind == "train" and multi_pod:
            npods = mesh.shape["pod"]
            step, tspec = st.make_round_step(cfg, hyper, mesh, npods)
            (tmpl, bspecs, v_sds), (pshard, bshard, vshard) = st.train_inputs(
                cfg, hyper, mesh, batch // npods, seq, tspec, multi_client=npods
            )
            v_sds_c = v_sds  # consensus shared across pods
            w_sds = jax.ShapeDtypeStruct((npods,), jnp.float32)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, bshard, vshard, sh.replicated(mesh)),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(tmpl, bspecs, v_sds_c, w_sds)
        elif kind == "train":
            step, tmpl, tspec, pspec, vspec = st.make_train_step(cfg, hyper, mesh)
            (tmpl_i, bspecs, v_sds), (pshard, bshard, vshard) = st.train_inputs(
                cfg, hyper, mesh, batch, seq, tspec
            )
            jitted = jax.jit(step, in_shardings=(pshard, bshard, vshard),
                             donate_argnums=(0,))
            lowered = jitted.lower(tmpl_i, bspecs, v_sds)
        elif kind == "prefill":
            step = st.make_prefill_step(cfg)
            tmpl = st.param_template(cfg)
            pspec = sh.param_pspecs(cfg, tmpl, mesh)
            bspecs = io.batch_specs(cfg, batch, seq)
            # multi-pod serving shards the batch over ('pod','data') —
            # handled inside batch_pspecs via _dp_axes
            bspec = sh.batch_pspecs(cfg, bspecs, mesh)
            jitted = jax.jit(
                step,
                in_shardings=(sh.to_named(mesh, pspec), sh.to_named(mesh, bspec)),
            )
            lowered = jitted.lower(tmpl, bspecs)
        else:  # decode
            step = st.make_serve_step(cfg)
            sds, shardings = st.serve_inputs(cfg, mesh, batch, seq)
            jitted = jax.jit(step, in_shardings=shardings, donate_argnums=(2,))
            lowered = jitted.lower(*sds)

        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    record["cost_analysis"] = {"flops": flops, "bytes_accessed": hbm}

    try:
        ma = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)
        }
    except Exception as e:  # noqa: BLE001 - backend-dependent availability
        record["memory_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    record["collectives"] = colls
    coll_total = sum(v["bytes"] for v in colls.values())
    record["roofline"] = roofline_terms(flops, hbm, coll_total, chips)
    record["roofline"]["collective_bytes_total"] = coll_total
    dom = max(record["roofline"], key=lambda k: record["roofline"][k] if k.endswith("_s") else -1)
    record["roofline"]["dominant"] = dom
    mf, n_total = model_flops(cfg, seq, batch, kind)
    record["model_flops_global"] = mf
    record["param_count"] = n_total
    # compiled module is per-device: compare against per-device share
    record["useful_flops_ratio"] = (mf / chips) / flops if flops else 0.0
    record["status"] = "ok"
    return record


def artifact_path(arch, shape_name, multi_pod, tag=""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        "experiments", "dryrun", f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=configs.ARCH_NAMES + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="smoke-scale shapes")
    ap.add_argument("--moe-impl", default="dense", choices=["dense", "sorted", "grouped"])
    ap.add_argument("--attn-shard", default="auto", choices=["auto", "seq"])
    ap.add_argument("--packed-vote", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--sketch-layout", default="leaf", choices=["leaf", "flat"])
    ap.add_argument("--no-sketch", action="store_true")
    ap.add_argument("--chunk", type=int, default=16384)
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    hyper = st.StepHyper(
        sketch_layout=args.sketch_layout,
        include_sketch=not args.no_sketch,
        chunk=args.chunk,
        packed_vote=args.packed_vote,
    )
    combos = (
        [(a, s) for a in configs.ARCH_NAMES for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    failures = 0
    for arch, shape_name in combos:
        path = args.out or artifact_path(arch, shape_name, args.multi_pod, args.tag)
        try:
            rec = lower_one(
                arch, shape_name, multi_pod=args.multi_pod, hyper=hyper,
                reduced=args.reduced, moe_impl=args.moe_impl, tag=args.tag,
                dtype=args.dtype, attn_shard=args.attn_shard,
                remat=not args.no_remat,
            )
        except Exception as e:  # noqa: BLE001 - report & continue the sweep
            rec = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            failures += 1
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (
                f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                f"coll={r['collective_s']:.3e}s dom={r['dominant']} "
                f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)"
            )
        elif status == "skipped":
            extra = rec["reason"][:80]
        else:
            extra = rec["error"][:160]
        print(f"[{status:7s}] {arch:24s} {shape_name:12s} {extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} combos failed")


if __name__ == "__main__":
    main()
