"""Step builders shared by the dry-run, the trainer and the benchmarks.

Four lowered programs per architecture:

  train_step  — one pFed1BS client step at scale: task grad (CE over the
                assigned LLM) + lam * Phi^T(tanh(gamma Phi w) - v) + mu*w,
                SGD update. The sketch is the sharding-aware tree sketch.
  round_step  — multi-pod federation round: pod axis = client axis; one
                local step + fresh one-bit sketches + cross-pod weighted
                majority vote (the only cross-pod traffic).
  prefill     — forward over the prompt, last-position logits.
  serve_step  — one new token against a seq_len KV cache / SSM state.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import treesketch as ts
from repro.models import io, lm
from repro.models.config import ArchConfig
from repro.sharding import specs as sh


@dataclasses.dataclass(frozen=True)
class StepHyper:
    lr: float = 0.02
    lam: float = 5e-4
    mu: float = 1e-5
    gamma: float = 1e4
    m_ratio: float = 0.1
    chunk: int = 16384
    sketch_layout: str = "leaf"     # leaf (sharded) | flat (paper-literal)
    include_sketch: bool = True     # regularizer+sketch inside train_step
    packed_vote: bool = False       # cross-pod vote on packed uint32 words
    #                                 (shard_map all-gather of m/32 words
    #                                 instead of an f32 all-reduce; §Perf)


def param_template(cfg: ArchConfig):
    return jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.key(0))


def build_tree_spec(cfg: ArchConfig, hyper: StepHyper, mesh):
    tmpl = param_template(cfg)
    majors = (
        sh.param_major_axes(cfg, tmpl, mesh)
        if hyper.sketch_layout == "leaf"
        else None
    )
    return ts.make_tree_sketch_spec(
        tmpl, hyper.m_ratio, chunk=hyper.chunk, major_axes=majors
    )


# ---------------------------------------------------------------------------
# train_step (single client-cohort; one pod)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, hyper: StepHyper, mesh):
    tmpl = param_template(cfg)
    tspec = build_tree_spec(cfg, hyper, mesh) if hyper.include_sketch else None

    def train_step(params, batch, v):
        def obj(p):
            loss, metrics = lm.loss_fn(cfg, p, batch)
            return loss, metrics

        (loss, _), grads = jax.value_and_grad(obj, has_aux=True)(params)
        if tspec is not None:
            rval, rgrad = ts.tree_reg_value_and_grad(
                tspec, params, v, hyper.gamma, hyper.lam, hyper.mu
            )
            grads = jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, rgrad)
            loss = loss + rval
        params = jax.tree.map(
            lambda p, g: p - hyper.lr * g.astype(p.dtype), params, grads
        )
        return params, loss

    pspec = sh.param_pspecs(cfg, tmpl, mesh)
    vspec = ts.sketch_pspecs(tspec, pspec, mesh) if tspec is not None else {}
    return train_step, tmpl, tspec, pspec, vspec


def train_inputs(cfg, hyper, mesh, batch, seq, tspec, multi_client=0):
    """ShapeDtypeStructs + shardings for (params, batch, v)."""
    tmpl = param_template(cfg)
    pspec = sh.param_pspecs(cfg, tmpl, mesh)
    bspecs = io.batch_specs(cfg, batch, seq)
    if multi_client:  # stack the client axis BEFORE computing shardings
        bspecs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((multi_client,) + s.shape, s.dtype), bspecs
        )
    bpspec = sh.batch_pspecs(cfg, bspecs, mesh, client_axis=bool(multi_client))
    vspec_tree = ts.sketch_pspecs(tspec, pspec, mesh) if tspec is not None else {}
    v_sds = (
        {
            path: jax.ShapeDtypeStruct((spec.num_chunks, spec.m_chunk), jnp.float32)
            for path, spec, _, _ in tspec.entries
        }
        if tspec is not None
        else {}
    )
    if multi_client:
        tmpl = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((multi_client,) + s.shape, s.dtype), tmpl
        )
        pspec = jax.tree.map(lambda s: P(*(("pod",) + tuple(s))), pspec,
                             is_leaf=lambda x: isinstance(x, P))
    shardings = (
        sh.to_named(mesh, pspec),
        sh.to_named(mesh, bpspec),
        sh.to_named(mesh, vspec_tree),
    )
    return (tmpl, bspecs, v_sds), shardings


# ---------------------------------------------------------------------------
# round_step (multi-pod: pod axis = federation axis)
# ---------------------------------------------------------------------------

def make_round_step(cfg: ArchConfig, hyper: StepHyper, mesh, n_clients: int):
    tspec = build_tree_spec(cfg, hyper, mesh)
    vspec_by_path = dict(
        (path, spec) for path, spec, _, _ in tspec.entries
    )
    sharded_paths = {
        path: pspec
        for path, pspec in ts.sketch_pspecs(
            tspec, sh.param_pspecs(cfg, param_template(cfg), mesh), mesh
        ).items()
    }

    def _packed_vote_leaf(path, zz, weights):
        """Cross-pod vote on PACKED words: all-gather m/32 uint32 per client
        instead of all-reducing m float32 partial sums (32x wire reduction —
        the honest one-bit downlink)."""
        from jax.experimental.shard_map import shard_map
        from repro.kernels import ops as kops

        k, nc, mc = zz.shape
        pad = (-mc) % 32
        zp = kops.pack_signs(jnp.pad(zz, ((0, 0), (0, 0), (0, pad))))
        row_spec = sharded_paths[path]  # P("model",None) or P(None,None)
        row_axis = row_spec[0]          # "model" | None

        def local(zp_l, w_l):
            zall = jax.lax.all_gather(zp_l, "pod", axis=0, tiled=True)  # (K,...)
            pm = kops.unpack_signs(zall)
            s = jnp.einsum("k,kcm->cm", w_l, pm)
            return jnp.where(s >= 0, 1.0, -1.0)

        v = shard_map(
            local, mesh=mesh,
            in_specs=(P("pod", row_axis, None), P()),
            out_specs=P(row_axis, None),
            check_rep=False,
        )(zp, weights)
        return v[:, :mc]

    def round_step(clients, batch, v, weights):
        def one(p, b):
            def obj(q):
                loss, _ = lm.loss_fn(cfg, q, b)
                return loss

            loss, grads = jax.value_and_grad(obj)(p)
            _, rgrad = ts.tree_reg_value_and_grad(
                tspec, p, v, hyper.gamma, hyper.lam, hyper.mu
            )
            grads = jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, rgrad)
            p = jax.tree.map(lambda a, g: a - hyper.lr * g.astype(a.dtype), p, grads)
            z = ts.tree_sketch_forward(tspec, p)
            z = {k: jnp.sign(zz) + (zz == 0) for k, zz in z.items()}
            return p, z, loss

        newp, zs, losses = jax.vmap(one)(clients, batch)
        # weighted majority vote per sketch block — the ONLY cross-pod traffic
        if hyper.packed_vote:
            v_new = {k: _packed_vote_leaf(k, zz, weights) for k, zz in zs.items()}
        else:
            v_new = {
                k: jnp.sign(jnp.einsum("k,kcm->cm", weights, zz))
                for k, zz in zs.items()
            }
        return newp, v_new, jnp.mean(losses)

    return round_step, tspec


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return lm.prefill(cfg, params, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, token, cache, pos):
        return lm.decode_step(cfg, params, token, cache, pos)

    return serve_step


def serve_inputs(cfg: ArchConfig, mesh, batch: int, seq: int):
    """Specs + shardings for (params, token, cache, pos)."""
    tmpl = param_template(cfg)
    pspec = sh.param_pspecs(cfg, tmpl, mesh)
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, seq, enc_len=seq))
    cspec = sh.cache_pspecs(cfg, cache, mesh)
    tok = io.decode_token_spec(cfg, batch)
    tok_spec = jax.tree.leaves(
        sh.batch_pspecs(cfg, {"t": tok}, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )[0]
    shardings = (
        sh.to_named(mesh, pspec),
        NamedSharding(mesh, tok_spec),
        sh.to_named(mesh, cspec),
        NamedSharding(mesh, P()),
    )
    sds = (tmpl, tok, cache, jax.ShapeDtypeStruct((), jnp.int32))
    return sds, shardings
