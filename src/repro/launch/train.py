"""End-to-end federated training driver.

Tasks:
  mlp / vgg     — the paper's own experiment models on synthetic non-iid
                  image classification (label-skew partition).
  lm:<arch>     — federated fine-tuning of a REDUCED assigned architecture
                  on per-client skewed token streams.

Algorithms: pfed1bs (ours) or any baseline (fedavg/obda/obcsaa/zsignfed/
eden/fedbat). Emits per-round metrics JSON + final personalized/global
accuracy, and writes per-client checkpoints.

Examples:
  PYTHONPATH=src python -m repro.launch.train --task mlp --algo pfed1bs --rounds 30
  PYTHONPATH=src python -m repro.launch.train --task lm:granite-8b --rounds 10
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.core.baselines import BaselineConfig, BaselineFL
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.fl import comms
from repro.models import lm, smallnets as sn


def build_task(args, key):
    """Returns (init_fn, loss_fn, eval_fn, data, sample_batches, n_tensors)."""
    if args.task in ("mlp", "vgg"):
        hw, ch = (28, 1) if args.task == "mlp" else (32, 3)
        data = ds.make_federated_classification(
            key, num_clients=args.clients, image_hw=hw, channels=ch,
            train_per_client=args.train_per_client,
            test_per_client=args.test_per_client,
            classes_per_client=args.classes_per_client, noise=args.noise,
        )
        if args.task == "mlp":
            init_fn = lambda k: sn.init_mlp(k, input_dim=hw * hw * ch, hidden=args.hidden)
            apply_fn = sn.apply_mlp
        else:
            init_fn = lambda k: sn.init_vgg(k, input_hw=hw, channels=ch)
            apply_fn = sn.apply_vgg

        def loss_fn(params, batch):
            return sn.softmax_xent(apply_fn(params, batch["x"]), batch["y"])

        def eval_fn(params, x, y):
            return sn.accuracy(apply_fn(params, x), y)

        sample = lambda k: ds.sample_round_batches(k, data, args.local_steps, args.batch)
        return init_fn, loss_fn, eval_fn, data, sample

    if args.task.startswith("lm:"):
        arch = args.task.split(":", 1)[1]
        cfg = configs.get(arch).reduced()
        data = ds.make_federated_lm(
            key, args.clients, vocab=cfg.vocab, seq=args.seq,
            samples_per_client=args.train_per_client,
        )
        init_fn = lambda k: lm.init_params(cfg, k)

        def loss_fn(params, batch):
            loss, _ = lm.loss_fn(cfg, params, batch)
            return loss

        def eval_fn(params, tokens):
            batch = {"tokens": tokens[..., :-1], "labels": tokens[..., 1:]}
            loss, _ = lm.loss_fn(cfg, params, batch)
            return -loss  # higher is better (negative CE)

        sample = lambda k: ds.sample_lm_batches(k, data, args.local_steps, args.batch)
        return init_fn, loss_fn, eval_fn, data, sample

    raise ValueError(args.task)


def evaluate(args, engine, state, eval_fn, data):
    if args.task.startswith("lm:"):
        if hasattr(state, "clients"):
            vals = jax.vmap(lambda p, t: eval_fn(p, t))(state.clients, data.tokens)
        else:
            vals = jax.vmap(lambda t: eval_fn(state.params, t))(data.tokens)
        return {"neg_ce": float(jnp.mean(vals))}
    if hasattr(state, "clients"):  # personalized
        accs = jax.vmap(eval_fn)(state.clients, data.test_x, data.test_y)
    else:  # single global model
        accs = jax.vmap(lambda x, y: eval_fn(state.params, x, y))(data.test_x, data.test_y)
    return {"accuracy_mean": float(accs.mean()), "accuracy_std": float(accs.std())}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="mlp")
    ap.add_argument("--algo", default="pfed1bs")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--participate", type=int, default=0, help="0 => all")
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--lam", type=float, default=5e-4)
    ap.add_argument("--mu", type=float, default=1e-5)
    ap.add_argument("--gamma", type=float, default=1e4)
    ap.add_argument("--m-ratio", type=float, default=0.1)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--hidden", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--noise", type=float, default=0.8)
    ap.add_argument("--classes-per-client", type=int, default=2)
    ap.add_argument("--train-per-client", type=int, default=256)
    ap.add_argument("--test-per-client", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--out", default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    participate = args.participate or args.clients

    key = jax.random.key(args.seed)
    init_fn, loss_fn, eval_fn, data, sample = build_task(args, key)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(template))
    n_tensors = len(jax.tree.leaves(template))

    if args.algo == "pfed1bs":
        cfg = PFed1BSConfig(
            num_clients=args.clients, participate=participate,
            local_steps=args.local_steps, lr=args.lr, lam=args.lam,
            mu=args.mu, gamma=args.gamma, m_ratio=args.m_ratio,
            chunk=args.chunk, sketch_seed=args.seed,
        )
        engine = PFed1BS(cfg, loss_fn, template)
        m_dim = engine.spec.m
    else:
        cfg = BaselineConfig(
            algo=args.algo, num_clients=args.clients, participate=participate,
            local_steps=args.local_steps, lr=args.lr, chunk=args.chunk,
            m_ratio=args.m_ratio, seed=args.seed,
        )
        engine = BaselineFL(cfg, loss_fn, template)
        m_dim = engine.spec.m
    state = engine.init(init_fn, jax.random.key(args.seed + 1))

    bits = comms.round_bits(args.algo, n=n, m=m_dim, s=participate,
                            num_tensors=n_tensors)
    history = []
    t0 = time.time()
    for r in range(args.rounds):
        kb, kr = jax.random.split(jax.random.fold_in(key, 1000 + r))
        state, metrics = engine.round(state, sample(kb), data.weights, kr)
        # scalars only: vector diagnostics (e.g. per-coordinate
        # vote_margins) are for the online health monitor, not the history
        rec = {"round": r, **{k: float(v) for k, v in metrics.items()
                              if np.ndim(v) == 0}}
        if (r + 1) % args.eval_every == 0 or r == args.rounds - 1:
            rec.update(evaluate(args, engine, state, eval_fn, data))
        history.append(rec)
        if not args.quiet and (r % args.eval_every == 0 or r == args.rounds - 1):
            print(f"[{args.algo}] round {r}: " + ", ".join(
                f"{k}={v:.4f}" for k, v in rec.items() if k != "round"), flush=True)

    result = {
        "args": vars(args), "n_params": n, "m": m_dim,
        "comm_per_round": bits,
        "comm_reduction_vs_fedavg": comms.reduction_vs_fedavg(
            args.algo, n=n, m=m_dim, s=participate, num_tensors=n_tensors),
        "final": history[-1], "history": history,
        "wall_s": round(time.time() - t0, 1),
    }
    out = args.out or os.path.join(
        "experiments", "runs", f"{args.task.replace(':', '_')}__{args.algo}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    if args.ckpt:
        tree = state.clients if hasattr(state, "clients") else state.params
        save_checkpoint(args.ckpt, tree, meta={"algo": args.algo, "rounds": args.rounds})
    if not args.quiet:
        print(json.dumps({k: result[k] for k in
                          ("n_params", "m", "comm_per_round", "final")}, indent=2))
    return result


if __name__ == "__main__":
    main()
