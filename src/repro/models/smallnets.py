"""The paper's own experiment models: 2-layer MLP (MNIST/FMNIST) and a
small VGG (CIFAR-10/100, SVHN), as pure-JAX functional nets."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _dense(key, fan_in, fan_out):
    k1, k2 = jax.random.split(key)
    w = jax.random.normal(k1, (fan_in, fan_out)) * np.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((fan_out,))}


def _conv(key, kh, kw, cin, cout):
    w = jax.random.normal(key, (kh, kw, cin, cout)) * np.sqrt(2.0 / (kh * kw * cin))
    return {"w": w, "b": jnp.zeros((cout,))}


# --- MLP (paper: "two-layer MLP for MNIST and FMNIST") ---------------------

def init_mlp(key, input_dim=784, hidden=200, classes=10):
    k1, k2 = jax.random.split(key)
    return {"fc1": _dense(k1, input_dim, hidden), "fc2": _dense(k2, hidden, classes)}


def apply_mlp(params, x):
    x = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


# --- VGG-small (paper: "VGG architectures for the other datasets") ---------

def init_vgg(key, input_hw=32, channels=3, classes=10, widths=(32, 64, 128)):
    ks = jax.random.split(key, len(widths) * 2 + 2)
    params = {"convs": []}
    cin = channels
    i = 0
    for w in widths:
        params["convs"].append(
            {"a": _conv(ks[i], 3, 3, cin, w), "b": _conv(ks[i + 1], 3, 3, w, w)}
        )
        cin = w
        i += 2
    feat_hw = input_hw // (2 ** len(widths))
    feat = feat_hw * feat_hw * widths[-1]
    params["fc1"] = _dense(ks[i], feat, 256)
    params["fc2"] = _dense(ks[i + 1], 256, classes)
    return params


def _conv2d(x, p):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + p["b"]


def apply_vgg(params, x):
    for blk in params["convs"]:
        x = jax.nn.relu(_conv2d(x, blk["a"]))
        x = jax.nn.relu(_conv2d(x, blk["b"]))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
