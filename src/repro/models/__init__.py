# Model substrate: transformer/SSM/MoE/hybrid/enc-dec families for the
# assigned architecture pool, plus the paper's own MLP/VGG nets.
