"""Batch construction: concrete random batches (tests/examples) and
ShapeDtypeStruct stand-ins (multi-pod dry-run; no device allocation).

Modality frontends are STUBS per the brief: for [vlm] the ViT+projector and
for [audio] the mel/conv feature extractor are not implemented — batches
carry precomputed patch/frame embeddings of the right shape, and the
language/decoder transformer consumes them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


def batch_shapes(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Shapes/dtypes of one training (or prefill) batch."""
    emb_dt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        st = seq - cfg.num_patches
        return {
            "patches": ((batch, cfg.num_patches, cfg.d_model), emb_dt),
            "tokens": ((batch, st), jnp.int32),
            "labels": ((batch, st), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "frames": ((batch, seq, cfg.d_model), emb_dt),
            "tokens": ((batch, seq), jnp.int32),
            "labels": ((batch, seq), jnp.int32),
        }
    return {
        "tokens": ((batch, seq), jnp.int32),
        "labels": ((batch, seq), jnp.int32),
    }


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    return {
        k: jax.ShapeDtypeStruct(shape, dt)
        for k, (shape, dt) in batch_shapes(cfg, batch, seq).items()
    }


def make_batch(cfg: ArchConfig, key, batch: int, seq: int) -> dict:
    out = {}
    for name, (shape, dt) in batch_shapes(cfg, batch, seq).items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(dt, jnp.integer):
            out[name] = jax.random.randint(sub, shape, 0, cfg.vocab, dtype=dt)
        else:
            out[name] = jax.random.normal(sub, shape, dt)
    return out


def checkpoint_leaf_reader(path: str):
    """Lazy per-leaf reader over a checkpoint/ckpt.py npz: returns
    (paths, get_leaf) where `paths` are the stored keystr leaf paths
    (sorted) and `get_leaf(path)` loads exactly that member from disk.

    np.load on an npz is lazy per member — each get_leaf decompresses one
    leaf, so feeding this to core/stream.stream_sketch encodes a
    checkpointed LM at O(max-leaf + m) peak host memory without the model
    ever being resident (DESIGN.md §13)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    return sorted(data.files), data.__getitem__


def decode_token_spec(cfg: ArchConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, 1), jnp.int32)


def make_decode_token(cfg: ArchConfig, key, batch: int) -> jax.Array:
    return jax.random.randint(key, (batch, 1), 0, cfg.vocab, dtype=jnp.int32)
