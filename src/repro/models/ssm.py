"""Selective state-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

TPU adaptation: the CUDA selective-scan kernel becomes a *chunked* scan —
`lax.scan` over sequence chunks with a `lax.associative_scan` inside each
chunk. This bounds live state-expansion memory to (B, chunk, d, N) per step
instead of (B, S, d, N) for the whole sequence, matching how VMEM-sized
tiles would stream on real hardware. Decode is the O(1) single-step
recurrence on a carried (B, d, N) state + a depthwise-conv ring buffer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, pdt, rms_norm


def _causal_conv(x, w, b):
    """Depthwise causal conv along S. x: (B,S,C); w: (C,K); b: (C,)."""
    k = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[None, None, :, i] for i in range(k))
    return out + b


def _conv_step(state, xt, w, b):
    """One-token conv. state: (B,K-1,C) past inputs; xt: (B,C)."""
    full = jnp.concatenate([state, xt[:, None, :]], axis=1)     # (B,K,C)
    out = jnp.einsum("bkc,ck->bc", full, w) + b
    return full[:, 1:], out


def _chunked_ssm_scan(decay, inp, c_coef, h0, chunk):
    """h_t = decay_t * h_{t-1} + inp_t ;  y_t = <h_t, C_t> over the state axis.

    decay/inp: (B, S, ..., N); c_coef: (B, S, N); h0: (B, ..., N).
    Returns (y: (B, S, ...), h_final). Never materializes (B,S,...,N) at once.
    The scan itself runs in float32 (recurrent error accumulates in bf16).
    """
    out_dtype = inp.dtype
    decay = decay.astype(jnp.float32)
    inp = inp.astype(jnp.float32)
    c_coef = c_coef.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)
    b, s = decay.shape[0], decay.shape[1]
    chunk = math.gcd(chunk, s)  # short/odd sequences: largest common chunk
    nc = s // chunk
    resh = lambda t: jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)
    dc, ic, cc = resh(decay), resh(inp), resh(c_coef)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def step(h, args):
        d, i, c = args                                  # (B, chunk, ..., N)
        aprod, bacc = jax.lax.associative_scan(combine, (d, i), axis=1)
        hs = aprod * h[:, None] + bacc                  # (B, chunk, ..., N)
        c = c.reshape(c.shape[:2] + (1,) * (hs.ndim - 3) + c.shape[-1:])
        y = jnp.sum(hs * c, axis=-1)                    # (B, chunk, ...)
        return hs[:, -1], y

    h_final, ys = jax.lax.scan(step, h0, (dc, ic, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape((b, s) + ys.shape[3:])
    return y.astype(out_dtype), h_final.astype(out_dtype)


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg):
    d, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), pdt(cfg)),
        "conv_w": dense_init(ks[1], (di, cfg.d_conv), pdt(cfg), scale=0.5),
        "conv_b": jnp.zeros((di,), pdt(cfg)),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), pdt(cfg)),
        "dt_proj": dense_init(ks[3], (dtr, di), pdt(cfg)),
        "dt_bias": jnp.full((di,), -4.6, pdt(cfg)),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
        ).astype(pdt(cfg)),
        "D": jnp.ones((di,), pdt(cfg)),
        "out_proj": dense_init(ks[4], (di, d), pdt(cfg)),
    }


def _mamba1_coeffs(p, cfg, x_act):
    n, dtr = cfg.ssm_state, cfg.dt_rank
    proj = x_act @ p["x_proj"].astype(x_act.dtype)
    dt_in, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(x_act.dtype) + p["dt_bias"].astype(x_act.dtype))
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (di, N)
    return dt, a, b_ssm, c_ssm


def mamba1(p, cfg, x, state=None):
    """Full-sequence Mamba-1. x: (B,S,D) -> (B,S,D). state optional (B,di,N)."""
    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_act = jax.nn.silu(_causal_conv(x_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype)))
    dt, a, b_ssm, c_ssm = _mamba1_coeffs(p, cfg, x_act)
    decay = jnp.exp(dt[..., None] * a)                              # (B,S,di,N)
    inp = (dt * x_act)[..., None] * b_ssm[:, :, None, :]
    h0 = jnp.zeros((b, di, n), x.dtype) if state is None else state
    y, h = _chunked_ssm_scan(decay, inp, c_ssm, h0, cfg.ssm_chunk)
    y = y + p["D"].astype(x.dtype) * x_act
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(x.dtype)
    return out, h


def init_mamba1_state(cfg, batch, dtype):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }


def mamba1_decode(p, cfg, x, state):
    """One-token recurrence. x: (B,1,D)."""
    xt = x[:, 0]
    xz = xt @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    conv, xc = _conv_step(state["conv"], x_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    x_act = jax.nn.silu(xc)[:, None, :]                             # (B,1,di)
    dt, a, b_ssm, c_ssm = _mamba1_coeffs(p, cfg, x_act)
    decay = jnp.exp(dt[..., None] * a)[:, 0]                        # (B,di,N)
    inp = ((dt * x_act)[..., None] * b_ssm[:, :, None, :])[:, 0]
    h = (decay * state["h"].astype(jnp.float32) + inp).astype(state["h"].dtype)
    y = jnp.sum(h.astype(x.dtype) * c_ssm[:, 0, None, :], axis=-1) + p["D"].astype(x.dtype) * x_act[:, 0]
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(x.dtype)
    return out.astype(x.dtype)[:, None, :], {"h": h, "conv": conv}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD: scalar decay per head, (hd x N) state per head)
# ---------------------------------------------------------------------------

def _m2_heads(cfg):
    assert cfg.d_inner % cfg.ssm_head_dim == 0
    return cfg.d_inner // cfg.ssm_head_dim


def init_mamba2(key, cfg):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h2 = _m2_heads(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h2), pdt(cfg)),
        "conv_w": dense_init(ks[1], (di, cfg.d_conv), pdt(cfg), scale=0.5),
        "conv_b": jnp.zeros((di,), pdt(cfg)),
        "A_log": jnp.zeros((h2,), pdt(cfg)),
        "dt_bias": jnp.full((h2,), -4.6, pdt(cfg)),
        "D": jnp.ones((h2,), pdt(cfg)),
        "norm_w": jnp.ones((di,), pdt(cfg)),
        "out_proj": dense_init(ks[2], (di, d), pdt(cfg)),
    }


def _m2_split(p, cfg, x):
    di, n = cfg.d_inner, cfg.ssm_state
    h2 = _m2_heads(cfg)
    proj = x @ p["in_proj"].astype(x.dtype)
    return jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)


def mamba2(p, cfg, x, state=None):
    b, s, _ = x.shape
    di, n, hd2 = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h2 = _m2_heads(cfg)
    z, x_in, b_ssm, c_ssm, dt_in = _m2_split(p, cfg, x)
    x_act = jax.nn.silu(_causal_conv(x_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype)))
    dt = jax.nn.softplus(dt_in + p["dt_bias"].astype(x.dtype))      # (B,S,H2)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (H2,)
    xh = x_act.reshape(b, s, h2, hd2)
    decay = jnp.exp(dt * a)[..., None, None]                        # (B,S,H2,1,1)
    inp = (dt[..., None] * xh)[..., None] * b_ssm[:, :, None, None, :]
    h0 = jnp.zeros((b, h2, hd2, n), x.dtype) if state is None else state
    decay = jnp.broadcast_to(decay, inp.shape)
    y, h = _chunked_ssm_scan(decay, inp, c_ssm, h0, cfg.ssm_chunk)  # (B,S,H2,hd2)
    y = y + p["D"].astype(x.dtype)[:, None] * xh
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"].astype(x.dtype), h


def init_mamba2_state(cfg, batch, dtype):
    h2 = _m2_heads(cfg)
    return {
        "h": jnp.zeros((batch, h2, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }


def mamba2_decode(p, cfg, x, state):
    b = x.shape[0]
    di, n, hd2 = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h2 = _m2_heads(cfg)
    z, x_in, b_ssm, c_ssm, dt_in = _m2_split(p, cfg, x[:, 0])
    conv, xc = _conv_step(state["conv"], x_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    x_act = jax.nn.silu(xc)
    dt = jax.nn.softplus(dt_in + p["dt_bias"].astype(x.dtype))      # (B,H2)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x_act.reshape(b, h2, hd2)
    decay = jnp.exp(dt * a)[..., None, None]
    inp = (dt[..., None] * xh)[..., None] * b_ssm[:, None, None, :]
    h = (decay * state["h"].astype(jnp.float32) + inp).astype(state["h"].dtype)
    y = jnp.sum(h.astype(x.dtype) * c_ssm[:, None, None, :], axis=-1) + p["D"].astype(x.dtype)[:, None] * xh
    y = y.reshape(b, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"].astype(x.dtype)
    return out.astype(x.dtype)[:, None, :], {"h": h, "conv": conv}
