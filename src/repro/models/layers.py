"""Transformer building blocks: norms, RoPE, GQA/SWA/MLA attention, MLP, MoE.

Conventions: x is (B, S, D); params are nested dicts of arrays; every init_*
takes (key, cfg) and every apply takes (params, cfg, ...). Layer stacks are
built by vmapping init over layer keys and scanned at apply time (lm.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x, positions, theta, rot_dim=None):
    """Apply rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    rot = rot_dim or hd
    freqs = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype) if rot < hd else out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (full-causal, bidirectional, exact block-SWA, decode cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, h * hd), pdt(cfg)),
        "wk": dense_init(k2, (d, kv * hd), pdt(cfg)),
        "wv": dense_init(k3, (d, kv * hd), pdt(cfg)),
        "wo": dense_init(k4, (h * hd, d), pdt(cfg)),
    }


def _qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, kv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, kv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd) grouped against k/v: (B,Sk,KV,hd); mask broadcastable
    to (B,KV,G,Sq,Sk) or (Sq,Sk)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / np.sqrt(hd)
    scores = jnp.where(mask, scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h * hd)


def attention(p, cfg, x, positions, *, bidir=False, window=0):
    """Full-sequence attention (train / prefill). Exact block-SWA used when
    window > 0 and S is a multiple of the window (sub-quadratic).

    cfg.attn_shard == "seq" enables sequence-parallel attention: queries are
    sharded along S over the 'model' axis while the (small, GQA) K/V are
    gathered — the right layout when head counts don't divide the TP axis
    (e.g. starcoder2's 36 heads on a 16-way mesh), where head sharding would
    otherwise force score-tensor all-reduces."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    if cfg.attn_shard == "seq" and s > 1:
        from jax.sharding import PartitionSpec as P
        q = jax.lax.with_sharding_constraint(q, P("data", "model", None, None))
        k = jax.lax.with_sharding_constraint(k, P("data", None, None, None))
        v = jax.lax.with_sharding_constraint(v, P("data", None, None, None))
    if window and not bidir and s > window and s % window == 0:
        out = _block_swa(cfg, q, k, v, window)
    else:
        ar = jnp.arange(s)
        mask = jnp.ones((s, s), bool) if bidir else (ar[None, :] <= ar[:, None])
        if window and not bidir:
            mask &= ar[:, None] - ar[None, :] < window
        out = _sdpa(q, k, v, mask)
    if cfg.attn_shard == "seq" and s > 1:
        from jax.sharding import PartitionSpec as P
        # keep the sequence sharding through wo: resharding the (q-sharded)
        # probs/context to a feature layout forces SPMD to rematerialize the
        # full (B,H,S,S) tensor; gathering the 42MB wo weight instead is free
        out = jax.lax.with_sharding_constraint(out, P("data", "model", None))
    return out @ p["wo"].astype(x.dtype)


def _block_swa(cfg, q, k, v, w):
    """Exact sliding-window attention via (current + previous) w-blocks."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    nb = s // w
    qb = q.reshape(b, nb, w, h, hd)
    kb = k.reshape(b, nb, w, kv, hd)
    vb = v.reshape(b, nb, w, kv, hd)
    shift = lambda t: jnp.concatenate([jnp.zeros_like(t[:, :1]), t[:, :-1]], axis=1)
    kw = jnp.concatenate([shift(kb), kb], axis=2)   # (B, nb, 2w, kv, hd)
    vw = jnp.concatenate([shift(vb), vb], axis=2)
    i = jnp.arange(w)[:, None]
    sidx = jnp.arange(2 * w)[None, :]
    mask = (sidx > i) & (sidx <= i + w)             # causal AND within window
    first = jnp.arange(nb)[:, None, None] > 0
    mask = mask[None] & (first | (sidx[None] >= w))  # block 0 has no prev
    g = h // kv
    qg = qb.reshape(b, nb, w, kv, g, hd)
    scores = jnp.einsum("bnqkgd,bnskd->bnkgqs", qg, kw) / np.sqrt(hd)
    scores = jnp.where(mask[:, None, None], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", probs, vw)
    return out.reshape(b, s, h * hd)


def init_kv_cache(cfg, batch, length, dtype=None):
    kv, hd = cfg.n_kv, cfg.hd
    cap = min(length, cfg.window) if cfg.window else length
    dt = dtype or cdt(cfg)
    return {
        "k": jnp.zeros((batch, cap, kv, hd), dt),
        "v": jnp.zeros((batch, cap, kv, hd), dt),
    }


def attention_decode(p, cfg, x, cache, pos):
    """One-token decode against a (possibly ring) KV cache.

    x: (B, 1, D); cache k/v: (B, cap, KV, hd) storing *roped* keys;
    pos: scalar absolute position of the new token.
    """
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    cap = cache["k"].shape[1]
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, 1, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, 1, kv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, 1, kv, hd)
    posv = jnp.full((1,), pos)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    slot = pos % cap
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    valid = (jnp.arange(cap) <= pos)  # pre-wrap fill mask; all-valid once wrapped
    valid = valid | (pos >= cap)
    out = _sdpa(q, ck, cv, valid[None, None, None, None, :])
    return out @ p["wo"].astype(x.dtype), {"k": ck, "v": cv}


def init_cross_cache(cfg, batch, length, dtype=None):
    dt = dtype or cdt(cfg)
    return {
        "ck": jnp.zeros((batch, length, cfg.n_kv, cfg.hd), dt),
        "cv": jnp.zeros((batch, length, cfg.n_kv, cfg.hd), dt),
    }


def cross_attention(p, cfg, x, enc_kv, decode=False):
    """Decoder->encoder attention; enc_kv = (k, v) precomputed from encoder."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k, v = enc_kv
    out = _sdpa(q, k, v, jnp.ones((1, 1, 1, s, k.shape[1]), bool))
    return out @ p["wo"].astype(x.dtype)


def encoder_kv(p, cfg, enc_out):
    b, se, _ = enc_out.shape
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, se, cfg.n_kv, cfg.hd)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, se, cfg.n_kv, cfg.hd)
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache
# and the absorbed-matmul decode path.
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, cfg.q_lora), pdt(cfg)),
        "q_norm": jnp.ones((cfg.q_lora,), pdt(cfg)),
        "wq_b": dense_init(ks[1], (cfg.q_lora, h * (nd + rd)), pdt(cfg)),
        "wkv_a": dense_init(ks[2], (d, cfg.kv_lora + rd), pdt(cfg)),
        "kv_norm": jnp.ones((cfg.kv_lora,), pdt(cfg)),
        "wk_b": dense_init(ks[3], (cfg.kv_lora, h * nd), pdt(cfg)),
        "wv_b": dense_init(ks[4], (cfg.kv_lora, h * vd), pdt(cfg)),
        "wo": dense_init(ks[5], (h * vd, d), pdt(cfg)),
    }


def _mla_q(p, cfg, x, positions):
    b, s, _ = x.shape
    h, nd, rd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    ql = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"])
    q = (ql @ p["wq_b"].astype(x.dtype)).reshape(b, s, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p, cfg, x, positions):
    """Training/prefill MLA (non-absorbed form, full causal)."""
    b, s, _ = x.shape
    h, nd, rd, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    kva = x @ p["wkv_a"].astype(x.dtype)
    ckv = rms_norm(kva[..., : cfg.kv_lora], p["kv_norm"])
    k_rope = rope(kva[..., cfg.kv_lora:][:, :, None, :], positions, cfg.rope_theta)
    k_nope = (ckv @ p["wk_b"].astype(x.dtype)).reshape(b, s, h, nd)
    v = (ckv @ p["wv_b"].astype(x.dtype)).reshape(b, s, h, vd)
    ar = jnp.arange(s)
    mask = ar[None, :] <= ar[:, None]
    scale = 1.0 / np.sqrt(nd + rd)
    scores = (
        jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
        + jnp.einsum("bqhd,bsxd->bhqs", q_rope, jnp.broadcast_to(k_rope, (b, s, 1, rd)))
    ) * scale
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(b, s, h * vd)
    return out @ p["wo"].astype(x.dtype)


def init_mla_cache(cfg, batch, length, dtype=None):
    dt = dtype or cdt(cfg)
    return {
        "ckv": jnp.zeros((batch, length, cfg.kv_lora), dt),
        "krope": jnp.zeros((batch, length, cfg.qk_rope_dim), dt),
    }


def mla_decode(p, cfg, x, cache, pos):
    """Absorbed MLA decode: scores/values computed in the 512-d latent space —
    the cache is (kv_lora + rope_dim) per token instead of 2*H*hd."""
    b = x.shape[0]
    h, nd, rd, vd = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    posv = jnp.full((1,), pos)
    q_nope, q_rope = _mla_q(p, cfg, x, posv)            # (B,1,H,nd),(B,1,H,rd)
    kva = x @ p["wkv_a"].astype(x.dtype)
    ckv_new = rms_norm(kva[..., : cfg.kv_lora], p["kv_norm"])
    kr_new = rope(kva[..., cfg.kv_lora:][:, :, None, :], posv, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos % cache["ckv"].shape[1], 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], kr_new.astype(cache["krope"].dtype), (0, pos % cache["krope"].shape[1], 0))
    # absorb W_k_b into q: q_tilde (B,H,kv_lora)
    wkb = p["wk_b"].astype(x.dtype).reshape(cfg.kv_lora, h, nd)
    q_t = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], wkb)
    scale = 1.0 / np.sqrt(nd + rd)
    scores = (
        jnp.einsum("bhl,bsl->bhs", q_t, ckv)
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], krope)
    ) * scale
    cap = ckv.shape[1]
    valid = (jnp.arange(cap) <= pos) | (pos >= cap)
    scores = jnp.where(valid[None, None], scores.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhs,bsl->bhl", probs, ckv)        # latent context
    wvb = p["wv_b"].astype(x.dtype).reshape(cfg.kv_lora, h, vd)
    out = jnp.einsum("bhl,lhd->bhd", ctx, wvb).reshape(b, 1, h * vd)
    return out @ p["wo"].astype(x.dtype), {"ckv": ckv, "krope": krope}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": dense_init(k1, (d, f), pdt(cfg)), "w2": dense_init(k2, (f, d), pdt(cfg))}
    if cfg.mlp_type == "gated":
        # separate gate/value projections (llama w1/w3): splitting a fused
        # (D, 2F) tensor along a model-sharded 2F axis would reshard every
        # layer (the halves live on disjoint device groups)
        p["w3"] = dense_init(k3, (d, f), pdt(cfg))
    return p


def mlp(p, cfg, x):
    if cfg.mlp_type == "gated":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE — top-k routing with either GShard one-hot dispatch (dense einsums,
# the faithful TPU classic) or sort/gather dispatch (sub-quadratic; a §Perf
# hillclimb lever). Shared experts (DeepSeek-V2) run densely for all tokens.
# ---------------------------------------------------------------------------

def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w1": dense_init(ks[1], (e, d, f), pdt(cfg)),
        "w2": dense_init(ks[2], (e, f, d), pdt(cfg)),
    }
    if cfg.mlp_type == "gated":
        p["w3"] = dense_init(ks[4], (e, d, f), pdt(cfg))
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[3], cfg, d_ff=f * cfg.n_shared_experts)
    return p


def _expert_ffn(cfg, p, xe):
    h = jnp.einsum("ecd,edf->ecf", xe, p["w1"].astype(xe.dtype))
    if cfg.mlp_type == "gated":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, p["w3"].astype(xe.dtype))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(xe.dtype))


def _route(p, cfg, xf):
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)                          # (T,k)
    gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)
    # aux losses: load-balance (Switch) + router z-loss
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)
    lb = e * jnp.sum(me * frac)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gate, idx, lb + 1e-3 * z


def moe(p, cfg, x):
    """Returns (y, aux_loss). x: (B,S,D)."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gate, idx, aux = _route(p, cfg, xf)
    cap = max(int(cfg.capacity_factor * t * cfg.top_k / cfg.n_experts), 1)
    if s == 1:
        cap = t  # decode: drop-free (worst case: every token -> one expert)
    if cfg.moe_impl == "sorted":
        y = _moe_sorted(p, cfg, xf, gate, idx, cap)
    elif cfg.moe_impl == "grouped":
        g = math.gcd(cfg.moe_groups, t)
        cap_g = max(cap // g, 1)
        y = jax.vmap(
            lambda xg, gg, ig: _moe_sorted(p, cfg, xg, gg, ig, cap_g)
        )(
            xf.reshape(g, t // g, -1),
            gate.reshape(g, t // g, cfg.top_k),
            idx.reshape(g, t // g, cfg.top_k),
        ).reshape(t, -1)
    else:
        y = _moe_dense(p, cfg, xf, gate, idx, cap)
    if cfg.n_shared_experts:
        y = y + mlp(p["shared"], cfg, xf)
    return y.reshape(b, s, d), aux


def _moe_dense(p, cfg, xf, gate, idx, cap):
    t, e = xf.shape[0], cfg.n_experts
    onehot = jax.nn.one_hot(idx, e, dtype=xf.dtype)                  # (T,k,E)
    pos = jnp.cumsum(onehot.reshape(t * cfg.top_k, e), axis=0).reshape(t, cfg.top_k, e) - onehot
    keep = onehot * (pos < cap)
    # dispatch (T,E,C): sum over k of keep * one_hot(position-in-expert)
    poh = jax.nn.one_hot(pos, cap, dtype=xf.dtype)                   # (T,k,E,C)
    disp = jnp.einsum("tke,tkec->tec", keep, poh)
    comb = jnp.einsum("tk,tke,tkec->tec", gate.astype(xf.dtype), keep, poh)
    xe = jnp.einsum("td,tec->ecd", xf, disp)
    ye = _expert_ffn(cfg, p, xe)
    return jnp.einsum("tec,ecd->td", comb, ye)


def _moe_sorted(p, cfg, xf, gate, idx, cap):
    t, e, k = xf.shape[0], cfg.n_experts, cfg.top_k
    flat_e = idx.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=e)
    offsets = jnp.cumsum(counts) - counts                            # (E,)
    slots = offsets[:, None] + jnp.arange(cap)[None, :]              # (E,C)
    valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    src = order[jnp.clip(slots, 0, t * k - 1)]                       # (E,C)
    tok = src // k
    xe = xf[tok] * valid[..., None].astype(xf.dtype)                 # (E,C,D)
    ye = _expert_ffn(cfg, p, xe)
    w = gate.reshape(t * k)[src] * valid                             # (E,C)
    y = jnp.zeros_like(xf)
    return y.at[tok.reshape(-1)].add(
        (ye * w[..., None].astype(xf.dtype)).reshape(e * cap, -1)
    )
