"""Architecture configuration dataclass shared by all model families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static architecture description (hashable: usable as a jit static arg)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0                 # 0 => attention-free
    n_kv: int = 0
    d_ff: int = 0
    vocab: int = 0
    head_dim: int = 0                # 0 => d_model // n_heads
    mlp_type: str = "gated"          # gated (silu) | plain (gelu)
    rope_theta: float = 10000.0
    window: int = 0                  # 0 => full causal attention, else SWA
    attn_shard: str = "auto"         # auto (heads via weight sharding) | seq
    #                                  (sequence-parallel attention; §Perf —
    #                                  for head counts indivisible by the TP axis)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_impl: str = "dense"          # dense (GShard one-hot) | sorted (gather)
    #                                  | grouped (shard-local sort; §Perf)
    moe_groups: int = 16             # grouped impl: groups aligned to data shards
    capacity_factor: float = 1.25
    # --- MLA (deepseek-v2) ---
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM ---
    ssm_state: int = 0
    ssm_variant: str = ""            # mamba1 | mamba2
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64           # mamba2 only
    ssm_chunk: int = 256             # scan chunk length
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0       # apply ONE shared attn block every N ssm layers
    # --- enc-dec (seamless) ---
    enc_layers: int = 0
    # --- modality frontends (stubs per brief) ---
    num_patches: int = 0             # vlm: vision tokens prepended
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = True               # activation checkpointing on layer blocks
    # --- citation ---
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def vocab_pad(self) -> int:
        return pad_to(self.vocab, 256)

    @property
    def dt_rank(self) -> int:
        return pad_to(-(-self.d_model // 16), 8)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state, hybrid, or sliding-window attn."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test variant of the same family (<=2 layers, d_model<=256)."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv=min(self.n_kv, 2) if self.n_kv else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=64 if self.n_heads else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            q_lora=64 if self.q_lora else 0,
            kv_lora=32 if self.kv_lora else 0,
            qk_nope_dim=32 if self.qk_nope_dim else 0,
            qk_rope_dim=16 if self.qk_rope_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_variant == "mamba2" else self.ssm_head_dim,
            ssm_chunk=32,
            shared_attn_every=1 if self.shared_attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            num_patches=min(self.num_patches, 8),
            window=min(self.window, 64) if self.window else 0,
            name=self.name + "-reduced",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
