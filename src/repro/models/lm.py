"""Model assembly for all assigned architecture families.

Functional API (cfg-dispatched, jit/vmap friendly):
    init_params(cfg, key)                        -> params pytree
    loss_fn(cfg, params, batch)                  -> (loss, metrics)
    prefill(cfg, params, batch)                  -> (last_logits, cache)
    decode_step(cfg, params, token, cache, pos)  -> (logits, cache)
    init_cache(cfg, batch, seq_len)              -> cache pytree

Layers are stacked (vmapped init) and applied with `lax.scan`, so HLO size is
depth-independent (a 95-layer DeepSeek compiles the same program size as a
24-layer Danube). Train blocks are rematerialized (cfg-controlled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ArchConfig


def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Per-family layer init
# ---------------------------------------------------------------------------

def _init_dense_layer(cfg):
    def go(key):
        k1, k2 = jax.random.split(key)
        attn = L.init_mla(k1, cfg) if cfg.kv_lora else L.init_attention(k1, cfg)
        ffn = L.init_moe(k2, cfg) if cfg.n_experts else L.init_mlp(k2, cfg)
        return {
            "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "attn": attn,
            "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "ffn": ffn,
        }
    return go


def _init_ssm_layer(cfg):
    def go(key):
        return {
            "ln": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "mamba": S.init_mamba1(key, cfg) if cfg.ssm_variant == "mamba1" else S.init_mamba2(key, cfg),
        }
    return go


def _init_encdec_layers(cfg, key):
    ke, kd = jax.random.split(key)

    def enc(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "attn": L.init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "mlp": L.init_mlp(k2, cfg),
        }

    def dec(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "self_attn": L.init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "cross_attn": L.init_attention(k2, cfg),
            "ln3": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "mlp": L.init_mlp(k3, cfg),
        }

    return (
        _stack_init(enc, ke, cfg.enc_layers),
        _stack_init(dec, kd, cfg.n_layers),
    )


def init_params(cfg: ArchConfig, key) -> dict:
    kemb, klay, khead, kextra = jax.random.split(key, 4)
    params = {
        "embed": L.dense_init(kemb, (cfg.vocab_pad, cfg.d_model), L.pdt(cfg), scale=0.02),
        "ln_f": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "head": L.dense_init(khead, (cfg.d_model, cfg.vocab_pad), L.pdt(cfg)),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stack_init(_init_dense_layer(cfg), klay, cfg.n_layers)
    elif cfg.family == "ssm":
        params["layers"] = _stack_init(_init_ssm_layer(cfg), klay, cfg.n_layers)
    elif cfg.family == "hybrid":
        params["layers"] = _stack_init(_init_ssm_layer(cfg), klay, cfg.n_layers)
        ka, kb = jax.random.split(kextra)
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "attn": L.init_attention(ka, cfg),
            "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "mlp": L.init_mlp(kb, cfg),
        }
    elif cfg.family == "audio":
        params["enc_layers"], params["layers"] = _init_encdec_layers(cfg, klay)
        params["ln_enc"] = jnp.ones((cfg.d_model,), L.pdt(cfg))
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# Forward passes (full sequence)
# ---------------------------------------------------------------------------

def _dense_block(cfg, x, lp, positions, window):
    h = L.rms_norm(x, lp["ln1"])
    if cfg.kv_lora:
        x = x + L.mla_attention(lp["attn"], cfg, h, positions)
    else:
        x = x + L.attention(lp["attn"], cfg, h, positions, window=window)
    h = L.rms_norm(x, lp["ln2"])
    if cfg.n_experts:
        y, aux = L.moe(lp["ffn"], cfg, h)
        return x + y, aux
    return x + L.mlp(lp["ffn"], cfg, h), jnp.float32(0.0)


def _run_layers(cfg, params, x, positions):
    """Scanned layer stack -> (x, aux_loss)."""
    window = cfg.window

    if cfg.family == "hybrid":
        return _run_hybrid(cfg, params, x, positions)

    if cfg.family in ("dense", "moe", "vlm"):
        def blk(carry, lp):
            y, aux = _dense_block(cfg, carry, lp, positions, window)
            return y, aux
    elif cfg.family == "ssm":
        def blk(carry, lp):
            y, _ = (S.mamba1 if cfg.ssm_variant == "mamba1" else S.mamba2)(
                lp["mamba"], cfg, L.rms_norm(carry, lp["ln"])
            )
            return carry + y, jnp.float32(0.0)
    else:
        raise ValueError(cfg.family)

    f = jax.checkpoint(blk) if _remat(cfg) else blk
    x, aux = jax.lax.scan(f, x, params["layers"])
    return x, jnp.sum(aux)


def _remat(cfg):
    return cfg.remat


def _shared_attn_apply(cfg, sp, x, positions):
    h = L.rms_norm(x, sp["ln1"])
    x = x + L.attention(sp["attn"], cfg, h, positions, window=cfg.window)
    h = L.rms_norm(x, sp["ln2"])
    return x + L.mlp(sp["mlp"], cfg, h)


def _run_hybrid(cfg, params, x, positions):
    """zamba2: scan groups of `shared_attn_every` mamba2 layers, applying the
    single shared attention block (same weights) after each group."""
    k = cfg.shared_attn_every
    ng = cfg.n_layers // k
    grouped = jax.tree.map(lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
    sp = params["shared_attn"]

    def inner(carry, lp):
        y, _ = S.mamba2(lp["mamba"], cfg, L.rms_norm(carry, lp["ln"]))
        return carry + y, None

    def group(carry, glp):
        y, _ = jax.lax.scan(inner, carry, glp)
        y = _shared_attn_apply(cfg, sp, y, positions)
        return y, jnp.float32(0.0)

    f = jax.checkpoint(group) if _remat(cfg) else group
    x, aux = jax.lax.scan(f, x, grouped)
    return x, jnp.sum(aux)


def _run_encoder(cfg, params, frames):
    positions = jnp.arange(frames.shape[1])

    def blk(x, lp):
        h = L.rms_norm(x, lp["ln1"])
        x = x + L.attention(lp["attn"], cfg, h, positions, bidir=True)
        h = L.rms_norm(x, lp["ln2"])
        return x + L.mlp(lp["mlp"], cfg, h), None

    f = jax.checkpoint(blk) if _remat(cfg) else blk
    x, _ = jax.lax.scan(f, frames, params["enc_layers"])
    return L.rms_norm(x, params["ln_enc"])


def _run_decoder(cfg, params, x, enc_out, positions):
    def blk(carry, lp):
        h = L.rms_norm(carry, lp["ln1"])
        carry = carry + L.attention(lp["self_attn"], cfg, h, positions)
        h = L.rms_norm(carry, lp["ln2"])
        enc_kv = L.encoder_kv(lp["cross_attn"], cfg, enc_out)
        carry = carry + L.cross_attention(lp["cross_attn"], cfg, h, enc_kv)
        h = L.rms_norm(carry, lp["ln3"])
        return carry + L.mlp(lp["mlp"], cfg, h), None

    f = jax.checkpoint(blk) if _remat(cfg) else blk
    x, _ = jax.lax.scan(f, x, params["layers"])
    return x


def _embed(cfg, params, tokens):
    return params["embed"].astype(L.cdt(cfg))[tokens]


def forward(cfg: ArchConfig, params, batch):
    """Full-sequence logits. Returns (logits over positions-with-labels, aux)."""
    if cfg.family == "audio":
        enc_out = _run_encoder(cfg, params, batch["frames"].astype(L.cdt(cfg)))
        x = _embed(cfg, params, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        x = _run_decoder(cfg, params, x, enc_out, positions)
        aux = jnp.float32(0.0)
    elif cfg.family == "vlm":
        tx = _embed(cfg, params, batch["tokens"])
        x = jnp.concatenate([batch["patches"].astype(L.cdt(cfg)), tx], axis=1)
        positions = jnp.arange(x.shape[1])
        x, aux = _run_layers(cfg, params, x, positions)
        x = x[:, batch["patches"].shape[1]:]          # loss on text positions
    else:
        x = _embed(cfg, params, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        x, aux = _run_layers(cfg, params, x, positions)
    x = L.rms_norm(x, params["ln_f"])
    logits = (x @ params["head"].astype(x.dtype)).astype(jnp.float32)
    return logits, aux


def loss_fn(cfg: ArchConfig, params, batch):
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + 0.01 * aux
    return loss, {"task_loss": jnp.mean(nll), "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + one-token decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, length: int, enc_len: int = 0):
    dt = L.cdt(cfg)
    n = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.kv_lora:
            one = L.init_mla_cache(cfg, batch, length, dt)
        else:
            one = L.init_kv_cache(cfg, batch, length, dt)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)
    if cfg.family == "ssm":
        one = (S.init_mamba1_state if cfg.ssm_variant == "mamba1" else S.init_mamba2_state)(cfg, batch, dt)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), one)
    if cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.shared_attn_every
        m = S.init_mamba2_state(cfg, batch, dt)
        kvc = L.init_kv_cache(cfg, batch, length, dt)
        return {
            "mamba": jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), m),
            "attn": jax.tree.map(lambda a: jnp.broadcast_to(a, (ng,) + a.shape), kvc),
        }
    if cfg.family == "audio":
        kvc = L.init_kv_cache(cfg, batch, length, dt)
        cc = L.init_cross_cache(cfg, batch, enc_len or length, dt)
        return {
            "self": jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), kvc),
            "cross": jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape), cc),
        }
    raise ValueError(cfg.family)


def _decode_layers(cfg, params, x, cache, pos):
    if cfg.family in ("dense", "moe", "vlm"):
        def blk(carry, args):
            lp, c = args
            h = L.rms_norm(carry, lp["ln1"])
            if cfg.kv_lora:
                a, c2 = L.mla_decode(lp["attn"], cfg, h, c, pos)
            else:
                a, c2 = L.attention_decode(lp["attn"], cfg, h, c, pos)
            carry = carry + a
            h = L.rms_norm(carry, lp["ln2"])
            if cfg.n_experts:
                y, _ = L.moe(lp["ffn"], cfg, h)
            else:
                y = L.mlp(lp["ffn"], cfg, h)
            return carry + y, c2
        return jax.lax.scan(blk, x, (params["layers"], cache))

    if cfg.family == "ssm":
        step = S.mamba1_decode if cfg.ssm_variant == "mamba1" else S.mamba2_decode
        def blk(carry, args):
            lp, c = args
            y, c2 = step(lp["mamba"], cfg, L.rms_norm(carry, lp["ln"]), c)
            return carry + y, c2
        return jax.lax.scan(blk, x, (params["layers"], cache))

    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        ng = cfg.n_layers // k
        grouped = jax.tree.map(lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
        mcache = jax.tree.map(lambda a: a.reshape((ng, k) + a.shape[1:]), cache["mamba"])
        sp = params["shared_attn"]

        def inner(carry, args):
            lp, c = args
            y, c2 = S.mamba2_decode(lp["mamba"], cfg, L.rms_norm(carry, lp["ln"]), c)
            return carry + y, c2

        def group(carry, args):
            glp, gmc, ac = args
            y, mc2 = jax.lax.scan(inner, carry, (glp, gmc))
            h = L.rms_norm(y, sp["ln1"])
            a, ac2 = L.attention_decode(sp["attn"], cfg, h, ac, pos)
            y = y + a
            h = L.rms_norm(y, sp["ln2"])
            y = y + L.mlp(sp["mlp"], cfg, h)
            return y, (mc2, ac2)

        x, (mc, ac) = jax.lax.scan(group, x, (grouped, mcache, cache["attn"]))
        mc = jax.tree.map(lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), mc)
        return x, {"mamba": mc, "attn": ac}

    if cfg.family == "audio":
        def blk(carry, args):
            lp, c, cc = args
            h = L.rms_norm(carry, lp["ln1"])
            a, c2 = L.attention_decode(lp["self_attn"], cfg, h, c, pos)
            carry = carry + a
            h = L.rms_norm(carry, lp["ln2"])
            carry = carry + L.cross_attention(lp["cross_attn"], cfg, h, (cc["ck"], cc["cv"]))
            h = L.rms_norm(carry, lp["ln3"])
            return carry + L.mlp(lp["mlp"], cfg, h), c2
        x, c2 = jax.lax.scan(blk, x, (params["layers"], cache["self"], cache["cross"]))
        return x, {"self": c2, "cross": cache["cross"]}

    raise ValueError(cfg.family)


def decode_step(cfg: ArchConfig, params, token, cache, pos):
    """One new token against a cache. token: (B,1) int32; pos: scalar."""
    x = _embed(cfg, params, token)
    x, cache = _decode_layers(cfg, params, x, cache, pos)
    x = L.rms_norm(x, params["ln_f"])
    logits = (x @ params["head"].astype(x.dtype)).astype(jnp.float32)
    return logits, cache


def build_cross_cache(cfg: ArchConfig, params, frames):
    """Audio serving: run the encoder once and cache per-decoder-layer
    cross-attention K/V."""
    enc_out = _run_encoder(cfg, params, frames.astype(L.cdt(cfg)))

    def per_layer(_, lp):
        k, v = L.encoder_kv(lp["cross_attn"], cfg, enc_out)
        return None, {"ck": k, "cv": v}

    _, cross = jax.lax.scan(per_layer, None, params["layers"])
    return cross


def prefill(cfg: ArchConfig, params, batch):
    """Forward over a prompt; returns last-position logits (inference-prefill).

    Cache population for serving is done by stepping `decode_step` over the
    prompt (see examples/serve_personalized.py); this function is the bulk
    prefill compute that the prefill_32k dry-run shape exercises.
    """
    logits, _ = forward(cfg, params, batch)
    return logits[:, -1]
