from repro.checkpoint.ckpt import (
    load_checkpoint,
    load_client_store,
    load_meta,
    save_checkpoint,
    save_client_store,
)
