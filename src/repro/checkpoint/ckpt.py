"""npz pytree checkpointing: per-client personalized models + round state."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save_checkpoint(path: str, tree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(path, **arrays)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str, template):
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in leaves_t:
        arr = data[jax.tree_util.keystr(p)]
        assert arr.shape == leaf.shape, f"{jax.tree_util.keystr(p)}: {arr.shape} != {leaf.shape}"
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)


def load_meta(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
