"""npz pytree checkpointing: per-client personalized models + round state.

Also round-trips the serving tier's compressed client store
(serve/store.SketchStore): the packed uint32 sign words, per-pass fp32
scales and the fp32 base model are a plain pytree, saved through the same
npz path, with the codec parameters (layout/m_ratio/chunk/seed/passes) in
the JSON sidecar so the store can be rebuilt against a model template.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save_checkpoint(path: str, tree, meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten_with_paths(tree)
    np.savez(path, **arrays)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str, template):
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    leaves_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in leaves_t:
        name = jax.tree_util.keystr(p)
        if name not in data:
            raise ValueError(
                f"checkpoint {path!r} is missing leaf {name!r} "
                f"(has: {sorted(data.files)[:8]}...)"
            )
        arr = data[name]
        if arr.shape != tuple(leaf.shape):
            # a raise, not an assert: shape validation must survive python -O
            raise ValueError(
                f"checkpoint {path!r} leaf {name!r}: stored shape {arr.shape} "
                f"does not match template shape {tuple(leaf.shape)}"
            )
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)


def load_meta(path: str) -> dict:
    with open(path + ".meta.json") as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Serving-tier client store (packed one-bit sketch residuals)
# ---------------------------------------------------------------------------

def save_client_store(path: str, store, extra_meta: dict | None = None) -> None:
    """Persist a serve.store.SketchStore: uint32 bit-words + scales + base
    in the npz, codec parameters in the meta sidecar."""
    meta = dict(store.spec_meta())
    if extra_meta:
        meta.update(extra_meta)
    save_checkpoint(path, store.state_tree(), meta=meta)


def load_client_store(path: str, template):
    """Rebuild a SketchStore from save_client_store output.

    template: pytree of arrays/ShapeDtypeStructs shaped like one client
    model (defines the base/template structure the npz leaves are checked
    against)."""
    from repro.serve.store import SketchStore, make_store_spec

    meta = load_meta(path)
    if meta.get("kind") != "sketch_store":
        raise ValueError(
            f"{path!r} is not a client-store checkpoint (kind={meta.get('kind')!r})"
        )
    sspec = make_store_spec(
        template,
        int(meta["num_clients"]),
        m_ratio=float(meta["m_ratio"]),
        chunk=int(meta["chunk"]),
        seed=int(meta["seed"]),
        passes=int(meta["passes"]),
        layout=meta["layout"],
    )
    if sspec.n != int(meta["n"]) or sspec.m != int(meta["m"]):
        raise ValueError(
            f"store checkpoint {path!r} was built for n={meta['n']}, "
            f"m={meta['m']} but the template gives n={sspec.n}, m={sspec.m}"
        )
    base_t = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), l.dtype), template
    )
    state_t = {
        "base": base_t,
        "words": jax.ShapeDtypeStruct(
            (sspec.num_clients, sspec.passes, sspec.words_per_pass), np.uint32
        ),
        "scales": jax.ShapeDtypeStruct(
            (sspec.num_clients, sspec.passes), np.float32
        ),
    }
    state = load_checkpoint(path, state_t)
    return SketchStore.from_state_tree(sspec, state, template=base_t)
