from repro.optim.sgd import sgd_init, sgd_update, adam_init, adam_update
