"""Pure-pytree optimizers used by client updates and the launchers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return ()
    return (jax.tree.map(jnp.zeros_like, params),)


def sgd_update(params, grads, state, lr: float, momentum: float = 0.0):
    if momentum == 0.0:
        return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads), ()
    (m,) = state
    m = jax.tree.map(lambda mi, g: momentum * mi + g.astype(mi.dtype), m, grads)
    return jax.tree.map(lambda p, mi: p - lr * mi, params, m), (m,)


def adam_init(params):
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return (z, jax.tree.map(jnp.copy, z), jnp.int32(0))


def adam_update(params, grads, state, lr: float, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t = t + 1
    m = jax.tree.map(lambda mi, g: b1 * mi + (1 - b1) * g.astype(jnp.float32), m, grads)
    v = jax.tree.map(lambda vi, g: b2 * vi + (1 - b2) * jnp.square(g.astype(jnp.float32)), v, grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    params = jax.tree.map(
        lambda p, mi, vi: p - (lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)).astype(p.dtype),
        params, m, v,
    )
    return params, (m, v, t)
