# Pallas TPU kernels for the paper's compute hot-spots: the Fast Hadamard
# Transform (the paper's own O(n log n) optimization, re-tiled for the MXU)
# and one-bit pack/unpack/majority-vote transport.
from repro.kernels import ops, ref
from repro.kernels.ops import fht, pack_signs, unpack_signs, vote_packed

__all__ = ["ops", "ref", "fht", "pack_signs", "unpack_signs", "vote_packed"]
