"""Fused SRHT Pallas kernels (DESIGN.md §3.3).

The sketch operator Phi = sqrt(c/m) * S @ H @ D (paper Eq. 15-18) is a
four-stage pipeline when executed naively: Rademacher sign flip, FHT,
strided row subsample, scale — four HBM round trips per chunk. The kernels
here perform the whole pipeline in one VMEM-resident pass per
(block_rows, chunk) tile:

  srht_fwd_pallas        x, D, offsets -> z = sqrt(c/m) * S(FHT(D x))
  srht_fwd_packed_pallas same, with a sign + bit-pack epilogue so the uplink
                         wire format (uint32 words) comes straight out of
                         the kernel
  srht_adj_pallas        v, D, offsets -> w = sqrt(c/m) * D FHT(S^T v)
  dfht_pallas            scale * FHT(D x)  (or scale * FHT(x) * D) — the
                         fused sign-flip + transform used by the global
                         (paper-exact, permutation-subsampled) mode, whose
                         arbitrary row gather happens on the kernel output

The FHT itself is the Kronecker two-matmul factorization of kernels/fht.py
(DESIGN.md §3): H_c = H_a (x) H_b with a, b <= 128, so each tile costs two
MXU matmuls. The strided subsample idx = offset + arange(m) * stride
(stride = c // m, offset < stride) is fused as a one-hot select over the
stride axis of the first m*stride transform coefficients — no gather
instruction, just a VPU compare + multiply + reduce. The adjoint scatters
through the same one-hot mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fht import _fht_tile, _split_pow2
from repro.kernels.ref import hadamard_matrix


def _subsample_mask(off, br: int, stride: int):
    """One-hot (br, 1, stride) mask: lane s of row r is selected iff
    s == offsets[r]. off: (br, 1) int32."""
    lanes = jax.lax.broadcasted_iota(jnp.int32, (br, 1, stride), 2)
    return lanes == off[:, :, None]


def _srht_fwd_kernel(
    x_ref, d_ref, off_ref, ha_ref, hb_ref, o_ref,
    *, a: int, b: int, stride: int, m_chunk: int, scale: float, pack: bool,
):
    br = x_ref.shape[0]
    y = _fht_tile(x_ref[...] * d_ref[...], ha_ref[...], hb_ref[...], a, b)
    # strided subsample: y[off + j*stride] == y[:m*stride].reshape(m, stride)[j, off]
    y3 = y[:, : m_chunk * stride].reshape(br, m_chunk, stride)
    sel = _subsample_mask(off_ref[...], br, stride)
    z = scale * jnp.sum(y3 * sel.astype(jnp.float32), axis=-1)   # (br, m_chunk)
    if pack:
        bits = (z >= 0).astype(jnp.uint32).reshape(br, m_chunk // 32, 32)
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
        o_ref[...] = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)
    else:
        o_ref[...] = z.astype(o_ref.dtype)


def _srht_adj_kernel(
    v_ref, d_ref, off_ref, ha_ref, hb_ref, o_ref,
    *, a: int, b: int, stride: int, m_chunk: int, scale: float,
):
    br = v_ref.shape[0]
    c = a * b
    sel = _subsample_mask(off_ref[...], br, stride)
    lifted = (scale * v_ref[...])[:, :, None] * sel.astype(jnp.float32)
    lifted = lifted.reshape(br, m_chunk * stride)
    if m_chunk * stride < c:
        lifted = jnp.pad(lifted, ((0, 0), (0, c - m_chunk * stride)))
    y = _fht_tile(lifted, ha_ref[...], hb_ref[...], a, b)
    o_ref[...] = (y * d_ref[...]).astype(o_ref.dtype)


def _dfht_kernel(x_ref, d_ref, ha_ref, hb_ref, o_ref, *, a, b, scale, d_post):
    x = x_ref[...]
    d = d_ref[...]
    if d_post:
        y = _fht_tile(x, ha_ref[...], hb_ref[...], a, b) * d
    else:
        y = _fht_tile(x * d, ha_ref[...], hb_ref[...], a, b)
    o_ref[...] = (scale * y).astype(o_ref.dtype)


def _pad_rows(arrs, block_rows: int):
    rows = arrs[0].shape[0]
    pad = (-rows) % block_rows
    if pad:
        arrs = [jnp.pad(z, ((0, pad), (0, 0))) for z in arrs]
    return arrs, rows, arrs[0].shape[0]


def _row_blocked_call(kernel, ins, widths, out_width, out_dtype, block_rows, interpret):
    """pallas_call gridded over row blocks.

    The first len(widths) operands are (rows, width_i) and get row-blocked;
    the rest (the Hadamard factors) are broadcast whole to every grid step.
    """
    blocked, rows, padded = _pad_rows(ins[: len(widths)], block_rows)
    bcast = ins[len(widths):]
    out = pl.pallas_call(
        kernel,
        grid=(padded // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)) for w in widths
        ] + [
            pl.BlockSpec(h.shape, lambda i: (0, 0)) for h in bcast
        ],
        out_specs=pl.BlockSpec((block_rows, out_width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, out_width), out_dtype),
        interpret=interpret,
    )(*blocked, *bcast)
    return out[:rows]


@functools.partial(
    jax.jit, static_argnames=("m_chunk", "scale", "pack", "block_rows", "interpret")
)
def srht_fwd_pallas(
    x: jax.Array,
    d: jax.Array,
    offsets: jax.Array,
    *,
    m_chunk: int,
    scale: float,
    pack: bool = False,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Fused forward SRHT over chunk rows.

    x, d: (num_chunks, c) float32; offsets: (num_chunks, 1) int32 in
    [0, c // m_chunk). Returns (num_chunks, m_chunk) float32, or packed
    (num_chunks, m_chunk // 32) uint32 signs when pack=True.
    """
    rows, c = x.shape
    a, b = _split_pow2(c)
    stride = c // m_chunk
    assert offsets.shape == (rows, 1)
    if pack:
        assert m_chunk % 32 == 0, "packed epilogue needs m_chunk % 32 == 0"
    ha = hadamard_matrix(a, jnp.float32)
    hb = hadamard_matrix(b, jnp.float32)
    block_rows = min(block_rows, rows)
    kernel = functools.partial(
        _srht_fwd_kernel, a=a, b=b, stride=stride, m_chunk=m_chunk,
        scale=scale, pack=pack,
    )
    out_w = m_chunk // 32 if pack else m_chunk
    out_dt = jnp.uint32 if pack else jnp.float32
    return _row_blocked_call(
        kernel, [x, d, offsets.astype(jnp.int32), ha, hb],
        [c, c, 1], out_w, out_dt, block_rows, interpret,
    )


@functools.partial(jax.jit, static_argnames=("scale", "block_rows", "interpret"))
def srht_adj_pallas(
    v: jax.Array,
    d: jax.Array,
    offsets: jax.Array,
    *,
    scale: float,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Fused adjoint SRHT: v (num_chunks, m_chunk) -> (num_chunks, c)."""
    rows, m_chunk = v.shape
    c = d.shape[-1]
    a, b = _split_pow2(c)
    stride = c // m_chunk
    assert offsets.shape == (rows, 1)
    ha = hadamard_matrix(a, jnp.float32)
    hb = hadamard_matrix(b, jnp.float32)
    block_rows = min(block_rows, rows)
    kernel = functools.partial(
        _srht_adj_kernel, a=a, b=b, stride=stride, m_chunk=m_chunk, scale=scale,
    )
    return _row_blocked_call(
        kernel, [v, d, offsets.astype(jnp.int32), ha, hb],
        [m_chunk, c, 1], c, jnp.float32, block_rows, interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("scale", "d_post", "block_rows", "interpret")
)
def dfht_pallas(
    x: jax.Array,
    d: jax.Array,
    *,
    scale: float,
    d_post: bool = False,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """scale * FHT(x * d) per row (d_post=False) or scale * FHT(x) * d
    (d_post=True — the adjoint-side ordering). x, d: (rows, c)."""
    rows, c = x.shape
    a, b = _split_pow2(c)
    ha = hadamard_matrix(a, jnp.float32)
    hb = hadamard_matrix(b, jnp.float32)
    block_rows = min(block_rows, rows)
    kernel = functools.partial(_dfht_kernel, a=a, b=b, scale=scale, d_post=d_post)
    return _row_blocked_call(
        kernel, [x, d, ha, hb], [c, c], c, jnp.float32, block_rows, interpret
    )
