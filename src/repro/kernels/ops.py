"""Jit'd public wrappers selecting kernel vs reference implementation.

On TPU the Pallas kernels run compiled; on CPU hosts (this container) the
default execution path is the pure-jnp reference (Pallas interpret mode is
correct but slow — it is exercised in the test suite, not in production
paths). `impl` can force either path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fht import fht_pallas
from repro.kernels.onebit import pack_pallas, unpack_pallas, vote_pallas

_KERNEL_MAX_C = 128 * 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if _on_tpu() else "ref"


def fht(x: jax.Array, impl: str = "auto") -> jax.Array:
    """Normalized FHT along the last axis (any power-of-two length).

    Lengths above the single-tile kernel limit (2^14) are handled by the
    Kronecker split H_{ab} = H_a (x) H_b: FHT along each factor of a
    row-major (a, b) reshape.
    """
    impl = _auto(impl)
    n = x.shape[-1]
    assert _ref.is_pow2(n), f"FHT length must be a power of two, got {n}"
    if impl == "ref":
        return _ref.fht_ref(x)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, n)

    def go(y):  # y: (rows, c), c any pow2
        c = y.shape[-1]
        if c <= _KERNEL_MAX_C:
            return fht_pallas(y, interpret=not _on_tpu())
        b = _KERNEL_MAX_C
        a = c // b
        y = y.reshape(-1, a, b)
        y = go(y.reshape(-1, b)).reshape(-1, a, b)          # H_b along last
        y = jnp.swapaxes(y, 1, 2)                            # (rows, b, a)
        y = go(y.reshape(-1, a)).reshape(-1, b, a)           # H_a along last
        return jnp.swapaxes(y, 1, 2).reshape(-1, c)

    return go(x2).reshape(*lead, n)


def pack_signs(x: jax.Array, impl: str = "auto") -> jax.Array:
    """Pack signs (x >= 0) of the last axis (multiple of 32) into uint32."""
    impl = _auto(impl)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]) if lead else x[None]
    if impl == "ref" or x2.shape[0] % 8 != 0 or (x2.shape[-1] // 32) % 512 != 0:
        out = _ref.pack_ref(x2)
    else:
        out = pack_pallas(x2, interpret=not _on_tpu())
    return out.reshape(*lead, -1) if lead else out[0]


def unpack_signs(words: jax.Array, impl: str = "auto") -> jax.Array:
    """Unpack uint32 words into +/-1 float32 along the last axis."""
    impl = _auto(impl)
    lead = words.shape[:-1]
    w2 = words.reshape(-1, words.shape[-1]) if lead else words[None]
    if impl == "ref" or w2.shape[0] % 8 != 0 or w2.shape[-1] % 512 != 0:
        out = _ref.unpack_ref(w2)
    else:
        out = unpack_pallas(w2, interpret=not _on_tpu())
    return out.reshape(*lead, -1) if lead else out[0]


def vote_packed(words: jax.Array, weights: jax.Array, impl: str = "auto") -> jax.Array:
    """Weighted majority vote over (K, W) packed sketches -> (W,) packed."""
    impl = _auto(impl)
    if impl == "ref" or words.shape[-1] % 256 != 0:
        return _ref.vote_ref(words, weights)
    return vote_pallas(words, weights, interpret=not _on_tpu())
