"""Jit'd public wrappers selecting kernel vs reference implementation.

On TPU the Pallas kernels run compiled; on CPU hosts (this container) the
default execution path is the pure-jnp reference (Pallas interpret mode is
correct but slow — it is exercised in the test suite, not in production
paths). `impl` can force either path.

Every primitive dispatcher here is wrapped by `obs.probe.instrument` (see
the rebinding loop at the bottom of the file): inside a
`obs.probe.probing(...)` scope, eager calls are timed with compile
separated out and bytes-moved estimated — the per-kernel table in
benchmarks/report.py. Outside a probing scope the wrapper is a single
module-global check; calls under an active jax trace pass through
untimed, so jitted programs are never perturbed (DESIGN.md §12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fht import fht_pallas
from repro.kernels.onebit import (
    finish_vote_counts_pallas,
    merge_counters_pallas,
    pack_pallas,
    popcount_partial_pallas,
    unpack_pallas,
    vote_pallas,
    vote_popcount_pallas,
    xor_popcount_pallas,
)
from repro.kernels.srht import dfht_pallas, srht_adj_pallas, srht_fwd_pallas

# Largest chunk the single-tile Kronecker kernels handle (a = b = 128).
KERNEL_MAX_C = 128 * 128
_KERNEL_MAX_C = KERNEL_MAX_C  # backwards-compat alias


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    """Resolve "auto" to the concrete path for this host."""
    if impl != "auto":
        return impl
    return "pallas" if _on_tpu() else "ref"


_auto = resolve_impl  # backwards-compat alias


def fht(x: jax.Array, impl: str = "auto") -> jax.Array:
    """Normalized FHT along the last axis (any power-of-two length).

    Lengths above the single-tile kernel limit (2^14) are handled by the
    Kronecker split H_{ab} = H_a (x) H_b: FHT along each factor of a
    row-major (a, b) reshape.
    """
    impl = resolve_impl(impl)
    n = x.shape[-1]
    assert _ref.is_pow2(n), f"FHT length must be a power of two, got {n}"
    if impl == "ref":
        return _ref.fht_ref(x)

    lead = x.shape[:-1]
    x2 = x.reshape(-1, n)

    def go(y):  # y: (rows, c), c any pow2
        c = y.shape[-1]
        if c <= KERNEL_MAX_C:
            return fht_pallas(y, interpret=not _on_tpu())
        b = KERNEL_MAX_C
        a = c // b
        y = y.reshape(-1, a, b)
        y = go(y.reshape(-1, b)).reshape(-1, a, b)          # H_b along last
        y = jnp.swapaxes(y, 1, 2)                            # (rows, b, a)
        y = go(y.reshape(-1, a)).reshape(-1, b, a)           # H_a along last
        return jnp.swapaxes(y, 1, 2).reshape(-1, c)

    return go(x2).reshape(*lead, n)


# ---------------------------------------------------------------------------
# Fused SRHT (single-pass sign-flip + FHT + subsample + scale per tile)
# ---------------------------------------------------------------------------

def srht_forward_2d(
    x: jax.Array,
    d: jax.Array,
    offsets: jax.Array,
    *,
    m_chunk: int,
    scale: float,
    impl: str = "auto",
) -> jax.Array:
    """Fused forward SRHT (Eq. 15-18 per block): one pass per chunk tile.

    x, d: (num_chunks, c) float32 (signal rows, Rademacher diagonals);
    offsets: (num_chunks, 1) int32 strided-subsample offsets in
    [0, c // m_chunk). Returns (num_chunks, m_chunk) float32 =
    scale * FHT(x * d)[offset + arange(m_chunk) * stride] per row.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.srht_fwd_ref(x, d, offsets, m_chunk=m_chunk, scale=scale)
    return srht_fwd_pallas(
        x, d, offsets, m_chunk=m_chunk, scale=scale, interpret=not _on_tpu()
    )


def srht_forward_packed_2d(
    x: jax.Array,
    d: jax.Array,
    offsets: jax.Array,
    *,
    m_chunk: int,
    scale: float,
    impl: str = "auto",
) -> jax.Array:
    """Forward SRHT with the sign + bit-pack epilogue — the uplink wire
    format of Alg. 1 step 2 (z_k = sign(Phi w_k), bit = value >= 0).

    Same operands as srht_forward_2d; returns (num_chunks, m_chunk // 32)
    uint32. Requires m_chunk % 32 == 0. On the kernel path the float
    sketch never leaves VMEM."""
    assert m_chunk % 32 == 0
    impl = resolve_impl(impl)
    if impl == "ref":
        z = _ref.srht_fwd_ref(x, d, offsets, m_chunk=m_chunk, scale=scale)
        return _ref.pack_ref(z)
    return srht_fwd_pallas(
        x, d, offsets, m_chunk=m_chunk, scale=scale, pack=True,
        interpret=not _on_tpu(),
    )


def srht_adjoint_2d(
    v: jax.Array,
    d: jax.Array,
    offsets: jax.Array,
    *,
    scale: float,
    impl: str = "auto",
) -> jax.Array:
    """Fused adjoint SRHT — the Phi^T of every Eq. 11 gradient step.

    v: (num_chunks, m_chunk) float32 cotangents; d: (num_chunks, c)
    diagonals; offsets: (num_chunks, 1) int32. Returns (num_chunks, c)
    float32 = FHT(scatter(scale * v)) * d per row (exact transpose of
    srht_forward_2d)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.srht_adj_ref(v, d, offsets, scale=scale)
    return srht_adj_pallas(v, d, offsets, scale=scale, interpret=not _on_tpu())


def srht_adjoint_batched_2d(
    v: jax.Array,
    d: jax.Array,
    offsets: jax.Array,
    *,
    scale: float,
    impl: str = "auto",
) -> jax.Array:
    """Batched fused adjoint: materialize B reconstructions in ONE pass.

    The serving-tier decode (serve/store.py) turns B clients' one-bit
    sketch residuals back into parameters at once. All B share the same
    sketch operator (same d, offsets — the store's spec), so the batch
    folds into the kernel's row grid: (B, num_chunks, m_chunk) cotangents
    become (B * num_chunks) rows of the same row-blocked pallas_call that
    srht_adjoint_2d launches for one client, instead of B sequential
    kernel dispatches.

    v: (B, num_chunks, m_chunk) float32; d: (num_chunks, c) diagonals;
    offsets: (num_chunks, 1) int32. Returns (B, num_chunks, c) float32,
    row b identical to srht_adjoint_2d(v[b], d, offsets).
    """
    impl = resolve_impl(impl)
    b, rows, m_chunk = v.shape
    c = d.shape[-1]
    vf = v.reshape(b * rows, m_chunk)
    df = jnp.broadcast_to(d[None], (b, rows, c)).reshape(b * rows, c)
    off = jnp.broadcast_to(offsets[None], (b, rows, 1)).reshape(b * rows, 1)
    if impl == "ref":
        out = _ref.srht_adj_ref(vf, df, off, scale=scale)
    else:
        out = srht_adj_pallas(vf, df, off, scale=scale, interpret=not _on_tpu())
    return out.reshape(b, rows, c)


def dfht(
    x: jax.Array, d: jax.Array, *, scale: float, d_post: bool = False,
    impl: str = "auto",
) -> jax.Array:
    """Fused scale * FHT(x * d) per row — the global-mode (paper-exact
    single-block SRHT) fast path; d_post applies d after the transform
    instead (the adjoint's order). x, d: (rows, c) float32, c a power of
    two <= 2^14; returns (rows, c) float32."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.dfht_ref(x, d, scale=scale, d_post=d_post)
    return dfht_pallas(x, d, scale=scale, d_post=d_post, interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# One-bit transport
# ---------------------------------------------------------------------------

def _block_words_for(nw: int, biggest: int) -> int:
    """Largest hardware-friendly block size dividing nw."""
    if nw <= biggest:
        return nw
    for bw in (biggest, biggest // 2, biggest // 4):
        if nw % bw == 0:
            return bw
    return 128


def pack_signs(x: jax.Array, impl: str = "auto") -> jax.Array:
    """Pack signs (x >= 0) of the last axis (multiple of 32) into uint32.

    The Pallas path handles arbitrary row counts / word counts by padding
    internally to the (8-row, 128-word) alignment and slicing the result.
    """
    impl = resolve_impl(impl)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]) if lead else x[None]
    if impl == "ref":
        out = _ref.pack_ref(x2)
    else:
        rows, m = x2.shape
        nw = m // 32
        rpad = (-rows) % 8
        # always pad the word count to a 128-lane multiple: Mosaic wants the
        # trailing block dim lane-aligned, and small unaligned widths are
        # exactly the shapes the old ref-fallback guard was protecting
        wpad = (-nw) % 128
        xp = jnp.pad(x2, ((0, rpad), (0, wpad * 32)))
        bw = _block_words_for(nw + wpad, 512)
        out = pack_pallas(xp, block_words=bw, interpret=not _on_tpu())[:rows, :nw]
    return out.reshape(*lead, -1) if lead else out[0]


def unpack_signs(words: jax.Array, impl: str = "auto") -> jax.Array:
    """Unpack uint32 words into +/-1 float32 along the last axis.

    Arbitrary shapes are padded internally on the Pallas path (see
    pack_signs) and sliced back out.
    """
    impl = resolve_impl(impl)
    lead = words.shape[:-1]
    w2 = words.reshape(-1, words.shape[-1]) if lead else words[None]
    if impl == "ref":
        out = _ref.unpack_ref(w2)
    else:
        rows, nw = w2.shape
        rpad = (-rows) % 8
        wpad = (-nw) % 128
        wp = jnp.pad(w2, ((0, rpad), (0, wpad)))
        bw = _block_words_for(nw + wpad, 512)
        out = unpack_pallas(wp, block_words=bw, interpret=not _on_tpu())
        out = out[:rows, : nw * 32]
    return out.reshape(*lead, -1) if lead else out[0]


def vote_packed(words: jax.Array, weights: jax.Array, impl: str = "auto") -> jax.Array:
    """Weighted majority vote on the wire format (server side of Lemma 1).

    words: (K, W) uint32 packed sketches; weights: (K,) float p_k.
    Returns (W,) uint32 — the packed consensus sign(sum_k p_k z_k) with
    ties broken to +1. Word count W is padded internally to the 128-lane
    alignment on the Pallas path and sliced back.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.vote_ref(words, weights)
    nw = words.shape[-1]
    wpad = (-nw) % 128
    wp = jnp.pad(words, ((0, 0), (0, wpad)))
    bw = _block_words_for(nw + wpad, 256)
    return vote_pallas(wp, weights, block_words=bw, interpret=not _on_tpu())[:nw]


def vote_packed_ragged(words: jax.Array, weights: jax.Array,
                       valid: jax.Array, impl: str = "auto") -> jax.Array:
    """Weighted vote over a RAGGED buffer padded to a static row capacity.

    The async tier's buffer flush (repro/sim/server.py) votes over however
    many uploads have arrived — B on a full flush, fewer on the final
    drain — but a jitted vote must see a static shape. Callers keep a
    fixed-capacity (B, W) uint32 buffer and a (B,) `valid` mask; invalid
    rows (stale slots from a previous flush, never-filled tail rows) are
    annihilated by zeroing their weight before the weighted vote, so their
    word content never matters. weights: (B,) float (already including any
    staleness discount); valid: (B,) float/bool.

    Returns (W,) uint32 packed consensus, ties -> +1 (vote_packed
    semantics).
    """
    w = weights * valid.astype(weights.dtype)
    return vote_packed(words, w, impl=impl)


def hamming_packed(words: jax.Array, ref_words: jax.Array,
                   impl: str = "auto") -> jax.Array:
    """Per-row Hamming distance between packed sketches and a packed
    reference (the trimmed packed vote's disagreement score).

    words: (K, W) uint32; ref_words: (W,) uint32 -> (K,) int32. The Pallas
    path XOR-popcounts word-level (kernels/onebit.py, no unpack) with the
    usual pad-to-alignment-and-slice; padded words are zero on both sides
    so they contribute 0 to every row equally.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.hamming_ref(words, ref_words)
    rows, nw = words.shape
    rpad = (-rows) % 8
    wpad = (-nw) % 128
    wp = jnp.pad(words, ((0, rpad), (0, wpad)))
    vp = jnp.pad(ref_words, (0, wpad))
    bw = _block_words_for(nw + wpad, 512)
    counts = xor_popcount_pallas(wp, vp, block_words=bw,
                                 interpret=not _on_tpu())
    return jnp.sum(counts[:rows, :nw], axis=-1)


def vote_packed_trimmed(words: jax.Array, weights: jax.Array, trim: int,
                        impl: str = "auto") -> jax.Array:
    """Trimmed weighted vote on the wire format (DESIGN.md §10): rank the
    voters by Hamming distance to a provisional packed consensus, zero the
    `trim` most-disagreeing voters' weights (never below one survivor),
    revote. Equal distances break to the lower client index (stable
    argsort); zero-weight rows never vote and are never trimmed.

    The provisional consensus is UNWEIGHTED (uniform over the active
    voters) for the same reason as core/consensus.trimmed_vote: a
    weight-heavy colluding bloc must not be able to drag the ranking
    reference toward its own corruption. The final revote is weighted.

    words: (K, W) uint32; weights: (K,) float -> (W,) uint32 packed
    consensus. Ties -> +1 in both votes (vote_packed semantics). Padded
    word columns are constant across rows, so they cancel in every
    pairwise distance comparison and cannot reorder the trim ranking.
    """
    v0 = vote_packed(words, (weights > 0).astype(jnp.float32), impl=impl)
    d = hamming_packed(words, v0, impl=impl)
    score = jnp.where(weights > 0, d, -1)           # non-voters rank last
    voters = jnp.sum((weights > 0).astype(jnp.int32))
    t = jnp.minimum(jnp.asarray(trim, jnp.int32), jnp.maximum(voters - 1, 0))
    order = jnp.argsort(-score)                     # stable: ties -> low index
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    kept = jnp.where(ranks < t, 0.0, weights)
    return vote_packed(words, kept, impl=impl)


def vote_popcount(words: jax.Array, impl: str = "auto") -> jax.Array:
    """UNWEIGHTED majority vote, fully word-level (no unpack, no floats).

    The uniform-p_k specialization of Lemma 1: consensus bit b is set iff
    at least ceil(K/2) of the K clients set bit b (tie -> +1). The Pallas
    kernel keeps per-position counts as bit-sliced uint32 planes
    (kernels/onebit.py); the reference counts via unpack. Integer-exact:
    both paths agree bit-for-bit for every K.

    words: (K, W) uint32 -> (W,) uint32. Padded word columns (all-zero)
    vote to 0 for K >= 2 and are sliced off by the caller's [:m] unpack.
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.vote_popcount_ref(words)
    nw = words.shape[-1]
    wpad = (-nw) % 128
    wp = jnp.pad(words, ((0, 0), (0, wpad)))
    bw = _block_words_for(nw + wpad, 512)
    return vote_popcount_pallas(wp, block_words=bw, interpret=not _on_tpu())[:nw]


# ---------------------------------------------------------------------------
# Partial popcount counters — hierarchical tree aggregation (DESIGN.md §11)
# ---------------------------------------------------------------------------

def popcount_partial(words: jax.Array, impl: str = "auto") -> jax.Array:
    """A leaf tier's partial popcount counter over its packed sketches.

    words: (Kl, W) uint32 -> (W, 32) int32 per-(word, bit-position) set-bit
    counts in [0, Kl]. Counters are sum-decomposable: summing the counters
    of any row partition equals counting the flat matrix — the exactness
    property the tree vote rests on (unlike sign-then-sign, see
    core/consensus.tree_vote_popcount). An empty leaf (Kl = 0) counts to
    all zeros on both paths.
    """
    if words.shape[0] == 0:
        return jnp.zeros((words.shape[-1], 32), jnp.int32)
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.popcount_partial_ref(words)
    nw = words.shape[-1]
    wpad = (-nw) % 128
    wp = jnp.pad(words, ((0, 0), (0, wpad)))
    bw = _block_words_for(nw + wpad, 512)
    return popcount_partial_pallas(wp, block_words=bw, interpret=not _on_tpu())[:nw]


def merge_counters(counters: jax.Array, impl: str = "auto") -> jax.Array:
    """Merge a stack of partial counters at an interior tier.

    counters: (T, W, 32) int32 -> (W, 32) int32 elementwise integer sum —
    exact, associative, commutative, so the tree shape cannot change the
    totals. T = 0 merges to zeros.
    """
    if counters.shape[0] == 0:
        return jnp.zeros(counters.shape[1:], jnp.int32)
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.merge_counters_ref(counters)
    t, nw, _ = counters.shape
    wpad = (-nw) % 4  # lane axis is the flattened W*32 — pad to 128 lanes
    cp = jnp.pad(counters, ((0, 0), (0, wpad), (0, 0)))
    bc = _block_words_for((nw + wpad) * 32, 512)
    return merge_counters_pallas(cp, block_cols=bc, interpret=not _on_tpu())[:nw]


def finish_vote_counts(counts: jax.Array, k, impl: str = "auto") -> jax.Array:
    """Finish the majority vote at the root from fully merged counters.

    counts: (W, 32) int32; k: total voters. Consensus bit is 2*cnt >= k
    (tie -> +1, vote_popcount semantics; k = 0 gives all +1, matching a
    zero-weight packed vote). A traced k — the trimmed revote's kept-count
    is data-dependent — always takes the ref finisher; the Pallas kernel
    needs k static.
    """
    impl = resolve_impl(impl)
    if impl == "ref" or isinstance(k, jax.Array):
        return _ref.finish_vote_counts_ref(counts, k)
    nw = counts.shape[0]
    wpad = (-nw) % 128
    cp = jnp.pad(counts, ((0, wpad), (0, 0)))
    bw = _block_words_for(nw + wpad, 512)
    return finish_vote_counts_pallas(
        cp, k=int(k), block_words=bw, interpret=not _on_tpu()
    )[:nw]


# ---------------------------------------------------------------------------
# Kernel probe instrumentation (obs/probe.py)
# ---------------------------------------------------------------------------

from repro.obs import probe as _probe  # noqa: E402  (after the dispatchers)

# The PRIMITIVE dispatchers. vote_packed_ragged / vote_packed_trimmed are
# deliberately NOT probed: they are thin compositions of probed primitives,
# and wrapping both layers would double-count every inner call's time and
# bytes in the per-kernel table.
_PROBED = (
    "fht",
    "srht_forward_2d",
    "srht_forward_packed_2d",
    "srht_adjoint_2d",
    "srht_adjoint_batched_2d",
    "dfht",
    "pack_signs",
    "unpack_signs",
    "vote_packed",
    "hamming_packed",
    "vote_popcount",
    "popcount_partial",
    "merge_counters",
    "finish_vote_counts",
)
for _name in _PROBED:
    globals()[_name] = _probe.instrument(_name, globals()[_name])
del _name
