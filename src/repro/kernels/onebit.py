"""Pallas TPU kernels for one-bit sketch transport.

pFed1BS puts *bits* on the wire: sketches are sign vectors packed 32-per-word
before crossing the pod (federation) axis, and the server's majority vote
operates on the packed representation. These are VPU-bound elementwise
kernels; blocking keeps each tile in VMEM and lane-aligned (last dim 128).

Kernels:
  pack_pallas          : (rows, 32*W) float -> (rows, W) uint32  (bit = x >= 0)
  unpack_pallas        : (rows, W) uint32   -> (rows, 32*W) +/-1 float
  vote_pallas          : (K, W) uint32, (K,) weights -> (W,) uint32 weighted
                         majority (unpacks to float lanes internally)
  vote_popcount_pallas : (K, W) uint32 -> (W,) uint32 UNWEIGHTED majority,
                         fully word-level: per-position counts are held as
                         ceil(log2(K+1)) bit-sliced uint32 planes and the
                         majority test is one carry-propagating constant add
                         — no 32x unpack, no float math (DESIGN.md §6.2)
  xor_popcount_pallas  : (K, W) uint32 vs a (W,) reference row -> (K, W)
                         int32 per-word differing-bit counts (SWAR popcount
                         of the XOR, no unpack) — the Hamming-distance
                         measure of the trimmed packed vote (DESIGN.md §10);
                         callers row-sum the word counts
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref):
    rows, m = x_ref.shape
    bits = (x_ref[...] >= 0).astype(jnp.uint32).reshape(rows, m // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    o_ref[...] = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def _unpack_kernel(w_ref, o_ref):
    rows, nw = w_ref.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w_ref[...][..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(o_ref.dtype) * 2 - 1
    o_ref[...] = pm.reshape(rows, nw * 32)


def _vote_kernel(w_ref, p_ref, o_ref):
    k, nw = w_ref.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w_ref[...][..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(jnp.float32) * 2 - 1                    # (K, nw, 32)
    s = jnp.einsum("k,kwb->wb", p_ref[...], pm)              # weighted sum
    out_bits = (s >= 0).astype(jnp.uint32) << shifts[0]      # tie -> +1
    o_ref[...] = jnp.sum(out_bits, axis=-1).astype(jnp.uint32)[None]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def pack_pallas(x, *, block_rows: int = 8, block_words: int = 512, interpret: bool = False):
    rows, m = x.shape
    assert m % 32 == 0
    nw = m // 32
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _pack_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words * 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def unpack_pallas(words, *, block_rows: int = 8, block_words: int = 512, interpret: bool = False):
    rows, nw = words.shape
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _unpack_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_words * 32), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw * 32), jnp.float32),
        interpret=interpret,
    )(words)


def _popcount_vote_kernel(w_ref, o_ref):
    """Bit-sliced majority vote: counts live as uint32 bit planes.

    For each of the 32 bit positions of every word lane we need
    cnt = #clients whose bit is set, then the majority bit cnt >= ceil(K/2).
    Instead of unpacking to (K, W, 32) lanes, keep the per-position count as
    P = bitlength(K) "vertical" planes c_0..c_{P-1} (plane j holds bit j of
    every count) and ripple-carry each client word in: ~K*P bitwise VPU ops
    on (1, W) words total. The threshold 2*cnt >= K is evaluated bit-sliced
    too: the carry-out of adding the constant 2^P - ceil(K/2) to the counter
    is exactly the majority mask (tie -> +1 for even K).
    """
    k, nw = w_ref.shape
    p = k.bit_length()
    x = w_ref[...]
    zero = jnp.zeros((1, nw), jnp.uint32)
    planes = [zero] * p
    for i in range(k):                       # static unroll over clients
        carry = x[i : i + 1]
        for j in range(p):                   # half-adder ripple into planes
            planes[j], carry = planes[j] ^ carry, planes[j] & carry
    thresh = (1 << p) - ((k + 1) // 2)       # cnt + thresh overflows 2^P
    ones = jnp.full((1, nw), 0xFFFFFFFF, dtype=jnp.uint32)
    carry = zero                             # iff cnt >= ceil(K/2)
    for j in range(p):
        b = ones if (thresh >> j) & 1 else zero
        carry = (planes[j] & b) | (carry & (planes[j] ^ b))
    o_ref[...] = carry


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def vote_popcount_pallas(words, *, block_words: int = 512, interpret: bool = False):
    """Unweighted word-level majority vote: (K, W) uint32 -> (W,) uint32."""
    k, nw = words.shape
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        _popcount_vote_kernel,
        grid=(nw // block_words,),
        in_specs=[pl.BlockSpec((k, block_words), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nw), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[0]


def _xor_popcount_kernel(w_ref, v_ref, o_ref):
    """Per-word count of bits differing from the reference row.

    XOR then the classic SWAR popcount — pair, nibble, byte-fold via
    shifts (no 32-bit multiply): pure VPU bitwise ops on uint32 lanes,
    same alignment story as pack/unpack. Per-word counts <= 32 so every
    intermediate byte field stays far below overflow.
    """
    x = w_ref[...] ^ v_ref[...]                              # (rows, W)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = x + (x >> 8)
    x = x + (x >> 16)
    o_ref[...] = (x & jnp.uint32(0x3F)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def xor_popcount_pallas(words, vwords, *, block_rows: int = 8,
                        block_words: int = 512, interpret: bool = False):
    """(K, W) uint32 rows vs (W,) uint32 reference -> (K, W) int32 per-word
    Hamming counts (sum along the word axis for per-row distances)."""
    rows, nw = words.shape
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _xor_popcount_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[
            pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_words), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.int32),
        interpret=interpret,
    )(words, vwords[None])


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def vote_pallas(words, weights, *, block_words: int = 256, interpret: bool = False):
    """Weighted majority vote over K packed sketches -> packed consensus."""
    k, nw = words.shape
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        _vote_kernel,
        grid=(nw // block_words,),
        in_specs=[
            pl.BlockSpec((k, block_words), lambda j: (0, j)),
            pl.BlockSpec((k,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nw), jnp.uint32),
        interpret=interpret,
    )(words, weights.astype(jnp.float32))
    return out[0]
