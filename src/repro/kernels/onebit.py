"""Pallas TPU kernels for one-bit sketch transport.

pFed1BS puts *bits* on the wire: sketches are sign vectors packed 32-per-word
before crossing the pod (federation) axis, and the server's majority vote
operates on the packed representation. These are VPU-bound elementwise
kernels; blocking keeps each tile in VMEM and lane-aligned (last dim 128).

Kernels:
  pack_pallas          : (rows, 32*W) float -> (rows, W) uint32  (bit = x >= 0)
  unpack_pallas        : (rows, W) uint32   -> (rows, 32*W) +/-1 float
  vote_pallas          : (K, W) uint32, (K,) weights -> (W,) uint32 weighted
                         majority (unpacks to float lanes internally)
  vote_popcount_pallas : (K, W) uint32 -> (W,) uint32 UNWEIGHTED majority,
                         fully word-level: per-position counts are held as
                         ceil(log2(K+1)) bit-sliced uint32 planes and the
                         majority test is one carry-propagating constant add
                         — no 32x unpack, no float math (DESIGN.md §6.2)
  xor_popcount_pallas  : (K, W) uint32 vs a (W,) reference row -> (K, W)
                         int32 per-word differing-bit counts (SWAR popcount
                         of the XOR, no unpack) — the Hamming-distance
                         measure of the trimmed packed vote (DESIGN.md §10);
                         callers row-sum the word counts

Hierarchical tree aggregation (DESIGN.md §11) splits the popcount vote at
the leaf/root boundary so edge tiers can merge without finishing:
  popcount_partial_pallas    : (Kl, W) uint32 -> (W, 32) int32 per-position
                               set-bit counts — a leaf's partial counter.
                               Counts ride the same bit-sliced ripple-carry
                               planes as the fused vote, then expand the
                               P = bitlength(Kl) planes (not the Kl rows)
                               into integer lanes
  merge_counters_pallas      : (T, W, 32) int32 -> (W, 32) int32 exact sum
                               — an interior tier merging child counters
  finish_vote_counts_pallas  : (W, 32) int32 counts, static total k ->
                               (W,) uint32 packed majority (2*cnt >= k,
                               tie -> +1) — the root finishing the vote
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref):
    rows, m = x_ref.shape
    bits = (x_ref[...] >= 0).astype(jnp.uint32).reshape(rows, m // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    o_ref[...] = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def _unpack_kernel(w_ref, o_ref):
    rows, nw = w_ref.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w_ref[...][..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(o_ref.dtype) * 2 - 1
    o_ref[...] = pm.reshape(rows, nw * 32)


def _vote_kernel(w_ref, p_ref, o_ref):
    k, nw = w_ref.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w_ref[...][..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(jnp.float32) * 2 - 1                    # (K, nw, 32)
    s = jnp.einsum("k,kwb->wb", p_ref[...], pm)              # weighted sum
    out_bits = (s >= 0).astype(jnp.uint32) << shifts[0]      # tie -> +1
    o_ref[...] = jnp.sum(out_bits, axis=-1).astype(jnp.uint32)[None]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def pack_pallas(x, *, block_rows: int = 8, block_words: int = 512, interpret: bool = False):
    rows, m = x.shape
    assert m % 32 == 0
    nw = m // 32
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _pack_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words * 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def unpack_pallas(words, *, block_rows: int = 8, block_words: int = 512, interpret: bool = False):
    rows, nw = words.shape
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _unpack_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_words * 32), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw * 32), jnp.float32),
        interpret=interpret,
    )(words)


def _popcount_vote_kernel(w_ref, o_ref):
    """Bit-sliced majority vote: counts live as uint32 bit planes.

    For each of the 32 bit positions of every word lane we need
    cnt = #clients whose bit is set, then the majority bit cnt >= ceil(K/2).
    Instead of unpacking to (K, W, 32) lanes, keep the per-position count as
    P = bitlength(K) "vertical" planes c_0..c_{P-1} (plane j holds bit j of
    every count) and ripple-carry each client word in: ~K*P bitwise VPU ops
    on (1, W) words total. The threshold 2*cnt >= K is evaluated bit-sliced
    too: the carry-out of adding the constant 2^P - ceil(K/2) to the counter
    is exactly the majority mask (tie -> +1 for even K).
    """
    k, nw = w_ref.shape
    p = k.bit_length()
    x = w_ref[...]
    zero = jnp.zeros((1, nw), jnp.uint32)
    planes = [zero] * p
    for i in range(k):                       # static unroll over clients
        carry = x[i : i + 1]
        for j in range(p):                   # half-adder ripple into planes
            planes[j], carry = planes[j] ^ carry, planes[j] & carry
    thresh = (1 << p) - ((k + 1) // 2)       # cnt + thresh overflows 2^P
    ones = jnp.full((1, nw), 0xFFFFFFFF, dtype=jnp.uint32)
    carry = zero                             # iff cnt >= ceil(K/2)
    for j in range(p):
        b = ones if (thresh >> j) & 1 else zero
        carry = (planes[j] & b) | (carry & (planes[j] ^ b))
    o_ref[...] = carry


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def vote_popcount_pallas(words, *, block_words: int = 512, interpret: bool = False):
    """Unweighted word-level majority vote: (K, W) uint32 -> (W,) uint32."""
    k, nw = words.shape
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        _popcount_vote_kernel,
        grid=(nw // block_words,),
        in_specs=[pl.BlockSpec((k, block_words), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nw), jnp.uint32),
        interpret=interpret,
    )(words)
    return out[0]


def _popcount_partial_kernel(w_ref, o_ref):
    """Leaf-side partial popcount: packed rows -> per-position counts.

    Same bit-sliced ripple-carry accumulation as the fused vote kernel
    (P = bitlength(Kl) uint32 planes, ~Kl*P bitwise ops), but instead of
    thresholding, the P *planes* are expanded into integer lanes: count of
    bit position b in word w is sum_j (bit b of plane_j[w]) << j. That is
    P plane-expansions instead of Kl row-unpacks — the leaf pays the same
    VPU cost as voting, yet emits mergeable counts. Output layout inside
    the kernel is position-major (32, W) so the lane axis stays the
    128-aligned word axis; the wrapper transposes to the (W, 32) oracle
    layout.
    """
    k, nw = w_ref.shape
    p = max(k.bit_length(), 1)
    x = w_ref[...]
    zero = jnp.zeros((1, nw), jnp.uint32)
    planes = [zero] * p
    for i in range(k):                       # static unroll over clients
        carry = x[i : i + 1]
        for j in range(p):                   # half-adder ripple into planes
            planes[j], carry = planes[j] ^ carry, planes[j] & carry
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, 1), 0)
    cnt = jnp.zeros((32, nw), jnp.int32)
    for j in range(p):                       # expand planes, not rows
        plane = jnp.broadcast_to(planes[j], (32, nw))
        bits = ((plane >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        cnt = cnt + (bits << j)
    o_ref[...] = cnt


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def popcount_partial_pallas(words, *, block_words: int = 512, interpret: bool = False):
    """Partial counter of a leaf shard: (Kl, W) uint32 -> (W, 32) int32."""
    k, nw = words.shape
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        _popcount_partial_kernel,
        grid=(nw // block_words,),
        in_specs=[pl.BlockSpec((k, block_words), lambda j: (0, j))],
        out_specs=pl.BlockSpec((32, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((32, nw), jnp.int32),
        interpret=interpret,
    )(words)
    return out.T


def _merge_counters_kernel(c_ref, o_ref):
    o_ref[...] = jnp.sum(c_ref[...], axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_cols", "interpret"))
def merge_counters_pallas(counters, *, block_cols: int = 512, interpret: bool = False):
    """Exact interior-tier merge: (T, W, 32) int32 -> (W, 32) int32.

    Integer lane adds over the flattened (W*32) count axis — associativity
    of the tree merge is inherited from integer addition, nothing subtle.
    """
    t, nw, _ = counters.shape
    cols = nw * 32
    flat = counters.astype(jnp.int32).reshape(t, cols)
    block_cols = min(block_cols, cols)
    assert cols % block_cols == 0
    out = pl.pallas_call(
        _merge_counters_kernel,
        grid=(cols // block_cols,),
        in_specs=[pl.BlockSpec((t, block_cols), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_cols), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, cols), jnp.int32),
        interpret=interpret,
    )(flat)
    return out.reshape(nw, 32)


def _finish_vote_kernel(c_ref, o_ref, *, k):
    """Root-side finish: majority bit = 2*cnt >= k (tie -> +1), repacked."""
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (32, 1), 0)
    maj = jnp.where(2 * c_ref[...] >= k, jnp.uint32(1), jnp.uint32(0)) << shifts
    o_ref[...] = jnp.sum(maj, axis=0, keepdims=True).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "block_words", "interpret"))
def finish_vote_counts_pallas(counts, *, k: int, block_words: int = 512,
                              interpret: bool = False):
    """Finish the vote from merged counters: (W, 32) int32 -> (W,) uint32.

    k (the total voter count) is static; callers with a traced k (the
    trimmed revote's kept-count) use the ref finisher via kernels/ops.
    """
    nw = counts.shape[0]
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        functools.partial(_finish_vote_kernel, k=k),
        grid=(nw // block_words,),
        in_specs=[pl.BlockSpec((32, block_words), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nw), jnp.uint32),
        interpret=interpret,
    )(counts.T)
    return out[0]


def _xor_popcount_kernel(w_ref, v_ref, o_ref):
    """Per-word count of bits differing from the reference row.

    XOR then the classic SWAR popcount — pair, nibble, byte-fold via
    shifts (no 32-bit multiply): pure VPU bitwise ops on uint32 lanes,
    same alignment story as pack/unpack. Per-word counts <= 32 so every
    intermediate byte field stays far below overflow.
    """
    x = w_ref[...] ^ v_ref[...]                              # (rows, W)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = x + (x >> 8)
    x = x + (x >> 16)
    o_ref[...] = (x & jnp.uint32(0x3F)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def xor_popcount_pallas(words, vwords, *, block_rows: int = 8,
                        block_words: int = 512, interpret: bool = False):
    """(K, W) uint32 rows vs (W,) uint32 reference -> (K, W) int32 per-word
    Hamming counts (sum along the word axis for per-row distances)."""
    rows, nw = words.shape
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _xor_popcount_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[
            pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_words), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.int32),
        interpret=interpret,
    )(words, vwords[None])


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def vote_pallas(words, weights, *, block_words: int = 256, interpret: bool = False):
    """Weighted majority vote over K packed sketches -> packed consensus."""
    k, nw = words.shape
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        _vote_kernel,
        grid=(nw // block_words,),
        in_specs=[
            pl.BlockSpec((k, block_words), lambda j: (0, j)),
            pl.BlockSpec((k,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nw), jnp.uint32),
        interpret=interpret,
    )(words, weights.astype(jnp.float32))
    return out[0]
