"""Pallas TPU kernels for one-bit sketch transport.

pFed1BS puts *bits* on the wire: sketches are sign vectors packed 32-per-word
before crossing the pod (federation) axis, and the server's majority vote
operates on the packed representation. These are VPU-bound elementwise
kernels; blocking keeps each tile in VMEM and lane-aligned (last dim 128).

Kernels:
  pack_pallas    : (rows, 32*W) float -> (rows, W) uint32   (bit = x >= 0)
  unpack_pallas  : (rows, W) uint32   -> (rows, 32*W) +/-1 float
  vote_pallas    : (K, W) uint32, (K,) weights -> (W,) uint32 weighted majority
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pack_kernel(x_ref, o_ref):
    rows, m = x_ref.shape
    bits = (x_ref[...] >= 0).astype(jnp.uint32).reshape(rows, m // 32, 32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    o_ref[...] = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def _unpack_kernel(w_ref, o_ref):
    rows, nw = w_ref.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w_ref[...][..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(o_ref.dtype) * 2 - 1
    o_ref[...] = pm.reshape(rows, nw * 32)


def _vote_kernel(w_ref, p_ref, o_ref):
    k, nw = w_ref.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (w_ref[...][..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(jnp.float32) * 2 - 1                    # (K, nw, 32)
    s = jnp.einsum("k,kwb->wb", p_ref[...], pm)              # weighted sum
    out_bits = (s >= 0).astype(jnp.uint32) << shifts[0]      # tie -> +1
    o_ref[...] = jnp.sum(out_bits, axis=-1).astype(jnp.uint32)[None]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def pack_pallas(x, *, block_rows: int = 8, block_words: int = 512, interpret: bool = False):
    rows, m = x.shape
    assert m % 32 == 0
    nw = m // 32
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _pack_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words * 32), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw), jnp.uint32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def unpack_pallas(words, *, block_rows: int = 8, block_words: int = 512, interpret: bool = False):
    rows, nw = words.shape
    block_rows = min(block_rows, rows)
    block_words = min(block_words, nw)
    assert rows % block_rows == 0 and nw % block_words == 0
    return pl.pallas_call(
        _unpack_kernel,
        grid=(rows // block_rows, nw // block_words),
        in_specs=[pl.BlockSpec((block_rows, block_words), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_words * 32), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, nw * 32), jnp.float32),
        interpret=interpret,
    )(words)


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def vote_pallas(words, weights, *, block_words: int = 256, interpret: bool = False):
    """Weighted majority vote over K packed sketches -> packed consensus."""
    k, nw = words.shape
    block_words = min(block_words, nw)
    assert nw % block_words == 0
    out = pl.pallas_call(
        _vote_kernel,
        grid=(nw // block_words,),
        in_specs=[
            pl.BlockSpec((k, block_words), lambda j: (0, j)),
            pl.BlockSpec((k,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_words), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, nw), jnp.uint32),
        interpret=interpret,
    )(words, weights.astype(jnp.float32))
    return out[0]
