"""Pallas TPU kernel for the Fast Hadamard Transform.

TPU adaptation (see DESIGN.md §3): instead of a butterfly network (a GPU
warp-shuffle pattern with no TPU analogue), we factor the Walsh-Hadamard
matrix as a Kronecker product H_c = H_a (x) H_b with a, b <= 128, so the
per-tile transform is two MXU matmuls on a VMEM-resident (block_rows, a, b)
tile:

    Y = H_a @ X @ H_b        where X = x.reshape(block_rows, a, b)

Both H_a and H_b are normalized (orthonormal), so the composition is the
normalized FHT. Tiles are hardware-aligned: a = b = 128 gives 128x128 MXU
matmuls for the default chunk size c = 16384.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import hadamard_matrix, is_pow2


def _split_pow2(c: int) -> tuple[int, int]:
    """Split c = a*b with a, b powers of two and a, b <= 128."""
    assert is_pow2(c) and c <= 128 * 128, f"kernel supports c <= 16384, got {c}"
    log = c.bit_length() - 1
    la = log // 2
    return 1 << la, 1 << (log - la)


def _fht_tile(x: jax.Array, ha: jax.Array, hb: jax.Array, a: int, b: int):
    """FHT of a (rows, a*b) tile via the two-matmul Kronecker factorization.

    Shared by the standalone FHT kernel below and the fused SRHT kernels
    (kernels/srht.py) — the tile math must stay identical between them.
    """
    rows = x.shape[0]
    x = x.reshape(rows, a, b)
    # X @ H_b: contract the trailing b axis (MXU matmul, b-aligned).
    t = jax.lax.dot_general(
        x, hb, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (rows, a, b)
    # H_a @ X: contract the a axis.
    y = jax.lax.dot_general(
        t, ha, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (rows, b, a) -- note output axes order (rows, b, a)
    return jnp.transpose(y, (0, 2, 1)).reshape(rows, a * b)


def _fht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int):
    """One grid step: FHT of a (block_rows, a*b) VMEM tile via two matmuls."""
    o_ref[...] = _fht_tile(
        x_ref[...], ha_ref[...], hb_ref[...], a, b
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fht_pallas(
    x: jax.Array, *, block_rows: int = 8, interpret: bool = False
) -> jax.Array:
    """Normalized FHT along the last axis of x: (rows, c) with c = 2^k <= 16384.

    Grid over row blocks; each step holds a (block_rows, c) tile plus the two
    Hadamard factors in VMEM (c=16384, br=8: 8*16384*4B = 512KiB + 2*64KiB).
    """
    rows, c = x.shape
    a, b = _split_pow2(c)
    ha = hadamard_matrix(a, jnp.float32)
    hb = hadamard_matrix(b, jnp.float32)

    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    padded_rows = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_fht_kernel, a=a, b=b),
        grid=(padded_rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, c), x.dtype),
        interpret=interpret,
    )(x, ha, hb)
    return out[:rows] if pad else out
