"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real lowering on TPU). They are also the default execution path on
CPU hosts, where Pallas interpret mode would be needlessly slow.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Normalized Walsh-Hadamard matrix H_n with H @ H.T = I (n power of 2)."""
    assert is_pow2(n), f"Hadamard size must be a power of two, got {n}"
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return jnp.asarray(h / np.sqrt(n), dtype=dtype)


def fht_ref(x: jax.Array) -> jax.Array:
    """Normalized Fast Hadamard Transform along the last axis.

    Iterative butterfly; length must be a power of two. Orthonormal:
    fht_ref(fht_ref(x)) == x.
    """
    n = x.shape[-1]
    assert is_pow2(n), f"FHT length must be a power of two, got {n}"
    orig_shape = x.shape
    x = x.reshape(-1, n)
    h = 1
    while h < n:
        x = x.reshape(-1, n // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
        h *= 2
    return (x / jnp.sqrt(jnp.asarray(n, x.dtype))).reshape(orig_shape)


# ---------------------------------------------------------------------------
# Fused SRHT oracles (staged pipeline; ground truth for kernels/srht.py)
# ---------------------------------------------------------------------------

def srht_fwd_ref(x, d, offsets, *, m_chunk, scale):
    """Staged forward SRHT: z = scale * FHT(x * d)[offset + arange(m)*stride].

    x, d: (rows, c); offsets: (rows, 1) int32 in [0, c // m_chunk).
    """
    c = x.shape[-1]
    stride = c // m_chunk
    y = fht_ref(x * d)
    idx = offsets + jnp.arange(m_chunk)[None, :] * stride   # (rows, m_chunk)
    return scale * jnp.take_along_axis(y, idx, axis=-1)


def srht_adj_ref(v, d, offsets, *, scale):
    """Staged adjoint SRHT: w = FHT(S^T (scale * v)) * d. v: (rows, m_chunk)."""
    rows, m_chunk = v.shape
    c = d.shape[-1]
    stride = c // m_chunk
    idx = offsets + jnp.arange(m_chunk)[None, :] * stride
    lifted = jnp.zeros((rows, c), jnp.float32).at[
        jnp.arange(rows)[:, None], idx
    ].set(scale * v)
    return fht_ref(lifted) * d


def dfht_ref(x, d, *, scale, d_post=False):
    """scale * FHT(x * d) per row, or scale * FHT(x) * d when d_post."""
    if d_post:
        return scale * fht_ref(x) * d
    return scale * fht_ref(x * d)


# ---------------------------------------------------------------------------
# One-bit packing / majority vote
# ---------------------------------------------------------------------------

def pack_ref(x: jax.Array) -> jax.Array:
    """Pack signs of x (last axis length divisible by 32) into uint32 words.

    Convention: bit = 1 iff x >= 0 (zero maps to +1).
    """
    m = x.shape[-1]
    assert m % 32 == 0, f"pack length must be divisible by 32, got {m}"
    bits = (x >= 0).astype(jnp.uint32).reshape(*x.shape[:-1], m // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint32)


def unpack_ref(words: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Unpack uint32 words into +/-1 values (bit 1 -> +1, bit 0 -> -1)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    pm = bits.astype(dtype) * 2 - 1
    return pm.reshape(*words.shape[:-1], words.shape[-1] * 32)


def vote_ref(words: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted majority vote over packed one-bit sketches.

    words: (K, W) uint32 packed sketches; weights: (K,) nonnegative.
    Returns packed uint32 (W,) with ties (weighted sum == 0) broken to +1.
    """
    pm = unpack_ref(words)                       # (K, 32W)
    s = jnp.einsum("k,km->m", weights, pm)       # weighted sign sum
    return pack_ref(s)                           # >= 0 -> +1 handles tie->+1


def hamming_ref(words: jax.Array, vwords: jax.Array) -> jax.Array:
    """Per-row Hamming distance to a packed reference row.

    Ground truth for the XOR-popcount kernel (the trimmed packed vote's
    disagreement measure): row k's count of bit positions where it differs
    from `vwords`. words: (K, W) uint32; vwords: (W,) uint32 -> (K,) int32.
    """
    diff = words ^ vwords[None, :]
    return jnp.sum(jax.lax.population_count(diff).astype(jnp.int32), axis=-1)


def vote_popcount_ref(words: jax.Array) -> jax.Array:
    """Unweighted (uniform-p_k) majority vote on packed words via bit counts.

    Ground truth for the word-level popcount vote kernel: per bit position b,
    count the set bits across the K clients; the consensus bit is
    2*count >= K (tie -> +1, matching `vote_ref` with uniform weights,
    integer-exact — no float accumulation at all).

    words: (K, W) uint32 -> (W,) uint32.
    """
    k = words.shape[0]
    maj = finish_vote_counts_ref(popcount_partial_ref(words), k)
    return maj


# ---------------------------------------------------------------------------
# Partial popcount counters (hierarchical tree aggregation, DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# A leaf aggregator that holds only SOME of the K clients cannot finish the
# majority vote — but it can count. `popcount_partial_ref` turns a leaf's
# packed words into per-bit-position set-bit counts; counts are integers, so
# merging two leaves is an exact elementwise sum (associative, commutative,
# invariant to how the rows were split — the properties tests/test_hier.py
# pins with hypothesis), and `finish_vote_counts_ref` at the root reproduces
# `vote_popcount_ref` on the flat matrix BIT-exactly. Taking the sign at the
# leaf instead (majority-of-majorities) destroys the margins and is NOT
# equivalent — the pinned counterexample in tests/test_hier.py.

def popcount_partial_ref(words: jax.Array) -> jax.Array:
    """Partial popcount counter of a leaf's packed sketches: per (word, bit
    position), the number of rows with that bit set.

    words: (Kl, W) uint32 -> (W, 32) int32 counts in [0, Kl]. The (W, 32)
    layout matches the 32-per-word bit packing: counter[w, b] counts bit b
    of word w, i.e. sketch coordinate 32*w + b.
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)   # (Kl, W, 32)
    return jnp.sum(bits.astype(jnp.int32), axis=0)        # (W, 32)


def merge_counters_ref(counters: jax.Array) -> jax.Array:
    """Sum a stack of partial counters: (T, W, 32) int32 -> (W, 32) int32.

    Integer addition — exact, associative, commutative; merging in any tree
    shape yields the same totals as counting the flat matrix once.
    """
    return jnp.sum(counters.astype(jnp.int32), axis=0)


def finish_vote_counts_ref(counts: jax.Array, k) -> jax.Array:
    """Finish the majority vote from merged counters: consensus bit b of
    word w is set iff 2*counts[w, b] >= k (tie -> +1, vote_popcount_ref's
    convention; k = 0 packs all-ones, matching a zero-weight packed vote).

    counts: (W, 32) int32; k: total voters (python int or traced int32).
    Returns (W,) uint32 packed consensus.
    """
    shifts = jnp.arange(32, dtype=jnp.uint32)
    maj = (2 * counts >= k).astype(jnp.uint32) << shifts
    return jnp.sum(maj, axis=-1).astype(jnp.uint32)
