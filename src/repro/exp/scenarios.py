"""Heterogeneity scenarios: partition x imbalance x participation.

The paper's claim is comparative — pFed1BS matches the advanced
communication-efficient baselines at a fraction of the bits *under client
heterogeneity* — and related work pins exactly these axes: FedSKETCH
sweeps heterogeneity levels, DisPFL shows personalized-FL conclusions flip
with Dirichlet non-IID severity and participation rate. A `Scenario`
composes the three axes as frozen dataclasses:

  data axis          DirichletPartition(alpha) | LabelSkewPartition(c) |
                     IIDPartition — how the centralized pool is split
                     (data/synthetic.py partitioners), plus a lognormal
                     per-client sample-count `imbalance` sigma.
  participation axis FullParticipation | UniformSampling(rate) |
                     StragglerDropout(rate, drop) |
                     AvailabilityCycle(rate, period, duty) — who shows up
                     each round, drawn seed-deterministically OUTSIDE the
                     jitted round and passed in as (idx, active); the
                     engines (core/pfed1bs.py, core/baselines.py) treat
                     active=0 as "trained nothing landed": params kept, no
                     vote, no bits.
  latency axis       ConstantLatency | ComputeNetworkLatency |
                     StragglerTailLatency (sim/clock.py) — how long each
                     client's round trip takes in VIRTUAL seconds. The
                     synchronous harness uses it only to cost a round
                     (sync waits for the slowest active client); the async
                     tier (repro/sim, DESIGN.md §9) drives its event queue
                     with it. None (the default) means time is not
                     modeled, which is every pre-async scenario.

Every participation draw has a STATIC capacity S (= the engine's
`participate`), so the jitted round never retraces across rounds; dropout
and unavailability surface as active-mask zeros, and the per-round billed
client count is sum(active) — exactly the `s` that fl/comms.round_bits is
invoiced with (tests/test_scenarios.py pins this).

Two robustness axes ride the same composite (DESIGN.md §10):

  adversary axis     SignFlipAttack | ColludingBloc | ScaledGarbage — a
                     static seed-deterministic round(fraction*K)-client
                     bloc corrupts its transmitted sketches POST-encode,
                     PRE-vote (core/rounds.py); the client's local model
                     is never touched, only what it claims on the wire.
  privacy axis       RandomizedResponse(epsilon) — epsilon-LDP uplink bit
                     flips with the debias correction folded into the
                     server's vote weights. Both axes are billed nothing
                     extra by fl/comms.py: one bit is one bit.

`paper_matrix()` is the named registry the benchmarks sweep
(benchmarks/exp_bench.py -> BENCH_exp.json); `robust_matrix()` is the
adversary/privacy registry (benchmarks/robust_bench.py ->
BENCH_robust.json). DESIGN.md §8 / §10 document the layers.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic as ds


# --- data axis ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DirichletPartition:
    """Per-class Dirichlet(alpha) split: alpha -> inf IID, alpha -> 0 one
    class per client (data/synthetic.py::dirichlet_partition)."""
    alpha: float

    def split(self, rng, labels, num_clients):
        return ds.dirichlet_partition(rng, labels, num_clients, self.alpha)


@dataclasses.dataclass(frozen=True)
class LabelSkewPartition:
    """The paper's fixed protocol: each client owns `classes_per_client`
    classes."""
    classes_per_client: int = 2

    def split(self, rng, labels, num_clients):
        return ds.label_skew_partition(
            rng, labels, num_clients, self.classes_per_client
        )


@dataclasses.dataclass(frozen=True)
class IIDPartition:
    """Uniform shuffle-and-split (the alpha -> inf limit, exactly)."""

    def split(self, rng, labels, num_clients):
        return ds.iid_partition(rng, labels, num_clients)


# --- participation axis ------------------------------------------------------

def _fold(key, rnd):
    return jax.random.fold_in(key, rnd)


@dataclasses.dataclass(frozen=True)
class FullParticipation:
    """Every client, every round."""

    def capacity(self, k: int) -> int:
        return k

    def draw(self, key, rnd: int, k: int):
        return jnp.arange(k, dtype=jnp.int32), jnp.ones((k,), jnp.float32)


@dataclasses.dataclass(frozen=True)
class UniformSampling:
    """S = max(1, round(rate*K)) clients uniformly without replacement."""
    rate: float = 0.5

    def capacity(self, k: int) -> int:
        return max(1, int(round(self.rate * k)))

    def draw(self, key, rnd: int, k: int):
        s = self.capacity(k)
        idx = jax.random.permutation(_fold(key, rnd), k)[:s].astype(jnp.int32)
        return idx, jnp.ones((s,), jnp.float32)


@dataclasses.dataclass(frozen=True)
class StragglerDropout:
    """Uniformly sampled S clients; each then independently drops out with
    probability `drop` before its upload lands (trains, transmits nothing).
    At least one survivor is guaranteed so a round always has a vote."""
    rate: float = 0.5
    drop: float = 0.3

    def capacity(self, k: int) -> int:
        return max(1, int(round(self.rate * k)))

    def draw(self, key, rnd: int, k: int):
        s = self.capacity(k)
        kp, kd = jax.random.split(_fold(key, rnd))
        idx = jax.random.permutation(kp, k)[:s].astype(jnp.int32)
        active = jax.random.bernoulli(kd, 1.0 - self.drop, (s,)).astype(jnp.float32)
        first = jnp.where(jnp.sum(active) == 0, 1.0, active[0])
        return idx, active.at[0].set(first)


@dataclasses.dataclass(frozen=True)
class AvailabilityCycle:
    """Diurnal availability: client k is online iff
    ((round + k mod period) mod period) < duty*period; S = rate*K slots are
    filled uniformly from the online clients (offline picks pad the fixed
    capacity with active=0 when fewer than S are online)."""
    rate: float = 0.5
    period: int = 4
    duty: float = 0.5

    def capacity(self, k: int) -> int:
        return max(1, int(round(self.rate * k)))

    def draw(self, key, rnd: int, k: int):
        s = self.capacity(k)
        phases = jnp.arange(k, dtype=jnp.int32) % self.period
        avail = (((rnd + phases) % self.period) < self.duty * self.period)
        avail = avail.astype(jnp.float32)
        # available clients strictly dominate any unavailable one; random
        # tiebreak inside each group
        scores = jax.random.uniform(_fold(key, rnd), (k,)) + 2.0 * avail
        idx = jnp.argsort(-scores)[:s].astype(jnp.int32)
        active = avail[idx]
        # keep-alive: if the cycle leaves NOBODY online this round (k <
        # period, tiny duty), the top-scored client checks in anyway — a
        # zero-voter round would overwrite the learned consensus with the
        # vote's tie value. idx[0] is online whenever anyone is, so this
        # only fires in the genuinely-dead case.
        first = jnp.where(jnp.sum(active) == 0, 1.0, active[0])
        return idx, active.at[0].set(first)


# --- adversary axis (DESIGN.md §10) ------------------------------------------
#
# WHO is Byzantine is a static, seed-deterministic property of the
# population: round(fraction*K) clients picked by a seeded permutation
# (core/rounds.py::byzantine_mask) — not a per-round redraw, matching the
# standard Byzantine model where the adversary controls fixed machines.
# WHAT they transmit replaces the float sketch POST-encode, PRE-vote
# (core/pfed1bs.py::cohort_update), so the honest local model is intact
# and only the wire is lied on — and all three executors (fused, sharded,
# async) inject bit-identically because the hook lives in the one shared
# cohort program. All math delegates to core/rounds.py; these dataclasses
# are configuration, so `core` never imports `exp`.

@dataclasses.dataclass(frozen=True)
class SignFlipAttack:
    """Byzantine clients transmit -z: the strongest untargeted one-bit
    attack (every corrupted coordinate votes against the honest sign)."""
    fraction: float
    seed: int = 0

    def corrupt(self, zs, idx, rnd, num_clients):
        from repro.core import rounds
        byz = rounds.byzantine_mask(self.seed, num_clients, self.fraction)
        return rounds.corrupt_sign_flip(zs, byz[idx])


@dataclasses.dataclass(frozen=True)
class ColludingBloc:
    """Byzantine clients agree on ONE crafted Rademacher sketch and all
    transmit it — the bloc votes as a unit, the worst case for an
    unweighted majority at a given fraction."""
    fraction: float
    target_key: int = 0
    seed: int = 0

    def corrupt(self, zs, idx, rnd, num_clients):
        from repro.core import rounds
        byz = rounds.byzantine_mask(self.seed, num_clients, self.fraction)
        target = rounds.colluding_target(self.target_key, zs.shape[-1])
        return rounds.corrupt_colluding(zs, byz[idx], target)


@dataclasses.dataclass(frozen=True)
class ScaledGarbage:
    """Byzantine clients transmit scale*z (huge-magnitude garbage). Sign
    quantization provably neutralizes it: sign(scale*z) = sign(z) for any
    scale > 0, so the defended AND undefended votes are bit-exact with the
    honest run (the calibration cell of BENCH_robust; property-tested in
    tests/test_robust.py). This is the robustness argument magnitude-based
    compressors cannot make."""
    fraction: float
    scale: float = 1e6
    seed: int = 0

    def __post_init__(self):
        assert self.scale > 0, "scale <= 0 is a sign attack, not garbage"

    def corrupt(self, zs, idx, rnd, num_clients):
        from repro.core import rounds
        byz = rounds.byzantine_mask(self.seed, num_clients, self.fraction)
        return rounds.corrupt_scaled(zs, byz[idx], self.scale)


# --- privacy axis (DESIGN.md §10) --------------------------------------------

@dataclasses.dataclass(frozen=True)
class RandomizedResponse:
    """epsilon-local-DP uplink: every client flips each transmitted bit
    independently with probability q = 1/(1 + e^eps) (Warner's randomized
    response — the optimal local DP mechanism for one bit). Flips are
    keyed (seed, round, CLIENT ID) so every executor flips the same bits
    (core/rounds.py::rr_flip). The server folds the 1/tanh(eps/2) debias
    into the vote weights (core/pfed1bs.py::vote_defended). Billing is
    unchanged: one bit is one bit, flipped or not (fl/comms.py)."""
    epsilon: float
    seed: int = 0

    def __post_init__(self):
        assert self.epsilon > 0, "RR requires epsilon > 0"

    @property
    def flip_probability(self) -> float:
        from repro.core import rounds
        return rounds.rr_flip_probability(self.epsilon)

    def flip(self, signs, idx, rnd):
        from repro.core import rounds
        return rounds.rr_flip(signs, idx, rnd, self.seed, self.epsilon)

    def debias(self) -> float:
        from repro.core import rounds
        return rounds.rr_debias(self.epsilon)


# --- topology axis (DESIGN.md §11) -------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeAggregation:
    """Hierarchical tree-of-aggregators federation: leaves of width
    <= fan_out emit partial popcount counters, interior tiers of arity
    `fan_out` merge them, the root finishes the vote — bit-exact with the
    flat popcount vote for every shape (launch/fedexec.py::hier_round).
    `build(s)` materializes the balanced HierTopology for a cohort of S;
    the lazy import keeps `exp` importable without the launch tier."""
    fan_out: int = 4

    def __post_init__(self):
        assert self.fan_out >= 2, self.fan_out

    def build(self, s: int):
        from repro.launch.fedexec import HierTopology
        return HierTopology.build(s, self.fan_out)


# --- the composite -----------------------------------------------------------

Partition = DirichletPartition | LabelSkewPartition | IIDPartition
Participation = (
    FullParticipation | UniformSampling | StragglerDropout | AvailabilityCycle
)
Adversary = SignFlipAttack | ColludingBloc | ScaledGarbage


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the heterogeneity matrix. `build` materializes the
    federated dataset (pool -> partition -> imbalance trim -> fixed-shape
    clients); `draw_participants` yields the round's (idx, active) pair for
    the engines' `participants=` argument."""
    name: str
    partition: Partition
    participation: Participation = FullParticipation()
    imbalance: float = 0.0        # lognormal sigma; 0 = balanced counts
    noise: float = 1.0
    concept_shift: bool = False   # reserved: per-client label permutation
    latency: object | None = None  # sim/clock.py LatencyModel; None = time
    #                                not modeled (sync-only scenario)
    adversary: object | None = None  # Adversary dataclass; None = all honest
    privacy: object | None = None    # RandomizedResponse; None = raw signs
    topology: object | None = None   # TreeAggregation; None = flat (star)
    #                                  server — set, the harness runs the
    #                                  round through the counter tree
    #                                  (fedexec.hier_round, DESIGN.md §11)

    def capacity(self, num_clients: int) -> int:
        return self.participation.capacity(num_clients)

    def draw_participants(self, key, rnd: int, num_clients: int):
        return self.participation.draw(key, rnd, num_clients)

    def build(
        self,
        key,
        num_clients: int,
        num_classes: int = 10,
        train_per_client: int = 128,
        test_per_client: int = 64,
        pool_factor: float = 1.5,
    ) -> ds.FedClassification:
        kp, km = jax.random.split(key)
        pool = int(num_clients * (train_per_client + test_per_client) * pool_factor)
        px, py = ds.make_classification_pool(
            kp, pool, num_classes=num_classes, noise=self.noise
        )
        rng = np.random.RandomState(_seed_of(self.name))
        parts = self.partition.split(rng, np.asarray(py), num_clients)
        parts, _ = ds.imbalance_counts(rng, parts, self.imbalance)
        return ds.materialize_from_partition(
            km, px, py, parts, train_per_client, test_per_client, num_classes
        )


def _seed_of(name: str) -> int:
    # stable across processes (str hash is salted; crc32 is not)
    return zlib.crc32(name.encode()) % (2**31 - 1)


def paper_matrix() -> dict[str, Scenario]:
    """The named heterogeneity matrix the benchmarks sweep. Severity grows
    left to right on the data axis (IID -> Dirichlet 1.0 -> 0.1 -> fixed
    label skew) and realism grows on the participation axis (full ->
    uniform sampling -> stragglers -> availability cycling)."""
    return {
        "iid": Scenario("iid", IIDPartition()),
        "dir1.0": Scenario(
            "dir1.0", DirichletPartition(1.0), UniformSampling(0.5)
        ),
        "dir0.1": Scenario(
            "dir0.1", DirichletPartition(0.1), UniformSampling(0.5)
        ),
        "labelskew": Scenario("labelskew", LabelSkewPartition(2)),
        "dir0.3-imb": Scenario(
            "dir0.3-imb", DirichletPartition(0.3), UniformSampling(0.5),
            imbalance=1.0,
        ),
        "straggler": Scenario(
            "straggler", DirichletPartition(0.3), StragglerDropout(0.5, 0.3)
        ),
        "cycling": Scenario(
            "cycling", DirichletPartition(0.3),
            AvailabilityCycle(0.5, period=4, duty=0.5),
        ),
    }


def robust_matrix() -> dict[str, Scenario]:
    """The adversary/privacy registry benchmarks/robust_bench.py sweeps.
    All cells share ONE data/participation base so accuracy differences
    are attributable to the attack/defense axes alone; the garbage cell
    is the bit-exact calibration anchor (see ScaledGarbage)."""
    base = dict(partition=DirichletPartition(0.3),
                participation=UniformSampling(0.5))
    return {
        "honest": Scenario("honest", **base),
        "garbage20": Scenario(
            "garbage20", **base, adversary=ScaledGarbage(0.2, scale=1e6)
        ),
        "signflip20": Scenario(
            "signflip20", **base, adversary=SignFlipAttack(0.2)
        ),
        "colluding20": Scenario(
            "colluding20", **base, adversary=ColludingBloc(0.2, target_key=7)
        ),
        "rr-eps2": Scenario(
            "rr-eps2", **base, privacy=RandomizedResponse(2.0)
        ),
    }


def hier_matrix() -> dict[str, Scenario]:
    """Topology-axis registry (benchmarks/hier_bench.py): one shared
    data/participation base, fan-out sweeping the tree shape from binary
    to wide. The flat cell is the parity anchor every tree cell must match
    bit-exactly (the §11 contract)."""
    base = dict(partition=DirichletPartition(0.3),
                participation=FullParticipation())
    return {
        "flat": Scenario("flat", **base),
        "tree-fan2": Scenario("tree-fan2", **base,
                              topology=TreeAggregation(fan_out=2)),
        "tree-fan4": Scenario("tree-fan4", **base,
                              topology=TreeAggregation(fan_out=4)),
        "tree-fan16": Scenario("tree-fan16", **base,
                               topology=TreeAggregation(fan_out=16)),
    }


def async_matrix() -> dict[str, Scenario]:
    """Scenarios with the latency axis set — what the async tier
    (repro/sim) simulates and benchmarks/async_bench.py sweeps. Imported
    lazily so the sync-only harness never pays the sim import."""
    from repro.sim.clock import (
        ComputeNetworkLatency,
        ConstantLatency,
        StragglerTailLatency,
    )

    return {
        # every client equally fast: async buys nothing (control cell)
        "uniform-const": Scenario(
            "uniform-const", DirichletPartition(0.3), UniformSampling(0.5),
            latency=ConstantLatency(1.0),
        ),
        # persistent device heterogeneity + network tail
        "hetero-lognormal": Scenario(
            "hetero-lognormal", DirichletPartition(0.3), UniformSampling(0.5),
            latency=ComputeNetworkLatency(client_speed_sigma=0.6),
        ),
        # the headline regime: a heavy straggler tail bounds every
        # synchronous round while the buffered server flushes on the
        # fastest B arrivals
        "straggler-tail": Scenario(
            "straggler-tail", DirichletPartition(0.3), UniformSampling(0.5),
            latency=StragglerTailLatency(
                tail_prob=0.25, tail_mult=10.0, tail_scale=1.0
            ),
        ),
    }


# --- fed_lm cells (DESIGN.md §13) -------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMFederation:
    """One fed_lm experiment cell: WHICH real architecture federates, WHAT
    subset of it trains, and the round geometry. The registry below is what
    benchmarks/fl_lm_bench.py sweeps into BENCH_fl_lm.json and what
    examples/fl_llm_finetune.py names on the command line.

    arch: configs registry name (models/config.ArchConfig); trainable: ()
    federates the full parameter tree, otherwise path-substring patterns
    for core/subset.py (e.g. ("attn",) = the LoRA-style attention-only
    subset). Benches run the `.reduced()` smoke variant of the arch; the
    at-scale bits/memory rows are analytic over the full config's
    eval_shape template (no allocation).
    """

    name: str
    arch: str
    trainable: tuple = ()
    seq: int = 32
    num_clients: int = 2
    participate: int = 2
    local_steps: int = 2
    batch: int = 2
    m_ratio: float = 0.05
    chunk: int = 4096

    def arch_config(self, reduced: bool = True):
        from repro.configs import get

        cfg = get(self.arch)
        return cfg.reduced() if reduced else cfg

    def fl_config(self):
        from repro.core.pfed1bs import PFed1BSConfig

        return PFed1BSConfig(
            num_clients=self.num_clients,
            participate=self.participate,
            local_steps=self.local_steps,
            m_ratio=self.m_ratio,
            chunk=self.chunk,
            layout="leaf",
            trainable=self.trainable or None,
        )


def lm_matrix() -> dict[str, LMFederation]:
    """The fed_lm registry: the two smallest dense real configs, each full
    AND attention-subset, so the bench's bits/memory table shows subset
    billing against full-tree federation on the same architecture."""
    return {
        "granite-full": LMFederation("granite-full", "granite-8b"),
        "granite-attn": LMFederation(
            "granite-attn", "granite-8b", trainable=("attn",)
        ),
        "starcoder-full": LMFederation("starcoder-full", "starcoder2-7b"),
        "starcoder-attn": LMFederation(
            "starcoder-attn", "starcoder2-7b", trainable=("attn",)
        ),
    }
