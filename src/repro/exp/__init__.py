"""Scenario-matrix experiment harness (DESIGN.md §8).

scenarios.py — heterogeneity axes (partition x imbalance x participation)
runner.py    — algo x scenario sweeps through the shared round surface
report.py    — Table-1/2 artifacts + the CI schema/accounting gate
"""
from repro.exp.runner import ALGOS, ExpConfig, run_cell, sweep  # noqa: F401
from repro.exp.scenarios import Scenario, paper_matrix  # noqa: F401
