"""Scenario-matrix runner: algorithm x heterogeneity sweeps through the
ONE shared round surface.

Every cell (algo, scenario) runs the same protocol: build the scenario's
federated dataset (exp/scenarios.py), construct the engine — PFed1BS for
"pfed1bs", BaselineFL for the six global-model baselines — with the
scenario's static participation capacity S, then drive `engine.round`
once per round with the scenario's externally drawn `(idx, active)`
participants. All seven algorithms therefore share:

  * the jitted gather -> local-steps -> compress -> aggregate round
    (core/pfed1bs.py §4 path / core/baselines.py encode-finish surface),
  * the fused SRHT kernel dispatch for every projection (pFed1BS's sketch,
    OBCSAA's compressed-sensing sketch, EDEN's square rotation — all via
    core/sketch.py over kernels/ops),
  * optionally the shard_map federation executor (ExpConfig.executor=
    "sharded" routes pFed1BS through launch/fedexec.sharded_round and the
    baselines through sharded_baseline_round on the same `fed` mesh),
  * the Table-2 bit meter: each round is billed with the REALIZED client
    count sum(active) via fl/comms.round_bits, accumulated by
    fl/comms.accumulate_round_bits (a straggler that never uploaded is
    not invoiced).

`run_cell` returns one cell record; `sweep` the full matrix, which
exp/report.py joins into paper-style Table-1/2 artifacts.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.core.baselines import BaselineConfig, BaselineFL
from repro.core.pfed1bs import PFed1BS, PFed1BSConfig
from repro.data import synthetic as ds
from repro.exp.scenarios import Scenario
from repro.fl import comms
from repro.models import smallnets as sn
from repro.obs import health as obshealth
from repro.obs import registry as obsreg
from repro.obs import trace as obstrace

ALGOS = ("fedavg", "obda", "obcsaa", "zsignfed", "eden", "fedbat", "pfed1bs")


@dataclasses.dataclass(frozen=True)
class ExpConfig:
    """Protocol knobs shared by every cell of a sweep (the scenario supplies
    the heterogeneity; this supplies the task scale)."""
    num_clients: int = 10
    rounds: int = 10
    local_steps: int = 4
    batch: int = 24
    lr: float = 0.05
    hidden: int = 48
    m_ratio: float = 0.1
    chunk: int = 2048
    train_per_client: int = 128
    test_per_client: int = 64
    num_classes: int = 10
    noise_scale: float = 1.0     # multiplies the scenario's template noise
    eval_every: int = 0          # also evaluate every E rounds (0: final only)
    seed: int = 0
    # pfed1bs regularizer (paper defaults)
    lam: float = 5e-4
    mu: float = 1e-5
    gamma: float = 1e4
    # round executor: "fused" = single-host jitted round; "sharded" = thread
    # EVERY algorithm through the launch/fedexec.py shard_map executor over
    # `fed_shards` devices (pfed1bs: sharded_round, baselines:
    # sharded_baseline_round)
    executor: str = "fused"
    fed_shards: int = 1
    # robust voting defense (pfed1bs only; DESIGN.md §10): "none" | "trim"
    # (drop the trim_frac*S most consensus-disagreeing voters per round) |
    # "reputation" (per-client EMA of sign-agreement weights the vote)
    defense: str = "none"
    trim_frac: float = 0.2
    rep_beta: float = 0.25


def make_task(cfg: ExpConfig):
    """The shared model/loss/eval triple (MLP on flattened 28x28)."""
    init_fn = lambda k: sn.init_mlp(
        k, input_dim=784, hidden=cfg.hidden, classes=cfg.num_classes
    )
    loss_fn = lambda p, b: sn.softmax_xent(sn.apply_mlp(p, b["x"]), b["y"])
    eval_fn = lambda p, x, y: sn.accuracy(sn.apply_mlp(p, x), y)
    return init_fn, loss_fn, eval_fn


def build_engine(algo: str, cfg: ExpConfig, capacity: int, loss_fn, template,
                 scenario: Scenario | None = None, tracer=None):
    """One engine per cell, capacity = the scenario's static S. The
    scenario's adversary/privacy axes thread into the pfed1bs engine; the
    global-model baselines transmit float payloads with no vote to defend,
    so those axes are out of scope for them (refused, not ignored)."""
    sharded = cfg.executor == "sharded"
    adversary = scenario.adversary if scenario is not None else None
    privacy = scenario.privacy if scenario is not None else None
    topology = scenario.topology if scenario is not None else None
    if algo == "pfed1bs":
        # the topology axis builds a HierTopology over the scenario's
        # capacity; it implies the sharded popcount executor (counters are
        # the popcount vote split at the leaf/root boundary, DESIGN.md §11)
        topo = topology.build(capacity) if topology is not None else None
        return PFed1BS(
            PFed1BSConfig(
                num_clients=cfg.num_clients, participate=capacity,
                local_steps=cfg.local_steps, lr=cfg.lr, lam=cfg.lam,
                mu=cfg.mu, gamma=cfg.gamma, m_ratio=cfg.m_ratio,
                chunk=cfg.chunk, sketch_seed=cfg.seed,
                sharded_round=sharded or topo is not None,
                fed_shards=cfg.fed_shards,
                vote="popcount" if topo is not None else "exact",
                topology=topo,
                adversary=adversary, privacy=privacy,
                defense=cfg.defense, trim_frac=cfg.trim_frac,
                rep_beta=cfg.rep_beta,
            ),
            loss_fn, template, tracer=tracer,
        )
    if topology is not None:
        raise ValueError(
            f"the topology axis aggregates one-bit vote counters; baseline "
            f"{algo!r} transmits float payloads with nothing to count"
        )
    if adversary is not None or privacy is not None:
        raise ValueError(
            f"adversary/privacy axes are one-bit-vote semantics; baseline "
            f"{algo!r} has no vote to corrupt or defend"
        )
    if cfg.defense != "none":
        raise ValueError(f"defense={cfg.defense!r} requires algo='pfed1bs'")
    return BaselineFL(
        BaselineConfig(
            algo=algo, num_clients=cfg.num_clients, participate=capacity,
            local_steps=cfg.local_steps, lr=cfg.lr, m_ratio=cfg.m_ratio,
            chunk=cfg.chunk, seed=cfg.seed,
            sharded_round=sharded, fed_shards=cfg.fed_shards,
        ),
        loss_fn, template,
    )


def run_cell(algo: str, scenario: Scenario, cfg: ExpConfig,
             tracer=None) -> dict:
    """One (algorithm, scenario) cell: per-round loss + realized
    participation + Table-2 bit accounting + final (and optional periodic)
    per-client accuracy. Personalized algorithms are scored on each
    client's own model, global ones on the shared model — both against the
    client's own test shard.

    With a wall-clock tracer the cell emits one "cell" span, per-round
    uplink/downlink/vote counters (re-derivable against the returned
    "billing" spec via obs.validate_trace), and threads the tracer into
    the pfed1bs engine for per-round executor spans."""
    tr = obstrace.NOOP if tracer is None else tracer
    registry = obsreg.MetricsRegistry(tracer=tr)
    base = jax.random.key(cfg.seed)
    kd, kp, ke = jax.random.split(jax.random.fold_in(base, 17), 3)
    if cfg.noise_scale != 1.0:   # harder task = more template noise
        scenario = dataclasses.replace(
            scenario, noise=scenario.noise * cfg.noise_scale
        )
    data = scenario.build(
        kd, cfg.num_clients, num_classes=cfg.num_classes,
        train_per_client=cfg.train_per_client,
        test_per_client=cfg.test_per_client,
    )
    init_fn, loss_fn, eval_fn = make_task(cfg)
    template = jax.eval_shape(init_fn, jax.random.key(1))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(template))
    num_tensors = len(jax.tree.leaves(template))

    capacity = scenario.capacity(cfg.num_clients)
    eng = build_engine(
        algo, cfg, capacity, loss_fn, template, scenario,
        tracer=tracer if algo == "pfed1bs" else None,
    )
    m_dim = eng.m if algo == "pfed1bs" else eng.spec.m
    state = eng.init(init_fn, jax.random.fold_in(base, 23))

    # per-round tier surcharge for tree cells: the flat fl/comms.round_bits
    # invoice plus the interior counter uplink and the per-tier broadcast
    # (one m-bit consensus per level instead of one total)
    extra_up = extra_down = 0
    if algo == "pfed1bs" and scenario.topology is not None:
        topo0 = scenario.topology.build(capacity)
        hb0 = comms.hier_round_bits(
            m=m_dim, leaf_widths=topo0.leaf_sizes, fan_out=topo0.fan_out
        )
        extra_up = sum(hb0["tier_uplink_bits"])
        extra_down = hb0["downlink_bits"] - m_dim

    def evaluate(st):
        if hasattr(st, "clients"):       # personalized: own model, own shard
            accs = jax.vmap(eval_fn)(st.clients, data.test_x, data.test_y)
        else:                            # global: shared model, every shard
            accs = jax.vmap(lambda x, y: eval_fn(st.params, x, y))(
                data.test_x, data.test_y
            )
        return float(accs.mean()), float(accs.std())

    # online convergence monitor (obs/health.py) — pfed1bs cells only:
    # the baselines have no consensus sign vector to watch
    monitor = obshealth.HealthMonitor() if algo == "pfed1bs" else None
    losses, s_per_round, acc_curve, round_s = [], [], [], []
    with tr.span("cell", track="exp", algo=algo, scenario=scenario.name,
                 rounds=cfg.rounds):
        for r in range(cfg.rounds):
            participants = scenario.draw_participants(kp, r, cfg.num_clients)
            kb, kr = jax.random.split(jax.random.fold_in(ke, r))
            batches = ds.sample_round_batches(
                kb, data, cfg.local_steps, cfg.batch
            )
            t0 = time.time()
            state, metrics = eng.round(
                state, batches, data.weights, kr, participants
            )
            loss = float(metrics["task_loss"])  # blocks on the round's result
            round_s.append(time.time() - t0)
            losses.append(loss)
            if monitor is not None:
                monitor.update(
                    v=np.asarray(state.v),
                    ef_norm=(float(metrics["ef_residual_norm"])
                             if "ef_residual_norm" in metrics else None),
                    agreement=(float(metrics["sign_agreement"])
                               if "sign_agreement" in metrics else None),
                    margins=(np.asarray(metrics["vote_margins"])
                             if "vote_margins" in metrics else None),
                )
            s_r = int(round(float(np.sum(np.asarray(participants[1])))))
            s_per_round.append(s_r)
            if tr.enabled:
                # per-round counter emission sums EXACTLY to the cell's
                # "rounds" billing spec: accumulate_round_bits is a literal
                # sum of round_bits over s_per_round, plus the constant
                # per-round tier surcharge for topology cells
                rb = comms.round_bits(
                    algo, n=n, m=m_dim, s=s_r, num_tensors=num_tensors
                )
                registry.add("uplink_bits", rb["uplink_bits"] + extra_up)
                registry.add("downlink_bits", rb["downlink_bits"] + extra_down)
                if algo == "pfed1bs":
                    registry.add("votes_cast", s_r)
                    if cfg.defense == "trim":
                        registry.add(
                            "trimmed_voters",
                            min(eng.trim_count, max(s_r - 1, 0)),
                        )
                    if "rr_flips" in metrics:
                        registry.add(
                            "rr_flips", int(round(float(metrics["rr_flips"])))
                        )
                    if "ef_residual_norm" in metrics:
                        registry.observe(
                            "ef_residual_norm",
                            float(metrics["ef_residual_norm"]),
                        )
            if cfg.eval_every and (r + 1) % cfg.eval_every == 0:
                acc_curve.append({"round": r + 1, "acc": evaluate(state)[0]})
        acc, acc_std = evaluate(state)
    # steady state: round 0 pays jit trace+compile; eval is outside the timer
    steady = round_s[1:] or round_s
    bits = comms.accumulate_round_bits(
        algo, n=n, m=m_dim, s_per_round=s_per_round, num_tensors=num_tensors
    )
    topo_tag = None
    if algo == "pfed1bs" and scenario.topology is not None:
        # tree cells bill the interior tiers on top of the flat client
        # uplink, and one consensus broadcast per tier instead of one total
        # (fl/comms.hier_round_bits; the executor's own metrics agree) —
        # the per-round surcharge extra_up/extra_down was computed above
        up = bits["uplink_bits"] + extra_up * cfg.rounds
        down = bits["downlink_bits"] + extra_down * cfg.rounds
        bits = {
            **bits, "uplink_bits": up, "downlink_bits": down,
            "total_bits": up + down, "total_mb": (up + down) / 8e6,
        }
        topo_tag = f"tree-fan{topo0.fan_out}"
    adv = scenario.adversary
    return {
        "algo": algo,
        "scenario": scenario.name,
        "topology": topo_tag,
        "acc": acc,
        "acc_std": acc_std,
        # robustness axes of the cell (DESIGN.md §10; None/"none" = honest)
        "defense": cfg.defense,
        "adversary": type(adv).__name__ if adv is not None else None,
        "adversary_fraction": adv.fraction if adv is not None else 0.0,
        "epsilon": (
            scenario.privacy.epsilon if scenario.privacy is not None else None
        ),
        "loss_curve": losses,
        "acc_curve": acc_curve,
        "s_per_round": s_per_round,
        "rounds": cfg.rounds,
        "n": n,
        "m": m_dim,
        "num_tensors": num_tensors,
        "uplink_bits": bits["uplink_bits"],
        "downlink_bits": bits["downlink_bits"],
        "total_bits": bits["total_bits"],
        "total_mb": bits["total_mb"],
        "us_per_round": float(np.mean(steady)) * 1e6,
        # per-cell federation health verdict (obs/health.py): consensus
        # churn / EF trend / vote-margin distribution; None for baselines
        "health": monitor.verdict() if monitor is not None else None,
        # re-derivation spec for obs.validate_trace: the cell's counter
        # emissions sum to exactly what this spec re-computes from fl/comms
        "billing": {
            "kind": "rounds", "algo": algo, "n": n, "m": m_dim,
            "s_per_round": s_per_round, "num_tensors": num_tensors,
            "extra_uplink_bits": extra_up * cfg.rounds,
            "extra_downlink_bits": extra_down * cfg.rounds,
        },
    }


def sweep(algos, scenarios, cfg: ExpConfig, progress=None,
          tracer=None) -> dict:
    """The full matrix: cells + enough config to re-derive every number.
    `scenarios`: dict name -> Scenario (e.g. exp.scenarios.paper_matrix());
    `progress`: optional callable(cell_dict) fired after each cell;
    `tracer`: optional wall-clock obs.Tracer threaded into every cell."""
    cells = []
    for sname, scenario in scenarios.items():
        for algo in algos:
            cell = run_cell(algo, scenario, cfg, tracer=tracer)
            cells.append(cell)
            if progress is not None:
                progress(cell)
    return {
        "cells": cells,
        "algos": list(algos),
        "scenarios": list(scenarios.keys()),
        "config": dataclasses.asdict(cfg),
    }
