"""Report layer: join sweep cells into paper-style Table-1/2 artifacts.

`matrix_markdown` renders one accuracy-vs-bits table per scenario (the
shape of the paper's Tables 1-2: rows = algorithms, columns = accuracy and
wire cost, reduction measured against the same scenario's FedAvg row).
`validate_matrix` is the schema gate the CI bench-smoke job runs via
`python -m benchmarks.report --validate`: it fails on missing cell keys,
on a matrix thinner than the acceptance floor (5 algorithms x 3
scenarios), and — the accounting invariant — if any pFed1BS cell's billed
bits differ from re-invoicing its recorded per-round participation
through fl/comms.accumulate_round_bits.

`validate_robust` is the same gate for the robustness artifact
(benchmarks/robust_bench.py -> BENCH_robust.json, DESIGN.md §10). Its
three load-bearing invariants: the scaled-garbage cell must be BIT-exact
with the honest run (sign quantization neutralizes magnitude garbage —
if this trips, corruption leaked past the encode), every cell must bill
identical uplink bits (robustness axes are free at the wire), and the
headline defense must recover >= `min_recovery` of the accuracy gap the
attack opened (defended vs undefended vs honest, same data, same seeds).
"""
from __future__ import annotations

from repro.fl import comms

REQUIRED_CELL_KEYS = (
    "algo", "scenario", "acc", "acc_std", "loss_curve", "s_per_round",
    "rounds", "n", "m", "num_tensors", "uplink_bits", "downlink_bits",
    "total_bits", "total_mb", "us_per_round",
)


def validate_matrix(results: dict, min_algos: int = 5,
                    min_scenarios: int = 3) -> None:
    """Raise ValueError unless `results` is a well-formed sweep artifact."""
    for key in ("cells", "algos", "scenarios", "config"):
        if key not in results:
            raise ValueError(f"sweep artifact missing top-level key {key!r}")
    cells = results["cells"]
    algos = {c.get("algo") for c in cells}
    scenarios = {c.get("scenario") for c in cells}
    if len(algos) < min_algos:
        raise ValueError(
            f"matrix has {len(algos)} algorithms ({sorted(algos)}); "
            f"need >= {min_algos}"
        )
    if len(scenarios) < min_scenarios:
        raise ValueError(
            f"matrix has {len(scenarios)} scenarios ({sorted(scenarios)}); "
            f"need >= {min_scenarios}"
        )
    for cell in cells:
        missing = [k for k in REQUIRED_CELL_KEYS if k not in cell]
        if missing:
            raise ValueError(
                f"cell {cell.get('algo')}/{cell.get('scenario')} missing "
                f"keys {missing}"
            )
        # the bit meter must re-derive exactly from the recorded rounds
        expect = comms.accumulate_round_bits(
            cell["algo"], n=cell["n"], m=cell["m"],
            s_per_round=cell["s_per_round"],
            num_tensors=cell["num_tensors"],
        )
        for k in ("uplink_bits", "downlink_bits", "total_bits"):
            if cell[k] != expect[k]:
                raise ValueError(
                    f"cell {cell['algo']}/{cell['scenario']}: recorded {k}="
                    f"{cell[k]} != comms re-invoice {expect[k]}"
                )


ROBUST_TOP_KEYS = (
    "config", "m", "honest", "garbage_parity", "signflip_curve", "rr_curve",
    "recovery",
)


def validate_robust(results: dict, min_recovery: float = 0.5) -> None:
    """Raise ValueError unless `results` is a well-formed BENCH_robust
    artifact satisfying the §10 invariants (see module docstring)."""
    for key in ROBUST_TOP_KEYS:
        if key not in results:
            raise ValueError(f"robust artifact missing top-level key {key!r}")
    honest = results["honest"]
    if honest.get("defense") != "none" or honest.get("adversary") is not None:
        raise ValueError(
            "honest baseline cell must have defense='none' and no adversary"
        )

    # 1. neutralized-garbage parity: bit-exact, not approximately equal
    gp = results["garbage_parity"]
    if not gp.get("bit_exact"):
        raise ValueError("garbage_parity.bit_exact is not True")
    if gp["garbage_acc"] != gp["honest_acc"] or (
        gp["garbage_loss_curve"] != gp["honest_loss_curve"]
    ):
        raise ValueError(
            "scaled-garbage cell is not bit-exact with the honest vote: "
            f"acc {gp['garbage_acc']} vs {gp['honest_acc']} — corruption "
            "leaked past the sign quantizer"
        )

    # 2. at least one defended-vs-undefended pair at the same attack level
    curve = results["signflip_curve"]
    by_frac: dict[float, set] = {}
    for c in curve:
        by_frac.setdefault(c["adversary_fraction"], set()).add(c["defense"])
    paired = [
        f for f, defs in by_frac.items()
        if f > 0 and "none" in defs and (defs - {"none"})
    ]
    if not paired:
        raise ValueError(
            "signflip_curve has no attacked fraction with both an "
            "undefended and a defended cell"
        )

    # 3. headline recovery: the defense closes >= min_recovery of the gap
    rec = results["recovery"]
    gap = rec["honest_acc"] - rec["undefended_acc"]
    recovered = rec["defended_acc"] - rec["undefended_acc"]
    frac = recovered / gap if gap > 0 else 1.0
    if abs(frac - rec["recovered_frac"]) > 1e-9:
        raise ValueError(
            f"recovery.recovered_frac={rec['recovered_frac']} does not "
            f"re-derive from its own cells ({frac})"
        )
    if frac < min_recovery:
        raise ValueError(
            f"defense {rec['defense']!r} recovered only {frac:.3f} of the "
            f"accuracy gap at fraction {rec['fraction']}; need >= "
            f"{min_recovery}"
        )

    # 4. one bit is one bit: every cell bills identical uplink bits
    cells = [honest, *curve, *results["rr_curve"]]
    bits = {c["uplink_bits"] for c in cells}
    if len(bits) != 1:
        raise ValueError(
            f"uplink bits differ across robustness cells: {sorted(bits)} — "
            "an attack or defense changed the wire bill"
        )
    for c in results["rr_curve"]:
        if not (c.get("epsilon") or 0) > 0:
            raise ValueError(f"rr_curve cell has invalid epsilon: {c}")


HIER_TOP_KEYS = ("m", "fan_out", "counter_merge_parity", "scaling")


def validate_hier(results: dict, max_root_growth: float = 8.0) -> None:
    """Raise ValueError unless `results` is a well-formed BENCH_hier
    artifact satisfying the §11 invariants:

      1. the counter-merge-equals-flat parity cell is present and
         bit_exact (every engine topology AND every pure vote case);
      2. every scaling row's bits re-derive EXACTLY from
         fl/comms.hier_round_bits over the HierTopology the executor
         would build from (clients, fan_out) — the artifact carries no
         number this module cannot recompute;
      3. the headline claim holds: flat-server root ingress grows
         linearly in clients while the tree root's stays O(log S)
         (bounded by `max_root_growth` across the whole curve).
    """
    from repro.fl import comms
    from repro.launch.fedexec import HierTopology

    for key in HIER_TOP_KEYS:
        if key not in results:
            raise ValueError(f"hier artifact missing top-level key {key!r}")
    par = results["counter_merge_parity"]
    if par.get("bit_exact") is not True:
        raise ValueError("counter_merge_parity.bit_exact is not True")
    cells = list(par.get("engine_cells", [])) + list(par.get("vote_cases", []))
    if not cells:
        raise ValueError("counter_merge_parity carries no cells")
    bad = [c for c in cells if c.get("bit_exact") is not True]
    if bad:
        raise ValueError(f"non-bit-exact parity cells: {bad}")

    m = results["m"]
    rows = results["scaling"]
    if len(rows) < 2:
        raise ValueError("scaling needs >= 2 client counts for a curve")
    for row in rows:
        topo = HierTopology.build(int(row["clients"]), int(row["fan_out"]))
        hb = comms.hier_round_bits(
            m=m, leaf_widths=topo.leaf_sizes, fan_out=topo.fan_out
        )
        for key in ("tiers", "root_ingress_bits", "uplink_bits",
                    "downlink_bits", "tier_uplink_bits"):
            if row[key] != hb[key]:
                raise ValueError(
                    f"scaling row clients={row['clients']}: {key}="
                    f"{row[key]} does not re-derive from fl/comms ({hb[key]})"
                )
        if row["flat_ingress_bits"] != int(row["clients"]) * m:
            raise ValueError(
                f"scaling row clients={row['clients']}: flat_ingress_bits="
                f"{row['flat_ingress_bits']} != clients*m"
            )
    first, last = rows[0], rows[-1]
    lin = last["clients"] / first["clients"]
    if last["flat_ingress_bits"] / first["flat_ingress_bits"] != lin:
        raise ValueError("flat ingress did not grow linearly in clients")
    growth = last["root_ingress_bits"] / first["root_ingress_bits"]
    if growth > max_root_growth:
        raise ValueError(
            f"tree root ingress grew {growth:.2f}x over a {lin:.0f}x client "
            f"range — not the claimed O(log S) (bound {max_root_growth}x)"
        )


FL_LM_TOP_KEYS = ("parity", "memory", "rounds", "at_scale")


def validate_fl_lm(results: dict) -> None:
    """Raise ValueError unless `results` is a well-formed BENCH_fl_lm
    artifact satisfying the §13 invariants:

      1. the streamed-vs-materialized parity cell is bit_exact — the
         per-leaf streaming encoder (core/stream.py) produced the SAME
         (m,) sketch as the engine's materialized leaf-layout forward;
      2. every memory row's measured streaming peak EQUALS the
         closed-form core/stream.stream_peak_bound re-derived from the
         named lm_matrix cell — O(max-layer + m) — and sits strictly
         below the 4n bytes a materialized flat vector would cost (the
         artifact carries no number this module cannot recompute);
      3. every round row bills uplink = participate * m and downlink = m,
         and its bit dict re-derives through fl/comms.subset_round_bits
         at the trainable-parameter count;
      4. the at-scale rows (full, unreduced configs; analytic — no
         allocation) re-derive the same way.
    """
    import functools

    import jax
    import numpy as np

    from repro.core import flatten, stream, subset
    from repro.core import treesketch as ts
    from repro.exp import scenarios
    from repro.models import lm

    for key in FL_LM_TOP_KEYS:
        if key not in results:
            raise ValueError(f"fl_lm artifact missing top-level key {key!r}")
    par = results["parity"]
    if par.get("bit_exact") is not True:
        raise ValueError(
            "parity.bit_exact is not True — the streamed encode diverged "
            "from the materialized leaf-layout sketch"
        )

    cells = scenarios.lm_matrix()

    def derive(cell_name: str, reduced: bool):
        cell = cells[cell_name]
        arch = cell.arch_config(reduced=reduced)
        template = jax.eval_shape(
            functools.partial(lm.init_params, arch), jax.random.PRNGKey(0)
        )
        paths = (
            subset.match_paths(template, cell.trainable)
            if cell.trainable else None
        )
        tspec = ts.make_tree_sketch_spec(
            template, cell.m_ratio, chunk=cell.chunk, paths=paths
        )
        return cell, flatten.tree_size(template), tspec

    def check_geometry(row, where: str, reduced: bool):
        cell, n_total, tspec = derive(row["cell"], reduced)
        bound = stream.stream_peak_bound(tspec)
        expect = {
            "n": n_total,
            "n_trainable": tspec.n,
            "m": tspec.m,
            "peak_bound_bytes": bound,
            "flat_bytes": 4 * n_total,
        }
        for k, v in expect.items():
            if row.get(k) != v:
                raise ValueError(
                    f"{where} row {row['cell']!r}: {k}={row.get(k)} does "
                    f"not re-derive from lm_matrix ({v})"
                )
        if not bound < 4 * n_total:
            raise ValueError(
                f"{where} row {row['cell']!r}: streaming bound {bound} is "
                f"not below the 4n flat vector ({4 * n_total}) — the "
                "O(max-layer + m) claim fails"
            )
        return cell, n_total, tspec

    mem = results["memory"]
    if len(mem) < 2:
        raise ValueError("memory needs >= 2 model-size rows for a curve")
    for row in mem:
        check_geometry(row, "memory", reduced=True)
        if row.get("peak_bytes") != row["peak_bound_bytes"]:
            raise ValueError(
                f"memory row {row['cell']!r}: measured streaming peak "
                f"{row.get('peak_bytes')} != closed-form bound "
                f"{row['peak_bound_bytes']}"
            )

    rounds = results["rounds"]
    if not rounds:
        raise ValueError("rounds carries no cells")
    for row in rounds:
        cell, n_total, tspec = derive(row["cell"], reduced=True)
        s = int(row["participate"])
        if row["uplink_bits"] != s * tspec.m:
            raise ValueError(
                f"round row {row['cell']!r}: uplink_bits="
                f"{row['uplink_bits']} != participate*m ({s * tspec.m})"
            )
        if row["downlink_bits"] != tspec.m:
            raise ValueError(
                f"round row {row['cell']!r}: downlink_bits="
                f"{row['downlink_bits']} != m ({tspec.m})"
            )
        expect = comms.subset_round_bits(
            "pfed1bs", n_total=n_total, n_trainable=tspec.n, m=tspec.m, s=s
        )
        got = row["bits"]
        for k, v in expect.items():
            if not np.isclose(got.get(k), v, rtol=0, atol=0):
                raise ValueError(
                    f"round row {row['cell']!r}: bits[{k!r}]={got.get(k)} "
                    f"does not re-derive from subset_round_bits ({v})"
                )

    for row in results["at_scale"]:
        cell, n_total, tspec = check_geometry(row, "at_scale", reduced=False)
        expect = comms.subset_round_bits(
            "pfed1bs", n_total=n_total, n_trainable=tspec.n, m=tspec.m,
            s=cell.participate,
        )
        got = row["bits"]
        for k, v in expect.items():
            if not np.isclose(got.get(k), v, rtol=0, atol=0):
                raise ValueError(
                    f"at_scale row {row['cell']!r}: bits[{k!r}]="
                    f"{got.get(k)} does not re-derive ({v})"
                )


def robust_markdown(results: dict) -> str:
    """README-style digest: accuracy vs adversary fraction x defense, and
    accuracy vs epsilon."""
    lines = ["| fraction | defense | acc |", "|---|---|---|"]
    for c in sorted(results["signflip_curve"],
                    key=lambda c: (c["adversary_fraction"], c["defense"])):
        lines.append(
            f"| {c['adversary_fraction']:.2f} | {c['defense']} "
            f"| {c['acc']:.4f} |"
        )
    lines.append("")
    lines.append("| epsilon | acc |")
    lines.append("|---|---|")
    for c in sorted(results["rr_curve"], key=lambda c: c["epsilon"]):
        lines.append(f"| {c['epsilon']:.1f} | {c['acc']:.4f} |")
    return "\n".join(lines)


def _by_scenario(cells):
    out: dict[str, list[dict]] = {}
    for c in cells:
        out.setdefault(c["scenario"], []).append(c)
    return out


_EVAL_SEMANTICS_NOTE = """\
## Evaluation semantics

`acc` for **pfed1bs** scores each client's own personalized model on that
client's test shard; every baseline fields a **single global model** scored
on the same shards. That asymmetry is the object of study — under
concept shift a global model mathematically cannot fit all clients — but
it means pfed1bs's `acc` is not a like-for-like global-model number. The
paper-table artifacts (`experiments/bench/table2.json`,
`fig34_convergence.json`) therefore also record `acc_global`: a
mean-of-clients consensus model evaluated exactly like the baselines
(for baselines `acc_global == acc` by construction). Loss curves are
likewise per-algorithm objectives over different model sets (personalized
ensembles start from per-client inits), so curves are comparable across
rounds *within* an algorithm, not in absolute scale *across* algorithms.
"""


def matrix_markdown(results: dict) -> str:
    """GitHub-markdown Table-1/2 per scenario: accuracy vs wire cost."""
    lines = [_EVAL_SEMANTICS_NOTE]
    for scenario, cells in _by_scenario(results["cells"]).items():
        fedavg = next((c for c in cells if c["algo"] == "fedavg"), None)
        lines.append(f"### Scenario `{scenario}`\n")
        lines.append(
            "| algo | acc | ±std | total bits | MB | vs FedAvg | bits/round/client |"
        )
        lines.append("|---|---|---|---|---|---|---|")
        for c in sorted(cells, key=lambda c: -c["acc"]):
            red = (
                f"-{(1.0 - c['total_bits'] / fedavg['total_bits']) * 100:.2f}%"
                if fedavg and fedavg["total_bits"] else "—"
            )
            s_total = max(sum(c["s_per_round"]), 1)
            lines.append(
                f"| {c['algo']} | {c['acc']:.4f} | {c['acc_std']:.3f} "
                f"| {c['total_bits']:,} | {c['total_mb']:.3f} | {red} "
                f"| {c['total_bits'] / s_total:,.0f} |"
            )
        lines.append("")
    return "\n".join(lines)


def summarize(results: dict) -> dict:
    """Per-scenario {algo: (acc, total_bits)} digest for quick assertions."""
    return {
        scenario: {
            c["algo"]: {"acc": c["acc"], "total_bits": c["total_bits"]}
            for c in cells
        }
        for scenario, cells in _by_scenario(results["cells"]).items()
    }
