"""Report layer: join sweep cells into paper-style Table-1/2 artifacts.

`matrix_markdown` renders one accuracy-vs-bits table per scenario (the
shape of the paper's Tables 1-2: rows = algorithms, columns = accuracy and
wire cost, reduction measured against the same scenario's FedAvg row).
`validate_matrix` is the schema gate the CI bench-smoke job runs via
`python -m benchmarks.report --validate`: it fails on missing cell keys,
on a matrix thinner than the acceptance floor (5 algorithms x 3
scenarios), and — the accounting invariant — if any pFed1BS cell's billed
bits differ from re-invoicing its recorded per-round participation
through fl/comms.accumulate_round_bits.
"""
from __future__ import annotations

from repro.fl import comms

REQUIRED_CELL_KEYS = (
    "algo", "scenario", "acc", "acc_std", "loss_curve", "s_per_round",
    "rounds", "n", "m", "num_tensors", "uplink_bits", "downlink_bits",
    "total_bits", "total_mb", "us_per_round",
)


def validate_matrix(results: dict, min_algos: int = 5,
                    min_scenarios: int = 3) -> None:
    """Raise ValueError unless `results` is a well-formed sweep artifact."""
    for key in ("cells", "algos", "scenarios", "config"):
        if key not in results:
            raise ValueError(f"sweep artifact missing top-level key {key!r}")
    cells = results["cells"]
    algos = {c.get("algo") for c in cells}
    scenarios = {c.get("scenario") for c in cells}
    if len(algos) < min_algos:
        raise ValueError(
            f"matrix has {len(algos)} algorithms ({sorted(algos)}); "
            f"need >= {min_algos}"
        )
    if len(scenarios) < min_scenarios:
        raise ValueError(
            f"matrix has {len(scenarios)} scenarios ({sorted(scenarios)}); "
            f"need >= {min_scenarios}"
        )
    for cell in cells:
        missing = [k for k in REQUIRED_CELL_KEYS if k not in cell]
        if missing:
            raise ValueError(
                f"cell {cell.get('algo')}/{cell.get('scenario')} missing "
                f"keys {missing}"
            )
        # the bit meter must re-derive exactly from the recorded rounds
        expect = comms.accumulate_round_bits(
            cell["algo"], n=cell["n"], m=cell["m"],
            s_per_round=cell["s_per_round"],
            num_tensors=cell["num_tensors"],
        )
        for k in ("uplink_bits", "downlink_bits", "total_bits"):
            if cell[k] != expect[k]:
                raise ValueError(
                    f"cell {cell['algo']}/{cell['scenario']}: recorded {k}="
                    f"{cell[k]} != comms re-invoice {expect[k]}"
                )


def _by_scenario(cells):
    out: dict[str, list[dict]] = {}
    for c in cells:
        out.setdefault(c["scenario"], []).append(c)
    return out


def matrix_markdown(results: dict) -> str:
    """GitHub-markdown Table-1/2 per scenario: accuracy vs wire cost."""
    lines = []
    for scenario, cells in _by_scenario(results["cells"]).items():
        fedavg = next((c for c in cells if c["algo"] == "fedavg"), None)
        lines.append(f"### Scenario `{scenario}`\n")
        lines.append(
            "| algo | acc | ±std | total bits | MB | vs FedAvg | bits/round/client |"
        )
        lines.append("|---|---|---|---|---|---|---|")
        for c in sorted(cells, key=lambda c: -c["acc"]):
            red = (
                f"-{(1.0 - c['total_bits'] / fedavg['total_bits']) * 100:.2f}%"
                if fedavg and fedavg["total_bits"] else "—"
            )
            s_total = max(sum(c["s_per_round"]), 1)
            lines.append(
                f"| {c['algo']} | {c['acc']:.4f} | {c['acc_std']:.3f} "
                f"| {c['total_bits']:,} | {c['total_mb']:.3f} | {red} "
                f"| {c['total_bits'] / s_total:,.0f} |"
            )
        lines.append("")
    return "\n".join(lines)


def summarize(results: dict) -> dict:
    """Per-scenario {algo: (acc, total_bits)} digest for quick assertions."""
    return {
        scenario: {
            c["algo"]: {"acc": c["acc"], "total_bits": c["total_bits"]}
            for c in cells
        }
        for scenario, cells in _by_scenario(results["cells"]).items()
    }
