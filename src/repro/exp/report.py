"""Report layer: join sweep cells into paper-style Table-1/2 artifacts.

`matrix_markdown` renders one accuracy-vs-bits table per scenario (the
shape of the paper's Tables 1-2: rows = algorithms, columns = accuracy and
wire cost, reduction measured against the same scenario's FedAvg row).
`validate_matrix` is the schema gate the CI bench-smoke job runs via
`python -m benchmarks.report --validate`: it fails on missing cell keys,
on a matrix thinner than the acceptance floor (5 algorithms x 3
scenarios), and — the accounting invariant — if any pFed1BS cell's billed
bits differ from re-invoicing its recorded per-round participation
through fl/comms.accumulate_round_bits.

`validate_robust` is the same gate for the robustness artifact
(benchmarks/robust_bench.py -> BENCH_robust.json, DESIGN.md §10). Its
three load-bearing invariants: the scaled-garbage cell must be BIT-exact
with the honest run (sign quantization neutralizes magnitude garbage —
if this trips, corruption leaked past the encode), every cell must bill
identical uplink bits (robustness axes are free at the wire), and the
headline defense must recover >= `min_recovery` of the accuracy gap the
attack opened (defended vs undefended vs honest, same data, same seeds).
"""
from __future__ import annotations

from repro.fl import comms

REQUIRED_CELL_KEYS = (
    "algo", "scenario", "acc", "acc_std", "loss_curve", "s_per_round",
    "rounds", "n", "m", "num_tensors", "uplink_bits", "downlink_bits",
    "total_bits", "total_mb", "us_per_round",
)


def validate_matrix(results: dict, min_algos: int = 5,
                    min_scenarios: int = 3) -> None:
    """Raise ValueError unless `results` is a well-formed sweep artifact."""
    for key in ("cells", "algos", "scenarios", "config"):
        if key not in results:
            raise ValueError(f"sweep artifact missing top-level key {key!r}")
    cells = results["cells"]
    algos = {c.get("algo") for c in cells}
    scenarios = {c.get("scenario") for c in cells}
    if len(algos) < min_algos:
        raise ValueError(
            f"matrix has {len(algos)} algorithms ({sorted(algos)}); "
            f"need >= {min_algos}"
        )
    if len(scenarios) < min_scenarios:
        raise ValueError(
            f"matrix has {len(scenarios)} scenarios ({sorted(scenarios)}); "
            f"need >= {min_scenarios}"
        )
    for cell in cells:
        missing = [k for k in REQUIRED_CELL_KEYS if k not in cell]
        if missing:
            raise ValueError(
                f"cell {cell.get('algo')}/{cell.get('scenario')} missing "
                f"keys {missing}"
            )
        # the bit meter must re-derive exactly from the recorded rounds
        expect = comms.accumulate_round_bits(
            cell["algo"], n=cell["n"], m=cell["m"],
            s_per_round=cell["s_per_round"],
            num_tensors=cell["num_tensors"],
        )
        for k in ("uplink_bits", "downlink_bits", "total_bits"):
            if cell[k] != expect[k]:
                raise ValueError(
                    f"cell {cell['algo']}/{cell['scenario']}: recorded {k}="
                    f"{cell[k]} != comms re-invoice {expect[k]}"
                )


ROBUST_TOP_KEYS = (
    "config", "m", "honest", "garbage_parity", "signflip_curve", "rr_curve",
    "recovery",
)


def validate_robust(results: dict, min_recovery: float = 0.5) -> None:
    """Raise ValueError unless `results` is a well-formed BENCH_robust
    artifact satisfying the §10 invariants (see module docstring)."""
    for key in ROBUST_TOP_KEYS:
        if key not in results:
            raise ValueError(f"robust artifact missing top-level key {key!r}")
    honest = results["honest"]
    if honest.get("defense") != "none" or honest.get("adversary") is not None:
        raise ValueError(
            "honest baseline cell must have defense='none' and no adversary"
        )

    # 1. neutralized-garbage parity: bit-exact, not approximately equal
    gp = results["garbage_parity"]
    if not gp.get("bit_exact"):
        raise ValueError("garbage_parity.bit_exact is not True")
    if gp["garbage_acc"] != gp["honest_acc"] or (
        gp["garbage_loss_curve"] != gp["honest_loss_curve"]
    ):
        raise ValueError(
            "scaled-garbage cell is not bit-exact with the honest vote: "
            f"acc {gp['garbage_acc']} vs {gp['honest_acc']} — corruption "
            "leaked past the sign quantizer"
        )

    # 2. at least one defended-vs-undefended pair at the same attack level
    curve = results["signflip_curve"]
    by_frac: dict[float, set] = {}
    for c in curve:
        by_frac.setdefault(c["adversary_fraction"], set()).add(c["defense"])
    paired = [
        f for f, defs in by_frac.items()
        if f > 0 and "none" in defs and (defs - {"none"})
    ]
    if not paired:
        raise ValueError(
            "signflip_curve has no attacked fraction with both an "
            "undefended and a defended cell"
        )

    # 3. headline recovery: the defense closes >= min_recovery of the gap
    rec = results["recovery"]
    gap = rec["honest_acc"] - rec["undefended_acc"]
    recovered = rec["defended_acc"] - rec["undefended_acc"]
    frac = recovered / gap if gap > 0 else 1.0
    if abs(frac - rec["recovered_frac"]) > 1e-9:
        raise ValueError(
            f"recovery.recovered_frac={rec['recovered_frac']} does not "
            f"re-derive from its own cells ({frac})"
        )
    if frac < min_recovery:
        raise ValueError(
            f"defense {rec['defense']!r} recovered only {frac:.3f} of the "
            f"accuracy gap at fraction {rec['fraction']}; need >= "
            f"{min_recovery}"
        )

    # 4. one bit is one bit: every cell bills identical uplink bits
    cells = [honest, *curve, *results["rr_curve"]]
    bits = {c["uplink_bits"] for c in cells}
    if len(bits) != 1:
        raise ValueError(
            f"uplink bits differ across robustness cells: {sorted(bits)} — "
            "an attack or defense changed the wire bill"
        )
    for c in results["rr_curve"]:
        if not (c.get("epsilon") or 0) > 0:
            raise ValueError(f"rr_curve cell has invalid epsilon: {c}")


HIER_TOP_KEYS = ("m", "fan_out", "counter_merge_parity", "scaling")


def validate_hier(results: dict, max_root_growth: float = 8.0) -> None:
    """Raise ValueError unless `results` is a well-formed BENCH_hier
    artifact satisfying the §11 invariants:

      1. the counter-merge-equals-flat parity cell is present and
         bit_exact (every engine topology AND every pure vote case);
      2. every scaling row's bits re-derive EXACTLY from
         fl/comms.hier_round_bits over the HierTopology the executor
         would build from (clients, fan_out) — the artifact carries no
         number this module cannot recompute;
      3. the headline claim holds: flat-server root ingress grows
         linearly in clients while the tree root's stays O(log S)
         (bounded by `max_root_growth` across the whole curve).
    """
    from repro.fl import comms
    from repro.launch.fedexec import HierTopology

    for key in HIER_TOP_KEYS:
        if key not in results:
            raise ValueError(f"hier artifact missing top-level key {key!r}")
    par = results["counter_merge_parity"]
    if par.get("bit_exact") is not True:
        raise ValueError("counter_merge_parity.bit_exact is not True")
    cells = list(par.get("engine_cells", [])) + list(par.get("vote_cases", []))
    if not cells:
        raise ValueError("counter_merge_parity carries no cells")
    bad = [c for c in cells if c.get("bit_exact") is not True]
    if bad:
        raise ValueError(f"non-bit-exact parity cells: {bad}")

    m = results["m"]
    rows = results["scaling"]
    if len(rows) < 2:
        raise ValueError("scaling needs >= 2 client counts for a curve")
    for row in rows:
        topo = HierTopology.build(int(row["clients"]), int(row["fan_out"]))
        hb = comms.hier_round_bits(
            m=m, leaf_widths=topo.leaf_sizes, fan_out=topo.fan_out
        )
        for key in ("tiers", "root_ingress_bits", "uplink_bits",
                    "downlink_bits", "tier_uplink_bits"):
            if row[key] != hb[key]:
                raise ValueError(
                    f"scaling row clients={row['clients']}: {key}="
                    f"{row[key]} does not re-derive from fl/comms ({hb[key]})"
                )
        if row["flat_ingress_bits"] != int(row["clients"]) * m:
            raise ValueError(
                f"scaling row clients={row['clients']}: flat_ingress_bits="
                f"{row['flat_ingress_bits']} != clients*m"
            )
    first, last = rows[0], rows[-1]
    lin = last["clients"] / first["clients"]
    if last["flat_ingress_bits"] / first["flat_ingress_bits"] != lin:
        raise ValueError("flat ingress did not grow linearly in clients")
    growth = last["root_ingress_bits"] / first["root_ingress_bits"]
    if growth > max_root_growth:
        raise ValueError(
            f"tree root ingress grew {growth:.2f}x over a {lin:.0f}x client "
            f"range — not the claimed O(log S) (bound {max_root_growth}x)"
        )


def robust_markdown(results: dict) -> str:
    """README-style digest: accuracy vs adversary fraction x defense, and
    accuracy vs epsilon."""
    lines = ["| fraction | defense | acc |", "|---|---|---|"]
    for c in sorted(results["signflip_curve"],
                    key=lambda c: (c["adversary_fraction"], c["defense"])):
        lines.append(
            f"| {c['adversary_fraction']:.2f} | {c['defense']} "
            f"| {c['acc']:.4f} |"
        )
    lines.append("")
    lines.append("| epsilon | acc |")
    lines.append("|---|---|")
    for c in sorted(results["rr_curve"], key=lambda c: c["epsilon"]):
        lines.append(f"| {c['epsilon']:.1f} | {c['acc']:.4f} |")
    return "\n".join(lines)


def _by_scenario(cells):
    out: dict[str, list[dict]] = {}
    for c in cells:
        out.setdefault(c["scenario"], []).append(c)
    return out


def matrix_markdown(results: dict) -> str:
    """GitHub-markdown Table-1/2 per scenario: accuracy vs wire cost."""
    lines = []
    for scenario, cells in _by_scenario(results["cells"]).items():
        fedavg = next((c for c in cells if c["algo"] == "fedavg"), None)
        lines.append(f"### Scenario `{scenario}`\n")
        lines.append(
            "| algo | acc | ±std | total bits | MB | vs FedAvg | bits/round/client |"
        )
        lines.append("|---|---|---|---|---|---|---|")
        for c in sorted(cells, key=lambda c: -c["acc"]):
            red = (
                f"-{(1.0 - c['total_bits'] / fedavg['total_bits']) * 100:.2f}%"
                if fedavg and fedavg["total_bits"] else "—"
            )
            s_total = max(sum(c["s_per_round"]), 1)
            lines.append(
                f"| {c['algo']} | {c['acc']:.4f} | {c['acc_std']:.3f} "
                f"| {c['total_bits']:,} | {c['total_mb']:.3f} | {red} "
                f"| {c['total_bits'] / s_total:,.0f} |"
            )
        lines.append("")
    return "\n".join(lines)


def summarize(results: dict) -> dict:
    """Per-scenario {algo: (acc, total_bits)} digest for quick assertions."""
    return {
        scenario: {
            c["algo"]: {"acc": c["acc"], "total_bits": c["total_bits"]}
            for c in cells
        }
        for scenario, cells in _by_scenario(results["cells"]).items()
    }
