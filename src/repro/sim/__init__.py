"""Event-driven asynchronous federation tier (DESIGN.md §9).

Virtual-time simulation of a buffered async pFed1BS server: clients
arrive continuously under per-client latency models, their one-bit sketch
votes accumulate in a size-B buffer, and every flush re-votes the
consensus with staleness-discounted weights. With zero latency, buffer
size B = S and staleness exponent p = 0, one full drain of the event
queue is bit-exact with the synchronous fused round
(tests/test_async_sim.py).

  clock.py    deterministic virtual-time event queue + latency models
  client.py   per-client async state (download version, in-flight flag)
  server.py   buffered aggregator + the AsyncSimulator event loop
  metrics.py  wall-clock-vs-bits accounting on top of fl/comms
  hier.py     tree-of-aggregators tier: per-tier latency + buffers over
              partial popcount counters (DESIGN.md §11)
"""
from repro.sim.clock import (  # noqa: F401
    ConstantLatency,
    ComputeNetworkLatency,
    EventQueue,
    StragglerTailLatency,
)
from repro.sim.hier import (  # noqa: F401
    HierAsyncSimulator,
    HierSimConfig,
    TierSpec,
)
from repro.sim.server import AsyncConfig, AsyncSimulator  # noqa: F401
