"""Per-client async state for the event-driven federation tier.

A client in the async tier is a tiny state machine:

    idle --dispatch(version)--> in-flight --arrival--> idle
           downloads v^version                 upload lands; its vote
           starts R local steps                enters the server buffer

`download_version` is what staleness is measured against: when the upload
finally lands, the server has moved on to version V, and the vote is
discounted by 1/(1 + V - download_version)^p (core/consensus.py
::staleness_weights). A client has at most ONE job in flight — the
dispatch policy is version-gated (a client re-enters the pool only after
delivering its previous vote), which is what FedBuff calls bounded
concurrency and what keeps the zero-latency drain identical to the
synchronous cohort schedule.

The error-feedback residual named in the PR brief lives with the rest of
the stacked engine state (FLState.ef, one (K, m) row per client). It is
READ at dispatch — `_ef_quantize` runs inside the dispatch program
(server.py::_cohort_client_side), which is valid precisely because of the
version gate: with at most one job in flight, nothing can write a
client's residual between its dispatch and the flush that delivers its
vote — and the updated rows are WRITTEN back at flush by an exact index
scatter. Do not move the quantize into the flush body: computing it
outside the cohort program costs a ulp of XLA drift and breaks the
bit-exact parity contract (tests/test_async_sim.py, DESIGN.md §9.3).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClientState:
    download_version: int = -1     # consensus version last downloaded
    in_flight: bool = False
    jobs_done: int = 0             # uploads that have landed
    last_arrival_t: float = 0.0    # virtual time of the last landed upload
    reputation: float = 1.0        # server-side trust mirror: EMA of sign-
    #                                agreement with the consensus, updated at
    #                                flushes when defense="reputation"
    #                                (core/consensus.py::reputation_vote);
    #                                purely observational here — the voting
    #                                copy lives on FLState.rep


class Roster:
    """The K clients' async states + the version-gated dispatch rule."""

    def __init__(self, num_clients: int):
        self.states = [ClientState() for _ in range(num_clients)]

    def idle(self, client: int) -> bool:
        return not self.states[client].in_flight

    def dispatch(self, client: int, version: int) -> None:
        st = self.states[client]
        assert not st.in_flight, f"client {client} already in flight"
        st.in_flight = True
        st.download_version = version

    def arrive(self, client: int, t: float) -> int:
        """Mark the client's upload as landed; returns its download version
        (the server computes staleness against its own current version)."""
        st = self.states[client]
        assert st.in_flight, f"client {client} arrived without a dispatch"
        st.in_flight = False
        st.jobs_done += 1
        st.last_arrival_t = float(t)
        return st.download_version

    def set_reputation(self, values) -> None:
        """Mirror the engine's (K,) reputation vector onto the roster
        (called by the server after each defended flush)."""
        assert len(values) == len(self.states)
        for st, r in zip(self.states, values):
            st.reputation = float(r)

    def reputation(self) -> np.ndarray:
        return np.asarray([s.reputation for s in self.states], np.float32)

    def in_flight_count(self) -> int:
        return sum(s.in_flight for s in self.states)

    def jobs_done_counts(self) -> np.ndarray:
        return np.asarray([s.jobs_done for s in self.states], np.int64)
