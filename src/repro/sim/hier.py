"""Event-driven hierarchical aggregation tier (DESIGN.md §11).

The async tier (sim/server.py) models WHEN uploads land at one flat
server; this module models WHERE they land — a tree of edge aggregators
(launch/fedexec.py::HierTopology) in which every message is a partial
popcount counter, merged on arrival. Virtual time, same deterministic
EventQueue as the flat simulator.

Per consensus version:

  * the cohort is dispatched; each active client's m-bit sketch upload
    lands at its LEAF aggregator after a client latency draw. A client
    upload IS a width-1 counter (counter_bits(1) = 1 bit/coordinate —
    the one-bit sketch is the degenerate partial counter), so every hop
    in the tree carries the same object: (counts, rows_counted);
  * each aggregator node buffers incoming contributions. When its whole
    expected subtree has landed it forwards the merged counter to its
    parent after that tier's latency draw; a bounded `buffer_size` makes
    it EAGER instead — every `buffer_size` arrivals it forwards a partial
    counter and resets. Partial merges are exact (integer sums), so eager
    forwarding changes WHEN bits move and how many counter messages are
    paid, never the root's totals;
  * the root finishes the vote (2*cnt >= k over the arrived rows) once
    every expected row is counted, broadcasts one m-bit consensus per
    tier level, scatters client params, and dispatches the next cohort.

KEYSTONE INVARIANT (tests/test_hier.py): with zero latency everywhere,
full participation and full fan-in buffers, the drained consensus
sequence is BIT-EXACT with the synchronous hierarchical executor
(fedexec.hier_round) — which is itself bit-exact with the flat popcount
vote. Adversary / privacy axes ride the shared client-side program
(cohort_update + privatize_uplink, keyed by the dispatch version), so
injection is executor-invariant here too.

Billing: every message is time-stamped with its tier level and the
emitting node's client WIDTH; `HierSimReport.check_billing` re-derives
each message's bits from fl/comms.counter_bits (tier 0: the m-bit
sketch; tier L: counter_bits(width) * m) and each version's downlink as
one m-bit broadcast per tier level — the hierarchical analogue of the
flat tier's accumulate_round_bits re-invoice. With full fan-in buffers a
version's total equals HierTopology.round_bits(m) exactly.

Telemetry rides the tree (PR 10): each upload's latency draw enters its
leaf's mergeable QuantileSketch (obs/hist.py) and the sketch merges
upward with the partial counter — bucket sums next to popcount sums.
Because the sketch merge is exactly associative, the root's per-version
sketch holds exactly `arrivals` samples (asserted at every finish, the
histogram analogue of check_billing) and equals the sketch a flat server
would have built, however eager buffers batched the messages.

Defended votes are OUT of this tier by design: trimming needs the global
disagreement ranking, which only the root has — run defense through the
synchronous hier_round (where the root holds it) or the flat async tier.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rounds
from repro.fl import comms
from repro.kernels import ops as kops
from repro.obs import hist as obshist
from repro.obs import registry as obsreg
from repro.obs import trace as obstrace
from repro.sim.clock import ConstantLatency, EventQueue, LatencyModel


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One aggregator tier's behavior: the latency of forwarding a counter
    one hop up, and how many buffered contributions trigger an eager
    partial forward (None: full fan-in — forward once, when the node's
    whole expected subtree has landed)."""
    latency: LatencyModel = ConstantLatency(0.0)
    buffer_size: int | None = None


@dataclasses.dataclass(frozen=True)
class HierSimConfig:
    """Hierarchical-sim knobs. The all-defaults corner (zero latency, full
    fan-in) is the hier_round parity configuration."""
    topology: object                     # launch/fedexec.py::HierTopology
    max_versions: int = 4
    seed: int = 0
    client_latency: LatencyModel = ConstantLatency(0.0)
    tiers: tuple = ()                    # TierSpec per merge level, leaf->root;
    #                                      missing levels default to TierSpec()

    def tier_spec(self, level: int) -> TierSpec:
        return self.tiers[level] if level < len(self.tiers) else TierSpec()


@dataclasses.dataclass
class HierMeter:
    """Time-stamped per-tier billing. Uplink events: (t, tier_level,
    node_width, bits) — tier 0 is the client->leaf sketch hop; downlink
    events: (t, bits) consensus broadcasts. Like the flat AsyncMeter,
    a thin adapter over obs.registry.MetricsRegistry — billing mirrors
    onto the bound tracer's counter track."""
    m: int
    uplink_events: list = dataclasses.field(default_factory=list)
    downlink_events: list = dataclasses.field(default_factory=list)
    registry: obsreg.MetricsRegistry = dataclasses.field(
        default_factory=obsreg.MetricsRegistry
    )

    def bill_uplink(self, t: float, tier: int, width: int) -> None:
        bits = self.m if tier == 0 else comms.counter_bits(width) * self.m
        self.uplink_events.append((float(t), int(tier), int(width), bits))
        self.registry.add("uplink_bits", bits, t=t)

    def bill_downlink(self, t: float, levels: int) -> None:
        for _ in range(levels):
            self.downlink_events.append((float(t), self.m))
        self.registry.add("downlink_bits", levels * self.m, t=t)

    @property
    def uplink_bits(self) -> int:
        return sum(b for _, _, _, b in self.uplink_events)

    @property
    def downlink_bits(self) -> int:
        return sum(b for _, b in self.downlink_events)

    @property
    def total_bits(self) -> int:
        return self.uplink_bits + self.downlink_bits


@dataclasses.dataclass
class HierFlushRecord:
    version: int          # consensus version this root finish PRODUCED
    t: float              # virtual time of the root finish
    arrivals: int         # client uploads counted into this version
    counter_messages: int  # aggregator->parent messages this version paid
    task_loss: float
    lat: object = None    # root-merged client-latency QuantileSketch —
    #                       rode the tree alongside the counters; its
    #                       count == arrivals is asserted at every finish


@dataclasses.dataclass
class HierSimReport:
    """One hierarchical run, fully re-derivable."""
    m: int
    topology: object
    flushes: list = dataclasses.field(default_factory=list)
    meter: HierMeter | None = None

    @property
    def versions(self) -> int:
        return len(self.flushes)

    @property
    def final_t(self) -> float:
        return self.flushes[-1].t if self.flushes else 0.0

    def expected_bits(self) -> dict:
        """Re-derive the invoice from fl/comms: every logged uplink message
        re-bills from its (tier, width) — m bits for a client sketch,
        counter_bits(width) * m for an aggregator counter — and every
        version pays one m-bit broadcast per tier level. Delegates to the
        shared checker in obs/registry.py (same walk as the flat async
        tier's and the TRACE_* gate)."""
        return obsreg.expected_hier_bits(
            self.m,
            [(tier, width) for _, tier, width, _ in self.meter.uplink_events],
            self.versions,
            len(self.topology.level_widths()),
        )

    def check_billing(self) -> None:
        """Raise ValueError unless the meter re-derives exactly from
        fl/comms over the recorded message log."""
        got = {"uplink_bits": self.meter.uplink_bits,
               "downlink_bits": self.meter.downlink_bits}
        obsreg.assert_billing("hier meter", got, self.expected_bits())

    def latency_sketch(self) -> obshist.QuantileSketch:
        """All versions' root-merged client-latency sketches, merged once
        more — the run-level staleness/latency distribution. Because the
        sketch merge is exactly associative this equals a sketch built
        flat from every latency draw, however the tree batched them
        (asserted per version in the event loop, like check_billing)."""
        per_version = [f.lat for f in self.flushes if f.lat is not None]
        if not per_version:
            return obshist.QuantileSketch(rel_acc=0.01)
        return obshist.merged(*per_version)

    def to_dict(self) -> dict:
        return {
            "m": self.m,
            "versions": self.versions,
            "final_t": self.final_t,
            "arrivals_per_version": [f.arrivals for f in self.flushes],
            "counter_messages_per_version": [
                f.counter_messages for f in self.flushes
            ],
            "uplink_bits": self.meter.uplink_bits,
            "downlink_bits": self.meter.downlink_bits,
            "total_bits": self.meter.total_bits,
            "task_loss_curve": [f.task_loss for f in self.flushes],
            "client_latency": self.latency_sketch().summary(),
        }


class _Node:
    """One aggregator's per-version accumulation state. The pending
    buffer holds a partial popcount counter AND a latency sketch — both
    merge exactly (integer sums / bucket sums), so histograms ride the
    tree with the votes at zero extra coordination."""

    def __init__(self, width: int, expected_rows: int, nw: int):
        self.width = width                # clients covered (wire format size)
        self.expected = expected_rows     # active rows expected this version
        self.received = 0                 # rows merged so far (all forwards)
        self.pending_counts = jnp.zeros((nw, 32), jnp.int32)
        self.pending_rows = 0             # rows in the pending buffer
        self.pending_msgs = 0             # contributions since last forward
        self.pending_lat = obshist.QuantileSketch(rel_acc=0.01)

    def absorb(self, counts, nrows: int, lat=None) -> None:
        self.pending_counts = kops.merge_counters(
            jnp.stack([self.pending_counts, counts])
        )
        self.pending_rows += nrows
        self.pending_msgs += 1
        self.received += nrows
        if lat is not None:
            self.pending_lat.merge(lat)

    def take_pending(self):
        out = (self.pending_counts, self.pending_rows, self.pending_lat)
        self.pending_counts = jnp.zeros_like(self.pending_counts)
        self.pending_rows = 0
        self.pending_msgs = 0
        self.pending_lat = obshist.QuantileSketch(rel_acc=0.01)
        return out


class HierAsyncSimulator:
    """Event loop binding an engine to the tree of aggregators.

    engine: a PFed1BS instance (defense="none"; any adversary/privacy).
    weights: (K,) p_k — metrics weighting only; the tree vote is the
      unweighted popcount object, like the flat popcount executor.
    participants_fn(version) -> (idx (S,), active (S,)) and
    batch_fn(version) -> (K, R, B, ...) pytree: the same two callables the
      flat AsyncSimulator takes, shared with synchronous runs for exact
      comparisons.
    """

    def __init__(self, engine, cfg: HierSimConfig, weights,
                 participants_fn: Callable, batch_fn: Callable, tracer=None):
        assert engine.cfg.defense == "none", (
            "defended votes need the global ranking only the synchronous "
            "root has — run them through fedexec.hier_round"
        )
        if tracer is not None:
            assert tracer.clock == "virtual" or not tracer.enabled, (
                "HierAsyncSimulator needs a virtual-clock tracer"
            )
        self.tracer = obstrace.NOOP if tracer is None else tracer
        topo = cfg.topology
        assert topo.num_clients == engine.cfg.participate, (
            f"topology covers {topo.num_clients} clients, cohort is "
            f"{engine.cfg.participate}"
        )
        self.eng = engine
        self.cfg = cfg
        self.topo = topo
        self.weights = jnp.asarray(weights, jnp.float32)
        self.participants_fn = participants_fn
        self.batch_fn = batch_fn
        self._cohort = jax.jit(self._cohort_client_side)
        self._nw = (engine.m + (-engine.m) % 32) // 32
        # leaf id of each cohort row (contiguous split, like hier_round)
        self._leaf_of = np.repeat(
            np.arange(len(topo.leaf_sizes)),
            [int(s) for s in topo.leaf_sizes],
        )

    def _cohort_client_side(self, clients, batches, idx, v, ef, rnd):
        """Same one-program client side as the flat async tier: cohort
        update + (EF) sign-quantization + RR flips + bit-pack, keyed by the
        dispatch version (see sim/server.py::_cohort_client_side for the
        bit-exactness rationale)."""
        upd, task_loss, zs = self.eng.cohort_update(clients, batches, idx, v, rnd)
        if ef is None:
            signs = jnp.sign(zs) + (zs == 0)
            new_rows = None
        else:
            _, signs, new_rows = self.eng._ef_quantize(zs, ef[idx])
        wire = self.eng.privatize_uplink(signs, idx, rnd)
        flips = (
            jnp.sum((wire != signs).astype(jnp.int32), axis=1)
            if self.eng.cfg.privacy is not None else None
        )
        return upd, task_loss, self.eng._pack_uplink(wire), new_rows, flips

    def run(self, state, on_flush: Callable | None = None):
        """Drain cfg.max_versions tree rounds starting from a synchronous
        FLState. Returns (final FLState, HierSimReport)."""
        eng, cfg, topo = self.eng, self.cfg, self.topo
        tr = self.tracer
        levels = topo.level_widths()          # [[leaf widths], ..., [S]]
        n_levels = len(levels)
        queue = EventQueue()
        registry = obsreg.MetricsRegistry(tracer=tr)
        meter = HierMeter(m=eng.m, registry=registry)
        report = HierSimReport(m=eng.m, topology=topo, meter=meter)
        version = 0
        t = 0.0
        last_finish_t = 0.0
        nodes: dict = {}                      # (level, i) -> _Node
        staged: dict = {}                     # per-version cohort outputs
        counter_msgs = 0

        def parent(level: int, i: int):
            return (level + 1, i // topo.fan_out)

        def dispatch_cohort(t_now: float, ver: int, st):
            nonlocal counter_msgs
            counter_msgs = 0
            idx, active = self.participants_fn(ver)
            batches = self.batch_fn(ver)
            upd, task_loss, packed, ef_rows, flips = self._cohort(
                st.clients, batches, idx, st.v, st.ef, jnp.int32(ver)
            )
            act_np = np.asarray(active)
            staged[ver] = {"idx": idx, "active": active, "upd": upd,
                           "task_loss": task_loss, "packed": packed,
                           "ef_rows": ef_rows, "flips": flips}
            tr.instant("dispatch", t=t_now, track="server", version=ver,
                       clients=int((act_np > 0).sum()))
            # per-version node states sized by the ACTIVE rows under each
            # subtree (a dropped-out client transmits nothing; its empty
            # contribution is a valid zero count, never waited for)
            exp = [int((act_np[self._leaf_of == li] > 0).sum())
                   for li in range(len(levels[0]))]
            for lvl, widths in enumerate(levels):
                if lvl > 0:
                    exp = [sum(exp[i : i + topo.fan_out])
                           for i in range(0, len(exp), topo.fan_out)]
                for i, w in enumerate(widths):
                    nodes[(lvl, i)] = _Node(w, exp[i], self._nw)
            for row in range(len(act_np)):
                if act_np[row] <= 0:
                    continue
                c = int(np.asarray(idx)[row])
                delay = cfg.client_latency.duration(cfg.seed, c, ver)
                queue.push(t_now + delay, "arrival", c,
                           payload=(ver, row, int(self._leaf_of[row]),
                                    float(delay)))

        def forward(t_now: float, ver: int, level: int, i: int) -> None:
            """Send a node's pending (counts, rows) one hop up."""
            nonlocal counter_msgs
            node = nodes[(level, i)]
            counts, nrows, lat = node.take_pending()
            counter_msgs += 1
            meter.bill_uplink(t_now, level + 1, node.width)
            registry.add("tier_merges", 1, t=t_now)
            tr.instant("forward", t=t_now, track=f"tier{level + 1}",
                       node=i, rows=nrows, width=node.width)
            delay = cfg.tier_spec(level).latency.duration(
                cfg.seed, i, ver
            )
            queue.push(t_now + delay, "merge", i,
                       payload=(ver, level + 1, parent(level, i)[1],
                                counts, nrows, lat))

        def node_absorb(t_now, ver, level, i, counts, nrows, st, lat=None):
            """Merge a contribution into node (level, i); forward on a full
            subtree (or a full eager buffer); finish at the root."""
            node = nodes[(level, i)]
            node.absorb(counts, nrows, lat=lat)
            if level == n_levels - 1:         # the root
                if node.received >= node.expected:
                    return finish(t_now, ver, st)
                return st
            spec = cfg.tier_spec(level)
            if node.received >= node.expected:
                forward(t_now, ver, level, i)
            elif spec.buffer_size is not None and \
                    node.pending_msgs >= spec.buffer_size:
                forward(t_now, ver, level, i)  # eager partial counter
            return st

        def finish(t_now: float, ver: int, st):
            nonlocal version, last_finish_t
            entry = staged.pop(ver)
            root = nodes[(n_levels - 1, 0)]
            counts, k, lat = root.take_pending()
            vw = kops.finish_vote_counts(counts, jnp.int32(k))
            v_new = kops.unpack_signs(vw)[: eng.m]
            idx, active = entry["idx"], entry["active"]
            clients = rounds.scatter_rows(
                st.clients, idx, entry["upd"], active
            )
            new_ef = st.ef
            if st.ef is not None:
                rows = jnp.where(active[:, None] > 0, entry["ef_rows"],
                                 st.ef[idx])
                new_ef = st.ef.at[idx].set(rows)
            meter.bill_downlink(t_now, n_levels)
            w_s = self.weights[idx] * active
            task = float(jnp.sum(entry["task_loss"] * w_s)
                         / jnp.maximum(jnp.sum(w_s), 1e-9))
            version += 1
            arrivals = int(np.asarray(active).sum())
            tr.complete("version", t0=last_finish_t, t1=t_now, track="server",
                        version=version, arrivals=arrivals,
                        counter_messages=counter_msgs)
            last_finish_t = t_now
            tr.instant("broadcast", t=t_now, track="server", version=version,
                       levels=n_levels)
            registry.add("votes_cast", arrivals, t=t_now)
            # histogram-merge invariant, the latency analogue of
            # check_billing: every counted row contributed exactly one
            # latency sample at its leaf, and the sketch merge is exact,
            # so the root sketch must hold exactly `arrivals` samples
            if lat.count != arrivals:
                raise ValueError(
                    f"latency sketch lost samples riding the tree: root "
                    f"count {lat.count} != arrivals {arrivals}"
                )
            report.flushes.append(HierFlushRecord(
                version=version, t=t_now,
                arrivals=arrivals,
                counter_messages=counter_msgs, task_loss=task, lat=lat,
            ))
            st = st._replace(clients=clients, v=v_new,
                             round=st.round + 1, ef=new_ef)
            if on_flush is not None:
                on_flush(t_now, version, st)
            if version < cfg.max_versions:
                dispatch_cohort(t_now, version, st)
            return st

        dispatch_cohort(0.0, 0, state)
        while queue and version < cfg.max_versions:
            ev = queue.pop()
            t = ev.t
            if ev.kind == "arrival":
                ver, row, leaf, delay = ev.payload
                meter.bill_uplink(t, 0, 1)
                tr.instant("arrive", t=t, track="server", client=ev.client,
                           version=ver, leaf=leaf)
                if tr.enabled and staged[ver]["flips"] is not None:
                    registry.add(
                        "rr_flips", int(staged[ver]["flips"][row]), t=t
                    )
                counts = kops.popcount_partial(
                    staged[ver]["packed"][row : row + 1]
                )
                # the upload's latency enters the leaf's sketch here and
                # merges upward with the counter from now on
                one = obshist.QuantileSketch(rel_acc=0.01)
                one.add(delay)
                state = node_absorb(t, ver, 0, leaf, counts, 1, state,
                                    lat=one)
            else:
                ver, level, i, counts, nrows, lat = ev.payload
                state = node_absorb(t, ver, level, i, counts, nrows, state,
                                    lat=lat)
        report.check_billing()
        return state, report
