"""Deterministic virtual-time event queue + per-client latency models.

Time here is VIRTUAL: the simulator never reads a wall clock, so a run is
a pure function of (engine state, scenario, seed) — replayable in tests
and CI, and immune to host-load jitter. The queue is a binary heap keyed
on `(t, seq)`: `seq` is a monotone push counter, so events at the same
virtual instant pop in push order and ties can never be broken
nondeterministically (this is what makes the zero-latency drain reproduce
the synchronous round's client order exactly).

Latency models answer one question — "how long does client k's round-trip
take for the job it started at consensus version v?" — deterministically
from `(seed, client, version)` via numpy SeedSequence streams (no global
RNG state, no draw-order dependence). Three families:

  ConstantLatency          every job takes the same `seconds` (0.0 is the
                           parity configuration)
  ComputeNetworkLatency    lognormal compute (scaled by a persistent
                           per-client speed factor — slow devices stay
                           slow) + shifted-exponential network, the
                           standard FL latency decomposition
  StragglerTailLatency     a base model mixed with a heavy tail: with
                           probability `tail_prob` the job additionally
                           pays `tail_mult` x an Exp(tail_scale) stall —
                           the regime where synchronous rounds are bound
                           by the slowest client and buffered async wins
                           (benchmarks/async_bench.py)

Models are frozen dataclasses so they compose with `exp/scenarios.py`'s
Scenario as a fourth axis (`Scenario.latency`) without breaking hashing.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, NamedTuple

import numpy as np


class Event(NamedTuple):
    t: float          # virtual seconds
    seq: int          # heap tiebreak: push order
    kind: str         # "arrival" (client upload lands at the server)
    client: int
    payload: Any


class EventQueue:
    """Binary heap of Events keyed on (t, seq). Deterministic: equal-time
    events pop in push order; pushing never reads any clock."""

    def __init__(self):
        self._heap: list[Event] = []
        self._seq = 0

    def push(self, t: float, kind: str, client: int, payload=None) -> Event:
        assert t >= 0.0 and np.isfinite(t), t
        ev = Event(float(t), self._seq, kind, int(client), payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def peek(self) -> Event:
        return self._heap[0]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


def _rng(seed: int, *stream: int) -> np.random.Generator:
    """Independent deterministic stream for (seed, *stream) — SeedSequence
    spawning keys the stream on the whole tuple, so per-(client, version)
    draws never alias and never depend on draw order."""
    return np.random.default_rng(np.random.SeedSequence((seed,) + stream))


@dataclasses.dataclass(frozen=True)
class ConstantLatency:
    """Every job takes `seconds` of virtual time. seconds=0.0 is the
    parity configuration: all uploads of a cohort land at dispatch time,
    in dispatch order (heap seq)."""
    seconds: float = 0.0

    def duration(self, seed: int, client: int, version: int) -> float:
        return float(self.seconds)


@dataclasses.dataclass(frozen=True)
class ComputeNetworkLatency:
    """compute ~ speed_k * LogNormal(mu, sigma) + network ~ shift + Exp(scale).

    speed_k is a PERSISTENT per-client lognormal factor (drawn once from
    (seed, client)): device heterogeneity, not per-round noise. The
    per-job lognormal models R local steps' compute variance; the shifted
    exponential is the classic last-mile network model (a floor `shift`
    plus a memoryless tail)."""
    compute_mu: float = 0.0        # log-scale of per-job compute seconds
    compute_sigma: float = 0.25
    net_shift: float = 0.05        # network floor, seconds
    net_scale: float = 0.05        # Exp mean of the network tail
    client_speed_sigma: float = 0.4  # lognormal sigma of persistent speed_k

    def client_speed(self, seed: int, client: int) -> float:
        return float(_rng(seed, client, 0xC0).lognormal(
            mean=0.0, sigma=self.client_speed_sigma
        ))

    def duration(self, seed: int, client: int, version: int) -> float:
        g = _rng(seed, client, version, 0x01)
        compute = self.client_speed(seed, client) * g.lognormal(
            mean=self.compute_mu, sigma=self.compute_sigma
        )
        net = self.net_shift + g.exponential(self.net_scale)
        return float(compute + net)


@dataclasses.dataclass(frozen=True)
class StragglerTailLatency:
    """Mixture: `base` latency, plus — with probability `tail_prob` — a
    heavy stall of tail_mult * Exp(tail_scale) (background tasks, radio
    dropouts, airplane mode). The tail draw is keyed on (seed, client,
    version) like the base, so a given job is a straggler or not
    deterministically."""
    base: ComputeNetworkLatency = ComputeNetworkLatency()
    tail_prob: float = 0.15
    tail_mult: float = 10.0
    tail_scale: float = 1.0

    def duration(self, seed: int, client: int, version: int) -> float:
        d = self.base.duration(seed, client, version)
        g = _rng(seed, client, version, 0x7A)
        if g.uniform() < self.tail_prob:
            d += self.tail_mult * g.exponential(self.tail_scale)
        return float(d)


LatencyModel = ConstantLatency | ComputeNetworkLatency | StragglerTailLatency
