"""Wall-clock-vs-bits accounting for the async tier.

`fl/comms.py` answers "how many bits did a round cost"; the async tier
also needs WHEN those bits were on the wire — a buffered server that
flushes early spends the same per-upload bits but compresses them into
less virtual time. `AsyncMeter` time-stamps every billing event (one
m-bit uplink per landed upload, one m-bit consensus broadcast per flush)
and the report re-derives the totals through
`fl/comms.accumulate_round_bits` with the recorded arrivals-per-flush as
the realized `s_per_round` — the identical invoice the synchronous
scenario harness uses, so sync and async runs are compared at equal
billed bits (`benchmarks/report.py --validate` gates on the
re-derivation, like the exp matrix).

`SimReport` is the run artifact: the flush log (virtual time, arrivals,
staleness lags), the consensus-version lag histogram, the billing
timeline, and `time_to_target` over an accuracy-vs-virtual-time curve.
"""
from __future__ import annotations

import dataclasses

from repro.fl import comms
from repro.obs import hist as obshist
from repro.obs import registry as obsreg


@dataclasses.dataclass
class AsyncMeter:
    """Time-stamped bit billing: (t, bits) event lists per direction.

    Thin adapter over `obs.registry.MetricsRegistry` — every billing
    event mirrors into the registry (and through it onto the bound
    tracer's virtual-time counter track), while the local event lists
    keep the per-timestamp view (`bits_by_second`) the capacity-planner
    summaries need."""
    m: int
    uplink_events: list = dataclasses.field(default_factory=list)
    downlink_events: list = dataclasses.field(default_factory=list)
    registry: obsreg.MetricsRegistry = dataclasses.field(
        default_factory=obsreg.MetricsRegistry
    )

    def bill_uplink(self, t: float) -> None:
        self.uplink_events.append((float(t), self.m))
        self.registry.add("uplink_bits", self.m, t=t)

    def bill_downlink(self, t: float) -> None:
        self.downlink_events.append((float(t), self.m))
        self.registry.add("downlink_bits", self.m, t=t)

    @property
    def uplink_bits(self) -> int:
        return sum(b for _, b in self.uplink_events)

    @property
    def downlink_bits(self) -> int:
        return sum(b for _, b in self.downlink_events)

    @property
    def total_bits(self) -> int:
        return self.uplink_bits + self.downlink_bits

    def bits_by_second(self, bucket: float = 1.0) -> dict[int, int]:
        """Bits on the wire per virtual-`bucket`-second bin (both
        directions) — the load profile a capacity planner reads."""
        out: dict[int, int] = {}
        for t, b in self.uplink_events + self.downlink_events:
            out[int(t // bucket)] = out.get(int(t // bucket), 0) + b
        return dict(sorted(out.items()))

    def cumulative_bits_at(self, t: float) -> int:
        return sum(
            b
            for ts, b in self.uplink_events + self.downlink_events
            if ts <= t
        )


@dataclasses.dataclass
class FlushRecord:
    version: int          # consensus version this flush PRODUCED
    t: float              # virtual time of the flush
    arrivals: int         # uploads in the buffer (B, or fewer on drain)
    taus: list            # per-upload consensus-version lags at the flush
    task_loss: float


@dataclasses.dataclass
class SimReport:
    """One async run, fully re-derivable."""
    m: int
    flushes: list[FlushRecord] = dataclasses.field(default_factory=list)
    meter: AsyncMeter | None = None
    residual_arrivals: int = 0     # billed uploads still buffered at stop
    final_reputation: list | None = None   # (K,) trust EMA at stop, only
    #                                        when defense="reputation"
    #                                        (DESIGN.md §10)
    # NB: accuracy curves are the CALLER's to build (the simulator has no
    # eval function) — pass an on_flush hook to AsyncSimulator.run, as
    # benchmarks/async_bench.py does, and feed `time_to_target` with it.

    @property
    def versions(self) -> int:
        return len(self.flushes)

    @property
    def arrivals_per_flush(self) -> list[int]:
        return [f.arrivals for f in self.flushes]

    @property
    def final_t(self) -> float:
        return self.flushes[-1].t if self.flushes else 0.0

    def lag_histogram(self) -> dict[int, int]:
        """Consensus-version lag (staleness tau) histogram over every
        upload that entered a flush."""
        out: dict[int, int] = {}
        for f in self.flushes:
            for tau in f.taus:
                out[int(tau)] = out.get(int(tau), 0) + 1
        return dict(sorted(out.items()))

    def expected_bits(self) -> dict:
        """The fl/comms re-invoice of this run: each flush is billed like a
        sync round with s = its arrival count (m bits per upload + ONE
        m-bit broadcast), plus m uplink bits per still-buffered residual
        arrival (transmitted, never flushed before the stop). Delegates to
        the shared checker in obs/registry.py — the same walk gates the
        hier tier and the exported TRACE_* artifacts."""
        return obsreg.expected_async_bits(
            self.m, self.arrivals_per_flush,
            residual_arrivals=self.residual_arrivals,
        )

    def check_billing(self) -> None:
        """Raise ValueError unless the time-stamped meter re-derives
        exactly from fl/comms over the recorded flush log."""
        got = {
            "uplink_bits": self.meter.uplink_bits,
            "downlink_bits": self.meter.downlink_bits,
        }
        obsreg.assert_billing("async meter", got, self.expected_bits())

    def to_dict(self) -> dict:
        extra = (
            {"final_reputation": self.final_reputation}
            if self.final_reputation is not None else {}
        )
        return extra | {
            "m": self.m,
            "versions": self.versions,
            "arrivals_per_flush": self.arrivals_per_flush,
            "residual_arrivals": self.residual_arrivals,
            "final_t": self.final_t,
            "lag_histogram": {str(k): v for k, v in self.lag_histogram().items()},
            "uplink_bits": self.meter.uplink_bits,
            "downlink_bits": self.meter.downlink_bits,
            "total_bits": self.meter.total_bits,
            "flush_t": [f.t for f in self.flushes],
            "task_loss_curve": [f.task_loss for f in self.flushes],
        }


def time_to_target(curve, target: float) -> float | None:
    """First virtual time at which accuracy >= target on a [(t, acc), ...]
    curve; None if never reached."""
    for t, acc in curve:
        if acc >= target:
            return float(t)
    return None


def validate_async_artifact(obj: dict) -> None:
    """Schema + accounting gate for BENCH_async(.fast).json — the async
    analogue of exp/report.validate_matrix, run by
    `benchmarks/report.py --validate`:

      * the sync-parity cell must be present and bit-exact,
      * both runs' billed bits must re-derive exactly from fl/comms over
        the recorded arrivals-per-flush / clients-per-round,
      * async must beat sync on time-to-target accuracy.
    """
    parity = obj.get("sync_parity")
    if not isinstance(parity, dict) or parity.get("bit_exact") is not True:
        raise ValueError("sync_parity cell missing or not bit_exact")
    a = obj["async"]
    obsreg.assert_billing(
        "BENCH_async async block",
        {"uplink_bits": a["uplink_bits"], "downlink_bits": a["downlink_bits"]},
        obsreg.expected_async_bits(
            obj["m"], a["arrivals_per_flush"],
            residual_arrivals=a.get("residual_arrivals", 0),
        ),
    )
    s = obj["sync"]
    sbits = comms.accumulate_round_bits(
        "pfed1bs", n=0, m=obj["m"], s_per_round=s["s_per_round"]
    )
    for k in ("uplink_bits", "downlink_bits"):
        if s[k] != sbits[k]:
            raise ValueError(
                f"sync bits do not re-derive from fl/comms: {k} {s[k]} != "
                f"{sbits[k]}"
            )
    # the fairness premise of the headline claim: the two runs carry the
    # SAME number of client uploads, so the speedup is compared at equal
    # billed uplink bits (async additionally pays one m-bit broadcast per
    # extra flush — that asymmetry is visible in downlink_bits)
    if a["uplink_bits"] != s["uplink_bits"]:
        raise ValueError(
            f"async/sync uplink bits differ ({a['uplink_bits']} vs "
            f"{s['uplink_bits']}): the time-to-target comparison is no "
            f"longer at equal billed bits"
        )
    tts, tta = s["time_to_target_s"], a["time_to_target_s"]
    if tta is None:
        raise ValueError("async run never reached the target accuracy")
    if tts is not None and not tta < tts:
        raise ValueError(
            f"async time-to-target {tta} does not beat sync {tts}"
        )


def summarize_lags(taus: list[int]) -> dict:
    """Staleness-lag summary via the mergeable quantile sketch
    (obs/hist.py) — same summary block the serving tier and health
    monitor emit. Percentiles are sketch-derived (relative error <= 1%;
    lags are small integers, so in practice they are the exact
    sorted[floor(q*(n-1))] order statistic); mean/max exact."""
    sk = lag_sketch(taus)
    s = sk.summary()
    return {"mean": s["mean"], "p50": s["p50"], "p99": s["p99"], "max": s["max"]}


def lag_sketch(taus) -> obshist.QuantileSketch:
    """The staleness distribution as a mergeable sketch — per-shard lag
    sketches merge exactly (split-invariance), like the vote counters."""
    sk = obshist.QuantileSketch(rel_acc=0.01)
    for tau in (taus if len(taus) else [0]):
        sk.add(float(tau))
    return sk
