"""Buffered asynchronous pFed1BS server + the virtual-time event loop.

The FedBuff-style protocol, specialized to one-bit sketch votes:

  * the server holds a VERSIONED consensus v^V (FLState.round is the
    version counter) and a size-B buffer of landed uploads;
  * a dispatched client downloads the current consensus, runs its R local
    steps through the SAME cohort computation as the synchronous fused
    round (core/pfed1bs.py::cohort_update), and its one-bit sketch vote
    lands after a latency-model delay (sim/clock.py);
  * every B-th arrival FLUSHES: the buffered votes are re-voted with
    staleness-discounted weights p_k / (1 + tau_k)^p (tau_k = consensus
    versions elapsed since that client's download;
    core/consensus.py::staleness_weights), EF residuals are updated
    through the engine's own `_ef_quantize`, client params scatter
    through core/rounds.scatter_rows, and the new consensus version is
    broadcast (billed: one m-bit downlink per flush, one m-bit uplink per
    arrival — sim/metrics.py);
  * dispatch is version-gated (sim/client.py): at every flush the
    participation draw for the NEW version runs over the currently idle
    clients; stragglers still in flight simply land later, stale.

The cheapness of the re-vote is the point: pFed1BS's server state is m
sign-sums, so flushing every B arrivals costs one (B, m) weighted
majority vote — no model-delta averaging, no optimizer state. The vote
runs either in float sign space (`vote="exact"`, Lemma 1 in natural
client order — the parity path) or on the packed wire words over the
ragged buffer (`vote="packed"`, kernels/ops.py::vote_packed_ragged,
ties -> +1).

KEYSTONE INVARIANT (pinned by tests/test_async_sim.py): with
ConstantLatency(0), buffer_size B = S and staleness_exponent p = 0, one
full drain of the event queue is BIT-EXACT with the synchronous fused
round — same consensus, same client params, same EF residuals, EF on and
off, flat and leaf layouts. Every departure from the synchronous
semantics must therefore be switched by latency, B, or p — never by the
event-loop plumbing itself.

ROBUSTNESS (DESIGN.md §10): the adversary / privacy axes ride the same
plumbing. Corruption happens inside `cohort_update` (keyed by the
DOWNLOAD version — the async analogue of the sync round counter, so the
zero-latency drain corrupts exactly the rounds the fused run corrupts),
RR flips apply to the wire signs at dispatch, and the flush re-vote runs
through the engine's `vote_defended` (trimmed / reputation-weighted
voting with the RR debias folded in). Reputation state (per-client EMA
of sign-agreement) lives on FLState.rep, is updated only at flushes —
where votes actually land — and is mirrored onto the Roster for
inspection. Defended flushes require `vote="exact"`: the ragged packed
vote has no trimmed/reputation variant (asserted at construction).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, rounds
from repro.kernels import ops as kops
from repro.obs import registry as obsreg
from repro.obs import trace as obstrace
from repro.sim import metrics as simmetrics
from repro.sim.client import Roster
from repro.sim.clock import ConstantLatency, EventQueue, LatencyModel


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Async-tier knobs. The (0-latency, B=S, p=0) corner is sync parity."""
    buffer_size: int                     # B: arrivals per flush
    staleness_exponent: float = 0.0      # p in 1/(1+tau)^p
    max_versions: int = 10               # stop after this many flushes
    seed: int = 0                        # latency-model stream seed
    latency: LatencyModel = ConstantLatency(0.0)
    vote: str = "exact"                  # "exact" | "packed" (ragged wire vote)
    flush_partial_on_drain: bool = True  # ragged final flush if the queue
    #                                      empties with a part-full buffer


@dataclasses.dataclass
class _Buffered:
    """One landed upload waiting in the server buffer."""
    client: int
    download_version: int
    staged_version: int   # which staged cohort holds its rows
    row: int              # row index within that cohort
    t: float


class AsyncSimulator:
    """Event loop binding an engine to the buffered async server.

    engine: a PFed1BS instance (any sketch layout; fused semantics).
    weights: (K,) aggregation weights p_k.
    participants_fn(version) -> (idx (S,), active (S,)): the participation
      draw for the cohort dispatched at `version` (core/rounds.py
      semantics — active=0 rows are computed but never dispatched).
    batch_fn(version) -> (K, R, B, ...) pytree: the round's minibatches,
      same contract as the synchronous harness. Sharing these two
      callables with a synchronous run is what makes sync-vs-async
      comparisons (and the parity test) exact.
    """

    def __init__(self, engine, cfg: AsyncConfig, weights,
                 participants_fn: Callable, batch_fn: Callable, tracer=None):
        assert cfg.vote in ("exact", "packed"), cfg.vote
        assert cfg.buffer_size >= 1
        # defended votes (trim / reputation) exist only in float sign space;
        # the ragged packed flush vote has no trimmed variant.
        assert engine.cfg.defense == "none" or cfg.vote == "exact", (
            "defense requires vote='exact' in the async tier"
        )
        # Observability: events are stamped on the VIRTUAL clock so two
        # same-seed runs export byte-identical traces (DESIGN.md §12).
        if tracer is not None:
            assert tracer.clock == "virtual" or not tracer.enabled, (
                "AsyncSimulator needs a virtual-clock tracer"
            )
        self.tracer = obstrace.NOOP if tracer is None else tracer
        self.eng = engine
        self.cfg = cfg
        self.weights = jnp.asarray(weights, jnp.float32)
        self.participants_fn = participants_fn
        self.batch_fn = batch_fn
        self._cohort = jax.jit(self._cohort_client_side)
        self._flush_cache: dict = {}   # (b, has_ef) -> jitted flush body

    def _cohort_client_side(self, clients, batches, idx, v, ef, rnd):
        """The whole client side of a dispatch, ONE jitted program:
        cohort_update plus sign-quantization (EF-corrected when enabled).

        EF is applied at DISPATCH, not at flush: a client has at most one
        job in flight (version-gated dispatch), so its residual cannot be
        written between dispatch and the flush that delivers its vote —
        quantizing early is semantically identical to quantizing at upload
        time. It is also what makes EF parity BIT-exact: the EF chain
        (and the sketch feeding it) must live in the same program as the
        local update, the way the synchronous round compiles it — split
        across programs, XLA's compilation of the alpha mean drifts a ulp
        (see tests/test_async_sim.py::test_parity_*). The flush then only
        performs exact operations: index scatters and the sign vote.

        `rnd` is the dispatch (download) version: Byzantine corruption
        inside cohort_update and the RR uplink flips are both keyed by
        (seed, rnd, client id), so the zero-latency drain injects exactly
        what the synchronous round counter would (tests/test_robust.py)."""
        upd, task_loss, zs = self.eng.cohort_update(
            clients, batches, idx, v, rnd
        )
        if ef is None:
            signs = jnp.sign(zs) + (zs == 0)                   # {-1,+1}
            new_rows = None
        else:
            _, signs, new_rows = self.eng._ef_quantize(zs, ef[idx])
        wire = self.eng.privatize_uplink(signs, idx, rnd)
        # per-row RR flip counts for the obs registry — computed inside the
        # same program unconditionally (tracer-independent, so enabling the
        # tracer never changes this jaxpr), None when privacy is off
        flips = (
            jnp.sum((wire != signs).astype(jnp.int32), axis=1)
            if self.eng.cfg.privacy is not None else None
        )
        return upd, task_loss, zs, wire, new_rows, flips

    # -- jitted flush bodies (cached per ragged buffer size) -----------------

    def _flush_fn(self, b: int, has_ef: bool):
        # per-instance cache (an lru_cache on the method would key on self
        # and retain dead simulators at class level)
        key = (b, has_ef)
        if key in self._flush_cache:
            return self._flush_cache[key]
        eng, cfg = self.eng, self.cfg

        def flush(clients, ef, rep, signs, ids, tau, w_base, params_rows,
                  ef_rows):
            stale = consensus.staleness_weights(tau, cfg.staleness_exponent)
            w = w_base * stale
            if has_ef:
                ef = ef.at[ids].set(ef_rows)
            if cfg.vote == "packed":
                # ragged wire vote at the STATIC buffer capacity: a drain
                # flush with b < B pads its packed words up to B rows and
                # masks them out, so the vote kernel always sees one shape
                words = eng._pack_uplink(signs)          # (b, nw) wire words
                cap = max(cfg.buffer_size, b)
                valid = jnp.pad(jnp.ones((b,), jnp.float32), (0, cap - b))
                vw = kops.vote_packed_ragged(
                    jnp.pad(words, ((0, cap - b), (0, 0))),
                    jnp.pad(w, (0, cap - b)),
                    valid,
                )
                v_new = kops.unpack_signs(vw)[: eng.m]
                rep_new = rep
            else:
                # defense dispatch + RR debias; defense="none"/no privacy
                # reduces to vote_scattered exactly (the parity path)
                v_new, rep_new = eng.vote_defended(signs, ids, w, rep)
            clients = rounds.scatter_rows(
                clients, ids, params_rows, jnp.ones((b,), jnp.float32)
            )
            return clients, v_new, ef, rep_new, w

        self._flush_cache[key] = jax.jit(flush)
        return self._flush_cache[key]

    # -- the event loop ------------------------------------------------------

    def run(self, state, on_flush: Callable | None = None):
        """Drain the event queue for cfg.max_versions flushes starting from
        a synchronous FLState. Returns (final FLState, SimReport).
        on_flush(t, version, state) fires after every consensus bump (eval
        hooks; its cost is outside virtual time)."""
        eng, cfg = self.eng, self.cfg
        tr = self.tracer
        k = eng.cfg.num_clients
        queue = EventQueue()
        roster = Roster(k)
        registry = obsreg.MetricsRegistry(tracer=tr)
        meter = simmetrics.AsyncMeter(m=eng.m, registry=registry)
        report = simmetrics.SimReport(m=eng.m, meter=meter)
        staged: dict[int, dict] = {}
        buffer: list[_Buffered] = []
        version = 0
        t = 0.0
        last_flush_t = 0.0

        def dispatch_cohort(t_now: float, ver: int, st):
            """Draw participants for `ver` over idle clients, run the
            client side against the current consensus, stage the rows,
            and push one arrival event per dispatched client."""
            idx, active = self.participants_fn(ver)
            idx_np = np.asarray(idx)
            act_np = np.asarray(active)
            dispatchable = [
                (row, int(c))
                for row, (c, a) in enumerate(zip(idx_np, act_np))
                if a > 0 and roster.idle(int(c))
            ]   # others: dropped out / still chewing their last job
            if not dispatchable:
                return   # nobody to run — skip the cohort program entirely
            batches = self.batch_fn(ver)
            upd, task_loss, _zs, signs, ef_rows, flips = self._cohort(
                st.clients, batches, idx, st.v, st.ef, jnp.int32(ver)
            )
            # the pre-EF sketches are not staged: no flush reads them, and
            # a straggler cohort can stay staged for many versions
            entry = {"upd": upd, "task_loss": task_loss,
                     "signs": signs, "ef_rows": ef_rows, "flips": flips,
                     "refs": len(dispatchable)}
            tr.instant("dispatch", t=t_now, track="server", version=ver,
                       clients=len(dispatchable))
            for row, c in dispatchable:
                roster.dispatch(c, ver)
                delay = cfg.latency.duration(cfg.seed, c, ver)
                queue.push(t_now + delay, "arrival", c, payload=(ver, row))
            staged[ver] = entry

        def flush(t_now: float, st):
            nonlocal version, buffer, last_flush_t
            b = len(buffer)
            has_ef = st.ef is not None
            ids = jnp.asarray([e.client for e in buffer], jnp.int32)
            tau = jnp.asarray(
                [version - e.download_version for e in buffer], jnp.float32
            )
            row_of = lambda name, e: staged[e.staged_version][name][e.row]
            signs = jnp.stack([row_of("signs", e) for e in buffer])
            ef_rows = (
                jnp.stack([row_of("ef_rows", e) for e in buffer])
                if has_ef else None
            )
            params_rows = jax.tree.map(
                lambda *rows: jnp.stack(rows),
                *[
                    jax.tree.map(
                        lambda a, e=e: a[e.row], staged[e.staged_version]["upd"]
                    )
                    for e in buffer
                ],
            )
            tls = jnp.stack([row_of("task_loss", e) for e in buffer])
            w_base = self.weights[ids]
            clients, v_new, ef, rep_new, w = self._flush_fn(b, has_ef)(
                st.clients, st.ef, st.rep, signs, ids, tau, w_base,
                params_rows, ef_rows,
            )
            task = float(jnp.sum(tls * w) / jnp.maximum(jnp.sum(w), 1e-9))
            for e in buffer:   # release staged cohorts once fully delivered
                staged[e.staged_version]["refs"] -= 1
                if staged[e.staged_version]["refs"] == 0:
                    del staged[e.staged_version]
            report.flushes.append(simmetrics.FlushRecord(
                version=version + 1, t=t_now, arrivals=b,
                taus=[int(version - e.download_version) for e in buffer],
                task_loss=task,
            ))
            tr.complete("flush", t0=last_flush_t, t1=t_now, track="server",
                        version=version + 1, arrivals=b)
            last_flush_t = t_now
            registry.add("votes_cast", b, t=t_now)
            if eng.cfg.defense == "trim":
                # trimmed_vote clamps the static trim count to voters-1 at
                # trace time; mirror that clamp in the billed counter
                registry.add(
                    "trimmed_voters", min(eng.trim_count, max(b - 1, 0)),
                    t=t_now,
                )
            if tr.enabled:
                registry.observe("flush_sizes", b, t=t_now)
            buffer = []
            version += 1
            meter.bill_downlink(t_now)
            tr.instant("broadcast", t=t_now, track="server", version=version)
            st = st._replace(
                clients=clients, v=v_new, round=st.round + 1, ef=ef,
                rep=rep_new,
            )
            if tr.enabled and ef is not None:
                # ||EF residual|| series — costs one device sync, so traced
                # runs only
                registry.observe(
                    "ef_residual_norm",
                    float(jnp.sqrt(jnp.sum(jnp.square(ef)))), t=t_now,
                )
            if eng.cfg.defense == "reputation":
                roster.set_reputation(np.asarray(rep_new))
            if on_flush is not None:
                on_flush(t_now, version, st)
            return st

        dispatch_cohort(0.0, 0, state)
        while version < cfg.max_versions:
            if not queue:
                if buffer and cfg.flush_partial_on_drain:
                    state = flush(t, state)      # ragged drain flush
                    if version < cfg.max_versions:
                        dispatch_cohort(t, version, state)
                    continue
                break
            ev = queue.pop()
            t = ev.t
            roster.arrive(ev.client, t)
            meter.bill_uplink(t)
            sv, row = ev.payload
            tr.instant("arrive", t=t, track="server", client=ev.client,
                       version=sv)
            if tr.enabled and staged[sv]["flips"] is not None:
                registry.add("rr_flips", int(staged[sv]["flips"][row]), t=t)
            buffer.append(_Buffered(
                client=ev.client,
                download_version=sv,
                staged_version=sv, row=row, t=t,
            ))
            if len(buffer) >= cfg.buffer_size:
                state = flush(t, state)
                if version < cfg.max_versions:
                    dispatch_cohort(t, version, state)
        report.residual_arrivals = len(buffer)
        if eng.cfg.defense == "reputation":
            report.final_reputation = [
                float(x) for x in np.asarray(state.rep)
            ]
        report.check_billing()
        return state, report
