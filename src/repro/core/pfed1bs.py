"""pFed1BS — Algorithm 1 of the paper, model-agnostic and fully jitted.

Round t:
  1. Each participating client runs R local SGD steps on the smoothed
     objective F~_k(w; v^t) = f_k(w) + lam*(h_gamma(Phi w) - <v, Phi w>)
     + (mu/2)||w||^2 (Eq. 6); gradient per Eq. 11 — the sketch carries a
     custom VJP so every local step pays one fused forward + one fused
     adjoint instead of autodiff transposing the sketch trace.
  2. Each client uploads the one-bit sketch z_k = sign(Phi w_k^{t+1})
     (bit-packed: m bits on the wire).
  3. Server aggregates v^{t+1} = sign(sum_{k in S} p_k z_k) (Lemma 1) and
     broadcasts the m-bit consensus.

Hot-path layout (DESIGN.md §4): the round gathers the S sampled clients,
runs the vmapped local update on those S only, and scatters the results
back — non-sampled clients never pay local SGD. Each sampled client is
sketched exactly once per round; that sketch feeds the uplink signs, the
majority vote, the sign-agreement metric AND the potential Psi^t (the
staged seed path updated and sketched all K clients and re-sketched every
one of them inside the potential). The seed round is preserved behind
`PFed1BSConfig(fused_round=False)` for benchmarking
(benchmarks/sketch_bench.py) and parity tests.

Executors (DESIGN.md §6): with `sharded_round=True` the round runs through
the shard_map federation executor (launch/fedexec.py): sampled clients are
laid out along a 1-D `fed` mesh axis and the federation axis is crossed
only by packed uint32 sign words (uplink) and the broadcast consensus
(downlink). On a 1-device mesh at full participation the executor is
bit-exact with the fused round (tests/test_fedexec.py).

Sketch layouts: `layout="flat"` is the paper-literal global ravel of the
client pytree into w in R^n; `layout="leaf"` routes through
core/treesketch.py — every leaf gets its own block-diagonal SRHT (no
global ravel, so a sharded model never all-gathers its parameters just to
be sketched). The two layouts are different (equally valid) sketch
operators; see tests/test_treesketch.py for the parity contract.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import consensus, flatten, regularizer, rounds
from repro.core import sketch as sk
from repro.core import subset as sub_sel
from repro.core import treesketch as ts
from repro.kernels import ops as kops
from repro.obs import trace as obstrace


@dataclasses.dataclass(frozen=True)
class PFed1BSConfig:
    num_clients: int
    participate: int               # S <= K clients per round
    local_steps: int = 5           # R
    lr: float = 0.05               # eta
    lam: float = 5e-4              # lambda (sign-alignment strength)
    mu: float = 1e-5               # l2 penalty
    gamma: float = 1e4             # log-cosh smoothing
    m_ratio: float = 0.1           # m/n compression ratio
    chunk: int = 4096              # sketch block size (see DESIGN.md §3.2)
    sketch_seed: int = 0
    sketch_mode: str = "auto"      # global (paper-exact) | chunked | auto
    fused_round: bool = True       # gather/scatter round with one sketch per
    #                                client per round (DESIGN.md §4); False
    #                                reproduces the seed's all-K staged round.
    # --- round executor (DESIGN.md §6) ---
    sharded_round: bool = False    # run the round through the shard_map
    #                                executor (launch/fedexec.py): clients on
    #                                a `fed` mesh axis, packed-bits wire path.
    fed_shards: int = 1            # size of the `fed` mesh axis (must divide
    #                                `participate`; needs that many devices).
    layout: str = "flat"           # "flat": sketch the global ravel (paper-
    #                                literal); "leaf": per-leaf block-diagonal
    #                                SRHT via core/treesketch.py (no global
    #                                ravel — collective-free on sharded models).
    trainable: Any = None          # LoRA-style trainable subset: tuple of
    #                                path-substring patterns resolved against
    #                                the template's keystr leaf paths
    #                                (core/subset.py). Requires layout="leaf".
    #                                Local SGD, the sketch, the vote and the
    #                                bit bill all restrict to the selected
    #                                leaves; frozen leaves never change
    #                                (DESIGN.md §13).
    vote: str = "exact"            # "exact": server unpacks the wire words and
    #                                votes sign(sum p_k z_k) (Lemma 1, ties->0,
    #                                bit-exact vs the fused round); "popcount":
    #                                word-level integer majority (uniform p_k,
    #                                ties->+1, never unpacks — DESIGN.md §6.2).
    diagnostics: bool = True       # compute potential/sign-agreement metrics.
    #                                False + no EF lets the sharded executor
    #                                emit uplink words straight from the packed
    #                                kernel epilogue (float sketches never hit
    #                                HBM) — the production wire path.
    # --- beyond-paper extension ---
    error_feedback: bool = False   # EF residual on the one-bit sketch:
    #                                z_k = sign(Phi w_k + e_k),
    #                                e_k += Phi w_k - alpha_k z_k with the
    #                                l1-optimal scale alpha_k = mean|Phi w + e|.
    #                                Recovers accuracy at aggressive m/n.
    # --- robustness / privacy axes (DESIGN.md §10) ---
    adversary: Any = None          # duck-typed Byzantine model with
    #                                .corrupt(zs, idx, rnd, num_clients) —
    #                                exp/scenarios.py SignFlipAttack /
    #                                ColludingBloc / ScaledGarbage (frozen,
    #                                hashable); corruption is injected on the
    #                                cohort sketches post-encode, pre-vote via
    #                                core/rounds.corrupt_cohort in EVERY
    #                                executor (fused, sharded, async).
    privacy: Any = None            # duck-typed uplink privatizer with
    #                                .flip(signs, idx, rnd) and .debias() —
    #                                exp/scenarios.py RandomizedResponse;
    #                                flips wire sign bits per (round, client),
    #                                billing unchanged (one bit is one bit).
    topology: Any = None           # hierarchical aggregation tree
    #                                (launch/fedexec.py::HierTopology, duck-
    #                                typed like adversary): leaf shards emit
    #                                partial popcount counters, tiers merge,
    #                                the root finishes — bit-exact with the
    #                                flat popcount vote (DESIGN.md §11).
    #                                Requires sharded_round + vote="popcount";
    #                                leaf sizes must sum to `participate`.
    defense: str = "none"          # "none" | "trim" (drop the trim_frac * S
    #                                most-disagreeing voters per vote) |
    #                                "reputation" (per-client EMA of sign-
    #                                agreement multiplies the vote weights;
    #                                carried as FLState.rep, requires
    #                                vote="exact").
    trim_frac: float = 0.2         # fraction of the cohort the trimmed vote
    #                                drops (static count: round(frac * S)).
    rep_beta: float = 0.25         # reputation EMA step toward this round's
    #                                sign agreement.


class FLState(NamedTuple):
    clients: Any                   # stacked params, leading axis K
    v: jax.Array                   # (m,) consensus in {-1,0,+1}
    round: jax.Array               # scalar int32
    ef: Any = None                 # (K, m) EF residuals when enabled
    rep: Any = None                # (K,) reputation EMA (defense="reputation")


class PFed1BS:
    """Engine binding Algorithm 1 to a task (loss over params+batch).

    Public surface:
      __init__(cfg, loss_fn, params_template, mesh=None) — `params_template`
        is a pytree of arrays/ShapeDtypeStructs defining the per-client model;
        `mesh` optionally overrides the executor's `fed` mesh (a 1-D mesh with
        a "fed" axis; default: launch.mesh.make_fed_mesh(cfg.fed_shards)).
      init(init_params_fn, key) -> FLState — stacked client params (leading
        axis K), consensus v^0 = 0 in float32 (m,), EF residuals (K, m) when
        enabled.
      round(state, batches, weights, key) -> (state', metrics) — one
        communication round (Alg. 1). batches: (K, R, B, ...) pytree;
        weights: (K,) float p_k. Dispatches to the shard_map executor
        (cfg.sharded_round), the fused gather/scatter round (cfg.fused_round,
        default) or the seed staged round.

    `self.m` is the sketch dimension actually produced (uplink bits per
    client); `self.spec` is the flat SketchSpec (None under layout="leaf",
    where `self.tspec` is the TreeSketchSpec instead).
    """

    def __init__(self, cfg: PFed1BSConfig, loss_fn: Callable, params_template,
                 mesh=None, tracer=None, major_axes=None):
        assert cfg.layout in ("flat", "leaf"), cfg.layout
        assert cfg.vote in ("exact", "popcount"), cfg.vote
        assert cfg.defense in ("none", "trim", "reputation"), cfg.defense
        if cfg.defense == "reputation":
            # the popcount vote is weightless — reputation has nowhere to act
            assert cfg.vote == "exact", "defense='reputation' needs vote='exact'"
        if cfg.topology is not None:
            # the counter tree is the popcount vote split at the leaf/root
            # boundary — it has no float-weighted form (DESIGN.md §11)
            assert cfg.sharded_round, "topology needs sharded_round=True"
            assert cfg.vote == "popcount", "topology needs vote='popcount'"
            assert cfg.topology.num_clients == cfg.participate, (
                f"topology covers {cfg.topology.num_clients} clients, "
                f"round samples {cfg.participate}"
            )
        self.cfg = cfg
        # Observability (DESIGN.md §12). The tracer is deliberately NOT part
        # of the jit cache key: `_round_jit` takes `self` as a static arg
        # hashed by identity, and swapping `self.tracer` mutates the same
        # engine — enabling tracing never recompiles a round.
        self.tracer = obstrace.NOOP if tracer is None else tracer
        self.loss_fn = loss_fn     # loss_fn(params, batch) -> scalar
        self.n = flatten.tree_size(params_template)
        # LoRA-style trainable subset (DESIGN.md §13): resolve the path
        # patterns against the template once; the filtered tspec keeps the
        # full-template per-leaf seeds, so trainable=None and trainable=
        # <every path> build the identical operator.
        self.trainable_paths = None
        if cfg.trainable is not None:
            assert cfg.layout == "leaf", "cfg.trainable requires layout='leaf'"
            self.trainable_paths = sub_sel.match_paths(
                params_template, cfg.trainable
            )
        if cfg.layout == "leaf":
            self.spec = None
            self.tspec = ts.make_tree_sketch_spec(
                params_template, cfg.m_ratio, chunk=cfg.chunk,
                seed=cfg.sketch_seed, major_axes=major_axes,
                paths=self.trainable_paths,
            )
            self.m = self.tspec.m
        else:
            self.spec = sk.make_sketch_spec(
                self.n, cfg.m_ratio, chunk=cfg.chunk, seed=cfg.sketch_seed,
                mode=cfg.sketch_mode,
            )
            self.tspec = None
            self.m = self.spec.m
        # bits are billed at the trainable count (fl/comms.subset_round_bits)
        self.n_trainable = (
            self.tspec.n if self.trainable_paths is not None else self.n
        )
        self.fed_mesh = None
        if cfg.sharded_round:
            assert cfg.participate % cfg.fed_shards == 0, (
                f"participate={cfg.participate} must divide evenly over "
                f"fed_shards={cfg.fed_shards}"
            )
            if mesh is None:
                from repro.launch.mesh import make_fed_mesh

                mesh = make_fed_mesh(cfg.fed_shards)
            assert mesh.shape.get("fed") == cfg.fed_shards, mesh.shape
            self.fed_mesh = mesh

    # -- lifecycle -----------------------------------------------------------

    def init(self, init_params_fn: Callable, key) -> FLState:
        keys = jax.random.split(key, self.cfg.num_clients)
        clients = jax.vmap(init_params_fn)(keys)
        ef = (
            jnp.zeros((self.cfg.num_clients, self.m), jnp.float32)
            if self.cfg.error_feedback
            else None
        )
        rep = (
            jnp.ones((self.cfg.num_clients,), jnp.float32)
            if self.cfg.defense == "reputation"
            else None
        )
        return FLState(
            clients=clients,
            v=jnp.zeros((self.m,), jnp.float32),        # v^0 = 0 (Alg. 1)
            round=jnp.int32(0),
            ef=ef,
            rep=rep,
        )

    # -- client side ---------------------------------------------------------

    def _client_update(self, params, batches, v):
        """R local SGD steps on the smoothed objective F~_k (Eq. 6).

        params: one client's pytree; batches: (R, B, ...) pytree; v: (m,)
        consensus. Gradient per Eq. 11 — the sketch's custom VJP makes each
        step one fused forward + one fused adjoint. Returns (params', mean
        task loss over the R steps).
        """
        cfg = self.cfg
        if self.trainable_paths is not None:
            return self._client_update_subset(params, batches, v)

        def objective(p, batch):
            task = self.loss_fn(p, batch)
            z = self._sketch_client(p)
            reg = regularizer.smoothed_reg(v, z, cfg.gamma)
            if cfg.layout == "leaf":
                # no global ravel: the l2 term sums per leaf (same value)
                l2 = 0.5 * sum(
                    jnp.sum(jnp.square(l.astype(jnp.float32)))
                    for l in jax.tree.leaves(p)
                )
            else:
                w = flatten.ravel(p)
                l2 = 0.5 * jnp.sum(w * w)
            return task + cfg.lam * reg + cfg.mu * l2, task

        def step(p, batch):
            (_, task), grads = jax.value_and_grad(objective, has_aux=True)(p, batch)
            p = jax.tree.map(lambda a, g: a - cfg.lr * g.astype(a.dtype), p, grads)
            return p, task

        params, task_losses = jax.lax.scan(step, params, batches)
        return params, jnp.mean(task_losses)

    def _client_update_subset(self, params, batches, v):
        """The cfg.trainable variant of `_client_update`: R local SGD steps
        over ONLY the selected leaves. The scan carries the {path: leaf}
        subset dict (a valid pytree); the frozen remainder of `params` is
        closed over, so frozen leaves are literally never written. The
        sketch/regularizer see the path-filtered tspec — exactly the blocks
        the full operator would have assigned those leaves — and the l2
        term covers the trainable subset only (the frozen leaves' l2 is a
        constant with zero gradient; billing it would skew Psi across
        subset sizes)."""
        cfg = self.cfg
        sub0 = sub_sel.extract(params, self.trainable_paths)

        def objective(sub, batch):
            p = sub_sel.merge(params, sub)
            task = self.loss_fn(p, batch)
            z = self._sketch_client(sub)
            reg = regularizer.smoothed_reg(v, z, cfg.gamma)
            l2 = 0.5 * sum(
                jnp.sum(jnp.square(l.astype(jnp.float32)))
                for l in sub.values()
            )
            return task + cfg.lam * reg + cfg.mu * l2, task

        def step(sub, batch):
            (_, task), grads = jax.value_and_grad(objective, has_aux=True)(sub, batch)
            sub = jax.tree.map(lambda a, g: a - cfg.lr * g.astype(a.dtype), sub, grads)
            return sub, task

        sub, task_losses = jax.lax.scan(step, sub0, batches)
        return sub_sel.merge(params, sub), jnp.mean(task_losses)

    def _sketch_client(self, params):
        """z = Phi w_k for one client: (m,) float32. layout="flat" sketches
        the global ravel (Eq. 15-18); layout="leaf" concatenates the
        per-leaf block-diagonal sketches (treesketch leaf order)."""
        if self.cfg.layout == "leaf":
            return ts.flat_view(
                self.tspec, ts.tree_sketch_forward(self.tspec, params)
            )
        return sk.sketch_forward(self.spec, flatten.ravel(params))

    def _sketch_client_packed(self, params):
        """One client's uplink wire words: (ceil(m/32),) uint32, bit = z >= 0.

        When the flat chunked spec supports it (m_chunk % 32 == 0), the words
        come straight from the fused kernel's pack epilogue — the float
        sketch never round-trips HBM. Otherwise: float sketch, sign, pack
        (identical bits either way; tests/test_srht_fused.py pins that)."""
        if (
            self.cfg.layout == "flat"
            and self.spec.mode != "global"
            and self.spec.m_chunk % 32 == 0
        ):
            return sk.sketch_forward_packed(
                self.spec, flatten.ravel(params)
            ).reshape(-1)
        z = self._sketch_client(params)
        return self._pack_uplink(jnp.sign(z) + (z == 0))

    def _pack_uplink(self, signs):
        """Pack {-1,+1} signs into the uplink wire words, zero-padding the
        last axis up to a 32-bit word boundary (pad bits pack as +1).
        (..., m) float -> (..., ceil(m/32)) uint32."""
        pad = (-self.m) % 32
        widths = [(0, 0)] * (signs.ndim - 1) + [(0, pad)]
        return kops.pack_signs(jnp.pad(signs, widths))

    def _ef_quantize(self, zs, ef):
        """EF sign-quantization (the config's error_feedback formulas):
        corrected = Phi w + e; z = sign(corrected); e' = corrected -
        alpha * z with the l1-optimal alpha = mean|corrected| per client.
        zs, ef: (rows, m) float32 -> (corrected, signs, new_ef) same shape.
        Single source of truth for every round executor AND the async
        tier's dispatch (repro/sim/server.py).

        BIT-EXACTNESS CONSTRAINT: this chain (in particular the alpha mean
        reduction) must be compiled in the SAME program as the cohort
        update + sketch that produced `zs` — XLA compiles the reduction a
        ulp apart when `zs` instead enters as a program argument, even
        behind optimization_barriers. That is why the async tier quantizes
        at dispatch (one program with the cohort, like the sync round)
        rather than at flush; see sim/server.py::_cohort_client_side and
        tests/test_async_sim.py."""
        corrected = zs + ef
        signs = jnp.sign(corrected) + (corrected == 0)
        alpha = jnp.mean(jnp.abs(corrected), axis=1, keepdims=True)
        return corrected, signs, corrected - alpha * signs

    # -- one communication round ----------------------------------------------

    def _draw_participants(self, key, participants):
        """core/rounds.py straggler semantics: active=0 rows keep their
        params, cast no vote, transmit no bits."""
        return rounds.draw_participants(
            key, self.cfg.num_clients, self.cfg.participate, participants
        )

    # -- cohort primitives (shared by the fused round AND the async tier) ------

    def cohort_update(self, clients, batches, idx, v, rnd=None):
        """Gather the `idx` cohort and run the vmapped local update against
        consensus `v`, sketching each updated client exactly once.

        clients/batches: stacked (K, ...) pytrees; idx: (S,) distinct client
        ids; v: (m,) consensus; rnd: the round/version counter (traced int32)
        keying Byzantine corruption when cfg.adversary is set. Returns (upd
        (S,...) pytree, task_loss (S,), zs (S, m) pre-EF sketches — already
        CORRUPTED under an adversary: the attack replaces what the client
        TRANSMITS, never its local model, so `upd` is always honest). This
        is THE client-side computation of the fused round; the async
        simulator (repro/sim) dispatches cohorts through this same method so
        a zero-latency drain is bit-exact with the synchronous round
        (tests/test_async_sim.py), adversary included (tests/test_robust.py).
        """
        take = lambda tree: jax.tree.map(lambda a: a[idx], tree)
        upd, task_loss = jax.vmap(
            lambda p, b: self._client_update(p, b, v)
        )(take(clients), take(batches))
        zs = jax.vmap(self._sketch_client)(upd)                # (S, m)
        zs = rounds.corrupt_cohort(
            self.cfg.adversary, zs, idx, rnd, self.cfg.num_clients
        )
        return upd, task_loss, zs

    def privatize_uplink(self, signs, idx, rnd):
        """Randomized-response flips on the wire signs (cfg.privacy; identity
        when None). Applied AFTER EF quantization: the client's residual uses
        its true signs — the flip happens at transmission."""
        return rounds.privatize_signs(self.cfg.privacy, signs, idx, rnd)

    def vote_defended(self, signs, idx, w_s, rep):
        """The defense-dispatched Lemma-1 vote, shared by the fused round,
        the sharded executor's exact vote and the async flush: scatter the
        cohort into natural client order (vote_scattered's permutation-
        stability argument), fold the RR debias factor into the weights
        (rounds.rr_debias — a sign vote is invariant to the uniform scaling,
        but the weighted sum becomes an unbiased estimator of the
        non-private one), then vote per cfg.defense. Returns (v, rep') with
        rep' == rep unless defense="reputation"."""
        cfg = self.cfg
        if cfg.privacy is not None:
            w_s = w_s * cfg.privacy.debias()
        if cfg.defense == "none":
            return self.vote_scattered(signs, idx, w_s), rep
        k = cfg.num_clients
        signs_full = jnp.zeros((k, self.m), jnp.float32).at[idx].set(signs)
        w_full = jnp.zeros((k,), jnp.float32).at[idx].set(w_s)
        if cfg.defense == "trim":
            v, _ = consensus.trimmed_vote(signs_full, w_full, self.trim_count)
            return v, rep
        v, rep_new = consensus.reputation_vote(
            signs_full, w_full, rep, cfg.rep_beta
        )
        return v, rep_new

    @property
    def trim_count(self) -> int:
        """Static voters-to-drop of the trimmed vote: round(trim_frac * S).
        consensus.trimmed_vote further clamps to voters-1 at trace time, so
        a part-full async buffer is never trimmed empty."""
        return max(0, int(round(self.cfg.trim_frac * self.cfg.participate)))

    def vote_scattered(self, signs, idx, w_s):
        """Lemma 1 vote over a cohort, accumulated in NATURAL client order:
        the (S, m) sign rows and (S,) masked weights are scattered into
        zero-initialized (K, m)/(K,) buffers before the weighted sign-sum,
        so float accumulation order never depends on the sampling
        permutation (see the §4 note — permutation-order sums can flip
        near-zero consensus signs). Shared by the fused round, the sharded
        executor's exact vote, and the async tier's buffer flush."""
        k = self.cfg.num_clients
        signs_full = jnp.zeros((k, self.m), jnp.float32).at[idx].set(signs)
        w_full = jnp.zeros((k,), jnp.float32).at[idx].set(w_s)
        return consensus.majority_vote(signs_full, w_full)

    def round(self, state: FLState, batches, weights, key, participants=None):
        """One round of Algorithm 1: batches (K, R, B, ...) pytree, weights
        (K,) p_k, optional externally drawn participants (idx, active).
        Returns (state', metrics). Executor dispatch order: sharded_round
        (shard_map, DESIGN.md §6) > fused_round (§4) > staged seed round.

        Thin wrapper over the jitted `_round_jit`: with the tracer disabled
        (the default) it is a tail call — no sync, no span, the dispatch is
        one attribute check. With a wall-clock tracer bound, the round is
        wrapped in a "round" span and blocked to completion so the span
        measures execution, not dispatch (same convention as us_per_round).
        """
        tr = self.tracer
        if not tr.enabled or tr.clock != "wall":
            return self._round_jit(state, batches, weights, key, participants)
        cfg = self.cfg
        executor = (
            ("hier" if cfg.topology is not None else "sharded")
            if cfg.sharded_round
            else ("fused" if cfg.fused_round else "staged")
        )
        with tr.span("round", track="engine", executor=executor,
                     layout=cfg.layout, m=self.m):
            out = self._round_jit(state, batches, weights, key, participants)
            jax.block_until_ready(out)
        return out

    @functools.partial(jax.jit, static_argnums=0)
    def _round_jit(self, state: FLState, batches, weights, key,
                   participants=None):
        """The jitted round body behind `round` (executor dispatch)."""
        if self.cfg.sharded_round:
            from repro.launch import fedexec  # trace-time import; no cycle

            if self.cfg.topology is not None:
                return fedexec.hier_round(
                    self, state, batches, weights, key, participants
                )
            return fedexec.sharded_round(
                self, state, batches, weights, key, participants
            )
        if self.cfg.fused_round:
            return self._round_fused(state, batches, weights, key, participants)
        return self._round_staged(state, batches, weights, key, participants)

    def _round_fused(self, state: FLState, batches, weights, key,
                     participants=None):
        """Gather sampled clients -> vmapped update -> scatter; one sketch
        per sampled client per round, threaded through vote, metrics and
        Psi (on the pre-EF sketches, matching Eq. 28)."""
        cfg = self.cfg

        # partial participation: sample S clients without replacement
        idx, active = self._draw_participants(key, participants)

        # gather -> vmapped update -> one sketch per sampled client
        # (cohort_update; non-sampled clients never pay local SGD and their
        # unchanged sketches are never recomputed). Byzantine corruption (if
        # any) lands inside cohort_update, keyed by the round counter.
        upd, task_loss, zs = self.cohort_update(
            state.clients, batches, idx, state.v, state.round
        )

        # scatter updated models back; non-sampled AND inactive (dropped-out)
        # clients keep theirs
        clients = rounds.scatter_rows(state.clients, idx, upd, active)

        zs_phi = zs            # pre-EF sketches Phi w (the Eq. 28 potential)
        new_ef = state.ef
        if cfg.error_feedback:
            # Only clients that actually transmit flush their residuals.
            zs, signs, ef_rows = self._ef_quantize(zs, state.ef[idx])
            ef_rows = jnp.where(active[:, None] > 0, ef_rows, state.ef[idx])
            new_ef = state.ef.at[idx].set(ef_rows)
        else:
            signs = jnp.sign(zs) + (zs == 0)                   # {-1,+1}
        wire = self.privatize_uplink(signs, idx, state.round)
        packed = self._pack_uplink(wire)

        # server: weighted majority vote over the sampled clients (Lemma 1),
        # accumulated in natural client order and routed through the
        # configured defense (vote_defended == vote_scattered when
        # defense="none" and privacy=None — identical program). Dropped-out
        # rows (active=0) cast no vote.
        w_s = weights[idx] * active
        v_new, new_rep = self.vote_defended(wire, idx, w_s, state.rep)

        potential = self._potential_from_sketches(
            upd, zs_phi, v_new, task_loss, w_s
        )
        w_norm = jnp.maximum(jnp.sum(w_s), 1e-9)
        metrics = {
            "task_loss": jnp.sum(task_loss * w_s) / w_norm,
            "potential": potential,
            "uplink_bits": jnp.sum(active) * self.m,
            "downlink_bits": jnp.float32(self.m),
            "sign_agreement": jnp.mean((zs * v_new[None, :] > 0).astype(jnp.float32)),
            "packed_words": jnp.float32(packed.shape[-1]),
            # per-coordinate |weighted vote sum| / total weight in [0, 1]:
            # how far each consensus coordinate sat from a coin flip this
            # round, computed on the PRIVATIZED wire signs the server
            # actually tallied — the health monitor (obs/health.py)
            # sketches the distribution
            "vote_margins": jnp.abs(jnp.einsum("s,sm->m", w_s, wire)) / w_norm,
        }
        if cfg.privacy is not None:
            # sign bits the RR privatizer actually flipped on transmitting
            # (active) rows — the obs registry's rr_flips counter
            metrics["rr_flips"] = jnp.sum(
                (wire != signs).astype(jnp.float32) * active[:, None]
            )
        if cfg.error_feedback:
            metrics["ef_residual_norm"] = jnp.sqrt(
                jnp.sum(jnp.square(new_ef))
            )
        if cfg.defense == "reputation":
            metrics["rep_min"] = jnp.min(new_rep)
            metrics["rep_mean"] = jnp.mean(new_rep)
        return (
            FLState(clients=clients, v=v_new, round=state.round + 1,
                    ef=new_ef, rep=new_rep),
            metrics,
        )

    def _potential_from_sketches(self, clients, zs, v, task_loss, weights):
        """Psi^t = sum_k p_k F~_k(w_k; v) (Eq. 28) over the sampled clients
        (importance-normalized; exact at full participation), with f_k
        estimated by the round's minibatch losses and the regularizer
        evaluated on the already-computed sketches — no re-sketching."""
        cfg = self.cfg

        def fk(params, z, task):
            if self.trainable_paths is not None:
                # subset semantics (§13): Psi's l2 matches the objective —
                # trainable leaves only. Existing layouts keep the ravel.
                l2 = sum(
                    jnp.sum(jnp.square(l.astype(jnp.float32)))
                    for l in sub_sel.extract(params, self.trainable_paths).values()
                )
            else:
                w = flatten.ravel(params)
                l2 = jnp.sum(w * w)
            return (
                task
                + cfg.lam * regularizer.smoothed_reg(v, z, cfg.gamma)
                + 0.5 * cfg.mu * l2
            )

        vals = jax.vmap(fk)(clients, zs, task_loss)
        return jnp.sum(weights * vals) / jnp.maximum(jnp.sum(weights), 1e-9)

    # -- seed round (kept for parity tests + before/after benchmarking) -------

    def _round_staged(self, state: FLState, batches, weights, key,
                      participants=None):
        """The seed hot path: update all K clients then mask, re-sketch in
        the potential. Quadratically wasteful at S << K; see DESIGN.md §4."""
        cfg = self.cfg
        k = cfg.num_clients

        idx, active = self._draw_participants(key, participants)
        mask = jnp.zeros((k,), jnp.float32).at[idx].set(active)

        new_clients, task_loss = jax.vmap(
            lambda p, b: self._client_update(p, b, state.v)
        )(state.clients, batches)

        def keep(new, old):
            m = mask.reshape((k,) + (1,) * (new.ndim - 1))
            return jnp.where(m > 0, new, old)

        clients = jax.tree.map(keep, new_clients, state.clients)

        zs = jax.vmap(self._sketch_client)(clients)            # (K, m)
        # adversary/privacy over ALL K rows, keyed by client id — the same
        # per-client values the fused round computes for its cohort; rows
        # outside the cohort are masked out of the vote anyway
        all_ids = jnp.arange(k, dtype=jnp.int32)
        zs = rounds.corrupt_cohort(
            cfg.adversary, zs, all_ids, state.round, k
        )
        new_ef = state.ef
        if cfg.error_feedback:
            corrected, _, updated = self._ef_quantize(zs, state.ef)
            new_ef = jnp.where(mask[:, None] > 0, updated, state.ef)
            zs = jnp.where(mask[:, None] > 0, corrected, zs)
        signs = jnp.sign(zs) + (zs == 0)                       # {-1,+1}
        wire = self.privatize_uplink(signs, all_ids, state.round)
        packed = self._pack_uplink(wire)

        pw = weights * mask
        if cfg.defense == "none" and cfg.privacy is None:
            v_new, new_rep = consensus.majority_vote(wire, pw), state.rep
        else:
            v_new, new_rep = self.vote_defended(wire, all_ids, pw, state.rep)

        potential = self._potential(clients, v_new, task_loss, weights)
        metrics = {
            "task_loss": jnp.sum(task_loss * weights * mask) / jnp.maximum(jnp.sum(weights * mask), 1e-9),
            "potential": potential,
            "uplink_bits": jnp.sum(active) * self.m,
            "downlink_bits": jnp.float32(self.m),
            "sign_agreement": jnp.mean((zs * v_new[None, :] > 0).astype(jnp.float32)),
            "packed_words": jnp.float32(packed.shape[-1]),
            "vote_margins": jnp.abs(jnp.einsum("s,sm->m", pw, wire))
            / jnp.maximum(jnp.sum(pw), 1e-9),
        }
        if cfg.privacy is not None:
            metrics["rr_flips"] = jnp.sum(
                (wire != signs).astype(jnp.float32) * mask[:, None]
            )
        if cfg.error_feedback:
            metrics["ef_residual_norm"] = jnp.sqrt(
                jnp.sum(jnp.square(new_ef))
            )
        return (
            FLState(clients=clients, v=v_new, round=state.round + 1,
                    ef=new_ef, rep=new_rep),
            metrics,
        )

    def _potential(self, clients, v, task_loss, weights):
        """Seed potential: re-sketches every client from scratch."""
        cfg = self.cfg

        def fk(params, task):
            w = flatten.ravel(params)
            z = self._sketch_client(params)  # layout-aware (flat or leaf)
            return (
                task
                + cfg.lam * regularizer.smoothed_reg(v, z, cfg.gamma)
                + 0.5 * cfg.mu * jnp.sum(w * w)
            )

        vals = jax.vmap(fk)(clients, task_loss)
        return jnp.sum(weights * vals)

    # -- evaluation ------------------------------------------------------------

    def eval_clients(self, eval_fn, state: FLState, *args):
        """vmap an eval fn over personalized models."""
        return jax.vmap(lambda p: eval_fn(p, *args))(state.clients)
