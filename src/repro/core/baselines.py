"""Every baseline the paper compares against (Tables 1-2), as jitted
global-model FL rounds with the same interface as PFed1BS.round.

All of them learn ONE global model (no personalization — the paper's point);
they differ in how client updates Delta_k are compressed:

  FedAvg   — full precision both directions (McMahan et al. 2017).
  OBDA     — one-bit symmetric quantization both directions: clients send
             sign(Delta_k), server majority-votes and applies a server-lr
             signed step; downlink is the 1-bit vote (Zhu et al. 2020).
  OBCSAA   — 1-bit compressed-sensing uplink sign(Phi Delta_k) + amplitude
             scalar; server back-projects Phi^T z and rescales; downlink is
             the full-precision model (Fan et al. 2022).
  zSignFed — noisy-perturbed sign compression sign(Delta_k + n_k) with a
             transmitted scale; full-precision downlink (Tang et al. 2024).
  EDEN     — random-rotation (our SRHT rotation) + 1-bit quantization with
             the optimal unbiased scale <r, sign r>/n (Vargaftik et al. 2022).
  FedBAT   — learnable binarization; we use the closed-form optimal
             per-tensor scale alpha* = mean|Delta| with straight-through
             semantics (Li et al. 2024).

Round surface (shared with PFed1BS and the scenario-matrix harness,
DESIGN.md §8): every algorithm is the same three-stage round

    gather S sampled clients -> per-client `_encode` of the local delta
    -> weighted aggregate -> `_finish` server step,

so one jitted `round` serves all six, computes local SGD only for the S
sampled clients (the seed ran all K then masked), and accepts an external
`participants=(idx, active)` draw from exp/scenarios.py participation
models (straggler dropout / availability cycling). OBCSAA's and EDEN's
projections route through the shared SRHT dispatch (core/sketch.py over
kernels/ops — fused Pallas kernels where available): both specs are built
once at engine construction, EDEN's as the square m=n rotation, instead of
private per-trace paths. With `BaselineConfig(sharded_round=True)` the
client side (local steps + encode) runs inside the shard_map federation
executor (launch/fedexec.py::sharded_baseline_round) over the same `fed`
mesh as pFed1BS.

Communication accounting for each is in `repro.fl.comms`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import flatten, rounds
from repro.core import sketch as sk


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    algo: str                      # fedavg|obda|obcsaa|zsignfed|eden|fedbat
    num_clients: int
    participate: int
    local_steps: int = 5
    lr: float = 0.05
    server_lr: float = 0.01        # OBDA signed-step size
    m_ratio: float = 0.1           # OBCSAA sketch ratio
    chunk: int = 4096
    znoise: float = 1e-3           # zSignFed perturbation std
    seed: int = 0
    # --- round executor (DESIGN.md §6/§8) ---
    sharded_round: bool = False    # run the client side (local steps +
    #                                encode) through the shard_map federation
    #                                executor (launch/fedexec.py).
    fed_shards: int = 1            # size of the `fed` mesh axis (must divide
    #                                `participate`; needs that many devices).


class BaselineState(NamedTuple):
    params: Any                    # the single global model
    round: jax.Array


class BaselineFL:
    """Engine binding one baseline to a task (same surface as PFed1BS).

    round(state, batches, weights, key, participants=None): batches is the
    full (K, R, B, ...) pytree, weights (K,) p_k. `participants` is an
    optional externally drawn (idx (S,) int32, active (S,) float32) pair —
    S must equal cfg.participate; active=0 rows trained but transmit
    nothing (straggler semantics: no vote weight, no bits). When omitted
    the engine samples S of K uniformly, all active.
    """

    def __init__(self, cfg: BaselineConfig, loss_fn: Callable, params_template,
                 mesh=None):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.n = flatten.tree_size(params_template)
        self.template = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params_template)
        # shared SRHT dispatch (fused kernels via core/sketch.py -> kernels/ops):
        # OBCSAA's rectangular m = m_ratio*n sketch, EDEN's square m = n rotation.
        self.spec = sk.make_sketch_spec(self.n, cfg.m_ratio, chunk=cfg.chunk, seed=cfg.seed)
        self.rot_spec = (
            sk.make_sketch_spec(self.n, 1.0, chunk=cfg.chunk, seed=cfg.seed)
            if cfg.algo == "eden" else None
        )
        self.fed_mesh = None
        if cfg.sharded_round:
            assert cfg.participate % cfg.fed_shards == 0, (
                f"participate={cfg.participate} must divide evenly over "
                f"fed_shards={cfg.fed_shards}"
            )
            if mesh is None:
                from repro.launch.mesh import make_fed_mesh

                mesh = make_fed_mesh(cfg.fed_shards)
            assert mesh.shape.get("fed") == cfg.fed_shards, mesh.shape
            self.fed_mesh = mesh

    def init(self, init_params_fn: Callable, key) -> BaselineState:
        return BaselineState(params=init_params_fn(key), round=jnp.int32(0))

    def _local_delta(self, params, batches):
        cfg = self.cfg

        def step(p, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
            return jax.tree.map(lambda a, g: a - cfg.lr * g.astype(a.dtype), p, grads), loss

        new, losses = jax.lax.scan(step, params, batches)
        delta = flatten.ravel(new) - flatten.ravel(params)
        return delta, jnp.mean(losses)

    # --- the shared encode -> aggregate -> finish round surface -------------

    def _encode(self, delta, key):
        """One client's compress->decompress round trip: delta (n,) -> the
        reconstruction rec (n,) the server would decode from its uplink.
        Pure per-client (vmappable, shard_map-able); `key` feeds zSignFed's
        perturbation only."""
        algo = self.cfg.algo

        if algo == "fedavg":
            return delta

        if algo == "obda":
            return jnp.sign(delta)          # server applies sign(sum) later

        if algo == "obcsaa":
            z = jnp.sign(sk.sketch_forward(self.spec, delta))
            amp = jnp.linalg.norm(delta)                # transmitted scalar
            back = sk.sketch_adjoint(self.spec, z)
            return amp * back / (jnp.linalg.norm(back) + 1e-9)

        if algo == "zsignfed":
            noisy = delta + self.cfg.znoise * jax.random.normal(key, delta.shape)
            scale = jnp.mean(jnp.abs(delta))            # transmitted scalar
            return scale * jnp.sign(noisy)

        if algo == "eden":
            r = sk.sketch_forward(self.rot_spec, delta)
            scale = jnp.mean(jnp.abs(r))                # EDEN-optimal 1-bit scale
            return sk.sketch_adjoint(self.rot_spec, scale * jnp.sign(r))[: self.n]

        if algo == "fedbat":
            alpha = jnp.mean(jnp.abs(delta))            # closed-form alpha*
            return alpha * jnp.sign(delta)

        raise ValueError(algo)

    def _finish(self, agg, wsum):
        """Server step from the weighted aggregate of encoded updates."""
        if self.cfg.algo == "obda":
            return self.cfg.server_lr * jnp.sign(agg)   # 1-bit downlink step
        return agg / wsum

    @functools.partial(jax.jit, static_argnums=0)
    def round(self, state: BaselineState, batches, weights, key, participants=None):
        cfg = self.cfg
        kperm, kalg = jax.random.split(key)
        idx, active = rounds.draw_participants(
            kperm, cfg.num_clients, cfg.participate, participants
        )

        take = lambda tree: jax.tree.map(lambda a: a[idx], tree)
        pw = weights[idx] * active
        wsum = jnp.maximum(jnp.sum(pw), 1e-9)
        keys = jax.random.split(kalg, cfg.participate)

        if cfg.sharded_round:
            from repro.launch import fedexec  # trace-time import; no cycle

            agg, task_loss = fedexec.sharded_baseline_round(
                self, state.params, take(batches), pw, keys
            )
        else:
            deltas, losses = jax.vmap(
                lambda b: self._local_delta(state.params, b)
            )(take(batches))
            recs = jax.vmap(self._encode)(deltas, keys)
            agg = jnp.einsum("k,kn->n", pw, recs)
            task_loss = jnp.sum(losses * pw)

        update = self._finish(agg, wsum)
        w_new = flatten.ravel(state.params) + update
        params = flatten.unravel_like(w_new, state.params)
        metrics = {
            "task_loss": task_loss / wsum,
            "participants": jnp.sum(active),
        }
        return BaselineState(params=params, round=state.round + 1), metrics
