"""Every baseline the paper compares against (Tables 1-2), as jitted
global-model FL rounds with the same interface as PFed1BS.round.

All of them learn ONE global model (no personalization — the paper's point);
they differ in how client updates Delta_k are compressed:

  FedAvg   — full precision both directions (McMahan et al. 2017).
  OBDA     — one-bit symmetric quantization both directions: clients send
             sign(Delta_k), server majority-votes and applies a server-lr
             signed step; downlink is the 1-bit vote (Zhu et al. 2020).
  OBCSAA   — 1-bit compressed-sensing uplink sign(Phi Delta_k) + amplitude
             scalar; server back-projects Phi^T z and rescales; downlink is
             the full-precision model (Fan et al. 2022).
  zSignFed — noisy-perturbed sign compression sign(Delta_k + n_k) with a
             transmitted scale; full-precision downlink (Tang et al. 2024).
  EDEN     — random-rotation (our SRHT rotation) + 1-bit quantization with
             the optimal unbiased scale <r, sign r>/n (Vargaftik et al. 2022).
  FedBAT   — learnable binarization; we use the closed-form optimal
             per-tensor scale alpha* = mean|Delta| with straight-through
             semantics (Li et al. 2024).

Communication accounting for each is in `repro.fl.comms`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import flatten
from repro.core import sketch as sk
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    algo: str                      # fedavg|obda|obcsaa|zsignfed|eden|fedbat
    num_clients: int
    participate: int
    local_steps: int = 5
    lr: float = 0.05
    server_lr: float = 0.01        # OBDA signed-step size
    m_ratio: float = 0.1           # OBCSAA sketch ratio
    chunk: int = 4096
    znoise: float = 1e-3           # zSignFed perturbation std
    seed: int = 0


class BaselineState(NamedTuple):
    params: Any                    # the single global model
    round: jax.Array


class BaselineFL:
    def __init__(self, cfg: BaselineConfig, loss_fn: Callable, params_template):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.n = flatten.tree_size(params_template)
        self.template = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params_template)
        self.spec = sk.make_sketch_spec(self.n, cfg.m_ratio, chunk=cfg.chunk, seed=cfg.seed)

    def init(self, init_params_fn: Callable, key) -> BaselineState:
        return BaselineState(params=init_params_fn(key), round=jnp.int32(0))

    def _local_delta(self, params, batches):
        cfg = self.cfg

        def step(p, batch):
            loss, grads = jax.value_and_grad(self.loss_fn)(p, batch)
            return jax.tree.map(lambda a, g: a - cfg.lr * g.astype(a.dtype), p, grads), loss

        new, losses = jax.lax.scan(step, params, batches)
        delta = flatten.ravel(new) - flatten.ravel(params)
        return delta, jnp.mean(losses)

    # --- per-algorithm compression of the aggregated update -----------------

    def _compress(self, deltas, pw, key):
        """deltas: (K, n); pw: (K,) masked weights. Returns the server-side
        aggregate update (n,) after the algorithm's compression."""
        algo = self.cfg.algo
        wsum = jnp.maximum(jnp.sum(pw), 1e-9)

        if algo == "fedavg":
            return jnp.einsum("k,kn->n", pw, deltas) / wsum

        if algo == "obda":
            signs = jnp.sign(deltas)
            vote = jnp.sign(jnp.einsum("k,kn->n", pw, signs))
            return self.cfg.server_lr * vote           # 1-bit downlink step

        if algo == "obcsaa":
            def enc_dec(d):
                z = jnp.sign(sk.sketch_forward(self.spec, d))
                amp = jnp.linalg.norm(d)                # transmitted scalar
                back = sk.sketch_adjoint(self.spec, z)
                return amp * back / (jnp.linalg.norm(back) + 1e-9)
            rec = jax.vmap(enc_dec)(deltas)
            return jnp.einsum("k,kn->n", pw, rec) / wsum

        if algo == "zsignfed":
            keys = jax.random.split(key, deltas.shape[0])
            def enc(d, kk):
                noisy = d + self.cfg.znoise * jax.random.normal(kk, d.shape)
                scale = jnp.mean(jnp.abs(d))            # transmitted scalar
                return scale * jnp.sign(noisy)
            rec = jax.vmap(enc)(deltas, keys)
            return jnp.einsum("k,kn->n", pw, rec) / wsum

        if algo == "eden":
            # square rotation = sign-flip + FHT (no subsampling)
            rot = sk.make_sketch_spec(self.n, 1.0, chunk=self.cfg.chunk, seed=self.cfg.seed)
            def enc_dec(d):
                r = sk.sketch_forward(rot, d)
                scale = jnp.mean(jnp.abs(r))            # EDEN-optimal 1-bit scale
                return sk.sketch_adjoint(rot, scale * jnp.sign(r))[: self.n]
            rec = jax.vmap(enc_dec)(deltas)
            return jnp.einsum("k,kn->n", pw, rec) / wsum

        if algo == "fedbat":
            def enc(d):
                alpha = jnp.mean(jnp.abs(d))            # closed-form alpha*
                return alpha * jnp.sign(d)
            rec = jax.vmap(enc)(deltas)
            return jnp.einsum("k,kn->n", pw, rec) / wsum

        raise ValueError(algo)

    @functools.partial(jax.jit, static_argnums=0)
    def round(self, state: BaselineState, batches, weights, key):
        cfg = self.cfg
        k = cfg.num_clients
        kperm, kalg = jax.random.split(key)
        perm = jax.random.permutation(kperm, k)
        mask = jnp.zeros((k,), jnp.float32).at[perm[: cfg.participate]].set(1.0)

        deltas, losses = jax.vmap(lambda b: self._local_delta(state.params, b))(batches)
        pw = weights * mask
        update = self._compress(deltas, pw, kalg)

        w_new = flatten.ravel(state.params) + update
        params = flatten.unravel_like(w_new, state.params)
        metrics = {
            "task_loss": jnp.sum(losses * pw) / jnp.maximum(jnp.sum(pw), 1e-9),
        }
        return BaselineState(params=params, round=state.round + 1), metrics
