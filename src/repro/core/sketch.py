"""Matrix-free one-bit random sketching operators (the paper's core).

Implements the Subsampled Randomized Hadamard Transform

    Phi = sqrt(n'/m) * S @ H @ D @ P_pad          (paper Eq. 15-18)

in two flavors:

* **global** — the paper's exact construction: one sign-flip diagonal D over
  the whole zero-padded vector, one length-n' FHT, uniform row subsample.
  Used for paper-scale models (n <= ~2^22).

* **chunked** — block-diagonal SRHT for billion-parameter models (DESIGN.md
  §3.2): the flattened parameter vector is split into power-of-two chunks of
  size `c`; each chunk gets an independent D_i and a strided-random row
  subsample of m_i = m*c/n rows. `||Phi_i|| = sqrt(c/m_i)` exactly per block
  (the Lemma 2 argument only needs Q Q^T = I, which holds for *any* row
  subset), so the analysis constants carry over with n' -> c. Chunks align
  with parameter shards: sketching needs zero cross-device communication.

Execution (DESIGN.md §3.3): on the kernel path the whole pipeline — sign
flip, Kronecker FHT, strided subsample, sqrt(c/m) scale — runs as ONE fused
Pallas pass per chunk tile (`kernels/srht.py`); the staged multi-op pipeline
remains available as `sketch_forward_2d_staged` / `sketch_adjoint_staged`
for parity tests and benchmarking. `sketch_forward_2d` carries a
`jax.custom_vjp` whose backward pass is the hand-written fused adjoint, so
the regularizer gradient Phi^T(tanh(gamma Phi w) - v) of Eq. 11 never pays
autodiff to transpose the sketch trace.

Both flavors are linear operators with exact adjoints (`sketch_adjoint`),
validated against dense materialization and autodiff transposition in the
tests.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.ref import is_pow2


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Static description of one SRHT sketch operator Phi in R^{m x n}."""

    n: int                 # input dimension (flattened model size)
    m: int                 # total sketch dimension actually produced
    chunk: int             # power-of-two block size (== n_pad for global mode)
    m_chunk: int           # sketch rows per block
    num_chunks: int
    seed: int
    mode: str              # "global" | "chunked"

    @property
    def n_pad(self) -> int:
        return self.chunk * self.num_chunks

    @property
    def compression_ratio(self) -> float:
        return self.m / self.n

    @property
    def scale(self) -> float:
        # sqrt(n'/m) per block (Lemma 2: exact spectral norm of Phi).
        return math.sqrt(self.chunk / self.m_chunk)


def make_sketch_spec(
    n: int,
    m_ratio: float = 0.1,
    *,
    chunk: int = 16384,
    seed: int = 0,
    mode: str = "auto",
) -> SketchSpec:
    """Build a sketch spec targeting m ~= m_ratio * n.

    mode="auto" picks the paper's global SRHT when the padded size is a
    single chunk, else the chunked block-diagonal variant.
    """
    assert 0 < m_ratio <= 1
    assert is_pow2(chunk)
    n_pad_global = next_pow2(n)
    if mode == "auto":
        mode = "global" if n_pad_global <= chunk else "chunked"
    if mode == "global":
        c = n_pad_global
        m = max(1, round(m_ratio * n))
        m = min(m, c)
        return SketchSpec(n=n, m=m, chunk=c, m_chunk=m, num_chunks=1, seed=seed, mode=mode)
    num_chunks = -(-n // chunk)
    m_chunk = max(1, round(m_ratio * chunk))
    return SketchSpec(
        n=n, m=num_chunks * m_chunk, chunk=chunk, m_chunk=m_chunk,
        num_chunks=num_chunks, seed=seed, mode="chunked",
    )


# ---------------------------------------------------------------------------
# Per-chunk randomness. Strided-random subsampling keeps index generation
# O(m_chunk) per chunk (a length-c permutation per chunk would materialize
# num_chunks * c indices — infeasible at n ~ 1e10). Rows are distinct by
# construction: idx = offset + arange(m_chunk) * stride, stride = c // m_chunk.
# ---------------------------------------------------------------------------

def _chunk_key(spec: SketchSpec, i: jax.Array) -> jax.Array:
    return jax.random.fold_in(jax.random.key(spec.seed), i)


def _chunk_rand_offset(spec: SketchSpec, i: jax.Array):
    """Sign diagonal + strided-subsample offset for chunk i."""
    key = _chunk_key(spec, i)
    kd, ks = jax.random.split(key)
    d = jax.random.rademacher(kd, (spec.chunk,), dtype=jnp.float32)
    stride = spec.chunk // spec.m_chunk
    offset = jax.random.randint(ks, (), 0, stride)
    return d, offset


def _chunk_rand(spec: SketchSpec, i: jax.Array):
    d, offset = _chunk_rand_offset(spec, i)
    stride = spec.chunk // spec.m_chunk
    idx = offset + jnp.arange(spec.m_chunk) * stride
    return d, idx


def _all_chunk_rand(spec: SketchSpec):
    """(num_chunks, chunk) sign diagonals + (num_chunks, 1) int32 offsets —
    the operand layout of the fused kernels."""
    ii = jnp.arange(spec.num_chunks)
    d, off = jax.vmap(lambda i: _chunk_rand_offset(spec, i))(ii)
    return d, off.astype(jnp.int32).reshape(-1, 1)


def _global_perm_idx(spec: SketchSpec) -> jax.Array:
    """Uniform without-replacement rows for the global (paper-exact) mode."""
    key = jax.random.fold_in(jax.random.key(spec.seed), 0)
    _, ks = jax.random.split(key)
    return jax.random.permutation(ks, spec.chunk)[: spec.m_chunk]


def _pad_to(x: jax.Array, size: int) -> jax.Array:
    return jnp.pad(x, (0, size - x.shape[0]))


def _use_fused(spec: SketchSpec, impl: str) -> bool:
    """The fused single-pass kernels cover chunks up to the single-tile
    Kronecker limit; larger chunks fall back to the staged recursion."""
    return kops.resolve_impl(impl) == "pallas" and spec.chunk <= kops.KERNEL_MAX_C


def _as_blocks(spec: SketchSpec, w: jax.Array) -> jax.Array:
    w = _pad_to(w.astype(jnp.float32), spec.n_pad)
    return w.reshape(spec.num_chunks, spec.chunk)


# ---------------------------------------------------------------------------
# Forward / adjoint dispatch (fused kernel vs staged pipeline)
# ---------------------------------------------------------------------------

def _forward_2d(spec: SketchSpec, w: jax.Array, impl: str) -> jax.Array:
    if _use_fused(spec, impl):
        x = _as_blocks(spec, w)
        d, off = _all_chunk_rand(spec)
        if spec.mode == "global":
            # paper-exact permutation subsample: fuse D + FHT + scale in one
            # pass, gather the m permuted rows from the kernel output.
            y = kops.dfht(x, d, scale=spec.scale, impl=impl)
            return y[:, _global_perm_idx(spec)]
        return kops.srht_forward_2d(
            x, d, off, m_chunk=spec.m_chunk, scale=spec.scale, impl=impl
        )
    return _forward_2d_staged(spec, w, impl)


def _forward_2d_staged(spec: SketchSpec, w: jax.Array, impl: str) -> jax.Array:
    """The seed's four-stage pipeline (sign flip, FHT, gather, scale)."""
    x = _as_blocks(spec, w)

    if spec.mode == "global":
        d, _ = _chunk_rand(spec, jnp.int32(0))
        idx = _global_perm_idx(spec)
        y = kops.fht(x[0] * d, impl=impl)
        return (spec.scale * y[idx]).reshape(1, spec.m_chunk)

    def one(i, xc):
        d, idx = _chunk_rand(spec, i)
        y = kops.fht((xc * d)[None], impl=impl)[0]
        return spec.scale * y[idx]

    return jax.vmap(one)(jnp.arange(spec.num_chunks), x)


def _adjoint_2d(spec: SketchSpec, v: jax.Array, impl: str) -> jax.Array:
    v = v.reshape(spec.num_chunks, spec.m_chunk).astype(jnp.float32)
    if _use_fused(spec, impl):
        d, off = _all_chunk_rand(spec)
        if spec.mode == "global":
            idx = _global_perm_idx(spec)
            lifted = jnp.zeros((1, spec.chunk), jnp.float32).at[0, idx].set(v[0])
            x = kops.dfht(lifted, d, scale=spec.scale, d_post=True, impl=impl)
        else:
            x = kops.srht_adjoint_2d(v, d, off, scale=spec.scale, impl=impl)
        return x.reshape(spec.n_pad)[: spec.n]
    return _adjoint_staged(spec, v, impl)


def _adjoint_staged(spec: SketchSpec, v: jax.Array, impl: str) -> jax.Array:
    v = v.reshape(-1).astype(jnp.float32)

    if spec.mode == "global":
        d, _ = _chunk_rand(spec, jnp.int32(0))
        idx = _global_perm_idx(spec)
        lifted = jnp.zeros(spec.chunk, jnp.float32).at[idx].set(spec.scale * v)
        return (kops.fht(lifted, impl=impl) * d)[: spec.n]

    vz = v.reshape(spec.num_chunks, spec.m_chunk)

    def one(i, vc):
        d, idx = _chunk_rand(spec, i)
        lifted = jnp.zeros(spec.chunk, jnp.float32).at[idx].set(spec.scale * vc)
        return kops.fht(lifted[None], impl=impl)[0] * d

    x = jax.vmap(one)(jnp.arange(spec.num_chunks), vz)
    return x.reshape(spec.n_pad)[: spec.n]


# ---------------------------------------------------------------------------
# Public API. sketch_forward_2d carries a custom VJP: the cotangent of a
# linear operator is exactly its adjoint, so the backward pass is one fused
# adjoint kernel call instead of autodiff transposing the sketch trace
# (Eq. 11: grad_w = Phi^T (tanh(gamma Phi w) - v)).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 2))
def _sketch_forward_2d(spec: SketchSpec, w: jax.Array, impl: str) -> jax.Array:
    return _forward_2d(spec, w, impl)


def _sketch_forward_2d_fwd(spec, w, impl):
    return _forward_2d(spec, w, impl), None


def _sketch_forward_2d_bwd(spec, impl, _res, g):
    return (_adjoint_2d(spec, g, impl),)


_sketch_forward_2d.defvjp(_sketch_forward_2d_fwd, _sketch_forward_2d_bwd)


@functools.partial(jax.jit, static_argnames=("spec", "impl"))
def sketch_forward_2d(spec: SketchSpec, w: jax.Array, impl: str = "auto") -> jax.Array:
    """z = Phi @ w (Eq. 15-18): (n,) float -> (num_chunks, m_chunk) float32.

    Carries the custom VJP whose backward pass is the fused adjoint, so
    autodiff through this is exactly Eq. 11's Phi^T cotangent.
    The 2-D layout mirrors chunk ownership: when w's elements are laid out
    sharded-axis-major, chunk rows (axis 0) are device-local, so the sketch
    and everything downstream of it (consensus v, tanh, vote) shard on
    axis 0 with zero collectives.
    """
    assert w.shape == (spec.n,), f"expected ({spec.n},), got {w.shape}"
    return _sketch_forward_2d(spec, w, impl)


def sketch_forward(spec: SketchSpec, w: jax.Array, impl: str = "auto") -> jax.Array:
    """z = Phi @ w (Eq. 15-18), matrix-free. w: (n,) float -> (m,) float32."""
    return sketch_forward_2d(spec, w, impl=impl).reshape(spec.m)


@functools.partial(jax.jit, static_argnames=("spec", "impl"))
def sketch_forward_2d_staged(
    spec: SketchSpec, w: jax.Array, impl: str = "auto"
) -> jax.Array:
    """Seed pipeline, no custom VJP — parity/benchmark reference."""
    return _forward_2d_staged(spec, w, impl)


def sketch_forward_staged(spec: SketchSpec, w: jax.Array, impl: str = "auto") -> jax.Array:
    return sketch_forward_2d_staged(spec, w, impl=impl).reshape(spec.m)


@functools.partial(jax.jit, static_argnames=("spec", "impl"))
def sketch_forward_packed(
    spec: SketchSpec, w: jax.Array, impl: str = "auto"
) -> jax.Array:
    """Uplink wire format straight from the kernel: packed uint32 signs of
    Phi w, (num_chunks, m_chunk // 32). Requires m_chunk % 32 == 0 (pad the
    spec's m_chunk or pack the float sketch for odd sizes)."""
    assert spec.m_chunk % 32 == 0
    if _use_fused(spec, impl) and spec.mode != "global":
        x = _as_blocks(spec, w)
        d, off = _all_chunk_rand(spec)
        return kops.srht_forward_packed_2d(
            x, d, off, m_chunk=spec.m_chunk, scale=spec.scale, impl=impl
        )
    z = sketch_forward_2d(spec, w, impl=impl)
    return kops.pack_signs(z, impl=impl)


@functools.partial(jax.jit, static_argnames=("spec", "impl"))
def sketch_adjoint(spec: SketchSpec, v: jax.Array, impl: str = "auto") -> jax.Array:
    """w = Phi^T @ v, matrix-free — the adjoint of Eq. 15-18 (the operator
    every Eq. 11 gradient applies). v: (m,) or (num_chunks, m_chunk) float
    -> (n,) float32."""
    return _adjoint_2d(spec, v, impl)


@functools.partial(jax.jit, static_argnames=("spec", "impl"))
def sketch_adjoint_batched(spec: SketchSpec, v: jax.Array, impl: str = "auto") -> jax.Array:
    """W = Phi^T V for a batch: v (B, m) or (B, num_chunks, m_chunk) ->
    (B, n) float32, row b == sketch_adjoint(spec, v[b]).

    All B rows share the one operator Phi (spec randomness is drawn once),
    so the batch folds into the fused kernel's row grid — one pass
    materializes every reconstruction (kernels/ops.srht_adjoint_batched_2d)
    instead of B sequential adjoint dispatches. This is the decode half of
    the serving-tier codec (serve/store.py)."""
    b = v.shape[0]
    v = v.reshape(b, spec.num_chunks, spec.m_chunk).astype(jnp.float32)
    if _use_fused(spec, impl) or kops.resolve_impl(impl) == "ref":
        if spec.mode == "global":
            return jax.vmap(lambda vb: _adjoint_2d(spec, vb, impl))(v)
        d, off = _all_chunk_rand(spec)
        x = kops.srht_adjoint_batched_2d(v, d, off, scale=spec.scale, impl=impl)
        return x.reshape(b, spec.n_pad)[:, : spec.n]
    return jax.vmap(lambda vb: _adjoint_staged(spec, vb, impl))(v)


@functools.partial(jax.jit, static_argnames=("spec", "impl"))
def sketch_adjoint_staged(
    spec: SketchSpec, v: jax.Array, impl: str = "auto"
) -> jax.Array:
    """Seed adjoint pipeline — parity/benchmark reference."""
    return _adjoint_staged(spec, v, impl)


def dense_gaussian_sketch(n: int, m: int, seed: int = 0) -> jax.Array:
    """The paper's dense-Gaussian baseline projection (ablation §A.3).

    Entries ~ N(0, 1/m) so that E||Phi w||^2 = ||w||^2. Only for small n.
    """
    key = jax.random.key(seed)
    return jax.random.normal(key, (m, n), jnp.float32) / jnp.sqrt(m)


def materialize(spec: SketchSpec) -> jax.Array:
    """Densify Phi (tests only; m x n)."""
    eye = jnp.eye(spec.n, dtype=jnp.float32)
    return jax.vmap(lambda e: sketch_forward(spec, e))(eye).T
