"""Server-side consensus aggregation (paper Eq. 8, Lemma 1) + robust votes.

The server's discrete problem min_{v in {+-1}^m} sum_k p_k g(v, z_k) has the
exact closed-form minimizer v* = sign(sum_k p_k z_k) — a weighted majority
vote. `majority_vote` keeps jnp.sign semantics (tie -> 0, matching the paper's
note that v may contain {-1, 0, +1}); the packed transport path breaks ties
to +1 (a tie has measure zero under real-valued weights).

TIE-BREAKING CONVENTIONS (pinned by tests/test_regularizer_consensus.py::
test_tie_break_conventions — adversaries can FORCE exact ties, e.g. a
sign-flipped row exactly cancels its honest twin under uniform weights, so
the divergence between the vote paths must be explicit, not folklore):

  float paths    majority_vote, staleness_weighted_vote, trimmed_vote,
                 reputation_vote             tie (sum == 0)  ->  0
  packed paths   majority_vote_packed, majority_vote_popcount,
                 trimmed_vote_packed         tie             -> +1

Each robust vote inherits the convention of the base vote it composes:
`trimmed_vote`/`reputation_vote` revote through `majority_vote` (tie -> 0);
`trimmed_vote_packed` revotes through the packed word vote (tie -> +1).
A 0 in a float consensus counts as DISagreement for every voter in the
trim ranking and the reputation EMA (z * 0 > 0 is False) — uniformly, so
it can never reorder voters relative to each other.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.regularizer import one_sided_l1
from repro.kernels import ops as kops


def majority_vote(zs: jax.Array, p: jax.Array) -> jax.Array:
    """v = sign(sum_k p_k z_k). zs: (K, m), p: (K,) -> (m,) in {-1,0,1}."""
    return jnp.sign(jnp.einsum("k,km->m", p, zs))


def majority_vote_packed(words: jax.Array, p: jax.Array) -> jax.Array:
    """Vote directly on packed uint32 sketches (the wire format).

    words: (K, W) uint32; p: (K,) float weights -> (W,) uint32, tie -> +1.
    """
    return kops.vote_packed(words, p)


def majority_vote_popcount(words: jax.Array) -> jax.Array:
    """Uniform-weight vote on packed words, fully word-level (DESIGN.md §6.2).

    The p_k = 1/K specialization of Lemma 1: consensus bit = at least
    ceil(K/2) of the K clients set it (tie -> +1). Integer-exact — unlike
    the float paths, an exact tie can never be perturbed by rounding.

    words: (K, W) uint32 -> (W,) uint32 packed consensus.
    """
    return kops.vote_popcount(words)


def tree_vote_popcount(words: jax.Array, leaf_sizes, impl: str = "auto") -> jax.Array:
    """Hierarchical uniform-weight vote: count at the leaves, merge counts,
    finish once at the root (DESIGN.md §11).

    Rows of `words` are split contiguously into leaves of the given sizes
    (sum(leaf_sizes) == K; zero-size leaves allowed). Each leaf emits its
    partial popcount counter, counters are summed, and the root thresholds
    2*cnt >= K (tie -> +1). Because counting is integer addition, the
    result is BIT-IDENTICAL to `majority_vote_popcount(words)` for every
    partition and every merge order — the property a majority-of-majorities
    tree does not have (tests/test_hier.py pins the 3-leaf counterexample).

    words: (K, W) uint32; leaf_sizes: sequence of ints -> (W,) uint32.
    """
    k, nw = words.shape
    sizes = [int(s) for s in leaf_sizes]
    assert sum(sizes) == k, f"leaf sizes {sizes} must partition {k} rows"
    counters, start = [], 0
    for s in sizes:
        counters.append(kops.popcount_partial(words[start : start + s], impl=impl))
        start += s
    if not counters:
        counters = [jnp.zeros((nw, 32), jnp.int32)]
    total = kops.merge_counters(jnp.stack(counters), impl=impl)
    return kops.finish_vote_counts(total, k, impl=impl)


def staleness_weights(tau: jax.Array, exponent: float) -> jax.Array:
    """Polynomial staleness discount 1/(1+tau)^p for buffered async votes.

    tau: (B,) non-negative consensus-version lags (server version at flush
    minus the version each arriving client downloaded); exponent p >= 0.
    p = 0 returns exactly 1.0 for every row — multiplying a vote weight by
    it is a float no-op, which is what makes the async tier's zero-staleness
    drain bit-exact with the synchronous round (repro/sim, DESIGN.md §9).
    FedBuff and FedAsync both use this family; p is
    sim/server.py::AsyncConfig.staleness_exponent.
    """
    if exponent == 0.0:
        return jnp.ones_like(jnp.asarray(tau, jnp.float32))
    tau = jnp.asarray(tau, jnp.float32)
    return (1.0 + tau) ** (-float(exponent))


def staleness_weighted_vote(zs: jax.Array, p: jax.Array, tau: jax.Array,
                            exponent: float) -> jax.Array:
    """REFERENCE semantics of the async tier's flush vote (Lemma 1 with
    per-client staleness discounts): v = sign(sum_k p_k/(1+tau_k)^p z_k).
    zs: (B, m); p, tau: (B,).

    The simulator's production flush does NOT call this directly — it
    composes `staleness_weights` with the engine's order-pinned vote paths
    (pfed1bs.vote_scattered for natural-client-order parity with the sync
    round, kernels/ops.vote_packed_ragged for the wire format), because
    this buffer-order accumulation is not bit-stable under resampling.
    Tests compare against this form (tests/test_async_sim.py)."""
    return majority_vote(zs, p * staleness_weights(tau, exponent))


# --- robust votes (Byzantine defense layer, DESIGN.md §10) -------------------

def trimmed_vote(zs: jax.Array, p: jax.Array, trim: int):
    """Coordinate-free trimmed weighted vote: drop the `trim` most
    DISAGREEING voters, then revote.

    1. provisional Lemma-1 vote v0 = sign(sum_k p_k z_k);
    2. per-voter disagreement d_k = mean_j(z_kj * v0_j < 0) (the fraction
       of coordinates voting against the provisional consensus — a
       sign-flip attacker scores near 1, honest heterogeneous clients
       cluster well below);
    3. zero the weights of the top-`trim` voters by d_k (stable argsort:
       equal disagreement breaks to the lower client index, deterministic)
       — but never below one survivor: the realized trim count is
       min(trim, voters - 1) with voters = #(p > 0), so a part-full async
       buffer can't be trimmed to an empty vote;
    4. revote with the kept weights.

    The provisional vote is UNWEIGHTED (one voter, one vote): the attack
    surface of the weighted vote is weight concentration — a colluding
    bloc holding 20% of the clients can hold >40% of the p_k mass under
    data imbalance and drag the provisional consensus toward itself, at
    which point ranking disagreement against that consensus trims the
    HONEST voters. Measuring disagreement against the head-count majority
    keeps the reference honest whenever byzantine CLIENTS (not mass) are
    a minority, which is the standard Byzantine assumption. The final
    revote stays p-weighted (Lemma 1 fidelity on the kept voters).

    zs: (K, m) sign rows in natural client order (0 rows for non-voters);
    p: (K,) weights, p_k = 0 marks a non-voter (never counted, never
    trimmed). Returns (v, kept_weights). Tie convention: INHERITS
    majority_vote's tie -> 0 in both the provisional and the final vote; a
    provisional 0 counts as disagreement for everyone equally, so it
    cannot reorder the trim ranking."""
    v0 = majority_vote(zs, (p > 0).astype(jnp.float32))
    dis = jnp.mean((zs * v0[None, :] < 0).astype(jnp.float32), axis=1)
    dis = jnp.where(p > 0, dis, -jnp.inf)          # non-voters rank last
    voters = jnp.sum((p > 0).astype(jnp.int32))
    t = jnp.minimum(jnp.asarray(trim, jnp.int32), jnp.maximum(voters - 1, 0))
    order = jnp.argsort(-dis)                      # stable: ties -> low index
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    kept = jnp.where(ranks < t, 0.0, p)
    return majority_vote(zs, kept), kept


def trimmed_vote_packed(words: jax.Array, p: jax.Array, trim: int):
    """Trimmed vote on the packed wire words (kernels/ops.py
    ::vote_packed_trimmed): same rank-and-drop scheme with the
    disagreement measured as the XOR-popcount Hamming distance to the
    provisional packed consensus. words: (K, W) uint32; p: (K,). Returns
    the packed (W,) uint32 consensus. Tie convention: INHERITS the packed
    vote's tie -> +1 (both provisional and final), so a tie bit broken to
    +1 counts as disagreement only for the -1 voters — unlike the float
    path, where a 0 consensus bit penalizes everyone. With no exact vote
    ties the two paths pick the same voters and the same consensus
    (tests/test_robust.py pins this)."""
    return kops.vote_packed_trimmed(words, p, trim)


def reputation_vote(zs: jax.Array, p: jax.Array, rep: jax.Array,
                    beta: float):
    """Reputation-weighted vote: per-client multiplicative weights learned
    as an EMA of each voter's sign-agreement history.

    Vote with w_k = p_k * rep_k, then update rep toward this round's
    agreement a_k = mean_j(z_kj * ref_j > 0) for the clients that voted
    (rep' = (1-beta) rep + beta a; non-voters keep their reputation). The
    agreement REFERENCE is the unweighted head-count majority, not the
    returned weighted vote, for the same reason trimmed_vote ranks
    against it: a weight-heavy colluding bloc can drag the weighted
    consensus toward itself and then score perfect "agreement" with its
    own corruption. A persistent sign-flipper's agreement against the
    honest head-count sits near 0, so its effective weight decays
    geometrically while honest clients hover near their natural agreement
    level. rep in [0,1]^K stays in [0,1] (an EMA of [0,1] values), hence
    non-negative and finite under ANY adversarial history
    (hypothesis-pinned in tests/test_robust.py).

    BIT-EXACTNESS NOTE: a_k is a mean of 0/1 floats — integer partial
    sums, exact in float32 for any m < 2^24 — and the EMA is elementwise,
    so recomputing the chain in a different jitted program (the async
    flush vs the fused round) yields bit-identical reputations, unlike
    EF's alpha mean (see pfed1bs._ef_quantize). zs: (K, m) natural-order
    sign rows (0 rows for non-voters); p, rep: (K,). Returns (v, rep').
    Tie convention: INHERITS majority_vote's tie -> 0 (returned vote AND
    reference); a 0 reference bit counts as disagreement for every
    voter's EMA equally."""
    v = majority_vote(zs, p * rep)
    ref = majority_vote(zs, (p > 0).astype(jnp.float32))
    agree = jnp.mean((zs * ref[None, :] > 0).astype(jnp.float32), axis=1)
    rep_new = jnp.where(p > 0, (1.0 - beta) * rep + beta * agree, rep)
    return v, rep_new


def server_objective(v: jax.Array, zs: jax.Array, p: jax.Array) -> jax.Array:
    """sum_k p_k g(v, z_k) with the exact one-sided l1 regularizer."""
    return jnp.einsum("k,k->", p, jax.vmap(lambda z: one_sided_l1(v, z))(zs))


def brute_force_vote(zs: np.ndarray, p: np.ndarray) -> np.ndarray:
    """Exhaustive minimizer over {+-1}^m (tests of Lemma 1; small m only)."""
    m = zs.shape[1]
    assert m <= 16
    best, best_val = None, np.inf
    for bits in itertools.product((-1.0, 1.0), repeat=m):
        v = np.asarray(bits, np.float32)
        val = float(server_objective(jnp.asarray(v), jnp.asarray(zs), jnp.asarray(p)))
        if val < best_val - 1e-12:
            best, best_val = v, val
    return best
