"""Server-side consensus aggregation (paper Eq. 8, Lemma 1).

The server's discrete problem min_{v in {+-1}^m} sum_k p_k g(v, z_k) has the
exact closed-form minimizer v* = sign(sum_k p_k z_k) — a weighted majority
vote. `majority_vote` keeps jnp.sign semantics (tie -> 0, matching the paper's
note that v may contain {-1, 0, +1}); the packed transport path breaks ties
to +1 (a tie has measure zero under real-valued weights).
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.regularizer import one_sided_l1
from repro.kernels import ops as kops


def majority_vote(zs: jax.Array, p: jax.Array) -> jax.Array:
    """v = sign(sum_k p_k z_k). zs: (K, m), p: (K,) -> (m,) in {-1,0,1}."""
    return jnp.sign(jnp.einsum("k,km->m", p, zs))


def majority_vote_packed(words: jax.Array, p: jax.Array) -> jax.Array:
    """Vote directly on packed uint32 sketches (the wire format).

    words: (K, W) uint32; p: (K,) float weights -> (W,) uint32, tie -> +1.
    """
    return kops.vote_packed(words, p)


def majority_vote_popcount(words: jax.Array) -> jax.Array:
    """Uniform-weight vote on packed words, fully word-level (DESIGN.md §6.2).

    The p_k = 1/K specialization of Lemma 1: consensus bit = at least
    ceil(K/2) of the K clients set it (tie -> +1). Integer-exact — unlike
    the float paths, an exact tie can never be perturbed by rounding.

    words: (K, W) uint32 -> (W,) uint32 packed consensus.
    """
    return kops.vote_popcount(words)


def staleness_weights(tau: jax.Array, exponent: float) -> jax.Array:
    """Polynomial staleness discount 1/(1+tau)^p for buffered async votes.

    tau: (B,) non-negative consensus-version lags (server version at flush
    minus the version each arriving client downloaded); exponent p >= 0.
    p = 0 returns exactly 1.0 for every row — multiplying a vote weight by
    it is a float no-op, which is what makes the async tier's zero-staleness
    drain bit-exact with the synchronous round (repro/sim, DESIGN.md §9).
    FedBuff and FedAsync both use this family; p is
    sim/server.py::AsyncConfig.staleness_exponent.
    """
    if exponent == 0.0:
        return jnp.ones_like(jnp.asarray(tau, jnp.float32))
    tau = jnp.asarray(tau, jnp.float32)
    return (1.0 + tau) ** (-float(exponent))


def staleness_weighted_vote(zs: jax.Array, p: jax.Array, tau: jax.Array,
                            exponent: float) -> jax.Array:
    """REFERENCE semantics of the async tier's flush vote (Lemma 1 with
    per-client staleness discounts): v = sign(sum_k p_k/(1+tau_k)^p z_k).
    zs: (B, m); p, tau: (B,).

    The simulator's production flush does NOT call this directly — it
    composes `staleness_weights` with the engine's order-pinned vote paths
    (pfed1bs.vote_scattered for natural-client-order parity with the sync
    round, kernels/ops.vote_packed_ragged for the wire format), because
    this buffer-order accumulation is not bit-stable under resampling.
    Tests compare against this form (tests/test_async_sim.py)."""
    return majority_vote(zs, p * staleness_weights(tau, exponent))


def server_objective(v: jax.Array, zs: jax.Array, p: jax.Array) -> jax.Array:
    """sum_k p_k g(v, z_k) with the exact one-sided l1 regularizer."""
    return jnp.einsum("k,k->", p, jax.vmap(lambda z: one_sided_l1(v, z))(zs))


def brute_force_vote(zs: np.ndarray, p: np.ndarray) -> np.ndarray:
    """Exhaustive minimizer over {+-1}^m (tests of Lemma 1; small m only)."""
    m = zs.shape[1]
    assert m <= 16
    best, best_val = None, np.inf
    for bits in itertools.product((-1.0, 1.0), repeat=m):
        v = np.asarray(bits, np.float32)
        val = float(server_objective(jnp.asarray(v), jnp.asarray(zs), jnp.asarray(p)))
        if val < best_val - 1e-12:
            best, best_val = v, val
    return best
