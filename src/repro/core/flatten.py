"""Trace-compatible pytree <-> flat-vector utilities.

The sketch operates on the flattened trainable vector w in R^n. These helpers
work under jit/vmap (static split sizes derived from the template tree).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    return int(sum(np.prod(l.shape, dtype=np.int64) for l in jax.tree.leaves(tree)))


def ravel(tree) -> jax.Array:
    """Concatenate all leaves into one float32 vector (n,)."""
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])


def unravel_like(vec: jax.Array, template) -> object:
    """Inverse of ravel against a template tree (leaf dtypes preserved)."""
    leaves, treedef = jax.tree.flatten(template)
    sizes = [int(np.prod(l.shape, dtype=np.int64)) for l in leaves]
    offsets = np.cumsum([0] + sizes)
    out = [
        jax.lax.dynamic_slice_in_dim(vec, int(offsets[i]), sizes[i]).reshape(l.shape).astype(l.dtype)
        for i, l in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def tree_add_scaled(tree, vec_tree, scale):
    """tree + scale * vec_tree (elementwise over matching pytrees)."""
    return jax.tree.map(lambda a, b: a + scale * b.astype(a.dtype), tree, vec_tree)
