"""LoRA-style trainable-subset selection over parameter pytrees.

The fed_lm path (DESIGN.md §13) federates a real LM while training only a
subset of its leaves — attention projections, the head, an adapter — the
way parameter-efficient fine-tuning does. The subset is named by PATH
PATTERNS: substrings matched against the `jax.tree_util.keystr` leaf paths
(the same strings core/treesketch.py seeds its per-leaf SRHT blocks with
and checkpoint/ckpt.py keys its npz members with). Everything downstream
is keyed by those original path strings, so a subset-filtered
TreeSketchSpec (make_tree_sketch_spec(..., paths=...)) sketches a selected
leaf with EXACTLY the operator the full spec would have used — selecting
every path is the identity, not a reseeding.

Selection lives here, in `core`, because three layers share it: the
PFed1BS engine (cfg.trainable — gradients and sketches restricted to the
subset), the streamed encoder (core/stream.py walks the filtered spec),
and the bit meter (fl/comms.subset_round_bits bills the trainable count).
"""
from __future__ import annotations

import jax
import numpy as np


def leaf_paths(tree) -> list:
    """[(keystr path, leaf), ...] in template leaf order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in flat]


def match_paths(template, patterns) -> tuple:
    """Resolve path-substring `patterns` against a template's leaf paths.

    Returns the matching keystr paths as a tuple, in TEMPLATE LEAF ORDER
    (the order every spec/stream walks leaves in — stable regardless of
    pattern order). Raises on a pattern that matches nothing: a silently
    empty LoRA subset would train nothing and bill nothing.
    """
    paths = [p for p, _ in leaf_paths(template)]
    unmatched = [pat for pat in patterns
                 if not any(pat in p for p in paths)]
    if unmatched:
        raise ValueError(
            f"trainable patterns {unmatched} match no leaf path; "
            f"paths are like {paths[:4]}..."
        )
    sel = tuple(p for p in paths if any(pat in p for pat in patterns))
    return sel


def extract(tree, paths) -> dict:
    """The selected leaves as a {keystr path: leaf} dict.

    A plain dict keyed by the ORIGINAL paths — treesketch's forward looks
    leaves up by path (never by flatten order), so this dict is a valid
    differentiable pytree for the subset objective.
    """
    want = set(paths)
    out = {p: l for p, l in leaf_paths(tree) if p in want}
    missing = want - set(out)
    if missing:
        raise ValueError(f"tree has no leaves for {sorted(missing)}")
    return out


def merge(tree, sub: dict):
    """`tree` with the subset dict's leaves swapped in (inverse of extract
    up to the untouched leaves)."""

    def go(path, leaf):
        return sub.get(jax.tree_util.keystr(path), leaf)

    return jax.tree_util.tree_map_with_path(go, tree)


def subset_size(template, paths) -> int:
    """Trainable parameter count of the subset (the n that
    fl/comms.subset_round_bits bills)."""
    sel = extract(template, paths)
    return int(sum(int(np.prod(l.shape)) if l.shape else 1
                   for l in sel.values()))
