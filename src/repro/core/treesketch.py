"""Sharding-aware sketching of whole parameter pytrees.

The paper sketches the flattened model w in R^n. At framework scale a literal
ravel of a sharded pytree forces XLA to all-gather every parameter. Instead
we exploit that the chunked SRHT (sketch.py) is block-diagonal: blocks can be
assigned to *leaves* (each leaf gets its own independent SRHT blocks, seeded
by the leaf path), and within a leaf the element order can be any fixed
permutation — so we put the tensor-parallel-sharded axis outermost before
flattening. Result: every FHT block lives entirely on one device; the sketch
and its adjoint are collective-free, and only the m-bit consensus crosses
the federation (pod) axis.

Two layouts, selectable per experiment (§Perf records both):
  flat  — paper-literal: ravel everything, then chunk (baseline).
  leaf  — per-leaf, sharded-axis-major chunks (optimized; identical theory:
          still a block-diagonal SRHT with exact ||Phi_i|| = sqrt(c/m_i)).
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as sk


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in flat]


@dataclasses.dataclass(frozen=True)
class TreeSketchSpec:
    entries: tuple  # ((path, SketchSpec, m_offset, major_axis), ...)
    m: int
    n: int
    chunk: int
    m_ratio: float

    @property
    def compression_ratio(self):
        return self.m / self.n


def make_tree_sketch_spec(
    template, m_ratio: float = 0.1, *, chunk: int = 16384, seed: int = 0,
    major_axes=None, paths=None,
) -> TreeSketchSpec:
    """Build the per-leaf block-diagonal SRHT spec (Eq. 15-18 per leaf).

    template: pytree of arrays/ShapeDtypeStructs (shapes+dtypes only are
    read). Each leaf gets an independent chunked SketchSpec (chunk size
    min(chunk, next_pow2(leaf size)), m_i ~= m_ratio * leaf size) seeded by
    crc32(leaf path) ^ seed, so leaf sketches are independent and stable
    under tree reordering. major_axes: optional matching pytree of
    int|-1 giving the axis to move outermost (the tensor-parallel-sharded
    axis) before flattening each leaf — a fixed element permutation, which
    the SRHT analysis is invariant to, chosen so FHT chunks never straddle
    device shards. paths: optional collection of keystr leaf paths to KEEP
    (core/subset.py's LoRA-style trainable selection) — entries for other
    leaves are dropped, but kept leaves keep their full-template seeds, so
    selecting every path builds the identical spec and the spec's n/m
    count only the trainable subset."""
    majors = None if major_axes is None else _leaf_paths(major_axes)
    keep = None if paths is None else set(paths)
    entries = []
    off = 0
    total_n = 0
    for i, (path, leaf) in enumerate(_leaf_paths(template)):
        if keep is not None and path not in keep:
            continue
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        leaf_chunk = min(chunk, sk.next_pow2(size))
        leaf_seed = (zlib.crc32(path.encode()) ^ seed) & 0x7FFFFFFF
        spec = sk.make_sketch_spec(
            size, m_ratio, chunk=leaf_chunk, seed=leaf_seed, mode="chunked"
        )
        major = majors[i][1] if majors is not None else None
        if major is not None and major < 0:
            major = None
        entries.append((path, spec, off, major))
        off += spec.m
        total_n += size
    assert entries, "path filter selected no leaves"
    return TreeSketchSpec(
        entries=tuple(entries), m=off, n=total_n, chunk=chunk, m_ratio=m_ratio
    )


def _to_major(x, major):
    if major is not None and x.ndim > 1 and major != 0:
        x = jnp.moveaxis(x, major, 0)
    return x.reshape(-1)


def _from_major(flat, shape, major):
    if major is not None and len(shape) > 1 and major != 0:
        perm_shape = (shape[major],) + tuple(s for i, s in enumerate(shape) if i != major)
        return jnp.moveaxis(flat.reshape(perm_shape), 0, major)
    return flat.reshape(shape)


def _entry_leaves(tspec: TreeSketchSpec, tree) -> list:
    """Resolve the spec's entries to leaves of `tree`, BY PATH: `tree` is
    either a pytree whose leaf paths cover the entries (a superset when
    the spec was path-filtered — core/subset.py selection) or already a
    {keystr path: leaf} dict (an extracted subset)."""
    if isinstance(tree, dict):
        hit = [tree.get(path) for path, *_ in tspec.entries]
        if all(leaf is not None for leaf in hit):
            return hit
    got = dict(_leaf_paths(tree))
    try:
        return [got[path] for path, *_ in tspec.entries]
    except KeyError as e:
        raise ValueError(f"tree has no leaf for spec entry {e}") from None


def tree_sketch_forward(tspec: TreeSketchSpec, tree) -> dict:
    """z = Phi @ ravel(tree) with Phi leaf-block-diagonal (Eq. 15-18).

    tree: pytree matching the spec's template — or a SUPERSET of it when
    the spec was path-filtered (leaves are matched by path, so the full
    params pytree feeds a trainable-subset spec directly), or a
    {path: leaf} subset dict. Returns a dict {leaf_path: (num_chunks,
    m_chunk) float32} — each sketch block stays sharded exactly like its
    source leaf (no concat => no resharding). Differentiable; gradients
    flow through sketch_forward_2d's custom VJP, so d/dw of the Eq. 5
    regularizer is the Eq. 11 adjoint per leaf."""
    leaves = _entry_leaves(tspec, tree)
    out = {}
    for (path, spec, _, major), leaf in zip(tspec.entries, leaves):
        out[path] = sk.sketch_forward_2d(spec, _to_major(leaf, major))
    return out


def tree_sketch_adjoint(tspec: TreeSketchSpec, v: dict, template):
    """w = Phi^T v, the exact adjoint of tree_sketch_forward (Eq. 7/11).

    v: dict {leaf_path: (num_chunks, m_chunk) float} as produced by
    tree_sketch_forward. Returns a pytree shaped/dtyped like template
    (values cast back to each leaf dtype)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    outs = []
    for (path, spec, off, major), (p2, leaf) in zip(tspec.entries, flat):
        wi = sk.sketch_adjoint(spec, v[path])
        outs.append(_from_major(wi, leaf.shape, major).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), outs
    )


def tree_sketch_adjoint_batched(tspec: TreeSketchSpec, v: dict, template):
    """Batched W = Phi^T V over the leaf-block layout: v is a dict
    {leaf_path: (B, num_chunks, m_chunk) float} and the result is a
    stacked pytree (leading axis B) shaped like template per element.

    Each leaf is one fused batched-adjoint pass
    (core/sketch.sketch_adjoint_batched), so decoding B clients costs
    len(leaves) kernel dispatches total instead of B * len(leaves) —
    the leaf-layout decode half of the serving-tier codec."""
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    outs = []
    for (path, spec, off, major), (p2, leaf) in zip(tspec.entries, flat):
        wi = sk.sketch_adjoint_batched(spec, v[path])       # (B, leaf_n)
        wi = jax.vmap(lambda w: _from_major(w, leaf.shape, major))(wi)
        outs.append(wi.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), outs
    )


def flat_view(tspec: TreeSketchSpec, z: dict) -> jax.Array:
    """Concatenate a per-leaf sketch dict into one (m,) float32 vector in
    spec entry order (the layout PFed1BS's consensus/EF buffers use).
    Cheap for single-host clients; on a sharded model this DOES reshard —
    keep the dict layout there (launch/steps.py does)."""
    return jnp.concatenate([z[path].reshape(-1) for path, *_ in tspec.entries])


def zeros_like_sketch(tspec: TreeSketchSpec) -> dict:
    """v^0 = 0 in the per-leaf block layout."""
    return {
        path: jnp.zeros((spec.num_chunks, spec.m_chunk), jnp.float32)
        for path, spec, _, _ in tspec.entries
    }


def tree_reg_value_and_grad(tspec, tree, v: dict, gamma, lam, mu):
    """lam * g~(v, Phi w) + (mu/2)||w||^2 (Eq. 5-6 terms) and its gradient.

    Uses the explicit adjoint (Eq. 7: grad = lam * Phi^T(tanh(gamma Phi w)
    - v) + mu * w) rather than autodiff so the backward FHT reuses the
    forward's block structure exactly. v: per-leaf block dict (the
    tree_sketch_forward layout). Returns (scalar float32 value, gradient
    pytree shaped like `tree`)."""
    from repro.core import regularizer as reg

    z = tree_sketch_forward(tspec, tree)
    val = 0.0
    gz = {}
    for path in z:
        val = val + lam * reg.smoothed_reg(v[path].reshape(-1), z[path].reshape(-1), gamma)
        gz[path] = lam * reg.reg_grad_z(v[path], z[path], gamma)
    gtree = tree_sketch_adjoint(tspec, gz, tree)
    l2 = 0.0
    for leaf in jax.tree.leaves(tree):
        l2 = l2 + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
    val = val + 0.5 * mu * l2
    gtree = jax.tree.map(
        lambda g, w: g + (mu * w.astype(jnp.float32)).astype(g.dtype), gtree, tree
    )
    return val, gtree


def sketch_pspecs(tspec: TreeSketchSpec, param_pspecs_tree, mesh) -> dict:
    """PartitionSpecs for the per-leaf sketch blocks: chunk rows (axis 0)
    shard over 'model' whenever the source leaf was model-sharded and the
    row count divides."""
    from jax.sharding import PartitionSpec as P

    flat = {
        jax.tree_util.keystr(p): s
        for p, s in jax.tree_util.tree_flatten_with_path(
            param_pspecs_tree, is_leaf=lambda x: isinstance(x, P)
        )[0]
    }
    msize = mesh.shape["model"]
    out = {}
    for path, spec, _, major in tspec.entries:
        assert path in flat, f"pspecs tree has no leaf for {path}"
        sharded = major is not None and spec.num_chunks % msize == 0
        out[path] = P("model", None) if sharded else P(None, None)
    return out
