# The paper's primary contribution: one-bit random sketching (SRHT/FHT),
# the sign-based personalization regularizer, majority-vote consensus,
# and the pFed1BS alternating optimization scheme + all paper baselines.
from repro.core.sketch import SketchSpec, make_sketch_spec, sketch_forward, sketch_adjoint
from repro.core.regularizer import h_gamma, smoothed_reg, reg_grad_z, one_sided_l1
from repro.core.consensus import majority_vote, server_objective
