"""Shared round-surface helpers used by every executor (DESIGN.md §8.2).

Straggler semantics live HERE and only here: a round's participants are an
(idx (S,), active (S,)) pair; active=0 means the client's round never
landed — its params are kept, it casts no vote, and it is billed no bits.
PFed1BS's three executors (core/pfed1bs.py fused/staged,
launch/fedexec.py sharded) and BaselineFL (core/baselines.py) all resolve
participants through `draw_participants` and apply updates through
`scatter_rows`, so the invariant cannot silently diverge between them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def draw_participants(key, num_clients: int, capacity: int, participants):
    """Resolve a round's (idx (S,), active (S,)) pair: the externally drawn
    one (exp/scenarios.py participation models — S must equal the engine's
    static `participate` capacity) or the default uniform S-of-K sample,
    all active."""
    if participants is None:
        idx = jax.random.permutation(key, num_clients)[:capacity]
        return idx, jnp.ones((capacity,), jnp.float32)
    idx, active = participants
    return idx, active.astype(jnp.float32)


def scatter_rows(tree, idx, rows, active):
    """Stacked-pytree row scatter with straggler masking: leaf[idx] <- new
    row where active>0, else the existing row is kept. tree: (K, ...)
    leaves; rows: (S, ...) leaves; idx (S,) distinct; active (S,)."""
    def one(old, new):
        act = active.reshape((-1,) + (1,) * (new.ndim - 1))
        kept = jnp.where(act > 0, new.astype(old.dtype), old[idx])
        return old.at[idx].set(kept)

    return jax.tree.map(one, tree, rows)
