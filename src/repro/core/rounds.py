"""Shared round-surface helpers used by every executor (DESIGN.md §8.2).

Straggler semantics live HERE and only here: a round's participants are an
(idx (S,), active (S,)) pair; active=0 means the client's round never
landed — its params are kept, it casts no vote, and it is billed no bits.
PFed1BS's three executors (core/pfed1bs.py fused/staged,
launch/fedexec.py sharded) and BaselineFL (core/baselines.py) all resolve
participants through `draw_participants` and apply updates through
`scatter_rows`, so the invariant cannot silently diverge between them.

ADVERSARY / PRIVACY INJECTION POINT (DESIGN.md §10): Byzantine corruption
and randomized-response bit flips also live here and only here. Both act
on the TRANSMITTED sketch — post-encode, pre-vote — never on the client's
local model: an attacked system is hurt through the corrupted consensus
it broadcasts back, which is the paper's actual attack surface. The math
is seed-deterministic and keyed by (seed, round, client id), NOT by the
cohort position, so the fused, sharded and async executors all corrupt
the same (round, client) pairs bit-for-bit (tests/test_robust.py). The
adversary/privacy OBJECTS are frozen dataclasses in exp/scenarios.py
(the scenario axis); they delegate every number back to the functions
below, mirroring how the partition axis delegates to data/synthetic.py —
core never imports exp.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def draw_participants(key, num_clients: int, capacity: int, participants):
    """Resolve a round's (idx (S,), active (S,)) pair: the externally drawn
    one (exp/scenarios.py participation models — S must equal the engine's
    static `participate` capacity) or the default uniform S-of-K sample,
    all active."""
    if participants is None:
        idx = jax.random.permutation(key, num_clients)[:capacity]
        return idx, jnp.ones((capacity,), jnp.float32)
    idx, active = participants
    return idx, active.astype(jnp.float32)


def scatter_rows(tree, idx, rows, active):
    """Stacked-pytree row scatter with straggler masking: leaf[idx] <- new
    row where active>0, else the existing row is kept. tree: (K, ...)
    leaves; rows: (S, ...) leaves; idx (S,) distinct; active (S,)."""
    def one(old, new):
        act = active.reshape((-1,) + (1,) * (new.ndim - 1))
        kept = jnp.where(act > 0, new.astype(old.dtype), old[idx])
        return old.at[idx].set(kept)

    return jax.tree.map(one, tree, rows)


# --- Byzantine adversary axis (DESIGN.md §10) --------------------------------

def byzantine_mask(seed: int, num_clients: int, fraction: float) -> jax.Array:
    """The STATIC Byzantine membership: exactly round(fraction * K) clients,
    chosen by a seeded permutation. A pure function of (seed, K, fraction) —
    every executor recomputes it at trace time and gets the identical (K,)
    0/1 float mask, which is what makes injection seed-deterministic across
    the fused, sharded and async paths."""
    count = int(round(fraction * num_clients))
    count = max(0, min(num_clients, count))
    mask = jnp.zeros((num_clients,), jnp.float32)
    if count == 0:
        return mask
    perm = jax.random.permutation(jax.random.key(seed), num_clients)
    return mask.at[perm[:count]].set(1.0)


def corrupt_sign_flip(zs: jax.Array, byz: jax.Array) -> jax.Array:
    """Sign-flip attack: Byzantine rows transmit -z (vote exactly against
    their own honest sketch). zs: (S, m); byz: (S,) 0/1."""
    return jnp.where(byz[:, None] > 0, -zs, zs)


def corrupt_scaled(zs: jax.Array, byz: jax.Array, scale: float) -> jax.Array:
    """Magnitude attack: Byzantine rows transmit scale * z. Under one-bit
    sign quantization this is PROVABLY a no-op for any scale > 0 —
    sign(scale * z) == sign(z) — which tests/test_robust.py pins bit-exactly
    (the property holds whenever scaling does not underflow a negative value
    to -0.0 or overflow to a non-finite; see ScaledGarbage's docstring)."""
    return jnp.where(byz[:, None] > 0, scale * zs, zs)


def colluding_target(target_key: int, m: int) -> jax.Array:
    """The crafted consensus a colluding bloc agrees on: one Rademacher
    (m,) sign vector derived from `target_key`, identical at every round
    and on every executor."""
    return jax.random.rademacher(
        jax.random.key(target_key), (m,), dtype=jnp.float32
    )


def corrupt_colluding(zs: jax.Array, byz: jax.Array,
                      target: jax.Array) -> jax.Array:
    """Colluding-bloc attack: every Byzantine row transmits the SAME crafted
    sketch, maximizing their joint pull on the vote (uncoordinated attackers
    partially cancel; a bloc never does)."""
    return jnp.where(byz[:, None] > 0, target[None, :], zs)


def corrupt_cohort(adversary, zs: jax.Array, idx: jax.Array, rnd,
                   num_clients: int) -> jax.Array:
    """THE adversary hook every executor routes its cohort sketches through
    (post-encode, pre-vote). `adversary` is any object with
    .corrupt(zs, idx, rnd, num_clients) — the frozen dataclasses in
    exp/scenarios.py — or None (identity, no trace change). zs: (S, m)
    float sketches of cohort `idx`; rnd: the round/version counter (traced
    int32 is fine)."""
    if adversary is None:
        return zs
    if rnd is None:
        rnd = jnp.int32(0)
    return adversary.corrupt(zs, idx, rnd, num_clients)


# --- randomized-response privacy axis (DESIGN.md §10) ------------------------

def rr_flip_probability(epsilon: float) -> float:
    """Binary randomized response calibrated to epsilon-LDP: each uplink
    bit is kept with probability p = e^eps / (1 + e^eps) and flipped with
    q = 1 - p = 1 / (1 + e^eps); p/q = e^eps is the LDP constraint."""
    assert epsilon > 0, f"randomized response needs epsilon > 0, got {epsilon}"
    return 1.0 / (1.0 + math.exp(epsilon))


def rr_debias(epsilon: float) -> float:
    """Unbiasing factor for RR'd sign votes: E[flipped sign] =
    (p - q) * sign = tanh(eps/2) * sign, so dividing the vote weights by
    tanh(eps/2) makes the weighted sign-sum an unbiased estimator of the
    non-private one. A sign vote is invariant to uniform positive weight
    scaling, so with a single epsilon this is a principled no-op — carried
    anyway so per-client epsilons compose correctly."""
    assert epsilon > 0, f"randomized response needs epsilon > 0, got {epsilon}"
    return 1.0 / math.tanh(epsilon / 2.0)


def rr_flip(signs: jax.Array, idx: jax.Array, rnd, seed: int,
            epsilon: float) -> jax.Array:
    """Flip each uplink sign bit independently with the RR-calibrated
    probability. The flip stream is keyed by (seed, round, CLIENT ID) —
    never by cohort position — so every executor flips the same bits of
    the same (round, client) pairs. signs: (S, m) in {-1,+1}; idx: (S,)
    client ids; rnd: round/version counter."""
    q = rr_flip_probability(epsilon)
    if rnd is None:
        rnd = jnp.int32(0)
    base = jax.random.fold_in(jax.random.key(seed), rnd)

    def one(row, cid):
        flip = jax.random.bernoulli(jax.random.fold_in(base, cid), q, row.shape)
        return jnp.where(flip, -row, row)

    return jax.vmap(one)(signs, idx)


def privatize_signs(privacy, signs: jax.Array, idx: jax.Array,
                    rnd) -> jax.Array:
    """THE privacy hook for the uplink wire signs (post-quantize, post-EF —
    the flip happens at transmission; a client's own EF residual uses its
    true signs, since the client knows what it computed). `privacy` is any
    object with .flip(signs, idx, rnd) — exp/scenarios.py's
    RandomizedResponse — or None (identity)."""
    if privacy is None:
        return signs
    return privacy.flip(signs, idx, rnd)
