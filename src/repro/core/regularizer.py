"""Sign-based personalization regularizer (paper Eqs. 2-7).

g(v, Phi w) = ||[v . Phi w]_-||_1 measures sign disagreement between the
projected local model and the global consensus v. The smoothed surrogate
replaces ||z||_1 by h_gamma(z) = (1/gamma) sum log cosh(gamma z_i), giving

    g~(v, z) = h_gamma(z) - <v, z>            (Eq. 5, factor 1/2 absorbed)
    d g~/dz  = tanh(gamma z) - v              (Eq. 7)

so the w-gradient is Phi^T (tanh(gamma Phi w) - v) via the sketch adjoint.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sketch as sk

_LOG2 = 0.6931471805599453


def logcosh(y: jax.Array) -> jax.Array:
    """Numerically stable log(cosh(y)) (no overflow for large |y|)."""
    a = jnp.abs(y)
    return a + jnp.log1p(jnp.exp(-2.0 * a)) - _LOG2


def h_gamma(z: jax.Array, gamma: float) -> jax.Array:
    """Smooth surrogate for ||z||_1; -> ||z||_1 as gamma -> inf."""
    return jnp.sum(logcosh(gamma * z)) / gamma


def one_sided_l1(x: jax.Array, y: jax.Array) -> jax.Array:
    """Exact regularizer g(x,y) = ||[x . y]_-||_1 (Eq. 2)."""
    return jnp.sum(jax.nn.relu(-(x * y)))


def smoothed_reg(v: jax.Array, z: jax.Array, gamma: float) -> jax.Array:
    """g~(v, z) of Eq. 5 (z = Phi w)."""
    return h_gamma(z, gamma) - jnp.vdot(v, z)


def reg_grad_z(v: jax.Array, z: jax.Array, gamma: float) -> jax.Array:
    """d g~/dz = tanh(gamma z) - v (Eq. 7, pre-adjoint)."""
    return jnp.tanh(gamma * z) - v


def reg_value_and_grad_w(
    spec: sk.SketchSpec, w_flat: jax.Array, v: jax.Array, gamma: float
):
    """(g~(v, Phi w), Phi^T (tanh(gamma Phi w) - v)) — one fwd + one adjoint FHT."""
    z = sk.sketch_forward(spec, w_flat)
    val = smoothed_reg(v, z, gamma)
    gw = sk.sketch_adjoint(spec, reg_grad_z(v, z, gamma))
    return val, gw
