"""Streamed per-leaf sketch encode/decode: O(max-leaf + m) peak memory.

The paper sketches w in R^n; at LM scale materializing that flat vector —
or even the whole parameter tree at once — is exactly what a
memory-frugal client must not do. Because the leaf-layout treesketch
(core/treesketch.py) is block-diagonal PER LEAF, the uplink encode can
stream: pull one leaf at a time from a lazy source (a checkpoint on disk,
models/io.checkpoint_leaf_reader; a remote shard), push it through the
fused SRHT kernel, write its block into the (m,) accumulator, drop it.
The only objects ever live are the current leaf, its sketch block, and
the accumulator — peak bytes O(max-leaf + m), never O(n). The decode
mirror (`stream_adjoint`) walks Phi^T v the same way, emitting one leaf
at a time to a sink.

`MemMeter` is the accounting of that PROTOCOL: it counts the bytes the
streaming client holds live and tracks the peak — an invariant the tests
assert (`stream_peak_bound` is the exact closed form) and
benchmarks/fl_lm_bench.py records per model size, not a measurement of
allocator internals.

Bit-exactness: each leaf's block is produced by the same
`sketch_forward_2d(spec, ...)` program the materialized
`tree_sketch_forward` runs, so the streamed sketch is bit-exact with
`flat_view(tree_sketch_forward(tspec, tree))` — the fl_lm bench's parity
cell and tests/test_fed_lm.py pin this.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import numpy as np

from repro.core import sketch as sk
from repro.core import treesketch as ts


class MemMeter:
    """Live/peak byte meter for the streaming protocol."""

    def __init__(self):
        self.live = 0
        self.peak = 0

    def alloc(self, nbytes: int) -> None:
        self.live += int(nbytes)
        self.peak = max(self.peak, self.live)

    def free(self, nbytes: int) -> None:
        self.live -= int(nbytes)

    @contextlib.contextmanager
    def holding(self, nbytes: int):
        self.alloc(nbytes)
        try:
            yield
        finally:
            self.free(nbytes)


def stream_peak_bound(tspec: ts.TreeSketchSpec, itemsize: int = 4) -> int:
    """The exact peak `stream_sketch`'s meter reports for `itemsize`-byte
    leaves: the (m,) fp32 accumulator plus the largest (leaf + its fp32
    sketch block) pair. O(max-leaf + m) by construction — compare against
    the O(n) flat vector (4n bytes) a materialized encode holds."""
    return 4 * tspec.m + max(
        itemsize * spec.n + 4 * spec.m for _, spec, _, _ in tspec.entries
    )


@functools.lru_cache(maxsize=512)
def _leaf_encoder(spec, major):
    return jax.jit(lambda leaf: sk.sketch_forward_2d(spec, ts._to_major(leaf, major)))


@functools.lru_cache(maxsize=512)
def _leaf_decoder(spec, shape, major, dtype):
    def dec(block):
        wi = sk.sketch_adjoint(spec, block)
        return ts._from_major(wi, shape, major).astype(dtype)

    return jax.jit(dec)


def stream_sketch(tspec: ts.TreeSketchSpec, get_leaf, *, meter=None) -> np.ndarray:
    """z = Phi w, one leaf at a time: `get_leaf(path)` -> array for each
    spec entry (called once each, in entry order — a lazy npz reader, a
    shard fetch). Returns the (m,) float32 sketch in flat_view layout,
    bit-exact with `flat_view(tree_sketch_forward(tspec, tree))`.

    meter: optional MemMeter; the accumulator is counted for the whole
    call, each leaf and its block only while live — so meter.peak ==
    stream_peak_bound(tspec) for fp32 leaves.
    """
    meter = MemMeter() if meter is None else meter
    out = np.zeros((tspec.m,), np.float32)
    with meter.holding(out.nbytes):
        for path, spec, off, major in tspec.entries:
            leaf = np.asarray(get_leaf(path))
            with meter.holding(leaf.nbytes):
                block = np.asarray(_leaf_encoder(spec, major)(leaf))
                with meter.holding(block.nbytes):
                    out[off : off + spec.m] = block.reshape(-1)
            del leaf, block
    return out


def stream_adjoint(tspec: ts.TreeSketchSpec, v, template, emit, *, meter=None):
    """w = Phi^T v, one leaf at a time (the decode mirror): per entry the
    (m_i,) block of `v` is decoded into its leaf and handed to
    `emit(path, leaf)` — an npz writer, a shard push — so the full tree is
    never resident. template: pytree of arrays/ShapeDtypeStructs giving
    leaf shapes/dtypes (eval_shape output is fine; nothing is read but
    shape/dtype)."""
    meter = MemMeter() if meter is None else meter
    shapes = {p: (tuple(l.shape), np.dtype(l.dtype))
              for p, l in ts._leaf_paths(template)}
    v = np.asarray(v, np.float32)
    with meter.holding(v.nbytes):
        for path, spec, off, major in tspec.entries:
            shape, dtype = shapes[path]
            block = v[off : off + spec.m].reshape(spec.num_chunks, spec.m_chunk)
            leaf = np.asarray(
                _leaf_decoder(spec, shape, major, dtype.name)(block)
            )
            with meter.holding(leaf.nbytes):
                emit(path, leaf)
            del leaf
