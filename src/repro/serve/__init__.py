"""Personalized serving tier (DESIGN.md §7).

Three layers:
  store.py   — client-state codec: one fp32 base model + per-client
               bit-packed one-bit sketch residuals with an EDEN-style
               optimal scale; batched fused-adjoint decode.
  engine.py  — multi-tenant batched inference: per-client requests grouped
               into vmapped decode batches over models/lm.decode_step,
               with an LRU cache of hot materialized models.
  router.py  — request-stream harness: Zipf-distributed client traffic
               driven through the engine, with latency/throughput stats.
"""
from repro.serve.store import DenseStore, SketchStore, StoreSpec, make_store_spec
