"""Request-stream harness: Zipf-distributed client traffic over the engine.

Real personalized-serving traffic is heavy-tailed — a small set of hot
clients produces most requests while the long tail is cold. The router
simulates that regime: client ranks draw from a Zipf(alpha) law, ranks map
to client ids through a fixed permutation (hot clients are arbitrary ids,
not 0..h), and every request carries a random prompt. Driving the engine
with this stream exercises exactly the trade the serving tier makes:
LRU-resident hot models decode straight away; tail requests pay one
batched sketch-store reconstruct.

`run_stream` returns a StreamReport with the numbers the serving bench
publishes (tokens/sec, p50/p99 materialization latency, hit rate). The
percentiles are sketch-derived (obs/hist.py): the engine keeps NO
per-request latency list, and the report carries the sketch snapshot
plus the resident telemetry byte count so the bounded-memory claim is a
published number, not a comment.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.serve.engine import ServeEngine


def zipf_probs(num_clients: int, alpha: float = 1.1) -> np.ndarray:
    """P(rank = i) ∝ 1 / (i+1)^alpha, normalized over num_clients ranks."""
    p = 1.0 / np.arange(1, num_clients + 1, dtype=np.float64) ** alpha
    return p / p.sum()


def zipf_stream(
    seed: int, num_clients: int, num_requests: int, alpha: float = 1.1
) -> np.ndarray:
    """(num_requests,) client ids, Zipf-heavy with permuted rank->id map."""
    rng = np.random.RandomState(seed)
    rank_to_id = rng.permutation(num_clients)
    ranks = rng.choice(num_clients, size=num_requests, p=zipf_probs(num_clients, alpha))
    return rank_to_id[ranks].astype(np.int64)


def random_prompts(
    seed: int, num_requests: int, prompt_len: int, vocab: int
) -> np.ndarray:
    rng = np.random.RandomState(seed)
    return rng.randint(0, vocab, size=(num_requests, prompt_len)).astype(np.int32)


@dataclasses.dataclass
class StreamReport:
    num_clients: int
    num_requests: int
    zipf_alpha: float
    wall_s: float
    tokens_per_sec: float           # generated tokens / decode wall time
    end_to_end_tokens_per_sec: float  # generated tokens / total wall time
    hit_rate: float
    materialize_calls: int
    materialize_p50_ms: float       # sketch-derived (rel err <= rel_acc)
    materialize_p99_ms: float
    materialize_total_s: float
    tokens_generated: int
    lru_hits: int = 0               # unique-id LRU counters (obs registry
    lru_misses: int = 0             # mirrors these as lru_hits/lru_misses)
    telemetry_bytes: int = 0        # resident sketch+ring bytes (bounded)
    materialize_max_ms: float = 0.0  # exact tracked max
    mat_sketch: dict | None = None  # serialized QuantileSketch — mergeable
    #                                 across shards/streams via hist.merged

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def run_stream(
    engine: ServeEngine,
    client_ids: np.ndarray,
    prompts: np.ndarray,
    *,
    zipf_alpha: float = float("nan"),
    warm: bool = False,
) -> StreamReport:
    """Drive every (client_id, prompt) request through the engine.

    warm=True first serves one throwaway FULL group (max_batch copies of
    request 0) so both compiled shapes the stream will hit — the b=max_batch
    vmapped decode and the padded materialize batch — exist before the
    timer starts. (A partial trailing group still retraces at its own batch
    size; the engine pads materialize but decode batches are exact-size.)"""
    if warm:
        for _ in range(engine.cfg.max_batch):
            engine.submit(int(client_ids[0]), prompts[0])
        engine.flush()
        engine.reset_stats()
        engine.lru._d.clear()            # cold store for the measured stream

    t0 = time.perf_counter()
    for cid, prompt in zip(client_ids, prompts):
        engine.submit(int(cid), prompt)
    engine.flush()
    wall = time.perf_counter() - t0

    s = engine.stats()
    store = engine.store
    num_clients = (
        store.sspec.num_clients if hasattr(store, "sspec") else store.num_clients
    )
    return StreamReport(
        num_clients=num_clients,
        num_requests=len(client_ids),
        zipf_alpha=zipf_alpha,
        wall_s=wall,
        tokens_per_sec=s["tokens_per_sec"],
        end_to_end_tokens_per_sec=s["tokens_generated"] / max(wall, 1e-9),
        hit_rate=s["hit_rate"],
        materialize_calls=s["materialize_calls"],
        materialize_p50_ms=s["materialize_p50_ms"],
        materialize_p99_ms=s["materialize_p99_ms"],
        materialize_total_s=s["materialize_total_s"],
        tokens_generated=s["tokens_generated"],
        lru_hits=s["lru_hits"],
        lru_misses=s["lru_misses"],
        telemetry_bytes=s["telemetry_bytes"],
        materialize_max_ms=s["materialize_max_ms"],
        mat_sketch=engine.mat_ms.to_dict(),
    )
