"""Client-state store: one base model + per-client one-bit sketch residuals.

After federated training every client owns a personalized model w_k. Storing
K full fp32 models costs 32nK bits; at the ROADMAP's "millions of users"
that is the serving fleet's dominant memory bill. The same SRHT machinery
that compresses the paper's wire compresses the *state*: keep one fp32 base
w_base (e.g. the client average) and, per client, only the bit-packed signs
of the sketched residual

    z_k   = Phi r_k,          r_k = w_k - w_base
    store = (sign bits of z_k, alpha_k)       # m bits + one fp32 per pass

decoded on demand as

    w_hat_k = w_base + sum_p alpha_k^p * Phi_p^T sign(z_k^p).

The scale alpha = <z, sign z> / n' = sum|z| / n' is the exact least-squares
optimum of min_a ||a * Phi^T s - r||^2: each SRHT block satisfies
Phi Phi^T = (c/m) I exactly (Lemma 2's Q Q^T = I argument), so the
normal-equation denominator s^T Phi Phi^T s collapses to the padded block
size. At m = n (square rotation, the default) this is EDEN's optimal
unbiased one-bit scale <r, sign r>/n evaluated in the rotated basis
(Vargaftik et al. 2022, cf. core/baselines.py), and the store costs
~1 bit/param -> ~32x below fp32.

`passes` stacks greedy refinement rounds: pass p sketches the residual the
first p-1 passes failed to reconstruct, under an independently-seeded
operator. Each pass keeps fraction ~2/pi of the remaining residual energy
(at m = n), at m bits + 32 per client.

Encode runs the existing fused SRHT forward (kernels/srht.py) and the
sign/bit-pack kernel (kernels/onebit.py); decode is the batched fused
adjoint (kernels/ops.srht_adjoint_batched_2d) — B clients materialize in
ONE kernel pass per (pass, layout-block). Both `flat` (global-ravel SRHT)
and `leaf` (per-leaf block SRHT, core/treesketch.py) layouts are supported;
they are different-but-equivalent operators, mirroring PFed1BSConfig.layout.

Checkpointing: `state_tree()` / `from_state_tree()` round-trip the packed
words + scales + base through checkpoint/ckpt.py (see save_client_store /
load_client_store there).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatten
from repro.core import sketch as sk
from repro.core import treesketch as ts
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """Static description of the codec: one sketch operator per pass."""

    layout: str            # "flat" | "leaf"
    num_clients: int
    m_ratio: float         # sketch rows per parameter per pass (1.0 = EDEN)
    chunk: int
    seed: int
    passes: int
    n: int                 # parameters per client model
    m: int                 # sketch rows per pass
    n_pad: int             # sum of padded block sizes (the alpha denominator)
    flat_specs: tuple      # (SketchSpec, ...) per pass   (layout == "flat")
    tree_specs: tuple      # (TreeSketchSpec, ...) per pass (layout == "leaf")

    @property
    def words_per_pass(self) -> int:
        return -(-self.m // 32)


def make_store_spec(
    template,
    num_clients: int,
    *,
    m_ratio: float = 1.0,
    chunk: int = 4096,
    seed: int = 0,
    passes: int = 1,
    layout: str = "flat",
) -> StoreSpec:
    """Build the codec spec for `num_clients` models shaped like template.

    m_ratio=1.0 (default) is the square-rotation/EDEN regime: ~1 bit per
    parameter per pass. Lower ratios subsample (more compression, more
    reconstruction error); `passes` > 1 stacks refinement rounds."""
    assert layout in ("flat", "leaf"), layout
    assert passes >= 1
    n = flatten.tree_size(template)
    if layout == "flat":
        specs = tuple(
            sk.make_sketch_spec(
                n, m_ratio, chunk=chunk, seed=seed + 7919 * p, mode="chunked"
            )
            for p in range(passes)
        )
        m, n_pad = specs[0].m, specs[0].n_pad
        return StoreSpec(
            layout=layout, num_clients=num_clients, m_ratio=m_ratio,
            chunk=chunk, seed=seed, passes=passes, n=n, m=m, n_pad=n_pad,
            flat_specs=specs, tree_specs=(),
        )
    tspecs = tuple(
        ts.make_tree_sketch_spec(
            template, m_ratio, chunk=chunk, seed=seed + 7919 * p
        )
        for p in range(passes)
    )
    n_pad = sum(spec.n_pad for _, spec, _, _ in tspecs[0].entries)
    return StoreSpec(
        layout=layout, num_clients=num_clients, m_ratio=m_ratio, chunk=chunk,
        seed=seed, passes=passes, n=n, m=tspecs[0].m, n_pad=n_pad,
        flat_specs=(), tree_specs=tspecs,
    )


# ---------------------------------------------------------------------------
# Pure codec (jitted; StoreSpec is static)
# ---------------------------------------------------------------------------

def _sign(z):
    return jnp.sign(z) + (z == 0)            # {-1,+1}, zero -> +1 (pack conv.)


def _pack(sspec: StoreSpec, signs):
    """(..., m) {-1,+1} -> (..., W) uint32, zero-padded to the word boundary
    (pad bits pack as +1 and are sliced off again at decode)."""
    pad = (-sspec.m) % 32
    widths = [(0, 0)] * (signs.ndim - 1) + [(0, pad)]
    return kops.pack_signs(jnp.pad(signs, widths))


def _forward_flat_view(sspec: StoreSpec, p: int, r):
    """z = Phi_p r as one (m,) vector. r: flat (n,) for layout=flat, a
    residual pytree for layout=leaf."""
    if sspec.layout == "flat":
        return sk.sketch_forward(sspec.flat_specs[p], r)
    return ts.flat_view(
        sspec.tree_specs[p], ts.tree_sketch_forward(sspec.tree_specs[p], r)
    )


def _adjoint_from_flat_view(sspec: StoreSpec, p: int, v, template):
    """Phi_p^T v for one client. v: (m,); returns r-shaped (flat vector or
    pytree) to mirror _forward_flat_view."""
    if sspec.layout == "flat":
        return sk.sketch_adjoint(sspec.flat_specs[p], v)
    tspec = sspec.tree_specs[p]
    vd = {
        path: jax.lax.dynamic_slice_in_dim(v, off, spec.m).reshape(
            spec.num_chunks, spec.m_chunk
        )
        for path, spec, off, _ in tspec.entries
    }
    return ts.tree_sketch_adjoint(tspec, vd, template)


@functools.partial(jax.jit, static_argnames=("sspec",))
def encode(sspec: StoreSpec, base, params):
    """One client's packed state: (words (P, W) uint32, scales (P,) f32).

    Pass p sketches the residual left over by passes < p (greedy
    refinement); each pass's scale is its own least-squares optimum
    sum|z| / n'. The forward is the fused SRHT kernel; the bit-pack is the
    onebit pack kernel — float sketches exist only transiently."""
    resid = jax.tree.map(
        lambda w, b: w.astype(jnp.float32) - b.astype(jnp.float32),
        params, base,
    )
    if sspec.layout == "flat":
        resid = flatten.ravel(resid)
    words, scales = [], []
    for p in range(sspec.passes):
        z = _forward_flat_view(sspec, p, resid)
        alpha = jnp.sum(jnp.abs(z)) / sspec.n_pad
        signs = _sign(z)
        words.append(_pack(sspec, signs))
        scales.append(alpha)
        if p + 1 < sspec.passes:
            rec = _adjoint_from_flat_view(sspec, p, alpha * signs, resid)
            resid = jax.tree.map(lambda r, w: r - w, resid, rec)
    return jnp.stack(words), jnp.stack(scales)


@functools.partial(jax.jit, static_argnames=("sspec",))
def encode_batch(sspec: StoreSpec, base, params_stacked):
    """vmapped encode: stacked client pytree (leading axis B) ->
    (words (B, P, W), scales (B, P))."""
    return jax.vmap(lambda pr: encode(sspec, base, pr))(params_stacked)


def _unpacked_signs(sspec: StoreSpec, words):
    """(B, P, W) uint32 -> (B, P, m) float32 {-1,+1}."""
    return kops.unpack_signs(words)[..., : sspec.m]


@functools.partial(jax.jit, static_argnames=("sspec",))
def decode_flat_batch(sspec: StoreSpec, words, scales) -> jax.Array:
    """Batched residual reconstruction in the flat layout: (B, P, W) words +
    (B, P) scales -> (B, n) float32 = sum_p alpha_p Phi_p^T sign_p.

    One fused batched-adjoint kernel pass per refinement pass — the whole
    decode batch shares each pass's operator, so B never multiplies kernel
    dispatches (kernels/ops.srht_adjoint_batched_2d)."""
    assert sspec.layout == "flat"
    signs = _unpacked_signs(sspec, words)                  # (B, P, m)
    out = jnp.zeros((words.shape[0], sspec.n), jnp.float32)
    for p in range(sspec.passes):
        w = sk.sketch_adjoint_batched(sspec.flat_specs[p], signs[:, p])
        out = out + scales[:, p, None] * w
    return out


def decode_leaf_batch(sspec: StoreSpec, words, scales, template):
    """Batched residual reconstruction in the leaf layout: returns a stacked
    residual pytree (leading axis B), one fused batched adjoint per
    (pass, leaf). Not jitted itself (template is a shape pytree); the
    per-leaf batched adjoints underneath are."""
    assert sspec.layout == "leaf"
    signs = _unpacked_signs(sspec, words)                  # (B, P, m)
    b = words.shape[0]
    total = None
    for p in range(sspec.passes):
        tspec = sspec.tree_specs[p]
        vd = {
            path: (
                scales[:, p, None]
                * jax.lax.dynamic_slice_in_dim(signs[:, p], off, spec.m, axis=1)
            ).reshape(b, spec.num_chunks, spec.m_chunk)
            for path, spec, off, _ in tspec.entries
        }
        ftmpl = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), template
        )
        rec = ts.tree_sketch_adjoint_batched(tspec, vd, ftmpl)
        total = rec if total is None else jax.tree.map(jnp.add, total, rec)
    return total


def decode_batch(sspec: StoreSpec, base, words, scales, template):
    """Materialize B clients' parameters: base + decoded residuals, cast
    back to the template leaf dtypes. Returns a stacked pytree (axis B).
    Orchestrates the jitted batched-adjoint decoders; stays un-jitted
    because `template` is a shape pytree, not data."""
    if sspec.layout == "flat":
        delta = decode_flat_batch(sspec, words, scales)    # (B, n)
        resid = jax.vmap(lambda d: flatten.unravel_like(d, template))(delta)
    else:
        resid = decode_leaf_batch(sspec, words, scales, template)
    return jax.tree.map(
        lambda b0, r: (
            b0.astype(jnp.float32)[None] + r.astype(jnp.float32)
        ).astype(b0.dtype),
        base, resid,
    )


# ---------------------------------------------------------------------------
# Stores
# ---------------------------------------------------------------------------

def _checked_ids(client_ids, num_clients: int) -> jax.Array:
    """Host-side bounds check: jnp's gather CLAMPS out-of-range ids and
    scatter DROPS them — in a multi-tenant store that silently serves one
    user another user's weights or loses a write. Fail loudly instead."""
    ids = np.asarray(client_ids, np.int64)
    if ids.size and (ids.min() < 0 or ids.max() >= num_clients):
        raise ValueError(
            f"client ids must be in [0, {num_clients}); "
            f"got range [{ids.min()}, {ids.max()}]"
        )
    return jnp.asarray(ids, jnp.int32)

class SketchStore:
    """Mutable serving-side container: base model + K packed client states.

    put/put_batch encode through the fused SRHT forward + pack kernels;
    materialize decodes any id batch in one fused pass per (pass, block).
    `template` is a pytree of ShapeDtypeStructs (or arrays) fixing the
    client model's shapes/dtypes.
    """

    def __init__(self, sspec: StoreSpec, base, template=None):
        self.sspec = sspec
        self.base = base
        self.template = (
            jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), base)
            if template is None
            else template
        )
        k, p, w = sspec.num_clients, sspec.passes, sspec.words_per_pass
        self.words = jnp.zeros((k, p, w), jnp.uint32)
        self.scales = jnp.zeros((k, p), jnp.float32)
        # One jitted decode per store instance (sspec/template closed over —
        # they are static structure, not data). Retraces once per distinct
        # batch size, then every materialize is a single compiled call.
        self._decode = jax.jit(
            lambda base, words, scales: decode_batch(
                self.sspec, base, words, scales, self.template
            )
        )

    def _check_ids(self, client_ids) -> jax.Array:
        return _checked_ids(client_ids, self.sspec.num_clients)

    # -- encode -------------------------------------------------------------

    def put(self, client_id: int, params) -> None:
        cid = self._check_ids([client_id])[0]
        w, s = encode(self.sspec, self.base, params)
        self.words = self.words.at[cid].set(w)
        self.scales = self.scales.at[cid].set(s)

    def put_batch(self, client_ids, params_stacked) -> None:
        """Encode a stacked pytree (leading axis = len(client_ids))."""
        ids = self._check_ids(client_ids)
        w, s = encode_batch(self.sspec, self.base, params_stacked)
        self.words = self.words.at[ids].set(w)
        self.scales = self.scales.at[ids].set(s)

    # -- decode -------------------------------------------------------------

    def materialize(self, client_ids):
        """Stacked approximate client models (leading axis B) for the given
        ids — ONE batched fused-adjoint reconstruct, not B sequential ones."""
        ids = self._check_ids(client_ids)
        return self._decode(self.base, self.words[ids], self.scales[ids])

    def materialize_one(self, client_id: int):
        stacked = self.materialize([client_id])
        return jax.tree.map(lambda a: a[0], stacked)

    def materialize_flat(self, client_ids) -> jax.Array:
        """(B, n) flat parameter vectors (flat layout only)."""
        assert self.sspec.layout == "flat"
        ids = self._check_ids(client_ids)
        delta = decode_flat_batch(self.sspec, self.words[ids], self.scales[ids])
        return flatten.ravel(self.base)[None] + delta

    # -- accounting / persistence -------------------------------------------

    def resident_bytes(self) -> dict:
        """Actual resident state vs an fp32-per-client store (fl/comms.py
        storage_bits is the analytic mirror of this)."""
        k = self.sspec.num_clients
        base_b = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(self.base)
        )
        state_b = self.words.size * 4 + self.scales.size * 4
        fp32 = 4 * self.sspec.n * k
        return {
            "base_bytes": base_b,
            "client_state_bytes": state_b,
            "total_bytes": base_b + state_b,
            "per_client_bytes": (base_b + state_b) / k,
            "fp32_store_bytes": fp32,
            "fp32_per_client_bytes": 4 * self.sspec.n,
            "compression_vs_fp32": fp32 / (base_b + state_b),
        }

    def state_tree(self) -> dict:
        """Checkpoint payload: packed words + scales + base (a plain pytree;
        see checkpoint/ckpt.py save_client_store)."""
        return {"base": self.base, "words": self.words, "scales": self.scales}

    @classmethod
    def from_state_tree(cls, sspec: StoreSpec, state: dict, template=None):
        store = cls(sspec, state["base"], template)
        store.words = jnp.asarray(state["words"], jnp.uint32)
        store.scales = jnp.asarray(state["scales"], jnp.float32)
        return store

    def spec_meta(self) -> dict:
        """JSON-serializable codec parameters (enough to rebuild the spec
        against a template; stored in the checkpoint sidecar)."""
        s = self.sspec
        return {
            "kind": "sketch_store",
            "layout": s.layout, "num_clients": s.num_clients,
            "m_ratio": s.m_ratio, "chunk": s.chunk, "seed": s.seed,
            "passes": s.passes, "n": s.n, "m": s.m,
        }


class DenseStore:
    """fp32-per-client baseline store with the same materialize surface —
    the thing SketchStore is measured against (benchmarks/serve_bench.py)."""

    def __init__(self, num_clients: int, template):
        self.num_clients = num_clients
        self.template = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), template
        )
        self.clients = jax.tree.map(
            lambda l: jnp.zeros((num_clients,) + tuple(l.shape), l.dtype),
            self.template,
        )

    def put(self, client_id: int, params) -> None:
        cid = _checked_ids([client_id], self.num_clients)[0]
        self.clients = jax.tree.map(
            lambda all_, p: all_.at[cid].set(p.astype(all_.dtype)),
            self.clients, params,
        )

    def put_batch(self, client_ids, params_stacked) -> None:
        ids = _checked_ids(client_ids, self.num_clients)
        self.clients = jax.tree.map(
            lambda all_, p: all_.at[ids].set(p.astype(all_.dtype)),
            self.clients, params_stacked,
        )

    def materialize(self, client_ids):
        ids = _checked_ids(client_ids, self.num_clients)
        return jax.tree.map(lambda a: a[ids], self.clients)

    def materialize_one(self, client_id: int):
        cid = _checked_ids([client_id], self.num_clients)[0]
        return jax.tree.map(lambda a: a[cid], self.clients)

    def resident_bytes(self) -> dict:
        per_client = sum(
            int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(self.template)
        )
        total = per_client * self.num_clients
        return {
            "base_bytes": 0,
            "client_state_bytes": total,
            "total_bytes": total,
            "per_client_bytes": per_client,
            "fp32_store_bytes": total,
            "fp32_per_client_bytes": per_client,
            "compression_vs_fp32": 1.0,
        }
