"""Multi-tenant batched inference over a client store (DESIGN.md §7).

One decode batch serves B *different* personalized models at once: the
per-request client model is materialized from the store (one batched
fused-adjoint reconstruct for all of a batch's cache misses), the batch is
stacked along a leading model axis, and `models/lm.decode_step` runs
vmapped over that axis — every request decodes against its own weights and
its own KV cache in a single jitted step. Hot materialized models live in
an LRU so a Zipf-heavy stream (router.py) pays reconstruction only on the
long tail.

The engine is store-agnostic: anything with `materialize(ids) -> stacked
pytree` works (serve/store.SketchStore or the fp32 DenseStore baseline the
benchmarks compare against).
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ArchConfig
from repro.obs import hist as obshist
from repro.obs import registry as obsreg
from repro.obs import trace as obstrace

#: Telemetry memory knobs: the materialize-latency sketch is bounded to
#: this many buckets, and the burn-rate ring keeps this many recent
#: (t, ms) events — together the engine's telemetry footprint is a hard
#: constant, independent of how many requests it has served (asserted in
#: tests/test_serve.py).
SKETCH_MAX_BUCKETS = 128
SLO_RING_EVENTS = 256


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    prompt_len: int = 12
    gen_len: int = 16
    max_batch: int = 8          # requests per vmapped decode batch
    hot_models: int = 8         # LRU capacity (materialized models)


@dataclasses.dataclass
class BatchResult:
    client_ids: list
    tokens: np.ndarray          # (B, gen_len) int32 greedy continuations


class ModelLRU:
    """Hot materialized models, keyed by client id."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, cid):
        if cid in self._d:
            self._d.move_to_end(cid)
            self.hits += 1
            return self._d[cid]
        self.misses += 1
        return None

    def put(self, cid, params) -> None:
        self._d[cid] = params
        self._d.move_to_end(cid)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def __len__(self):
        return len(self._d)


class ServeEngine:
    """Admit per-client requests, serve them in vmapped decode batches.

    submit() enqueues (client_id, prompt); flush() drains the queue in
    groups of at most max_batch, materializing each group's cold models
    with ONE batched store decode. Requests in a group run in lockstep
    (shared prompt_len/gen_len — the admission contract), each against its
    own model and KV cache.
    """

    def __init__(self, arch: ArchConfig, store, cfg: EngineConfig,
                 tracer=None):
        self.arch = arch
        self.store = store
        self.cfg = cfg
        # request→materialize→decode spans on the wall clock + LRU counters
        # (DESIGN.md §12); NOOP tracer by default — zero overhead unprobed
        self.tracer = obstrace.NOOP if tracer is None else tracer
        self.registry = obsreg.MetricsRegistry(tracer=self.tracer)
        self.lru = ModelLRU(cfg.hot_models)
        self._pending = []
        # bounded telemetry (DESIGN.md §14): materialize wall-times go
        # into a mergeable quantile sketch (milliseconds) instead of an
        # unbounded list, plus a fixed ring of recent (t, ms) events for
        # SLO burn-rate windows — resident bytes are independent of the
        # request count
        self.mat_ms = obshist.QuantileSketch(
            rel_acc=0.01, max_buckets=SKETCH_MAX_BUCKETS
        )
        self.mat_recent = collections.deque(maxlen=SLO_RING_EVENTS)
        self.mat_total_s = 0.0
        self.req_hits = 0           # per-REQUEST counters (a group of 4
        self.req_misses = 0         # requests for one cold client is 4
        #                             misses; ModelLRU counts unique ids)
        self.decode_seconds = 0.0
        self.tokens_generated = 0
        self._t_start = time.perf_counter()

        def one_step(params, token, cache, pos):
            logits, cache = lm.decode_step(arch, params, token, cache, pos)
            return logits[0, 0], cache          # (vocab_pad,)

        # vmap over the leading model axis: B requests, B models, B caches.
        # (No cache donation: the CPU backend this container tests on does
        # not implement it and would warn every step.)
        self._decode = jax.jit(jax.vmap(one_step, in_axes=(0, 0, 0, None)))

    # -- admission -----------------------------------------------------------

    def submit(self, client_id: int, prompt) -> None:
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape != (self.cfg.prompt_len,):
            # a raise, not an assert: under python -O a wrong-length prompt
            # would survive to the prefill loop, whose jnp column indexing
            # CLAMPS out of range — serving against a corrupted prompt
            raise ValueError(
                f"prompt must have shape ({self.cfg.prompt_len},); "
                f"got {prompt.shape}"
            )
        self._pending.append((int(client_id), prompt))

    def flush(self) -> list:
        """Serve every pending request; returns [BatchResult, ...]."""
        out = []
        while self._pending:
            group = self._pending[: self.cfg.max_batch]
            self._pending = self._pending[self.cfg.max_batch:]
            cids = [c for c, _ in group]
            prompts = np.stack([p for _, p in group])
            out.append(self.serve_batch(cids, prompts))
        return out

    # -- model acquisition ----------------------------------------------------

    def _params_for(self, cids) -> list:
        """Per-request model list, LRU-first; all of the group's misses are
        decoded by a single batched store.materialize call. The miss batch
        is padded to max_batch (duplicate ids) so the batched reconstruct
        compiles exactly one shape — steady-state p50 latency is one
        compiled kernel pass, never a retrace."""
        cached = {c: self.lru.get(c) for c in dict.fromkeys(cids)}
        misses = [c for c, p in cached.items() if p is None]
        miss_set = set(misses)      # a request misses iff its model was not
        n_miss = sum(c in miss_set for c in cids)             # resident when
        n_hit = sum(c not in miss_set for c in cids)          # it arrived
        self.req_misses += n_miss
        self.req_hits += n_hit
        if n_hit:
            self.registry.add("lru_hits", n_hit)
        if n_miss:
            self.registry.add("lru_misses", n_miss)
        if misses:
            padded = misses + [misses[0]] * (self.cfg.max_batch - len(misses))
            t0 = time.perf_counter()
            with self.tracer.span("materialize", track="serve",
                                  misses=len(misses)):
                stacked = self.store.materialize(padded)
                jax.block_until_ready(stacked)
            t1 = time.perf_counter()
            ms = (t1 - t0) * 1e3
            self.mat_ms.add(ms)
            self.mat_recent.append((t1 - self._t_start, ms))
            self.mat_total_s += t1 - t0
            for i, c in enumerate(misses):
                p = jax.tree.map(lambda a: a[i], stacked)
                cached[c] = p
                self.lru.put(c, p)
        return [cached[c] for c in cids]

    # -- batched multi-tenant decode ------------------------------------------

    def serve_batch(self, cids, prompts: np.ndarray) -> BatchResult:
        """prompts: (B, prompt_len) int32 -> greedy (B, gen_len)."""
        cfg = self.cfg
        b = prompts.shape[0]
        with self.tracer.span("request", track="serve", batch=b):
            params = jax.tree.map(
                lambda *xs: jnp.stack(xs), *self._params_for(cids)
            )
            cache1 = lm.init_cache(self.arch, 1, cfg.prompt_len + cfg.gen_len)
            cache = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (b,) + a.shape), cache1
            )
            prompts = jnp.asarray(prompts, jnp.int32)

            t0 = time.perf_counter()
            with self.tracer.span("decode", track="serve", batch=b,
                                  gen_len=cfg.gen_len):
                logits = None
                for t in range(cfg.prompt_len):       # prefill by stepping
                    tok = prompts[:, t].reshape(b, 1, 1)
                    logits, cache = self._decode(
                        params, tok, cache, jnp.int32(t)
                    )
                toks = []
                cur = jnp.argmax(
                    logits[:, : self.arch.vocab], axis=-1
                ).astype(jnp.int32)
                for t in range(cfg.gen_len):
                    toks.append(cur)
                    tok = cur.reshape(b, 1, 1)
                    logits, cache = self._decode(
                        params, tok, cache, jnp.int32(cfg.prompt_len + t)
                    )
                    cur = jnp.argmax(
                        logits[:, : self.arch.vocab], axis=-1
                    ).astype(jnp.int32)
                tokens = np.stack([np.asarray(t) for t in toks], axis=1)
            self.decode_seconds += time.perf_counter() - t0
            self.tokens_generated += b * cfg.gen_len
        return BatchResult(client_ids=list(cids), tokens=tokens)

    # -- stats -----------------------------------------------------------------

    def stats(self) -> dict:
        """Point-in-time serving telemetry. Percentiles come from the
        mergeable materialize sketch (relative error <= its rel_acc);
        telemetry_bytes is the deterministic resident footprint of the
        sketch + burn ring — bounded regardless of request count."""
        return {
            "requests_hit": self.req_hits,
            "requests_miss": self.req_misses,
            "lru_hits": self.lru.hits,
            "lru_misses": self.lru.misses,
            "hit_rate": self.req_hits / max(self.req_hits + self.req_misses, 1),
            "materialize_calls": int(self.mat_ms.count),
            "materialize_p50_ms": self.mat_ms.quantile(0.50),
            "materialize_p99_ms": self.mat_ms.quantile(0.99),
            "materialize_max_ms": self.mat_ms.max,
            "materialize_total_s": self.mat_total_s,
            "telemetry_bytes": self.telemetry_bytes(),
            "decode_s": self.decode_seconds,
            "tokens_generated": self.tokens_generated,
            "tokens_per_sec": self.tokens_generated
            / max(self.decode_seconds, 1e-9),
        }

    def telemetry_bytes(self) -> int:
        """Resident telemetry accounting: sketch buckets + the bounded
        burn-rate ring (one slot per retained (t, ms) pair). A pure
        function of bounded structure sizes — never of request count."""
        return (self.mat_ms.resident_bytes()
                + obshist.BUCKET_BYTES * len(self.mat_recent))

    def slo_events(self) -> list:
        """Recent (t_seconds, materialize_ms) events for burn-rate
        windows, t on the engine's own clock (0 = construction)."""
        return list(self.mat_recent)

    @property
    def now(self) -> float:
        """Engine-clock time in seconds, the domain of slo_events()."""
        return time.perf_counter() - self._t_start

    def reset_stats(self) -> None:
        self.lru.hits = self.lru.misses = 0
        self.req_hits = self.req_misses = 0
        self.mat_ms = obshist.QuantileSketch(
            rel_acc=0.01, max_buckets=SKETCH_MAX_BUCKETS
        )
        self.mat_recent = collections.deque(maxlen=SLO_RING_EVENTS)
        self.mat_total_s = 0.0
        self.decode_seconds = 0.0
        self.tokens_generated = 0
        self.registry = obsreg.MetricsRegistry(tracer=self.tracer)
