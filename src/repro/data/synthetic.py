"""Synthetic federated datasets (offline container: no dataset downloads).

Two generators:

* `make_federated_classification` — class-conditional image data with the
  paper's label-skew protocol ("partition data among 20 clients based on
  labels"): each client sees only `classes_per_client` of the classes.
  Class templates are fixed random images; samples are template + noise,
  so the Bayes classifier is learnable and personalization has signal:
  a personalized model only needs its client's classes.

* `make_federated_lm` — per-client skewed token streams for LM federated
  fine-tuning (each client has its own favored vocabulary slice), used by
  the LLM FL examples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class FedClassification:
    train_x: jax.Array  # (K, N, H, W, C)
    train_y: jax.Array  # (K, N)
    test_x: jax.Array   # (K, Nt, H, W, C)
    test_y: jax.Array   # (K, Nt)
    num_classes: int

    @property
    def num_clients(self):
        return self.train_x.shape[0]

    @property
    def weights(self):
        k = self.num_clients
        return jnp.full((k,), 1.0 / k)


def make_federated_classification(
    key,
    num_clients: int = 20,
    num_classes: int = 10,
    image_hw: int = 28,
    channels: int = 1,
    train_per_client: int = 256,
    test_per_client: int = 128,
    classes_per_client: int = 2,
    noise: float = 0.6,
    concept_shift: bool = False,
) -> FedClassification:
    """concept_shift=True additionally applies a per-client label permutation
    (same inputs, client-specific labels) — the regime where a single global
    model mathematically cannot fit all clients and personalization is
    required (the paper's CIFAR-100 collapse phenomenon)."""
    kt, kc, kn, ktn = jax.random.split(key, 4)
    templates = jax.random.normal(kt, (num_classes, image_hw, image_hw, channels))

    # label-skew assignment: client k draws labels from its own class subset
    rng = np.random.RandomState(0)
    client_classes = np.stack(
        [rng.choice(num_classes, classes_per_client, replace=False) for _ in range(num_clients)]
    )
    perms = np.stack([
        rng.permutation(num_classes) if concept_shift else np.arange(num_classes)
        for _ in range(num_clients)
    ])

    def sample(key, classes, perm, n):
        ky, kx = jax.random.split(key)
        idx = jax.random.randint(ky, (n,), 0, classes_per_client)
        c = jnp.asarray(classes)[idx]
        y = jnp.asarray(perm)[c]
        x = templates[c] + noise * jax.random.normal(kx, (n, image_hw, image_hw, channels))
        return x, y

    tr_keys = jax.random.split(kn, num_clients)
    te_keys = jax.random.split(ktn, num_clients)
    trs = [sample(tr_keys[k], client_classes[k], perms[k], train_per_client) for k in range(num_clients)]
    tes = [sample(te_keys[k], client_classes[k], perms[k], test_per_client) for k in range(num_clients)]
    return FedClassification(
        train_x=jnp.stack([t[0] for t in trs]),
        train_y=jnp.stack([t[1] for t in trs]),
        test_x=jnp.stack([t[0] for t in tes]),
        test_y=jnp.stack([t[1] for t in tes]),
        num_classes=num_classes,
    )


def sample_round_batches(key, data: FedClassification, local_steps: int, batch: int):
    """Per-round minibatches for every client: (K, R, B, ...) pytree."""
    k = data.num_clients
    n = data.train_x.shape[1]
    idx = jax.random.randint(key, (k, local_steps, batch), 0, n)
    x = jax.vmap(lambda xs, i: xs[i])(data.train_x, idx)
    y = jax.vmap(lambda ys, i: ys[i])(data.train_y, idx)
    return {"x": x, "y": y}


@dataclasses.dataclass
class FedLM:
    tokens: jax.Array     # (K, N, S+1) int32 token streams
    vocab: int

    @property
    def num_clients(self):
        return self.tokens.shape[0]

    @property
    def weights(self):
        k = self.num_clients
        return jnp.full((k,), 1.0 / k)


def make_federated_lm(
    key, num_clients: int, vocab: int, seq: int, samples_per_client: int = 64,
    skew: float = 0.8,
) -> FedLM:
    """Each client's stream mixes a shared uniform vocabulary with a
    client-specific slice (probability `skew`) — label-skew for LM."""
    slice_size = max(vocab // num_clients, 8)

    def client(k_idx, kk):
        lo = (k_idx * slice_size) % max(vocab - slice_size, 1)
        ku, kc, km = jax.random.split(kk, 3)
        uni = jax.random.randint(ku, (samples_per_client, seq + 1), 0, vocab)
        loc = lo + jax.random.randint(kc, (samples_per_client, seq + 1), 0, slice_size)
        mask = jax.random.bernoulli(km, skew, (samples_per_client, seq + 1))
        return jnp.where(mask, loc, uni).astype(jnp.int32)

    keys = jax.random.split(key, num_clients)
    toks = jnp.stack([client(i, keys[i]) for i in range(num_clients)])
    return FedLM(tokens=toks, vocab=vocab)


def sample_lm_batches(key, data: FedLM, local_steps: int, batch: int):
    k, n, _ = data.tokens.shape
    idx = jax.random.randint(key, (k, local_steps, batch), 0, n)
    seqs = jax.vmap(lambda xs, i: xs[i])(data.tokens, idx)  # (K,R,B,S+1)
    return {"tokens": seqs[..., :-1], "labels": seqs[..., 1:]}
