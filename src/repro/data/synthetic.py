"""Synthetic federated datasets (offline container: no dataset downloads).

Two direct generators:

* `make_federated_classification` — class-conditional image data with the
  paper's label-skew protocol ("partition data among 20 clients based on
  labels"): each client sees only `classes_per_client` of the classes.
  Class templates are fixed random images; samples are template + noise,
  so the Bayes classifier is learnable and personalization has signal:
  a personalized model only needs its client's classes.

* `make_federated_lm` — per-client skewed token streams for LM federated
  fine-tuning (each client has its own favored vocabulary slice), used by
  the LLM FL examples.

Plus the pool-and-partition path the scenario-matrix harness
(src/repro/exp/, DESIGN.md §8) composes its heterogeneity axes from:

* `make_classification_pool` — one centralized labeled pool drawn from the
  same template+noise family.
* `dirichlet_partition` — per-class Dirichlet(alpha) split of the pool
  indices over clients (Hsu et al.; the protocol FedSKETCH/DisPFL sweep):
  alpha -> inf recovers IID, alpha -> 0 recovers one-class-per-client
  label skew. Every pool index lands on exactly one client.
* `label_skew_partition` — the paper's fixed protocol expressed as a
  partition: each client owns `classes_per_client` classes; each class's
  indices are split evenly among its owners.
* `imbalance_counts` / `materialize_from_partition` — lognormal per-client
  sample-count imbalance, then fixed-shape (K, N, ...) client arrays
  resampled from each client's own index set (true distinct-sample counts
  are kept in `FedClassification.counts` and drive the p_k weights).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class FedClassification:
    train_x: jax.Array  # (K, N, H, W, C)
    train_y: jax.Array  # (K, N)
    test_x: jax.Array   # (K, Nt, H, W, C)
    test_y: jax.Array   # (K, Nt)
    num_classes: int
    counts: jax.Array | None = None  # (K,) true distinct-sample counts when
    #                                  the clients were materialized from an
    #                                  (imbalanced) pool partition

    @property
    def num_clients(self):
        return self.train_x.shape[0]

    @property
    def weights(self):
        """Aggregation weights p_k: proportional to the client's true sample
        count when known (pool-partition path), else uniform."""
        k = self.num_clients
        if self.counts is None:
            return jnp.full((k,), 1.0 / k)
        c = jnp.asarray(self.counts, jnp.float32)
        return c / jnp.maximum(jnp.sum(c), 1e-9)


def make_federated_classification(
    key,
    num_clients: int = 20,
    num_classes: int = 10,
    image_hw: int = 28,
    channels: int = 1,
    train_per_client: int = 256,
    test_per_client: int = 128,
    classes_per_client: int = 2,
    noise: float = 0.6,
    concept_shift: bool = False,
) -> FedClassification:
    """concept_shift=True additionally applies a per-client label permutation
    (same inputs, client-specific labels) — the regime where a single global
    model mathematically cannot fit all clients and personalization is
    required (the paper's CIFAR-100 collapse phenomenon)."""
    kt, kc, kn, ktn = jax.random.split(key, 4)
    templates = jax.random.normal(kt, (num_classes, image_hw, image_hw, channels))

    # label-skew assignment: client k draws labels from its own class subset
    rng = np.random.RandomState(0)
    client_classes = np.stack(
        [rng.choice(num_classes, classes_per_client, replace=False) for _ in range(num_clients)]
    )
    perms = np.stack([
        rng.permutation(num_classes) if concept_shift else np.arange(num_classes)
        for _ in range(num_clients)
    ])

    def sample(key, classes, perm, n):
        ky, kx = jax.random.split(key)
        idx = jax.random.randint(ky, (n,), 0, classes_per_client)
        c = jnp.asarray(classes)[idx]
        y = jnp.asarray(perm)[c]
        x = templates[c] + noise * jax.random.normal(kx, (n, image_hw, image_hw, channels))
        return x, y

    tr_keys = jax.random.split(kn, num_clients)
    te_keys = jax.random.split(ktn, num_clients)
    trs = [sample(tr_keys[k], client_classes[k], perms[k], train_per_client) for k in range(num_clients)]
    tes = [sample(te_keys[k], client_classes[k], perms[k], test_per_client) for k in range(num_clients)]
    return FedClassification(
        train_x=jnp.stack([t[0] for t in trs]),
        train_y=jnp.stack([t[1] for t in trs]),
        test_x=jnp.stack([t[0] for t in tes]),
        test_y=jnp.stack([t[1] for t in tes]),
        num_classes=num_classes,
    )


# --- pool-and-partition path (scenario-matrix harness, DESIGN.md §8) --------

def make_classification_pool(
    key,
    num_samples: int,
    num_classes: int = 10,
    image_hw: int = 28,
    channels: int = 1,
    noise: float = 0.6,
):
    """One centralized labeled pool: (x (N, H, W, C), y (N,)) with uniform
    labels from the same class-template family as
    `make_federated_classification` — partitioners below split *this*."""
    kt, ky, kx = jax.random.split(key, 3)
    templates = jax.random.normal(kt, (num_classes, image_hw, image_hw, channels))
    y = jax.random.randint(ky, (num_samples,), 0, num_classes)
    x = templates[y] + noise * jax.random.normal(
        kx, (num_samples, image_hw, image_hw, channels)
    )
    return x, y


def dirichlet_partition(rng, labels, num_clients: int, alpha: float):
    """Partition indices 0..len(labels) over clients: for each class, draw
    proportions ~ Dirichlet(alpha * 1_K) and split that class's shuffled
    indices at the proportional cut points.

    Returns a list of K int arrays that are pairwise disjoint and whose
    union is the full index set (every sample lands on exactly one client).
    alpha -> inf: every client gets ~1/K of every class (IID).
    alpha -> 0:   each class concentrates on one client (label skew).
    """
    labels = np.asarray(labels)
    parts: list[list[np.ndarray]] = [[] for _ in range(num_clients)]
    for c in np.unique(labels):
        idx = rng.permutation(np.flatnonzero(labels == c))
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = np.floor(np.cumsum(props)[:-1] * len(idx)).astype(int)
        for k, piece in enumerate(np.split(idx, cuts)):
            parts[k].append(piece)
    return [
        np.concatenate(p) if p else np.empty((0,), np.int64) for p in parts
    ]


def label_skew_partition(rng, labels, num_clients: int, classes_per_client: int):
    """The paper's fixed label-skew protocol as a pool partition: client k
    owns `classes_per_client` classes; each class's indices are split evenly
    among the clients that own it (classes nobody drew go to a random
    client so the partition still covers the full pool)."""
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    owners: list[list[int]] = [[] for _ in range(num_classes)]
    load = np.zeros(num_clients, np.int64)    # distinct classes per client
    for k in range(num_clients):
        for c in rng.choice(num_classes, classes_per_client, replace=False):
            owners[int(c)].append(k)
            load[k] += 1
    for c in range(num_classes):              # orphan class -> least-loaded
        if not owners[c]:
            k = int(np.argmin(load))
            owners[c].append(k)
            load[k] += 1
    parts: list[list[np.ndarray]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = rng.permutation(np.flatnonzero(labels == c))
        if len(idx) == 0:
            continue
        for k, piece in zip(owners[c], np.array_split(idx, len(owners[c]))):
            parts[k].append(piece)
    return [
        np.concatenate(p) if p else np.empty((0,), np.int64) for p in parts
    ]


def iid_partition(rng, labels, num_clients: int):
    """Uniform shuffle-and-split (the alpha -> inf limit, exactly)."""
    idx = rng.permutation(len(np.asarray(labels)))
    return [np.sort(p) for p in np.array_split(idx, num_clients)]


def imbalance_counts(rng, parts, sigma: float):
    """Lognormal per-client sample-count imbalance: client k keeps the first
    ceil(f_k * len(part_k)) of its indices, f_k ~ clipped LogNormal(0, sigma)
    normalized so the largest client keeps everything. sigma=0 keeps all.
    Returns (trimmed parts, counts array)."""
    if sigma <= 0.0:
        return parts, np.asarray([len(p) for p in parts], np.int64)
    f = rng.lognormal(mean=0.0, sigma=sigma, size=len(parts))
    f = f / f.max()
    trimmed = []
    for p, fk in zip(parts, f):
        keep = max(int(np.ceil(fk * len(p))), min(len(p), 1))
        trimmed.append(p[:keep])
    return trimmed, np.asarray([len(p) for p in trimmed], np.int64)


def materialize_from_partition(
    key,
    pool_x,
    pool_y,
    parts,
    train_per_client: int,
    test_per_client: int,
    num_classes: int,
) -> FedClassification:
    """Fixed-shape (K, N, ...) client arrays from a pool partition.

    Each client's partition is first split DISJOINTLY into a train pool and
    a test pool (proportional to the requested shapes), then each side is
    resampled (with replacement when the pool is smaller than the requested
    shape) from its own side only — no test row is ever a training row, so
    accuracy measures generalization, not memorization. The per-client
    label distribution is the partition's on both sides; the true
    distinct-sample counts land in `counts` and drive `weights`. Clients
    with an empty (or single-sample) partition get random pool samples for
    the missing side — a straggler client still needs a well-formed slot."""
    n_pool = pool_x.shape[0]
    rng = np.random.RandomState(
        int(jax.random.randint(key, (), 0, 2**31 - 1))
    )
    counts = np.asarray([len(p) for p in parts], np.int64)
    test_frac = test_per_client / max(train_per_client + test_per_client, 1)
    tr_idx, te_idx = [], []
    for p in parts:
        p = rng.permutation(p)
        if len(p) >= 2:
            n_te = min(max(int(round(len(p) * test_frac)), 1), len(p) - 1)
            te_pool, tr_pool = p[:n_te], p[n_te:]
        else:   # nothing to split: fall back to random pool rows
            tr_pool = p if len(p) else rng.randint(n_pool, size=1)
            te_pool = rng.randint(n_pool, size=1)
        tr_idx.append(rng.choice(tr_pool, size=train_per_client, replace=True))
        te_idx.append(rng.choice(te_pool, size=test_per_client, replace=True))
    tr = jnp.asarray(np.stack(tr_idx))
    te = jnp.asarray(np.stack(te_idx))
    return FedClassification(
        train_x=pool_x[tr],
        train_y=pool_y[tr],
        test_x=pool_x[te],
        test_y=pool_y[te],
        num_classes=num_classes,
        counts=jnp.asarray(np.maximum(counts, 1)),
    )


def sample_round_batches(key, data: FedClassification, local_steps: int, batch: int):
    """Per-round minibatches for every client: (K, R, B, ...) pytree."""
    k = data.num_clients
    n = data.train_x.shape[1]
    idx = jax.random.randint(key, (k, local_steps, batch), 0, n)
    x = jax.vmap(lambda xs, i: xs[i])(data.train_x, idx)
    y = jax.vmap(lambda ys, i: ys[i])(data.train_y, idx)
    return {"x": x, "y": y}


@dataclasses.dataclass
class FedLM:
    tokens: jax.Array     # (K, N, S+1) int32 token streams
    vocab: int

    @property
    def num_clients(self):
        return self.tokens.shape[0]

    @property
    def weights(self):
        k = self.num_clients
        return jnp.full((k,), 1.0 / k)


def make_federated_lm(
    key, num_clients: int, vocab: int, seq: int, samples_per_client: int = 64,
    skew: float = 0.8,
) -> FedLM:
    """Each client's stream mixes a shared uniform vocabulary with a
    client-specific slice (probability `skew`) — label-skew for LM."""
    slice_size = max(vocab // num_clients, 8)

    def client(k_idx, kk):
        lo = (k_idx * slice_size) % max(vocab - slice_size, 1)
        ku, kc, km = jax.random.split(kk, 3)
        uni = jax.random.randint(ku, (samples_per_client, seq + 1), 0, vocab)
        loc = lo + jax.random.randint(kc, (samples_per_client, seq + 1), 0, slice_size)
        mask = jax.random.bernoulli(km, skew, (samples_per_client, seq + 1))
        return jnp.where(mask, loc, uni).astype(jnp.int32)

    keys = jax.random.split(key, num_clients)
    toks = jnp.stack([client(i, keys[i]) for i in range(num_clients)])
    return FedLM(tokens=toks, vocab=vocab)


def sample_lm_batches(key, data: FedLM, local_steps: int, batch: int):
    k, n, _ = data.tokens.shape
    idx = jax.random.randint(key, (k, local_steps, batch), 0, n)
    seqs = jax.vmap(lambda xs, i: xs[i])(data.tokens, idx)  # (K,R,B,S+1)
    return {"tokens": seqs[..., :-1], "labels": seqs[..., 1:]}
