from repro.data.synthetic import (
    FedClassification, FedLM, make_federated_classification, make_federated_lm,
    sample_round_batches, sample_lm_batches,
)
