"""Partition-spec rules for every architecture family on the production mesh.

Megatron-style tensor parallelism over the "model" axis (column-parallel
up-projections, row-parallel down-projections, vocab-sharded embeddings),
batch over "data" (and the federation/client axis over "pod" on the
multi-pod mesh). Sequence dimensions of decode caches are model-sharded
when heads aren't divisible. `fsdp=True` additionally shards parameter
rows over "data" (a §Perf lever).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

STACK_KEYS = ("layers", "enc_layers")


def _names(path):
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(k.key)
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
    return out


def _rule(names, shape, msize):
    """PartitionSpec for an UNSTACKED leaf (layer axis handled by caller)."""
    last = names[-1]
    div = lambda d: d % msize == 0
    rep = P(*([None] * len(shape)))

    if last == "embed":
        return P("model", None) if div(shape[0]) else rep
    if last == "head":
        return P(None, "model") if div(shape[1]) else rep
    if last in ("wq", "wk", "wv", "wq_b", "wk_b", "wv_b", "w1", "w3", "in_proj", "dt_proj"):
        if len(shape) == 3:  # MoE experts (E, D, F)
            if div(shape[0]):
                return P("model", None, None)
            if div(shape[2]):
                return P(None, None, "model")
            return rep
        return P(None, "model") if div(shape[-1]) else rep
    if last in ("wo", "w2", "out_proj", "x_proj", "conv_w", "A_log"):
        if len(shape) == 3:  # MoE experts (E, F, D)
            if div(shape[0]):
                return P("model", None, None)
            if div(shape[1]):
                return P(None, "model", None)
            return rep
        if len(shape) == 1:  # mamba2 scalar-per-head A_log
            return P("model") if div(shape[0]) else rep
        return P("model", None) if div(shape[0]) else rep
    if last in ("conv_b", "dt_bias", "D", "norm_w") and len(shape) == 1:
        return P("model") if div(shape[0]) else rep
    # router, norms, small projections (wq_a, wkv_a), biases: replicated
    return rep


def param_pspecs(cfg: ArchConfig, template, mesh) -> object:
    """Pytree of PartitionSpec matching a (stacked) param template."""
    msize = mesh.shape["model"]

    def go(path, leaf):
        names = _names(path)
        stacked = any(n in STACK_KEYS for n in names)
        shape = leaf.shape[1:] if stacked else leaf.shape
        spec = _rule(names, shape, msize)
        return P(*((None,) + tuple(spec))) if stacked else spec

    return jax.tree_util.tree_map_with_path(go, template)


def param_major_axes(cfg: ArchConfig, template, mesh) -> object:
    """Index of the model-sharded axis per leaf (for sharding-aware
    tree sketching), or -1 when replicated (-1, not None: None leaves
    vanish under tree flattening)."""
    specs = param_pspecs(cfg, template, mesh)

    def major(spec):
        for i, s in enumerate(spec):
            if s == "model" or (isinstance(s, tuple) and "model" in s):
                return i
        return -1

    return jax.tree.map(major, specs, is_leaf=lambda x: isinstance(x, P))


def _dp_axes(mesh, client_axis: bool):
    """Axes available for batch sharding: 'data', plus 'pod' when serving on
    the multi-pod mesh (training multi-pod uses pod as the client axis)."""
    if client_axis or "pod" not in mesh.shape:
        return ("data",)
    return ("pod", "data")


def _batch_dim_spec(b: int, mesh, axes):
    """Largest prefix of `axes` whose product divides the batch dim."""
    got = []
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
        if b % prod == 0:
            got.append(a)
        else:
            break
    if not got:
        return None
    return tuple(got) if len(got) > 1 else got[0]


def batch_pspecs(cfg: ArchConfig, template, mesh, client_axis: bool = False):
    """Batch sharding: leading batch dim over 'data' (x 'pod' when serving
    multi-pod); client_axis=True adds a leading 'pod' federation axis."""
    axes = _dp_axes(mesh, client_axis)

    def go(leaf):
        shape = leaf.shape[1:] if client_axis else leaf.shape
        spec = (_batch_dim_spec(shape[0], mesh, axes),) if shape else ()
        spec = spec + (None,) * (len(shape) - 1)
        return P(*((("pod",) if client_axis else ()) + spec))

    return jax.tree.map(go, template)


def cache_pspecs(cfg: ArchConfig, template, mesh, client_axis: bool = False):
    """Decode-cache sharding. KV caches shard kv-heads over 'model' when
    divisible, else the sequence/capacity dim; SSM states shard d_inner
    (or heads) over 'model'. Batch over 'data' when divisible."""
    msize = mesh.shape["model"]
    axes = _dp_axes(mesh, client_axis)

    def go(path, leaf):
        names = _names(path)
        last = names[-1]
        shape = leaf.shape[1:] if client_axis else leaf.shape
        spec = [None] * len(shape)
        # all caches here are layer-stacked: axis0 layers, axis1 batch
        if len(shape) >= 2:
            spec[1] = _batch_dim_spec(shape[1], mesh, axes)
        if last in ("k", "v", "ck", "cv"):          # (L,B,cap,kv,hd)
            if shape[3] % msize == 0:
                spec[3] = "model"
            elif shape[2] % msize == 0:
                spec[2] = "model"
        elif last in ("ckv", "krope"):               # (L,B,S,lat)
            if shape[2] % msize == 0:
                spec[2] = "model"
        elif last == "h":                            # (L,B,di,N)/(L,B,H2,hd,N)
            if shape[2] % msize == 0:
                spec[2] = "model"
        elif last == "conv":                         # (L,B,K-1,di)
            if shape[3] % msize == 0:
                spec[3] = "model"
        return P(*((("pod",) if client_axis else ()) + tuple(spec)))

    return jax.tree_util.tree_map_with_path(go, template)


def to_named(mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated(mesh):
    return NamedSharding(mesh, P())
