from repro.sharding import specs
