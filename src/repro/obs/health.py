"""Online federation health monitoring (DESIGN.md §14).

The paper's theory says the one-bit consensus converges to a stationary
neighborhood of the personalized optimum — which makes several signals
the executors ALREADY emit natural online convergence monitors, no extra
communication required:

  sign-flip churn      fraction of consensus coordinates that changed
                       sign vs the previous round. Near a stationary
                       point the majority vote stabilizes, so churn
                       decays toward the dithering floor; sustained high
                       churn after warmup means the vote is thrashing.
  EF residual trend    ||error-feedback residual|| per round. Bounded
                       under the paper's assumptions; a steady upward
                       trend is the classic EF divergence signature
                       (step size too large / sketch too small).
  vote margin          |sum_s w_s * sign_s| per coordinate — how far
                       each majority vote is from a coin flip. A healthy
                       consensus has margins bounded away from 0; the
                       distribution is summarized by a QuantileSketch.
  staleness tail       async-tier update staleness, sketched; a growing
                       p99 means stragglers are aging out of usefulness.

`HealthMonitor.update(...)` ingests whichever signals a tier has each
round/flush; `status()` classifies the trajectory:

  warming      fewer than `warmup` rounds observed — no verdict yet.
  converging   churn decaying / below alarm, EF trend flat or falling.
  plateau      mean churn over the trailing window under
               `churn_plateau` — the vote has locked in.
  diverging    churn above `churn_alarm` after warmup, or the EF
               residual trend growing by more than `ef_growth_alarm`
               across the window. This is the alarm state: `ok` is
               False and the flight recorder should snapshot.

All state is O(window + sketch buckets): trailing deques plus two
bounded sketches — the monitor itself obeys the telemetry memory bound.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.obs.hist import QuantileSketch


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    window: int = 8             # trailing rounds kept for trend estimates
    warmup: int = 3             # rounds before any non-"warming" verdict
    churn_plateau: float = 0.02  # mean churn below this => plateau
    churn_alarm: float = 0.5    # churn above this after warmup => diverging
    ef_growth_alarm: float = 1.5  # late/early EF ratio above this => diverging
    rel_acc: float = 0.01       # sketch accuracy for margins/staleness
    max_buckets: int = 128      # sketch memory bound


class HealthMonitor:
    """Per-round/flush federation health state machine. Feed it whatever
    signals the tier has; read `status()`/`verdict()` whenever."""

    def __init__(self, cfg: HealthConfig = HealthConfig()):
        self.cfg = cfg
        self.rounds = 0
        self._prev_v = None
        self.churn = collections.deque(maxlen=cfg.window)
        self.ef = collections.deque(maxlen=cfg.window)
        self.agreement = collections.deque(maxlen=cfg.window)
        self.margins = QuantileSketch(cfg.rel_acc, cfg.max_buckets)
        self.staleness = QuantileSketch(cfg.rel_acc, cfg.max_buckets)

    # -- ingest ---------------------------------------------------------------

    def update(self, v=None, ef_norm=None, agreement=None,
               margins=None, staleness=None) -> None:
        """One round/flush of signals; every argument optional.

        v: consensus sign vector (any array-like in {-1, 0, +1}) — churn
        is computed against the previous round's v. ef_norm: scalar EF
        residual norm. agreement: scalar sign-agreement rate. margins:
        per-coordinate vote margins (array-like, >= 0). staleness: one
        scalar staleness observation or an array of them."""
        self.rounds += 1
        if v is not None:
            v = np.asarray(v)
            if self._prev_v is not None and v.shape == self._prev_v.shape:
                self.churn.append(float(np.mean(v != self._prev_v)))
            self._prev_v = v.copy()
        if ef_norm is not None:
            self.ef.append(float(ef_norm))
        if agreement is not None:
            self.agreement.append(float(agreement))
        if margins is not None:
            self.margins.add_many(np.abs(np.asarray(margins, np.float64)))
        if staleness is not None:
            self.staleness.add_many(np.atleast_1d(staleness))

    # -- classify -------------------------------------------------------------

    def _ef_trend(self) -> float:
        """Late-half / early-half mean EF residual over the window; 1.0
        when flat or not enough data."""
        if len(self.ef) < 4:
            return 1.0
        vals = list(self.ef)
        half = len(vals) // 2
        early = float(np.mean(vals[:half]))
        late = float(np.mean(vals[half:]))
        if early <= 0.0:
            # keep the trend finite (JSON-safe): a zero early half with a
            # nonzero late half is maximal measurable growth
            return 1.0 if late <= 0.0 else late / 1e-30
        return late / early

    def alarms(self) -> list:
        """Active alarm names (empty when healthy or still warming)."""
        if self.rounds < self.cfg.warmup:
            return []
        out = []
        if self.churn and self.churn[-1] > self.cfg.churn_alarm:
            out.append("churn_alarm")
        if self._ef_trend() > self.cfg.ef_growth_alarm:
            out.append("ef_divergence")
        return out

    def status(self) -> str:
        if self.rounds < self.cfg.warmup:
            return "warming"
        if self.alarms():
            return "diverging"
        if self.churn and float(np.mean(self.churn)) < self.cfg.churn_plateau:
            return "plateau"
        return "converging"

    def verdict(self) -> dict:
        """Machine-readable health verdict (embedded in BENCH_exp cells;
        `ok` is False only in the alarm state)."""
        status = self.status()
        return {
            "status": status,
            "ok": status != "diverging",
            "rounds": int(self.rounds),
            "alarms": self.alarms(),
            "churn": {
                "last": float(self.churn[-1]) if self.churn else None,
                "mean_window": float(np.mean(self.churn)) if self.churn else None,
            },
            "ef": {
                "last": float(self.ef[-1]) if self.ef else None,
                "trend": float(self._ef_trend()),
            },
            "agreement": {
                "last": float(self.agreement[-1]) if self.agreement else None,
            },
            "margins": self.margins.summary(),
            "staleness": self.staleness.summary(),
        }
