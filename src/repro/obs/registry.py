"""One metrics registry for every tier, plus the shared billing checkers.

Before this module each tier owned a private meter (`AsyncMeter`,
`SimReport.check_billing`, `HierSimReport.check_billing`,
`StreamReport`) and a private copy of the "re-derive expected bits from
fl/comms and compare" walk. The meters survive as thin adapters over a
`MetricsRegistry`; the re-derivation lives here, once, as
`expected_async_bits` / `expected_hier_bits` / `assert_billing`, and the
same functions back `obs.validate_trace`'s CI gate.

A registry is a set of named cumulative counters plus a few observed
series (values that aren't additive, e.g. flush sizes). Every `add`
mirrors into the bound tracer as a Chrome counter event, so the exported
timeline carries the same numbers the invariants are checked against —
there is no second bookkeeping path to drift.
"""
from __future__ import annotations

from repro.fl import comms
from repro.obs.trace import NOOP, Tracer

#: Counter catalog: every name a registry may `add` to, with meaning and
#: unit. Tiers use the subset that applies to them; validate_trace and
#: DESIGN.md §12 reference this table.
COUNTERS = {
    "uplink_bits": "client→server payload bits on the wire (Table-2 accounting)",
    "downlink_bits": "server→client broadcast bits on the wire",
    "votes_cast": "client sign-vectors entering a majority vote",
    "rr_flips": "sign bits flipped by randomized-response privacy on the uplink",
    "trimmed_voters": "voters discarded by the trimmed defense",
    "ef_residual_norm": "series: ||error-feedback residual|| per round/flush",
    "lru_hits": "serving LRU cache hits (decoded params reused)",
    "lru_misses": "serving LRU cache misses (sketch materialized)",
    "flush_sizes": "series: arrivals aggregated per async flush",
    "tier_merges": "counter-tree partial-merge messages forwarded upward",
}

#: Names that record a series of observations rather than a running sum.
SERIES = frozenset({"ef_residual_norm", "flush_sizes"})


class MetricsRegistry:
    """Named cumulative counters + observed series, mirrored to a tracer.

    `add` is the additive path (bits, votes, flips, merges); `observe`
    appends to a series. Unknown names are rejected so the catalog stays
    the single source of truth.
    """

    def __init__(self, tracer: Tracer = NOOP):
        self.tracer = tracer
        self._counts: dict = {}
        self._series: dict = {}

    def add(self, name: str, delta, t: float | None = None) -> None:
        if name not in COUNTERS or name in SERIES:
            raise KeyError(f"unknown counter {name!r}; add it to obs.registry.COUNTERS")
        self._counts[name] = self._counts.get(name, 0) + delta
        self.tracer.count(name, delta, t=t)

    def observe(self, name: str, value, t: float | None = None) -> None:
        if name not in SERIES:
            raise KeyError(f"{name!r} is not a series; see obs.registry.SERIES")
        self._series.setdefault(name, []).append(value)
        self.tracer.count(name, value, t=t)

    def get(self, name: str, default=0):
        return self._counts.get(name, default)

    def series(self, name: str) -> list:
        return list(self._series.get(name, ()))

    @property
    def totals(self) -> dict:
        return dict(self._counts)

    def to_dict(self) -> dict:
        return {"counters": dict(self._counts),
                "series": {k: list(v) for k, v in self._series.items()}}


# -- shared billing re-derivation (satellite: dedupe check_billing) -----------

def expected_async_bits(m: int, arrivals_per_flush, residual_arrivals: int = 0) -> dict:
    """Expected wire bits for an async buffered run: each completed flush
    bills like one pfed1bs round with s = arrivals (uplink s*m, downlink
    m); arrivals still in flight at drain billed their uplink but saw no
    broadcast. Returns {"uplink_bits", "downlink_bits"}."""
    arrivals_per_flush = list(arrivals_per_flush)
    acc = comms.accumulate_round_bits(
        "pfed1bs", n=0, m=m, s_per_round=arrivals_per_flush
    )
    return {
        "uplink_bits": acc["uplink_bits"] + residual_arrivals * m,
        "downlink_bits": acc["downlink_bits"],
    }


def expected_hier_bits(m: int, uplink_events, versions: int, levels: int) -> dict:
    """Expected wire bits for a hierarchical run. `uplink_events` is an
    iterable of (tier, width): tier 0 = leaf clients sending m sign bits;
    tier > 0 = an aggregator forwarding m packed counters of
    `counter_bits(width)` bits each. Each finished version broadcasts m
    bits down every level."""
    up = 0
    for tier, width in uplink_events:
        up += m if tier == 0 else comms.counter_bits(width) * m
    return {"uplink_bits": up, "downlink_bits": versions * levels * m}


def assert_billing(label: str, got: dict, expect: dict) -> None:
    """Exact-equality billing invariant shared by every tier's
    check_billing. Bit counts are integers derived from the same fl/comms
    formulas on both sides — any mismatch is a bookkeeping bug, so no
    tolerance."""
    for key in ("uplink_bits", "downlink_bits"):
        g, e = int(got[key]), int(expect[key])
        if g != e:
            raise ValueError(
                f"{label}: billing mismatch — {key}={g} does not re-derive "
                f"from fl/comms (expected {e}, diff {g - e})"
            )
