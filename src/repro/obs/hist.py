"""Mergeable quantile sketches — bounded-memory percentiles (DESIGN.md §14).

The repo's percentiles used to be computed by hoarding every sample and
calling `np.percentile` at the end — O(requests) memory that cannot ride
a production stream. `QuantileSketch` is a DDSketch-style summary
(Masson, Rim & Lee, VLDB'19): samples land in log-spaced buckets

    key(x) = ceil(log(x) / log(gamma)),   gamma = (1 + a) / (1 - a)

for relative accuracy `a`, and a bucket's representative value
2*gamma^k / (gamma + 1) (the geometric midpoint of (gamma^(k-1),
gamma^k]) is within a factor (1 + a) of every sample it holds — so any
quantile estimate is within RELATIVE error `a` of the exact sample
statistic, regardless of stream length or value range.

The property that earns the sketch its place in THIS repo is the merge:
bucket counts are plain integers keyed by an integer index, so merging
two sketches is bucket-wise integer addition — exactly associative and
commutative, like the partial popcount counters PR 7 merges up the
aggregator tree. A sketch of a concatenated stream IS the merge of the
per-shard sketches (split-invariance), bit-for-bit in the counts, which
is what lets per-tier latency histograms ride the hierarchy alongside
the vote counters (sim/hier.py) and per-shard serving telemetry roll up
without re-deriving anything.

Two operating modes:

  max_buckets=None   exact merge algebra — the bucket dict grows with
                     the DYNAMIC RANGE of the data (log-many buckets),
                     never with the sample count. This is the mode the
                     hypothesis merge-algebra properties run under.
  max_buckets=B      the fixed-bound streaming counterpart: when the
                     dict would exceed B buckets the LOWEST keys are
                     collapsed into the smallest retained bucket
                     (standard DDSketch collapsing). Upper quantiles —
                     the p99s SLOs care about — keep their relative-
                     error guarantee; only the far-left tail degrades.
                     Resident bytes are then a hard constant bound,
                     independent of both sample count and range.

min/max/sum/count are tracked exactly, so `quantile(0)`, `quantile(1)`
and `mean` are exact; interior quantiles follow the rank convention
r = q*(count-1), returning the bucket holding sorted[floor(r)] — the
same element `np.percentile(values, 100q, method="lower")` returns,
which is what the small-N parity tests pin against.
"""
from __future__ import annotations

import math

#: Deterministic resident-memory accounting model (bytes): a fixed header
#: (scalars + dict overhead) plus a per-bucket cost of one boxed int key
#: and one boxed int count slot. An accounting constant, not
#: sys.getsizeof — the point is that the TOTAL is a pure function of the
#: bucket count, so "resident telemetry bytes independent of request
#: count" is a checkable invariant rather than an allocator artifact.
FIXED_BYTES = 160
BUCKET_BYTES = 16

#: Values at or below this magnitude land in the zero bucket (keys for
#: tiny positives would be huge negative ints for no informational gain).
ZERO_EPS = 1e-12


class QuantileSketch:
    """DDSketch-style mergeable quantile summary for non-negative values.

    add/merge/quantile/summary; `to_dict`/`from_dict` round-trip through
    JSON; `resident_bytes()` is the deterministic memory accounting used
    by the serving telemetry bound.
    """

    __slots__ = ("rel_acc", "max_buckets", "_gamma", "_log_gamma",
                 "buckets", "zero_count", "count", "sum", "_min", "_max")

    def __init__(self, rel_acc: float = 0.01, max_buckets: int | None = None):
        if not 0.0 < rel_acc < 1.0:
            raise ValueError(f"rel_acc must be in (0, 1); got {rel_acc}")
        if max_buckets is not None and max_buckets < 2:
            raise ValueError(f"max_buckets must be >= 2; got {max_buckets}")
        self.rel_acc = float(rel_acc)
        self.max_buckets = max_buckets
        self._gamma = (1.0 + rel_acc) / (1.0 - rel_acc)
        self._log_gamma = math.log(self._gamma)
        self.buckets: dict = {}     # int key -> int count
        self.zero_count = 0
        self.count = 0
        self.sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # -- ingest ---------------------------------------------------------------

    def _key(self, x: float) -> int:
        return math.ceil(math.log(x) / self._log_gamma)

    def _value(self, key: int) -> float:
        # geometric midpoint of the bucket (gamma^(k-1), gamma^k]
        return 2.0 * self._gamma ** key / (self._gamma + 1.0)

    def add(self, x, count: int = 1) -> None:
        x = float(x)
        if not math.isfinite(x) or x < 0.0:
            raise ValueError(f"sketch values must be finite and >= 0; got {x}")
        if count <= 0:
            raise ValueError(f"count must be positive; got {count}")
        if x <= ZERO_EPS:
            self.zero_count += count
        else:
            k = self._key(x)
            self.buckets[k] = self.buckets.get(k, 0) + count
            self._collapse()
        self.count += count
        self.sum += x * count
        self._min = min(self._min, x)
        self._max = max(self._max, x)

    def add_many(self, values) -> None:
        """Vectorized ingest of an array of values — same result as
        add() in a loop (bucket keys are computed identically; identical
        floats land in identical buckets), at numpy speed for the (m,)
        vote-margin / staleness vectors the health monitor feeds."""
        import numpy as np

        x = np.asarray(values, dtype=np.float64).ravel()
        if x.size == 0:
            return
        if not np.all(np.isfinite(x)) or np.any(x < 0.0):
            raise ValueError("sketch values must be finite and >= 0")
        zero = x <= ZERO_EPS
        nz = x[~zero]
        if nz.size:
            keys = np.ceil(np.log(nz) / self._log_gamma).astype(np.int64)
            uk, cnt = np.unique(keys, return_counts=True)
            for k, c in zip(uk.tolist(), cnt.tolist()):
                self.buckets[k] = self.buckets.get(k, 0) + c
            self._collapse()
        self.zero_count += int(zero.sum())
        self.count += int(x.size)
        self.sum += float(x.sum())
        self._min = min(self._min, float(x.min()))
        self._max = max(self._max, float(x.max()))

    def _collapse(self) -> None:
        """Fold the lowest keys together until <= max_buckets remain.
        Collapsing only ever moves counts to a LARGER key among the low
        buckets, so upper quantiles are untouched."""
        if self.max_buckets is None:
            return
        while len(self.buckets) > self.max_buckets:
            lo = sorted(self.buckets)
            k0, k1 = lo[0], lo[1]
            self.buckets[k1] += self.buckets.pop(k0)

    # -- merge algebra --------------------------------------------------------

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold `other` into self (bucket-wise integer addition); returns
        self. Both sketches must share rel_acc — merging across gammas
        would need bucket re-projection and lose the exactness argument."""
        if abs(other.rel_acc - self.rel_acc) > 1e-12:
            raise ValueError(
                f"cannot merge sketches with different rel_acc "
                f"({self.rel_acc} vs {other.rel_acc})"
            )
        for k, c in other.buckets.items():
            self.buckets[k] = self.buckets.get(k, 0) + c
        self.zero_count += other.zero_count
        self.count += other.count
        self.sum += other.sum
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        self._collapse()
        return self

    def copy(self) -> "QuantileSketch":
        out = QuantileSketch(self.rel_acc, self.max_buckets)
        out.buckets = dict(self.buckets)
        out.zero_count = self.zero_count
        out.count = self.count
        out.sum = self.sum
        out._min = self._min
        out._max = self._max
        return out

    # -- read -----------------------------------------------------------------

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Value within relative error rel_acc of sorted[floor(q*(n-1))]
        (np.percentile method="lower"). Exact at q<=0 / q>=1 via the
        tracked min/max; 0.0 on an empty sketch."""
        if self.count == 0:
            return 0.0
        if q <= 0.0:
            return self._min
        if q >= 1.0:
            return self._max
        rank = q * (self.count - 1)
        cum = self.zero_count
        if cum > rank:
            return 0.0
        for k in sorted(self.buckets):
            cum += self.buckets[k]
            if cum > rank:
                # clamp into the exact observed range: the representative
                # of an extreme bucket can overshoot the true min/max
                return min(max(self._value(k), self._min), self._max)
        return self._max

    def summary(self) -> dict:
        """The standard telemetry block: count + exact mean/max + sketch
        p50/p99, all plain floats (JSON-ready)."""
        return {
            "count": int(self.count),
            "mean": float(self.mean),
            "p50": float(self.quantile(0.50)),
            "p99": float(self.quantile(0.99)),
            "max": float(self.max),
        }

    def resident_bytes(self) -> int:
        """Deterministic memory accounting (see FIXED_BYTES/BUCKET_BYTES).
        Bounded by FIXED_BYTES + BUCKET_BYTES*(max_buckets+1) when
        max_buckets is set — independent of how many samples were added."""
        slots = len(self.buckets) + (1 if self.zero_count else 0)
        return FIXED_BYTES + BUCKET_BYTES * slots

    # -- wire format ----------------------------------------------------------

    def to_dict(self) -> dict:
        """JSON-serializable snapshot (bucket keys become strings)."""
        return {
            "rel_acc": self.rel_acc,
            "max_buckets": self.max_buckets,
            "buckets": {str(k): int(c) for k, c in sorted(self.buckets.items())},
            "zero_count": int(self.zero_count),
            "count": int(self.count),
            "sum": float(self.sum),
            "min": float(self._min) if self.count else None,
            "max": float(self._max) if self.count else None,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "QuantileSketch":
        out = cls(d["rel_acc"], d.get("max_buckets"))
        out.buckets = {int(k): int(c) for k, c in d["buckets"].items()}
        out.zero_count = int(d["zero_count"])
        out.count = int(d["count"])
        out.sum = float(d["sum"])
        out._min = math.inf if d.get("min") is None else float(d["min"])
        out._max = -math.inf if d.get("max") is None else float(d["max"])
        return out

    def __eq__(self, other) -> bool:
        if not isinstance(other, QuantileSketch):
            return NotImplemented
        return (self.rel_acc == other.rel_acc
                and self.buckets == other.buckets
                and self.zero_count == other.zero_count
                and self.count == other.count)

    def __repr__(self) -> str:
        return (f"QuantileSketch(rel_acc={self.rel_acc}, count={self.count}, "
                f"buckets={len(self.buckets)}, p50={self.quantile(0.5):.4g}, "
                f"p99={self.quantile(0.99):.4g})")


def merged(*sketches: QuantileSketch) -> QuantileSketch:
    """Pure merge of any number of same-rel_acc sketches (copies the
    first; folds the rest). Convenience for tree rollups."""
    if not sketches:
        raise ValueError("merged() needs at least one sketch")
    out = sketches[0].copy()
    for s in sketches[1:]:
        out.merge(s)
    return out
