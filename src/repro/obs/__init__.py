"""Unified observability layer: deterministic tracing, one metrics
registry, kernel probing, Perfetto-compatible export, and (PR 10) online
production telemetry — mergeable quantile sketches, SLO burn-rate gates,
federation health monitoring, and an always-on flight recorder.

See DESIGN.md §12 for the tracer model and clock domains and §14 for the
telemetry layer; the usual entry points are re-exported here.
"""
from repro.obs.export import dump_trace, dumps_trace, to_chrome
from repro.obs.flight import FlightRecorder, maybe_snapshot
from repro.obs.health import HealthConfig, HealthMonitor
from repro.obs.hist import QuantileSketch, merged
from repro.obs.probe import KernelProbe, probing
from repro.obs.registry import (
    COUNTERS,
    MetricsRegistry,
    assert_billing,
    expected_async_bits,
    expected_hier_bits,
)
from repro.obs.slo import BurnRateObjective, Objective, SLOSpec
from repro.obs.slo import evaluate as evaluate_slo
from repro.obs.trace import NOOP, Tracer
from repro.obs.validate_trace import (
    validate_flight,
    validate_slo_verdict,
    validate_trace,
)

__all__ = [
    "COUNTERS",
    "BurnRateObjective",
    "FlightRecorder",
    "HealthConfig",
    "HealthMonitor",
    "KernelProbe",
    "MetricsRegistry",
    "NOOP",
    "Objective",
    "QuantileSketch",
    "SLOSpec",
    "Tracer",
    "assert_billing",
    "dump_trace",
    "dumps_trace",
    "evaluate_slo",
    "expected_async_bits",
    "expected_hier_bits",
    "maybe_snapshot",
    "merged",
    "probing",
    "to_chrome",
    "validate_flight",
    "validate_slo_verdict",
    "validate_trace",
]
