"""Unified observability layer: deterministic tracing, one metrics
registry, kernel probing, and Perfetto-compatible export.

See DESIGN.md §12 for the tracer model and clock domains; the usual
entry points are re-exported here.
"""
from repro.obs.export import dump_trace, dumps_trace, to_chrome
from repro.obs.probe import KernelProbe, probing
from repro.obs.registry import (
    COUNTERS,
    MetricsRegistry,
    assert_billing,
    expected_async_bits,
    expected_hier_bits,
)
from repro.obs.trace import NOOP, Tracer
from repro.obs.validate_trace import validate_trace

__all__ = [
    "COUNTERS",
    "KernelProbe",
    "MetricsRegistry",
    "NOOP",
    "Tracer",
    "assert_billing",
    "dump_trace",
    "dumps_trace",
    "expected_async_bits",
    "expected_hier_bits",
    "probing",
    "to_chrome",
    "validate_trace",
]
