"""Kernel-level timing probe for the dispatch layer (`kernels/ops.py`).

Every public dispatcher in kernels/ops.py is wrapped with `instrument`.
With no probe active (the default, and all of training/serving unless a
benchmark opts in) the wrapper is a single module-global `is None` check
— the probe cannot slow down un-probed runs.

Inside a `probing(KernelProbe())` block, each eager call is timed with
`jax.block_until_ready` around the wrapped fn. Two conventions mirror
`us_per_round` elsewhere in the repo:

  * compile excluded by first-call separation: the first call for each
    (kernel, signature) pair is recorded as compile time, subsequent
    calls as steady-state — same convention as dropping round 0 from
    the round microbenchmark.
  * calls made while a jax trace is being built (the kernel is being
    inlined into a larger jitted program) are passed through untimed:
    timing a tracer-argument call would measure trace construction, not
    the kernel, and perturbing an active trace is exactly what the obs
    layer promises never to do.

Bytes moved are ESTIMATED from argument/output array shapes (sum of
nbytes both directions) — a lower bound on actual traffic that is good
enough to rank kernels for the roofline section; benchmarks/report.py
renders the per-kernel table from `KernelProbe.table()`.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

#: The active probe, or None. Module-global on purpose: the disabled
#: fast path must be one load+compare, not a context lookup.
_ACTIVE = None


def _leaves(tree):
    import jax

    return jax.tree_util.tree_leaves(tree)


def _nbytes(leaves) -> int:
    total = 0
    for leaf in leaves:
        nb = getattr(leaf, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def _sig_key(leaves) -> tuple:
    return tuple(
        (getattr(x, "shape", None), str(getattr(x, "dtype", type(x).__name__)))
        for x in leaves
    )


class KernelProbe:
    """Accumulates per-kernel timing/byte records; one per `probing` scope."""

    def __init__(self):
        self.records: list = []
        self._seen: set = set()

    def record(self, name: str, seconds: float, arg_bytes: int,
               out_bytes: int, sig: tuple) -> None:
        key = (name, sig)
        compile_call = key not in self._seen
        self._seen.add(key)
        self.records.append({
            "kernel": name, "seconds": seconds, "compile": compile_call,
            "arg_bytes": arg_bytes, "out_bytes": out_bytes,
        })

    def table(self) -> list:
        """Aggregate to one row per kernel: steady-state calls/us, compile
        time, and an effective-bandwidth estimate. Sorted by total
        steady-state time, heaviest first."""
        agg: dict = {}
        for r in self.records:
            row = agg.setdefault(r["kernel"], {
                "kernel": r["kernel"], "calls": 0, "steady_s": 0.0,
                "compile_calls": 0, "compile_s": 0.0, "bytes_moved": 0,
            })
            if r["compile"]:
                row["compile_calls"] += 1
                row["compile_s"] += r["seconds"]
            else:
                row["calls"] += 1
                row["steady_s"] += r["seconds"]
                row["bytes_moved"] += r["arg_bytes"] + r["out_bytes"]
        out = []
        for row in sorted(agg.values(), key=lambda r: -r["steady_s"]):
            calls = row["calls"]
            row["us_per_call"] = (row["steady_s"] / calls) * 1e6 if calls else None
            row["est_gb_per_s"] = (
                row["bytes_moved"] / row["steady_s"] / 1e9
                if row["steady_s"] > 0 else None
            )
            out.append(row)
        return out


@contextmanager
def probing(probe: KernelProbe):
    """Activate `probe` for the dynamic extent of the block. Nesting
    replaces (inner wins), restoring the outer probe on exit."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = probe
    try:
        yield probe
    finally:
        _ACTIVE = prev


def instrument(name: str, fn):
    """Wrap a kernel dispatcher for probing. Returns a function with the
    same signature; see module docstring for the timing conventions."""

    def probed(*args, **kwargs):
        probe = _ACTIVE
        if probe is None:
            return fn(*args, **kwargs)
        import jax

        arg_leaves = _leaves((args, kwargs))
        if any(isinstance(x, jax.core.Tracer) for x in arg_leaves):
            return fn(*args, **kwargs)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*args, **kwargs))
        dt = time.perf_counter() - t0
        probe.record(name, dt, _nbytes(arg_leaves), _nbytes(_leaves(out)),
                     _sig_key(arg_leaves))
        return out

    probed.__name__ = name
    probed.__qualname__ = name
    probed.__doc__ = fn.__doc__
    probed.__wrapped__ = fn
    return probed
