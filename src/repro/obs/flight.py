"""Always-on flight recorder: bounded span/counter ring + breach snapshot.

A `FlightRecorder` is a `Tracer` whose event store is a fixed-capacity
ring instead of an unbounded list — it can stay enabled in production
forever at constant memory, remembering the most recent `capacity`
spans/instants/counter samples (oldest evicted first; an eviction count
is kept so the snapshot says how much history fell off the back).

`snapshot(path, reason, ...)` freezes the ring into a Perfetto-loadable
`FLIGHT_*.json`: the standard Chrome trace shape (same `dumps_trace`
canonical serialization as TRACE files) plus a `flight` block recording
why the snapshot fired, the ring capacity, and the eviction count, and
optionally the SLO verdict / health verdict that triggered it. Unlike a
TRACE file it carries NO billing requirement — a ring that dropped
events cannot re-derive bit totals, and the point of a flight recording
is the last moments before the alarm, not the full ledger.
`obs.validate_trace.validate_flight` pins the schema.

Counter semantics under eviction: `Tracer` keeps cumulative totals in a
side dict that is never evicted, so `counterTotals` in the snapshot is
exact even when early counter SAMPLES fell out of the ring; surviving
samples are still monotone (evictions take the oldest first).
"""
from __future__ import annotations

import collections

from repro.obs.export import dumps_trace, to_chrome
from repro.obs.trace import Tracer

#: Default ring capacity — enough for a few hundred recent spans while
#: keeping the resident footprint trivially bounded.
DEFAULT_CAPACITY = 512


class _Ring:
    """Fixed-capacity append-only view with an eviction counter. Quacks
    enough like a list for `Tracer` (append) and `obs.export.to_chrome`
    (iteration) to use it unchanged."""

    __slots__ = ("capacity", "_buf", "total")

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._buf = collections.deque(maxlen=capacity)
        self.total = 0

    def append(self, ev) -> None:
        self._buf.append(ev)
        self.total += 1

    @property
    def dropped(self) -> int:
        return self.total - len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def __len__(self) -> int:
        return len(self._buf)


class FlightRecorder(Tracer):
    """A Tracer bounded to the last `capacity` events, with snapshots."""

    def __init__(self, clock: str = "wall", capacity: int = DEFAULT_CAPACITY,
                 enabled: bool = True):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        super().__init__(clock=clock, enabled=enabled)
        self.events = _Ring(capacity)

    @property
    def capacity(self) -> int:
        return self.events.capacity

    @property
    def dropped(self) -> int:
        return self.events.dropped

    def snapshot(self, path, reason: str, slo_verdict: dict | None = None,
                 health: dict | None = None, meta: dict | None = None) -> dict:
        """Write the ring to `path` as a FLIGHT_*.json; returns the
        object written. `reason` is the trigger ("slo_breach",
        "health_alarm", "manual", ...); the triggering SLO/health
        verdicts ride along for postmortem."""
        obj = to_chrome(self, billing=None, meta=meta)
        del obj["billing"]          # flight files carry no billing ledger
        obj["flight"] = {
            "reason": str(reason),
            "capacity": int(self.capacity),
            "dropped": int(self.dropped),
            "events_total": int(self.events.total),
        }
        if slo_verdict is not None:
            obj["slo_verdict"] = slo_verdict
        if health is not None:
            obj["health"] = health
        with open(path, "w") as fh:
            fh.write(dumps_trace(obj))
        return obj


def maybe_snapshot(recorder: FlightRecorder, path, slo_verdict: dict | None = None,
                   health: dict | None = None, meta: dict | None = None):
    """Snapshot iff something is actually wrong: an SLO verdict with
    ok=False or a health verdict with ok=False. Returns the written
    object, or None when everything is healthy (no file touched)."""
    reasons = []
    if slo_verdict is not None and not slo_verdict.get("ok", True):
        reasons.append("slo_breach")
    if health is not None and not health.get("ok", True):
        reasons.append("health_alarm")
    if not reasons:
        return None
    return recorder.snapshot(path, "+".join(reasons), slo_verdict=slo_verdict,
                             health=health, meta=meta)
