"""Deterministic span/event tracer — the timeline half of the obs layer.

One `Tracer` records a flat list of Chrome trace-event dicts
(obs/export.py serializes them into a Perfetto-loadable JSON file) plus a
set of cumulative named counters. Two clock domains, fixed at
construction:

  clock="wall"     spans/instants are stamped from time.perf_counter()
                   relative to the tracer's construction. This is the
                   domain of everything that runs on real hardware: the
                   engine round wrappers (core/pfed1bs.py), the serving
                   tier (serve/engine.py), the scenario runner
                   (exp/runner.py).
  clock="virtual"  every event must carry an explicit virtual-time `t`
                   (seconds on the simulator's EventQueue clock); reading
                   the wall clock is a hard error by construction — which
                   is exactly what makes two same-seed simulator runs
                   produce BYTE-identical exported traces
                   (tests/test_obs.py). This is the domain of sim/.

Disabled tracers are free: `NOOP` (the module-level singleton) and any
`Tracer(enabled=False)` early-return from every method, `span()` hands
back one shared no-op context manager, and nothing is ever allocated —
the instrumented hot paths pay one attribute check.

JIT SAFETY: tracer calls are host-side Python only — they never create
jax ops, so the jaxpr of an instrumented jitted function is IDENTICAL
with the tracer enabled or disabled (pinned by tests/test_obs.py). A
wall-clock `span()` opened while a jax trace is active (e.g. the per-tier
merge spans inside launch/fedexec.py's jitted round body) is recorded on
the dedicated "jit-trace" track: it fires once, at trace time, and shows
the traced program's structure — it is NOT a runtime measurement, and a
jit cache hit records nothing.

Counter events (`count`) keep a cumulative total per name and emit one
Chrome "C" sample per call; obs/export.py's `validate_trace` re-derives
the final uplink/downlink totals from fl/comms and requires exact
equality — the registry (obs/registry.py) is the layer that actually
emits them.
"""
from __future__ import annotations

import time


def _in_jax_trace() -> bool:
    """True while a jax trace (jit/vmap/grad tracing) is being built.
    Import is deferred so a disabled tracer never touches jax."""
    try:
        import jax

        return not jax.core.trace_state_clean()
    except Exception:
        return False


class _NullSpan:
    """Shared no-op context manager for disabled/virtual-clock span()."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One open wall-clock span; records a Chrome 'X' event on exit."""

    __slots__ = ("tr", "name", "track", "args", "t0")

    def __init__(self, tr, name, track, args):
        self.tr = tr
        self.name = name
        self.track = track
        self.args = args

    def __enter__(self):
        self.t0 = self.tr._now_us()
        return self

    def __exit__(self, *exc):
        tr = self.tr
        t1 = tr._now_us()
        tr.events.append({
            "name": self.name, "ph": "X", "ts": self.t0,
            "dur": t1 - self.t0, "pid": 1, "tid": tr._tid(self.track),
            "args": self.args,
        })
        return False


class Tracer:
    """Ordered event recorder with named tracks and cumulative counters.

    events: Chrome trace-event dicts, insertion order (deterministic —
    no sorting ever happens, so a deterministic caller yields a
    deterministic list). Tracks are named lanes ("server", "jit-trace",
    ...) mapped to integer tids in first-use order; obs/export.py emits
    the thread_name metadata so Perfetto shows the names.
    """

    def __init__(self, clock: str = "wall", enabled: bool = True):
        assert clock in ("wall", "virtual"), clock
        self.clock = clock
        self.enabled = enabled
        self.events: list = []
        self._totals: dict = {}
        self._tids: dict = {}
        self._t0 = time.perf_counter()

    # -- time/track plumbing --------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _ts(self, t) -> float:
        """Resolve an event timestamp (microseconds). Virtual tracers
        REQUIRE an explicit t — falling back to the wall clock would
        silently break byte-identical replay."""
        if t is not None:
            return float(t) * 1e6
        if self.clock == "virtual":
            raise ValueError(
                "virtual-clock tracer events need an explicit t= (seconds "
                "of simulator time); wall-clock fallback is forbidden"
            )
        return self._now_us()

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids) + 1
            self._tids[track] = tid
        return tid

    @property
    def tracks(self) -> dict:
        """track name -> tid, first-use order."""
        return dict(self._tids)

    # -- recording ------------------------------------------------------------

    def span(self, name: str, track: str = "main", **args):
        """Wall-clock duration span (context manager). No-op when the
        tracer is disabled OR on a virtual clock (virtual durations are
        recorded with `complete`, which takes explicit times). Inside an
        active jax trace the span lands on the "jit-trace" track — see
        module docstring."""
        if not self.enabled or self.clock == "virtual":
            return _NULL_SPAN
        if _in_jax_trace():
            track = "jit-trace"
        return _Span(self, name, track, args)

    def complete(self, name: str, t0: float, t1: float,
                 track: str = "main", **args) -> None:
        """A finished span with explicit [t0, t1] timestamps in seconds —
        the virtual-clock analogue of span()."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "X", "ts": float(t0) * 1e6,
            "dur": (float(t1) - float(t0)) * 1e6, "pid": 1,
            "tid": self._tid(track), "args": args,
        })

    def instant(self, name: str, t: float | None = None,
                track: str = "main", **args) -> None:
        """A point event (Chrome ph 'i', thread scope)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "i", "s": "t", "ts": self._ts(t), "pid": 1,
            "tid": self._tid(track), "args": args,
        })

    def count(self, name: str, delta, t: float | None = None) -> None:
        """Add `delta` to counter `name` and emit one cumulative Chrome
        counter sample. Integer deltas stay integers end to end (exact
        re-derivation against fl/comms needs no float tolerance)."""
        if not self.enabled:
            return
        total = self._totals.get(name, 0) + delta
        self._totals[name] = total
        self.events.append({
            "name": name, "ph": "C", "ts": self._ts(t), "pid": 1, "tid": 0,
            "args": {"value": total},
        })

    def counter_total(self, name: str, default=0):
        """Current cumulative value of counter `name`."""
        return self._totals.get(name, default)

    @property
    def counter_totals(self) -> dict:
        return dict(self._totals)


#: The shared disabled tracer — instrumented code defaults to this, so the
#: un-traced hot path costs one `enabled` attribute check.
NOOP = Tracer(enabled=False)
