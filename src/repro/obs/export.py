"""Chrome trace-event JSON export — Perfetto-loadable, byte-stable.

`to_chrome` assembles the serializable trace object: thread-name
metadata for every named track (so Perfetto shows "server", "jit-trace",
... instead of bare tids), then the recorded events in insertion order,
plus the tracer's clock domain and the billing specs that
`obs.validate_trace` re-derives against.

`dumps_trace` is THE serialization: sorted keys, no whitespace. Combined
with the virtual tracer's explicit-timestamp rule this is what makes two
same-seed simulator runs produce byte-identical trace files — the
determinism test pins `dumps_trace(to_chrome(...))` output, not some
parsed-then-compared view.

Open a dumped file at https://ui.perfetto.dev (or chrome://tracing):
both accept the {"traceEvents": [...]} JSON object form with extra
top-level keys.
"""
from __future__ import annotations

import json

from repro.obs.trace import Tracer

#: Reserved tid for counter events; real tracks start at 1.
_COUNTER_TID = 0


def to_chrome(tracer: Tracer, billing: list | None = None,
              meta: dict | None = None) -> dict:
    """Build the trace-event JSON object for `tracer`.

    billing: list of billing-spec dicts (see obs.validate_trace for the
    per-kind schemas) that let the validator re-derive expected bit
    totals from fl/comms. meta: extra top-level keys (benchmark name,
    fast flag, ...) — merged last, so they can't clobber traceEvents.
    """
    events: list = []
    names = {_COUNTER_TID: "counters", **{tid: trk for trk, tid in tracer.tracks.items()}}
    for tid in sorted(names):
        events.append({
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": names[tid]},
        })
    events.extend(tracer.events)
    obj = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "clock": tracer.clock,
        "counterTotals": dict(tracer.counter_totals),
        "billing": list(billing or ()),
    }
    if meta:
        for k, v in meta.items():
            obj.setdefault(k, v)
    return obj


def dumps_trace(obj: dict) -> str:
    """Canonical serialization — sorted keys, minimal separators. Every
    trace file in the repo goes through here so byte-level comparison is
    meaningful."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def dump_trace(path, tracer: Tracer, billing: list | None = None,
               meta: dict | None = None) -> dict:
    """Export `tracer` to `path`; returns the trace object."""
    obj = to_chrome(tracer, billing=billing, meta=meta)
    with open(path, "w") as fh:
        fh.write(dumps_trace(obj))
    return obj
