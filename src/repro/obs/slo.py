"""SLO objectives, error budgets, and burn-rate gates (DESIGN.md §14).

An `SLOSpec` is a named set of objectives evaluated against live
telemetry state — the flat scalar dict a tier's `stats()`/report emits
(threshold objectives) plus a bounded ring of recent timestamped events
(burn-rate objectives). `evaluate` returns a machine-readable verdict
that `benchmarks/report.py` embeds in artifacts and CI gates on via this
module's CLI; `obs.validate_trace.validate_slo_verdict` pins its schema.

Two objective kinds:

  threshold   "metric OP threshold" over a point-in-time scalar, e.g.
              p99 materialize_ms < 2500 or hit_rate >= 0.25. A missing
              metric is a BREACH (observed=None) — an SLO that silently
              passes because nobody emitted the number is worse than a
              false alarm.
  burn_rate   SRE error-budget math over trailing windows. Each event is
              (t_seconds, value); an event is "bad" when value > the
              per-event threshold. With availability target T the error
              budget is (1 - T); the burn rate over a window is
              bad_fraction / (1 - T) — burn 1.0 spends the budget
              exactly at the sustainable rate, burn B spends it B times
              too fast. Following the multi-window alerting pattern, the
              objective breaches only when EVERY configured window
              exceeds max_burn: the short window proves the problem is
              current, the long window proves it is not a blip. Empty
              windows burn 0.

Both kinds degrade to plain dict round-trips (`from_dict`/`to_dict`) so
specs live in committed JSON (benchmarks/slo_serve.json) and verdicts
live in BENCH artifacts.

CLI (the CI gate — nonzero exit on breach):

    PYTHONPATH=src python -m repro.obs.slo benchmarks/slo_serve.json \
        --artifact BENCH_serve.fast.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

_OPS = {
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


@dataclasses.dataclass(frozen=True)
class Objective:
    """Point-in-time threshold objective: `metric OP threshold`."""

    name: str
    metric: str
    op: str
    threshold: float

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown op {self.op!r}; use one of {sorted(_OPS)}")

    def evaluate(self, state: dict) -> dict:
        observed = state.get(self.metric)
        ok = observed is not None and _OPS[self.op](float(observed), self.threshold)
        return {
            "name": self.name, "kind": "threshold", "metric": self.metric,
            "op": self.op, "threshold": float(self.threshold),
            "observed": None if observed is None else float(observed),
            "ok": bool(ok),
        }


@dataclasses.dataclass(frozen=True)
class BurnRateObjective:
    """Error-budget burn over trailing windows of (t, value) events.

    target: availability target in (0, 1) — budget is 1 - target.
    threshold: per-event badness bound (value > threshold is bad).
    windows_s: trailing window lengths in seconds, all of which must
    exceed max_burn for a breach (multi-window alerting).
    """

    name: str
    metric: str
    threshold: float
    target: float
    windows_s: tuple
    max_burn: float

    def __post_init__(self):
        if not 0.0 < self.target < 1.0:
            raise ValueError(f"target must be in (0, 1); got {self.target}")
        if not self.windows_s:
            raise ValueError("burn-rate objective needs at least one window")

    def burn_rates(self, events, now: float) -> list:
        """Per-window burn rates over `events` = [(t_seconds, value)...]."""
        budget = 1.0 - self.target
        rates = []
        for w in self.windows_s:
            inside = [v for t, v in events if t >= now - float(w)]
            if not inside:
                rates.append(0.0)
                continue
            bad = sum(1 for v in inside if v > self.threshold)
            rates.append((bad / len(inside)) / budget)
        return rates

    def evaluate(self, events, now: float) -> dict:
        rates = self.burn_rates(events, now)
        ok = not all(r > self.max_burn for r in rates)
        return {
            "name": self.name, "kind": "burn_rate", "metric": self.metric,
            "threshold": float(self.threshold), "target": float(self.target),
            "windows_s": [float(w) for w in self.windows_s],
            "max_burn": float(self.max_burn),
            "observed": max(rates),            # worst window
            "burn_rates": [float(r) for r in rates],
            "ok": bool(ok),
        }


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """A named bundle of objectives — the committed contract CI enforces."""

    name: str
    objectives: tuple

    @classmethod
    def from_dict(cls, d: dict) -> "SLOSpec":
        objs = []
        for o in d["objectives"]:
            o = dict(o)
            kind = o.pop("kind", "threshold")
            if kind == "threshold":
                objs.append(Objective(**o))
            elif kind == "burn_rate":
                o["windows_s"] = tuple(o["windows_s"])
                objs.append(BurnRateObjective(**o))
            else:
                raise ValueError(f"unknown objective kind {kind!r}")
        return cls(name=d["name"], objectives=tuple(objs))

    @classmethod
    def load(cls, path) -> "SLOSpec":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))

    def to_dict(self) -> dict:
        out = []
        for o in self.objectives:
            d = dataclasses.asdict(o)
            d["kind"] = "burn_rate" if isinstance(o, BurnRateObjective) else "threshold"
            if "windows_s" in d:
                d["windows_s"] = list(d["windows_s"])
            out.append(d)
        return {"name": self.name, "objectives": out}


def evaluate(spec: SLOSpec, state: dict, events=None, now: float = 0.0) -> dict:
    """Evaluate every objective; returns the verdict dict whose schema
    `obs.validate_trace.validate_slo_verdict` pins:

        {"spec", "ok", "objectives": [per-objective dicts], "breaches"}

    state: flat scalar dict for threshold objectives. events/now: the
    (t, value) ring + current time for burn-rate objectives (an absent
    ring means empty windows, burn 0 — NOT a breach, matching the
    empty-window rule)."""
    results = []
    for obj in spec.objectives:
        if isinstance(obj, BurnRateObjective):
            results.append(obj.evaluate(list(events or ()), now))
        else:
            results.append(obj.evaluate(state))
    breaches = [r["name"] for r in results if not r["ok"]]
    return {
        "spec": spec.name,
        "ok": not breaches,
        "objectives": results,
        "breaches": breaches,
    }


# -- CI gate ------------------------------------------------------------------

def evaluate_artifact(spec: SLOSpec, artifact: dict) -> dict:
    """Re-evaluate `spec` against a BENCH_serve artifact: every stream
    grid cell must satisfy every threshold objective (cells expose the
    metric scalars directly), and each cell's STORED burn-rate observeds
    are re-checked against the spec's max_burn (the raw event ring is
    not persisted in the artifact — the bench evaluated it live and this
    re-check keeps the stored verdict honest against the committed
    spec). Returns a combined verdict with per-cell detail."""
    grid = artifact.get("stream", {}).get("grid", {})
    if not grid:
        raise ValueError("artifact has no stream.grid to evaluate against")
    cells = {}
    breaches = []
    for key in sorted(grid, key=lambda s: int(s)):
        cell = grid[key]
        results = []
        for obj in spec.objectives:
            if isinstance(obj, BurnRateObjective):
                stored = _stored_burn(cell, obj.name)
                ok = stored is None or float(stored) <= obj.max_burn
                results.append({
                    "name": obj.name, "kind": "burn_rate",
                    "metric": obj.metric, "threshold": float(obj.threshold),
                    "target": float(obj.target),
                    "windows_s": [float(w) for w in obj.windows_s],
                    "max_burn": float(obj.max_burn),
                    "observed": None if stored is None else float(stored),
                    "ok": bool(ok),
                })
            else:
                results.append(obj.evaluate(cell))
        bad = [r["name"] for r in results if not r["ok"]]
        cells[key] = {"ok": not bad, "objectives": results, "breaches": bad}
        breaches.extend(f"K={key}:{b}" for b in bad)
    return {
        "spec": spec.name,
        "ok": not breaches,
        "objectives": [r for c in cells.values() for r in c["objectives"]],
        "breaches": breaches,
        "cells": {k: c["ok"] for k, c in cells.items()},
    }


def _stored_burn(cell: dict, name: str):
    for r in cell.get("slo", {}).get("objectives", ()):
        if r.get("name") == name and r.get("kind") == "burn_rate":
            return r.get("observed")
    return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Evaluate a committed SLO spec against a bench "
                    "artifact; nonzero exit on breach (the CI gate)."
    )
    ap.add_argument("spec", help="SLO spec JSON (e.g. benchmarks/slo_serve.json)")
    ap.add_argument("--artifact", required=True,
                    help="bench artifact to evaluate (e.g. BENCH_serve.fast.json)")
    args = ap.parse_args(argv)

    spec = SLOSpec.load(args.spec)
    with open(args.artifact) as fh:
        artifact = json.load(fh)
    verdict = evaluate_artifact(spec, artifact)

    from repro.obs.validate_trace import validate_slo_verdict
    validate_slo_verdict(verdict)

    status = "OK" if verdict["ok"] else "BREACH"
    print(f"slo[{spec.name}] {status}: "
          f"{len(verdict['objectives'])} objectives over "
          f"{len(verdict['cells'])} cells"
          + ("" if verdict["ok"] else f" — breaches: {verdict['breaches']}"))
    if not verdict["ok"]:
        print(json.dumps(verdict, indent=2, sort_keys=True), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
