"""Schema + billing gate for exported trace files (`TRACE_*.json`).

`validate_trace` checks three things, raising ValueError on the first
violation:

  1. Shape: Chrome trace-event structure Perfetto will accept — every
     event has name/ph/pid/tid, ph is one of M/X/i/C, timestamps are
     numeric and non-negative, spans have non-negative durations.
  2. Counter sanity: the cumulative uplink_bits / downlink_bits counter
     samples are monotone non-decreasing (bits on the wire never come
     back), and the last sample equals the recorded counterTotals.
  3. Billing: the trace must carry a non-empty `billing` list of specs,
     and the summed expected uplink/downlink bits re-derived from
     fl/comms over those specs must EXACTLY equal the counter totals.
     This is the acceptance-criteria gate: the timeline's counters and
     the paper's Table-2 accounting are the same numbers or the build
     fails.

Billing spec kinds (each a dict with "kind"):
  "rounds":  {algo, n, m, s_per_round: [s...], num_tensors=1,
              extra_uplink_bits=0, extra_downlink_bits=0}
             → comms.accumulate_round_bits + the extras (topology cells
               add per-tier counter traffic computed by hier_round_bits).
  "async":   {m, arrivals_per_flush: [b...], residual_arrivals=0}
             → registry.expected_async_bits.
  "hier":    {m, uplink_events: [[tier, width]...], versions, levels}
             → registry.expected_hier_bits.

Runnable as a module for CI:
    PYTHONPATH=src python -m repro.obs.validate_trace TRACE_exp.fast.json
"""
from __future__ import annotations

import json
import numbers
import sys

from repro.fl import comms
from repro.obs import registry as reg

_PHASES = frozenset({"M", "X", "i", "C"})
_MONOTONE = ("uplink_bits", "downlink_bits")


def _num(v) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def _expected_for(spec: dict) -> dict:
    kind = spec.get("kind")
    if kind == "rounds":
        acc = comms.accumulate_round_bits(
            spec["algo"], n=spec["n"], m=spec["m"],
            s_per_round=spec["s_per_round"],
            num_tensors=spec.get("num_tensors", 1),
        )
        return {
            "uplink_bits": acc["uplink_bits"] + spec.get("extra_uplink_bits", 0),
            "downlink_bits": acc["downlink_bits"] + spec.get("extra_downlink_bits", 0),
        }
    if kind == "async":
        return reg.expected_async_bits(
            spec["m"], spec["arrivals_per_flush"],
            residual_arrivals=spec.get("residual_arrivals", 0),
        )
    if kind == "hier":
        return reg.expected_hier_bits(
            spec["m"], spec["uplink_events"], spec["versions"], spec["levels"]
        )
    raise ValueError(f"billing spec has unknown kind {spec.get('kind')!r}")


def validate_trace(obj: dict) -> dict:
    """Validate a loaded trace object; returns {"events", "expected"} on
    success, raises ValueError otherwise."""
    if not isinstance(obj, dict):
        raise ValueError("trace must be a JSON object")
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    if obj.get("clock") not in ("wall", "virtual"):
        raise ValueError(f"clock must be 'wall' or 'virtual', got {obj.get('clock')!r}")

    last: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"traceEvents[{i}] has unsupported ph {ph!r}")
        if ph == "M":
            continue
        if not _num(ev.get("ts")) or ev["ts"] < 0:
            raise ValueError(f"traceEvents[{i}] needs numeric ts >= 0")
        if ph == "X" and (not _num(ev.get("dur")) or ev["dur"] < 0):
            raise ValueError(f"traceEvents[{i}] span needs numeric dur >= 0")
        if ph == "C":
            value = ev.get("args", {}).get("value")
            if not _num(value):
                raise ValueError(f"traceEvents[{i}] counter needs numeric args.value")
            name = ev["name"]
            if name in _MONOTONE and value < last.get(name, 0):
                raise ValueError(
                    f"counter {name!r} decreases at traceEvents[{i}]: "
                    f"{last[name]} -> {value}"
                )
            last[name] = value

    totals = obj.get("counterTotals", {})
    for name in _MONOTONE:
        if name in last and last[name] != totals.get(name):
            raise ValueError(
                f"counterTotals[{name!r}]={totals.get(name)} disagrees with "
                f"final counter sample {last[name]}"
            )

    billing = obj.get("billing")
    if not isinstance(billing, list) or not billing:
        raise ValueError("trace must carry a non-empty billing list")
    expected = {"uplink_bits": 0, "downlink_bits": 0}
    for spec in billing:
        exp = _expected_for(spec)
        expected["uplink_bits"] += exp["uplink_bits"]
        expected["downlink_bits"] += exp["downlink_bits"]
    got = {k: int(totals.get(k, 0)) for k in expected}
    reg.assert_billing("trace", got, expected)
    return {"events": len(events), "expected": expected}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate_trace TRACE.json [...]",
              file=sys.stderr)
        return 2
    for path in argv:
        with open(path) as fh:
            obj = json.load(fh)
        info = validate_trace(obj)
        print(f"{path}: OK ({info['events']} events, "
              f"uplink={info['expected']['uplink_bits']} "
              f"downlink={info['expected']['downlink_bits']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
