"""Schema + billing gate for exported trace files (`TRACE_*.json`).

`validate_trace` checks three things, raising ValueError on the first
violation:

  1. Shape: Chrome trace-event structure Perfetto will accept — every
     event has name/ph/pid/tid, ph is one of M/X/i/C, timestamps are
     numeric and non-negative, spans have non-negative durations.
  2. Counter sanity: the cumulative uplink_bits / downlink_bits counter
     samples are monotone non-decreasing (bits on the wire never come
     back), and the last sample equals the recorded counterTotals.
  3. Billing: the trace must carry a non-empty `billing` list of specs,
     and the summed expected uplink/downlink bits re-derived from
     fl/comms over those specs must EXACTLY equal the counter totals.
     This is the acceptance-criteria gate: the timeline's counters and
     the paper's Table-2 accounting are the same numbers or the build
     fails.

Billing spec kinds (each a dict with "kind"):
  "rounds":  {algo, n, m, s_per_round: [s...], num_tensors=1,
              extra_uplink_bits=0, extra_downlink_bits=0}
             → comms.accumulate_round_bits + the extras (topology cells
               add per-tier counter traffic computed by hier_round_bits).
  "async":   {m, arrivals_per_flush: [b...], residual_arrivals=0}
             → registry.expected_async_bits.
  "hier":    {m, uplink_events: [[tier, width]...], versions, levels}
             → registry.expected_hier_bits.
  "serve":   {} — the serving tier moves NO federation bits; its trace
             still must carry a billing spec so the zero totals are an
             asserted invariant, not an accident.

Also here (PR 10): `validate_flight` pins the flight-recorder snapshot
schema (Chrome shape + `flight` ring block, NO billing requirement — a
bounded ring that evicted events cannot re-derive totals) and
`validate_slo_verdict` pins the machine-readable SLO verdict that
benches embed and CI gates on via `python -m repro.obs.slo`.

Runnable as a module for CI:
    PYTHONPATH=src python -m repro.obs.validate_trace TRACE_exp.fast.json
    PYTHONPATH=src python -m repro.obs.validate_trace --flight FLIGHT_x.json
"""
from __future__ import annotations

import json
import numbers
import sys

from repro.fl import comms
from repro.obs import registry as reg

_PHASES = frozenset({"M", "X", "i", "C"})
_MONOTONE = ("uplink_bits", "downlink_bits")


def _num(v) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def _expected_for(spec: dict) -> dict:
    kind = spec.get("kind")
    if kind == "rounds":
        acc = comms.accumulate_round_bits(
            spec["algo"], n=spec["n"], m=spec["m"],
            s_per_round=spec["s_per_round"],
            num_tensors=spec.get("num_tensors", 1),
        )
        return {
            "uplink_bits": acc["uplink_bits"] + spec.get("extra_uplink_bits", 0),
            "downlink_bits": acc["downlink_bits"] + spec.get("extra_downlink_bits", 0),
        }
    if kind == "async":
        return reg.expected_async_bits(
            spec["m"], spec["arrivals_per_flush"],
            residual_arrivals=spec.get("residual_arrivals", 0),
        )
    if kind == "hier":
        return reg.expected_hier_bits(
            spec["m"], spec["uplink_events"], spec["versions"], spec["levels"]
        )
    if kind == "serve":
        return {"uplink_bits": 0, "downlink_bits": 0}
    raise ValueError(f"billing spec has unknown kind {spec.get('kind')!r}")


def _check_chrome_shape(obj: dict) -> tuple:
    """Shared shape gate for TRACE and FLIGHT files: Chrome event
    structure, monotone wire counters, counterTotals agreement. Returns
    (events, last counter samples)."""
    if not isinstance(obj, dict):
        raise ValueError("trace must be a JSON object")
    events = obj.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    if obj.get("clock") not in ("wall", "virtual"):
        raise ValueError(f"clock must be 'wall' or 'virtual', got {obj.get('clock')!r}")

    last: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}] missing {key!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"traceEvents[{i}] has unsupported ph {ph!r}")
        if ph == "M":
            continue
        if not _num(ev.get("ts")) or ev["ts"] < 0:
            raise ValueError(f"traceEvents[{i}] needs numeric ts >= 0")
        if ph == "X" and (not _num(ev.get("dur")) or ev["dur"] < 0):
            raise ValueError(f"traceEvents[{i}] span needs numeric dur >= 0")
        if ph == "C":
            value = ev.get("args", {}).get("value")
            if not _num(value):
                raise ValueError(f"traceEvents[{i}] counter needs numeric args.value")
            name = ev["name"]
            if name in _MONOTONE and value < last.get(name, 0):
                raise ValueError(
                    f"counter {name!r} decreases at traceEvents[{i}]: "
                    f"{last[name]} -> {value}"
                )
            last[name] = value

    totals = obj.get("counterTotals", {})
    for name in _MONOTONE:
        if name in last and last[name] != totals.get(name):
            raise ValueError(
                f"counterTotals[{name!r}]={totals.get(name)} disagrees with "
                f"final counter sample {last[name]}"
            )
    return events, last


def validate_trace(obj: dict) -> dict:
    """Validate a loaded trace object; returns {"events", "expected"} on
    success, raises ValueError otherwise."""
    events, _ = _check_chrome_shape(obj)
    totals = obj.get("counterTotals", {})

    billing = obj.get("billing")
    if not isinstance(billing, list) or not billing:
        raise ValueError("trace must carry a non-empty billing list")
    expected = {"uplink_bits": 0, "downlink_bits": 0}
    for spec in billing:
        exp = _expected_for(spec)
        expected["uplink_bits"] += exp["uplink_bits"]
        expected["downlink_bits"] += exp["downlink_bits"]
    got = {k: int(totals.get(k, 0)) for k in expected}
    reg.assert_billing("trace", got, expected)
    return {"events": len(events), "expected": expected}


_OBJECTIVE_KINDS = frozenset({"threshold", "burn_rate"})


def validate_slo_verdict(obj: dict) -> dict:
    """Schema gate for the machine-readable SLO verdict (obs/slo.py):
    {"spec", "ok", "objectives": [...], "breaches"} with internally
    consistent ok/breaches. Returns {"objectives": n} or raises."""
    if not isinstance(obj, dict):
        raise ValueError("slo verdict must be a JSON object")
    if not isinstance(obj.get("spec"), str) or not obj["spec"]:
        raise ValueError("slo verdict needs a non-empty spec name")
    if not isinstance(obj.get("ok"), bool):
        raise ValueError("slo verdict needs a boolean ok")
    objectives = obj.get("objectives")
    if not isinstance(objectives, list) or not objectives:
        raise ValueError("slo verdict needs a non-empty objectives list")
    bad = []
    for i, r in enumerate(objectives):
        if not isinstance(r, dict):
            raise ValueError(f"objectives[{i}] is not an object")
        for key in ("name", "kind", "metric", "ok"):
            if key not in r:
                raise ValueError(f"objectives[{i}] missing {key!r}")
        if r["kind"] not in _OBJECTIVE_KINDS:
            raise ValueError(f"objectives[{i}] has unknown kind {r['kind']!r}")
        if not isinstance(r["ok"], bool):
            raise ValueError(f"objectives[{i}].ok must be boolean")
        if r.get("observed") is not None and not _num(r["observed"]):
            raise ValueError(f"objectives[{i}].observed must be numeric or null")
        if not r["ok"]:
            bad.append(r["name"])
    breaches = obj.get("breaches")
    if not isinstance(breaches, list):
        raise ValueError("slo verdict needs a breaches list")
    if obj["ok"] != (not breaches):
        raise ValueError("slo verdict ok flag disagrees with breaches list")
    # per-cell verdicts prefix breach names with "K=<cell>:" — require
    # every failing objective to be accounted for in breaches
    for name in bad:
        if not any(b == name or b.endswith(f":{name}") for b in breaches):
            raise ValueError(f"failing objective {name!r} missing from breaches")
    return {"objectives": len(objectives)}


def validate_flight(obj: dict) -> dict:
    """Schema gate for FLIGHT_*.json snapshots (obs/flight.py): Chrome
    shape + a `flight` ring block; NO billing requirement. An embedded
    slo_verdict is validated too. Returns {"events", "dropped"}."""
    events, _ = _check_chrome_shape(obj)
    flight = obj.get("flight")
    if not isinstance(flight, dict):
        raise ValueError("flight file needs a flight block")
    if not isinstance(flight.get("reason"), str) or not flight["reason"]:
        raise ValueError("flight block needs a non-empty reason")
    cap = flight.get("capacity")
    if not isinstance(cap, int) or isinstance(cap, bool) or cap < 1:
        raise ValueError("flight block needs integer capacity >= 1")
    dropped = flight.get("dropped")
    if not isinstance(dropped, int) or isinstance(dropped, bool) or dropped < 0:
        raise ValueError("flight block needs integer dropped >= 0")
    recorded = sum(1 for ev in events if ev.get("ph") != "M")
    if recorded > cap:
        raise ValueError(
            f"flight file holds {recorded} recorded events but claims "
            f"capacity {cap}"
        )
    if "slo_verdict" in obj:
        validate_slo_verdict(obj["slo_verdict"])
    return {"events": len(events), "dropped": dropped}


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate_trace "
              "[--flight] TRACE.json [...]", file=sys.stderr)
        return 2
    as_flight = False
    for path in argv:
        if path == "--flight":
            as_flight = True        # remaining paths are flight snapshots
            continue
        with open(path) as fh:
            obj = json.load(fh)
        if as_flight:
            info = validate_flight(obj)
            print(f"{path}: OK (flight, {info['events']} events, "
                  f"dropped={info['dropped']})")
        else:
            info = validate_trace(obj)
            print(f"{path}: OK ({info['events']} events, "
                  f"uplink={info['expected']['uplink_bits']} "
                  f"downlink={info['expected']['downlink_bits']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
