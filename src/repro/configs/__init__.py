"""Assigned architecture pool (10 archs, 6 families) + the paper's own nets.

Every entry cites its source paper/model card. `get(name)` returns the full
ArchConfig; `get(name).reduced()` is the CPU smoke-test variant.

Reachability audit (PR 5): every one of the ten configs is exercised —
tests/test_models.py and tests/test_sharding_data_ckpt.py parametrize over
ARCH_NAMES, launch/dryrun.py --all compiles all of them, launch/train.py
and serve_bench/test_serve/test_perf_features use granite-8b and
starcoder2-7b directly, and benchmarks/async_bench.py prices the Table-2
cost model at granite-8b's REAL parameter count (n ≈ 8.25e9 via
jax.eval_shape — the README table's n = 1e6 is the paper's toy setting).
A config removed from this registry fails tests; none are dead weight.
"""
from repro.configs import (
    falcon_mamba_7b, starcoder2_7b, granite_moe_3b, internvl2_26b,
    h2o_danube3_4b, zamba2_2p7b, deepseek_67b, deepseek_v2_236b,
    granite_8b, seamless_m4t_medium,
)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        falcon_mamba_7b, starcoder2_7b, granite_moe_3b, internvl2_26b,
        h2o_danube3_4b, zamba2_2p7b, deepseek_67b, deepseek_v2_236b,
        granite_8b, seamless_m4t_medium,
    )
}

ARCH_NAMES = sorted(REGISTRY)


def get(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return REGISTRY[name]
