"""Assigned architecture pool (10 archs, 6 families) + the paper's own nets.

Every entry cites its source paper/model card. `get(name)` returns the full
ArchConfig; `get(name).reduced()` is the CPU smoke-test variant.
"""
from repro.configs import (
    falcon_mamba_7b, starcoder2_7b, granite_moe_3b, internvl2_26b,
    h2o_danube3_4b, zamba2_2p7b, deepseek_67b, deepseek_v2_236b,
    granite_8b, seamless_m4t_medium,
)

REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        falcon_mamba_7b, starcoder2_7b, granite_moe_3b, internvl2_26b,
        h2o_danube3_4b, zamba2_2p7b, deepseek_67b, deepseek_v2_236b,
        granite_8b, seamless_m4t_medium,
    )
}

ARCH_NAMES = sorted(REGISTRY)


def get(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return REGISTRY[name]
