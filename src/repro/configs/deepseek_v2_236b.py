"""deepseek-v2-236b — MLA (kv_lora=512) + 160 routed top-6 + 2 shared
experts [arXiv:2405.04434]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv=128, d_ff=1536, vocab=102400,
    n_experts=160, top_k=6, n_shared_experts=2,
    q_lora=1536, kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    source="arXiv:2405.04434",
)
