"""seamless-m4t-medium — enc-dec multimodal; mel/conv audio frontend is a
STUB per brief (input_specs provides frame embeddings) [arXiv:2308.11596]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", n_layers=12, enc_layers=12,
    d_model=1024, n_heads=16, n_kv=16, d_ff=4096, vocab=256206, head_dim=64,
    source="arXiv:2308.11596",
)
