"""zamba2-2.7b — Mamba-2 backbone with ONE shared attention block applied
every 6 SSM layers [arXiv:2411.15242]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv=32, d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_variant="mamba2", ssm_head_dim=64, expand=2,
    shared_attn_every=6, window=4096,  # shared attn uses SWA in long-ctx mode
    source="arXiv:2411.15242",
)
