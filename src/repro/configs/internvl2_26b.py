"""internvl2-26b — InternViT frontend (STUB per brief) + InternLM2-20B
decoder backbone [arXiv:2404.16821]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv=8, d_ff=16384, vocab=92553, head_dim=128,
    num_patches=256, source="arXiv:2404.16821",
)
