"""granite-moe-3b-a800m — 40-expert top-8 MoE, GQA kv=8
[hf:ibm-granite/granite-3.0-1b-a400m-base family; 3b-a800m scale]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv=8, d_ff=512, vocab=49155, head_dim=64,
    n_experts=40, top_k=8, source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
